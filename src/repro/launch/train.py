"""End-to-end training driver: tiered data pipeline -> SPMD train step ->
two-tier checkpointing, with failure injection + restart (fault tolerance).

CPU-scale usage (examples/train_tiered.py drives a ~100M model):

  PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b --reduced \
      --steps 200 --batch 8 --seq 128

Fault tolerance:
  - skip-update on non-finite grad norm (data/numeric faults);
  - tier-1/tier-2 checkpoints + newest-valid restore (worker restarts);
  - ``--kill-at N`` simulates a mid-run failure: the process exits at step N
    and a relaunch resumes from the newest checkpoint (restart drill);
  - elastic: checkpoints are mesh-independent (global leaves), so a restart
    may use a different mesh/device count.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.archs import get_config
from repro.distributed.axes import SINGLE
from repro.models import params as pm
from repro.storage.datacache import (
    DataCache, DataCacheConfig, ShardedTokenStore,
)
from repro.training.checkpoint import (
    CheckpointConfig, restore_checkpoint, save_checkpoint,
)
from repro.training.compression import init_error_feedback
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training.train_step import TrainHyper, TrainState, make_train_step

__all__ = ["run_training", "main"]


def run_training(
    *,
    arch: str = "stablelm-3b",
    reduced: bool = True,
    steps: int = 100,
    batch: int = 8,
    seq: int = 128,
    lr: float = 3e-4,
    data_dir: str = "data/shards",
    ckpt: CheckpointConfig = CheckpointConfig(),
    kill_at: int = -1,
    resume: bool = True,
    log_every: int = 10,
    d_model_override: int = 0,
) -> dict:
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    if d_model_override:
        cfg = dataclasses.replace(
            cfg, d_model=d_model_override,
            n_heads=max(4, d_model_override // 64), head_dim=64,
            n_kv_heads=max(1, min(cfg.n_kv_heads, 4)),
            d_ff=d_model_override * 3 if cfg.d_ff else 0,
        )
    ms = pm.MeshSizes()
    ax = SINGLE

    params = pm.init_params(cfg, jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    state = TrainState(
        params=params,
        opt=adamw_init(params, cfg.opt_state_dtype),
        err_fb=init_error_feedback(params),
    )
    start = 0
    if resume:
        try:
            state, start = restore_checkpoint(state, ckpt)
            print(f"[restore] resumed from step {start}")
        except FileNotFoundError:
            pass

    hyper = TrainHyper(adamw=AdamWConfig(lr=lr, warmup_steps=20,
                                         decay_steps=max(steps, 100)))
    step_fn = jax.jit(make_train_step(cfg, ax, ms, hyper))

    store = ShardedTokenStore(data_dir, n_shards=16,
                              shard_tokens=batch * (seq + 1) * 4,
                              vocab=cfg.vocab)
    cache = DataCache(store, DataCacheConfig(cache_shards=4))

    losses = []
    t0 = time.time()
    for step in range(start, steps):
        b = cache.batch(step, batch, seq)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        state, metrics = step_fn(state, b)
        losses.append(float(metrics["loss"]))
        if step % log_every == 0 or step == steps - 1:
            print(f"step {step:5d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"cache hit% {100*cache.hits/max(cache.hits+cache.misses,1):.0f}")
        save_checkpoint(state, step + 1, ckpt)
        if kill_at == step:
            print(f"[fault-injection] simulated failure at step {step}")
            return {"killed_at": step, "losses": losses,
                    "n_params": n_params}
    return {
        "losses": losses,
        "final_loss": losses[-1] if losses else float("nan"),
        "steps_per_s": (steps - start) / max(time.time() - t0, 1e-9),
        "n_params": n_params,
        "cache_hits": cache.hits,
        "cache_misses": cache.misses,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--kill-at", type=int, default=-1)
    ap.add_argument("--d-model", type=int, default=0)
    args = ap.parse_args()
    out = run_training(arch=args.arch, reduced=args.reduced, steps=args.steps,
                       batch=args.batch, seq=args.seq, lr=args.lr,
                       kill_at=args.kill_at, d_model_override=args.d_model)
    print({k: v for k, v in out.items() if k != "losses"})


if __name__ == "__main__":
    main()
