"""Mesh construction for the production topology.

Single pod: 16x16 = 256 chips, axes ("data", "model").
Multi-pod:  2x16x16 = 512 chips, axes ("pod", "data", "model") — the "pod"
axis is pure data parallelism over the inter-pod links.

``make_production_mesh`` is a function (not a module constant) so importing
this module never touches jax device state; the dry-run sets
``--xla_force_host_platform_device_count=512`` before any jax import.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh", "axes_for_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    return jax.make_mesh(shape, axes)


def axes_for_mesh(mesh):
    """Axes context matching a mesh's axis names."""
    from repro.distributed.axes import Axes

    names = mesh.axis_names
    return Axes(
        data="data" if "data" in names else None,
        model="model" if "model" in names else None,
        pod="pod" if "pod" in names else None,
    )
