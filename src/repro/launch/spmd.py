"""shard_map wrappers: build fully-sharded train/serve steps for a mesh.

This is the glue between the pure SPMD step bodies (``training/train_step``,
``serving/engine``) and a concrete mesh: it derives every PartitionSpec from
the declarative param defs and wraps the body in shard_map + jit.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# The version-agnostic shard_map shim lives in repro.launch.compat so light
# consumers (the sweep engine) can share it without importing this module's
# model/training dependency tree; re-exported here for existing callers.
from repro.launch.compat import shard_map  # noqa: F401

from repro.configs.base import ModelConfig
from repro.distributed.axes import Axes
from repro.launch.mesh import axes_for_mesh
from repro.models import params as pm
from repro.training.optimizer import AdamWState
from repro.training.train_step import TrainHyper, TrainState, make_train_step

__all__ = [
    "mesh_sizes",
    "batch_pspec",
    "state_pspecs",
    "build_train_step",
    "batch_structs",
]


def mesh_sizes(mesh) -> pm.MeshSizes:
    names = dict(zip(mesh.axis_names, mesh.devices.shape))
    return pm.MeshSizes(data=names.get("data", 1), model=names.get("model", 1))


def _batch_axes(mesh):
    names = mesh.axis_names
    ax = tuple(n for n in ("pod", "data") if n in names)
    return ax if ax else None


def batch_pspec(cfg: ModelConfig, mesh) -> dict:
    """Batch dim sharded over (pod, data); everything else replicated."""
    b = _batch_axes(mesh)
    spec = {"tokens": P(b), "labels": P(b)}
    if cfg.vlm_prefix:
        spec["prefix_embeds"] = P(b)
    if cfg.enc_dec:
        spec["frames"] = P(b)
    return spec


def batch_structs(
    cfg: ModelConfig, *, global_batch: int, seq_len: int
) -> dict:
    """ShapeDtypeStruct stand-ins for a global training batch (dry-run)."""
    s_txt = seq_len - cfg.vlm_prefix
    out = {
        "tokens": jax.ShapeDtypeStruct((global_batch, s_txt), jnp.int32),
        "labels": jax.ShapeDtypeStruct((global_batch, s_txt), jnp.int32),
    }
    if cfg.vlm_prefix:
        out["prefix_embeds"] = jax.ShapeDtypeStruct(
            (global_batch, cfg.vlm_prefix, cfg.d_model), jnp.bfloat16
        )
    if cfg.enc_dec:
        out["frames"] = jax.ShapeDtypeStruct(
            (global_batch, cfg.enc_seq, cfg.d_model), jnp.bfloat16
        )
    return out


def state_pspecs(cfg: ModelConfig, mesh) -> TrainState:
    ms = mesh_sizes(mesh)
    names = mesh.axis_names
    pspec = pm.param_pspecs(
        cfg, ms,
        data_axis="data" if "data" in names else None,
        model_axis="model" if "model" in names else None,
    )
    return TrainState(
        params=pspec,
        opt=AdamWState(step=P(), mu=pspec, nu=pspec),
        err_fb=pspec,
    )


def build_train_step(
    cfg: ModelConfig,
    mesh,
    hyper: TrainHyper = TrainHyper(),
):
    """Returns (jitted step fn, state_specs, batch_specs)."""
    ms = mesh_sizes(mesh)
    ax = axes_for_mesh(mesh)
    body = make_train_step(cfg, ax, ms, hyper)
    st_spec = state_pspecs(cfg, mesh)
    b_spec = batch_pspec(cfg, mesh)
    metrics_spec = {k: P() for k in ("loss", "grad_norm", "aux_loss", "dropped")}
    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(st_spec, b_spec),
        out_specs=(st_spec, metrics_spec),
        check_vma=True,
    )
    return jax.jit(fn), st_spec, b_spec


# ---------------------------------------------------------------------------
# Serving wrappers (prefill / decode) — device-local state is stacked over
# every mesh axis (dim 0) in the global view; see serving/kvpool.py.
# ---------------------------------------------------------------------------


def _all_axes(mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)


def _state_pspec_tree(state_structs, mesh):
    axes = _all_axes(mesh)

    def one(sds):
        return P(axes, *([None] * (len(sds.shape) - 1)))

    return jax.tree.map(one, state_structs)


def serve_state_global_structs(state_structs, mesh):
    """Global ShapeDtypeStructs: device-local dim0 stacked over all devices."""
    n = mesh.devices.size

    def one(sds):
        return jax.ShapeDtypeStruct((sds.shape[0] * n,) + sds.shape[1:],
                                    sds.dtype)

    return jax.tree.map(one, state_structs)


def build_serve(cfg: ModelConfig, mesh, sc):
    """Returns (jit prefill, jit decode, specs dict) for an (arch, shape).

    sc: ServeConfig with batch_local = global_batch / batch_shards and
    page_axes naming mesh axes that shard the paged KV pools.
    """
    from repro.distributed.axes import pvary_tree
    from repro.serving.engine import (
        decode_state_structs, make_decode_step, make_prefill_step,
    )

    ms = mesh_sizes(mesh)
    ax = axes_for_mesh(mesh)
    names = mesh.axis_names
    batch_axes = tuple(n for n in ("pod", "data") if n in names
                       and n not in sc.page_axes)
    n_page_shards = 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for n in sc.page_axes:
        n_page_shards *= sizes.get(n, 1)

    p_spec = pm.param_pspecs(
        cfg, ms,
        data_axis="data" if "data" in names else None,
        model_axis="model" if "model" in names else None,
    )
    st_structs = decode_state_structs(cfg, sc, n_page_shards, ms)
    st_spec = _state_pspec_tree(st_structs, mesh)
    tok_spec = P(batch_axes if batch_axes else None)
    out_tok_spec = (tok_spec, tok_spec)  # (next_token, logprob)

    decode_body = make_decode_step(cfg, sc, ax, ms)

    # Token outputs are value-identical across non-batch axes but the type
    # system cannot prove it through all-gathered weights; a pvary+pmax pair
    # (numerically a no-op on identical values) settles them to invariant.
    clear_axes = tuple(n for n in names if n not in batch_axes)

    def _settle(v):
        if not clear_axes:
            return v
        v = pvary_tree(v, clear_axes)
        return jax.lax.pmax(v, clear_axes)

    def decode_wrapped(params, state, tokens):
        new_state, out = decode_body(params, state, tokens)
        out = jax.tree.map(_settle, out)
        return pvary_tree(new_state, names), out

    decode_fn = shard_map(
        decode_wrapped, mesh=mesh,
        in_specs=(p_spec, st_spec, tok_spec),
        out_specs=(st_spec, out_tok_spec),
        check_vma=True,
    )

    prefill_body = make_prefill_step(cfg, sc, ax, ms)
    extras_spec = {}
    if cfg.enc_dec:
        extras_spec["frames"] = tok_spec
    if cfg.vlm_prefix:
        extras_spec["prefix_embeds"] = tok_spec

    def prefill_wrapped(params, tokens, extras):
        state, out = prefill_body(params, tokens, extras)
        out = jax.tree.map(_settle, out)
        return pvary_tree(state, names), out

    prefill_fn = shard_map(
        prefill_wrapped, mesh=mesh,
        in_specs=(p_spec, tok_spec, extras_spec),
        out_specs=(st_spec, out_tok_spec),
        check_vma=True,
    )

    specs = dict(params=p_spec, state=st_spec, state_structs=st_structs,
                 tokens=tok_spec, extras=extras_spec,
                 batch_axes=batch_axes, n_page_shards=n_page_shards)
    return jax.jit(prefill_fn), jax.jit(decode_fn), specs
