import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e) + roofline extraction (deliverable g).

For every (architecture × input shape) cell:
  - build the production mesh (16x16 single-pod, or 2x16x16 multi-pod),
  - lower + compile the cell's step (train_step / prefill_step / decode_step)
    against ShapeDtypeStruct inputs (no allocation),
  - record memory_analysis(), cost_analysis() FLOPs/bytes, and the
    collective wire bytes parsed from the optimized HLO,
  - derive the three roofline terms (core/roofline.py).

Results are written incrementally to a JSON file so interrupted runs resume.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
      [--mesh pod1|pod2|both] [--out PATH] [--force]
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.archs import ARCHS, get_config
from repro.configs.base import SHAPES, ModelConfig, ShapeSpec
from repro.core import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.launch import spmd
from repro.models import params as pm
from repro.serving.engine import ServeConfig
from repro.training.optimizer import AdamWState
from repro.training.train_step import TrainHyper, TrainState

DEFAULT_OUT = "benchmarks/results/dryrun.json"


# ---------------------------------------------------------------------------
# Cell construction.
# ---------------------------------------------------------------------------


def active_param_count(cfg: ModelConfig, ms: pm.MeshSizes) -> tuple[int, int]:
    """(N_total, N_active) from the actual parameter structs."""
    structs = pm.param_structs(cfg, ms)
    total = 0
    active = 0
    scale_names = {"w_gate", "w_up", "w_down"} if cfg.moe else set()
    ratio = (cfg.moe.top_k / cfg.moe.n_experts) if cfg.moe else 1.0

    def walk(tree, path=()):
        nonlocal total, active
        if isinstance(tree, dict):
            for k, v in tree.items():
                walk(v, path + (k,))
        elif isinstance(tree, list):
            for i, v in enumerate(tree):
                walk(v, path + (i,))
        else:
            n = 1
            for d in tree.shape:
                n *= d
            total += n
            name = path[-1]
            active += int(n * ratio) if name in scale_names else n

    walk(structs)
    return total, active


def model_flops(cfg: ModelConfig, shape: ShapeSpec, ms: pm.MeshSizes) -> float:
    _, n_active = active_param_count(cfg, ms)
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch  # decode: per step


def long_ctx_supported(cfg: ModelConfig) -> bool:
    """long_500k runs only for sub-quadratic attention families."""
    return all(k != "attn_full" for k in cfg.block_pattern)


def serve_config(cfg: ModelConfig, shape: ShapeSpec, mesh) -> ServeConfig:
    names = mesh.axis_names
    sizes = dict(zip(names, mesh.devices.shape))
    if shape.name == "long_500k":
        page_axes = tuple(n for n in ("pod", "data", "model") if n in names)
        batch_shards = 1
    else:
        page_axes = ("model",)
        batch_shards = sizes.get("pod", 1) * sizes.get("data", 1)
    b_local = max(1, shape.global_batch // batch_shards)
    return ServeConfig(
        max_seq=shape.seq_len,
        batch_local=b_local,
        page_axes=page_axes,
        mapping="block_cyclic",
        hbm_fraction=0.5,
    )


def lower_cell(arch: str, shape_name: str, mesh, *, cfg=None, sc_patch=None):
    cfg = cfg or get_config(arch)
    shape = SHAPES[shape_name]
    ms = spmd.mesh_sizes(mesh)

    if shape.kind == "train":
        step, st_spec, b_spec = spmd.build_train_step(cfg, mesh, TrainHyper())
        params = pm.param_structs(cfg, ms)
        opt_dt = jnp.dtype(cfg.opt_state_dtype)
        opt = AdamWState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            mu=jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, opt_dt),
                            params),
            nu=jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, opt_dt),
                            params),
        )
        err = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params
        )
        state = TrainState(params=params, opt=opt, err_fb=err)
        batch = spmd.batch_structs(
            cfg, global_batch=shape.global_batch, seq_len=shape.seq_len
        )
        with mesh:
            return step.lower(state, batch)

    sc = serve_config(cfg, shape, mesh)
    if sc_patch:
        sc = dataclasses.replace(sc, **sc_patch)
    prefill_fn, decode_fn, specs = spmd.build_serve(cfg, mesh, sc)
    params = pm.param_structs(cfg, ms)
    n_batch_shards = max(1, shape.global_batch // sc.batch_local) \
        if specs["batch_axes"] else 1
    gb = sc.batch_local * (
        1 if not specs["batch_axes"] else _axes_size(mesh, specs["batch_axes"])
    )
    st_global = spmd.serve_state_global_structs(specs["state_structs"], mesh)

    if shape.kind == "prefill":
        s_txt = shape.seq_len - cfg.vlm_prefix
        tokens = jax.ShapeDtypeStruct((gb, s_txt), jnp.int32)
        extras = {}
        if cfg.enc_dec:
            extras["frames"] = jax.ShapeDtypeStruct(
                (gb, cfg.enc_seq, cfg.d_model), jnp.dtype(cfg.param_dtype))
        if cfg.vlm_prefix:
            extras["prefix_embeds"] = jax.ShapeDtypeStruct(
                (gb, cfg.vlm_prefix, cfg.d_model), jnp.dtype(cfg.param_dtype))
        with mesh:
            return prefill_fn.lower(params, tokens, extras)

    tokens = jax.ShapeDtypeStruct((gb,), jnp.int32)
    with mesh:
        return decode_fn.lower(params, st_global, tokens)


def _axes_size(mesh, axes) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in axes:
        n *= sizes.get(a, 1)
    return n


# ---------------------------------------------------------------------------
# Per-cell record.
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             cfg_patch=None, sc_patch=None) -> dict:
    cfg = get_config(arch)
    if cfg_patch:
        cfg = dataclasses.replace(cfg, **cfg_patch)
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and not long_ctx_supported(cfg):
        return {
            "status": "skipped",
            "reason": "pure full-attention arch: 512k decode needs "
                      "sub-quadratic attention (DESIGN.md §4)",
        }
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()
    lowered = lower_cell(arch, shape_name, mesh, cfg=cfg, sc_patch=sc_patch)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    mem_rec = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "peak_memory_in_bytes"):
        v = getattr(mem, f, None)
        if v is not None:
            mem_rec[f] = int(v)

    cost = compiled.cost_analysis() or {}
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))

    hlo = compiled.as_text()
    # XLA's cost_analysis counts while (lax.scan) bodies once; use the
    # trip-count-corrected HLO walker instead (validated in tests).
    walked = rl.hlo_cost(hlo)
    flops = walked["flops"]
    bytes_ = walked["bytes"]
    bytes_all = walked["bytes_all"]
    coll = rl.module_collective_bytes(hlo)
    ms = spmd.mesh_sizes(mesh)
    mf = model_flops(cfg, shape, ms)
    # The compiled module is the per-device SPMD program: HLO flops/bytes
    # and the parsed collective wire bytes are all PER DEVICE. Pass chips=1
    # and the per-chip slice of MODEL_FLOPS.
    report = rl.roofline_report(
        hlo_flops=flops * 1.0,
        hlo_bytes=bytes_,
        coll=coll,
        chips=1,
        model_flops=mf / chips,
    )
    report.update(
        status="ok",
        chips=chips,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        memory=mem_rec,
        hlo_bytes_accessed=bytes_,
        hlo_bytes_all_ops=bytes_all,
        xla_cost_flops=xla_flops,
        xla_cost_bytes=xla_bytes,
        collective_wire_bytes_total=coll.wire_bytes,
    )
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["pod1", "pod2", "both"])
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    results = {}
    if os.path.exists(args.out) and not args.force:
        with open(args.out) as f:
            results = json.load(f)

    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"pod1": [False], "pod2": [True], "both": [False, True]}[args.mesh]

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                key = f"{arch}|{shape}|{'pod2' if mp else 'pod1'}"
                if key in results and results[key].get("status") in (
                        "ok", "skipped") and not args.force:
                    print(f"[skip-cached] {key}")
                    continue
                print(f"[run] {key} ...", flush=True)
                try:
                    rec = run_cell(arch, shape, mp)
                except Exception as e:  # record failures for triage
                    rec = {"status": "error", "error": repr(e),
                           "trace": traceback.format_exc()[-2000:]}
                results[key] = rec
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1, sort_keys=True)
                status = rec.get("status")
                extra = ""
                if status == "ok":
                    extra = (f" dom={rec['dominant']}"
                             f" frac={rec['roofline_frac']:.3f}"
                             f" compile={rec['compile_s']}s")
                print(f"[done] {key}: {status}{extra}", flush=True)

    n_ok = sum(1 for r in results.values() if r.get("status") == "ok")
    n_skip = sum(1 for r in results.values() if r.get("status") == "skipped")
    n_err = sum(1 for r in results.values() if r.get("status") == "error")
    print(f"\nTOTAL ok={n_ok} skipped={n_skip} error={n_err}")


if __name__ == "__main__":
    main()
