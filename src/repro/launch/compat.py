"""Version-agnostic jax SPMD compat shims.

Small, dependency-free home for the cross-version wrappers used by both the
heavyweight launch layer (:mod:`repro.launch.spmd`) and light consumers like
the sweep engine (:mod:`repro.sim.sweep`), which must not drag the model /
training stack into their import graph.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh

try:  # jax >= 0.6: top-level export, replication check spelled check_vma
    from jax import shard_map as _shard_map

    _CHECK_KW = "check_vma"
except ImportError:  # jax <= 0.4.x: experimental module, kwarg is check_rep
    from jax.experimental.shard_map import shard_map as _shard_map

    _CHECK_KW = "check_rep"

__all__ = ["shard_map", "device_mesh"]


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """Version-agnostic shard_map: translates ``check_vma`` to the kwarg the
    installed jax understands. Pre-vma jax's ``check_rep`` inference cannot
    prove replication through our psum/all_gather patterns (it rejects specs
    the vma system accepts), so there the check is disabled outright."""
    check = check_vma if _CHECK_KW == "check_vma" else False
    return _shard_map(
        f,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        **{_CHECK_KW: check},
    )


def device_mesh(axis_name: str, devices=None) -> Mesh:
    """A 1-D mesh over ``devices`` (default: all *local* devices, so callers
    that pad host-side batches to ``jax.local_device_count()`` agree with the
    mesh size even under multi-process jax)."""
    devs = list(jax.local_devices() if devices is None else devices)
    return Mesh(np.asarray(devs), (axis_name,))
