"""Serving launcher: batched requests through the paged two-tier engine.

CPU-scale usage (reduced configs):

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x22b \
      --requests 8 --prompt 32 --new 32 --hbm-fraction 0.5 [--int8-kv]

Prints per-request generations stats and the tier-1/tier-2 traffic +
OL-learner state — the paper's fig. 2 pipeline end to end. The same engine
lowers on the production mesh via launch/dryrun.py (decode/prefill cells).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.archs import get_config
from repro.distributed.axes import SINGLE
from repro.models import params as pm
from repro.serving import kvpool as kvp
from repro.serving.engine import (
    ServeConfig, make_decode_step, make_kv_spec, make_prefill_step,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x22b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--new", type=int, default=32)
    ap.add_argument("--hbm-fraction", type=float, default=0.5)
    ap.add_argument("--int8-kv", action="store_true")
    ap.add_argument("--promote-every", type=int, default=4)
    ap.add_argument("--full", action="store_true",
                    help="use the full config (TPU-scale; default reduced)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    ms = pm.MeshSizes()
    params = pm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    max_seq = args.prompt + args.new
    max_seq = -(-max_seq // cfg.page_size) * cfg.page_size
    sc = ServeConfig(
        max_seq=max_seq, batch_local=args.requests, page_axes=(),
        hbm_fraction=args.hbm_fraction,
        kv_dtype="int8" if args.int8_kv else "auto",
    )
    spec = make_kv_spec(cfg, sc, 1)

    prompts = rng.integers(0, cfg.vocab, (args.requests, args.prompt))
    prompts = prompts.astype(np.int32)
    extras = {}
    if cfg.enc_dec:
        extras["frames"] = jnp.asarray(
            rng.normal(size=(args.requests, cfg.enc_seq, cfg.d_model)) * 0.02,
            jnp.dtype(cfg.param_dtype))
    if cfg.vlm_prefix:
        extras["prefix_embeds"] = jnp.asarray(
            rng.normal(size=(args.requests, cfg.vlm_prefix, cfg.d_model))
            * 0.02, jnp.dtype(cfg.param_dtype))

    prefill = jax.jit(make_prefill_step(cfg, sc, SINGLE, ms))
    decode = jax.jit(make_decode_step(cfg, sc, SINGLE, ms))
    promote = jax.jit(lambda kv: kvp.promote_pages(kv, spec, sc.n_promote))

    t0 = time.time()
    state, (tok, lp) = prefill(params, jnp.asarray(prompts), extras)
    jax.block_until_ready(tok)
    t_prefill = time.time() - t0

    outs = [np.asarray(tok)]
    t0 = time.time()
    for t in range(args.new - 1):
        state, (tok, lp) = decode(params, state, tok)
        outs.append(np.asarray(tok))
        if state.kv is not None and t % args.promote_every == (
                args.promote_every - 1):
            state = state._replace(kv=promote(state.kv))
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = np.stack(outs, axis=1)
    print(f"arch={cfg.name} requests={args.requests} prompt={args.prompt} "
          f"new={args.new} kv={'int8' if args.int8_kv else cfg.param_dtype}")
    print(f"prefill {t_prefill:.2f}s; decode {t_decode:.2f}s "
          f"({args.requests * (args.new - 1) / max(t_decode, 1e-9):.1f} tok/s "
          f"aggregate, CPU)")
    if state.kv is not None:
        kv = state.kv
        total = int(kv.t1_reads[0]) + int(kv.t2_reads[0])
        print(f"tier-1 page reads {int(kv.t1_reads[0])}, tier-2 (miss) "
              f"{int(kv.t2_reads[0])} -> hit rate "
              f"{100 * int(kv.t1_reads[0]) / max(total, 1):.1f}%")
        print(f"OL weights (lru/lfu/random): "
              f"{np.round(np.asarray(kv.ols.weights), 3)}")
    print(f"first generations: {gen[:2, :8].tolist()}")


if __name__ == "__main__":
    main()
