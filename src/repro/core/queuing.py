"""Queuing-network performance model of the two-tier store (paper §V).

Implements equations 1–7 plus the standard M/M/1, M/M/k and (Allen–Cunneen
approximate) M/G/k building blocks, and the paper's worked example.

The network (Fig. 5): read/write requests arrive at tier 1 at rate λ; hits
exit via the k-server RPC pool (M/G/k, service rate μ1); misses (fraction
``p12``) enter the single IO-thread queue (M/M/1, service rate μ2) and
re-enter tier 1 when serviced. The system is analyzable at equilibrium
(all utilization ratios < 1).

Two conventions for the *effective arrival rate* at the k-server queue:

- ``flow="paper"`` reproduces §V's worked example, which feeds the miss
  traffic back at rate ``p12 * μ2``  (λ_eff = (1-p12)·λ + p12·μ2; gives
  λ_eff = 86.6 for the example).
- ``flow="conserving"`` uses flow conservation at equilibrium (the miss
  queue's throughput equals its arrival rate): λ_eff = (1-p12)·λ + p12·λ = λ.

Every queue primitive and :class:`TwoTierModel` is **vectorized**: λ, μ and
``p12`` may be scalars or arbitrary-shape numpy arrays (broadcast against
each other); ``k`` stays a Python int (it is structural). Scalar inputs
return plain-float metrics, array inputs return arrays elementwise equal to
the scalar formulas — one call solves a whole ``[point, shard]`` or
``[shard, window]`` grid instead of a Python loop.

Beyond the equilibrium analysis, :func:`transient_two_tier` solves the
network over time windows in one of two modes:

- ``mode="piecewise"``: each window is an *independent* stationary solve at
  that window's measured arrival rate and miss fraction (the PR 4 path,
  kept as the stationary-limit oracle);
- ``mode="fluid"`` (:func:`fluid_two_tier`, the pipeline default): a
  pointwise-stationary fluid ODE ``dQ/dt = lam(t) - G(Q)`` integrated over
  the window grid **with queue-length carryover between windows**. The
  drain ``G`` is the exact inverse of the stationary queue-length map
  (PSFFA — for M/M/1, ``G(Q) = mu*Q/(1+Q)``; the pure-fluid limit of
  ``G`` is ``mu*min(Q, k)``), so constant-rate workloads land exactly on
  the piecewise/stationary solution while rate bursts show non-instant
  backlog drain — the transient view the paper's steady-state summary
  (and a window-independent solve) hides.

The fluid path additionally models **degraded-mode dynamics**: μ1/μ2 may
vary per window (fault schedules — a dead device is μ(t) = 0, handled
exactly: backlog grows at λ(t) and residence times report ∞ only where
load is actually offered), ``k_scale`` scales effective tier-1 capacity
over time, ``tier1_spill=True`` routes offered-above-capacity tier-1 work
to tier-2, and ``retry=RetryPolicy(...)`` closes a retrial-orbit feedback
loop (``dQ/dt = λ(t) + λ_retry(Q,t) − G(Q; μ(t))``): work that times out
re-enters the arrival stream after its backoff delay, so aggressive
timeouts produce *retry storms* — windows flagged ``metastable`` (stable
in external rates, unstable in total offered rate) with
:meth:`FluidReport.metastable_onset` locating the trailing storm.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal, NamedTuple, Optional

import numpy as np

__all__ = [
    "ServiceTimes",
    "service_time_model",
    "system_service_rate",
    "mm1_queue",
    "mmk_queue",
    "mgk_queue",
    "QueueMetrics",
    "RetryPolicy",
    "TwoTierModel",
    "TwoTierReport",
    "TransientReport",
    "FluidReport",
    "transient_two_tier",
    "fluid_two_tier",
    "fluid_two_tier_batched",
    "fluid_compile_count",
    "reset_fluid_compile_count",
    "residence_times",
    "expected_response",
]


# ---------------------------------------------------------------------------
# Equations 1–4: total service time (non-equilibrium / minimum-time model).
# ---------------------------------------------------------------------------


class ServiceTimes(NamedTuple):
    t_hit: np.ndarray   # T_h_i per process (eq. 1)
    t_miss: np.ndarray  # T_m_i per process (eq. 2)
    t_proc: np.ndarray  # T_i = max(T_h, T_m) per process (eq. 3)
    t_total: float      # T = max_i T_i (eq. 4)


def service_time_model(
    n_read: np.ndarray,
    n_write: np.ndarray,
    n_miss: np.ndarray,
    mu1_read: float,
    mu1_write: float,
    mu2: float,
) -> ServiceTimes:
    """Equations 1–4. Inputs are per-process request/miss counts."""
    n_read = np.asarray(n_read, float)
    n_write = np.asarray(n_write, float)
    n_miss = np.asarray(n_miss, float)
    t_hit = n_read / mu1_read + n_write / mu1_write
    t_miss = n_miss / mu2
    t_proc = np.maximum(t_hit, t_miss)
    return ServiceTimes(t_hit, t_miss, t_proc, float(np.max(t_proc)))


def system_service_rate(mu1, mu2, p12):
    """Equation 5: harmonic composition of tier service rates (elementwise
    over broadcastable array inputs)."""
    inv = (1.0 - p12) / mu1 + p12 / mu2
    return 1.0 / inv


# ---------------------------------------------------------------------------
# Queue primitives (vectorized; scalar in -> scalar out).
# ---------------------------------------------------------------------------


class QueueMetrics(NamedTuple):
    rho: np.ndarray     # utilization (per-server for k-server queues)
    p0: np.ndarray      # probability of an empty system
    lq: np.ndarray      # expected queue length (waiting)
    l: np.ndarray       # expected number in system
    wq: np.ndarray      # expected waiting time
    w: np.ndarray       # expected time in system
    stable: np.ndarray  # bool


def _metrics(rho, p0, lq, l, wq, w, stable) -> QueueMetrics:
    """Pack metrics; 0-d arrays collapse to plain float/bool (the historic
    scalar API)."""
    if np.ndim(rho) == 0:
        return QueueMetrics(float(rho), float(p0), float(lq), float(l),
                            float(wq), float(w), bool(stable))
    return QueueMetrics(np.asarray(rho, float), np.asarray(p0, float),
                        np.asarray(lq, float), np.asarray(l, float),
                        np.asarray(wq, float), np.asarray(w, float),
                        np.asarray(stable, bool))


def mm1_queue(lam, mu) -> QueueMetrics:
    """M/M/1 (paper eq. 7 uses Lq = rho^2/(1-rho)). Vectorized over
    broadcastable ``lam``/``mu`` arrays; λ ≤ 0 means an idle queue (empty,
    residence = pure service) and ρ ≥ 1 a saturated one (inf waits).
    A dead device (μ ≤ 0) reports ρ = inf / unstable when offered work and
    a stable-but-unserviceable queue (inf residence) when idle."""
    lam, mu = np.broadcast_arrays(np.asarray(lam, float), np.asarray(mu, float))
    idle = lam <= 0.0
    dead = mu <= 0.0
    lam_safe = np.where(idle, 1.0, lam)
    mu_safe = np.where(dead, 1.0, mu)
    rho = np.where(idle, 0.0, np.where(dead, np.inf, lam_safe / mu_safe))
    stable = rho < 1.0
    live = stable & ~idle
    one_minus = np.where(stable, 1.0 - rho, 1.0)
    lq = np.where(stable, rho * rho / one_minus, np.inf)
    l = np.where(stable, rho / one_minus, np.inf)
    wq = np.where(live, lq / lam_safe, np.where(idle, 0.0, np.inf))
    w_idle = np.where(dead, np.inf, 1.0 / mu_safe)
    w = np.where(live, l / lam_safe, np.where(idle, w_idle, np.inf))
    p0 = np.where(stable, 1.0 - rho, 0.0)
    return _metrics(rho, p0, lq, l, wq, w, stable)


def _mmk_p0(a, k: int):
    """P0 for M/M/k with offered load a = lam/mu (paper cites [42]).
    Vectorized over ``a``; only meaningful where a < k."""
    a = np.asarray(a, float)
    a_clip = np.minimum(a, k * (1.0 - 1e-12))  # keep the tail term finite
    s = sum(a_clip**i / math.factorial(i) for i in range(k))
    s = s + a_clip**k / (math.factorial(k) * (1.0 - a_clip / k))
    return 1.0 / s


def mmk_queue(lam, mu, k: int) -> QueueMetrics:
    """M/M/k. Paper eq. 6: L1 = P0 * a^(k+1) / ((k-1)! (k-a)^2), a = lam/mu.
    Vectorized over broadcastable ``lam``/``mu``; ``k`` is a Python int.
    Dead devices (μ ≤ 0) follow the :func:`mm1_queue` convention: offered
    work ⇒ a = inf / unstable; idle ⇒ stable with inf residence."""
    lam, mu = np.broadcast_arrays(np.asarray(lam, float), np.asarray(mu, float))
    idle = lam <= 0.0
    dead = mu <= 0.0
    lam_safe = np.where(idle, 1.0, lam)
    mu_safe = np.where(dead, 1.0, mu)
    a = np.where(idle, 0.0, np.where(dead, np.inf, lam_safe / mu_safe))
    rho = a / k
    stable = rho < 1.0
    live = stable & ~idle
    p0 = np.where(stable, _mmk_p0(a, k), 0.0)
    k_minus_a = np.where(stable, k - a, 1.0)
    # a is finite wherever `stable` picks the first branch; a_fin keeps the
    # discarded branch's powers finite so no inf*0 NaNs leak out of where.
    a_fin = np.where(stable, a, 0.0)
    lq = np.where(
        stable,
        p0 * a_fin ** (k + 1) / (math.factorial(k - 1) * k_minus_a**2),
        np.inf,
    )
    l = np.where(stable, lq + a_fin, np.inf)
    wq = np.where(live, lq / lam_safe, np.where(idle, 0.0, np.inf))
    w_idle = np.where(dead, np.inf, 1.0 / mu_safe)
    w = np.where(live, l / lam_safe, np.where(idle, w_idle, np.inf))
    p0 = np.where(idle, 1.0, p0)
    return _metrics(rho, p0, lq, l, wq, w, stable)


def mgk_queue(lam, mean_s, var_s, k: int) -> QueueMetrics:
    """M/G/k via the Allen–Cunneen approximation:
    Lq(M/G/k) ≈ Lq(M/M/k) * (1 + C_s^2) / 2, C_s^2 = var/mean^2.

    The paper derives its tier-1 queue "using the mean and variance of the
    read/write service (hit) time distribution" — this is that model.
    Vectorized like :func:`mmk_queue`.
    """
    # Broadcast *before* the base M/M/k solve so its metrics already carry
    # the full output shape (a var_s wider than lam must widen everything).
    lam_b, mean_b, var_b = np.broadcast_arrays(
        np.asarray(lam, float), np.asarray(mean_s, float),
        np.asarray(var_s, float))
    # A dead device arrives here as mean_s = inf (1/mu with mu = 0): its
    # service rate becomes 0 and mmk_queue's dead-device convention applies.
    with np.errstate(divide="ignore"):
        base = mmk_queue(lam_b, 1.0 / mean_b, k)
    idle = lam_b <= 0.0
    lam_safe = np.where(idle, 1.0, lam_b)
    live = np.asarray(base.stable, bool) & ~idle
    mean_fin = np.where(np.isfinite(mean_b), mean_b, 0.0)
    with np.errstate(divide="ignore", invalid="ignore"):
        cs2 = var_b / (mean_b * mean_b)
    cs2 = np.where(np.isfinite(cs2), cs2, 0.0)
    scale = (1.0 + cs2) / 2.0
    lq = np.where(live, base.lq * scale, base.lq)
    l = np.where(live, lq + lam_b * mean_fin, base.l)
    wq = np.where(live, lq / lam_safe, base.wq)
    w = np.where(live, l / lam_safe, base.w)
    return _metrics(base.rho, base.p0, lq, l, wq, w, base.stable)


# ---------------------------------------------------------------------------
# The composed two-tier model (Fig. 5 + eqs. 5–7).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TwoTierModel:
    """Per-process two-tier queuing network.

    lam:  workload request arrival rate (reqs/sec per process)
    mu1:  tier-1 hit service rate (per RPC server; includes RPC + sync costs)
    mu2:  tier-2 miss service rate (IO thread + HDD)
    p12:  miss rate (fraction of requests forwarded to tier 2)
    k:    RPC service threads per process (k-server queue)
    var_s1: variance of tier-1 service time (M/G/k); 0 => exponential M/M/k

    ``lam``/``mu1``/``mu2``/``p12`` may be broadcastable numpy arrays; the
    whole analysis then runs elementwise (one solve for a grid of operating
    points instead of a Python loop).
    """

    lam: float
    mu1: float
    mu2: float
    p12: float
    k: int = 1
    var_s1: float = 0.0
    flow: Literal["paper", "conserving"] = "paper"

    def effective_arrival(self):
        """Arrival rate at the k-server (tier-1) queue."""
        if self.flow == "paper":
            # §V worked example: misses re-enter at rate p12 * mu2.
            return (1.0 - self.p12) * self.lam + self.p12 * self.mu2
        return self.lam

    def analyze(self) -> "TwoTierReport":
        lam_eff = self.effective_arrival()
        # Tier-1 k-server queue: M/G/k where var_s1 > 0, M/M/k where it is
        # 0 — elementwise, so a mixed var_s1 array keeps the documented
        # "0 => exponential M/M/k" contract per element. Dead devices
        # (mu = 0) flow through as 1/mu = inf mean service times; the
        # errstate guard keeps that conversion warning-free.
        var = np.asarray(self.var_s1, float)
        if not np.any(var > 0):
            q1 = mmk_queue(lam_eff, self.mu1, self.k)
        else:
            with np.errstate(divide="ignore"):
                inv_mu1 = 1.0 / np.asarray(self.mu1, float)
            q1 = mgk_queue(lam_eff, inv_mu1, var, self.k)
            if np.any(var <= 0):
                q_m = mmk_queue(lam_eff, self.mu1, self.k)
                pick = var > 0
                # np.where keeps bool dtype for the stable field.
                q1 = QueueMetrics(*[
                    np.where(pick, g, m) for g, m in zip(q1, q_m)
                ])
        # Tier-2 M/M/1 miss queue (eq. 7).
        lam_miss = self.p12 * self.lam
        q2 = mm1_queue(lam_miss, self.mu2)
        with np.errstate(divide="ignore", invalid="ignore"):
            mu_sys = system_service_rate(self.mu1, self.mu2, self.p12)
            rho_sys = self.lam / mu_sys
        eq = np.logical_and(q1.stable, q2.stable)
        return TwoTierReport(
            model=self,
            lam_eff=lam_eff,
            q1=q1,
            q2=q2,
            mu_system=mu_sys,
            rho_system=rho_sys,
            equilibrium=bool(eq) if np.ndim(eq) == 0 else eq,
        )

    def time_for(self, n_requests: int) -> dict[str, float]:
        """§V worked example: wall time for ``n_requests`` arrivals plus the
        pure response time (all requests at tier-1 service rate)."""
        lam_eff = self.effective_arrival()
        return {
            "arrival_window_s": n_requests / lam_eff,
            "response_time_s": n_requests / self.mu1,
        }


@dataclasses.dataclass(frozen=True)
class TwoTierReport:
    model: TwoTierModel
    lam_eff: float
    q1: QueueMetrics
    q2: QueueMetrics
    mu_system: float
    rho_system: float
    equilibrium: bool

    def summary(self) -> dict[str, float]:
        return {
            "lam_eff": self.lam_eff,
            "rho1": self.q1.rho * self.model.k,  # offered load a = lam/mu
            "rho2": self.q2.rho,
            "L1": self.q1.lq,
            "W1": self.q1.wq,
            "L2": self.q2.lq,
            "W2": self.q2.wq,
            "mu_system": self.mu_system,
            "rho_system": self.rho_system,
            "equilibrium": (
                float(self.equilibrium)
                if np.ndim(self.equilibrium) == 0
                else np.asarray(self.equilibrium, float)
            ),
        }


def residence_times(wq1, wq2, mu1, mu2, stable):
    """Residence times W = Wq + 1/μ for both tiers; wherever *either* queue
    saturates (``stable`` False) both report inf — the shared convention of
    the steady-state and transient reports."""
    stable = np.asarray(stable, bool)
    # 1/mu -> inf for dead devices (mu = 0): residence on a dead-but-idle
    # tier is inf by convention, not a warning.
    with np.errstate(divide="ignore"):
        w1 = np.where(stable, wq1 + 1.0 / np.asarray(mu1, float), np.inf)
        w2 = np.where(stable, wq2 + 1.0 / np.asarray(mu2, float), np.inf)
    return w1, w2


def expected_response(w1, w2, p12):
    """Expected response time w1 + p12*w2, elementwise, guarding both
    factors so p12 = 0 never multiplies an inf w2 (0*inf = nan)."""
    has_miss = np.asarray(p12) > 0.0
    return w1 + np.where(has_miss, p12, 0.0) * np.where(has_miss, w2, 0.0)


# ---------------------------------------------------------------------------
# Retry policy (client timeouts + exponential backoff).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Client retry behavior: timeout, retry budget, exponential backoff.

    A request whose *virtual wait* at tier 1 (backlog over capacity,
    ``w_v = (Q1 + 1) / (k * mu1)``) exceeds ``timeout`` is abandoned by its
    client and re-issued after a backoff delay — but the abandoned work
    **stays in the server queue** (the server cannot tell), which is the
    wasted-work amplification that turns aggressive timeouts into retry
    storms. The fluid model tracks one *orbit* per retry attempt ``r``
    (0-based): timed-out offered work enters orbit 0, re-offers at rate
    ``R_r / d_r``, and on a further timeout cascades to orbit ``r+1``
    until the retry budget is spent (then it is *dropped* — the client
    gives up).

    timeout:       client timeout in seconds (must be > 0). Requests whose
                   virtual wait exceeds it re-enter the arrival stream.
    max_retries:   retry budget per request (>= 0; 0 disables retries —
                   timed-out requests are dropped immediately).
    backoff_base:  exponential backoff multiplier between attempts (>= 1;
                   1.0 = constant backoff, i.e. no exponential growth).
    backoff_init:  delay before the first retry, seconds (0 -> ``timeout``,
                   the common "retry as soon as the RPC deadline fires").
    backoff_cap:   upper bound on any backoff delay, seconds (0 -> no cap).
    jitter:        fractional jitter in [0, 1) applied by real clients to
                   desynchronize retries. The fluid (mean-field) model is
                   jitter-invariant — the *mean* re-offer rate of a jittered
                   exponential backoff equals the unjittered one — so this
                   field documents the client config but does not change
                   the ODE. Kept for spec fidelity and report metadata.
    """

    timeout: float
    max_retries: int = 3
    backoff_base: float = 2.0
    backoff_init: float = 0.0
    backoff_cap: float = 0.0
    jitter: float = 0.0

    def __post_init__(self):
        if not (self.timeout > 0.0):
            raise ValueError(
                f"RetryPolicy.timeout must be > 0, got {self.timeout}")
        if self.max_retries < 0:
            raise ValueError(
                f"RetryPolicy.max_retries must be >= 0, got "
                f"{self.max_retries}")
        if self.backoff_base < 1.0:
            raise ValueError(
                f"RetryPolicy.backoff_base must be >= 1, got "
                f"{self.backoff_base}")
        if self.backoff_init < 0.0:
            raise ValueError(
                f"RetryPolicy.backoff_init must be >= 0, got "
                f"{self.backoff_init}")
        if self.backoff_cap < 0.0:
            raise ValueError(
                f"RetryPolicy.backoff_cap must be >= 0, got "
                f"{self.backoff_cap}")
        if not (0.0 <= self.jitter < 1.0):
            raise ValueError(
                f"RetryPolicy.jitter must be in [0, 1), got {self.jitter}")

    def delays(self) -> np.ndarray:
        """Backoff delay before attempt ``r`` (seconds), shape
        ``[max_retries]``: ``min(cap, init * base**r)`` with the 0-means-
        default conventions of :class:`RetryPolicy`."""
        init = self.backoff_init if self.backoff_init > 0.0 else self.timeout
        d = init * self.backoff_base ** np.arange(self.max_retries, dtype=float)
        if self.backoff_cap > 0.0:
            d = np.minimum(d, self.backoff_cap)
        return d


# ---------------------------------------------------------------------------
# Transient analysis (windowed telemetry -> the network).
# ---------------------------------------------------------------------------


def _sanitize_rates(lam, p12):
    """Guard measured per-window inputs: all-idle windows (lambda = 0 burst
    gaps) sometimes reach the solver as NaN (0/0 from an empty window's
    rate estimate). Treat non-finite entries as idle (lambda = 0, p12 = 0)
    so they solve as empty queues instead of poisoning the utilization
    series — and through it the saturation-onset index."""
    lam = np.asarray(lam, float)
    p12 = np.asarray(p12, float)
    lam = np.where(np.isfinite(lam), lam, 0.0)
    idle = lam <= 0.0
    p12 = np.where(np.isfinite(p12) & ~idle, p12, 0.0)
    return lam, p12


def _sanitize_mu(mu):
    """Guard service-rate inputs: clamp negatives and non-finite entries to
    0 (= dead device). A fault schedule that zeroes mu during an outage
    window must flow through as a *dead* device — cleanly growing fluid
    backlog / unstable stationary solve — never as a divide-by-zero or a
    poisoned bisection bracket. Strictly positive finite rates pass through
    bit-identical."""
    mu = np.asarray(mu, float)
    return np.where(np.isfinite(mu), np.maximum(mu, 0.0), 0.0)


class TransientReport(NamedTuple):
    """Per-window solution of the two-tier network, last axis = time window.

    Each window is solved as a stationary network at that window's measured
    arrival rate and miss fraction (piecewise-stationary approximation —
    valid when windows are long relative to queue relaxation times). ``w1``
    / ``w2`` are residence times (waiting + service); windows where either
    queue saturates report ``inf`` latencies and ``stable=False``.
    """

    lam: np.ndarray       # measured arrival rate per window
    p12: np.ndarray       # measured miss fraction per window
    lam_eff: np.ndarray   # effective tier-1 arrival rate
    rho1: np.ndarray      # tier-1 offered load (a = lam_eff/mu1)
    rho2: np.ndarray      # tier-2 utilization
    w1: np.ndarray        # tier-1 residence time (s)
    w2: np.ndarray        # tier-2 residence time (s)
    response: np.ndarray  # expected response: w1 + p12 * w2
    stable: np.ndarray    # bool per window

    def onset(self) -> np.ndarray:
        """Saturation onset: index of the first unstable window along the
        time axis, -1 where every window is stable. Shape = stable.shape
        minus the window axis."""
        unstable = ~np.asarray(self.stable, bool)
        first = np.argmax(unstable, axis=-1)
        return np.where(np.any(unstable, axis=-1), first, -1)


def transient_two_tier(
    lam,
    p12,
    mu1,
    mu2,
    *,
    k: int = 1,
    var_s1: float = 0.0,
    flow: str = "paper",
    mode: Literal["piecewise", "fluid"] = "piecewise",
    dt: Optional[float] = None,
    q0=None,
    n_substeps: int = 8,
    retry: Optional[RetryPolicy] = None,
    tier1_spill: bool = False,
    k_scale=None,
    mu_load=None,
) -> "TransientReport | FluidReport":
    """Solve the two-tier network over the window grid.

    ``lam``/``p12`` carry the time axis last (e.g. ``[window]`` or
    ``[shard, window]``); ``mu1``/``mu2`` broadcast against them (scalars,
    or ``[shard, 1]`` for per-shard device rates). Returns latency /
    utilization time series plus per-series saturation onsets via
    :meth:`TransientReport.onset`.

    ``mode="piecewise"`` (this function's historic behavior, the
    stationary-limit oracle) solves every window independently at its own
    measured rates. ``mode="fluid"`` delegates to :func:`fluid_two_tier`
    (requires ``dt``, the wall-clock window duration): the same per-window
    rates drive a fluid ODE whose queue state carries over between windows.
    """
    if mode == "fluid":
        if dt is None:
            raise ValueError("mode='fluid' requires dt (window duration, s)")
        return fluid_two_tier(
            lam, p12, mu1, mu2, dt=dt, k=k, var_s1=var_s1, flow=flow,
            q0=q0, n_substeps=n_substeps, retry=retry,
            tier1_spill=tier1_spill, k_scale=k_scale, mu_load=mu_load,
        )
    if mode != "piecewise":
        raise ValueError(f"unknown transient mode: {mode!r}")
    if retry is not None or tier1_spill or k_scale is not None \
            or mu_load is not None:
        raise ValueError(
            "retry feedback / tier-1 spill / k(t) scaling / load-dependent "
            "mu(Q) are fluid-only dynamics: use mode='fluid' (the piecewise "
            "mode solves each window as an independent stationary network)")
    lam, p12 = _sanitize_rates(lam, p12)
    lam = np.atleast_1d(lam)
    p12 = np.atleast_1d(p12)
    mu1 = np.asarray(mu1, float)
    mu2 = np.asarray(mu2, float)
    rep = TwoTierModel(
        lam=lam, mu1=mu1, mu2=mu2, p12=p12, k=k, var_s1=var_s1,
        flow=flow,  # type: ignore[arg-type]
    ).analyze()
    stable = np.broadcast_arrays(
        np.asarray(rep.equilibrium, bool), lam
    )[0].astype(bool)
    w1, w2 = residence_times(rep.q1.wq, rep.q2.wq, mu1, mu2, stable)
    response = expected_response(w1, w2, p12)
    return TransientReport(
        lam=lam,
        p12=p12,
        lam_eff=np.broadcast_arrays(np.asarray(rep.lam_eff, float), lam)[0],
        rho1=np.broadcast_arrays(np.asarray(rep.q1.rho, float) * k, lam)[0],
        rho2=np.broadcast_arrays(np.asarray(rep.q2.rho, float), lam)[0],
        w1=w1,
        w2=w2,
        response=response,
        stable=stable,
    )


# ---------------------------------------------------------------------------
# Fluid transient analysis: pointwise-stationary fluid ODE with carryover.
# ---------------------------------------------------------------------------


class FluidReport(NamedTuple):
    """Fluid-flow transient solution of the two-tier network, last axis =
    time window.

    Unlike :class:`TransientReport` (independent per-window stationary
    solves), the fluid state carries over between windows: after a rate
    burst the backlog drains at the servers' capacity, so latency stays
    elevated for a physically-determined number of windows instead of
    snapping back. ``w1``/``w2`` stay *finite* through saturated windows
    (the fluid backlog is finite at any finite time); ``stable`` flags
    windows whose offered rates exceed capacity (same onset semantics as
    the piecewise report), and ``q1``/``q2`` expose the window-mean fluid
    queue lengths themselves.
    """

    lam: np.ndarray       # measured arrival rate per window
    p12: np.ndarray       # measured miss fraction per window
    lam_eff: np.ndarray   # nominal effective tier-1 arrival rate
    rho1: np.ndarray      # tier-1 served offered load (throughput / mu1)
    rho2: np.ndarray      # tier-2 utilization (throughput / mu2)
    w1: np.ndarray        # tier-1 residence time (s), finite in overload
    w2: np.ndarray        # tier-2 residence time (s)
    response: np.ndarray  # expected response: w1 + p12 * w2
    stable: np.ndarray    # bool per window (offered rate below capacity)
    q1: np.ndarray        # window-mean tier-1 fluid queue length
    q2: np.ndarray        # window-mean tier-2 fluid queue length
    # Retry-feedback diagnostics (None unless solved with a RetryPolicy):
    retry_rate: Optional[np.ndarray] = None  # window-mean re-offered rate
    orbit: Optional[np.ndarray] = None       # window-mean orbit population
    dropped: Optional[np.ndarray] = None     # window-mean give-up rate
    # metastable: external rates below capacity but total offered (external
    # + retries) above it — the system would be stable without the retry
    # feedback yet cannot drain. None unless solved with a RetryPolicy.
    metastable: Optional[np.ndarray] = None
    # Terminal (end-of-horizon) fluid backlogs — the q0 a continuation
    # solve resumes from (q1/q2 above are window *means*, useless as
    # initial conditions). Shape = the leading axes, no window axis.
    q1_end: Optional[np.ndarray] = None
    q2_end: Optional[np.ndarray] = None

    def onset(self) -> np.ndarray:
        """Saturation onset: index of the first unstable window along the
        time axis, -1 where every window is stable (idle/NaN-rate windows
        count as stable — see ``_sanitize_rates``)."""
        unstable = ~np.asarray(self.stable, bool)
        first = np.argmax(unstable, axis=-1)
        return np.where(np.any(unstable, axis=-1), first, -1)

    def metastable_onset(self) -> np.ndarray:
        """Onset of the *trailing* metastable run: the first window of the
        contiguous metastable stretch that persists through the end of the
        horizon, -1 where the final window is healthy (a transient storm
        that drains before the horizon ends is not metastable — the flag
        marks non-recovering states, analogous to :meth:`onset` for
        saturation). Shape = metastable.shape minus the window axis."""
        if self.metastable is None:
            return np.full(np.shape(self.stable)[:-1], -1, dtype=int)
        m = np.asarray(self.metastable, bool)
        n = m.shape[-1]
        rev = m[..., ::-1]
        # Length of the trailing True run = index of the first False in the
        # reversed series (n when the whole series is metastable).
        trail = np.where(rev.all(axis=-1), n, np.argmin(rev, axis=-1))
        return np.where(m[..., -1], n - trail, -1)


def _stationary_l1(x, mu1, k: int, var_s1) -> np.ndarray:
    """Stationary tier-1 queue length L(x) at arrival rate ``x`` (M/M/k, or
    M/G/k elementwise where var_s1 > 0 — the same dispatch as
    :meth:`TwoTierModel.analyze`)."""
    var = np.asarray(var_s1, float)
    if not np.any(var > 0):
        return np.asarray(mmk_queue(x, mu1, k).l, float)
    with np.errstate(divide="ignore"):
        inv_mu1 = 1.0 / np.asarray(mu1, float)
    l_g = np.asarray(mgk_queue(x, inv_mu1, var, k).l, float)
    if np.any(var <= 0):
        l_m = np.asarray(mmk_queue(x, mu1, k).l, float)
        return np.where(var > 0, l_g, l_m)
    return l_g


def _implicit_mm1_step(l, a, mu, h):
    """One implicit-Euler substep of the M/M/1 PSFFA ODE
    ``dL/dt = a - mu*L/(1+L)``: returns (L_next, served rate x). The update
    solves ``L' + h*x = L + h*a`` with ``L' = x/(mu-x)`` — a quadratic in
    ``x`` whose lower root always lies in [0, mu)."""
    r = l + h * a
    b = 1.0 + h * mu + r
    disc = b * b - 4.0 * h * r * mu
    x = (b - np.sqrt(np.maximum(disc, 0.0))) / (2.0 * h)
    x = np.clip(x, 0.0, None)
    return l + h * (a - x), x


def _implicit_l1_step(l, a, mu1, k: int, var_s1, h, hi):
    """One implicit-Euler substep for the tier-1 queue: solve the served
    rate ``x`` in [0, k*mu1) with ``L1(x) + h*x = L + h*a`` (monotone in
    ``x`` — vectorized bisection), where L1 is the stationary M/M/k / M/G/k
    queue-length map."""
    rhs = l + h * a
    lo = np.zeros_like(rhs)
    hi = np.broadcast_to(hi, rhs.shape).copy()
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        too_high = _stationary_l1(mid, mu1, k, var_s1) + h * mid > rhs
        hi = np.where(too_high, mid, hi)
        lo = np.where(too_high, lo, mid)
        # The bracket halves every iteration; stop once the whole grid is
        # resolved well past f32-output precision (each iteration is a
        # full vectorized M/M/k / M/G/k solve — the dominant cost here).
        if np.all(hi - lo <= 1e-9 * np.maximum(hi, 1.0)):
            break
    x = 0.5 * (lo + hi)
    return l + h * (a - x), x


def _norm_mu_load(mu_load):
    """Validate/normalize the load-dependent service hook: ``((a1, b1),
    (a2, b2))`` per-tier coefficients of the rational load factor
    ``f(Q) = (1 + a*Q) / (1 + b*Q)`` applied multiplicatively to μ at each
    substep's queue state (``b > a`` models a device that slows under
    backlog, ``a > b`` one that batches better; ``a = b = 0`` is exactly
    the identity). Returns the normalized nested float tuple or None."""
    if mu_load is None:
        return None
    try:
        (a1, b1), (a2, b2) = mu_load
        coefs = tuple(float(v) for v in (a1, b1, a2, b2))
    except (TypeError, ValueError) as exc:
        raise ValueError(
            "mu_load must be ((a1, b1), (a2, b2)) per-tier load-factor "
            f"coefficients, got {mu_load!r}") from exc
    for v in coefs:
        if not (math.isfinite(v) and v >= 0.0):
            raise ValueError(
                "mu_load coefficients must be finite and >= 0, got "
                f"{mu_load!r}")
    if not any(coefs):
        # a = b = 0 is the identity factor: route to the plain fixed-rate
        # kernel so "all-zero coefficients" is *bitwise* "off" (a separate
        # kernel computing f(Q)=1 would fuse differently at the ulp level).
        return None
    return ((coefs[0], coefs[1]), (coefs[2], coefs[3]))


class _FluidInputs(NamedTuple):
    """Sanitized/broadcast solver inputs shared by the numpy and batched
    fluid paths (everything before the window loop, bit-identical)."""

    lam: np.ndarray       # [..., W] sanitized arrival rates
    p12: np.ndarray       # [..., W] sanitized miss fractions
    p12_fill: np.ndarray  # [..., W] p12 carried forward over idle windows
    lam_eff: np.ndarray   # [..., W] nominal effective tier-1 arrivals
    lam2: np.ndarray      # [..., W] nominal tier-2 arrivals
    mu1_w: np.ndarray     # [..., W] per-window tier-1 rates (k_scale folded)
    mu2_w: np.ndarray     # [..., W] per-window tier-2 rates
    h: np.ndarray         # [lead] substep duration
    l1: np.ndarray        # [lead] initial tier-1 fluid backlog
    l2: np.ndarray        # [lead] initial tier-2 fluid backlog
    full: tuple           # broadcast shape incl. window axis
    lead: tuple           # leading (batch) shape
    n_windows: int
    analytic1: bool       # k == 1 and no service-time variance anywhere


def _fluid_inputs(lam, p12, mu1, mu2, *, dt, k, var_s1, flow, q0,
                  n_substeps, k_scale) -> _FluidInputs:
    """Shared head of the fluid solvers: sanitize, broadcast, compute the
    nominal flows, forward-fill p12 over idle windows and warm-start the
    initial backlog. Pure numpy — both the scalar and the batched solver
    consume bit-identical inputs."""
    lam, p12 = _sanitize_rates(lam, p12)
    lam = np.atleast_1d(lam)
    p12 = np.atleast_1d(p12)
    lam, p12 = np.broadcast_arrays(lam, p12)
    mu1 = _sanitize_mu(mu1)
    mu2 = _sanitize_mu(mu2)
    if k_scale is not None:
        mu1 = mu1 * np.maximum(np.asarray(k_scale, float), 0.0)
    full = np.broadcast_shapes(lam.shape, mu1.shape, mu2.shape)
    lam = np.broadcast_to(lam, full)
    p12 = np.broadcast_to(p12, full)
    mu1_w = np.broadcast_to(mu1, full)
    mu2_w = np.broadcast_to(mu2, full)
    lead = full[:-1]
    n_windows = full[-1]
    dt = np.broadcast_to(np.asarray(dt, float), lead)
    if np.any(dt <= 0.0):
        raise ValueError("dt (window duration) must be positive")
    if n_substeps < 1:
        raise ValueError("n_substeps must be >= 1")

    # Nominal effective arrival rates per window (same flow conventions as
    # the stationary model).
    if flow == "paper":
        lam_eff = (1.0 - p12) * lam + p12 * mu2_w
    elif flow == "conserving":
        lam_eff = lam.copy()
    else:
        raise ValueError(f"unknown flow convention: {flow!r}")
    # Idle windows offer nothing to tier 1 (no arrivals -> no re-entries).
    lam_eff = np.where(lam > 0.0, lam_eff, 0.0)
    lam2 = p12 * lam

    cap1 = float(k) * mu1_w[..., 0] * (1.0 - 1e-12)
    analytic1 = k == 1 and not np.any(np.asarray(var_s1, float) > 0)

    # Initial state: warm (first-window equilibrium, clipped to empty where
    # that window is already saturated) or explicit backlogs.
    if q0 is None:
        a1_0, a2_0 = lam_eff[..., 0], lam2[..., 0]
        s1 = a1_0 < cap1
        l1 = np.where(
            s1, _stationary_l1(np.where(s1, a1_0, 0.0), mu1_w[..., 0], k,
                               var_s1), 0.0)
        s2 = a2_0 < mu2_w[..., 0]
        l2 = np.where(
            s2,
            np.asarray(mm1_queue(np.where(s2, a2_0, 0.0), mu2_w[..., 0]).l,
                       float),
            0.0)
        l1 = np.broadcast_to(l1, lead).astype(float).copy()
        l2 = np.broadcast_to(l2, lead).astype(float).copy()
    else:
        q1_0, q2_0 = q0 if isinstance(q0, (tuple, list)) else (q0, q0)
        l1 = np.broadcast_to(np.asarray(q1_0, float), lead).copy()
        l2 = np.broadcast_to(np.asarray(q2_0, float), lead).copy()

    # p12 carried forward over idle windows: sanitizing set their p12 to 0,
    # which would snap `response` to bare service time while w2/q2 still
    # show a residual tier-2 backlog draining — the virtual-wait convention
    # must survive composition. The retry path also composes re-offered
    # traffic with the filled p12 (retries during an idle gap are re-issued
    # reads with the workload's last observed miss fraction).
    p12_fill = np.array(p12, copy=True)
    idle = lam <= 0.0
    for w in range(1, n_windows):
        p12_fill[..., w] = np.where(idle[..., w], p12_fill[..., w - 1],
                                    p12[..., w])

    h = dt / n_substeps
    return _FluidInputs(
        lam=lam, p12=p12, p12_fill=p12_fill, lam_eff=lam_eff, lam2=lam2,
        mu1_w=mu1_w, mu2_w=mu2_w, h=h, l1=l1, l2=l2, full=full, lead=lead,
        n_windows=n_windows, analytic1=analytic1,
    )


def _fluid_report(fi: _FluidInputs, *, k, has_retry, q1_mean, q2_mean,
                  g1_mean, g2_mean, off1, off2, tot1, tot2, retry_mean,
                  orbit_mean, drop_mean, l1, l2) -> FluidReport:
    """Shared tail of the fluid solvers: dead-device guards, Little's-law
    residence times, stability/metastability flags and report packing —
    pure numpy on the window-loop outputs, bit-identical across paths."""
    lam_eff, lam2 = fi.lam_eff, fi.lam2
    mu1_w, mu2_w = fi.mu1_w, fi.mu2_w
    # Dead-device guards: mu = 0 windows report rho = inf (work offered) or
    # 0 (truly idle), and inf residence whenever anything is offered or
    # backlogged. For mu > 0 every expression below is op-identical to the
    # historic path (safe_mu == mu elementwise).
    tiny = 1e-9
    dead1 = mu1_w <= 0.0
    dead2 = mu2_w <= 0.0
    safe_mu1 = np.where(dead1, 1.0, mu1_w)
    safe_mu2 = np.where(dead2, 1.0, mu2_w)
    rho1 = np.where(dead1, np.where(off1 > tiny, np.inf, 0.0),
                    g1_mean / safe_mu1)
    rho2 = np.where(dead2, np.where(off2 > tiny, np.inf, 0.0),
                    g2_mean / safe_mu2)
    # Residence via Little's law on the fluid state for windows that see
    # arrivals. Idle windows (lambda = 0 burst gaps) have no arriving
    # requests to attribute waits to — Little's ratio degenerates (0/0 is
    # the NaN the onset guard exists for, and a residual backlog collapsing
    # mid-window inflates it) — so they report the *virtual* waiting time
    # instead: residual backlog over capacity, plus service.
    w1 = np.where(
        dead1,
        np.where((off1 > tiny) | (q1_mean > tiny), np.inf, 0.0),
        np.where(
            lam_eff > tiny,
            q1_mean / np.maximum(g1_mean, tiny),
            q1_mean / (float(k) * safe_mu1) + 1.0 / safe_mu1))
    w2 = np.where(
        dead2,
        np.where((off2 > tiny) | (q2_mean > tiny), np.inf, 0.0),
        np.where(
            lam2 > tiny,
            q2_mean / np.maximum(g2_mean, tiny),
            q2_mean / safe_mu2 + 1.0 / safe_mu2))
    response = expected_response(w1, w2, fi.p12_fill)
    # Stability keeps the piecewise onset semantics: a window saturates when
    # its *offered* rates reach capacity (the fluid drain itself never
    # exceeds capacity, so served rates cannot flag it). The `<= 0` escape
    # keeps idle-but-dead windows stable (nothing offered, nothing lost) —
    # for mu > 0 it is implied by `rate < capacity` and changes nothing.
    stable = (((lam_eff < k * mu1_w) | (lam_eff <= 0.0))
              & ((lam2 < mu2_w) | (lam2 <= 0.0)))
    metastable = None
    if has_retry:
        # Metastable: the external rates alone are within capacity, but the
        # total offered stream (external + retry re-offers) is not — the
        # retry feedback sustains an overload the workload itself would
        # recover from.
        stable_tot = (((tot1 < k * mu1_w) | (tot1 <= 0.0))
                      & ((tot2 < mu2_w) | (tot2 <= 0.0)))
        metastable = stable & ~stable_tot
    return FluidReport(
        lam=fi.lam,
        p12=fi.p12,
        lam_eff=lam_eff,
        rho1=rho1,
        rho2=rho2,
        w1=w1,
        w2=w2,
        response=response,
        stable=stable,
        q1=q1_mean,
        q2=q2_mean,
        retry_rate=retry_mean,
        orbit=orbit_mean,
        dropped=drop_mean,
        metastable=metastable,
        q1_end=np.array(l1),
        q2_end=np.array(l2),
    )


def fluid_two_tier(
    lam,
    p12,
    mu1,
    mu2,
    *,
    dt,
    k: int = 1,
    var_s1: float = 0.0,
    flow: str = "paper",
    q0=None,
    n_substeps: int = 8,
    retry: Optional[RetryPolicy] = None,
    tier1_spill: bool = False,
    k_scale=None,
    mu_load=None,
) -> FluidReport:
    """Fluid-flow transient solve of the two-tier network over time windows
    **with queue-length carryover**.

    Both queues follow the pointwise-stationary fluid ODE
    ``dQ/dt = lam(t) - G(Q)`` where the drain ``G`` inverts the stationary
    queue-length map (PSFFA): tier 2 (M/M/1) uses the analytic
    ``G(Q) = mu2*Q/(1+Q)``, tier 1 (M/M/k / M/G/k) inverts its map by
    vectorized bisection. The pure-fluid limit of ``G`` is
    ``mu*min(Q, k)``; the stationary inverse additionally reproduces the
    stochastic queueing delay, so under a constant arrival rate the fixed
    point ``G(Q*) = lam`` lands *exactly* on the piecewise-stationary
    (equilibrium) solution — the piecewise mode is this solver's
    stationary-limit oracle. Integration is implicit Euler
    (unconditionally stable, exact at fixed points), ``n_substeps`` per
    window.

    ``lam``/``p12`` carry the window axis last, ``mu1``/``mu2`` broadcast
    against them (e.g. ``[shard, 1]``), and the solve is vectorized over
    all leading axes — only the window axis is sequential (carryover).
    ``dt`` is the wall-clock window duration in seconds (scalar or
    broadcastable to the leading axes). ``q0`` sets the initial queue
    lengths: ``None`` warm-starts at the first window's stationary
    solution (an equilibrium start — constant-rate workloads then match
    the piecewise oracle in *every* window), a scalar or ``(q1_0, q2_0)``
    pair starts cold at explicit backlogs (0 = empty system).

    Fault-injection extensions (each exactly inert at its default):

    - ``mu1``/``mu2`` may carry the window axis (time-varying service
      rates, e.g. a fault schedule's per-window μ-multipliers); μ = 0
      during an outage window is a *dead* device — the backlog grows at
      the offered rate, residence is inf, and the window flags unstable.
    - ``k_scale``: optional per-window multiplier on tier-1 *capacity*
      (the fluid representation of a time-varying server count ``k(t)``:
      capacity is ``k · μ1(t) · k_scale(t)``, folded into μ1).
    - ``retry``: a :class:`RetryPolicy`. The ODE becomes
      ``dQ/dt = λ(t) + λ_retry(Q, t) − G(Q; μ(t))``: work whose virtual
      wait exceeds the timeout re-enters the arrival stream from backoff
      orbits (one per retry attempt), while the abandoned copy stays in
      the queue — wasted work. The report then carries ``retry_rate`` /
      ``orbit`` / ``dropped`` series plus the ``metastable`` flag
      (external rates below capacity, total offered above — a retry
      storm that cannot drain) and :meth:`FluidReport.metastable_onset`.
    - ``tier1_spill``: route tier-1 offered work above capacity
      (``max(a1 − k·μ1(t), 0)``, exactly 0 for a healthy tier) into the
      tier-2 arrival stream — degraded tier 1 sheds reads to tier 2.
    - ``mu_load``: load-dependent service rates μ(Q) — ``((a1, b1),
      (a2, b2))`` coefficients of the rational factor
      ``f(Q) = (1 + a·Q)/(1 + b·Q)`` applied to each tier's μ at the
      substep's own queue state (the queue-depth sensitivity the device
      models measure; ``b > a`` = slows under backlog). ``None`` (default)
      keeps the solver bit-identical to the historic path.
    """
    ml = _norm_mu_load(mu_load)
    fi = _fluid_inputs(lam, p12, mu1, mu2, dt=dt, k=k, var_s1=var_s1,
                       flow=flow, q0=q0, n_substeps=n_substeps,
                       k_scale=k_scale)
    lam, p12 = fi.lam, fi.p12
    lam_eff, lam2 = fi.lam_eff, fi.lam2
    mu1_w, mu2_w = fi.mu1_w, fi.mu2_w
    p12_fill, h, l1, l2 = fi.p12_fill, fi.h, fi.l1, fi.l2
    full, lead, n_windows = fi.full, fi.lead, fi.n_windows
    analytic1 = fi.analytic1

    q1_mean = np.empty(full)
    q2_mean = np.empty(full)
    g1_mean = np.empty(full)
    g2_mean = np.empty(full)
    faulted = retry is not None or tier1_spill or ml is not None
    if not faulted:
        # The historic (pre-fault) loop, kept verbatim: the fault-aware
        # loop below is exactly equivalent at spill = retry = 0, but this
        # path guarantees healthy solves stay bit-identical op-for-op.
        for w in range(n_windows):
            a1, a2 = lam_eff[..., w], lam2[..., w]
            l1_sum = 0.5 * l1
            l2_sum = 0.5 * l2
            x1_sum = np.zeros(lead)
            x2_sum = np.zeros(lead)
            for s in range(n_substeps):
                if analytic1:
                    l1, x1 = _implicit_mm1_step(l1, a1, mu1_w[..., w], h)
                else:
                    l1, x1 = _implicit_l1_step(
                        l1, a1, mu1_w[..., w], k, var_s1, h,
                        float(k) * mu1_w[..., w] * (1.0 - 1e-12))
                l2, x2 = _implicit_mm1_step(l2, a2, mu2_w[..., w], h)
                weight = 0.5 if s == n_substeps - 1 else 1.0
                l1_sum += weight * l1
                l2_sum += weight * l2
                x1_sum += x1
                x2_sum += x2
            q1_mean[..., w] = l1_sum / n_substeps
            q2_mean[..., w] = l2_sum / n_substeps
            g1_mean[..., w] = x1_sum / n_substeps
            g2_mean[..., w] = x2_sum / n_substeps
        off1, off2 = lam_eff, lam2
        retry_mean = orbit_mean = drop_mean = None
        tot1 = tot2 = None
    else:
        # Fault-aware loop: arrival flows are re-composed every substep so
        # retry feedback (orbit re-offers join the external stream) and
        # tier-1 overflow spill can respond to the evolving queue state.
        m = retry.max_retries if retry is not None else 0
        delays = retry.delays() if retry is not None else np.empty(0)
        orbits = [np.zeros(lead) for _ in range(m)]
        off1 = np.empty(full)   # post-spill offered rate at tier 1
        off2 = np.empty(full)   # post-spill offered rate at tier 2
        tot1 = np.empty(full)   # pre-spill offered (external + retries)
        tot2 = np.empty(full)
        retry_mean = np.empty(full) if retry is not None else None
        orbit_mean = np.empty(full) if retry is not None else None
        drop_mean = np.empty(full) if retry is not None else None
        for w in range(n_windows):
            lam_w = lam[..., w]
            p12_w = p12_fill[..., w]
            mu1_ww = mu1_w[..., w]
            mu2_ww = mu2_w[..., w]
            cap_w = float(k) * mu1_ww
            l1_sum = 0.5 * l1
            l2_sum = 0.5 * l2
            x1_sum = np.zeros(lead)
            x2_sum = np.zeros(lead)
            a1_sum = np.zeros(lead)
            a2_sum = np.zeros(lead)
            o1_sum = np.zeros(lead)
            o2_sum = np.zeros(lead)
            r_sum = np.zeros(lead)
            orb_sum = np.zeros(lead)
            d_sum = np.zeros(lead)
            for s in range(n_substeps):
                # Load-dependent service rates: μ evaluated at the substep's
                # own queue state (semi-implicit — μ is frozen over the
                # substep). ml = None reuses the nominal per-window arrays,
                # keeping every expression below op-identical.
                if ml is not None:
                    (a1c, b1c), (a2c, b2c) = ml
                    mu1_s = mu1_ww * (1.0 + a1c * l1) / (1.0 + b1c * l1)
                    mu2_s = mu2_ww * (1.0 + a2c * l2) / (1.0 + b2c * l2)
                    cap_s = float(k) * mu1_s
                else:
                    mu1_s, mu2_s, cap_s = mu1_ww, mu2_ww, cap_w
                # Re-offered rate from the backoff orbits (pre-update).
                reoffer = [orbits[r] / delays[r] for r in range(m)]
                lam_r = sum(reoffer, np.zeros(lead))
                lam_tot = lam_w + lam_r
                # Flow composition at the total arrival rate — identical
                # expression to the nominal lam_eff when lam_r = 0.
                if flow == "paper":
                    a1 = np.where(lam_tot > 0.0,
                                  (1.0 - p12_w) * lam_tot + p12_w * mu2_s,
                                  0.0)
                else:
                    a1 = lam_tot
                a2 = p12_w * lam_tot
                # Tier-1 overflow spills to tier 2 (exactly 0 when the
                # offered rate is within capacity).
                if tier1_spill:
                    spill = np.maximum(a1 - cap_s, 0.0)
                else:
                    spill = np.zeros(lead)
                a1s = a1 - spill
                a2s = a2 + spill
                if retry is not None:
                    # Timeout fraction from the *virtual wait* at tier 1,
                    # w_v = (Q1 + 1)/(k mu1): p_to = clip(1 - T/w_v, 0, 1)
                    # — written multiplication-only so a dead tier
                    # (cap = 0, w_v = inf) lands on p_to = 1 cleanly.
                    p_to = np.clip(
                        1.0 - retry.timeout * cap_s / (l1 + 1.0), 0.0, 1.0)
                if analytic1:
                    l1, x1 = _implicit_mm1_step(l1, a1s, mu1_s, h)
                else:
                    l1, x1 = _implicit_l1_step(
                        l1, a1s, mu1_s, k, var_s1, h,
                        cap_s * (1.0 - 1e-12))
                l2, x2 = _implicit_mm1_step(l2, a2s, mu2_s, h)
                if retry is not None:
                    # Orbit chain: timed-out external work enters orbit 0,
                    # a re-offer that times out again cascades one orbit
                    # down, and the last orbit's timeouts are dropped (the
                    # client's retry budget is spent). The abandoned copy
                    # is NOT removed from the queue — wasted work.
                    inflow = [p_to * lam_w] + [p_to * reoffer[r]
                                               for r in range(m - 1)]
                    dropped_now = (p_to * reoffer[m - 1] if m > 0
                                   else p_to * lam_w)
                    for r in range(m):
                        orbits[r] = ((orbits[r] + h * inflow[r])
                                     / (1.0 + h / delays[r]))
                    r_sum += lam_r
                    orb_sum += sum(orbits, np.zeros(lead))
                    d_sum += dropped_now
                weight = 0.5 if s == n_substeps - 1 else 1.0
                l1_sum += weight * l1
                l2_sum += weight * l2
                x1_sum += x1
                x2_sum += x2
                a1_sum += a1
                a2_sum += a2
                o1_sum += a1s
                o2_sum += a2s
            q1_mean[..., w] = l1_sum / n_substeps
            q2_mean[..., w] = l2_sum / n_substeps
            g1_mean[..., w] = x1_sum / n_substeps
            g2_mean[..., w] = x2_sum / n_substeps
            tot1[..., w] = a1_sum / n_substeps
            tot2[..., w] = a2_sum / n_substeps
            off1[..., w] = o1_sum / n_substeps
            off2[..., w] = o2_sum / n_substeps
            if retry is not None:
                retry_mean[..., w] = r_sum / n_substeps
                orbit_mean[..., w] = orb_sum / n_substeps
                drop_mean[..., w] = d_sum / n_substeps

    return _fluid_report(
        fi, k=k, has_retry=retry is not None,
        q1_mean=q1_mean, q2_mean=q2_mean, g1_mean=g1_mean, g2_mean=g2_mean,
        off1=off1, off2=off2, tot1=tot1, tot2=tot2,
        retry_mean=retry_mean, orbit_mean=orbit_mean, drop_mean=drop_mean,
        l1=l1, l2=l2,
    )


# ---------------------------------------------------------------------------
# Batched fluid solver: the same PSFFA window loop as a jitted lax.scan.
# ---------------------------------------------------------------------------

# One jitted kernel per *structural* config (k, analytic/bisection, flow,
# substeps, retry-orbit count, spill, mu_load); the counter increments at
# trace time, i.e. exactly once per XLA compile (a second shape through the
# same config retraces and counts again — benchmarks/bench_report.py gates
# on this).
_FLUID_CACHE: dict = {}
_FLUID_COMPILES = [0]


def fluid_compile_count() -> int:
    """Number of XLA compiles of the batched fluid kernel so far."""
    return _FLUID_COMPILES[0]


def reset_fluid_compile_count() -> None:
    _FLUID_COMPILES[0] = 0


def _fluid_kernel(cfg):
    """Build the jitted scan kernel for one structural config. The body is
    the fault-aware substep loop of :func:`fluid_two_tier` (exactly
    equivalent at retry = spill = mu_load = off) with windows scanned by
    ``lax.scan`` and substeps unrolled; the static flags in ``cfg`` prune
    the unused dynamics out of the trace."""
    (k, analytic, use_mgk, flow_paper, n_substeps, m, has_retry, spill,
     muload) = cfg
    needs_flows = has_retry or spill or muload
    import jax
    import jax.numpy as jnp

    def mm1_step(l, a, mu, h):
        r = l + h * a
        b = 1.0 + h * mu + r
        disc = b * b - 4.0 * h * r * mu
        x = (b - jnp.sqrt(jnp.maximum(disc, 0.0))) / (2.0 * h)
        x = jnp.maximum(x, 0.0)
        return l + h * (a - x), x

    def stationary_l1(x, mu, var):
        # L(x) of the M/M/k (elementwise M/G/k via Allen–Cunneen where
        # var > 0) — the jnp port of `_stationary_l1` with the same idle /
        # dead-device conventions.
        idle = x <= 0.0
        dead = mu <= 0.0
        x_s = jnp.where(idle, 1.0, x)
        mu_s = jnp.where(dead, 1.0, mu)
        a = jnp.where(idle, 0.0, jnp.where(dead, jnp.inf, x_s / mu_s))
        stable = a < k
        a_clip = jnp.minimum(a, k * (1.0 - 1e-12))
        s = sum(a_clip**i / math.factorial(i) for i in range(k))
        s = s + a_clip**k / (math.factorial(k) * (1.0 - a_clip / k))
        p0 = jnp.where(stable, 1.0 / s, 0.0)
        k_minus_a = jnp.where(stable, k - a, 1.0)
        a_fin = jnp.where(stable, a, 0.0)
        lq = jnp.where(
            stable,
            p0 * a_fin ** (k + 1) / (math.factorial(k - 1) * k_minus_a**2),
            jnp.inf)
        l_m = jnp.where(stable, lq + a_fin, jnp.inf)
        if not use_mgk:
            return l_m
        live = stable & ~idle & ~dead
        inv_mu = 1.0 / mu_s
        cs2 = var / (inv_mu * inv_mu)
        l_g = jnp.where(live, lq * ((1.0 + cs2) / 2.0) + x_s * inv_mu, l_m)
        return jnp.where(var > 0.0, l_g, l_m)

    def l1_step(l, a, mu, var, h, hi):
        # Implicit substep by 60-iteration bisection (the numpy path's
        # early-exit tolerance is ~1e-9 relative; the fixed-count jax loop
        # resolves past f64 — agreement is ~1e-9, covered by the looser
        # k > 1 test tolerances).
        rhs = l + h * a
        lo = jnp.zeros_like(rhs)
        hi = jnp.broadcast_to(hi, rhs.shape)
        mu_b = jnp.broadcast_to(mu, rhs.shape)
        var_b = jnp.broadcast_to(var, rhs.shape)

        def bis(_, lohi):
            lo, hi = lohi
            mid = 0.5 * (lo + hi)
            too_high = stationary_l1(mid, mu_b, var_b) + h * mid > rhs
            return (jnp.where(too_high, lo, mid),
                    jnp.where(too_high, mid, hi))

        lo, hi = jax.lax.fori_loop(0, 60, bis, (lo, hi))
        x = 0.5 * (lo + hi)
        return l + h * (a - x), x

    def run(xs, h, l1_0, l2_0, timeout, delays, mlc):
        _FLUID_COMPILES[0] += 1  # trace-time: once per XLA compile
        lead = l1_0.shape
        zeros = jnp.zeros(lead)

        def body(carry, xw):
            l1, l2, orbits = carry
            lam_w = xw["lam"]
            p12_w = xw["p12"]
            mu1_ww = xw["mu1"]
            mu2_ww = xw["mu2"]
            var_w = xw.get("var")
            l1_sum = 0.5 * l1
            l2_sum = 0.5 * l2
            x1_sum = x2_sum = zeros
            a1_sum = a2_sum = o1_sum = o2_sum = zeros
            r_sum = orb_sum = d_sum = zeros
            for s in range(n_substeps):
                if muload:
                    mu1_s = mu1_ww * (1.0 + mlc[0] * l1) / (1.0 + mlc[1] * l1)
                    mu2_s = mu2_ww * (1.0 + mlc[2] * l2) / (1.0 + mlc[3] * l2)
                else:
                    mu1_s, mu2_s = mu1_ww, mu2_ww
                cap_s = float(k) * mu1_s
                if m > 0:
                    reoffer = orbits / delays.reshape((m,) + (1,) * len(lead))
                    lam_r = reoffer.sum(axis=0)
                    lam_tot = lam_w + lam_r
                else:
                    lam_r = zeros
                    lam_tot = lam_w + zeros
                if flow_paper:
                    a1 = jnp.where(lam_tot > 0.0,
                                   (1.0 - p12_w) * lam_tot + p12_w * mu2_s,
                                   0.0)
                else:
                    a1 = lam_tot
                a2 = p12_w * lam_tot
                if spill:
                    spl = jnp.maximum(a1 - cap_s, 0.0)
                else:
                    spl = zeros
                a1s = a1 - spl
                a2s = a2 + spl
                if has_retry:
                    p_to = jnp.clip(
                        1.0 - timeout * cap_s / (l1 + 1.0), 0.0, 1.0)
                if analytic:
                    l1, x1 = mm1_step(l1, a1s, mu1_s, h)
                else:
                    l1, x1 = l1_step(l1, a1s, mu1_s, var_w, h,
                                     cap_s * (1.0 - 1e-12))
                l2, x2 = mm1_step(l2, a2s, mu2_s, h)
                if has_retry:
                    if m > 0:
                        inflow = [p_to * lam_w] + [
                            p_to * reoffer[r] for r in range(m - 1)]
                        dropped_now = p_to * reoffer[m - 1]
                        orbits = jnp.stack([
                            (orbits[r] + h * inflow[r])
                            / (1.0 + h / delays[r]) for r in range(m)])
                        orb_sum = orb_sum + orbits.sum(axis=0)
                    else:
                        dropped_now = p_to * lam_w
                    r_sum = r_sum + lam_r
                    d_sum = d_sum + dropped_now
                weight = 0.5 if s == n_substeps - 1 else 1.0
                l1_sum = l1_sum + weight * l1
                l2_sum = l2_sum + weight * l2
                x1_sum = x1_sum + x1
                x2_sum = x2_sum + x2
                if needs_flows:
                    a1_sum = a1_sum + a1
                    a2_sum = a2_sum + a2
                    o1_sum = o1_sum + a1s
                    o2_sum = o2_sum + a2s
            out = {
                "q1": l1_sum / n_substeps,
                "q2": l2_sum / n_substeps,
                "g1": x1_sum / n_substeps,
                "g2": x2_sum / n_substeps,
            }
            if needs_flows:
                out.update(
                    tot1=a1_sum / n_substeps, tot2=a2_sum / n_substeps,
                    off1=o1_sum / n_substeps, off2=o2_sum / n_substeps)
            if has_retry:
                out.update(retry=r_sum / n_substeps,
                           orbit=orb_sum / n_substeps,
                           drop=d_sum / n_substeps)
            return (l1, l2, orbits), out

        orbits0 = jnp.zeros((m,) + lead)
        (l1_e, l2_e, _), ys = jax.lax.scan(
            body, (jnp.asarray(l1_0), jnp.asarray(l2_0), orbits0), xs)
        return l1_e, l2_e, ys

    return jax.jit(run)


def fluid_two_tier_batched(
    lam,
    p12,
    mu1,
    mu2,
    *,
    dt,
    k: int = 1,
    var_s1: float = 0.0,
    flow: str = "paper",
    q0=None,
    n_substeps: int = 8,
    retry: Optional[RetryPolicy] = None,
    tier1_spill: bool = False,
    k_scale=None,
    mu_load=None,
) -> FluidReport:
    """Drop-in batched counterpart of :func:`fluid_two_tier`: identical
    signature and semantics, with the sequential window loop executed as a
    jitted ``lax.scan`` in float64 over *all leading axes at once* — one
    device solve for a stacked ``[point, shard, window]`` rate tensor
    instead of a host loop per point.

    Numerics: the head (sanitize/broadcast/warm start) and tail (guards,
    residence, stability flags) are the numpy helpers shared with
    :func:`fluid_two_tier`, so only the window loop runs through XLA.
    On the analytic ``k = 1`` path results match the numpy solver to
    ~1e-13 (XLA FMA contraction is the only divergence) and are **bitwise
    invariant to the batch composition** — solving one point alone equals
    slicing it from any larger stack. The ``k > 1`` bisection runs a fixed
    60 iterations (no early exit), agreeing with numpy to ~1e-9.

    Compiles are cached per structural config ``(k, analytic, flow,
    n_substeps, retry orbits, spill, mu_load)`` + operand shapes and
    counted by :func:`fluid_compile_count`.
    """
    ml = _norm_mu_load(mu_load)
    fi = _fluid_inputs(lam, p12, mu1, mu2, dt=dt, k=k, var_s1=var_s1,
                       flow=flow, q0=q0, n_substeps=n_substeps,
                       k_scale=k_scale)
    m = retry.max_retries if retry is not None else 0
    has_retry = retry is not None
    use_mgk = bool(np.any(np.asarray(var_s1, float) > 0))
    cfg = (int(k), fi.analytic1, use_mgk, flow == "paper", int(n_substeps),
           int(m), has_retry, bool(tier1_spill), ml is not None)
    fn = _FLUID_CACHE.get(cfg)
    if fn is None:
        fn = _fluid_kernel(cfg)
        _FLUID_CACHE[cfg] = fn

    def wfirst(a):
        return np.ascontiguousarray(np.moveaxis(a, -1, 0))

    xs = {"lam": wfirst(fi.lam), "p12": wfirst(fi.p12_fill),
          "mu1": wfirst(fi.mu1_w), "mu2": wfirst(fi.mu2_w)}
    if not fi.analytic1:
        xs["var"] = wfirst(
            np.broadcast_to(np.asarray(var_s1, float), fi.full))
    timeout = np.float64(retry.timeout) if has_retry else None
    delays = retry.delays() if has_retry else np.empty(0)
    mlc = (np.asarray([ml[0][0], ml[0][1], ml[1][0], ml[1][1]], float)
           if ml is not None else None)

    from jax.experimental import enable_x64
    with enable_x64():
        l1_e, l2_e, ys = fn(xs, fi.h, fi.l1, fi.l2, timeout, delays, mlc)
        ys = {key: np.moveaxis(np.asarray(val), 0, -1)
              for key, val in ys.items()}
        l1_e = np.asarray(l1_e)
        l2_e = np.asarray(l2_e)

    needs_flows = has_retry or tier1_spill or ml is not None
    if needs_flows:
        off1, off2 = ys["off1"], ys["off2"]
        tot1, tot2 = ys["tot1"], ys["tot2"]
    else:
        off1, off2 = fi.lam_eff, fi.lam2
        tot1 = tot2 = None
    return _fluid_report(
        fi, k=k, has_retry=has_retry,
        q1_mean=ys["q1"], q2_mean=ys["q2"],
        g1_mean=ys["g1"], g2_mean=ys["g2"],
        off1=off1, off2=off2, tot1=tot1, tot2=tot2,
        retry_mean=ys.get("retry"), orbit_mean=ys.get("orbit"),
        drop_mean=ys.get("drop"),
        l1=l1_e, l2=l2_e,
    )
