"""Queuing-network performance model of the two-tier store (paper §V).

Implements equations 1–7 plus the standard M/M/1, M/M/k and (Allen–Cunneen
approximate) M/G/k building blocks, and the paper's worked example.

The network (Fig. 5): read/write requests arrive at tier 1 at rate λ; hits
exit via the k-server RPC pool (M/G/k, service rate μ1); misses (fraction
``p12``) enter the single IO-thread queue (M/M/1, service rate μ2) and
re-enter tier 1 when serviced. The system is analyzable at equilibrium
(all utilization ratios < 1).

Two conventions for the *effective arrival rate* at the k-server queue:

- ``flow="paper"`` reproduces §V's worked example, which feeds the miss
  traffic back at rate ``p12 * μ2``  (λ_eff = (1-p12)·λ + p12·μ2; gives
  λ_eff = 86.6 for the example).
- ``flow="conserving"`` uses flow conservation at equilibrium (the miss
  queue's throughput equals its arrival rate): λ_eff = (1-p12)·λ + p12·λ = λ.

Every queue primitive and :class:`TwoTierModel` is **vectorized**: λ, μ and
``p12`` may be scalars or arbitrary-shape numpy arrays (broadcast against
each other); ``k`` stays a Python int (it is structural). Scalar inputs
return plain-float metrics, array inputs return arrays elementwise equal to
the scalar formulas — one call solves a whole ``[point, shard]`` or
``[shard, window]`` grid instead of a Python loop.

Beyond the equilibrium analysis, :func:`transient_two_tier` solves the
network **piecewise-stationary over time windows**: each window's measured
arrival rate and miss fraction feed the same equations, yielding latency /
utilization time series plus saturation-onset detection (the first window
whose utilization reaches 1) — the transient view the paper's steady-state
summary hides.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal, NamedTuple

import numpy as np

__all__ = [
    "ServiceTimes",
    "service_time_model",
    "system_service_rate",
    "mm1_queue",
    "mmk_queue",
    "mgk_queue",
    "QueueMetrics",
    "TwoTierModel",
    "TwoTierReport",
    "TransientReport",
    "transient_two_tier",
    "residence_times",
    "expected_response",
]


# ---------------------------------------------------------------------------
# Equations 1–4: total service time (non-equilibrium / minimum-time model).
# ---------------------------------------------------------------------------


class ServiceTimes(NamedTuple):
    t_hit: np.ndarray   # T_h_i per process (eq. 1)
    t_miss: np.ndarray  # T_m_i per process (eq. 2)
    t_proc: np.ndarray  # T_i = max(T_h, T_m) per process (eq. 3)
    t_total: float      # T = max_i T_i (eq. 4)


def service_time_model(
    n_read: np.ndarray,
    n_write: np.ndarray,
    n_miss: np.ndarray,
    mu1_read: float,
    mu1_write: float,
    mu2: float,
) -> ServiceTimes:
    """Equations 1–4. Inputs are per-process request/miss counts."""
    n_read = np.asarray(n_read, float)
    n_write = np.asarray(n_write, float)
    n_miss = np.asarray(n_miss, float)
    t_hit = n_read / mu1_read + n_write / mu1_write
    t_miss = n_miss / mu2
    t_proc = np.maximum(t_hit, t_miss)
    return ServiceTimes(t_hit, t_miss, t_proc, float(np.max(t_proc)))


def system_service_rate(mu1, mu2, p12):
    """Equation 5: harmonic composition of tier service rates (elementwise
    over broadcastable array inputs)."""
    inv = (1.0 - p12) / mu1 + p12 / mu2
    return 1.0 / inv


# ---------------------------------------------------------------------------
# Queue primitives (vectorized; scalar in -> scalar out).
# ---------------------------------------------------------------------------


class QueueMetrics(NamedTuple):
    rho: np.ndarray     # utilization (per-server for k-server queues)
    p0: np.ndarray      # probability of an empty system
    lq: np.ndarray      # expected queue length (waiting)
    l: np.ndarray       # expected number in system
    wq: np.ndarray      # expected waiting time
    w: np.ndarray       # expected time in system
    stable: np.ndarray  # bool


def _metrics(rho, p0, lq, l, wq, w, stable) -> QueueMetrics:
    """Pack metrics; 0-d arrays collapse to plain float/bool (the historic
    scalar API)."""
    if np.ndim(rho) == 0:
        return QueueMetrics(float(rho), float(p0), float(lq), float(l),
                            float(wq), float(w), bool(stable))
    return QueueMetrics(np.asarray(rho, float), np.asarray(p0, float),
                        np.asarray(lq, float), np.asarray(l, float),
                        np.asarray(wq, float), np.asarray(w, float),
                        np.asarray(stable, bool))


def mm1_queue(lam, mu) -> QueueMetrics:
    """M/M/1 (paper eq. 7 uses Lq = rho^2/(1-rho)). Vectorized over
    broadcastable ``lam``/``mu`` arrays; λ ≤ 0 means an idle queue (empty,
    residence = pure service) and ρ ≥ 1 a saturated one (inf waits)."""
    lam, mu = np.broadcast_arrays(np.asarray(lam, float), np.asarray(mu, float))
    idle = lam <= 0.0
    lam_safe = np.where(idle, 1.0, lam)
    rho = np.where(idle, 0.0, lam_safe / mu)
    stable = rho < 1.0
    live = stable & ~idle
    one_minus = np.where(stable, 1.0 - rho, 1.0)
    lq = np.where(stable, rho * rho / one_minus, np.inf)
    l = np.where(stable, rho / one_minus, np.inf)
    wq = np.where(live, lq / lam_safe, np.where(idle, 0.0, np.inf))
    w = np.where(live, l / lam_safe, np.where(idle, 1.0 / mu, np.inf))
    p0 = np.where(stable, 1.0 - rho, 0.0)
    return _metrics(rho, p0, lq, l, wq, w, stable)


def _mmk_p0(a, k: int):
    """P0 for M/M/k with offered load a = lam/mu (paper cites [42]).
    Vectorized over ``a``; only meaningful where a < k."""
    a = np.asarray(a, float)
    a_clip = np.minimum(a, k * (1.0 - 1e-12))  # keep the tail term finite
    s = sum(a_clip**i / math.factorial(i) for i in range(k))
    s = s + a_clip**k / (math.factorial(k) * (1.0 - a_clip / k))
    return 1.0 / s


def mmk_queue(lam, mu, k: int) -> QueueMetrics:
    """M/M/k. Paper eq. 6: L1 = P0 * a^(k+1) / ((k-1)! (k-a)^2), a = lam/mu.
    Vectorized over broadcastable ``lam``/``mu``; ``k`` is a Python int."""
    lam, mu = np.broadcast_arrays(np.asarray(lam, float), np.asarray(mu, float))
    idle = lam <= 0.0
    lam_safe = np.where(idle, 1.0, lam)
    a = np.where(idle, 0.0, lam_safe / mu)
    rho = a / k
    stable = rho < 1.0
    live = stable & ~idle
    p0 = np.where(stable, _mmk_p0(a, k), 0.0)
    k_minus_a = np.where(stable, k - a, 1.0)
    lq = np.where(
        stable,
        p0 * a ** (k + 1) / (math.factorial(k - 1) * k_minus_a**2),
        np.inf,
    )
    l = np.where(stable, lq + a, np.inf)
    wq = np.where(live, lq / lam_safe, np.where(idle, 0.0, np.inf))
    w = np.where(live, l / lam_safe, np.where(idle, 1.0 / mu, np.inf))
    p0 = np.where(idle, 1.0, p0)
    return _metrics(rho, p0, lq, l, wq, w, stable)


def mgk_queue(lam, mean_s, var_s, k: int) -> QueueMetrics:
    """M/G/k via the Allen–Cunneen approximation:
    Lq(M/G/k) ≈ Lq(M/M/k) * (1 + C_s^2) / 2, C_s^2 = var/mean^2.

    The paper derives its tier-1 queue "using the mean and variance of the
    read/write service (hit) time distribution" — this is that model.
    Vectorized like :func:`mmk_queue`.
    """
    # Broadcast *before* the base M/M/k solve so its metrics already carry
    # the full output shape (a var_s wider than lam must widen everything).
    lam_b, mean_b, var_b = np.broadcast_arrays(
        np.asarray(lam, float), np.asarray(mean_s, float),
        np.asarray(var_s, float))
    base = mmk_queue(lam_b, 1.0 / mean_b, k)
    idle = lam_b <= 0.0
    lam_safe = np.where(idle, 1.0, lam_b)
    live = np.asarray(base.stable, bool) & ~idle
    cs2 = var_b / (mean_b * mean_b)
    scale = (1.0 + cs2) / 2.0
    lq = np.where(live, base.lq * scale, base.lq)
    l = np.where(live, lq + lam_b * mean_b, base.l)
    wq = np.where(live, lq / lam_safe, base.wq)
    w = np.where(live, l / lam_safe, base.w)
    return _metrics(base.rho, base.p0, lq, l, wq, w, base.stable)


# ---------------------------------------------------------------------------
# The composed two-tier model (Fig. 5 + eqs. 5–7).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TwoTierModel:
    """Per-process two-tier queuing network.

    lam:  workload request arrival rate (reqs/sec per process)
    mu1:  tier-1 hit service rate (per RPC server; includes RPC + sync costs)
    mu2:  tier-2 miss service rate (IO thread + HDD)
    p12:  miss rate (fraction of requests forwarded to tier 2)
    k:    RPC service threads per process (k-server queue)
    var_s1: variance of tier-1 service time (M/G/k); 0 => exponential M/M/k

    ``lam``/``mu1``/``mu2``/``p12`` may be broadcastable numpy arrays; the
    whole analysis then runs elementwise (one solve for a grid of operating
    points instead of a Python loop).
    """

    lam: float
    mu1: float
    mu2: float
    p12: float
    k: int = 1
    var_s1: float = 0.0
    flow: Literal["paper", "conserving"] = "paper"

    def effective_arrival(self):
        """Arrival rate at the k-server (tier-1) queue."""
        if self.flow == "paper":
            # §V worked example: misses re-enter at rate p12 * mu2.
            return (1.0 - self.p12) * self.lam + self.p12 * self.mu2
        return self.lam

    def analyze(self) -> "TwoTierReport":
        lam_eff = self.effective_arrival()
        # Tier-1 k-server queue: M/G/k where var_s1 > 0, M/M/k where it is
        # 0 — elementwise, so a mixed var_s1 array keeps the documented
        # "0 => exponential M/M/k" contract per element.
        var = np.asarray(self.var_s1, float)
        if not np.any(var > 0):
            q1 = mmk_queue(lam_eff, self.mu1, self.k)
        else:
            q1 = mgk_queue(lam_eff, 1.0 / np.asarray(self.mu1, float),
                           var, self.k)
            if np.any(var <= 0):
                q_m = mmk_queue(lam_eff, self.mu1, self.k)
                pick = var > 0
                # np.where keeps bool dtype for the stable field.
                q1 = QueueMetrics(*[
                    np.where(pick, g, m) for g, m in zip(q1, q_m)
                ])
        # Tier-2 M/M/1 miss queue (eq. 7).
        lam_miss = self.p12 * self.lam
        q2 = mm1_queue(lam_miss, self.mu2)
        mu_sys = system_service_rate(self.mu1, self.mu2, self.p12)
        eq = np.logical_and(q1.stable, q2.stable)
        return TwoTierReport(
            model=self,
            lam_eff=lam_eff,
            q1=q1,
            q2=q2,
            mu_system=mu_sys,
            rho_system=self.lam / mu_sys,
            equilibrium=bool(eq) if np.ndim(eq) == 0 else eq,
        )

    def time_for(self, n_requests: int) -> dict[str, float]:
        """§V worked example: wall time for ``n_requests`` arrivals plus the
        pure response time (all requests at tier-1 service rate)."""
        lam_eff = self.effective_arrival()
        return {
            "arrival_window_s": n_requests / lam_eff,
            "response_time_s": n_requests / self.mu1,
        }


@dataclasses.dataclass(frozen=True)
class TwoTierReport:
    model: TwoTierModel
    lam_eff: float
    q1: QueueMetrics
    q2: QueueMetrics
    mu_system: float
    rho_system: float
    equilibrium: bool

    def summary(self) -> dict[str, float]:
        return {
            "lam_eff": self.lam_eff,
            "rho1": self.q1.rho * self.model.k,  # offered load a = lam/mu
            "rho2": self.q2.rho,
            "L1": self.q1.lq,
            "W1": self.q1.wq,
            "L2": self.q2.lq,
            "W2": self.q2.wq,
            "mu_system": self.mu_system,
            "rho_system": self.rho_system,
            "equilibrium": (
                float(self.equilibrium)
                if np.ndim(self.equilibrium) == 0
                else np.asarray(self.equilibrium, float)
            ),
        }


def residence_times(wq1, wq2, mu1, mu2, stable):
    """Residence times W = Wq + 1/μ for both tiers; wherever *either* queue
    saturates (``stable`` False) both report inf — the shared convention of
    the steady-state and transient reports."""
    stable = np.asarray(stable, bool)
    w1 = np.where(stable, wq1 + 1.0 / np.asarray(mu1, float), np.inf)
    w2 = np.where(stable, wq2 + 1.0 / np.asarray(mu2, float), np.inf)
    return w1, w2


def expected_response(w1, w2, p12):
    """Expected response time w1 + p12*w2, elementwise, guarding both
    factors so p12 = 0 never multiplies an inf w2 (0*inf = nan)."""
    has_miss = np.asarray(p12) > 0.0
    return w1 + np.where(has_miss, p12, 0.0) * np.where(has_miss, w2, 0.0)


# ---------------------------------------------------------------------------
# Piecewise-stationary transient analysis (windowed telemetry -> the network).
# ---------------------------------------------------------------------------


class TransientReport(NamedTuple):
    """Per-window solution of the two-tier network, last axis = time window.

    Each window is solved as a stationary network at that window's measured
    arrival rate and miss fraction (piecewise-stationary approximation —
    valid when windows are long relative to queue relaxation times). ``w1``
    / ``w2`` are residence times (waiting + service); windows where either
    queue saturates report ``inf`` latencies and ``stable=False``.
    """

    lam: np.ndarray       # measured arrival rate per window
    p12: np.ndarray       # measured miss fraction per window
    lam_eff: np.ndarray   # effective tier-1 arrival rate
    rho1: np.ndarray      # tier-1 offered load (a = lam_eff/mu1)
    rho2: np.ndarray      # tier-2 utilization
    w1: np.ndarray        # tier-1 residence time (s)
    w2: np.ndarray        # tier-2 residence time (s)
    response: np.ndarray  # expected response: w1 + p12 * w2
    stable: np.ndarray    # bool per window

    def onset(self) -> np.ndarray:
        """Saturation onset: index of the first unstable window along the
        time axis, -1 where every window is stable. Shape = stable.shape
        minus the window axis."""
        unstable = ~np.asarray(self.stable, bool)
        first = np.argmax(unstable, axis=-1)
        return np.where(np.any(unstable, axis=-1), first, -1)


def transient_two_tier(
    lam,
    p12,
    mu1,
    mu2,
    *,
    k: int = 1,
    var_s1: float = 0.0,
    flow: str = "paper",
) -> TransientReport:
    """Solve the two-tier network window by window (piecewise-stationary).

    ``lam``/``p12`` carry the time axis last (e.g. ``[window]`` or
    ``[shard, window]``); ``mu1``/``mu2`` broadcast against them (scalars,
    or ``[shard, 1]`` for per-shard device rates). Returns latency /
    utilization time series plus per-series saturation onsets via
    :meth:`TransientReport.onset`.
    """
    lam = np.atleast_1d(np.asarray(lam, float))
    p12 = np.atleast_1d(np.asarray(p12, float))
    mu1 = np.asarray(mu1, float)
    mu2 = np.asarray(mu2, float)
    rep = TwoTierModel(
        lam=lam, mu1=mu1, mu2=mu2, p12=p12, k=k, var_s1=var_s1,
        flow=flow,  # type: ignore[arg-type]
    ).analyze()
    stable = np.broadcast_arrays(
        np.asarray(rep.equilibrium, bool), lam
    )[0].astype(bool)
    w1, w2 = residence_times(rep.q1.wq, rep.q2.wq, mu1, mu2, stable)
    response = expected_response(w1, w2, p12)
    return TransientReport(
        lam=lam,
        p12=p12,
        lam_eff=np.broadcast_arrays(np.asarray(rep.lam_eff, float), lam)[0],
        rho1=np.broadcast_arrays(np.asarray(rep.q1.rho, float) * k, lam)[0],
        rho2=np.broadcast_arrays(np.asarray(rep.q2.rho, float), lam)[0],
        w1=w1,
        w2=w2,
        response=response,
        stable=stable,
    )
