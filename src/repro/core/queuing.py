"""Queuing-network performance model of the two-tier store (paper §V).

Implements equations 1–7 plus the standard M/M/1, M/M/k and (Allen–Cunneen
approximate) M/G/k building blocks, and the paper's worked example.

The network (Fig. 5): read/write requests arrive at tier 1 at rate λ; hits
exit via the k-server RPC pool (M/G/k, service rate μ1); misses (fraction
``p12``) enter the single IO-thread queue (M/M/1, service rate μ2) and
re-enter tier 1 when serviced. The system is analyzable at equilibrium
(all utilization ratios < 1).

Two conventions for the *effective arrival rate* at the k-server queue:

- ``flow="paper"`` reproduces §V's worked example, which feeds the miss
  traffic back at rate ``p12 * μ2``  (λ_eff = (1-p12)·λ + p12·μ2; gives
  λ_eff = 86.6 for the example).
- ``flow="conserving"`` uses flow conservation at equilibrium (the miss
  queue's throughput equals its arrival rate): λ_eff = (1-p12)·λ + p12·λ = λ.

Everything is plain float math (no tracing requirement) with jnp-compatible
vector forms where useful for sweeps.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal, NamedTuple

import numpy as np

__all__ = [
    "ServiceTimes",
    "service_time_model",
    "system_service_rate",
    "mm1_queue",
    "mmk_queue",
    "mgk_queue",
    "QueueMetrics",
    "TwoTierModel",
    "TwoTierReport",
]


# ---------------------------------------------------------------------------
# Equations 1–4: total service time (non-equilibrium / minimum-time model).
# ---------------------------------------------------------------------------


class ServiceTimes(NamedTuple):
    t_hit: np.ndarray   # T_h_i per process (eq. 1)
    t_miss: np.ndarray  # T_m_i per process (eq. 2)
    t_proc: np.ndarray  # T_i = max(T_h, T_m) per process (eq. 3)
    t_total: float      # T = max_i T_i (eq. 4)


def service_time_model(
    n_read: np.ndarray,
    n_write: np.ndarray,
    n_miss: np.ndarray,
    mu1_read: float,
    mu1_write: float,
    mu2: float,
) -> ServiceTimes:
    """Equations 1–4. Inputs are per-process request/miss counts."""
    n_read = np.asarray(n_read, float)
    n_write = np.asarray(n_write, float)
    n_miss = np.asarray(n_miss, float)
    t_hit = n_read / mu1_read + n_write / mu1_write
    t_miss = n_miss / mu2
    t_proc = np.maximum(t_hit, t_miss)
    return ServiceTimes(t_hit, t_miss, t_proc, float(np.max(t_proc)))


def system_service_rate(mu1: float, mu2: float, p12: float) -> float:
    """Equation 5: harmonic composition of tier service rates."""
    inv = (1.0 - p12) / mu1 + p12 / mu2
    return 1.0 / inv


# ---------------------------------------------------------------------------
# Queue primitives.
# ---------------------------------------------------------------------------


class QueueMetrics(NamedTuple):
    rho: float      # utilization (per-server for k-server queues)
    p0: float       # probability of an empty system
    lq: float       # expected queue length (waiting)
    l: float        # expected number in system
    wq: float       # expected waiting time
    w: float        # expected time in system
    stable: bool


def mm1_queue(lam: float, mu: float) -> QueueMetrics:
    """M/M/1 (paper eq. 7 uses Lq = rho^2/(1-rho))."""
    if lam <= 0.0:  # no arrivals: empty queue, residence = pure service
        return QueueMetrics(0.0, 1.0, 0.0, 0.0, 0.0, 1.0 / mu, True)
    rho = lam / mu
    if rho >= 1.0:
        return QueueMetrics(rho, 0.0, math.inf, math.inf, math.inf, math.inf, False)
    lq = rho * rho / (1.0 - rho)
    l = rho / (1.0 - rho)
    return QueueMetrics(rho, 1.0 - rho, lq, l, lq / lam, l / lam, True)


def _mmk_p0(a: float, k: int) -> float:
    """P0 for M/M/k with offered load a = lam/mu (paper cites [42])."""
    s = sum(a**i / math.factorial(i) for i in range(k))
    s += a**k / (math.factorial(k) * (1.0 - a / k))
    return 1.0 / s


def mmk_queue(lam: float, mu: float, k: int) -> QueueMetrics:
    """M/M/k. Paper eq. 6: L1 = P0 * a^(k+1) / ((k-1)! (k-a)^2), a = lam/mu."""
    if lam <= 0.0:
        return QueueMetrics(0.0, 1.0, 0.0, 0.0, 0.0, 1.0 / mu, True)
    a = lam / mu
    rho = a / k
    if rho >= 1.0:
        return QueueMetrics(rho, 0.0, math.inf, math.inf, math.inf, math.inf, False)
    p0 = _mmk_p0(a, k)
    lq = p0 * a ** (k + 1) / (math.factorial(k - 1) * (k - a) ** 2)
    l = lq + a
    return QueueMetrics(rho, p0, lq, l, lq / lam, l / lam, True)


def mgk_queue(lam: float, mean_s: float, var_s: float, k: int) -> QueueMetrics:
    """M/G/k via the Allen–Cunneen approximation:
    Lq(M/G/k) ≈ Lq(M/M/k) * (1 + C_s^2) / 2, C_s^2 = var/mean^2.

    The paper derives its tier-1 queue "using the mean and variance of the
    read/write service (hit) time distribution" — this is that model.
    """
    mu = 1.0 / mean_s
    base = mmk_queue(lam, mu, k)
    if not base.stable or lam <= 0.0:
        return base
    cs2 = var_s / (mean_s * mean_s)
    scale = (1.0 + cs2) / 2.0
    lq = base.lq * scale
    l = lq + lam * mean_s
    return QueueMetrics(base.rho, base.p0, lq, l, lq / lam, l / lam, True)


# ---------------------------------------------------------------------------
# The composed two-tier model (Fig. 5 + eqs. 5–7).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TwoTierModel:
    """Per-process two-tier queuing network.

    lam:  workload request arrival rate (reqs/sec per process)
    mu1:  tier-1 hit service rate (per RPC server; includes RPC + sync costs)
    mu2:  tier-2 miss service rate (IO thread + HDD)
    p12:  miss rate (fraction of requests forwarded to tier 2)
    k:    RPC service threads per process (k-server queue)
    var_s1: variance of tier-1 service time (M/G/k); 0 => exponential M/M/k
    """

    lam: float
    mu1: float
    mu2: float
    p12: float
    k: int = 1
    var_s1: float = 0.0
    flow: Literal["paper", "conserving"] = "paper"

    def effective_arrival(self) -> float:
        """Arrival rate at the k-server (tier-1) queue."""
        if self.flow == "paper":
            # §V worked example: misses re-enter at rate p12 * mu2.
            return (1.0 - self.p12) * self.lam + self.p12 * self.mu2
        return self.lam

    def analyze(self) -> "TwoTierReport":
        lam_eff = self.effective_arrival()
        # Tier-1 k-server queue (M/M/k or M/G/k).
        if self.var_s1 > 0:
            q1 = mgk_queue(lam_eff, 1.0 / self.mu1, self.var_s1, self.k)
        else:
            q1 = mmk_queue(lam_eff, self.mu1, self.k)
        # Tier-2 M/M/1 miss queue (eq. 7).
        lam_miss = self.p12 * self.lam
        q2 = mm1_queue(lam_miss, self.mu2)
        mu_sys = system_service_rate(self.mu1, self.mu2, self.p12)
        return TwoTierReport(
            model=self,
            lam_eff=lam_eff,
            q1=q1,
            q2=q2,
            mu_system=mu_sys,
            rho_system=self.lam / mu_sys,
            equilibrium=q1.stable and q2.stable,
        )

    def time_for(self, n_requests: int) -> dict[str, float]:
        """§V worked example: wall time for ``n_requests`` arrivals plus the
        pure response time (all requests at tier-1 service rate)."""
        lam_eff = self.effective_arrival()
        return {
            "arrival_window_s": n_requests / lam_eff,
            "response_time_s": n_requests / self.mu1,
        }


@dataclasses.dataclass(frozen=True)
class TwoTierReport:
    model: TwoTierModel
    lam_eff: float
    q1: QueueMetrics
    q2: QueueMetrics
    mu_system: float
    rho_system: float
    equilibrium: bool

    def summary(self) -> dict[str, float]:
        return {
            "lam_eff": self.lam_eff,
            "rho1": self.q1.rho * self.model.k,  # offered load a = lam/mu
            "rho2": self.q2.rho,
            "L1": self.q1.lq,
            "W1": self.q1.wq,
            "L2": self.q2.lq,
            "W2": self.q2.wq,
            "mu_system": self.mu_system,
            "rho_system": self.rho_system,
            "equilibrium": float(self.equilibrium),
        }
