"""Roofline analysis from compiled dry-run artifacts (deliverable g).

Three terms per (arch × shape × mesh), in seconds:

    compute    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory     = HLO_bytes / (chips × HBM_bw)
    collective = collective_wire_bytes / (chips × link_bw)

FLOPs/bytes come from ``compiled.cost_analysis()``. Collective bytes are
parsed from the HLO text: every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute contributes its *wire* bytes under ring
scheduling (factors below), divided by the number of participating devices
(per-chip link traffic).

Hardware model (TPU v5e-class, per chip): 197 bf16 TFLOP/s, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

__all__ = ["HW", "CollectiveStats", "collective_bytes", "roofline_report"]

HW = dict(
    peak_flops=197e12,   # bf16 per chip
    hbm_bw=819e9,        # bytes/s per chip
    link_bw=50e9,        # bytes/s per ICI link
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

# output-shape -> wire-bytes multiplier under ring schedules with group size n
# (expressed as a function of n; see e.g. the collective cost models in XLA).
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(tok: str) -> int:
    m = _SHAPE_RE.match(tok)
    if not m:
        return 0
    dt, dims = m.groups()
    b = _DTYPE_BYTES.get(dt)
    if b is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * b


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)  # replica_groups=[G,N] iota form
    if m:
        return int(m.group(2))
    return 1


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes: float = 0.0
    by_kind: Optional[dict] = None
    count: int = 0

    def __post_init__(self):
        if self.by_kind is None:
            self.by_kind = {}


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum per-chip wire bytes of every collective op in an HLO module.

    Ring-schedule factors on the *output* shape S with group size n:
      all-gather:          S · (n-1)/n         (each chip receives S·(n-1)/n)
      reduce-scatter:      S · (n-1)           (input = S·n, sends (n-1) shards)
      all-reduce:          2 · S · (n-1)/n     (RS + AG)
      all-to-all:          S · (n-1)/n
      collective-permute:  S
    """
    st = CollectiveStats()
    for line in hlo_text.splitlines():
        stripped = line.strip()
        eq = stripped.find("=")
        if eq < 0:
            continue
        kind = None
        pos = -1
        for k in _COLL_KINDS:
            for suffix in ("(", "-start("):
                p = stripped.find(" " + k + suffix)
                if p > eq:
                    kind, pos = k, p
                    break
            if kind:
                break
        if kind is None:
            continue
        # Output type(s) sit between "=" and the op name (layouts ignored).
        out_tok = stripped[eq + 1: pos]
        out_bytes = sum(_shape_bytes(t) for t in
                        re.findall(r"\w+\[[\d,]*\]", out_tok))
        n = _group_size(stripped)
        if kind == "all-gather":
            wire = out_bytes * (n - 1) / max(n, 1)
        elif kind == "reduce-scatter":
            wire = out_bytes * (n - 1)
        elif kind == "all-reduce":
            wire = 2.0 * out_bytes * (n - 1) / max(n, 1)
        elif kind == "all-to-all":
            wire = out_bytes * (n - 1) / max(n, 1)
        else:  # collective-permute
            wire = float(out_bytes)
        st.wire_bytes += wire
        st.by_kind[kind] = st.by_kind.get(kind, 0.0) + wire
        st.count += 1
    return st


_BLOCK_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{")
_WHILE_RE = re.compile(r"while\(.*?\).*?condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def module_collective_bytes(hlo_text: str) -> CollectiveStats:
    """Collective wire bytes for a whole HLO module, multiplying collectives
    inside ``while`` bodies (lax.scan layers) by their trip counts.

    Trip counts are recovered from the loop condition's integer constant
    (XLA canonicalizes scan conditions to ``iter < constant(N)``).
    """
    blocks: dict[str, list[str]] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        m = _BLOCK_HDR.match(line.strip())
        if m:
            cur = m.group(2)
            blocks[cur] = []
            if m.group(1):
                entry = cur
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                blocks[cur].append(line)

    def trip_count(cond_name: str) -> int:
        consts = [int(c) for ln in blocks.get(cond_name, ())
                  for c in _CONST_RE.findall(ln)]
        return max(consts) if consts else 1

    memo: dict[str, CollectiveStats] = {}

    def total(name: str, depth=0) -> CollectiveStats:
        if name in memo:
            return memo[name]
        st = collective_bytes("\n".join(blocks.get(name, ())))
        if depth < 8:
            for ln in blocks.get(name, ()):
                w = _WHILE_RE.search(ln)
                if w:
                    cond, body = w.groups()
                    inner = total(body, depth + 1)
                    n = trip_count(cond)
                    st.wire_bytes += n * inner.wire_bytes
                    st.count += n * inner.count
                    for k, v in inner.by_kind.items():
                        st.by_kind[k] = st.by_kind.get(k, 0.0) + n * v
        memo[name] = st
        return st

    if entry is None:
        return collective_bytes(hlo_text)
    # Also include non-entry computations reachable via call/fusion? XLA
    # inlines collectives into the entry/while graph post-optimization, so
    # entry + while bodies cover them.
    out = total(entry)
    return out


_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[\w\[\],]+(?:\{[\d,]*\})?)\s+([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_SKIP_BYTES = {"get-tuple-element", "tuple", "parameter", "constant",
               "bitcast", "after-all", "custom-call"}

# Ops whose outputs hit HBM under TPU-class fusion (elementwise/broadcast/
# reshape chains fuse into their consumers and stay in VMEM/registers).
_MAJOR_OPS = {
    "dot", "convolution", "fusion", "copy", "gather", "scatter",
    "dynamic-update-slice", "dynamic-slice", "concatenate", "pad", "sort",
    "reduce", "reduce-window", "all-gather", "all-reduce", "reduce-scatter",
    "all-to-all", "collective-permute", "rng", "rng-bit-generator", "cumsum",
}


def _shape_dims(tok: str):
    m = _SHAPE_RE.match(tok)
    if not m:
        return None, 0
    dt, dims = m.groups()
    b = _DTYPE_BYTES.get(dt, 0)
    out = [int(d) for d in dims.split(",") if d]
    return out, b


def hlo_cost(hlo_text: str) -> dict:
    """Trip-count-corrected FLOPs / HBM-bytes estimate from HLO text.

    XLA's ``compiled.cost_analysis()`` counts while-loop (lax.scan) bodies
    exactly once, which undercounts layer-scanned models by ~n_layers. This
    walker counts per-computation dot FLOPs (2·M·N·K; fusion-internal dots
    included) and fusion-boundary bytes (operand + output sizes of top-level
    ops), then multiplies while bodies by their trip counts — the same
    computation-graph traversal as :func:`module_collective_bytes`.
    """
    blocks: dict[str, list[str]] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        m = _BLOCK_HDR.match(line.strip())
        if m:
            cur = m.group(2)
            blocks[cur] = []
            if m.group(1):
                entry = cur
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                blocks[cur].append(line)

    # Pass 1 per block: symbol table name -> (dims, bytes).
    sym: dict[str, tuple] = {}
    for name, lines in blocks.items():
        for ln in lines:
            s = ln.strip()
            dm = _DEF_RE.match(s)
            if not dm:
                continue
            rest = s[s.find("=") + 1:]
            shapes = re.findall(r"\w+\[[\d,]*\]", rest.split("(")[0])
            dims_total = 0
            by = 0
            dims = None
            for t in shapes:
                d, eb = _shape_dims(t)
                if d is None:
                    continue
                n = 1
                for x in d:
                    n *= x
                by += n * eb
                dims = d if dims is None else dims
            sym[dm.group(1)] = (dims, by)

    def trip_count(cond_name: str) -> int:
        consts = [int(c) for ln in blocks.get(cond_name, ())
                  for c in _CONST_RE.findall(ln)]
        return max(consts) if consts else 1

    def dot_flops(line: str) -> float:
        """2*M*N*K from output dims x contract size (lhs operand shape)."""
        s = line.strip()
        out_dims, _ = sym.get(_DEF_RE.match(s).group(1), (None, 0))
        if out_dims is None:
            return 0.0
        out_n = 1
        for d in out_dims:
            out_n *= d
        # contraction size: from the lhs operand's dims + contracting spec
        ops = _OPERAND_RE.findall(s.split("(", 1)[1]) if "(" in s else []
        cm = _CDIMS_RE.search(s)
        k = 1
        if ops and cm:
            lhs_dims, _ = sym.get(ops[0], (None, 0))
            if lhs_dims:
                for ci in cm.group(1).split(","):
                    if ci:
                        i = int(ci)
                        if i < len(lhs_dims):
                            k *= lhs_dims[i]
        return 2.0 * out_n * k

    # FLOPs inside a computation, *without* loop multiplication (fusion
    # bodies counted at their call sites).
    flops_memo: dict[str, float] = {}

    def block_dot_flops(name: str, depth=0) -> float:
        if name in flops_memo:
            return flops_memo[name]
        total = 0.0
        for ln in blocks.get(name, ()):
            s = ln.strip()
            om = _OPCODE_RE.search(s)
            op = om.group(1) if om else None
            if op == "dot":
                total += dot_flops(ln)
            elif op == "fusion" and depth < 6:
                cm = _CALLS_RE.search(s)
                if cm:
                    total += block_dot_flops(cm.group(1), depth + 1)
        flops_memo[name] = total
        return total

    def block_bytes(name: str) -> float:
        """HBM traffic estimate for a TPU-class compiler: elementwise chains
        fuse into neighbouring matmuls, so only *major* producers write HBM
        (dots, fusions, copies, gathers/scatters, collectives, reductions).
        Each such output is written once and read ~once => 2x output bytes.
        The raw all-ops sum (CPU HLO materializes every intermediate) is
        tracked separately as ``bytes_all`` for comparison.
        """
        total = 0.0
        for ln in blocks.get(name, ()):
            s = ln.strip()
            dm = _DEF_RE.match(s)
            om = _OPCODE_RE.search(s)
            if not dm or not om:
                continue
            op = om.group(1)
            if op not in _MAJOR_OPS:
                continue
            _, out_b = sym.get(dm.group(1), (None, 0))
            total += 2.0 * out_b
        return total

    def block_bytes_all(name: str) -> float:
        total = 0.0
        for ln in blocks.get(name, ()):
            s = ln.strip()
            dm = _DEF_RE.match(s)
            om = _OPCODE_RE.search(s)
            if not dm or not om:
                continue
            op = om.group(1)
            if op in _SKIP_BYTES or op == "while":
                continue
            _, out_b = sym.get(dm.group(1), (None, 0))
            total += 2.0 * out_b
        return total

    def walk(name: str, depth=0) -> tuple[float, float, float]:
        f = block_dot_flops(name)
        b = block_bytes(name)
        ba = block_bytes_all(name)
        if depth < 8:
            for ln in blocks.get(name, ()):
                w = _WHILE_RE.search(ln)
                if w:
                    cond, body = w.groups()
                    bf, bb, bba = walk(body, depth + 1)
                    n = trip_count(cond)
                    f += n * bf
                    b += n * bb
                    ba += n * bba
        return f, b, ba

    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "bytes_all": 0.0}
    f, b, ba = walk(entry)
    return {"flops": f, "bytes": b, "bytes_all": ba}


def roofline_report(
    *,
    hlo_flops: float,
    hlo_bytes: float,
    coll: CollectiveStats,
    chips: int,
    model_flops: float,
) -> dict:
    """The §Roofline record for one (arch × shape × mesh) cell."""
    t_compute = hlo_flops / (chips * HW["peak_flops"])
    t_memory = hlo_bytes / (chips * HW["hbm_bw"])
    # wire_bytes already per-chip-ish (each chip sends/receives its share of
    # the ring); divide by link bandwidth per chip.
    t_coll = coll.wire_bytes / (chips * HW["link_bw"])
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    useful = model_flops / hlo_flops if hlo_flops else 0.0
    # Roofline fraction: ideal model-compute time over the binding term.
    ideal = model_flops / (chips * HW["peak_flops"])
    frac = ideal / bound if bound > 0 else 0.0
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": model_flops,
        "hlo_flops": hlo_flops,
        "useful_flops_frac": useful,
        "roofline_frac": frac,
        "collective_by_kind": dict(coll.by_kind),
        "collective_ops": coll.count,
    }
