"""Behavioral device models via interaction-term regression (paper §V-A/V-B).

The paper models total IO time of each device class with a linear regression
whose *interaction terms* capture load distribution, concurrency and device
internals (R formula syntax):

- NVMe  (eq. 8):  ``Y ~ X1*X3*X4 + X5*X4*X3``
  X1 = client threads, X3 = request size, X4 = #requests, X5 = address range.
  Significant: ``X1:X3:X4`` (per-thread load) and ``X3:X4:X5`` (page faults +
  garbage collection) — Tables I–II.
- HDD   (eq. 9):  ``Y ~ X3*X4 + X5*X1*X2``
  X1 = processes, X2 = stripe count (disks), X3 = stripes/disk,
  X4 = stripe size, X5 = file size. Significant: ``X5``, ``X5:X1``,
  ``X5:X2``, ``X5:X1:X2`` (communication) and ``X3`` — Tables III–IV.

This module provides: R-style formula expansion into a design matrix, OLS
with standard errors / t-values / p-values / AIC (matching R's ``lm``
summary columns), K-fold cross-validation (paper: K=20), and **simulated
device measurement campaigns** standing in for the paper's 400 NVMe / 200
HDD experiments on Delta (no NVMe/HDD in this container — the devices are
simulated with behavioral ground truth + noise; the regression machinery is
identical and the recovered significance *structure* is compared to the
paper's tables in the benchmarks).

The fitted rates feed :mod:`repro.core.queuing` (μ1, μ2) and the tier-2
simulator (:mod:`repro.storage.tier2`).
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Callable, Sequence

import numpy as np

try:  # p-values via Student-t; scipy is available in this environment.
    from scipy import stats as _sstats
except Exception:  # pragma: no cover
    _sstats = None

__all__ = [
    "expand_formula",
    "design_matrix",
    "OLSFit",
    "fit_ols",
    "kfold_cv",
    "NVME_TERMS",
    "HDD_TERMS",
    "PAPER_NVME_WRITE",
    "PAPER_NVME_READ",
    "PAPER_HDD_WRITE",
    "PAPER_HDD_READ",
    "simulate_nvme",
    "simulate_hdd",
    "fit_nvme_model",
    "fit_hdd_model",
    "DeviceModel",
    "fit_mu_load",
    "mu_load_from_devices",
]


# ---------------------------------------------------------------------------
# R-style formula expansion: "x1*x3*x4 + x5*x4*x3" -> unique terms.
# ---------------------------------------------------------------------------


def expand_formula(formula: str) -> list[tuple[str, ...]]:
    """Expand an R-style formula RHS into unique interaction terms.

    ``a*b*c`` expands to all non-empty subsets {a, b, c, a:b, a:c, b:c,
    a:b:c}; ``+`` unions term sets (dedup, order preserved by first
    appearance). Returns tuples of variable names (1-tuples = main effects).
    """
    terms: list[tuple[str, ...]] = []
    seen = set()
    for prod in formula.replace(" ", "").split("+"):
        vars_ = prod.split("*")
        for r in range(1, len(vars_) + 1):
            for combo in itertools.combinations(vars_, r):
                key = tuple(sorted(combo))
                if key not in seen:
                    seen.add(key)
                    terms.append(key)
    return terms


def design_matrix(
    data: dict[str, np.ndarray], terms: Sequence[tuple[str, ...]]
) -> np.ndarray:
    """[n, 1+len(terms)] design matrix with intercept column first."""
    n = len(next(iter(data.values())))
    cols = [np.ones(n)]
    for t in terms:
        col = np.ones(n)
        for v in t:
            col = col * np.asarray(data[v], float)
        cols.append(col)
    return np.stack(cols, axis=1)


# ---------------------------------------------------------------------------
# OLS with the R `summary(lm)` columns.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OLSFit:
    terms: tuple[tuple[str, ...], ...]
    coef: np.ndarray       # [p] incl. intercept at index 0
    stderr: np.ndarray
    tvalues: np.ndarray
    pvalues: np.ndarray
    aic: float
    r2: float
    sigma2: float
    n: int

    def term_names(self) -> list[str]:
        return ["(Intercept)"] + [":".join(t) for t in self.terms]

    def predict(self, data: dict[str, np.ndarray]) -> np.ndarray:
        return design_matrix(data, self.terms) @ self.coef

    def significant(self, alpha: float = 1e-3) -> list[str]:
        names = self.term_names()
        return [names[i] for i in range(len(names)) if self.pvalues[i] < alpha]

    def table(self) -> str:
        rows = ["term                 estimate     stderr     t       p"]
        for name, c, se, t, p in zip(
            self.term_names(), self.coef, self.stderr, self.tvalues, self.pvalues
        ):
            rows.append(f"{name:<20} {c: .3e} {se: .3e} {t: 7.2f} {p: .3e}")
        rows.append(f"AIC={self.aic:.1f}  R2={self.r2:.4f}  n={self.n}")
        return "\n".join(rows)


def fit_ols(
    data: dict[str, np.ndarray], y: np.ndarray, formula: str
) -> OLSFit:
    terms = tuple(expand_formula(formula))
    X = design_matrix(data, terms)
    n, p = X.shape
    coef, *_ = np.linalg.lstsq(X, y, rcond=None)
    resid = y - X @ coef
    rss = float(resid @ resid)
    dof = max(n - p, 1)
    sigma2 = rss / dof
    xtx_inv = np.linalg.pinv(X.T @ X)
    stderr = np.sqrt(np.maximum(np.diag(xtx_inv) * sigma2, 1e-300))
    tvals = coef / stderr
    if _sstats is not None:
        pvals = 2.0 * _sstats.t.sf(np.abs(tvals), dof)
    else:  # normal approximation
        pvals = 2.0 * 0.5 * np.erfc(np.abs(tvals) / math.sqrt(2))
    tss = float(((y - y.mean()) ** 2).sum())
    r2 = 1.0 - rss / max(tss, 1e-300)
    # R's AIC for gaussian lm: n*log(2*pi*rss/n) + n + 2*(p+1)
    aic = n * math.log(2 * math.pi * rss / n) + n + 2 * (p + 1)
    return OLSFit(terms, coef, stderr, tvals, pvals, aic, r2, sigma2, n)


def kfold_cv(
    data: dict[str, np.ndarray],
    y: np.ndarray,
    formula: str,
    k: int = 20,
    seed: int = 0,
) -> float:
    """K-fold cross-validated RMSE (paper uses K=20 to reduce over-fitting)."""
    n = len(y)
    rng = np.random.default_rng(seed)
    idx = rng.permutation(n)
    folds = np.array_split(idx, k)
    sq = 0.0
    for f in folds:
        mask = np.ones(n, bool)
        mask[f] = False
        train = {v: a[mask] for v, a in data.items()}
        test = {v: a[f] for v, a in data.items()}
        fit = fit_ols(train, y[mask], formula)
        pred = fit.predict(test)
        sq += float(((pred - y[f]) ** 2).sum())
    return math.sqrt(sq / n)


# ---------------------------------------------------------------------------
# Simulated measurement campaigns (the container has no NVMe/HDD).
# Ground truth mirrors the paper's *findings* so the regression should
# recover the same significance structure.
# ---------------------------------------------------------------------------

NVME_FORMULA = "x1*x3*x4 + x5*x4*x3"  # eq. 8
HDD_FORMULA = "x3*x4 + x5*x1*x2"      # eq. 9
NVME_TERMS = expand_formula(NVME_FORMULA)
HDD_TERMS = expand_formula(HDD_FORMULA)

# Ground-truth coefficients = the paper's own fitted estimates (Tables I–IV).
# The simulated "device" IS the paper's behavioral model plus measurement
# noise, so the regression benchmark can check *recovery* of both the
# coefficients and the significance structure against the published tables.
PAPER_NVME_WRITE = {  # Table I
    "(Intercept)": -5.941, "x1": 6.252e-1, "x3": -6.326e-5, "x4": 3.726e-5,
    "x5": 6.213e-11, "x1:x3": 1.667e-6, "x1:x4": -8.464e-7, "x3:x4": -1.650e-9,
    "x4:x5": 2.029e-16, "x3:x5": -6.564e-16, "x1:x3:x4": 1.973e-10,
    "x3:x4:x5": 1.103e-20,
}
PAPER_NVME_READ = {  # Table II
    "(Intercept)": -6.059, "x1": 2.182e-2, "x3": 1.009e-4, "x4": -3.566e-6,
    "x5": 6.963e-11, "x1:x3": -2.066e-7, "x1:x4": -1.165e-8, "x3:x4": -4.060e-10,
    "x4:x5": 1.259e-16, "x3:x5": -2.984e-15, "x1:x3:x4": -6.675e-12,
    "x3:x4:x5": 1.896e-20,
}
PAPER_HDD_WRITE = {  # Table III
    "(Intercept)": 7.297, "x3": 4.318e-4, "x4": -4.354e-6, "x5": 1.002e-8,
    "x1": 3.869e-1, "x2": 6.664, "x3:x4": 2.007e-11, "x1:x5": -7.486e-11,
    "x2:x5": -9.269e-10, "x1:x2": -9.916e-2, "x1:x2:x5": 8.344e-12,
}
PAPER_HDD_READ = {  # Table IV
    "(Intercept)": -3.771e-1, "x3": 5.913e-4, "x4": -1.584e-6, "x2": 8.933,
    "x1": -2.563, "x5": 6.274e-10, "x3:x4": 1.715e-8, "x1:x2": 3.694e-1,
    "x2:x5": -2.272e-10, "x1:x5": -4.751e-11, "x1:x2:x5": 5.167e-12,
}


def _truth(data: dict[str, np.ndarray], coefs: dict[str, float]) -> np.ndarray:
    n = len(next(iter(data.values())))
    y = np.full(n, coefs.get("(Intercept)", 0.0))
    for name, c in coefs.items():
        if name == "(Intercept)":
            continue
        col = np.ones(n)
        for v in name.split(":"):
            col = col * data[v]
        y = y + c * col
    return y


def simulate_nvme(
    n_exp: int = 400, *, read: bool, seed: int = 0, noise: float = 0.05
) -> tuple[dict[str, np.ndarray], np.ndarray]:
    """Simulated NVMe campaign over the paper's §V-A training ranges.

    Response = the paper's fitted model (Table I/II) + gaussian noise with
    ``noise`` * sd(signal).
    """
    rng = np.random.default_rng(seed + (1 if read else 0))
    x1 = rng.choice([8, 16, 32, 64], n_exp).astype(float)           # threads
    x3 = rng.choice([512, 4096, 8192, 65536, 262144], n_exp).astype(float)
    x4 = np.exp(rng.uniform(np.log(1e3), np.log(4e6), n_exp))       # #requests
    x5 = np.exp(rng.uniform(np.log(5e8), np.log(5e11), n_exp))      # addr range
    x2 = np.minimum(x5 / x3, x4)                                    # distinct blocks
    data = dict(x1=x1, x2=x2, x3=x3, x4=x4, x5=x5)
    y = _truth(data, PAPER_NVME_READ if read else PAPER_NVME_WRITE)
    y = y + rng.normal(0.0, noise * y.std(), n_exp)
    return data, y


def simulate_hdd(
    n_exp: int = 200, *, read: bool, seed: int = 0, noise: float = 0.05
) -> tuple[dict[str, np.ndarray], np.ndarray]:
    """Simulated parallel-HDF5-on-Lustre campaign over the §V-B ranges.

    Response = the paper's fitted model (Table III/IV) + gaussian noise.
    """
    rng = np.random.default_rng(seed + (10 if read else 11))
    x1 = rng.choice([4, 8, 16, 32, 64, 128, 200], n_exp).astype(float)  # procs
    x2 = rng.choice([1, 2, 4, 8], n_exp).astype(float)                  # disks
    x4 = np.exp(rng.uniform(np.log(65536), np.log(6.4e7), n_exp))       # stripe
    x5 = np.exp(rng.uniform(np.log(1e8), np.log(3.5e11), n_exp))        # file
    x3 = np.maximum(x5 / (x4 * x2), 1.0)                                # stripes/disk
    data = dict(x1=x1, x2=x2, x3=x3, x4=x4, x5=x5)
    y = _truth(data, PAPER_HDD_READ if read else PAPER_HDD_WRITE)
    y = y + rng.normal(0.0, noise * y.std(), n_exp)
    return data, y


@dataclasses.dataclass(frozen=True)
class DeviceModel:
    """A fitted device behavioral model usable as a queuing service rate."""

    fit: OLSFit
    kind: str  # nvme_read | nvme_write | hdd_read | hdd_write
    cv_rmse: float

    def total_time(self, **xs: float) -> float:
        data = {k: np.asarray([v], float) for k, v in xs.items()}
        return float(self.fit.predict(data)[0])

    def service_rate(self, n_requests: float, **xs: float) -> float:
        """Mean requests/sec implied by the model (μ for queuing)."""
        t = self.total_time(x4=n_requests, **xs)
        return n_requests / max(t, 1e-9)


def fit_nvme_model(*, read: bool, n_exp: int = 400, seed: int = 0) -> DeviceModel:
    data, y = simulate_nvme(n_exp, read=read, seed=seed)
    fit = fit_ols(data, y, NVME_FORMULA)
    cv = kfold_cv(data, y, NVME_FORMULA, k=20, seed=seed)
    return DeviceModel(fit, "nvme_read" if read else "nvme_write", cv)


def fit_hdd_model(*, read: bool, n_exp: int = 200, seed: int = 0) -> DeviceModel:
    data, y = simulate_hdd(n_exp, read=read, seed=seed)
    fit = fit_ols(data, y, HDD_FORMULA)
    cv = kfold_cv(data, y, HDD_FORMULA, k=20, seed=seed)
    return DeviceModel(fit, "hdd_read" if read else "hdd_write", cv)


# ---------------------------------------------------------------------------
# Load-dependent service: fit the μ(Q)/μ(0) ratio to a rational factor.
# ---------------------------------------------------------------------------


def fit_mu_load(
    q: Sequence[float], ratio: Sequence[float]
) -> tuple[float, float]:
    """Fit ``(a, b)`` of the load factor ``f(Q) = (1 + a·Q) / (1 + b·Q)`` to
    measured service-rate ratios ``ratio[i] ≈ μ(q[i]) / μ(0)``.

    The factor multiplies the base service rate in the fluid solve
    (``RateSpec.mu_load``): ``a > b`` models throughput that *improves*
    with queue depth (deeper device queues batch/coalesce better — the
    NVMe behavior behind the x1:x3:x4 term), ``a < b`` models degradation
    (page-fault/GC pressure), and ``a = b`` is load-independent. The form
    is linear in (a, b) after rearranging ``r·(1 + b·Q) = 1 + a·Q`` into
    ``r − 1 = a·Q − r·b·Q``, so the fit is one least-squares solve. Both
    coefficients are clamped to ≥ 0, matching the solver's stability
    guard (f stays positive and bounded by max(1, a/b)).
    """
    q = np.asarray(q, float)
    r = np.asarray(ratio, float)
    if q.shape != r.shape or q.ndim != 1 or len(q) < 2:
        raise ValueError(
            "fit_mu_load needs matching 1-d q/ratio arrays with >= 2 points")
    if np.any(~np.isfinite(q)) or np.any(~np.isfinite(r)) or np.any(r <= 0):
        raise ValueError("q and ratio must be finite with ratio > 0")
    X = np.stack([q, -r * q], axis=1)
    (a, b), *_ = np.linalg.lstsq(X, r - 1.0, rcond=None)
    return max(float(a), 0.0), max(float(b), 0.0)


def mu_load_from_devices(
    tier1_q: Sequence[float], tier1_ratio: Sequence[float],
    tier2_q: Sequence[float], tier2_ratio: Sequence[float],
) -> tuple[tuple[float, float], tuple[float, float]]:
    """Build a ``RateSpec.mu_load`` value from per-tier load-sensitivity
    curves: two :func:`fit_mu_load` fits packed as ``((a1, b1), (a2, b2))``."""
    return (fit_mu_load(tier1_q, tier1_ratio),
            fit_mu_load(tier2_q, tier2_ratio))
