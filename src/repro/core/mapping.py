"""Page -> shard mapping policies (paper §III).

The paper distributes pages across MPI processes with round-robin, random,
block and block-cyclic policies; the policy is chosen from the correlation
structure of the workload ("random mapping will provide good load balance
... block mapping will minimize inter process communication for exclusively
accessed pages").

Here a *shard* is a device slice of the mesh ``model`` axis (tier-1 page
pools live in per-device HBM). All maps are pure jittable int32 -> int32
functions so they can run inside shard_map'd engines and Pallas index maps.
"""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

__all__ = ["page_to_shard", "MAPPING_POLICIES", "shard_load"]

# Knuth multiplicative hash constant (fits in uint32).
_HASH_MULT = jnp.uint32(2654435761)


def _round_robin(page: jnp.ndarray, n_shards: int, n_pages: int) -> jnp.ndarray:
    del n_pages
    return (page % n_shards).astype(jnp.int32)


def _random(page: jnp.ndarray, n_shards: int, n_pages: int) -> jnp.ndarray:
    del n_pages
    h = (page.astype(jnp.uint32) * _HASH_MULT) >> jnp.uint32(16)
    return (h % jnp.uint32(n_shards)).astype(jnp.int32)


def _block(page: jnp.ndarray, n_shards: int, n_pages: int) -> jnp.ndarray:
    block = -(-n_pages // n_shards)  # ceil
    return jnp.clip(page // block, 0, n_shards - 1).astype(jnp.int32)


def _block_cyclic(
    page: jnp.ndarray, n_shards: int, n_pages: int, block: int = 8
) -> jnp.ndarray:
    del n_pages
    return ((page // block) % n_shards).astype(jnp.int32)


MAPPING_POLICIES: dict[str, Callable[..., jnp.ndarray]] = {
    "round_robin": _round_robin,
    "random": _random,
    "block": _block,
    "block_cyclic": _block_cyclic,
}


def page_to_shard(
    page: jnp.ndarray,
    n_shards: int,
    n_pages: int,
    policy: str = "block",
    **kw,
) -> jnp.ndarray:
    """Map page numbers to owning shard ids under ``policy``.

    ``page`` may be any int array; returns int32 of the same shape.
    """
    try:
        fn = MAPPING_POLICIES[policy]
    except KeyError:
        raise ValueError(
            f"unknown mapping policy {policy!r}; options: {sorted(MAPPING_POLICIES)}"
        ) from None
    return fn(page, n_shards, n_pages, **kw)


def shard_load(
    pages: jnp.ndarray, n_shards: int, n_pages: int, policy: str, **kw
) -> jnp.ndarray:
    """Request count per shard for a page stream — the load-balance metric the
    paper uses to choose between policies (§III)."""
    owner = page_to_shard(pages, n_shards, n_pages, policy, **kw)
    return jnp.bincount(owner, length=n_shards)
