"""Page -> shard mapping policies (paper §III).

The paper distributes pages across MPI processes with round-robin, random,
block and block-cyclic policies; the policy is chosen from the correlation
structure of the workload ("random mapping will provide good load balance
... block mapping will minimize inter process communication for exclusively
accessed pages").

Here a *shard* is a device slice of the mesh ``model`` axis (tier-1 page
pools live in per-device HBM). All maps are pure jittable int32 -> int32
functions so they can run inside shard_map'd engines and Pallas index maps.
"""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp
import numpy as np

__all__ = ["page_to_shard", "MAPPING_POLICIES", "shard_load",
           "apply_failover"]

# Knuth multiplicative hash constant (fits in uint32).
_HASH_MULT = jnp.uint32(2654435761)


def _round_robin(page: jnp.ndarray, n_shards: int, n_pages: int) -> jnp.ndarray:
    del n_pages
    return (page % n_shards).astype(jnp.int32)


def _random(page: jnp.ndarray, n_shards: int, n_pages: int) -> jnp.ndarray:
    del n_pages
    h = (page.astype(jnp.uint32) * _HASH_MULT) >> jnp.uint32(16)
    return (h % jnp.uint32(n_shards)).astype(jnp.int32)


def _block(page: jnp.ndarray, n_shards: int, n_pages: int) -> jnp.ndarray:
    block = -(-n_pages // n_shards)  # ceil
    return jnp.clip(page // block, 0, n_shards - 1).astype(jnp.int32)


def _block_cyclic(
    page: jnp.ndarray, n_shards: int, n_pages: int, block: int = 8
) -> jnp.ndarray:
    del n_pages
    return ((page // block) % n_shards).astype(jnp.int32)


MAPPING_POLICIES: dict[str, Callable[..., jnp.ndarray]] = {
    "round_robin": _round_robin,
    "random": _random,
    "block": _block,
    "block_cyclic": _block_cyclic,
}


def page_to_shard(
    page: jnp.ndarray,
    n_shards: int,
    n_pages: int,
    policy: str = "block",
    **kw,
) -> jnp.ndarray:
    """Map page numbers to owning shard ids under ``policy``.

    ``page`` may be any int array; returns int32 of the same shape.
    """
    try:
        fn = MAPPING_POLICIES[policy]
    except KeyError:
        raise ValueError(
            f"unknown mapping policy {policy!r}; options: {sorted(MAPPING_POLICIES)}"
        ) from None
    return fn(page, n_shards, n_pages, **kw)


def apply_failover(
    owner: np.ndarray,
    times: np.ndarray,
    down_intervals,
    n_shards: int,
) -> tuple[np.ndarray, np.ndarray]:
    """Reroute requests owned by down shards to surviving shards.

    ``owner[i]`` is request i's home shard (from :func:`page_to_shard`),
    ``times[i]`` its wall-clock arrival, and ``down_intervals`` a sequence
    of ``(shard, t0, t1)`` outages (:meth:`FaultSpec.down_intervals`). A
    request whose home shard is down at its arrival time fails over to the
    nearest *alive* shard by cyclic rotation ``(home + offset) % n_shards``
    — deterministic, so the same key range lands on the same survivor
    (the survivor absorbs the failed shard's working set, evicting its
    own). If every shard is down at that instant the request keeps its
    home (it will queue against a dead device).

    Host-side numpy on purpose: the remap is *data* preparation for the
    jitted engine (the remapped owner array is an operand, so fault grids
    do not recompile), mirroring how traffic generation stays host-side.

    Returns ``(new_owner, remapped)`` — int32 owners and the bool mask of
    rerouted requests.
    """
    owner = np.asarray(owner)
    times = np.asarray(times, float)
    if owner.shape != times.shape:
        raise ValueError("owner and times must have matching shapes")
    down = np.zeros((n_shards, owner.shape[0]), dtype=bool)
    for shard, t0, t1 in down_intervals:
        if not 0 <= shard < n_shards:
            raise ValueError(f"down shard {shard} out of range "
                             f"[0, {n_shards})")
        down[shard] |= (times >= t0) & (times < t1)
    new_owner = owner.astype(np.int32).copy()
    needy = down[owner, np.arange(owner.shape[0])]
    # Rotate each needy request through the ring until it finds an alive
    # shard; n_shards - 1 hops always suffice when any survivor exists.
    unresolved = needy.copy()
    for offset in range(1, n_shards):
        if not unresolved.any():
            break
        cand = (owner + offset) % n_shards
        take = unresolved & ~down[cand, np.arange(owner.shape[0])]
        new_owner[take] = cand[take]
        unresolved &= ~take
    # Fully-down instants keep their home shard (nothing alive to take
    # the traffic); they do not count as remapped.
    remapped = needy & ~unresolved
    return new_owner, remapped


def shard_load(
    pages: jnp.ndarray, n_shards: int, n_pages: int, policy: str, **kw
) -> jnp.ndarray:
    """Request count per shard for a page stream — the load-balance metric the
    paper uses to choose between policies (§III)."""
    owner = page_to_shard(pages, n_shards, n_pages, policy, **kw)
    return jnp.bincount(owner, length=n_shards)
