"""IO request prefetchers (paper §III, §II).

Two categories per the paper: **stream identifiers** (constant strides
computed from differences between miss addresses) and **Markov chains**
(transition tables over recent pages — "better at recognizing non-trivial
sequences than stream identifiers").

Prefetched pages land in a separate prefetch buffer that follows the same
mapping function as the cache; misses first probe the buffer and, on a hit,
promote the page to the cache (§III). Prefetching happens only when the
buffer has empty slots, and "page misses are prioritized over prefetches".
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "PrefetchState",
    "init_prefetch",
    "probe_and_promote",
    "observe_miss",
    "issue_prefetches",
    "MarkovState",
    "init_markov",
    "markov_observe",
    "markov_predict",
]


class PrefetchState(NamedTuple):
    """Prefetch buffer + stream-identifier state."""

    ptags: jnp.ndarray      # int32[B] page ids in the buffer (-1 empty)
    pvalid: jnp.ndarray     # bool[B]
    last_miss: jnp.ndarray  # int32[] previous miss page
    stride: jnp.ndarray     # int32[] current candidate stride
    conf: jnp.ndarray       # int32[] consecutive confirmations of stride
    issued: jnp.ndarray     # int32[] total prefetches issued (stat)
    useful: jnp.ndarray     # int32[] prefetch-buffer hits (stat)


def init_prefetch(buf_size: int) -> PrefetchState:
    z = jnp.zeros((), jnp.int32)
    return PrefetchState(
        ptags=jnp.full((buf_size,), -1, jnp.int32),
        pvalid=jnp.zeros((buf_size,), bool),
        last_miss=jnp.full((), -1, jnp.int32),
        stride=z,
        conf=z,
        issued=z,
        useful=z,
    )


def probe_and_promote(pf: PrefetchState, page: jnp.ndarray):
    """On a cache miss, look for ``page`` in the prefetch buffer. If found it
    is *removed* (promotion to the cache happens in the store engine).

    Returns ``(pf, found)``.
    """
    match = pf.pvalid & (pf.ptags == page)
    found = jnp.any(match)
    pvalid = jnp.where(match, False, pf.pvalid)
    return (
        pf._replace(pvalid=pvalid, useful=pf.useful + found.astype(jnp.int32)),
        found,
    )


def observe_miss(pf: PrefetchState, page: jnp.ndarray) -> PrefetchState:
    """Stream identifier: track differences between consecutive miss pages;
    two equal consecutive deltas confirm a stride."""
    delta = page - pf.last_miss
    same = (delta == pf.stride) & (pf.last_miss >= 0) & (delta != 0)
    conf = jnp.where(same, pf.conf + 1, jnp.where(delta != 0, 1, pf.conf))
    stride = jnp.where(same, pf.stride, jnp.where(delta != 0, delta, pf.stride))
    return pf._replace(last_miss=page, stride=stride, conf=conf)


def issue_prefetches(
    pf: PrefetchState,
    page: jnp.ndarray,
    cache_tags: jnp.ndarray,
    cache_valid: jnp.ndarray,
    width: int,
) -> PrefetchState:
    """Insert up to ``width`` predicted pages (page + k*stride) into empty
    buffer slots, skipping pages already cached or buffered.

    Static-shape: iterates ``width`` candidates with a fori_loop, each doing a
    masked single-slot insert — mirrors "prefetching is performed only if
    there are empty slots in the prefetch buffer".
    """
    active = pf.conf >= 2

    def body(k, pf_):
        cand = page + (k + 1) * pf_.stride
        in_cache = jnp.any(cache_valid & (cache_tags == cand))
        in_buf = jnp.any(pf_.pvalid & (pf_.ptags == cand))
        free = ~pf_.pvalid
        has_free = jnp.any(free)
        do = active & has_free & ~in_cache & ~in_buf & (cand >= 0)
        slot = jnp.argmax(free).astype(jnp.int32)
        ptags = jnp.where(do, pf_.ptags.at[slot].set(cand), pf_.ptags)
        pvalid = jnp.where(do, pf_.pvalid.at[slot].set(True), pf_.pvalid)
        return pf_._replace(
            ptags=ptags, pvalid=pvalid, issued=pf_.issued + do.astype(jnp.int32)
        )

    return jax.lax.fori_loop(0, width, body, pf)


# ---------------------------------------------------------------------------
# Markov-chain prefetcher (first order, hashed state table) — §II [12], [40].
# ---------------------------------------------------------------------------


class MarkovState(NamedTuple):
    succ: jnp.ndarray   # int32[S, K] successor pages per hashed state
    count: jnp.ndarray  # int32[S, K] transition counts
    prev: jnp.ndarray   # int32[] previous page (-1 at start)


def _hash_state(page: jnp.ndarray, n_states: int) -> jnp.ndarray:
    h = page.astype(jnp.uint32) * jnp.uint32(2654435761)
    return ((h >> jnp.uint32(8)) % jnp.uint32(n_states)).astype(jnp.int32)


def init_markov(n_states: int = 256, k: int = 4) -> MarkovState:
    return MarkovState(
        succ=jnp.full((n_states, k), -1, jnp.int32),
        count=jnp.zeros((n_states, k), jnp.int32),
        prev=jnp.full((), -1, jnp.int32),
    )


def markov_observe(mk: MarkovState, page: jnp.ndarray) -> MarkovState:
    """Record transition prev -> page in the hashed table (LFU slot steal)."""
    n_states = mk.succ.shape[0]
    s = _hash_state(mk.prev, n_states)
    row_succ = mk.succ[s]
    row_cnt = mk.count[s]
    match = row_succ == page
    found = jnp.any(match)
    slot = jnp.where(found, jnp.argmax(match), jnp.argmin(row_cnt)).astype(jnp.int32)
    new_succ = row_succ.at[slot].set(page)
    new_cnt = jnp.where(found, row_cnt.at[slot].add(1), row_cnt.at[slot].set(1))
    do = mk.prev >= 0
    succ = jnp.where(do, mk.succ.at[s].set(new_succ), mk.succ)
    count = jnp.where(do, mk.count.at[s].set(new_cnt), mk.count)
    return MarkovState(succ=succ, count=count, prev=page)


def markov_predict(mk: MarkovState, page: jnp.ndarray, top: int = 2) -> jnp.ndarray:
    """Most probable next pages from the current state (int32[top], -1 pad)."""
    s = _hash_state(page, mk.succ.shape[0])
    row_succ, row_cnt = mk.succ[s], mk.count[s]
    order = jnp.argsort(-row_cnt)
    cand = row_succ[order][:top]
    cnt = row_cnt[order][:top]
    return jnp.where(cnt > 0, cand, -1)
