"""Core contribution of the paper: OL cache replacement, traffic models,
mapping policies, prefetchers, queuing-network and device behavioral models.

``configurator`` is imported lazily (it depends on the storage layer).
"""
from repro.core import (  # noqa: F401
    device_models,
    mapping,
    online_learning,
    prefetch,
    queuing,
    roofline,
    traffic,
)
