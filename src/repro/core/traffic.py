"""IO traffic models (paper §VI-A).

The paper evaluates cache replacement under two traffic models:

- **Poisson**: "the probability of a page request decreases exponentially
  with time since its arrival" — pages have exponentially-decaying temporal
  locality. Chosen so the sequence is *slow evolving* (LRU-friendly, §II).
- **IRM** (Independent Reference Model): pages have fixed popularities drawn
  from a Zipf distribution and fixed lifetimes (maximum request counts).
  A page expires once its requests exceed the maximum and is replaced by a
  fresh page (sharp popularity changes; LFU-friendly).

Also provided: strided streams (exercise the stream-identifier prefetcher,
§III) and Markov-chain streams (§II, [40]) for the Markov prefetcher.

**Non-stationary workloads** (the time axis the equilibrium analysis hides):

- **phase schedules** (``kind="phased"`` / :func:`phase_schedule`) compose
  existing :class:`TrafficSpec` s into sequential phases — read-then-write,
  IRM-then-Poisson, anything the base generators produce — so miss rate and
  per-shard load drift over the stream;
- **on/off burst modulation** (``kind="onoff"`` / :func:`onoff_stream`)
  alternates background Zipf-read traffic with checkpoint-style sequential
  write bursts over a small hot page range (the paper's bursty checkpoint
  evaluation traffic).

Generators are host-side (numpy, seeded) — traffic is an *input* to the
jitted storage engine, mirroring the paper where clients generate requests
outside the cache. Each generator returns ``(pages, is_write)`` int32/bool
arrays of length ``n``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

__all__ = [
    "TrafficSpec",
    "poisson_stream",
    "irm_stream",
    "strided_stream",
    "markov_stream",
    "mixed_stream",
    "phased_stream",
    "phase_schedule",
    "onoff_stream",
    "make_stream",
]


@dataclasses.dataclass(frozen=True)
class TrafficSpec:
    """Declarative description of a workload (used by benchmarks/configs)."""

    kind: str  # poisson | irm | strided | markov | mixed | phased | onoff
    n_requests: int
    n_pages: int
    write_fraction: float = 0.0
    seed: int = 0
    # poisson
    decay_tau: float = 200.0
    arrival_rate: float = 0.05
    # irm
    zipf_s: float = 1.1
    lifetime: int = 200
    # strided
    stride: int = 1
    n_streams: int = 1
    # markov
    n_hot_states: int = 16
    hot_self_p: float = 0.85
    # phased: sequential composition of other TrafficSpecs (hashable tuple;
    # build via phase_schedule() so n_requests/n_pages stay consistent)
    phases: Optional[tuple] = None
    # onoff: background traffic modulated by checkpoint-style write bursts
    on_len: int = 64      # burst length (requests)
    off_len: int = 192    # background stretch between bursts (requests)
    burst_pages: int = 32  # checkpoint working-set size (hot page range)


def _writes(rng: np.random.Generator, n: int, frac: float) -> np.ndarray:
    if frac <= 0.0:
        return np.zeros(n, dtype=bool)
    return rng.random(n) < frac


def poisson_stream(
    n: int,
    n_pages: int,
    *,
    decay_tau: float = 200.0,
    arrival_rate: float = 0.05,
    write_fraction: float = 0.0,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """Poisson traffic model: exponential temporal decay since page arrival.

    Pages "arrive" (become active) according to a Poisson process with rate
    ``arrival_rate`` per step; at each step a request is drawn with
    probability proportional to ``exp(-(t - arrival_t[p]) / decay_tau)`` over
    active pages. ``decay_tau`` large => slow-evolving (paper's choice).
    """
    rng = np.random.default_rng(seed)
    arrival_t = np.full(n_pages, np.inf)
    # Seed a small active set so the stream is well-defined from step 0.
    n_seed = max(1, n_pages // 16)
    arrival_t[:n_seed] = 0.0
    next_page = n_seed
    pages = np.empty(n, dtype=np.int32)
    for t in range(n):
        # New page arrivals.
        k = rng.poisson(arrival_rate)
        for _ in range(k):
            if next_page < n_pages:
                arrival_t[next_page] = t
                next_page += 1
        active = np.isfinite(arrival_t)
        w = np.exp(-(t - arrival_t[active]) / decay_tau)
        w_sum = w.sum()
        if w_sum <= 0:
            w = np.ones_like(w)
            w_sum = w.sum()
        idx = rng.choice(np.nonzero(active)[0], p=w / w_sum)
        pages[t] = idx
    return pages, _writes(rng, n, write_fraction)


def irm_stream(
    n: int,
    n_pages: int,
    *,
    zipf_s: float = 1.1,
    lifetime: int = 200,
    write_fraction: float = 0.0,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """IRM traffic: Zipf popularity + fixed lifetimes (max requests).

    ``n_pages`` concurrent popularity *slots*; when a slot's page exceeds its
    lifetime it expires and a brand-new page id takes over the slot (sharp
    change in the active set, preserving the popularity distribution).
    Page ids grow beyond ``n_pages`` as pages expire — callers should treat
    the page id space as unbounded (the cache engine hashes tags, not ranks).
    """
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, n_pages + 1, dtype=np.float64)
    pop = ranks ** (-zipf_s)
    pop /= pop.sum()
    slot_page = np.arange(n_pages, dtype=np.int64)  # page id per slot
    slot_count = np.zeros(n_pages, dtype=np.int64)
    slot_life = rng.poisson(lifetime, size=n_pages).clip(min=1)
    next_id = n_pages
    pages = np.empty(n, dtype=np.int32)
    slots = rng.choice(n_pages, size=n, p=pop)
    for t, s in enumerate(slots):
        pages[t] = slot_page[s]
        slot_count[s] += 1
        if slot_count[s] >= slot_life[s]:  # page expired -> fresh page
            slot_page[s] = next_id
            next_id += 1
            slot_count[s] = 0
            slot_life[s] = max(1, int(rng.poisson(lifetime)))
    return pages, _writes(rng, n, write_fraction)


def strided_stream(
    n: int,
    n_pages: int,
    *,
    stride: int = 1,
    n_streams: int = 1,
    write_fraction: float = 0.0,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """Interleaved constant-stride streams (prefetcher-friendly)."""
    rng = np.random.default_rng(seed)
    starts = rng.integers(0, n_pages, size=n_streams)
    pages = np.empty(n, dtype=np.int32)
    for t in range(n):
        s = t % n_streams
        step = t // n_streams
        pages[t] = (starts[s] + step * stride) % n_pages
    return pages, _writes(rng, n, write_fraction)


def markov_stream(
    n: int,
    n_pages: int,
    *,
    n_hot_states: int = 16,
    hot_self_p: float = 0.85,
    write_fraction: float = 0.0,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """First-order Markov page stream: a hot ring with occasional jumps.

    From hot page ``h`` the next request is ``h+1`` in the hot ring with
    probability ``hot_self_p``, otherwise a uniform random page. Exercises
    the Markov prefetcher (non-strided but predictable transitions).
    """
    rng = np.random.default_rng(seed)
    hot = rng.choice(n_pages, size=min(n_hot_states, n_pages), replace=False)
    pages = np.empty(n, dtype=np.int32)
    pos = 0
    for t in range(n):
        if rng.random() < hot_self_p:
            pages[t] = hot[pos]
            pos = (pos + 1) % len(hot)
        else:
            pages[t] = rng.integers(0, n_pages)
    return pages, _writes(rng, n, write_fraction)


def mixed_stream(
    n: int,
    n_pages: int,
    *,
    switch_every: int = 1000,
    write_fraction: float = 0.0,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """Alternate Poisson and IRM phases — the paper's motivation for OL:
    "a mix of cache replacement algorithms will perform better for complex
    IO traffic" (§I). Phase switches force the OL policy to re-learn.
    """
    rng = np.random.default_rng(seed)
    pages = np.empty(n, dtype=np.int32)
    t = 0
    phase = 0
    while t < n:
        m = min(switch_every, n - t)
        gen = poisson_stream if phase == 0 else irm_stream
        p, _ = gen(m, n_pages, seed=int(rng.integers(2**31)))
        pages[t : t + m] = p
        t += m
        phase ^= 1
    return pages, _writes(rng, n, write_fraction)


def phased_stream(
    phases: Sequence[TrafficSpec],
) -> tuple[np.ndarray, np.ndarray]:
    """Concatenate the streams of sequential phases (shared page space).

    Each phase is generated by its own :class:`TrafficSpec` (own kind, seed,
    write fraction, length); the phases run back to back, so the composed
    stream's locality, write mix and page footprint shift at phase
    boundaries — exactly the non-stationarity a windowed report resolves.
    """
    if not phases:
        raise ValueError("phased traffic needs at least one phase")
    parts = [make_stream(p) for p in phases]
    pages = np.concatenate([p for p, _ in parts]).astype(np.int32)
    writes = np.concatenate([w for _, w in parts]).astype(bool)
    return pages, writes


def phase_schedule(*phases: TrafficSpec, seed: int = 0) -> TrafficSpec:
    """Compose :class:`TrafficSpec` phases into one ``kind="phased"`` spec.

    The schedule's ``n_requests`` is the sum over phases and its ``n_pages``
    the max (the §III mapping partitions the widest declared page space).
    """
    if not phases:
        raise ValueError("phase_schedule needs at least one phase")
    return TrafficSpec(
        kind="phased",
        n_requests=sum(p.n_requests for p in phases),
        n_pages=max(p.n_pages for p in phases),
        seed=seed,
        phases=tuple(phases),
    )


def onoff_stream(
    n: int,
    n_pages: int,
    *,
    on_len: int = 64,
    off_len: int = 192,
    burst_pages: int = 32,
    zipf_s: float = 1.1,
    write_fraction: float = 0.0,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """On/off burst modulation: Zipf-read background with periodic
    checkpoint-style write bursts.

    OFF stretches (``off_len`` requests) draw Zipf-popular pages over the
    full page space with the base ``write_fraction``; ON bursts (``on_len``
    requests) issue sequential *writes* over a small hot checkpoint range
    (``burst_pages`` pages, resuming where the previous burst stopped).
    Bursts shift both the miss fraction and the write mix window to window —
    the paper's bursty checkpoint traffic.
    """
    if on_len < 0 or off_len < 0 or on_len + off_len == 0:
        raise ValueError("need on_len + off_len > 0 (both non-negative)")
    rng = np.random.default_rng(seed)
    burst_span = max(1, min(burst_pages, n_pages))
    ranks = np.arange(1, n_pages + 1, dtype=np.float64)
    pop = ranks ** (-zipf_s)
    pop /= pop.sum()
    pages = np.empty(n, dtype=np.int32)
    writes = np.zeros(n, dtype=bool)
    t = 0
    ckpt = 0
    while t < n:
        m = min(off_len, n - t)
        if m:
            pages[t : t + m] = rng.choice(n_pages, size=m, p=pop)
            writes[t : t + m] = rng.random(m) < write_fraction
            t += m
        m = min(on_len, n - t)
        if m:
            pages[t : t + m] = (ckpt + np.arange(m)) % burst_span
            writes[t : t + m] = True
            ckpt = (ckpt + m) % burst_span
            t += m
    return pages, writes


def make_stream(spec: TrafficSpec) -> tuple[np.ndarray, np.ndarray]:
    """Build a stream from a :class:`TrafficSpec`."""
    common = dict(
        write_fraction=spec.write_fraction,
        seed=spec.seed,
    )
    if spec.kind == "poisson":
        return poisson_stream(
            spec.n_requests,
            spec.n_pages,
            decay_tau=spec.decay_tau,
            arrival_rate=spec.arrival_rate,
            **common,
        )
    if spec.kind == "irm":
        return irm_stream(
            spec.n_requests,
            spec.n_pages,
            zipf_s=spec.zipf_s,
            lifetime=spec.lifetime,
            **common,
        )
    if spec.kind == "strided":
        return strided_stream(
            spec.n_requests,
            spec.n_pages,
            stride=spec.stride,
            n_streams=spec.n_streams,
            **common,
        )
    if spec.kind == "markov":
        return markov_stream(
            spec.n_requests,
            spec.n_pages,
            n_hot_states=spec.n_hot_states,
            hot_self_p=spec.hot_self_p,
            **common,
        )
    if spec.kind == "mixed":
        return mixed_stream(spec.n_requests, spec.n_pages, **common)
    if spec.kind == "phased":
        if not spec.phases:
            raise ValueError("phased TrafficSpec needs a non-empty phases "
                             "tuple (see phase_schedule())")
        total = sum(p.n_requests for p in spec.phases)
        if total != spec.n_requests:
            raise ValueError(
                f"phased n_requests={spec.n_requests} != sum of phase "
                f"lengths {total} (build the spec via phase_schedule())"
            )
        return phased_stream(spec.phases)
    if spec.kind == "onoff":
        return onoff_stream(
            spec.n_requests,
            spec.n_pages,
            on_len=spec.on_len,
            off_len=spec.off_len,
            burst_pages=spec.burst_pages,
            zipf_s=spec.zipf_s,
            **common,
        )
    raise ValueError(f"unknown traffic kind: {spec.kind!r}")
