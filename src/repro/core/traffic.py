"""IO traffic models (paper §VI-A).

The paper evaluates cache replacement under two traffic models:

- **Poisson**: "the probability of a page request decreases exponentially
  with time since its arrival" — pages have exponentially-decaying temporal
  locality. Chosen so the sequence is *slow evolving* (LRU-friendly, §II).
- **IRM** (Independent Reference Model): pages have fixed popularities drawn
  from a Zipf distribution and fixed lifetimes (maximum request counts).
  A page expires once its requests exceed the maximum and is replaced by a
  fresh page (sharp popularity changes; LFU-friendly).

Also provided: strided streams (exercise the stream-identifier prefetcher,
§III) and Markov-chain streams (§II, [40]) for the Markov prefetcher.

**Non-stationary workloads** (the time axis the equilibrium analysis hides):

- **phase schedules** (``kind="phased"`` / :func:`phase_schedule`) compose
  existing :class:`TrafficSpec` s into sequential phases — read-then-write,
  IRM-then-Poisson, anything the base generators produce — so miss rate and
  per-shard load drift over the stream;
- **on/off burst modulation** (``kind="onoff"`` / :func:`onoff_stream`)
  alternates background Zipf-read traffic with checkpoint-style sequential
  write bursts over a small hot page range (the paper's bursty checkpoint
  evaluation traffic).

**Wall-clock arrival timestamps** (the time axis the request-index view
hides): :func:`make_timed_stream` emits an arrival-time process alongside
every stream — Poisson arrivals (exponential inter-arrival gaps) for the
stationary kinds, MMPP-style modulated rates for ``onoff`` (background
stretches arrive at the base rate, checkpoint bursts at ``burst_rate``
with *deterministic* spacing — a checkpoint writer streams stripes
back-to-back, it does not jitter), and second-composed phases for
``phased`` (each phase's own rate process, offset by the previous phase's
realized end — :func:`phase_schedule` composes in seconds, not request
counts). Timestamps let the windowed pipeline bin outcomes by wall-clock
time, so per-window arrival rates are *measured*, not flat by
construction.

**Multi-tenant chunked workloads** (``kind="tenant_mix"`` /
:func:`tenant_mix`): N tenants with distinct Poisson arrival rates,
disjoint Zipf key spaces and read/write mixes, merged by arrival time.
:class:`TenantStream` generates the merged stream *chunk by chunk* — each
tenant is a deterministic event sequence drawn in fixed-size blocks, so
the merged prefix is bit-identical whatever the chunking and the full mix
never has to materialize at once (the streaming replay path's generator).
``state()``/``restore()`` snapshot the generator for checkpoint/resume.

Generators are host-side (numpy, seeded) — traffic is an *input* to the
jitted storage engine, mirroring the paper where clients generate requests
outside the cache. Each generator returns ``(pages, is_write)`` int32/bool
arrays of length ``n``; :func:`make_timed_stream` adds a float64 ``times``
array (strictly increasing arrival seconds).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import numpy as np

__all__ = [
    "TrafficSpec",
    "TenantSpec",
    "TenantStream",
    "tenant_mix",
    "tenant_mix_stream",
    "poisson_stream",
    "irm_stream",
    "strided_stream",
    "markov_stream",
    "mixed_stream",
    "phased_stream",
    "phase_schedule",
    "onoff_stream",
    "make_stream",
    "arrival_times",
    "onoff_arrival_times",
    "make_timed_stream",
    "nominal_duration",
    "nominal_duration_std",
]


@dataclasses.dataclass(frozen=True)
class TrafficSpec:
    """Declarative description of a workload (used by benchmarks/configs).

    Nonsensical configurations raise ``ValueError`` at construction (not as
    NaN reports downstream): ``n_requests``/``n_pages`` must be positive,
    ``write_fraction`` must lie in [0, 1], and the wall-clock rates must be
    non-negative (0 = unset, the caller supplies a default).
    """

    kind: str  # poisson | irm | strided | markov | mixed | phased | onoff
    n_requests: int
    n_pages: int
    write_fraction: float = 0.0
    seed: int = 0
    # poisson
    decay_tau: float = 200.0
    arrival_rate: float = 0.05
    # irm
    zipf_s: float = 1.1
    lifetime: int = 200
    # strided
    stride: int = 1
    n_streams: int = 1
    # markov
    n_hot_states: int = 16
    hot_self_p: float = 0.85
    # phased: sequential composition of other TrafficSpecs (hashable tuple;
    # build via phase_schedule() so n_requests/n_pages stay consistent)
    phases: Optional[tuple] = None
    # onoff: background traffic modulated by checkpoint-style write bursts
    on_len: int = 64      # burst length (requests)
    off_len: int = 192    # background stretch between bursts (requests)
    burst_pages: int = 32  # checkpoint working-set size (hot page range)
    # wall-clock arrival process (make_timed_stream): offered arrival rate
    # in req/s. 0.0 = unset — the caller supplies a default (repro.sim uses
    # lam * n_shards, the aggregate offered rate).
    rate: float = 0.0
    # onoff: arrival rate inside checkpoint bursts (req/s, deterministic
    # back-to-back stripes). 0.0 = BURST_RATE_MULT x the base rate.
    burst_rate: float = 0.0
    # tenant_mix: per-tenant arrival/key-space/write profiles (hashable
    # tuple of TenantSpec; build via tenant_mix() so rate/n_pages stay
    # consistent with the tenant sums).
    tenants: Optional[tuple] = None

    def __post_init__(self):
        if self.kind == "tenant_mix":
            if not self.tenants:
                raise ValueError(
                    "tenant_mix TrafficSpec needs a non-empty tenants "
                    "tuple (build it via tenant_mix())")
            for t in self.tenants:
                if not isinstance(t, TenantSpec):
                    raise ValueError(
                        "TrafficSpec.tenants entries must be TenantSpec, "
                        f"got {type(t).__name__}")
            total_pages = sum(t.n_pages for t in self.tenants)
            if self.n_pages != total_pages:
                raise ValueError(
                    f"tenant_mix n_pages={self.n_pages} must equal the sum "
                    f"of tenant page spaces {total_pages} (tenants own "
                    "disjoint key ranges; build the spec via tenant_mix())")
            total_rate = sum(t.rate for t in self.tenants)
            if not math.isclose(self.rate, total_rate, rel_tol=1e-9):
                raise ValueError(
                    f"tenant_mix rate={self.rate} must equal the sum of "
                    f"tenant rates {total_rate} (build the spec via "
                    "tenant_mix())")
        elif self.tenants is not None:
            raise ValueError(
                "TrafficSpec.tenants is only meaningful for "
                f"kind='tenant_mix', got kind={self.kind!r}")
        if self.n_requests <= 0:
            raise ValueError(
                f"TrafficSpec.n_requests must be positive, got "
                f"{self.n_requests}")
        if self.n_pages <= 0:
            raise ValueError(
                f"TrafficSpec.n_pages must be positive, got {self.n_pages}")
        if not 0.0 <= self.write_fraction <= 1.0:
            raise ValueError(
                f"TrafficSpec.write_fraction must be in [0, 1], got "
                f"{self.write_fraction}")
        if self.rate < 0.0:
            raise ValueError(
                f"TrafficSpec.rate must be non-negative (0 = unset), got "
                f"{self.rate}")
        if self.burst_rate < 0.0:
            raise ValueError(
                f"TrafficSpec.burst_rate must be non-negative (0 = unset), "
                f"got {self.burst_rate}")


def _writes(rng: np.random.Generator, n: int, frac: float) -> np.ndarray:
    if frac <= 0.0:
        return np.zeros(n, dtype=bool)
    return rng.random(n) < frac


def poisson_stream(
    n: int,
    n_pages: int,
    *,
    decay_tau: float = 200.0,
    arrival_rate: float = 0.05,
    write_fraction: float = 0.0,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """Poisson traffic model: exponential temporal decay since page arrival.

    Pages "arrive" (become active) according to a Poisson process with rate
    ``arrival_rate`` per step; at each step a request is drawn with
    probability proportional to ``exp(-(t - arrival_t[p]) / decay_tau)`` over
    active pages. ``decay_tau`` large => slow-evolving (paper's choice).
    """
    rng = np.random.default_rng(seed)
    arrival_t = np.full(n_pages, np.inf)
    # Seed a small active set so the stream is well-defined from step 0.
    n_seed = max(1, n_pages // 16)
    arrival_t[:n_seed] = 0.0
    next_page = n_seed
    pages = np.empty(n, dtype=np.int32)
    for t in range(n):
        # New page arrivals.
        k = rng.poisson(arrival_rate)
        for _ in range(k):
            if next_page < n_pages:
                arrival_t[next_page] = t
                next_page += 1
        active = np.isfinite(arrival_t)
        w = np.exp(-(t - arrival_t[active]) / decay_tau)
        w_sum = w.sum()
        if w_sum <= 0:
            w = np.ones_like(w)
            w_sum = w.sum()
        idx = rng.choice(np.nonzero(active)[0], p=w / w_sum)
        pages[t] = idx
    return pages, _writes(rng, n, write_fraction)


def irm_stream(
    n: int,
    n_pages: int,
    *,
    zipf_s: float = 1.1,
    lifetime: int = 200,
    write_fraction: float = 0.0,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """IRM traffic: Zipf popularity + fixed lifetimes (max requests).

    ``n_pages`` concurrent popularity *slots*; when a slot's page exceeds its
    lifetime it expires and a brand-new page id takes over the slot (sharp
    change in the active set, preserving the popularity distribution).
    Page ids grow beyond ``n_pages`` as pages expire — callers should treat
    the page id space as unbounded (the cache engine hashes tags, not ranks).
    """
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, n_pages + 1, dtype=np.float64)
    pop = ranks ** (-zipf_s)
    pop /= pop.sum()
    slot_page = np.arange(n_pages, dtype=np.int64)  # page id per slot
    slot_count = np.zeros(n_pages, dtype=np.int64)
    slot_life = rng.poisson(lifetime, size=n_pages).clip(min=1)
    next_id = n_pages
    pages = np.empty(n, dtype=np.int32)
    slots = rng.choice(n_pages, size=n, p=pop)
    for t, s in enumerate(slots):
        pages[t] = slot_page[s]
        slot_count[s] += 1
        if slot_count[s] >= slot_life[s]:  # page expired -> fresh page
            slot_page[s] = next_id
            next_id += 1
            slot_count[s] = 0
            slot_life[s] = max(1, int(rng.poisson(lifetime)))
    return pages, _writes(rng, n, write_fraction)


def strided_stream(
    n: int,
    n_pages: int,
    *,
    stride: int = 1,
    n_streams: int = 1,
    write_fraction: float = 0.0,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """Interleaved constant-stride streams (prefetcher-friendly)."""
    rng = np.random.default_rng(seed)
    starts = rng.integers(0, n_pages, size=n_streams)
    pages = np.empty(n, dtype=np.int32)
    for t in range(n):
        s = t % n_streams
        step = t // n_streams
        pages[t] = (starts[s] + step * stride) % n_pages
    return pages, _writes(rng, n, write_fraction)


def markov_stream(
    n: int,
    n_pages: int,
    *,
    n_hot_states: int = 16,
    hot_self_p: float = 0.85,
    write_fraction: float = 0.0,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """First-order Markov page stream: a hot ring with occasional jumps.

    From hot page ``h`` the next request is ``h+1`` in the hot ring with
    probability ``hot_self_p``, otherwise a uniform random page. Exercises
    the Markov prefetcher (non-strided but predictable transitions).
    """
    rng = np.random.default_rng(seed)
    hot = rng.choice(n_pages, size=min(n_hot_states, n_pages), replace=False)
    pages = np.empty(n, dtype=np.int32)
    pos = 0
    for t in range(n):
        if rng.random() < hot_self_p:
            pages[t] = hot[pos]
            pos = (pos + 1) % len(hot)
        else:
            pages[t] = rng.integers(0, n_pages)
    return pages, _writes(rng, n, write_fraction)


def mixed_stream(
    n: int,
    n_pages: int,
    *,
    switch_every: int = 1000,
    write_fraction: float = 0.0,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """Alternate Poisson and IRM phases — the paper's motivation for OL:
    "a mix of cache replacement algorithms will perform better for complex
    IO traffic" (§I). Phase switches force the OL policy to re-learn.
    """
    rng = np.random.default_rng(seed)
    pages = np.empty(n, dtype=np.int32)
    t = 0
    phase = 0
    while t < n:
        m = min(switch_every, n - t)
        gen = poisson_stream if phase == 0 else irm_stream
        p, _ = gen(m, n_pages, seed=int(rng.integers(2**31)))
        pages[t : t + m] = p
        t += m
        phase ^= 1
    return pages, _writes(rng, n, write_fraction)


def phased_stream(
    phases: Sequence[TrafficSpec],
) -> tuple[np.ndarray, np.ndarray]:
    """Concatenate the streams of sequential phases (shared page space).

    Each phase is generated by its own :class:`TrafficSpec` (own kind, seed,
    write fraction, length); the phases run back to back, so the composed
    stream's locality, write mix and page footprint shift at phase
    boundaries — exactly the non-stationarity a windowed report resolves.
    """
    if not phases:
        raise ValueError("phased traffic needs at least one phase")
    parts = [make_stream(p) for p in phases]
    pages = np.concatenate([p for p, _ in parts]).astype(np.int32)
    writes = np.concatenate([w for _, w in parts]).astype(bool)
    return pages, writes


def phase_schedule(*phases: TrafficSpec, seed: int = 0) -> TrafficSpec:
    """Compose :class:`TrafficSpec` phases into one ``kind="phased"`` spec.

    The schedule's ``n_requests`` is the sum over phases and its ``n_pages``
    the max (the §III mapping partitions the widest declared page space).

    The composed schedule runs in **seconds**, not request counts: when
    every phase declares an arrival ``rate``, the schedule's ``rate`` is
    the mean over the composed wall-clock span (total requests / total
    duration), and :func:`make_timed_stream` emits each phase's arrivals at
    that phase's own rate, offset by the previous phase's end — a
    high-rate phase occupies a proportionally *short* stretch of the
    timeline (a true rate burst), instead of one fixed window per equal
    request count.
    """
    if not phases:
        raise ValueError("phase_schedule needs at least one phase")
    rate = 0.0
    if all(p.rate > 0 for p in phases):
        total_n = sum(p.n_requests for p in phases)
        rate = total_n / sum(p.n_requests / p.rate for p in phases)
    return TrafficSpec(
        kind="phased",
        n_requests=sum(p.n_requests for p in phases),
        n_pages=max(p.n_pages for p in phases),
        seed=seed,
        phases=tuple(phases),
        rate=rate,
    )


def onoff_stream(
    n: int,
    n_pages: int,
    *,
    on_len: int = 64,
    off_len: int = 192,
    burst_pages: int = 32,
    zipf_s: float = 1.1,
    write_fraction: float = 0.0,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """On/off burst modulation: Zipf-read background with periodic
    checkpoint-style write bursts.

    OFF stretches (``off_len`` requests) draw Zipf-popular pages over the
    full page space with the base ``write_fraction``; ON bursts (``on_len``
    requests) issue sequential *writes* over a small hot checkpoint range
    (``burst_pages`` pages, resuming where the previous burst stopped).
    Bursts shift both the miss fraction and the write mix window to window —
    the paper's bursty checkpoint traffic.
    """
    if on_len < 0 or off_len < 0 or on_len + off_len == 0:
        raise ValueError("need on_len + off_len > 0 (both non-negative)")
    rng = np.random.default_rng(seed)
    burst_span = max(1, min(burst_pages, n_pages))
    ranks = np.arange(1, n_pages + 1, dtype=np.float64)
    pop = ranks ** (-zipf_s)
    pop /= pop.sum()
    pages = np.empty(n, dtype=np.int32)
    writes = np.zeros(n, dtype=bool)
    t = 0
    ckpt = 0
    while t < n:
        m = min(off_len, n - t)
        if m:
            pages[t : t + m] = rng.choice(n_pages, size=m, p=pop)
            writes[t : t + m] = rng.random(m) < write_fraction
            t += m
        m = min(on_len, n - t)
        if m:
            pages[t : t + m] = (ckpt + np.arange(m)) % burst_span
            writes[t : t + m] = True
            ckpt = (ckpt + m) % burst_span
            t += m
    return pages, writes


def make_stream(spec: TrafficSpec) -> tuple[np.ndarray, np.ndarray]:
    """Build a stream from a :class:`TrafficSpec`."""
    common = dict(
        write_fraction=spec.write_fraction,
        seed=spec.seed,
    )
    if spec.kind == "poisson":
        return poisson_stream(
            spec.n_requests,
            spec.n_pages,
            decay_tau=spec.decay_tau,
            arrival_rate=spec.arrival_rate,
            **common,
        )
    if spec.kind == "irm":
        return irm_stream(
            spec.n_requests,
            spec.n_pages,
            zipf_s=spec.zipf_s,
            lifetime=spec.lifetime,
            **common,
        )
    if spec.kind == "strided":
        return strided_stream(
            spec.n_requests,
            spec.n_pages,
            stride=spec.stride,
            n_streams=spec.n_streams,
            **common,
        )
    if spec.kind == "markov":
        return markov_stream(
            spec.n_requests,
            spec.n_pages,
            n_hot_states=spec.n_hot_states,
            hot_self_p=spec.hot_self_p,
            **common,
        )
    if spec.kind == "mixed":
        return mixed_stream(spec.n_requests, spec.n_pages, **common)
    if spec.kind == "phased":
        _validate_phased(spec)
        return phased_stream(spec.phases)
    if spec.kind == "onoff":
        return onoff_stream(
            spec.n_requests,
            spec.n_pages,
            on_len=spec.on_len,
            off_len=spec.off_len,
            burst_pages=spec.burst_pages,
            zipf_s=spec.zipf_s,
            **common,
        )
    if spec.kind == "tenant_mix":
        pages, writes, _, _ = tenant_mix_stream(spec)
        return pages, writes
    raise ValueError(f"unknown traffic kind: {spec.kind!r}")


def _validate_phased(spec: TrafficSpec) -> None:
    """The phased-spec invariants shared by the timed and untimed
    generators: a non-empty phase tuple whose lengths sum to the composed
    ``n_requests`` (both guaranteed by :func:`phase_schedule`)."""
    if not spec.phases:
        raise ValueError("phased TrafficSpec needs a non-empty phases "
                         "tuple (see phase_schedule())")
    total = sum(p.n_requests for p in spec.phases)
    if total != spec.n_requests:
        raise ValueError(
            f"phased n_requests={spec.n_requests} != sum of phase "
            f"lengths {total} (build the spec via phase_schedule())"
        )


# ---------------------------------------------------------------------------
# Wall-clock arrival-time processes.
# ---------------------------------------------------------------------------

# Default ON-burst rate multiplier when TrafficSpec.burst_rate is unset: a
# checkpoint writer streams stripes much faster than the offered background
# rate (the paper's bursty checkpoint traffic).
BURST_RATE_MULT = 4.0

# Seed stream for arrival times, decorrelated from the page-generator seed
# so timestamps never perturb the request sequence itself.
_TIME_SEED = 0x7157


def arrival_times(
    n: int,
    rate: float,
    *,
    seed: int = 0,
    gap_rates: Optional[np.ndarray] = None,
    deterministic: Optional[np.ndarray] = None,
    t0: float = 0.0,
) -> np.ndarray:
    """Arrival timestamps (seconds, strictly increasing) for ``n`` requests.

    The base process is Poisson at ``rate`` (i.i.d. exponential inter-arrival
    gaps). ``gap_rates`` (float[n]) modulates the rate per gap — request i
    arrives ``Exp(1/gap_rates[i])`` after request i-1, the conditional form
    of an MMPP whose modulating state is indexed by request position.
    ``deterministic`` (bool[n]) marks gaps with *no* jitter (exactly
    ``1/gap_rates[i]`` — checkpoint bursts stream back-to-back). ``t0``
    offsets the whole process (phase composition in seconds).
    """
    if rate <= 0.0 and gap_rates is None:
        raise ValueError("arrival rate must be positive")
    rng = np.random.default_rng([seed, _TIME_SEED])
    rates = (np.full(n, float(rate)) if gap_rates is None
             else np.asarray(gap_rates, float))
    if rates.shape != (n,):
        raise ValueError(f"gap_rates must have shape ({n},)")
    if np.any(rates <= 0.0):
        raise ValueError("all gap rates must be positive")
    gaps = rng.exponential(1.0, size=n) / rates
    if deterministic is not None:
        det = np.asarray(deterministic, bool)
        gaps = np.where(det, 1.0 / rates, gaps)
    return t0 + np.cumsum(gaps)


def onoff_arrival_times(
    n: int,
    rate: float,
    *,
    on_len: int,
    off_len: int,
    burst_rate: float = 0.0,
    seed: int = 0,
    t0: float = 0.0,
) -> np.ndarray:
    """MMPP-style arrivals for the ``onoff`` kind: OFF stretches are Poisson
    at the base ``rate``, ON bursts arrive *deterministically* at
    ``burst_rate`` (default :data:`BURST_RATE_MULT` x base — checkpoint
    stripes stream back-to-back, they do not jitter). The ON/OFF regime of
    position ``i`` mirrors :func:`onoff_stream`'s layout exactly
    (``off_len`` background requests, then ``on_len`` burst requests,
    repeating)."""
    if burst_rate <= 0.0:
        burst_rate = BURST_RATE_MULT * rate
    period = on_len + off_len
    if period <= 0:
        raise ValueError("need on_len + off_len > 0")
    pos = np.arange(n) % period
    on = pos >= off_len  # onoff_stream emits the OFF stretch first
    return arrival_times(
        n, rate, seed=seed, t0=t0,
        gap_rates=np.where(on, burst_rate, rate),
        deterministic=on,
    )


def nominal_duration(spec: TrafficSpec, default_rate: float = 0.0) -> float:
    """Expected wall-clock span of a spec's arrival process (seconds):
    ``n_requests / rate``, phases summed over their own rates, and the
    ``onoff`` MMPP accounting for its burst stretches arriving at
    ``burst_rate``. Deterministic from the spec (no sampling), so callers
    can derive a fixed window grid that does not recompile across seeds."""
    if spec.kind == "phased" and spec.phases:
        return sum(nominal_duration(p, default_rate) for p in spec.phases)
    rate = spec.rate if spec.rate > 0 else default_rate
    if rate <= 0:
        raise ValueError(
            "traffic spec has no arrival rate; set TrafficSpec.rate or pass "
            "a default_rate"
        )
    if spec.kind == "onoff":
        burst = spec.burst_rate if spec.burst_rate > 0 else (
            BURST_RATE_MULT * rate)
        n_on, n_off = _onoff_split(spec)
        return n_off / rate + n_on / burst
    return spec.n_requests / rate


def _onoff_split(spec: TrafficSpec) -> tuple[int, int]:
    """(n_on, n_off) request counts of an onoff spec's deterministic
    regime layout."""
    period = spec.on_len + spec.off_len
    full, rem = divmod(spec.n_requests, period)
    n_on = full * spec.on_len + max(0, rem - spec.off_len)
    return n_on, spec.n_requests - n_on


def nominal_duration_std(spec: TrafficSpec,
                         default_rate: float = 0.0) -> float:
    """Standard deviation of the realized wall-clock span around
    :func:`nominal_duration`: exponential gaps contribute ``1/rate**2``
    variance each (the span of ``n`` Poisson arrivals is Gamma(n, 1/rate)),
    deterministic checkpoint-burst gaps contribute none, phases add in
    quadrature. Lets callers pad a derived window grid so the sampled
    process almost never overflows it."""
    if spec.kind == "phased" and spec.phases:
        return math.sqrt(sum(
            nominal_duration_std(p, default_rate) ** 2 for p in spec.phases))
    rate = spec.rate if spec.rate > 0 else default_rate
    if rate <= 0:
        raise ValueError(
            "traffic spec has no arrival rate; set TrafficSpec.rate or pass "
            "a default_rate"
        )
    if spec.kind == "onoff":
        _, n_off = _onoff_split(spec)
        return math.sqrt(n_off) / rate
    return math.sqrt(spec.n_requests) / rate


def make_timed_stream(
    spec: TrafficSpec, *, default_rate: float = 0.0
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Build ``(pages, is_write, times)`` from a :class:`TrafficSpec`.

    ``pages``/``is_write`` are bit-identical to :func:`make_stream` (the
    timestamp process draws from its own decorrelated seed stream); ``times``
    is the wall-clock arrival process in seconds:

    - stationary kinds (``poisson``/``irm``/``strided``/``markov``/
      ``mixed``): homogeneous Poisson at the spec's ``rate``;
    - ``onoff``: MMPP-style modulation (:func:`onoff_arrival_times`);
    - ``phased``: each phase's own process, offset by the previous phase's
      realized end — the schedule composes in *seconds*, so a fast phase
      compresses its requests into a short wall-clock span and the measured
      per-window arrival rate genuinely bursts.

    ``default_rate`` fills in for specs whose ``rate`` is unset (0.0).
    """
    rate = spec.rate if spec.rate > 0 else default_rate
    if spec.kind == "tenant_mix":
        # The tenant merge *is* an arrival-time process (each tenant its
        # own Poisson stream); the timed view just keeps the merge times.
        pages, writes, times, _ = tenant_mix_stream(spec)
        return pages, writes, times
    if spec.kind == "phased":
        _validate_phased(spec)
        parts, t0 = [], 0.0
        for p in spec.phases:
            pg, wr, ts = make_timed_stream(p, default_rate=rate)
            parts.append((pg, wr, ts + t0))
            if ts.size:
                t0 += float(ts[-1])
        pages = np.concatenate([pg for pg, _, _ in parts]).astype(np.int32)
        writes = np.concatenate([wr for _, wr, _ in parts]).astype(bool)
        times = np.concatenate([ts for _, _, ts in parts])
        return pages, writes, times
    pages, writes = make_stream(spec)
    n = pages.shape[0]
    if spec.kind == "onoff":
        times = onoff_arrival_times(
            n, rate, on_len=spec.on_len, off_len=spec.off_len,
            burst_rate=spec.burst_rate, seed=spec.seed,
        )
    else:
        times = arrival_times(n, rate, seed=spec.seed)
    return pages, writes, times


# ---------------------------------------------------------------------------
# Multi-tenant chunked traffic (kind="tenant_mix").
# ---------------------------------------------------------------------------

# Seed tag decorrelating tenant draws from the page/time seed streams above.
_TENANT_SEED = 0x7E4A

# Per-tenant generation block (events per refill). A structural constant of
# the stream: every tenant always draws whole blocks in a fixed order
# (gaps, pages, writes), so the event sequence is a pure function of the
# tenant's seed — never of how consumers chunk their reads.
TENANT_BLOCK = 4096


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant of a ``tenant_mix`` workload: an independent Poisson
    arrival process at ``rate`` req/s over the tenant's own *disjoint*
    Zipf(``zipf_s``)-popular key space of ``n_pages`` pages, with a
    ``write_fraction`` share of writes. Tenants are merged by arrival time
    (:class:`TenantStream`); page ids are offset so key ranges never
    collide across tenants."""

    name: str
    rate: float
    n_pages: int
    zipf_s: float = 1.1
    write_fraction: float = 0.0
    seed: int = 0

    def __post_init__(self):
        if not self.name:
            raise ValueError("TenantSpec.name must be non-empty")
        if self.rate <= 0.0:
            raise ValueError(
                f"TenantSpec.rate must be positive, got {self.rate}")
        if self.n_pages <= 0:
            raise ValueError(
                f"TenantSpec.n_pages must be positive, got {self.n_pages}")
        if not 0.0 <= self.write_fraction <= 1.0:
            raise ValueError(
                f"TenantSpec.write_fraction must be in [0, 1], got "
                f"{self.write_fraction}")


def tenant_mix(*tenants: TenantSpec, n_requests: int,
               seed: int = 0) -> TrafficSpec:
    """Compose tenants into one ``kind="tenant_mix"`` :class:`TrafficSpec`.

    The mix's ``rate`` is the sum of tenant rates (the superposition of
    independent Poisson processes is Poisson at the summed rate, so the
    generic duration formulas hold) and its ``n_pages`` the sum of tenant
    page spaces (disjoint key ranges, offset in declaration order)."""
    if not tenants:
        raise ValueError("tenant_mix needs at least one TenantSpec")
    names = [t.name for t in tenants]
    if len(set(names)) != len(names):
        raise ValueError(f"tenant names must be unique, got {names}")
    return TrafficSpec(
        kind="tenant_mix",
        n_requests=n_requests,
        n_pages=sum(t.n_pages for t in tenants),
        seed=seed,
        rate=sum(t.rate for t in tenants),
        tenants=tuple(tenants),
    )


@dataclasses.dataclass
class _TenantState:
    """Mutable per-tenant generator state inside a :class:`TenantStream`."""

    spec: TenantSpec
    rng: np.random.Generator
    cum: np.ndarray        # Zipf popularity CDF over the tenant's pages
    offset: int            # first page id of the tenant's key range
    t_last: float          # last *generated* arrival time
    buf_t: np.ndarray      # buffered (generated, unconsumed) arrival times
    buf_p: np.ndarray      # ... page ids (already offset)
    buf_w: np.ndarray      # ... write flags


class TenantStream:
    """Chunk-by-chunk generator of a ``tenant_mix`` stream.

    Each tenant is a *deterministic* event sequence: its own seeded
    generator, drawn in fixed :data:`TENANT_BLOCK`-event blocks with a
    fixed draw order (inter-arrival gaps, then pages, then write flags) —
    the sequence depends only on the tenant's seed, never on how many
    events a consumer asked for. The mix is the k-way merge of those
    sequences by arrival time (ties broken by tenant index), so any
    ``take()`` chunking emits the *bit-identical* merged prefix: chunked
    streaming replay equals one-shot replay by construction, and
    ``make_stream`` / ``make_timed_stream`` on the same spec are simply
    the full drain.

    ``take(m)`` returns up to ``m`` merged events as
    ``(pages, is_write, times, tenant_ids)`` (capped by the spec's
    ``n_requests``; empty arrays once exhausted). ``state()`` /
    ``restore()`` snapshot and restore the generator mid-stream for
    checkpoint/resume (bit-exact continuation)."""

    def __init__(self, spec: TrafficSpec):
        if spec.kind != "tenant_mix":
            raise ValueError(
                f"TenantStream needs kind='tenant_mix', got {spec.kind!r}")
        self.spec = spec
        self.total = spec.n_requests
        self.emitted = 0
        self._tenants = []
        offset = 0
        for i, t in enumerate(spec.tenants):
            ranks = np.arange(1, t.n_pages + 1, dtype=np.float64)
            pop = ranks ** (-t.zipf_s)
            self._tenants.append(_TenantState(
                spec=t,
                rng=np.random.default_rng(
                    [spec.seed, i, t.seed, _TENANT_SEED]),
                cum=np.cumsum(pop / pop.sum()),
                offset=offset,
                t_last=0.0,
                buf_t=np.zeros(0, np.float64),
                buf_p=np.zeros(0, np.int32),
                buf_w=np.zeros(0, bool),
            ))
            offset += t.n_pages

    @property
    def n_tenants(self) -> int:
        return len(self._tenants)

    @property
    def remaining(self) -> int:
        return self.total - self.emitted

    def _refill(self, st: _TenantState) -> None:
        """Generate one more block of events for one tenant (fixed draw
        order — the invariant behind chunk-size invariance)."""
        b = TENANT_BLOCK
        # Inverse-CDF exponential gaps (not rng.exponential: an explicit
        # uniform draw keeps the consumed bit-stream count per block
        # obvious and fixed).
        gaps = -np.log1p(-st.rng.random(b)) / st.spec.rate
        times = st.t_last + np.cumsum(gaps)
        st.t_last = float(times[-1])
        pages = st.offset + np.searchsorted(
            st.cum, st.rng.random(b), side="right")
        writes = st.rng.random(b) < st.spec.write_fraction
        st.buf_t = np.concatenate([st.buf_t, times])
        st.buf_p = np.concatenate([st.buf_p, pages.astype(np.int32)])
        st.buf_w = np.concatenate([st.buf_w, writes])

    def take(self, m: int):
        """The next ``min(m, remaining)`` merged events:
        ``(pages, is_write, times, tenant_ids)``."""
        m = min(int(m), self.remaining)
        if m <= 0:
            return (np.zeros(0, np.int32), np.zeros(0, bool),
                    np.zeros(0, np.float64), np.zeros(0, np.int32))
        # Only events at or before the *frontier* — the minimum over
        # tenants of the last generated time — are final in the merge (a
        # tenant's future events all arrive after its t_last). Refill the
        # laggard tenant until the frontier covers m events.
        while True:
            frontier = min(st.t_last for st in self._tenants)
            avail = sum(
                int(np.searchsorted(st.buf_t, frontier, side="right"))
                for st in self._tenants)
            if avail >= m:
                break
            self._refill(min(self._tenants, key=lambda s: s.t_last))
        parts = []
        for i, st in enumerate(self._tenants):
            k = int(np.searchsorted(st.buf_t, frontier, side="right"))
            parts.append((st.buf_t[:k], st.buf_p[:k], st.buf_w[:k],
                          np.full(k, i, np.int32)))
        times = np.concatenate([p[0] for p in parts])
        pages = np.concatenate([p[1] for p in parts])
        writes = np.concatenate([p[2] for p in parts])
        tids = np.concatenate([p[3] for p in parts])
        # Stable merge order: time, then tenant index (a deterministic
        # tie-break keeps the sequence well-defined even on equal times).
        order = np.lexsort((tids, times))[:m]
        # The taken events are a time-prefix of the merge, hence a prefix
        # of each tenant's buffer — consume by per-tenant count.
        taken = np.bincount(tids[order], minlength=self.n_tenants)
        for st, k in zip(self._tenants, taken):
            st.buf_t = st.buf_t[k:]
            st.buf_p = st.buf_p[k:]
            st.buf_w = st.buf_w[k:]
        self.emitted += m
        return pages[order], writes[order], times[order], tids[order]

    def state(self) -> dict:
        """Snapshot for bit-exact resume (host data only: generator
        states, per-tenant time cursors and unconsumed buffers)."""
        return dict(
            emitted=self.emitted,
            tenants=[dict(
                rng=st.rng.bit_generator.state,
                t_last=st.t_last,
                buf_t=st.buf_t.copy(),
                buf_p=st.buf_p.copy(),
                buf_w=st.buf_w.copy(),
            ) for st in self._tenants],
        )

    def restore(self, state: dict) -> None:
        if len(state["tenants"]) != self.n_tenants:
            raise ValueError(
                "TenantStream.restore: snapshot has "
                f"{len(state['tenants'])} tenants, stream has "
                f"{self.n_tenants}")
        self.emitted = int(state["emitted"])
        for st, s in zip(self._tenants, state["tenants"]):
            st.rng.bit_generator.state = s["rng"]
            st.t_last = float(s["t_last"])
            st.buf_t = np.asarray(s["buf_t"], np.float64).copy()
            st.buf_p = np.asarray(s["buf_p"], np.int32).copy()
            st.buf_w = np.asarray(s["buf_w"], bool).copy()


def tenant_mix_stream(spec: TrafficSpec):
    """Whole-stream drain of a ``tenant_mix`` spec:
    ``(pages, is_write, times, tenant_ids)``. The canonical one-shot view —
    definitionally equal to any :class:`TenantStream` chunking."""
    return TenantStream(spec).take(spec.n_requests)
