"""Weight-sharing online learning for cache replacement (paper §III-A).

Implements Algorithms 1 (GetVictim) and 2 (WeightSharing: Weight Adjust):

- Three low-overhead experts — **LRU** (timestamps), **LFU** (frequency
  counters) and **Random** — each propose an eviction victim readable
  directly from the current cache state.
- The expert with the highest probability is chosen (Algorithm 1); every
  expert's proposal is recorded in its *prediction vector* for the epoch.
- A miss on a page present in expert *i*'s prediction vector is a
  **misprediction** for *i* (the expert evicted / would have evicted a page
  that was re-requested within the epoch).
- Every ``EPOCH_WIDTH`` iterations the weights are adjusted: experts whose
  misprediction count reaches ``THRESHOLD * miss_count`` are penalized
  multiplicatively (``w_i <- w_i * beta^{l_i}``) and the total lost weight is
  shared back (``w_i <- w_i + alpha * mean_lost``), after which weights are
  normalized into probabilities. Prediction vectors are cleared each epoch
  "to avoid mixing history from distant past".

Note on the paper's pseudocode: Algorithm 2 writes
``weights[i] = weights[i] - weights[i] * d`` with ``d = beta^l``, which for a
*perfect* expert (``l = 0``, ``d = 1``) would zero its weight — the opposite
of the intended penalty and inconsistent with the cited weighted-majority /
weight-share literature [50], [54] (Blum & Burch). We implement the intended
multiplicative update ``w_i <- w_i * beta^{l_i}`` (beta < 1: more
mispredictions => smaller weight), followed by the paper's alpha-sharing and
normalization. The paper's defaults are EPOCH_WIDTH=4 and THRESHOLD=0.25.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "EXPERTS",
    "OLConfig",
    "OLState",
    "init_ol",
    "propose_victims",
    "choose_expert",
    "record_predictions",
    "note_miss",
    "weight_adjust",
    "probabilities",
]

# Expert order is part of the public contract (indices used in stats/tests).
EXPERTS: tuple[str, ...] = ("lru", "lfu", "random")
N_EXPERTS = len(EXPERTS)


class OLConfig(NamedTuple):
    """Online-learning knobs. ``epoch_width`` and ``pred_cap`` are structural
    (they shape arrays / the scan) and must be concrete; ``alpha``, ``beta``
    and ``threshold`` are plain scalars that may also be jax tracers, so one
    compiled engine can serve a whole grid of hyperparameter settings (the
    sweep engine stacks them on a vmap axis)."""

    epoch_width: int = 4      # iterations per epoch (paper §III-A)
    alpha: float = 0.5        # weight-share rate
    beta: float = 0.7         # multiplicative penalty base (< 1)
    threshold: float = 0.25   # ignore experts below threshold*miss_count
    pred_cap: int = 64        # prediction-vector ring capacity per expert


class OLState(NamedTuple):
    weights: jnp.ndarray       # f32[E]
    pred: jnp.ndarray          # int32[E, C] evicted pages this epoch (-1 empty)
    pred_n: jnp.ndarray        # int32[E] ring write cursor
    mispred: jnp.ndarray       # int32[E]
    epoch_misses: jnp.ndarray  # int32[1] misses in current epoch
    chosen: jnp.ndarray        # int32[1] expert used for the last eviction
    # (1-element arrays, not scalars: every leaf keeps a leading dim so
    # device-local learner state shards cleanly under shard_map.)


def init_ol(cfg: OLConfig) -> OLState:
    return OLState(
        weights=jnp.ones((N_EXPERTS,), jnp.float32) / N_EXPERTS,
        pred=jnp.full((N_EXPERTS, cfg.pred_cap), -1, jnp.int32),
        pred_n=jnp.zeros((N_EXPERTS,), jnp.int32),
        mispred=jnp.zeros((N_EXPERTS,), jnp.int32),
        epoch_misses=jnp.zeros((1,), jnp.int32),
        chosen=jnp.zeros((1,), jnp.int32),
    )


def probabilities(weights: jnp.ndarray) -> jnp.ndarray:
    s = jnp.sum(weights)
    return jnp.where(s > 0, weights / s, jnp.full_like(weights, 1.0 / N_EXPERTS))


def propose_victims(cache, key: jax.Array, pinned=None) -> jnp.ndarray:
    """Each expert's victim line index, int32[E] = [lru, lfu, random].

    Decisions are computed "by reading the current cache state" (§III-A):
    LRU = oldest timestamp, LFU = lowest frequency, Random = uniform over
    valid lines. Invalid lines are excluded (callers only evict when full,
    but the masking makes the proposals total functions). ``pinned`` marks
    lines that must not be evicted (e.g. in-flight pages of active
    sequences — the paper's single-writer lock on lines in service).
    """
    ok = cache.valid if pinned is None else (cache.valid & ~pinned)
    big = jnp.iinfo(jnp.int32).max
    ts = jnp.where(ok, cache.ts, big)
    fq = jnp.where(ok, cache.freq, big)
    lru = jnp.argmin(ts).astype(jnp.int32)
    lfu = jnp.argmin(fq).astype(jnp.int32)
    noise = jax.random.uniform(key, cache.tags.shape)
    rnd = jnp.argmax(jnp.where(ok, noise, -1.0)).astype(jnp.int32)
    return jnp.stack([lru, lfu, rnd])


def choose_expert(ol: OLState, policy_idx=None) -> jnp.ndarray:
    """Algorithm 1: highest-probability expert (or a fixed expert when the
    store is configured with a single policy for baseline runs).

    ``policy_idx`` may be ``None`` (online learning), a concrete int, or a
    traced int32 scalar where ``-1`` means online learning — the traced form
    lets one compiled engine switch policies per sweep point.
    """
    learned = jnp.argmax(probabilities(ol.weights)).astype(jnp.int32)
    if policy_idx is None:
        return learned
    idx = jnp.asarray(policy_idx, jnp.int32)
    return jnp.where(idx >= 0, jnp.clip(idx, 0, N_EXPERTS - 1), learned)


def record_predictions(ol: OLState, cfg: OLConfig, victim_pages: jnp.ndarray) -> OLState:
    """Append each expert's proposed victim page to its prediction ring."""
    slot = ol.pred_n % cfg.pred_cap
    pred = ol.pred.at[jnp.arange(N_EXPERTS), slot].set(victim_pages.astype(jnp.int32))
    return ol._replace(pred=pred, pred_n=ol.pred_n + 1)


def note_miss(ol: OLState, page: jnp.ndarray) -> OLState:
    """Count the miss and any expert mispredictions it reveals (Algorithm 2's
    ``p in pred[i]`` scan, done online)."""
    hit_pred = jnp.any(ol.pred == page, axis=1)  # bool[E]
    return ol._replace(
        mispred=ol.mispred + hit_pred.astype(jnp.int32),
        epoch_misses=ol.epoch_misses + 1,
    )


def weight_adjust(ol: OLState, cfg: OLConfig) -> OLState:
    """Algorithm 2 epoch-boundary update (see module docstring). ``alpha``,
    ``beta`` and ``threshold`` may be traced scalars (see :class:`OLConfig`)."""
    threshold = jnp.asarray(cfg.threshold, jnp.float32)
    thresh = threshold * ol.epoch_misses[0].astype(jnp.float32)
    losses = jnp.where(
        ol.mispred.astype(jnp.float32) >= thresh, ol.mispred, 0
    ).astype(jnp.float32)
    prev = ol.weights
    w = prev * jnp.power(jnp.asarray(cfg.beta, jnp.float32), losses)
    shared = jnp.mean(prev - w)  # total lost weight / n
    w = w + jnp.asarray(cfg.alpha, jnp.float32) * shared
    # Guard against total collapse, then renormalize.
    w = jnp.maximum(w, 1e-8)
    w = w / jnp.sum(w)
    return ol._replace(
        weights=w,
        pred=jnp.full_like(ol.pred, -1),
        pred_n=jnp.zeros_like(ol.pred_n),
        mispred=jnp.zeros_like(ol.mispred),
        epoch_misses=jnp.zeros_like(ol.epoch_misses),
    )
