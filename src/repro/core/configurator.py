"""Configuration search driven by the performance models (paper §VII).

"For required arrival and service rates, these performance models can be
used to configure cache size (miss rate), number of processes and data sizes
at each tier."

Given a workload (traffic spec + request rate) and device models, the
configurator:

1. measures the miss-rate curve miss_rate(cache_lines) by running the
   tier-1 engine on a sample stream (Fig. 3's capacity-miss curve),
2. composes μ1/μ2 from the device behavioral models,
3. sweeps candidate configurations through the queuing network and keeps
   those in equilibrium (all ρ < 1), ranked by predicted response time.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.queuing import TwoTierModel
from repro.core.traffic import TrafficSpec, make_stream
from repro.storage.tier2 import Tier1Sim, Tier2Sim
from repro.storage.tiered_store import StoreConfig, run_stream

__all__ = ["CandidateConfig", "miss_rate_curve", "configure"]


@dataclasses.dataclass(frozen=True)
class CandidateConfig:
    n_lines: int
    k_threads: int
    miss_rate: float
    rho1: float
    rho2: float
    equilibrium: bool
    predicted_time_s: float  # eq. 1-4 minimum service time for the workload
    w1: float
    w2: float


def miss_rate_curve(
    spec: TrafficSpec, cache_sizes: Sequence[int], policy: str = "ws"
) -> list[tuple[int, float]]:
    """Fig. 3: miss rate vs cache size (capacity misses, 1 process)."""
    pages, writes = make_stream(spec)
    out = []
    for n in cache_sizes:
        stats = run_stream(StoreConfig(n_lines=int(n), policy=policy), pages, writes)
        out.append((int(n), float(stats.miss_rate)))
    return out


def configure(
    spec: TrafficSpec,
    *,
    arrival_rate: float,
    cache_sizes: Sequence[int] = (32, 64, 128, 256, 512),
    k_threads: Sequence[int] = (1, 4, 16, 64),
    tier1: Tier1Sim | None = None,
    tier2: Tier2Sim | None = None,
    policy: str = "ws",
) -> list[CandidateConfig]:
    """Sweep (cache size × thread count), return equilibrium-feasible
    candidates sorted by predicted completion time."""
    tier1 = tier1 or Tier1Sim()
    tier2 = tier2 or Tier2Sim()
    mu1 = tier1.mu1(read=True)
    mu2 = tier2.mu2(read=True)
    curve = dict(miss_rate_curve(spec, cache_sizes, policy))
    n = spec.n_requests
    out = []
    for n_lines, p12 in curve.items():
        for k in k_threads:
            model = TwoTierModel(
                lam=arrival_rate, mu1=mu1 * k, mu2=mu2, p12=p12, k=k
            )
            rep = model.analyze()
            # eq. 1–4 minimum completion time (single process, reads only)
            t_hit = n * (1 - p12) / (mu1 * k)
            t_miss = n * p12 / mu2
            out.append(
                CandidateConfig(
                    n_lines=n_lines,
                    k_threads=k,
                    miss_rate=p12,
                    rho1=rep.q1.rho,
                    rho2=rep.q2.rho,
                    equilibrium=rep.equilibrium,
                    predicted_time_s=max(t_hit, t_miss),
                    w1=rep.q1.wq,
                    w2=rep.q2.wq,
                )
            )
    out.sort(key=lambda c: (not c.equilibrium, c.predicted_time_s))
    return out
