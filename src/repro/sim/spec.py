"""Declarative specs for the end-to-end two-tier simulator.

A :class:`SimSpec` names everything the paper's end-to-end model needs in
one object: the workload (:class:`repro.core.traffic.TrafficSpec`), the
distributed tier-1 cache (:class:`repro.storage.tiered_store.StoreConfig`
plus shard count / mapping policy), and the queuing-network parameters
(§V, Fig. 5). :class:`RateSpec` decides where the service rates μ1/μ2 come
from:

- ``source="devices"``: fitted behavioral device models (§V-A/B) via
  :class:`repro.storage.tier2.Tier1Sim` / ``Tier2Sim`` — the paper's
  "behavioral models feed the queuing network" composition;
- ``source="paper"``: the §V worked-example constants (μ1=1000, μ2=33);
- explicit ``mu1``/``mu2`` overrides win over either source.

Specs are frozen dataclasses so they hash/compare — the sweep engine uses
equality of sub-specs to dedupe expensive cache simulations.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from repro.core.queuing import RetryPolicy, _norm_mu_load
from repro.core.traffic import (
    TrafficSpec,
    nominal_duration,
    nominal_duration_std,
)
from repro.storage.tier2 import Tier1Sim, Tier2Sim
from repro.storage.tiered_store import StoreConfig

__all__ = [
    "RateSpec", "ResolvedRates", "SimSpec", "PAPER_MU1", "PAPER_MU2",
    "FaultEvent", "FaultSpec", "RetryPolicy",
    "shard_down", "device_degrade", "tier2_outage",
]

# §V worked example constants: "μ1 = 1000 requests/sec, μ2 = 33 stripes/sec".
PAPER_MU1 = 1000.0
PAPER_MU2 = 33.0


@dataclasses.dataclass(frozen=True)
class ResolvedRates:
    """Concrete service rates handed to the queuing network (req/s).

    ``mu1_shards``/``mu2_shards`` carry optional per-shard rate vectors (the
    paper's Tables VII–IX strong-scaling runs, where the tier-1 device count
    X1 — and hence each process's service rate — varies). When set, the
    scalar fields hold the across-shard means used by the pooled/aggregate
    queue solve; :meth:`for_shard` yields each shard's own rates.
    """

    mu1: float        # tier-1 service rate used by the queue model
    mu2: float        # tier-2 (miss) service rate
    mu1_read: float   # read/write split for the minimum-time model (eqs 1-4)
    mu1_write: float
    mu1_shards: Optional[tuple] = None  # per-shard μ1 overrides
    mu2_shards: Optional[tuple] = None  # per-shard μ2 overrides
    # Load-dependent service hook ((a1, b1), (a2, b2)) — see RateSpec.mu_load.
    mu_load: Optional[tuple] = None

    def for_shard(self, i: int) -> "ResolvedRates":
        """Shard ``i``'s rates. Per-shard μ1 scales the read/write split
        proportionally, preserving the base source's read:write ratio."""
        if self.mu1_shards is None and self.mu2_shards is None:
            return self
        mu1 = float(self.mu1_shards[i]) if self.mu1_shards else self.mu1
        mu2 = float(self.mu2_shards[i]) if self.mu2_shards else self.mu2
        scale = mu1 / self.mu1
        return ResolvedRates(
            mu1=mu1,
            mu2=mu2,
            mu1_read=self.mu1_read * scale,
            mu1_write=self.mu1_write * scale,
            mu_load=self.mu_load,
        )

    def shard_vectors(self, n_shards: int):
        """(mu1_read[n], mu1_write[n], mu2[n]) arrays for eqs. 1–4."""
        per = [self.for_shard(i) for i in range(n_shards)]
        return (
            np.asarray([r.mu1_read for r in per]),
            np.asarray([r.mu1_write for r in per]),
            np.asarray([r.mu2 for r in per]),
        )


@dataclasses.dataclass(frozen=True)
class RateSpec:
    """Where μ1/μ2 come from. Explicit values override the chosen source."""

    source: str = "devices"  # devices | paper
    mu1: Optional[float] = None
    mu2: Optional[float] = None
    mu1_read: Optional[float] = None
    mu1_write: Optional[float] = None
    # Per-shard heterogeneous rates (paper Tables VII–IX: X1 varies per
    # process). Tuples so the spec stays hashable; length must equal the
    # SimSpec's n_shards. When set, the scalar mu1/mu2 (explicit or the
    # across-shard mean) feed the pooled queue solve.
    mu1_shards: Optional[tuple] = None
    mu2_shards: Optional[tuple] = None
    # Device-model operating points (used when source="devices").
    tier1: Tier1Sim = Tier1Sim()
    tier2: Tier2Sim = Tier2Sim()
    n_requests_op: float = 1e5   # NVMe operating point (x4) for μ1
    n_stripes_op: float = 1024.0  # HDD operating point for μ2
    # Load-dependent service: per-tier rational factors ((a1, b1), (a2, b2))
    # scaling μ_i by (1 + a·Q)/(1 + b·Q) at the instantaneous fluid backlog
    # Q — the queue-depth dependence NVMe/HDD devices actually show
    # (deeper queues batch better until they saturate). Fit from device
    # curves with repro.core.device_models.fit_mu_load. None (default)
    # keeps service rates load-independent — the solver paths are then
    # bit-identical to pre-hook behavior. Fluid-only dynamics.
    mu_load: Optional[tuple] = None

    def __post_init__(self):
        # Normalize to nested float tuples so the spec stays hashable and
        # malformed coefficient pairs fail at construction time.
        object.__setattr__(self, "mu_load", _norm_mu_load(self.mu_load))
        for name in ("mu1", "mu2", "mu1_read", "mu1_write"):
            val = getattr(self, name)
            if val is not None and val <= 0:
                raise ValueError(
                    f"RateSpec.{name} must be a positive rate (req/s), got "
                    f"{val} — model a failed device with SimSpec.faults, "
                    f"not a zero service rate")
        for name in ("mu1_shards", "mu2_shards"):
            vec = getattr(self, name)
            if vec is not None and (len(vec) == 0 or min(vec) <= 0):
                raise ValueError(f"RateSpec.{name} must be a non-empty "
                                 "tuple of positive rates")
        if self.n_requests_op <= 0:
            raise ValueError(
                f"RateSpec.n_requests_op must be positive, got "
                f"{self.n_requests_op}")
        if self.n_stripes_op <= 0:
            raise ValueError(
                f"RateSpec.n_stripes_op must be positive, got "
                f"{self.n_stripes_op}")

    def resolve(self) -> ResolvedRates:
        if self.source == "paper":
            mu1_r = mu1_w = PAPER_MU1
            mu2 = PAPER_MU2
        elif self.source == "devices":
            mu1_r = self.tier1.mu1(read=True, n_requests=self.n_requests_op)
            mu1_w = self.tier1.mu1(read=False, n_requests=self.n_requests_op)
            mu2 = self.tier2.mu2(read=True, n_stripes=self.n_stripes_op)
        else:
            raise ValueError(f"unknown rate source: {self.source!r}")
        for name, vec in (("mu1_shards", self.mu1_shards),
                          ("mu2_shards", self.mu2_shards)):
            if vec is not None and (len(vec) == 0 or min(vec) <= 0):
                raise ValueError(f"{name} must be a non-empty tuple of "
                                 "positive rates")
        mu1_r = self.mu1_read if self.mu1_read is not None else mu1_r
        mu1_w = self.mu1_write if self.mu1_write is not None else mu1_w
        mu1 = self.mu1 if self.mu1 is not None else mu1_r
        mu2 = self.mu2 if self.mu2 is not None else mu2
        if self.mu1_shards is not None and self.mu1 is None:
            # Scalar μ1 becomes the across-shard mean; the read/write split
            # rescales with it so for_shard(i) lands exactly on mu1_shards[i]
            # while preserving the source's read:write ratio.
            new_mu1 = sum(self.mu1_shards) / len(self.mu1_shards)
            mu1_r *= new_mu1 / mu1
            mu1_w *= new_mu1 / mu1
            mu1 = new_mu1
        if self.mu2_shards is not None and self.mu2 is None:
            mu2 = sum(self.mu2_shards) / len(self.mu2_shards)
        if min(mu1, mu2, mu1_r, mu1_w) <= 0:
            raise ValueError("service rates must be positive")
        return ResolvedRates(
            mu1=mu1, mu2=mu2, mu1_read=mu1_r, mu1_write=mu1_w,
            mu1_shards=(tuple(float(v) for v in self.mu1_shards)
                        if self.mu1_shards is not None else None),
            mu2_shards=(tuple(float(v) for v in self.mu2_shards)
                        if self.mu2_shards is not None else None),
            mu_load=self.mu_load,
        )


# ---------------------------------------------------------------------------
# Fault injection: wall-clock schedules of device failures and degradation.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One fault on the wall-clock timeline, active over ``[t0, t1)``.

    Built via the :func:`shard_down` / :func:`device_degrade` /
    :func:`tier2_outage` constructors rather than directly.

    kind:    "shard_down" | "degrade" | "tier2_outage"
    t0, t1:  activation interval in seconds (0 <= t0 < t1)
    shard:   affected shard index; -1 = every shard (degrade only —
             shard_down names one shard)
    tier:    affected tier for "degrade" (1 or 2)
    factor:  remaining-capacity fraction in [0, 1] for "degrade"
             (0 = dead, 1 = no-op); unused by the other kinds
    """

    kind: str
    t0: float
    t1: float
    shard: int = -1
    tier: int = 1
    factor: float = 0.0

    def __post_init__(self):
        if self.kind not in ("shard_down", "degrade", "tier2_outage"):
            raise ValueError(f"unknown fault kind: {self.kind!r}")
        if not (0.0 <= self.t0 < self.t1):
            raise ValueError(
                f"fault interval must satisfy 0 <= t0 < t1, got "
                f"[{self.t0}, {self.t1})")
        if self.kind == "degrade":
            if self.tier not in (1, 2):
                raise ValueError(f"degrade tier must be 1 or 2, got "
                                 f"{self.tier}")
            if not 0.0 <= self.factor <= 1.0:
                raise ValueError(
                    f"degrade factor (remaining-capacity fraction) must be "
                    f"in [0, 1], got {self.factor}")
        if self.kind == "shard_down" and self.shard < 0:
            raise ValueError("shard_down needs a concrete shard index")


def shard_down(shard: int, t0: float, t1: float) -> FaultEvent:
    """Shard ``shard``'s tier-1 device is down over ``[t0, t1)``: its μ1
    drops to 0 for the overlap and its key range fails over to survivors
    (the engine remaps its arrivals; on recovery the shard re-warms from a
    cold cache)."""
    return FaultEvent(kind="shard_down", t0=t0, t1=t1, shard=shard)


def device_degrade(tier: int, factor: float, t0: float, t1: float,
                   shard: int = -1) -> FaultEvent:
    """Tier ``tier`` runs at ``factor`` of its service rate over
    ``[t0, t1)`` — a straggler NVMe (tier 1) or a slow disk (tier 2).
    ``shard`` restricts a tier-1 degrade to one shard (-1 = all shards;
    tier-2 is a shared device, so ``shard`` is ignored there)."""
    return FaultEvent(kind="degrade", t0=t0, t1=t1, shard=shard, tier=tier,
                      factor=factor)


def tier2_outage(t0: float, t1: float) -> FaultEvent:
    """The shared tier-2 (HDD / IO thread) is unreachable over ``[t0, t1)``:
    μ2 drops to 0 — misses queue up with nowhere to drain."""
    return FaultEvent(kind="tier2_outage", t0=t0, t1=t1)


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """A wall-clock fault-injection schedule plus the client retry policy.

    events:      tuple of :class:`FaultEvent` (overlapping events compose
                 multiplicatively on the affected service rates)
    retry:       optional :class:`repro.core.queuing.RetryPolicy` — client
                 timeout / backoff behavior; enables retry-feedback
                 dynamics (and metastability detection) in the fluid solve
    refill_cold: model the cold-cache refill after a shard_down recovery
                 by resetting the shard's windowed hit-rate telemetry (its
                 first post-recovery requests re-miss up to one cache's
                 worth of lines)

    The schedule is pure *data*: per-window μ-multipliers and λ-remap
    arrays derived from it ride the megabatch as operands, so fault grids
    sweep without recompiling the engine.
    """

    events: tuple = ()
    retry: Optional[RetryPolicy] = None
    refill_cold: bool = True

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(self.events))
        for ev in self.events:
            if not isinstance(ev, FaultEvent):
                raise ValueError(
                    f"FaultSpec.events must contain FaultEvent instances "
                    f"(use shard_down()/device_degrade()/tier2_outage()), "
                    f"got {ev!r}")

    def validate(self, n_shards: int) -> None:
        """Schedule/spec cross-checks (shard indices in range)."""
        for ev in self.events:
            if ev.shard >= n_shards:
                raise ValueError(
                    f"fault event {ev.kind!r} names shard {ev.shard} but "
                    f"n_shards={n_shards}")

    def down_intervals(self) -> tuple:
        """``(shard, t0, t1)`` triples of the shard_down events — the λ
        failover remap the storage layer applies."""
        return tuple((ev.shard, ev.t0, ev.t1) for ev in self.events
                     if ev.kind == "shard_down")

    def remap_signature(self) -> tuple:
        """The part of the schedule that changes the *tier-1 counter
        simulation* (arrival remapping): shard_down intervals only.
        Degrades, outages and retry policy act on the queuing side and are
        free to sweep over one cached counter run."""
        return self.down_intervals()

    def mu_multipliers(self, n_windows: int, window_dt: float,
                       n_shards: int) -> tuple[np.ndarray, np.ndarray]:
        """Per-window service-rate multipliers ``(mu1_mult[S, W],
        mu2_mult[W])`` implied by the schedule.

        Each event scales the affected rates by its overlap fraction with
        every window (an event covering half a window at factor 0 halves
        that window's rate); overlapping events compose multiplicatively.
        These arrays are plain data — they feed ``fluid_two_tier``'s
        time-varying μ(t) and ride sweeps as operands.
        """
        edges = np.arange(n_windows + 1) * float(window_dt)
        mu1_mult = np.ones((n_shards, n_windows))
        mu2_mult = np.ones(n_windows)
        for ev in self.events:
            overlap = (np.minimum(edges[1:], ev.t1)
                       - np.maximum(edges[:-1], ev.t0))
            frac = np.clip(overlap / float(window_dt), 0.0, 1.0)
            if ev.kind == "shard_down":
                mu1_mult[ev.shard] *= 1.0 - frac
            elif ev.kind == "tier2_outage":
                mu2_mult *= 1.0 - frac
            elif ev.kind == "degrade" and ev.tier == 1:
                scale = 1.0 - frac * (1.0 - ev.factor)
                if ev.shard < 0:
                    mu1_mult *= scale[None, :]
                else:
                    mu1_mult[ev.shard] *= scale
            else:  # degrade tier 2 (shared device)
                mu2_mult *= 1.0 - frac * (1.0 - ev.factor)
        return mu1_mult, mu2_mult


@dataclasses.dataclass(frozen=True)
class SimSpec:
    """One end-to-end scenario: traffic -> distributed tier 1 -> queuing."""

    traffic: TrafficSpec
    store: StoreConfig = StoreConfig()
    n_shards: int = 4
    mapping: str = "block"       # §III page->shard policy
    lam: float = 100.0           # offered arrival rate per process (req/s)
    k_servers: int = 1           # RPC service threads per process (M/G/k k)
    flow: str = "paper"          # paper | conserving (see core.queuing)
    rates: RateSpec = RateSpec()
    # When set, the queuing network uses this miss fraction instead of the
    # measured one (the §V worked example fixes p12 = 0.2).
    p12_override: Optional[float] = None
    # Time resolution of the report: every engine counter is additionally
    # resolved over this many equal windows of the request stream, and the
    # queuing network is re-solved per window (transient analysis +
    # saturation-onset detection). 1 = the historic steady-state-only
    # report.
    n_windows: int = 1
    # Wall-clock window duration in seconds. When set, it supersedes the
    # request-index windows: traffic is generated with arrival timestamps
    # (rate = traffic.rate, or lam * n_shards when unset), counters are
    # binned by arrival time (bin = t // window_dt, overflow clipping into
    # the last bin), and the per-window arrival rate is *measured* rather
    # than flat by construction. n_windows == 1 derives the window count
    # from the spec's nominal horizon (n_requests / rate — deterministic,
    # so compiled shapes do not depend on the sampled timestamps);
    # n_windows > 1 pins the count explicitly.
    window_dt: Optional[float] = None
    # Transient solver fed with the measured per-window rates: "fluid"
    # (queue-length carryover between windows, the default — see
    # repro.core.queuing.fluid_two_tier) or "piecewise" (independent
    # per-window stationary solves, the PR 4 oracle path).
    transient_mode: str = "fluid"
    # Wall-clock fault-injection schedule + client retry policy (see
    # FaultSpec). Requires the wall-clock path (window_dt set) — faults are
    # timeline events — and transient_mode="fluid" when a retry policy or
    # any event is present (degraded-mode dynamics are fluid-only).
    faults: Optional[FaultSpec] = None

    def __post_init__(self):
        if self.n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        if self.n_windows < 1:
            raise ValueError("n_windows must be >= 1")
        if self.lam < 0:
            raise ValueError(
                f"lam (offered arrival rate) must be non-negative, got "
                f"{self.lam}")
        if self.k_servers < 1:
            raise ValueError(
                f"k_servers must be >= 1, got {self.k_servers}")
        if self.window_dt is not None and not (
                math.isfinite(self.window_dt) and self.window_dt > 0):
            raise ValueError(
                f"window_dt must be a positive finite number of seconds, "
                f"got {self.window_dt}")
        if self.transient_mode not in ("fluid", "piecewise"):
            raise ValueError(
                f"unknown transient_mode: {self.transient_mode!r}")
        if self.faults is not None:
            if self.window_dt is None:
                raise ValueError(
                    "fault schedules are wall-clock events: set window_dt "
                    "(the timed-arrivals path) to use SimSpec.faults")
            if self.transient_mode != "fluid":
                raise ValueError(
                    "SimSpec.faults needs transient_mode='fluid' (degraded-"
                    "mode and retry dynamics are fluid-only)")
            self.faults.validate(self.n_shards)
        if (self.rates.mu_load is not None
                and self.transient_mode != "fluid"):
            raise ValueError(
                "rates.mu_load (load-dependent service) needs "
                "transient_mode='fluid' — the piecewise mode solves "
                "stationary networks at fixed rates")
        if self.flow not in ("paper", "conserving"):
            raise ValueError(f"unknown flow convention: {self.flow!r}")
        for name in ("mu1_shards", "mu2_shards"):
            vec = getattr(self.rates, name)
            if vec is not None and len(vec) != self.n_shards:
                raise ValueError(
                    f"rates.{name} has {len(vec)} entries but n_shards="
                    f"{self.n_shards}"
                )
        if self.p12_override is not None and not 0.0 <= self.p12_override <= 1.0:
            raise ValueError("p12_override must be in [0, 1]")

    # -- wall-clock time axis ------------------------------------------------
    def agg_rate(self) -> float:
        """Aggregate offered arrival rate (req/s) of the workload's
        wall-clock arrival process: the traffic spec's own ``rate`` when
        set, else the queuing-side offered load ``lam * n_shards`` (the
        whole stream arrives at the aggregate rate — exactly the historic
        request-index assumption, now realized as actual timestamps)."""
        if self.traffic.rate > 0:
            return float(self.traffic.rate)
        return float(self.lam * self.n_shards)

    def window_grid(self) -> tuple[int, Optional[float]]:
        """The report's time grid ``(n_windows, window_dt)``.

        ``window_dt=None`` (historic): ``n_windows`` equal request-count
        slices. Otherwise wall-clock bins of ``window_dt`` seconds — the
        bin *count* comes from the spec's nominal horizon
        (:func:`repro.core.traffic.nominal_duration`, padded by 4 standard
        deviations of the realized span so the sampled arrival process
        almost never overflows into the clipped last bin — trailing
        windows an early-finishing seed leaves empty are idle-guarded)
        when ``n_windows`` is the default 1, or from an explicit
        ``n_windows``. The count is deterministic from the spec (never
        from sampled timestamps), so compiled engine shapes are stable
        across seeds.
        """
        if self.window_dt is None:
            return self.n_windows, None
        if self.n_windows > 1:
            return self.n_windows, self.window_dt
        rate = self.agg_rate()
        horizon = (nominal_duration(self.traffic, rate)
                   + 4.0 * nominal_duration_std(self.traffic, rate))
        return max(1, math.ceil(horizon / self.window_dt)), self.window_dt

    # -- sweep support -------------------------------------------------------
    def replace(self, **updates) -> "SimSpec":
        """dataclasses.replace with dotted-path support:
        ``spec.replace(**{"store.n_lines": 128, "traffic.kind": "irm"})``.
        """
        direct: dict = {}
        nested: dict[str, dict] = {}
        for key, val in updates.items():
            if "." in key:
                head, rest = key.split(".", 1)
                nested.setdefault(head, {})[rest] = val
            else:
                direct[key] = val
        spec = dataclasses.replace(self, **direct) if direct else self
        for head, sub in nested.items():
            child = getattr(spec, head)
            new_child = (
                child.replace(**sub)
                if isinstance(child, SimSpec)
                else _replace_nested(child, sub)
            )
            spec = dataclasses.replace(spec, **{head: new_child})
        return spec

    def cache_signature(self) -> tuple:
        """Everything the tier-1 counter simulation depends on. Sweep points
        sharing a signature reuse one cache run (queuing params are free).
        The window grid is part of the signature: windowed counters depend
        on the time resolution even though totals do not. On the
        wall-clock path the *rate* of the arrival process matters too
        (timestamps scale with it), which is why ``agg_rate`` — and hence
        ``lam`` when the traffic spec carries no rate of its own — joins
        the signature only when ``window_dt`` is set. A fault schedule
        joins through its *remap signature* only (shard_down intervals
        reroute arrivals and so change the counters); degrades, outages
        and retry policies are queuing-side and sweep over one cached
        run."""
        remap = (self.faults.remap_signature() or None
                 if self.faults is not None else None)
        return (self.traffic, self.store, self.n_shards, self.mapping,
                self.window_grid(),
                self.agg_rate() if self.window_dt is not None else None,
                remap)


def _replace_nested(obj, updates: dict):
    direct = {k: v for k, v in updates.items() if "." not in k}
    out = dataclasses.replace(obj, **direct)
    for key, val in updates.items():
        if "." in key:
            head, rest = key.split(".", 1)
            out = dataclasses.replace(
                out, **{head: _replace_nested(getattr(out, head), {rest: val})}
            )
    return out
