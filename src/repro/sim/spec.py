"""Declarative specs for the end-to-end two-tier simulator.

A :class:`SimSpec` names everything the paper's end-to-end model needs in
one object: the workload (:class:`repro.core.traffic.TrafficSpec`), the
distributed tier-1 cache (:class:`repro.storage.tiered_store.StoreConfig`
plus shard count / mapping policy), and the queuing-network parameters
(§V, Fig. 5). :class:`RateSpec` decides where the service rates μ1/μ2 come
from:

- ``source="devices"``: fitted behavioral device models (§V-A/B) via
  :class:`repro.storage.tier2.Tier1Sim` / ``Tier2Sim`` — the paper's
  "behavioral models feed the queuing network" composition;
- ``source="paper"``: the §V worked-example constants (μ1=1000, μ2=33);
- explicit ``mu1``/``mu2`` overrides win over either source.

Specs are frozen dataclasses so they hash/compare — the sweep engine uses
equality of sub-specs to dedupe expensive cache simulations.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from repro.core.traffic import (
    TrafficSpec,
    nominal_duration,
    nominal_duration_std,
)
from repro.storage.tier2 import Tier1Sim, Tier2Sim
from repro.storage.tiered_store import StoreConfig

__all__ = ["RateSpec", "ResolvedRates", "SimSpec", "PAPER_MU1", "PAPER_MU2"]

# §V worked example constants: "μ1 = 1000 requests/sec, μ2 = 33 stripes/sec".
PAPER_MU1 = 1000.0
PAPER_MU2 = 33.0


@dataclasses.dataclass(frozen=True)
class ResolvedRates:
    """Concrete service rates handed to the queuing network (req/s).

    ``mu1_shards``/``mu2_shards`` carry optional per-shard rate vectors (the
    paper's Tables VII–IX strong-scaling runs, where the tier-1 device count
    X1 — and hence each process's service rate — varies). When set, the
    scalar fields hold the across-shard means used by the pooled/aggregate
    queue solve; :meth:`for_shard` yields each shard's own rates.
    """

    mu1: float        # tier-1 service rate used by the queue model
    mu2: float        # tier-2 (miss) service rate
    mu1_read: float   # read/write split for the minimum-time model (eqs 1-4)
    mu1_write: float
    mu1_shards: Optional[tuple] = None  # per-shard μ1 overrides
    mu2_shards: Optional[tuple] = None  # per-shard μ2 overrides

    def for_shard(self, i: int) -> "ResolvedRates":
        """Shard ``i``'s rates. Per-shard μ1 scales the read/write split
        proportionally, preserving the base source's read:write ratio."""
        if self.mu1_shards is None and self.mu2_shards is None:
            return self
        mu1 = float(self.mu1_shards[i]) if self.mu1_shards else self.mu1
        mu2 = float(self.mu2_shards[i]) if self.mu2_shards else self.mu2
        scale = mu1 / self.mu1
        return ResolvedRates(
            mu1=mu1,
            mu2=mu2,
            mu1_read=self.mu1_read * scale,
            mu1_write=self.mu1_write * scale,
        )

    def shard_vectors(self, n_shards: int):
        """(mu1_read[n], mu1_write[n], mu2[n]) arrays for eqs. 1–4."""
        per = [self.for_shard(i) for i in range(n_shards)]
        return (
            np.asarray([r.mu1_read for r in per]),
            np.asarray([r.mu1_write for r in per]),
            np.asarray([r.mu2 for r in per]),
        )


@dataclasses.dataclass(frozen=True)
class RateSpec:
    """Where μ1/μ2 come from. Explicit values override the chosen source."""

    source: str = "devices"  # devices | paper
    mu1: Optional[float] = None
    mu2: Optional[float] = None
    mu1_read: Optional[float] = None
    mu1_write: Optional[float] = None
    # Per-shard heterogeneous rates (paper Tables VII–IX: X1 varies per
    # process). Tuples so the spec stays hashable; length must equal the
    # SimSpec's n_shards. When set, the scalar mu1/mu2 (explicit or the
    # across-shard mean) feed the pooled queue solve.
    mu1_shards: Optional[tuple] = None
    mu2_shards: Optional[tuple] = None
    # Device-model operating points (used when source="devices").
    tier1: Tier1Sim = Tier1Sim()
    tier2: Tier2Sim = Tier2Sim()
    n_requests_op: float = 1e5   # NVMe operating point (x4) for μ1
    n_stripes_op: float = 1024.0  # HDD operating point for μ2

    def resolve(self) -> ResolvedRates:
        if self.source == "paper":
            mu1_r = mu1_w = PAPER_MU1
            mu2 = PAPER_MU2
        elif self.source == "devices":
            mu1_r = self.tier1.mu1(read=True, n_requests=self.n_requests_op)
            mu1_w = self.tier1.mu1(read=False, n_requests=self.n_requests_op)
            mu2 = self.tier2.mu2(read=True, n_stripes=self.n_stripes_op)
        else:
            raise ValueError(f"unknown rate source: {self.source!r}")
        for name, vec in (("mu1_shards", self.mu1_shards),
                          ("mu2_shards", self.mu2_shards)):
            if vec is not None and (len(vec) == 0 or min(vec) <= 0):
                raise ValueError(f"{name} must be a non-empty tuple of "
                                 "positive rates")
        mu1_r = self.mu1_read if self.mu1_read is not None else mu1_r
        mu1_w = self.mu1_write if self.mu1_write is not None else mu1_w
        mu1 = self.mu1 if self.mu1 is not None else mu1_r
        mu2 = self.mu2 if self.mu2 is not None else mu2
        if self.mu1_shards is not None and self.mu1 is None:
            # Scalar μ1 becomes the across-shard mean; the read/write split
            # rescales with it so for_shard(i) lands exactly on mu1_shards[i]
            # while preserving the source's read:write ratio.
            new_mu1 = sum(self.mu1_shards) / len(self.mu1_shards)
            mu1_r *= new_mu1 / mu1
            mu1_w *= new_mu1 / mu1
            mu1 = new_mu1
        if self.mu2_shards is not None and self.mu2 is None:
            mu2 = sum(self.mu2_shards) / len(self.mu2_shards)
        if min(mu1, mu2, mu1_r, mu1_w) <= 0:
            raise ValueError("service rates must be positive")
        return ResolvedRates(
            mu1=mu1, mu2=mu2, mu1_read=mu1_r, mu1_write=mu1_w,
            mu1_shards=(tuple(float(v) for v in self.mu1_shards)
                        if self.mu1_shards is not None else None),
            mu2_shards=(tuple(float(v) for v in self.mu2_shards)
                        if self.mu2_shards is not None else None),
        )


@dataclasses.dataclass(frozen=True)
class SimSpec:
    """One end-to-end scenario: traffic -> distributed tier 1 -> queuing."""

    traffic: TrafficSpec
    store: StoreConfig = StoreConfig()
    n_shards: int = 4
    mapping: str = "block"       # §III page->shard policy
    lam: float = 100.0           # offered arrival rate per process (req/s)
    k_servers: int = 1           # RPC service threads per process (M/G/k k)
    flow: str = "paper"          # paper | conserving (see core.queuing)
    rates: RateSpec = RateSpec()
    # When set, the queuing network uses this miss fraction instead of the
    # measured one (the §V worked example fixes p12 = 0.2).
    p12_override: Optional[float] = None
    # Time resolution of the report: every engine counter is additionally
    # resolved over this many equal windows of the request stream, and the
    # queuing network is re-solved per window (transient analysis +
    # saturation-onset detection). 1 = the historic steady-state-only
    # report.
    n_windows: int = 1
    # Wall-clock window duration in seconds. When set, it supersedes the
    # request-index windows: traffic is generated with arrival timestamps
    # (rate = traffic.rate, or lam * n_shards when unset), counters are
    # binned by arrival time (bin = t // window_dt, overflow clipping into
    # the last bin), and the per-window arrival rate is *measured* rather
    # than flat by construction. n_windows == 1 derives the window count
    # from the spec's nominal horizon (n_requests / rate — deterministic,
    # so compiled shapes do not depend on the sampled timestamps);
    # n_windows > 1 pins the count explicitly.
    window_dt: Optional[float] = None
    # Transient solver fed with the measured per-window rates: "fluid"
    # (queue-length carryover between windows, the default — see
    # repro.core.queuing.fluid_two_tier) or "piecewise" (independent
    # per-window stationary solves, the PR 4 oracle path).
    transient_mode: str = "fluid"

    def __post_init__(self):
        if self.n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        if self.n_windows < 1:
            raise ValueError("n_windows must be >= 1")
        if self.window_dt is not None and self.window_dt <= 0:
            raise ValueError("window_dt must be positive (seconds)")
        if self.transient_mode not in ("fluid", "piecewise"):
            raise ValueError(
                f"unknown transient_mode: {self.transient_mode!r}")
        if self.flow not in ("paper", "conserving"):
            raise ValueError(f"unknown flow convention: {self.flow!r}")
        for name in ("mu1_shards", "mu2_shards"):
            vec = getattr(self.rates, name)
            if vec is not None and len(vec) != self.n_shards:
                raise ValueError(
                    f"rates.{name} has {len(vec)} entries but n_shards="
                    f"{self.n_shards}"
                )
        if self.p12_override is not None and not 0.0 <= self.p12_override <= 1.0:
            raise ValueError("p12_override must be in [0, 1]")

    # -- wall-clock time axis ------------------------------------------------
    def agg_rate(self) -> float:
        """Aggregate offered arrival rate (req/s) of the workload's
        wall-clock arrival process: the traffic spec's own ``rate`` when
        set, else the queuing-side offered load ``lam * n_shards`` (the
        whole stream arrives at the aggregate rate — exactly the historic
        request-index assumption, now realized as actual timestamps)."""
        if self.traffic.rate > 0:
            return float(self.traffic.rate)
        return float(self.lam * self.n_shards)

    def window_grid(self) -> tuple[int, Optional[float]]:
        """The report's time grid ``(n_windows, window_dt)``.

        ``window_dt=None`` (historic): ``n_windows`` equal request-count
        slices. Otherwise wall-clock bins of ``window_dt`` seconds — the
        bin *count* comes from the spec's nominal horizon
        (:func:`repro.core.traffic.nominal_duration`, padded by 4 standard
        deviations of the realized span so the sampled arrival process
        almost never overflows into the clipped last bin — trailing
        windows an early-finishing seed leaves empty are idle-guarded)
        when ``n_windows`` is the default 1, or from an explicit
        ``n_windows``. The count is deterministic from the spec (never
        from sampled timestamps), so compiled engine shapes are stable
        across seeds.
        """
        if self.window_dt is None:
            return self.n_windows, None
        if self.n_windows > 1:
            return self.n_windows, self.window_dt
        rate = self.agg_rate()
        horizon = (nominal_duration(self.traffic, rate)
                   + 4.0 * nominal_duration_std(self.traffic, rate))
        return max(1, math.ceil(horizon / self.window_dt)), self.window_dt

    # -- sweep support -------------------------------------------------------
    def replace(self, **updates) -> "SimSpec":
        """dataclasses.replace with dotted-path support:
        ``spec.replace(**{"store.n_lines": 128, "traffic.kind": "irm"})``.
        """
        direct: dict = {}
        nested: dict[str, dict] = {}
        for key, val in updates.items():
            if "." in key:
                head, rest = key.split(".", 1)
                nested.setdefault(head, {})[rest] = val
            else:
                direct[key] = val
        spec = dataclasses.replace(self, **direct) if direct else self
        for head, sub in nested.items():
            child = getattr(spec, head)
            new_child = (
                child.replace(**sub)
                if isinstance(child, SimSpec)
                else _replace_nested(child, sub)
            )
            spec = dataclasses.replace(spec, **{head: new_child})
        return spec

    def cache_signature(self) -> tuple:
        """Everything the tier-1 counter simulation depends on. Sweep points
        sharing a signature reuse one cache run (queuing params are free).
        The window grid is part of the signature: windowed counters depend
        on the time resolution even though totals do not. On the
        wall-clock path the *rate* of the arrival process matters too
        (timestamps scale with it), which is why ``agg_rate`` — and hence
        ``lam`` when the traffic spec carries no rate of its own — joins
        the signature only when ``window_dt`` is set."""
        return (self.traffic, self.store, self.n_shards, self.mapping,
                self.window_grid(),
                self.agg_rate() if self.window_dt is not None else None)


def _replace_nested(obj, updates: dict):
    direct = {k: v for k, v in updates.items() if "." not in k}
    out = dataclasses.replace(obj, **direct)
    for key, val in updates.items():
        if "." in key:
            head, rest = key.split(".", 1)
            out = dataclasses.replace(
                out, **{head: _replace_nested(getattr(out, head), {rest: val})}
            )
    return out
