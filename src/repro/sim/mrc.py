"""Miss-rate curves from one pass: exact LRU counters for every cache size.

The scan engine (:func:`repro.sim.engine.tier1_counters`) re-simulates the
whole request stream per cache size, and ``store.n_lines`` is *structural*
(a new compile per size). For LRU the classic Mattson stack-distance
result makes that loop unnecessary: a request hits a fully-associative LRU
cache of capacity ``C`` iff its *reuse distance* ``d`` (distinct pages
touched since its previous access; infinity for a first access) satisfies
``d < C``. One distance pass (:mod:`repro.kernels.reuse_distance`) plus a
histogram therefore yields the counters for **all** sizes at once.

:func:`mrc_tier1_counters` reconstructs the *complete*
:class:`~repro.sim.engine.Tier1Counters` — whole-stream and per-window,
including evictions, write-backs and the online-learning telemetry — so
:func:`~repro.sim.engine.report_from_counters` and the fluid transient
path run unchanged on its output. Every field is **bit-identical** to the
sequential scan engine inside the supported domain (the property harness
in ``tests/test_reuse_distance.py`` and ``benchmarks/bench_mrc.py`` gate
this); outside it the functions raise ``ValueError`` (and ``sweep()``
falls back to the scan engine with a logged reason):

- ``policy`` must be ``"lru"`` — LFU and the learned weight-sharing
  policy have no exact single-pass stack formulation (their eviction
  choice depends on the realized cache content at each capacity).
- ``prefetch`` must be off — the prefetch buffer adds state outside the
  LRU stack.
- Write traffic is exact whole-stream (single window): a dirty page
  evicted in the gap after its access ``j`` produces a write-back for
  exactly the capacities ``M_j < C <= U_j``, where ``U_j`` is the reuse
  distance at the page's next access (or the count of distinct pages
  after its last access) and ``M_j`` is the running max distance since
  the page's last write (0 at a write, infinity if never written) — the
  cache line is dirty at capacity ``C`` iff the insertion that created it
  is not newer than the last write, i.e. ``C > M_j``. With multiple
  windows the write-back lands in the window of the *evicting* access,
  which depends on ``C`` — no cheap all-sizes attribution exists, so
  windowed grids require write-free traffic.

Derived counter identities (per shard, per window ``w``, capacity ``C``):

- ``hits = #{d < C}``; ``misses = requests - hits``;
  ``tier2_reads = misses`` (no prefetch); ``prefetch_hits = 0``.
- ``evictions = misses - clip(C - misses_before_w, 0, misses_in_w)``:
  the cache fills one free line per miss until ``C`` lines are live, so
  exactly the first ``C`` misses of the shard do not evict.
- ``expert_use[lru] = evictions`` (fixed-policy evictions are all issued
  by the LRU expert); ``weights`` are the uniform initial vector wherever
  the window saw a request (fixed policies never adjust weights).
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.core import online_learning as ol
from repro.kernels.reuse_distance import (
    DIST_INF,
    prev_occurrence,
    reuse_distances,
)
from repro.sim.engine import Tier1Counters, fault_owner, stream_for_spec
from repro.sim.spec import SimSpec
from repro.storage.tiered_store import (
    partition_streams,
    timestamp_window_ids,
)

__all__ = [
    "mrc_unsupported_reason",
    "mrc_tier1_counters",
    "mrc_curve",
]

_LRU_EXPERT = ol.EXPERTS.index("lru")

# Distance arrays are padded to power-of-two length buckets (same rationale
# as sweep.MIN_BUCKET): repeated calls across traffic sizes land in a
# handful of compiled shapes.
_MIN_BUCKET = 16


def _bucket_cap(n: int) -> int:
    cap = _MIN_BUCKET
    while cap < n:
        cap <<= 1
    return cap


def _traffic_may_write(traffic) -> bool:
    if traffic.write_fraction > 0:
        return True
    phases = getattr(traffic, "phases", None) or ()
    return any(p.write_fraction > 0 for p in phases)


def mrc_unsupported_reason(spec: SimSpec) -> Optional[str]:
    """``None`` when :func:`mrc_tier1_counters` can serve this spec (at any
    ``store.n_lines``) bit-exactly; otherwise a human-readable reason. This
    is the routing predicate ``sweep()`` consults before replacing scan
    runs with the MRC path — conservative by construction (a spec that
    *may* emit writes counts as writing)."""
    if spec.store.policy != "lru":
        return (
            f"policy={spec.store.policy!r} has no exact stack-distance "
            "formulation (only 'lru' does)"
        )
    if spec.store.prefetch:
        return "prefetch=True adds buffer state outside the LRU stack"
    if spec.traffic.kind == "tenant_mix":
        return (
            "tenant_mix workloads route through the chunked streaming "
            "engine (per-tenant attribution needs the streamed composite "
            "windows; the MRC pass also materializes the whole merge)"
        )
    n_windows, _ = spec.window_grid()
    if n_windows > 1 and _traffic_may_write(spec.traffic):
        return (
            "windowed tier2_writes cannot be attributed exactly: a "
            "write-back lands in the window of the evicting access, which "
            "depends on the cache size (write-free traffic or a single "
            "window is exact)"
        )
    return None


def _check_supported(spec: SimSpec) -> None:
    if spec.store.policy != "lru":
        raise ValueError(
            "MRC supports exact stack-distance counters only for "
            f"policy='lru' (got {spec.store.policy!r}); LFU and learned "
            "policies have no exact single-pass formulation — use the "
            "scan engine"
        )
    if spec.store.prefetch:
        raise ValueError(
            "MRC does not support prefetch=True: the prefetch buffer adds "
            "state outside the LRU stack — use the scan engine"
        )


def mrc_tier1_counters(
    spec: SimSpec, sizes: Sequence[int], trace=None
) -> dict[int, Tier1Counters]:
    """Exact per-shard :class:`~repro.sim.engine.Tier1Counters` for every
    cache size in ``sizes``, from one stream pass.

    The stream (generated or ``trace``-provided), the §III shard
    partition, the fault-schedule owner remap and the window binning are
    all shared with :func:`~repro.sim.engine.tier1_counters` — only the
    per-request cache simulation is replaced by the stack-distance
    histogram. ``spec.store.n_lines`` is ignored (that is the point);
    returns ``{size: counters}``.

    Raises ``ValueError`` for non-LRU policies, prefetch, or write traffic
    on a multi-window grid (see the module docstring for why those are
    outside the exactness domain).
    """
    sizes_arr = np.unique(np.asarray(list(sizes), np.int64))
    if sizes_arr.size == 0:
        raise ValueError("sizes must be non-empty")
    if (sizes_arr < 1).any():
        raise ValueError("cache sizes must be >= 1")
    _check_supported(spec)

    pages, is_write, times, n_pages, n_windows, window_dt = stream_for_spec(
        spec, trace)
    owner = fault_owner(spec, pages, times, n_pages)
    has_writes = bool(np.asarray(is_write, bool).any())
    if has_writes and n_windows > 1:
        raise ValueError(
            "MRC windowed counters require write-free traffic: a "
            "write-back lands in the window of the evicting access, which "
            "depends on the cache size — use a single window or the scan "
            "engine"
        )

    S = spec.n_shards
    if times is not None:
        # Same float64 host-side binning as the scan-engine path: the raw
        # (unsharded, full-precision) arrival times become int32 ids which
        # then ride the shard scatter — bit-identical window assignment.
        gwin = timestamp_window_ids(times, n_windows, window_dt)
        sh_pages, sh_writes, counts, owner, sh_win = partition_streams(
            pages, is_write, n_shards=S, mapping=spec.mapping,
            n_pages=n_pages, n_windows=n_windows, window_ids=gwin,
            owner=owner,
        )
    else:
        sh_pages, sh_writes, counts, owner, sh_win = partition_streams(
            pages, is_write, n_shards=S, mapping=spec.mapping,
            n_pages=n_pages, n_windows=n_windows, owner=owner,
        )

    # --- one distance pass (padded to a power-of-two length bucket) -------
    cap = sh_pages.shape[1]
    capb = _bucket_cap(cap)
    sh_pages_b = np.pad(sh_pages, ((0, 0), (0, capb - cap)))
    prev, valid = prev_occurrence(sh_pages_b, counts)
    dist = np.asarray(reuse_distances(prev, valid))        # int32 [S, capb]
    win_b = np.full((S, capb), n_windows, np.int32)
    win_b[:, :cap] = sh_win

    # --- histogram: (shard, window, size-bin) -> counts -------------------
    m = int(sizes_arr.size)
    vmask = valid
    s_idx = np.broadcast_to(np.arange(S)[:, None], (S, capb))[vmask]
    w_idx = win_b[vmask].astype(np.int64)
    d_v = dist[vmask].astype(np.int64)
    # bin = number of sizes <= d: request hits size index i iff bin <= i.
    bins = np.searchsorted(sizes_arr, d_v, side="right")
    composite = (s_idx * n_windows + w_idx) * (m + 1) + bins
    hist = np.bincount(
        composite, minlength=S * n_windows * (m + 1)
    ).reshape(S, n_windows, m + 1)
    win_req = hist.sum(axis=-1)                            # [S, W]
    win_hits = np.cumsum(hist, axis=-1)[..., :m]           # [S, W, m]
    win_miss = win_req[..., None] - win_hits
    win_t2r = win_miss
    # Free-line fills: the shard's first C misses (chronological — window
    # ids are nondecreasing along each shard row) insert without evicting.
    miss_before = np.cumsum(win_miss, axis=1) - win_miss
    free = np.clip(sizes_arr[None, None, :] - miss_before, 0, win_miss)
    win_ev = win_miss - free

    win_t2w = np.zeros_like(win_miss)
    if has_writes:
        win_t2w[:, 0, :] = _tier2_writes(
            sizes_arr, s_idx, vmask, sh_pages_b, d_v,
            sh_writes, counts, S,
        )

    # --- assemble Tier1Counters per size ----------------------------------
    counts64 = np.asarray(counts, np.int64)
    writes64 = np.bincount(owner[np.asarray(is_write, bool)],
                           minlength=S).astype(np.int64)
    zeros_w = np.zeros((S, n_windows), np.int64)
    win_eu = np.zeros((S, n_windows, ol.N_EXPERTS, m), np.int64)
    win_eu[:, :, _LRU_EXPERT, :] = win_ev
    # Fixed-policy weights never move: each window with a real request
    # snapshots the uniform initial vector, empty windows stay zero
    # (exactly the engine's accumulator semantics — including the f32
    # representation of 1/E the engine's accumulator carries).
    uniform = (np.ones(ol.N_EXPERTS, np.float32)
               / ol.N_EXPERTS).astype(float)
    win_wt = np.where(
        (win_req > 0)[..., None], uniform, 0.0
    )                                                      # [S, W, E]

    out: dict[int, Tier1Counters] = {}
    for i, size in enumerate(sizes_arr):
        hits_i = win_hits[..., i].astype(np.int64)
        miss_i = win_miss[..., i].astype(np.int64)
        ev_i = win_ev[..., i].astype(np.int64)
        t2w_i = win_t2w[..., i].astype(np.int64)
        out[int(size)] = Tier1Counters(
            requests=counts64,
            reads=counts64 - writes64,
            writes=writes64,
            hits=hits_i.sum(axis=1),
            misses=miss_i.sum(axis=1),
            prefetch_hits=np.zeros(S, np.int64),
            tier2_reads=miss_i.sum(axis=1),
            tier2_writes=t2w_i.sum(axis=1),
            evictions=ev_i.sum(axis=1),
            win_requests=win_req.astype(np.int64),
            win_hits=hits_i,
            win_misses=miss_i,
            win_prefetch_hits=zeros_w,
            win_tier2_reads=miss_i,
            win_tier2_writes=t2w_i,
            win_evictions=ev_i,
            win_expert_use=win_eu[..., i],
            win_weights=win_wt,
        )
    return out


def _tier2_writes(
    sizes_arr, s_idx, vmask, sh_pages_b, d_v, sh_writes, counts, S
):
    """Whole-stream dirty write-backs per shard for every size: interval
    counting over per-access episodes (see the module docstring).

    Each real access ``j`` opens one potential eviction gap, contributing
    a write-back for the capacities ``M_j < C <= U_j``. ``U_j`` is the
    reuse distance at the page's next access (the gap's distinct-page
    count) — or, for the page's final access, the number of distinct pages
    accessed afterwards (suffix count of last-occurrence flags). ``M_j``
    is the segmented running max of ``d`` since the page's last write
    (reset to 0 at writes, infinity while never written). Returns int64
    ``[S, len(sizes)]``.
    """
    m = int(sizes_arr.size)
    # Flat valid-entry views, ordered by (shard, position) — row-major.
    pos_v = np.broadcast_to(
        np.arange(sh_pages_b.shape[1])[None, :], sh_pages_b.shape
    )[vmask].astype(np.int64)
    page_v = sh_pages_b[vmask].astype(np.int64)
    cap = sh_pages_b.shape[1]
    w_b = np.zeros(sh_pages_b.shape, bool)
    w_b[:, : sh_writes.shape[1]] = sh_writes
    w_v = w_b[vmask]

    # Group same-page accesses: stable order (shard, page, position).
    order = np.lexsort((pos_v, page_v, s_idx))
    n = order.size
    if n == 0:
        return np.zeros((S, m), np.int64)
    run_start = np.ones(n, bool)
    run_start[1:] = (s_idx[order[1:]] != s_idx[order[:-1]]) | (
        page_v[order[1:]] != page_v[order[:-1]]
    )
    run_end = np.empty(n, bool)
    run_end[:-1] = run_start[1:]
    run_end[-1] = True

    # d_end: distinct pages after a final access = later last-occurrences
    # in the same shard (in original per-shard position order).
    lastocc = np.zeros(n, np.int64)
    lastocc[order] = run_end.astype(np.int64)
    cum = np.cumsum(lastocc)
    shard_tot = np.bincount(s_idx, weights=lastocc,
                            minlength=S).astype(np.int64)
    d_end = np.cumsum(shard_tot)[s_idx] - cum

    # U per gap (in run order): next access's distance, or d_end at run end.
    d_run = d_v[order]
    u_run = np.empty(n, np.int64)
    u_run[:-1] = d_run[1:]
    u_run[run_end] = d_end[order][run_end]

    # M per gap: segmented cummax of (0 at writes, d otherwise) with
    # segments opening at run starts and at writes. Monotone segment
    # offsets turn the reset-cummax into one np.maximum.accumulate.
    x = np.where(w_v[order], 0, d_run)
    seg = np.cumsum(run_start | w_v[order]).astype(np.int64)
    big = np.int64(1) << 33                                # > DIST_INF
    m_run = np.maximum.accumulate(x + seg * big) - seg * big

    # Gap contributes to size indices [lo, hi): C > M and C <= U. Empty
    # episodes (M >= U: clean line, or no eviction before reuse) must
    # contribute nothing — without the clamp their reversed [hi, lo)
    # difference interval would *subtract* from other episodes' counts.
    lo = np.searchsorted(sizes_arr, m_run, side="right")
    hi = np.maximum(np.searchsorted(sizes_arr, u_run, side="right"), lo)
    s_run = s_idx[order]
    diff = np.zeros((S, m + 1), np.int64)
    np.add.at(diff, (s_run, lo), 1)
    np.add.at(diff, (s_run, hi), -1)
    return np.cumsum(diff, axis=1)[:, :m]


def mrc_curve(spec: SimSpec, sizes: Sequence[int], trace=None):
    """Convenience: ``(sizes, miss_rates)`` arrays for a spec over a grid
    of cache sizes — the paper's capacity-planning curve — from one pass.
    ``sizes`` is deduplicated and sorted ascending."""
    ctrs = mrc_tier1_counters(spec, sizes, trace)
    sz = np.asarray(sorted(ctrs), np.int64)
    mr = np.asarray([
        ctrs[int(c)].misses.sum() / max(int(ctrs[int(c)].requests.sum()), 1)
        for c in sz
    ])
    return sz, mr
