"""End-to-end two-tier simulator: traffic -> tier-1 shards -> queuing.

This is the composition the paper's §V builds by hand for one worked
example, as a subsystem: :func:`simulate` generates (or accepts) a request
stream, pushes it through the distributed tier-1 cache engine
(:func:`repro.storage.tiered_store.run_distributed`), converts the
resulting counters into queuing-network inputs (λ, p12, μ1, μ2), and
reports per-shard and aggregate latency / throughput / utilization plus
the minimum-time model (eqs. 1-4).

The counters -> queuing mapping:

=====================  ====================================================
counter                queuing-network input
=====================  ====================================================
``misses/requests``    p12, the tier-2 branch probability (per shard and
                       pooled; ``SimSpec.p12_override`` pins it instead)
``requests - writes``  n_read_i in eq. 1 (hit service at μ1_read)
``writes``             n_write_i in eq. 1 (hit service at μ1_write)
``misses``             n_miss_i in eq. 2 (miss service at μ2)
``tier2_reads/writes`` reported as device traffic (prefetch fetches and
                       dirty write-backs ride the same IO thread)
=====================  ====================================================

Service rates come from :class:`repro.sim.spec.RateSpec` (fitted device
models or the §V paper constants).

**Time-resolved reports.** With ``SimSpec.n_windows > 1`` every counter is
additionally resolved over windows of the request stream
(:class:`WindowSeries`), each window's measured arrival rate and miss
fraction re-solve the network (:func:`repro.core.queuing.transient_two_tier`
— fluid carryover by default, piecewise-stationary via
``SimSpec.transient_mode``), and the report carries the resulting
latency/utilization time series plus the saturation onset — the first
window in which the offered rate reaches capacity.

**Wall-clock windows.** With ``SimSpec.window_dt`` set, the stream carries
arrival *timestamps* (:func:`repro.core.traffic.make_timed_stream`) and
windows are wall-clock time bins: the pooled per-window arrival rate is
*measured* from the arrival process (Poisson fluctuations, MMPP bursts,
second-composed phases), no longer flat by construction, and the measured
rates drive the fluid transient solver with queue carryover between
windows. On the historic request-index path (``window_dt=None``) time
variation still enters through the measured miss fraction and per-shard
arrival skew only. All per-shard equilibrium queue solves are
numpy-vectorized (one array solve instead of a Python loop over shards).

**Fault injection.** With ``SimSpec.faults`` set (wall-clock path only),
the schedule acts at three layers: arrivals during a ``shard_down``
interval fail over to surviving shards (:func:`fault_owner` — a
deterministic host-side remap of the request *owner* operand, so fault
grids never recompile the engine); the fluid transient runs at per-window
degraded rates μ(t) with tier-1 overflow spilling to tier-2 and optional
``RetryPolicy`` feedback (``SimReport.metastable_onset`` flags retry
storms); and on recovery the failed shard re-warms from a cold cache
(:func:`_cold_refill` converts its first post-recovery hits back into
misses against the store capacity, keeping windowed counters bit-exactly
reconciled with totals). A schedule-free spec takes none of these paths
and produces bit-identical reports.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Sequence

import numpy as np

from time import perf_counter

from repro.core.mapping import apply_failover, page_to_shard
from repro.core.queuing import (
    FluidReport,
    ServiceTimes,
    TransientReport,
    TwoTierModel,
    expected_response,
    fluid_two_tier_batched,
    residence_times,
    service_time_model,
    transient_two_tier,
)
from repro.core.traffic import make_stream, make_timed_stream
from repro.sim.spec import ResolvedRates, SimSpec
from repro.storage.tiered_store import correct_padded_stats, run_distributed
import jax.numpy as jnp

__all__ = ["Tier1Counters", "TenantCounters", "WindowSeries", "ShardReport",
           "TenantReport", "SimReport",
           "tier1_counters", "report_from_counters", "batched_reports",
           "simulate", "fault_owner", "stream_for_spec"]


class Tier1Counters(NamedTuple):
    """Per-shard int64 counter arrays measured by the tier-1 engine.

    ``win_*`` fields resolve the same counters over the time windows of the
    global request stream (shape ``[n_shards, n_windows]``; window sums
    equal the whole-stream counters exactly)."""

    requests: np.ndarray
    reads: np.ndarray
    writes: np.ndarray
    hits: np.ndarray
    misses: np.ndarray
    prefetch_hits: np.ndarray
    tier2_reads: np.ndarray
    tier2_writes: np.ndarray
    evictions: np.ndarray
    win_requests: np.ndarray
    win_hits: np.ndarray
    win_misses: np.ndarray
    win_prefetch_hits: np.ndarray
    win_tier2_reads: np.ndarray
    win_tier2_writes: np.ndarray
    win_evictions: np.ndarray
    win_expert_use: np.ndarray   # int64[n_shards, n_windows, E]
    win_weights: np.ndarray      # float[n_shards, n_windows, E]

    @property
    def n_windows(self) -> int:
        return self.win_requests.shape[-1]


class TenantCounters(NamedTuple):
    """Per-tenant windowed engine counters of a ``tenant_mix`` workload,
    pooled across shards (shapes ``[n_tenants, n_windows]``; sums over the
    tenant axis equal the pooled :class:`Tier1Counters` window series
    exactly). Produced by the streaming replay path
    (:func:`repro.sim.stream.stream_tier1_counters`), which resolves the
    engine's windowed scatters over composite ``window x tenant`` ids —
    attribution costs no extra engine pass."""

    names: tuple            # tenant names, declaration order
    win_requests: np.ndarray
    win_hits: np.ndarray
    win_misses: np.ndarray

    @property
    def n_tenants(self) -> int:
        return self.win_requests.shape[0]

    @property
    def n_windows(self) -> int:
        return self.win_requests.shape[-1]


class WindowSeries(NamedTuple):
    """Per-shard, per-window telemetry (shapes ``[n_shards, n_windows]``):
    the windowed engine counters plus the measured queuing-network inputs
    (arrival rate and miss fraction) each window feeds into the transient
    solve.

    ``lam`` is each *shard's* share of the offered load in that window. On
    the wall-clock path (``SimSpec.window_dt``) it is genuinely measured —
    bursty arrival processes show up as per-window rate swings, pooled and
    per shard. On the request-index path windows are equal slices of a
    constant-rate stream, so per-shard rates resolve mapping skew and
    phased footprint shifts while the across-shard pooled rate stays ~λ by
    construction.

    ``expert_use`` / ``weights`` resolve the online learner over the same
    windows (``[n_shards, n_windows, E]``): evictions issued per expert,
    and the expert weight vector at each window's last request (empty
    windows carry the previous window's weights forward — the learner did
    not move), so adaptation at phase boundaries is observable."""

    requests: np.ndarray
    hits: np.ndarray
    misses: np.ndarray
    prefetch_hits: np.ndarray
    tier2_reads: np.ndarray
    tier2_writes: np.ndarray
    evictions: np.ndarray
    expert_use: np.ndarray  # [n_shards, n_windows, E] evictions per expert
    weights: np.ndarray     # [n_shards, n_windows, E] learner weights
    lam: np.ndarray   # measured per-shard arrival rate (req/s)
    p12: np.ndarray   # measured per-shard miss fraction

    def to_dict(self) -> dict:
        return {name: _plain(getattr(self, name)) for name in self._fields}


@dataclasses.dataclass(frozen=True)
class ShardReport:
    """One tier-1 shard: measured counters + its queuing-network solution."""

    shard: int
    requests: int
    reads: int
    writes: int
    hits: int
    misses: int
    prefetch_hits: int
    tier2_reads: int
    tier2_writes: int
    evictions: int
    p12: float           # miss fraction used by the queue model
    lam_eff: float       # effective arrival rate at the k-server queue
    rho1: float          # tier-1 offered load (a = lam_eff/mu1)
    rho2: float          # tier-2 utilization
    w1: float            # tier-1 residence time (s)
    w2: float            # tier-2 residence time (s)
    response_s: float    # expected response: w1 + p12 * w2
    equilibrium: bool
    # First window in which this shard's transient solve saturates (ρ ≥ 1);
    # None when every window is stable (or n_windows == 1 and stable).
    saturation_onset: Optional[int] = None
    # First window of the *trailing* metastable run — external load back
    # under capacity, but retry feedback keeping total offered load above
    # it. None when the shard ends healthy or no retry policy is active.
    metastable_onset: Optional[int] = None

    def to_dict(self) -> dict:
        return _plain(dataclasses.asdict(self))


@dataclasses.dataclass(frozen=True)
class TenantReport:
    """One tenant of a ``tenant_mix`` workload: measured windowed counters
    plus the latency the tenant observes riding the *pooled* queues.

    Tenants share the tier-1/tier-2 service processes, so each window's
    residence times come from the pooled transient solve; what is per
    tenant is the miss mix — ``response_s[w] = w1[w] + p12[w] * w2[w]``
    with the *tenant's* measured per-window miss fraction. A cache-hungry
    tenant therefore reports higher expected response than a cache-friendly
    one inside the same window, which is the attribution the multi-tenant
    capacity questions need."""

    tenant: int              # index in the spec's declaration order
    name: str
    requests: int
    hits: int
    misses: int
    miss_rate: float         # whole-stream: misses / requests
    win_requests: np.ndarray  # [n_windows] pooled across shards
    win_misses: np.ndarray    # [n_windows]
    lam: np.ndarray           # [n_windows] measured tenant arrival rate
    p12: np.ndarray           # [n_windows] tenant miss fraction
    response_s: np.ndarray    # [n_windows] expected response this tenant sees
    mean_response_s: float    # request-weighted mean of response_s

    def to_dict(self) -> dict:
        return _plain(dataclasses.asdict(self))


@dataclasses.dataclass(frozen=True)
class SimReport:
    """Aggregate + per-shard results for one :class:`SimSpec` scenario."""

    spec: SimSpec
    rates: ResolvedRates
    shards: tuple
    # aggregate counters
    requests: int
    hits: int
    misses: int
    prefetch_hits: int
    tier2_reads: int
    tier2_writes: int
    evictions: int
    miss_rate: float        # measured: misses / requests
    p12: float              # miss fraction used by the queue model
    # aggregate queuing network (pooled p12, per-process λ)
    lam_eff: float
    rho1: float
    rho2: float
    w1: float
    w2: float
    response_s: float       # expected response time: w1 + p12 * w2
    mu_system: float        # eq. 5 composed service rate
    rho_system: float
    equilibrium: bool
    throughput_rps: float   # equilibrium throughput across all shards
    # minimum-time model (eqs. 1-4)
    min_time: ServiceTimes
    t_total_s: float        # eq. 4: max over shards
    min_time_throughput_rps: float  # total requests / t_total
    # time-resolved telemetry (window axis = n_windows slices of the stream:
    # wall-clock bins when spec.window_dt is set, request-count otherwise)
    n_windows: int
    window_duration_s: float
    windows: WindowSeries
    # Pooled transient solve: FluidReport (carryover, the default — adds
    # q1/q2 backlog series) or TransientReport (mode="piecewise").
    transient: "TransientReport | FluidReport"
    saturation_onset: Optional[int]  # first pooled window ρ ≥ 1 (None=never)
    # First window of the pooled solve's trailing retry-storm run (see
    # ShardReport.metastable_onset). None = ends healthy / no retry policy.
    metastable_onset: Optional[int] = None
    # Per-tenant attribution (tenant_mix workloads replayed through the
    # streaming path); empty for single-tenant specs.
    tenants: tuple = ()

    def to_dict(self) -> dict:
        d = {
            f.name: getattr(self, f.name)
            for f in dataclasses.fields(self)
            if f.name not in ("spec", "rates", "shards", "min_time",
                              "windows", "transient", "tenants")
        }
        d["rates"] = dataclasses.asdict(self.rates)
        d["spec"] = {
            "traffic": dataclasses.asdict(self.spec.traffic),
            "store": dataclasses.asdict(self.spec.store),
            "n_shards": self.spec.n_shards,
            "mapping": self.spec.mapping,
            "lam": self.spec.lam,
            "k_servers": self.spec.k_servers,
            "flow": self.spec.flow,
            "p12_override": self.spec.p12_override,
            "n_windows": self.spec.n_windows,
            "window_dt": self.spec.window_dt,
            "transient_mode": self.spec.transient_mode,
            "faults": (dataclasses.asdict(self.spec.faults)
                       if self.spec.faults is not None else None),
        }
        d["min_time"] = {
            "t_hit": [float(v) for v in np.atleast_1d(self.min_time.t_hit)],
            "t_miss": [float(v) for v in np.atleast_1d(self.min_time.t_miss)],
            "t_proc": [float(v) for v in np.atleast_1d(self.min_time.t_proc)],
            "t_total": float(self.min_time.t_total),
        }
        d = _plain(d)  # scalar fields, rates (tuples!), spec, min_time
        # These sub-reports sanitize themselves — attach after the walk so
        # nothing is converted twice.
        d["windows"] = self.windows.to_dict()
        d["transient"] = {
            name: _plain(getattr(self.transient, name))
            for name in self.transient._fields
        }
        d["shards"] = [s.to_dict() for s in self.shards]
        d["tenants"] = [t.to_dict() for t in self.tenants]
        return d


def _plain(obj):
    """Recursively convert numpy scalars/arrays (and tuples) into plain
    JSON-serializable Python values."""
    if isinstance(obj, dict):
        return {k: _plain(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_plain(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return _plain(obj.tolist())
    if isinstance(obj, np.generic):
        return obj.item()
    return obj


def sim_n_pages(spec: SimSpec, pages: np.ndarray) -> int:
    """Page-space size for the §III mapping: the declared traffic page
    space, widened if the stream outgrew it (IRM page ids are unbounded —
    expired pages are replaced by fresh ids)."""
    return max(spec.traffic.n_pages, int(pages.max()) + 1)


def fault_owner(spec: SimSpec, pages: np.ndarray,
                times: Optional[np.ndarray], n_pages: int) -> np.ndarray:
    """Per-request owner shard under the spec's fault schedule: the §III
    mapping, with requests arriving during a shard_down interval rerouted
    to survivors (:func:`repro.core.mapping.apply_failover`). Pure host-side
    data — the remapped owner array is an engine *operand*, so fault grids
    share one compiled engine."""
    owner = np.asarray(
        page_to_shard(jnp.asarray(pages), spec.n_shards, n_pages,
                      spec.mapping)
    )
    if spec.faults is None or times is None:
        return owner
    down = spec.faults.down_intervals()
    if not down:
        return owner
    owner, _ = apply_failover(owner, times, down, spec.n_shards)
    return owner


def stream_for_spec(spec: SimSpec, trace=None):
    """Resolve the concrete request stream a spec (plus optional trace
    override) describes: ``(pages, is_write, times, n_pages, n_windows,
    window_dt)``. ``times`` is None on the request-index path. Shared by
    the sequential scan engine (:func:`tier1_counters`) and the MRC
    stack-distance engine (:mod:`repro.sim.mrc`), so both paths consume
    bit-identical streams by construction. ``trace`` overrides the
    generated stream with a user-provided ``(pages, is_write)`` pair — or
    ``(pages, is_write, times)`` triple on the wall-clock path
    (``spec.window_dt`` set; a 2-tuple trace then gets deterministic
    arrivals at the aggregate offered rate) — mapped over its own observed
    page space."""
    n_windows, window_dt = spec.window_grid()
    times = None
    if trace is not None:
        pages, is_write = np.asarray(trace[0]), np.asarray(trace[1], bool)
        n_pages = int(pages.max()) + 1
        if window_dt is not None:
            if len(trace) > 2:
                times = np.asarray(trace[2], float)
                # Normalize to t0 = 0: real traces carry absolute (epoch)
                # timestamps, and the window origin is the trace start —
                # otherwise a derived grid sizes itself to the epoch.
                if times.size:
                    times = times - times.min()
            else:
                times = (1.0 + np.arange(pages.shape[0])) / spec.agg_rate()
            if spec.n_windows == 1:
                # Derived grids must cover the *trace's* horizon — the
                # spec's nominal traffic no longer describes the stream.
                n_windows = max(1, int(np.ceil(
                    float(times.max()) / window_dt)))
    elif window_dt is not None:
        pages, is_write, times = make_timed_stream(
            spec.traffic, default_rate=spec.agg_rate())
        n_pages = sim_n_pages(spec, pages)
    else:
        pages, is_write = make_stream(spec.traffic)
        n_pages = sim_n_pages(spec, pages)
    return pages, is_write, times, n_pages, n_windows, window_dt


def tier1_counters(spec: SimSpec, trace=None, *,
                   engine: str = "fused") -> Tier1Counters:
    """Run the workload through the distributed tier-1 cache
    (:func:`repro.storage.tiered_store.run_distributed`) and return exact
    per-shard counters (whole-stream and per-window). ``trace`` overrides
    the generated stream (see :func:`stream_for_spec`); ``engine`` selects
    the fused cache-scan engine (default) or the original ``"scan"``
    reference it is bit-exact against."""
    pages, is_write, times, n_pages, n_windows, window_dt = stream_for_spec(
        spec, trace)
    owner = fault_owner(spec, pages, times, n_pages)
    stats, counts = run_distributed(
        spec.store, pages, is_write,
        n_shards=spec.n_shards, mapping=spec.mapping, n_pages=n_pages,
        n_windows=n_windows, timestamps=times, window_dt=window_dt,
        owner=owner, engine=engine,
    )
    writes = np.bincount(owner[is_write], minlength=spec.n_shards)
    return _assemble_counters(stats, counts, writes)


def _assemble_counters(corrected_stats, counts, writes) -> Tier1Counters:
    """Build :class:`Tier1Counters` from padding-corrected StreamStats."""
    counts = np.asarray(counts, np.int64)
    s = corrected_stats
    return Tier1Counters(
        requests=counts,
        reads=counts - np.asarray(writes, np.int64),
        writes=np.asarray(writes, np.int64),
        hits=np.asarray(s.hits, np.int64),
        misses=np.asarray(s.misses, np.int64),
        prefetch_hits=np.asarray(s.prefetch_hits, np.int64),
        tier2_reads=np.asarray(s.tier2_reads, np.int64),
        tier2_writes=np.asarray(s.tier2_writes, np.int64),
        evictions=np.asarray(s.evictions, np.int64),
        win_requests=np.asarray(s.win_requests, np.int64),
        win_hits=np.asarray(s.win_hits, np.int64),
        win_misses=np.asarray(s.win_misses, np.int64),
        win_prefetch_hits=np.asarray(s.win_prefetch_hits, np.int64),
        win_tier2_reads=np.asarray(s.win_tier2_reads, np.int64),
        win_tier2_writes=np.asarray(s.win_tier2_writes, np.int64),
        win_evictions=np.asarray(s.win_evictions, np.int64),
        win_expert_use=np.asarray(s.win_expert_use, np.int64),
        win_weights=np.asarray(s.win_weights, float),
    )


def counters_from_stats(stats, counts, writes, *, cap: int) -> Tier1Counters:
    """Assemble :class:`Tier1Counters` from *padded* per-shard StreamStats
    (the sweep engine's batched path), delegating the padding/phantom-miss
    correction to :func:`repro.storage.tiered_store.correct_padded_stats`."""
    return _assemble_counters(
        correct_padded_stats(stats, counts, cap), counts, writes
    )


def _shard_rate_vectors(spec: SimSpec, rates: ResolvedRates):
    """Per-shard queue-model (μ1, μ2) arrays (scalars broadcast)."""
    per = [rates.for_shard(i) for i in range(spec.n_shards)]
    return (np.asarray([r.mu1 for r in per], float),
            np.asarray([r.mu2 for r in per], float))


def _ffill_weights(win_weights, win_requests) -> np.ndarray:
    """Carry expert weights forward over empty windows: a window with no
    real requests left a zero row in the engine's snapshot accumulator —
    the learner did not move, so it inherits the previous window's weights
    (leading empties get the uniform initial weights)."""
    w = np.array(win_weights, float, copy=True)      # [..., W, E]
    req = np.asarray(win_requests)
    n_experts = w.shape[-1]
    prev = np.full(w.shape[:-2] + (n_experts,), 1.0 / n_experts)
    for t in range(w.shape[-2]):
        empty = (req[..., t] == 0)[..., None]
        w[..., t, :] = np.where(empty, prev, w[..., t, :])
        prev = w[..., t, :]
    return w


def _cold_refill(spec: SimSpec, ctr: Tier1Counters,
                 window_dt: float) -> Tier1Counters:
    """Model the cold-cache refill after each shard_down recovery.

    The jitted cache engine keeps its state through an outage (the remap is
    an input-side reroute), but a real recovering shard comes back *cold*:
    its first post-recovery requests re-miss up to one cache's worth of
    lines while survivors evicted its working set. Approximate that by
    reclassifying post-recovery windowed hits into misses (+ tier-2 reads)
    on the recovered shard, with a budget of ``store.n_lines`` touched
    lines; the whole-stream totals get the same correction, so windowed
    counters still reconcile bit-exactly with totals."""
    hits = np.array(ctr.win_hits, np.int64, copy=True)
    misses = np.array(ctr.win_misses, np.int64, copy=True)
    t2r = np.array(ctr.win_tier2_reads, np.int64, copy=True)
    reqs = np.asarray(ctr.win_requests, np.int64)
    n_windows = ctr.n_windows
    for shard, _, t1 in spec.faults.down_intervals():
        w_rec = int(np.floor(t1 / window_dt))
        budget = int(spec.store.n_lines)
        for w in range(max(w_rec, 0), n_windows):
            if budget <= 0:
                break
            cold = min(budget, int(reqs[shard, w]))
            extra = min(int(hits[shard, w]), cold)
            hits[shard, w] -= extra
            misses[shard, w] += extra
            t2r[shard, w] += extra
            budget -= cold
    d_hits = hits.sum(axis=1) - np.asarray(ctr.win_hits).sum(axis=1)
    return ctr._replace(
        win_hits=hits, win_misses=misses, win_tier2_reads=t2r,
        hits=np.asarray(ctr.hits, np.int64) + d_hits,
        misses=np.asarray(ctr.misses, np.int64) - d_hits,
        tier2_reads=np.asarray(ctr.tier2_reads, np.int64) - d_hits,
    )


class _PreparedReport(NamedTuple):
    """Everything :func:`report_from_counters` derives *before* the
    transient solves: resolved rates, windowed telemetry, and the fluid
    solver inputs. The batched report path (:func:`batched_reports`)
    prepares every point first, gathers compatible points' rate tensors
    into one ``[point, shard, window]`` device solve, and assembles each
    :class:`SimReport` with :func:`_finish_report` — the scalar path runs
    the exact same prepare/solve/finish sequence one point at a time."""

    spec: SimSpec
    ctr: Tier1Counters            # cold-refill-corrected counters
    tenants: Optional[TenantCounters]
    rates: ResolvedRates
    mu1_v: np.ndarray             # [S] equilibrium per-shard rates
    mu2_v: np.ndarray
    p12_sh: np.ndarray            # [S] whole-stream per-shard miss fraction
    req: np.ndarray               # [S] per-shard request totals
    total_req: int
    total_miss: int
    miss_rate: float
    p12: float                    # aggregate miss fraction for the solves
    duration: float
    n_windows: int
    windows: WindowSeries
    lam_sw: np.ndarray            # [S, W] measured per-shard rates
    p12_sw: np.ndarray
    mode: str                     # fluid | piecewise (after idle fallback)
    tr_kw: dict                   # transient kwargs (dt/retry/spill/mu_load)
    sh_mu1: np.ndarray            # [S, 1] or [S, W] degraded μ1(t)
    sh_mu2: np.ndarray
    pool_lam: np.ndarray          # [W] pooled per-process rate
    pool_p12: np.ndarray
    pool_mu1: object              # scalar or [W] degraded pooled μ1(t)
    pool_mu2: object


class _Equilibrium(NamedTuple):
    """Stationary queue solutions feeding the report: per-shard fields
    carry a trailing shard axis, aggregate fields are scalars — both with
    arbitrary leading (point) axes, so one call serves a single report or
    a whole stacked batch."""

    sh_lam_eff: np.ndarray
    sh_rho1: np.ndarray
    sh_rho2: np.ndarray
    sh_w1: np.ndarray
    sh_w2: np.ndarray
    sh_resp: np.ndarray
    sh_eq: np.ndarray
    agg_lam_eff: object
    agg_rho1: object
    agg_rho2: object
    agg_mu_system: object
    agg_rho_system: object
    agg_eq: object
    w1: object
    w2: object


def _prepare_report(
    spec: SimSpec, ctr: Tier1Counters,
    tenants: Optional[TenantCounters] = None,
) -> _PreparedReport:
    """Counters → queuing-network inputs (the pre-solve half of
    :func:`report_from_counters`)."""
    rates = spec.rates.resolve()
    # (mu*_shards length vs n_shards is enforced by SimSpec.__post_init__.)
    mu1_v, mu2_v = _shard_rate_vectors(spec, rates)
    _, window_dt = spec.window_grid()
    if (spec.faults is not None and spec.faults.refill_cold
            and window_dt is not None and spec.faults.down_intervals()):
        ctr = _cold_refill(spec, ctr, window_dt)

    req = np.asarray(ctr.requests, np.int64)
    p12_sh = (
        np.full(spec.n_shards, spec.p12_override, float)
        if spec.p12_override is not None
        else np.asarray(ctr.misses, float) / np.maximum(req, 1)
    )

    n_windows = ctr.n_windows
    total_req = int(req.sum())
    if window_dt is not None:
        # Wall-clock bins: fixed duration, measured per-window rates.
        duration = float(window_dt)
        if not duration > 0:
            # SimSpec validation rejects non-finite/non-positive window_dt;
            # a spec that bypassed it (pickles of older versions, direct
            # object.__setattr__) must fail here, not divide rates by 0.
            raise ValueError(
                f"timed spec has a non-positive window duration "
                f"({duration!r} s from window_dt={spec.window_dt!r}) — the "
                f"wall-clock report path needs a positive finite window_dt")
    else:
        # Request-index windows: the whole stream arrives at aggregate rate
        # λ·S, so each of the n_windows equal request-count slices spans
        # this duration. λ ≤ 0 is the idle regime (no arrivals): windows
        # have no duration and the measured rates below stay 0.
        duration = (
            total_req / (spec.lam * spec.n_shards * n_windows)
            if total_req and spec.lam > 0 else 0.0
        )
    win_req = np.asarray(ctr.win_requests, float)
    lam_sw = win_req / duration if duration > 0 else np.zeros_like(win_req)
    p12_sw = (
        np.full_like(win_req, spec.p12_override)
        if spec.p12_override is not None
        else np.asarray(ctr.win_misses, float) / np.maximum(win_req, 1)
    )
    windows = WindowSeries(
        requests=ctr.win_requests,
        hits=ctr.win_hits,
        misses=ctr.win_misses,
        prefetch_hits=ctr.win_prefetch_hits,
        tier2_reads=ctr.win_tier2_reads,
        tier2_writes=ctr.win_tier2_writes,
        evictions=ctr.win_evictions,
        expert_use=ctr.win_expert_use,
        weights=_ffill_weights(ctr.win_weights, ctr.win_requests),
        lam=lam_sw,
        p12=p12_sw,
    )
    # Fluid carryover needs a positive window duration; an all-idle stream
    # (duration 0) degenerates to per-window stationary (= idle) solves.
    mode = spec.transient_mode if duration > 0 else "piecewise"
    tr_kw = dict(k=spec.k_servers, flow=spec.flow, mode=mode)
    if mode == "fluid":
        tr_kw["dt"] = duration
        if rates.mu_load is not None:
            # Load-dependent μ(Q) rides the fluid solve only (SimSpec
            # validation requires transient_mode='fluid'; an all-idle
            # stream that degenerated to piecewise has no load to bend μ).
            tr_kw["mu_load"] = rates.mu_load
    # Fault schedule → time-varying μ(t) per shard/window plus retry
    # feedback. Only the fluid solver understands these dynamics (SimSpec
    # validation guarantees transient_mode='fluid'; an all-idle stream that
    # degenerated to piecewise above has no arrivals to retry anyway).
    sh_mu1: np.ndarray = mu1_v[:, None]
    sh_mu2: np.ndarray = mu2_v[:, None]
    pool_mu1, pool_mu2 = rates.mu1, rates.mu2
    if spec.faults is not None and mode == "fluid":
        tr_kw["retry"] = spec.faults.retry
        if spec.faults.events and window_dt is not None:
            # Degraded tier-1 can't absorb its offered load: spill the
            # excess to tier-2 so the backup tier serves what tier-1 drops.
            tr_kw["tier1_spill"] = True
            mu1_mult, mu2_mult = spec.faults.mu_multipliers(
                n_windows, window_dt, spec.n_shards)
            sh_mu1 = sh_mu1 * mu1_mult
            sh_mu2 = sh_mu2 * mu2_mult[None, :]
            pool_mu1 = rates.mu1 * mu1_mult.mean(axis=0)
            pool_mu2 = rates.mu2 * mu2_mult
    # Pooled per-process arrival rate and miss fraction per window.
    pool_req = win_req.sum(axis=0)
    pool_lam = (
        pool_req / (duration * spec.n_shards)
        if duration > 0 else np.zeros(n_windows)
    )
    pool_p12 = (
        np.full(n_windows, spec.p12_override, float)
        if spec.p12_override is not None
        else np.asarray(ctr.win_misses, float).sum(axis=0)
        / np.maximum(pool_req, 1)
    )
    total_miss = int(ctr.misses.sum())
    miss_rate = total_miss / total_req if total_req else 0.0
    p12 = spec.p12_override if spec.p12_override is not None else miss_rate
    return _PreparedReport(
        spec=spec, ctr=ctr, tenants=tenants, rates=rates,
        mu1_v=mu1_v, mu2_v=mu2_v, p12_sh=p12_sh, req=req,
        total_req=total_req, total_miss=total_miss, miss_rate=miss_rate,
        p12=p12, duration=duration, n_windows=n_windows, windows=windows,
        lam_sw=lam_sw, p12_sw=p12_sw, mode=mode, tr_kw=tr_kw,
        sh_mu1=sh_mu1, sh_mu2=sh_mu2, pool_lam=pool_lam, pool_p12=pool_p12,
        pool_mu1=pool_mu1, pool_mu2=pool_mu2,
    )


def _solve_equilibrium(
    lam_sh, mu1_sh, mu2_sh, p12_sh, lam_agg, mu1_agg, mu2_agg, p12_agg,
    *, k: int, flow: str,
) -> _Equilibrium:
    """Per-shard + aggregate stationary solves — elementwise over any
    leading axes, so a ``[point, shard]`` stack costs two model calls for
    the whole batch instead of two per point."""
    sh_rep = TwoTierModel(
        lam=lam_sh, mu1=mu1_sh, mu2=mu2_sh, p12=p12_sh, k=k,
        flow=flow,  # type: ignore[arg-type]
    ).analyze()
    sh_sum = sh_rep.summary()
    sh_eq = np.asarray(sh_rep.equilibrium, bool)
    sh_w1, sh_w2 = residence_times(sh_sum["W1"], sh_sum["W2"],
                                   mu1_sh, mu2_sh, sh_eq)
    sh_resp = expected_response(sh_w1, sh_w2, p12_sh)
    agg_rep = TwoTierModel(
        lam=lam_agg, mu1=mu1_agg, mu2=mu2_agg, p12=p12_agg, k=k,
        flow=flow,  # type: ignore[arg-type]
    ).analyze()
    s = agg_rep.summary()
    w1, w2 = residence_times(s["W1"], s["W2"], mu1_agg, mu2_agg,
                             agg_rep.equilibrium)
    return _Equilibrium(
        sh_lam_eff=np.asarray(sh_sum["lam_eff"]),
        sh_rho1=np.asarray(sh_sum["rho1"]),
        sh_rho2=np.asarray(sh_sum["rho2"]),
        sh_w1=np.asarray(sh_w1), sh_w2=np.asarray(sh_w2),
        sh_resp=np.asarray(sh_resp), sh_eq=sh_eq,
        agg_lam_eff=s["lam_eff"], agg_rho1=s["rho1"], agg_rho2=s["rho2"],
        agg_mu_system=s["mu_system"], agg_rho_system=s["rho_system"],
        agg_eq=agg_rep.equilibrium, w1=w1, w2=w2,
    )


def _point_equilibrium(prep: _PreparedReport) -> _Equilibrium:
    return _solve_equilibrium(
        np.full(prep.spec.n_shards, prep.spec.lam, float),
        prep.mu1_v, prep.mu2_v, prep.p12_sh,
        prep.spec.lam, prep.rates.mu1, prep.rates.mu2, prep.p12,
        k=prep.spec.k_servers, flow=prep.spec.flow,
    )


def _onsets(sh_tr, transient) -> tuple:
    """(sh_onsets[S], sh_meta[S]|None, saturation_onset, metastable_onset)
    of one point's transient solves. The batched path instead computes
    these once over the whole ``[point, shard, window]`` stack (the onset
    scans vectorize over leading axes) and slices per point."""
    sh_onsets = np.asarray(sh_tr.onset())
    # Report-level onset = the pooled solve's first saturated window (system
    # drifting into overload). Per-shard onsets — which also capture mapping
    # skew concentrating load on one shard — live on each ShardReport.
    pooled_onset = int(transient.onset())
    saturation_onset = pooled_onset if pooled_onset >= 0 else None
    # Metastable onset (retry feedback keeping total offered load above
    # capacity after external load subsides) — fluid+retry solves only.
    pooled_meta = None
    sh_meta = None
    if isinstance(transient, FluidReport) and transient.metastable is not None:
        mo = int(transient.metastable_onset())
        pooled_meta = mo if mo >= 0 else None
    if isinstance(sh_tr, FluidReport) and sh_tr.metastable is not None:
        sh_meta = np.asarray(sh_tr.metastable_onset())
    return sh_onsets, sh_meta, saturation_onset, pooled_meta


def _finish_report(
    prep: _PreparedReport, eq: _Equilibrium, sh_tr, transient, onsets: tuple,
) -> SimReport:
    """Assemble the :class:`SimReport` from the solved pieces (the
    post-solve half of :func:`report_from_counters`)."""
    spec, ctr, rates = prep.spec, prep.ctr, prep.rates
    duration = prep.duration
    sh_onsets, sh_meta, saturation_onset, pooled_meta = onsets

    # --- per-tenant attribution (tenant_mix streaming replays) ------------
    tenant_reports: tuple = ()
    if prep.tenants is not None:
        tenants = prep.tenants
        t_reports = []
        w1_t = np.asarray(transient.w1, float)
        w2_t = np.asarray(transient.w2, float)
        for k, name in enumerate(tenants.names):
            t_req = np.asarray(tenants.win_requests[k], np.int64)
            t_miss = np.asarray(tenants.win_misses[k], np.int64)
            t_hits = int(np.asarray(tenants.win_hits[k]).sum())
            n_req = int(t_req.sum())
            t_p12 = t_miss / np.maximum(t_req, 1)
            t_lam = (t_req / duration if duration > 0
                     else np.zeros_like(t_req, float))
            t_resp = w1_t + t_p12 * w2_t
            wsum = float(t_req.sum())
            t_reports.append(TenantReport(
                tenant=k,
                name=str(name),
                requests=n_req,
                hits=t_hits,
                misses=int(t_miss.sum()),
                miss_rate=float(t_miss.sum() / max(n_req, 1)),
                win_requests=t_req,
                win_misses=t_miss,
                lam=np.asarray(t_lam, float),
                p12=np.asarray(t_p12, float),
                response_s=np.asarray(t_resp, float),
                mean_response_s=(
                    float((t_resp * t_req).sum() / wsum) if wsum > 0 else 0.0
                ),
            ))
        tenant_reports = tuple(t_reports)

    shard_reports = []
    for i in range(spec.n_shards):
        onset_i = int(sh_onsets[i])
        shard_reports.append(ShardReport(
            shard=i,
            requests=int(prep.req[i]),
            reads=int(ctr.reads[i]),
            writes=int(ctr.writes[i]),
            hits=int(ctr.hits[i]),
            misses=int(ctr.misses[i]),
            prefetch_hits=int(ctr.prefetch_hits[i]),
            tier2_reads=int(ctr.tier2_reads[i]),
            tier2_writes=int(ctr.tier2_writes[i]),
            evictions=int(ctr.evictions[i]),
            p12=float(prep.p12_sh[i]),
            lam_eff=float(np.asarray(eq.sh_lam_eff).reshape(-1)[i]),
            rho1=float(np.asarray(eq.sh_rho1).reshape(-1)[i]),
            rho2=float(np.asarray(eq.sh_rho2).reshape(-1)[i]),
            w1=float(eq.sh_w1[i]),
            w2=float(eq.sh_w2[i]),
            response_s=float(eq.sh_resp[i]),
            equilibrium=bool(eq.sh_eq[i]),
            saturation_onset=onset_i if onset_i >= 0 else None,
            metastable_onset=(
                int(sh_meta[i])
                if sh_meta is not None and int(sh_meta[i]) >= 0 else None
            ),
        ))

    # Minimum-time model (eqs. 1-4) over the per-shard counters: eq. 1 at
    # the read/write device rates, eq. 2 at the miss rate, eq. 4 = max.
    # Heterogeneous rate specs feed per-shard μ vectors into eqs. 1-2.
    mu1_read_v, mu1_write_v, mu2_mt_v = rates.shard_vectors(spec.n_shards)
    mt = service_time_model(
        ctr.reads, ctr.writes, ctr.misses, mu1_read_v, mu1_write_v, mu2_mt_v,
    )
    t_total = float(mt.t_total)

    equilibrium = bool(eq.agg_eq) and bool(eq.sh_eq.all())
    return SimReport(
        spec=spec,
        rates=rates,
        shards=tuple(shard_reports),
        requests=prep.total_req,
        hits=int(ctr.hits.sum()),
        misses=prep.total_miss,
        prefetch_hits=int(ctr.prefetch_hits.sum()),
        tier2_reads=int(ctr.tier2_reads.sum()),
        tier2_writes=int(ctr.tier2_writes.sum()),
        evictions=int(ctr.evictions.sum()),
        miss_rate=float(prep.miss_rate),
        p12=float(prep.p12),
        lam_eff=float(eq.agg_lam_eff),
        rho1=float(eq.agg_rho1),
        rho2=float(eq.agg_rho2),
        w1=float(eq.w1),
        w2=float(eq.w2),
        response_s=float(expected_response(eq.w1, eq.w2, prep.p12)),
        mu_system=float(eq.agg_mu_system),
        rho_system=float(eq.agg_rho_system),
        equilibrium=equilibrium,
        throughput_rps=float(spec.lam * spec.n_shards) if equilibrium
        else float(eq.agg_mu_system) * spec.n_shards,
        min_time=mt,
        t_total_s=t_total,
        min_time_throughput_rps=(
            prep.total_req / t_total if t_total > 0 else 0.0),
        n_windows=prep.n_windows,
        window_duration_s=float(duration),
        windows=prep.windows,
        transient=transient,
        saturation_onset=saturation_onset,
        metastable_onset=pooled_meta,
        tenants=tenant_reports,
    )


def report_from_counters(
    spec: SimSpec, ctr: Tier1Counters,
    tenants: Optional[TenantCounters] = None,
) -> SimReport:
    """Solve the queuing network for measured counters (no traffic rerun).

    Per-shard service-rate heterogeneity (``RateSpec.mu1_shards`` /
    ``mu2_shards``, the paper's Tables VII–IX strong-scaling sweeps) is
    honored here: each shard's queue is solved at its own μ1/μ2 and the
    minimum-time model (eqs. 1–4) uses the per-shard rate vectors; the
    aggregate/pooled queue uses the scalar (mean) rates. All per-shard and
    per-window solves are vectorized array calls into
    :mod:`repro.core.queuing` — no Python loop over shards or windows.
    (:func:`batched_reports` additionally batches the fluid transient
    solves of *many* reports into one device call.)

    ``tenants`` (a :class:`TenantCounters`, produced by the streaming
    replay of a ``tenant_mix`` workload) adds per-tenant
    :class:`TenantReport` attribution: each tenant's windowed miss mix
    priced at the pooled transient solve's per-window residence times.
    """
    prep = _prepare_report(spec, ctr, tenants)
    # Per-shard transient: measured per-shard rates at per-shard μ.
    sh_tr = transient_two_tier(
        prep.lam_sw, prep.p12_sw, prep.sh_mu1, prep.sh_mu2, **prep.tr_kw,
    )
    # Pooled transient: per-process pooled arrival rate and miss fraction.
    transient = transient_two_tier(
        prep.pool_lam, prep.pool_p12, prep.pool_mu1, prep.pool_mu2,
        **prep.tr_kw,
    )
    eq = _point_equilibrium(prep)
    return _finish_report(prep, eq, sh_tr, transient,
                          _onsets(sh_tr, transient))


def _report_group_key(prep: _PreparedReport) -> Optional[tuple]:
    """Points whose fluid solves can stack into one batched call share a
    key: same window grid / shard count (operand shapes), same window
    duration, and same structural solver config (k, flow convention, retry
    policy, spill, μ(Q) hook). None = solve this point on the scalar path
    (piecewise / idle-degenerate reports)."""
    if prep.mode != "fluid":
        return None
    return (
        np.shape(prep.lam_sw), prep.duration, prep.spec.k_servers,
        prep.spec.flow, prep.tr_kw.get("retry"),
        bool(prep.tr_kw.get("tier1_spill", False)),
        prep.tr_kw.get("mu_load"),
    )


def _take_fluid(rep: FluidReport, i: int) -> FluidReport:
    """Slice point ``i`` out of a batched FluidReport (every array field
    carries the point axis first; None diagnostics stay None)."""
    return FluidReport(*(None if v is None else np.asarray(v)[i]
                         for v in rep))


def batched_reports(
    items: Sequence, *, solver: str = "batched", _prof: Optional[dict] = None,
) -> list[SimReport]:
    """Reports for many ``(spec, counters[, tenant_counters])`` points with
    the fluid transient solves *batched*: compatible points' windowed rates
    stack into one ``[point, shard, window]`` tensor solved by a single
    jitted ``lax.scan`` (:func:`repro.core.queuing.fluid_two_tier_batched`
    — one compile per structural config, counted by
    :func:`repro.core.queuing.fluid_compile_count`), the stationary
    equilibrium solves run as two ``[point, shard]`` array calls per group,
    and the saturation/metastability onset scans vectorize over the point
    axis. Report assembly happens host-side from the batched outputs.

    ``solver="scalar"`` runs the same prepare/finish pipeline with the
    per-point numpy solver — the reference path (and the baseline the
    report-stage benchmark compares against). Piecewise-mode points
    (``transient_mode="piecewise"`` or idle streams) always take the
    scalar path.

    Batched and scalar solves agree to ~1e-13 on the analytic ``k = 1``
    path (~1e-9 for the ``k > 1`` bisection). Regrouping points into
    different batches perturbs results by at most a few ulp (XLA re-fuses
    the kernel per batch shape); a fixed grouping is deterministic.

    ``_prof`` (internal, used by ``sweep(profile=True)``): a dict that
    accumulates ``report_solve`` / ``assembly`` stage seconds.
    """
    if solver not in ("batched", "scalar"):
        raise ValueError(
            f"solver must be 'batched' or 'scalar', got {solver!r}")
    preps = []
    for item in items:
        spec, ctr = item[0], item[1]
        tenants = item[2] if len(item) > 2 else None
        preps.append(_prepare_report(spec, ctr, tenants))

    groups: dict[Optional[tuple], list[int]] = {}
    for i, prep in enumerate(preps):
        key = _report_group_key(prep) if solver == "batched" else None
        groups.setdefault(key, []).append(i)

    solve_s = 0.0
    asm_s = 0.0
    reports: list = [None] * len(preps)
    for key, idxs in groups.items():
        if key is None:
            for i in idxs:
                prep = preps[i]
                t0 = perf_counter()
                sh_tr = transient_two_tier(
                    prep.lam_sw, prep.p12_sw, prep.sh_mu1, prep.sh_mu2,
                    **prep.tr_kw)
                transient = transient_two_tier(
                    prep.pool_lam, prep.pool_p12, prep.pool_mu1,
                    prep.pool_mu2, **prep.tr_kw)
                eq = _point_equilibrium(prep)
                t1 = perf_counter()
                reports[i] = _finish_report(prep, eq, sh_tr, transient,
                                            _onsets(sh_tr, transient))
                t2 = perf_counter()
                solve_s += t1 - t0
                asm_s += t2 - t1
            continue

        group = [preps[i] for i in idxs]
        p0 = group[0]
        full = np.shape(p0.lam_sw)          # [S, W]
        t0 = perf_counter()
        kw = {k: v for k, v in p0.tr_kw.items() if k not in ("mode", "dt")}
        # Stacked per-shard solve: [P, S, W].
        sh_tr_b = fluid_two_tier_batched(
            np.stack([p.lam_sw for p in group]),
            np.stack([p.p12_sw for p in group]),
            np.stack([np.broadcast_to(p.sh_mu1, full) for p in group]),
            np.stack([np.broadcast_to(p.sh_mu2, full) for p in group]),
            dt=p0.duration, **kw)
        # Stacked pooled solve: [P, W].
        tr_b = fluid_two_tier_batched(
            np.stack([p.pool_lam for p in group]),
            np.stack([p.pool_p12 for p in group]),
            np.stack([np.broadcast_to(np.asarray(p.pool_mu1, float),
                                      full[-1:]) for p in group]),
            np.stack([np.broadcast_to(np.asarray(p.pool_mu2, float),
                                      full[-1:]) for p in group]),
            dt=p0.duration, **kw)
        # Onset scans once over the whole stack (satellite of the batched
        # pipeline: these used to re-run per report).
        sh_onsets_b = np.asarray(sh_tr_b.onset())            # [P, S]
        pooled_onset_b = np.asarray(tr_b.onset())            # [P]
        sh_meta_b = (np.asarray(sh_tr_b.metastable_onset())
                     if sh_tr_b.metastable is not None else None)
        pooled_meta_b = (np.asarray(tr_b.metastable_onset())
                         if tr_b.metastable is not None else None)
        # Stationary solves for the whole group: [P, S] + [P].
        eq_b = _solve_equilibrium(
            np.stack([np.full(p.spec.n_shards, p.spec.lam, float)
                      for p in group]),
            np.stack([p.mu1_v for p in group]),
            np.stack([p.mu2_v for p in group]),
            np.stack([p.p12_sh for p in group]),
            np.asarray([p.spec.lam for p in group], float),
            np.asarray([p.rates.mu1 for p in group], float),
            np.asarray([p.rates.mu2 for p in group], float),
            np.asarray([p.p12 for p in group], float),
            k=p0.spec.k_servers, flow=p0.spec.flow,
        )
        t1 = perf_counter()
        for j, i in enumerate(idxs):
            onset_j = int(pooled_onset_b[j])
            meta_j = (int(pooled_meta_b[j])
                      if pooled_meta_b is not None else -1)
            reports[i] = _finish_report(
                preps[i], _Equilibrium(*(np.asarray(f)[j] for f in eq_b)),
                _take_fluid(sh_tr_b, j), _take_fluid(tr_b, j),
                (sh_onsets_b[j],
                 sh_meta_b[j] if sh_meta_b is not None else None,
                 onset_j if onset_j >= 0 else None,
                 meta_j if meta_j >= 0 else None),
            )
        t2 = perf_counter()
        solve_s += t1 - t0
        asm_s += t2 - t1
    if _prof is not None:
        _prof["report_solve"] = _prof.get("report_solve", 0.0) + solve_s
        _prof["assembly"] = _prof.get("assembly", 0.0) + asm_s
    return reports


def simulate(spec: SimSpec, trace=None) -> SimReport:
    """The end-to-end model: workload -> distributed tier 1 -> queuing.

    ``tenant_mix`` workloads (no trace override) route through the chunked
    streaming replay (:func:`repro.sim.stream.simulate_stream`) — counters
    are bit-identical to the one-shot engine by construction (the tenant
    merge is chunk-invariant), and the report gains per-tenant
    :class:`TenantReport` attribution the one-shot path cannot produce."""
    if spec.traffic.kind == "tenant_mix" and trace is None:
        from repro.sim.stream import simulate_stream
        return simulate_stream(spec)
    return report_from_counters(spec, tier1_counters(spec, trace))
