"""End-to-end two-tier simulator: traffic -> tier-1 shards -> queuing.

This is the composition the paper's §V builds by hand for one worked
example, as a subsystem: :func:`simulate` generates (or accepts) a request
stream, pushes it through the distributed tier-1 cache engine
(:func:`repro.storage.tiered_store.run_distributed`), converts the
resulting counters into queuing-network inputs (λ, p12, μ1, μ2), and
reports per-shard and aggregate latency / throughput / utilization plus
the minimum-time model (eqs. 1-4).

The counters -> queuing mapping:

=====================  ====================================================
counter                queuing-network input
=====================  ====================================================
``misses/requests``    p12, the tier-2 branch probability (per shard and
                       pooled; ``SimSpec.p12_override`` pins it instead)
``requests - writes``  n_read_i in eq. 1 (hit service at μ1_read)
``writes``             n_write_i in eq. 1 (hit service at μ1_write)
``misses``             n_miss_i in eq. 2 (miss service at μ2)
``tier2_reads/writes`` reported as device traffic (prefetch fetches and
                       dirty write-backs ride the same IO thread)
=====================  ====================================================

Service rates come from :class:`repro.sim.spec.RateSpec` (fitted device
models or the §V paper constants).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import numpy as np

from repro.core.mapping import page_to_shard
from repro.core.queuing import ServiceTimes, TwoTierModel, service_time_model
from repro.core.traffic import make_stream
from repro.sim.spec import ResolvedRates, SimSpec
from repro.storage.tiered_store import correct_padded_stats, run_distributed
import jax.numpy as jnp

__all__ = ["Tier1Counters", "ShardReport", "SimReport", "tier1_counters",
           "report_from_counters", "simulate"]


class Tier1Counters(NamedTuple):
    """Per-shard int64 counter arrays measured by the tier-1 engine."""

    requests: np.ndarray
    reads: np.ndarray
    writes: np.ndarray
    hits: np.ndarray
    misses: np.ndarray
    prefetch_hits: np.ndarray
    tier2_reads: np.ndarray
    tier2_writes: np.ndarray
    evictions: np.ndarray


@dataclasses.dataclass(frozen=True)
class ShardReport:
    """One tier-1 shard: measured counters + its queuing-network solution."""

    shard: int
    requests: int
    reads: int
    writes: int
    hits: int
    misses: int
    prefetch_hits: int
    tier2_reads: int
    tier2_writes: int
    evictions: int
    p12: float           # miss fraction used by the queue model
    lam_eff: float       # effective arrival rate at the k-server queue
    rho1: float          # tier-1 offered load (a = lam_eff/mu1)
    rho2: float          # tier-2 utilization
    w1: float            # tier-1 residence time (s)
    w2: float            # tier-2 residence time (s)
    response_s: float    # expected response: w1 + p12 * w2
    equilibrium: bool

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class SimReport:
    """Aggregate + per-shard results for one :class:`SimSpec` scenario."""

    spec: SimSpec
    rates: ResolvedRates
    shards: tuple
    # aggregate counters
    requests: int
    hits: int
    misses: int
    prefetch_hits: int
    tier2_reads: int
    tier2_writes: int
    evictions: int
    miss_rate: float        # measured: misses / requests
    p12: float              # miss fraction used by the queue model
    # aggregate queuing network (pooled p12, per-process λ)
    lam_eff: float
    rho1: float
    rho2: float
    w1: float
    w2: float
    response_s: float       # expected response time: w1 + p12 * w2
    mu_system: float        # eq. 5 composed service rate
    rho_system: float
    equilibrium: bool
    throughput_rps: float   # equilibrium throughput across all shards
    # minimum-time model (eqs. 1-4)
    min_time: ServiceTimes
    t_total_s: float        # eq. 4: max over shards
    min_time_throughput_rps: float  # total requests / t_total

    def to_dict(self) -> dict:
        d = {
            f.name: getattr(self, f.name)
            for f in dataclasses.fields(self)
            if f.name not in ("spec", "rates", "shards", "min_time")
        }
        d["rates"] = dataclasses.asdict(self.rates)
        d["spec"] = {
            "traffic": dataclasses.asdict(self.spec.traffic),
            "store": dataclasses.asdict(self.spec.store),
            "n_shards": self.spec.n_shards,
            "mapping": self.spec.mapping,
            "lam": self.spec.lam,
            "k_servers": self.spec.k_servers,
            "flow": self.spec.flow,
            "p12_override": self.spec.p12_override,
        }
        d["min_time"] = {
            "t_hit": [float(v) for v in np.atleast_1d(self.min_time.t_hit)],
            "t_miss": [float(v) for v in np.atleast_1d(self.min_time.t_miss)],
            "t_proc": [float(v) for v in np.atleast_1d(self.min_time.t_proc)],
            "t_total": float(self.min_time.t_total),
        }
        d["shards"] = [s.to_dict() for s in self.shards]
        return d


def sim_n_pages(spec: SimSpec, pages: np.ndarray) -> int:
    """Page-space size for the §III mapping: the declared traffic page
    space, widened if the stream outgrew it (IRM page ids are unbounded —
    expired pages are replaced by fresh ids)."""
    return max(spec.traffic.n_pages, int(pages.max()) + 1)


def tier1_counters(spec: SimSpec, trace=None) -> Tier1Counters:
    """Run the workload through the distributed tier-1 cache
    (:func:`repro.storage.tiered_store.run_distributed`) and return exact
    per-shard counters. ``trace`` overrides the generated stream with a
    user-provided ``(pages, is_write)`` pair (mapped over its own observed
    page space)."""
    if trace is not None:
        pages, is_write = np.asarray(trace[0]), np.asarray(trace[1], bool)
        n_pages = int(pages.max()) + 1
    else:
        pages, is_write = make_stream(spec.traffic)
        n_pages = sim_n_pages(spec, pages)
    stats, counts = run_distributed(
        spec.store, pages, is_write,
        n_shards=spec.n_shards, mapping=spec.mapping, n_pages=n_pages,
    )
    owner = np.asarray(
        page_to_shard(jnp.asarray(pages), spec.n_shards, n_pages, spec.mapping)
    )
    writes = np.bincount(owner[is_write], minlength=spec.n_shards)
    return _assemble_counters(stats, counts, writes)


def _assemble_counters(corrected_stats, counts, writes) -> Tier1Counters:
    """Build :class:`Tier1Counters` from padding-corrected StreamStats."""
    counts = np.asarray(counts, np.int64)
    s = corrected_stats
    return Tier1Counters(
        requests=counts,
        reads=counts - np.asarray(writes, np.int64),
        writes=np.asarray(writes, np.int64),
        hits=np.asarray(s.hits, np.int64),
        misses=np.asarray(s.misses, np.int64),
        prefetch_hits=np.asarray(s.prefetch_hits, np.int64),
        tier2_reads=np.asarray(s.tier2_reads, np.int64),
        tier2_writes=np.asarray(s.tier2_writes, np.int64),
        evictions=np.asarray(s.evictions, np.int64),
    )


def counters_from_stats(stats, counts, writes, *, cap: int) -> Tier1Counters:
    """Assemble :class:`Tier1Counters` from *padded* per-shard StreamStats
    (the sweep engine's batched path), delegating the padding/phantom-miss
    correction to :func:`repro.storage.tiered_store.correct_padded_stats`."""
    return _assemble_counters(
        correct_padded_stats(stats, counts, cap), counts, writes
    )


def _response(w1: float, w2: float, p12: float) -> float:
    """Expected response time w1 + p12*w2, avoiding inf*0 -> nan when the
    tier-1 queue saturates while p12 = 0."""
    return float(w1 + (p12 * w2 if p12 > 0.0 else 0.0))


def _queue_summary(spec: SimSpec, rates: ResolvedRates, p12: float):
    model = TwoTierModel(
        lam=spec.lam,
        mu1=rates.mu1,
        mu2=rates.mu2,
        p12=p12,
        k=spec.k_servers,
        flow=spec.flow,  # type: ignore[arg-type]
    )
    rep = model.analyze()
    s = rep.summary()
    w1 = s["W1"] + 1.0 / rates.mu1          # waiting + service at tier 1
    w2 = s["W2"] + 1.0 / rates.mu2          # waiting + service at tier 2
    if not rep.equilibrium:
        w1 = w2 = float("inf")
    return rep, s, w1, w2


def report_from_counters(spec: SimSpec, ctr: Tier1Counters) -> SimReport:
    """Solve the queuing network for measured counters (no traffic rerun).

    Per-shard service-rate heterogeneity (``RateSpec.mu1_shards`` /
    ``mu2_shards``, the paper's Tables VII–IX strong-scaling sweeps) is
    honored here: each shard's queue is solved at its own μ1/μ2 and the
    minimum-time model (eqs. 1–4) uses the per-shard rate vectors; the
    aggregate/pooled queue uses the scalar (mean) rates.
    """
    rates = spec.rates.resolve()
    # (mu*_shards length vs n_shards is enforced by SimSpec.__post_init__.)

    shard_reports = []
    for i in range(spec.n_shards):
        req = int(ctr.requests[i])
        p12 = (
            spec.p12_override
            if spec.p12_override is not None
            else (int(ctr.misses[i]) / req if req else 0.0)
        )
        rep, s, w1, w2 = _queue_summary(spec, rates.for_shard(i), p12)
        shard_reports.append(ShardReport(
            shard=i,
            requests=req,
            reads=int(ctr.reads[i]),
            writes=int(ctr.writes[i]),
            hits=int(ctr.hits[i]),
            misses=int(ctr.misses[i]),
            prefetch_hits=int(ctr.prefetch_hits[i]),
            tier2_reads=int(ctr.tier2_reads[i]),
            tier2_writes=int(ctr.tier2_writes[i]),
            evictions=int(ctr.evictions[i]),
            p12=float(p12),
            lam_eff=float(s["lam_eff"]),
            rho1=float(s["rho1"]),
            rho2=float(s["rho2"]),
            w1=float(w1),
            w2=float(w2),
            response_s=_response(w1, w2, p12),
            equilibrium=bool(rep.equilibrium),
        ))

    total_req = int(ctr.requests.sum())
    total_miss = int(ctr.misses.sum())
    miss_rate = total_miss / total_req if total_req else 0.0
    p12 = spec.p12_override if spec.p12_override is not None else miss_rate
    rep, s, w1, w2 = _queue_summary(spec, rates, p12)

    # Minimum-time model (eqs. 1-4) over the per-shard counters: eq. 1 at
    # the read/write device rates, eq. 2 at the miss rate, eq. 4 = max.
    # Heterogeneous rate specs feed per-shard μ vectors into eqs. 1-2.
    mu1_read_v, mu1_write_v, mu2_v = rates.shard_vectors(spec.n_shards)
    mt = service_time_model(
        ctr.reads, ctr.writes, ctr.misses, mu1_read_v, mu1_write_v, mu2_v,
    )
    t_total = float(mt.t_total)

    equilibrium = bool(rep.equilibrium) and all(
        sr.equilibrium for sr in shard_reports
    )
    return SimReport(
        spec=spec,
        rates=rates,
        shards=tuple(shard_reports),
        requests=total_req,
        hits=int(ctr.hits.sum()),
        misses=total_miss,
        prefetch_hits=int(ctr.prefetch_hits.sum()),
        tier2_reads=int(ctr.tier2_reads.sum()),
        tier2_writes=int(ctr.tier2_writes.sum()),
        evictions=int(ctr.evictions.sum()),
        miss_rate=float(miss_rate),
        p12=float(p12),
        lam_eff=float(s["lam_eff"]),
        rho1=float(s["rho1"]),
        rho2=float(s["rho2"]),
        w1=float(w1),
        w2=float(w2),
        response_s=_response(w1, w2, p12),
        mu_system=float(s["mu_system"]),
        rho_system=float(s["rho_system"]),
        equilibrium=equilibrium,
        throughput_rps=float(spec.lam * spec.n_shards) if equilibrium
        else float(s["mu_system"]) * spec.n_shards,
        min_time=mt,
        t_total_s=t_total,
        min_time_throughput_rps=total_req / t_total if t_total > 0 else 0.0,
    )


def simulate(spec: SimSpec, trace=None) -> SimReport:
    """The end-to-end model: workload -> distributed tier 1 -> queuing."""
    return report_from_counters(spec, tier1_counters(spec, trace))
