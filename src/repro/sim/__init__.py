"""End-to-end tiered-storage simulator (paper §V composed end to end).

``simulate(SimSpec)`` runs workload -> distributed tier-1 cache -> queuing
network -> report; ``sweep()`` evaluates grids of scenarios with shared
cache runs batched under vmap. This is the integration surface for new
device models, replacement policies and traffic generators.
"""
from repro.sim.engine import (  # noqa: F401
    ShardReport,
    SimReport,
    TenantCounters,
    TenantReport,
    Tier1Counters,
    WindowSeries,
    batched_reports,
    report_from_counters,
    simulate,
    tier1_counters,
)
from repro.sim.mrc import (  # noqa: F401
    mrc_curve,
    mrc_tier1_counters,
    mrc_unsupported_reason,
)
from repro.sim.spec import (  # noqa: F401
    PAPER_MU1,
    PAPER_MU2,
    FaultEvent,
    FaultSpec,
    RateSpec,
    ResolvedRates,
    RetryPolicy,
    SimSpec,
    device_degrade,
    shard_down,
    tier2_outage,
)
from repro.sim.stream import (  # noqa: F401
    StreamCheckpoint,
    simulate_stream,
    stream_tier1_counters,
)
from repro.sim.sweep import (  # noqa: F401
    SweepResult,
    engine_compile_count,
    expand_grid,
    fluid_compile_count,
    reset_engine_compile_count,
    reset_fluid_compile_count,
    sweep,
)

__all__ = [
    "SimSpec", "RateSpec", "ResolvedRates", "PAPER_MU1", "PAPER_MU2",
    "FaultSpec", "FaultEvent", "RetryPolicy",
    "shard_down", "device_degrade", "tier2_outage",
    "SimReport", "ShardReport", "Tier1Counters", "WindowSeries",
    "TenantCounters", "TenantReport",
    "simulate", "tier1_counters", "report_from_counters", "batched_reports",
    "simulate_stream", "stream_tier1_counters", "StreamCheckpoint",
    "sweep", "expand_grid", "SweepResult",
    "engine_compile_count", "reset_engine_compile_count",
    "fluid_compile_count", "reset_fluid_compile_count",
    "mrc_curve", "mrc_tier1_counters", "mrc_unsupported_reason",
]
