"""Chunked streaming trace replay: bounded memory, one compile, bit-exact.

The one-shot path (:func:`repro.sim.engine.tier1_counters`) materializes
the whole request stream, partitions it, and pushes ``[n_shards, n]``
device buffers through one scan — peak device memory grows linearly with
trace length, and a multi-million-request replay either thrashes or OOMs.
This module replays the same workload in fixed-size *chunks* through the
resumable chunk engine
(:func:`repro.storage.tiered_store.stream_chunk_engine`):

- **Bounded memory.** Only one chunk's ``[n_shards, cap]`` buffers plus
  the carried ``(StoreState, accumulators)`` live on device at a time;
  the carry and chunk buffers are *donated* (``jit(...,
  donate_argnums=...)``) so every chunk reuses the previous chunk's
  allocations. Peak device memory is independent of trace length.
- **One compile (two shapes max).** Chunks land in one of exactly two
  per-shard length buckets — a primary bucket sized for balanced shard
  loads and a fallback sized for the worst skew — so an arbitrarily long
  replay compiles the engine at most twice
  (:func:`repro.storage.tiered_store.stream_compile_count` observes this).
- **Overlap.** The engine call dispatches asynchronously: host-side
  generation, window binning and partitioning of chunk ``k+1`` overlap
  device compute of chunk ``k`` (double buffering — the ``device_put``
  of the next chunk happens while the previous one is still running).
- **Bit-exact.** Chunk-boundary requests straddle window edges, bucket
  pads and fault events freely: pads carry the dropped window id and are
  *masked no-ops* in the chunk engine (state untouched, zero counter
  contribution), so every counter — whole-stream, windowed, faulted —
  equals the one-shot engine's exactly, for every chunk size.
- **Resume.** :class:`StreamCheckpoint` snapshots everything the replay
  carries (cache state, windowed accumulators, expert weights, traffic
  generator state, fluid backlog) as host data; a later process resumes
  bit-exactly mid-stream.

**Multi-tenant attribution.** ``tenant_mix`` traffic
(:func:`repro.core.traffic.tenant_mix`) is generated chunk-by-chunk on the
host (:class:`repro.core.traffic.TenantStream` — never materialized
whole), and per-tenant windowed counters cost no extra engine pass: the
windowed scatter runs over composite ``window * n_tenants + tenant`` ids,
and the host collapses the composite axis back into per-window totals
(sum over tenants) plus per-tenant series (sum over shards).
"""
from __future__ import annotations

import dataclasses
from time import perf_counter
from typing import Optional

import numpy as np

import jax

from repro.core.queuing import transient_two_tier
from repro.core.traffic import TenantStream
from repro.sim.engine import (
    SimReport,
    TenantCounters,
    Tier1Counters,
    _assemble_counters,
    fault_owner,
    report_from_counters,
    stream_for_spec,
)
from repro.sim.spec import SimSpec
from repro.storage.tiered_store import (
    init_stream_carry,
    partition_streams,
    stream_chunk_engine,
    stream_stats_from_carry,
    stream_window_ids,
    timestamp_window_ids,
)

__all__ = [
    "DEFAULT_CHUNK",
    "StreamCheckpoint",
    "stream_tier1_counters",
    "simulate_stream",
]

# Default requests per chunk. Large enough that per-chunk dispatch overhead
# amortizes, small enough that one chunk's device buffers stay modest.
DEFAULT_CHUNK = 1 << 18

# Floor of the primary per-shard length bucket (balanced-load sizing).
MIN_CAP = 512


def _next_pow2(n: int) -> int:
    cap = 1
    while cap < n:
        cap <<= 1
    return cap


def _chunk_caps(chunk: int, n_shards: int) -> tuple[int, int]:
    """The two per-shard length buckets every chunk of a replay lands in.

    The primary bucket assumes roughly balanced shard loads (2x headroom
    over ``chunk / n_shards``); a chunk whose worst shard overflows it —
    pathological mapping skew — takes the fallback bucket, which fits any
    chunk (one shard owning everything). Two buckets → at most two XLA
    compiles per replay, no matter how many chunks stream through."""
    fallback = _next_pow2(max(chunk, 1))
    primary = min(_next_pow2(max(MIN_CAP, -(-2 * chunk // n_shards))),
                  fallback)
    return primary, fallback


@dataclasses.dataclass
class StreamCheckpoint:
    """Everything a chunked replay carries between chunks, as host data.

    Snapshot of a replay frontier: the per-shard cache/learner state and
    windowed accumulators (``carry`` — numpy copies of the chunk-engine
    carry, safe to pickle), the consumed-request offset and per-shard
    tallies, the traffic generator's mid-stream state (``tenant_state``,
    tenant workloads only), the host-tracked last-tenant table behind
    windowed expert-weight attribution, and the pooled fluid backlog
    ``fluid_q0 = (q1, q2)`` at the frontier — the ``q0`` a continuation
    transient solve resumes from. Resuming validates ``signature`` (the
    spec's :meth:`~repro.sim.spec.SimSpec.cache_signature`) plus the
    stream's length and page space, so a checkpoint cannot silently
    continue a different workload."""

    signature: tuple
    offset: int                  # requests consumed so far
    total: int                   # total requests of the stream
    counts: np.ndarray           # [n_shards] real requests per shard
    shard_writes: np.ndarray     # [n_shards] writes per shard
    carry: object                # host-numpy (StoreState, _Accum) pytree
    n_pages: int
    n_windows: int               # plain window count W (not composite)
    n_tenants: int               # 0 = single-tenant replay
    tenant_state: Optional[dict] = None
    last_tenant: Optional[np.ndarray] = None   # [n_shards, W], -1 = empty
    fluid_q0: Optional[tuple] = None           # (q1, q2) at the frontier

    @property
    def done(self) -> bool:
        return self.offset >= self.total


def _validate_resume(ck: StreamCheckpoint, signature: tuple, total: int,
                     n_pages: int, n_windows: int, n_tenants: int) -> None:
    if ck.signature != signature:
        raise ValueError(
            "StreamCheckpoint does not match this spec (cache_signature "
            "differs) — a checkpoint resumes only the workload it snapshot")
    if (ck.total, ck.n_pages, ck.n_windows, ck.n_tenants) != (
            total, n_pages, n_windows, n_tenants):
        raise ValueError(
            "StreamCheckpoint stream layout mismatch: checkpoint has "
            f"(total={ck.total}, n_pages={ck.n_pages}, "
            f"n_windows={ck.n_windows}, n_tenants={ck.n_tenants}), replay "
            f"has ({total}, {n_pages}, {n_windows}, {n_tenants})")


def stream_tier1_counters(
    spec: SimSpec,
    trace=None,
    *,
    chunk: int = DEFAULT_CHUNK,
    unroll: int = 1,
    checkpoint: Optional[StreamCheckpoint] = None,
    max_requests: Optional[int] = None,
    donate: bool = True,
    engine: str = "fused",
    profile: Optional[dict] = None,
):
    """Chunked-replay counterpart of :func:`repro.sim.engine.tier1_counters`.

    Returns ``(counters, tenant_counters, checkpoint)``:
    :class:`Tier1Counters` bit-identical to the one-shot engine's for the
    consumed prefix, :class:`TenantCounters` for ``tenant_mix`` workloads
    (``None`` otherwise), and the :class:`StreamCheckpoint` at the final
    frontier (``checkpoint.done`` when the stream is exhausted).

    ``tenant_mix`` specs are generated chunk-by-chunk on the host; any
    other spec (or an explicit ``trace``) is materialized host-side once
    (exactly the one-shot stream) and *fed* in chunks — device memory
    stays bounded either way. ``checkpoint`` resumes a prior partial run;
    ``max_requests`` bounds how many further requests this call consumes
    (``None`` = run to the end). ``donate=False`` disables buffer donation
    and async overlap — the naive baseline the benchmarks compare
    against. ``engine`` selects the fused cache-scan request loop
    (default) or the original ``"scan"`` reference (bit-exact either way).

    ``profile`` (a mutable dict) accumulates per-chunk wall-clock
    sub-timings: ``stream_chunk_host`` (generation + binning +
    partitioning), ``stream_chunk_dispatch`` (device_put + async engine
    submission), ``stream_chunk_wait`` (blocking materialization of the
    final carry; per-chunk blocking too when ``donate=False``) and
    ``stream_chunks`` (chunk count)."""
    if chunk < 1:
        raise ValueError("chunk must be >= 1")
    prof = profile
    n_shards = spec.n_shards
    signature = spec.cache_signature()
    tenant = spec.traffic.kind == "tenant_mix" and trace is None
    if tenant:
        gen = TenantStream(spec.traffic)
        n_tenants = gen.n_tenants
        total = spec.traffic.n_requests
        n_pages = spec.traffic.n_pages   # tenant key ranges are bounded
        n_windows, window_dt = spec.window_grid()
        pages = is_write = times = owner_all = gwin_all = None
    else:
        gen = None
        n_tenants = 0
        pages, is_write, times, n_pages, n_windows, window_dt = (
            stream_for_spec(spec, trace))
        total = int(pages.shape[0])
        # Whole-stream host precompute, identical to the one-shot path:
        # window binning (float64) and the fault-schedule owner remap are
        # global maps, so chunking cannot perturb them.
        if window_dt is not None:
            gwin_all = timestamp_window_ids(times, n_windows, window_dt)
        else:
            gwin_all = stream_window_ids(total, n_windows)
        owner_all = fault_owner(spec, pages, times, n_pages)
    # Composite window ids interleave the tenant axis into the engine's
    # windowed scatter: id = window * n_tenants + tenant. The engine runs
    # at W * n_tenants windows; the host collapses the axis afterwards.
    eng_windows = n_windows * max(n_tenants, 1)

    if checkpoint is not None:
        _validate_resume(checkpoint, signature, total, n_pages, n_windows,
                         n_tenants)
        offset = int(checkpoint.offset)
        counts = np.asarray(checkpoint.counts, np.int64).copy()
        shard_writes = np.asarray(checkpoint.shard_writes, np.int64).copy()
        carry = jax.device_put(checkpoint.carry)
        last_tenant = (np.asarray(checkpoint.last_tenant, np.int32).copy()
                       if tenant else None)
        if tenant:
            gen.restore(checkpoint.tenant_state)
    else:
        offset = 0
        counts = np.zeros(n_shards, np.int64)
        shard_writes = np.zeros(n_shards, np.int64)
        carry = init_stream_carry(spec.store, n_shards,
                                  n_windows=eng_windows)
        last_tenant = (np.full((n_shards, n_windows), -1, np.int32)
                       if tenant else None)

    stop = total if max_requests is None else min(total,
                                                  offset + int(max_requests))
    primary, fallback = _chunk_caps(chunk, n_shards)
    eng = stream_chunk_engine(spec.store, unroll=unroll,
                              n_windows=eng_windows, donate=donate,
                              engine=engine)
    hyper = spec.store.hyper()

    while offset < stop:
        tc0 = perf_counter()
        m = min(chunk, stop - offset)
        if tenant:
            p, w, t, tids = gen.take(m)
            own = fault_owner(spec, p, t, n_pages)
            if window_dt is not None:
                win = timestamp_window_ids(t, n_windows, window_dt)
            else:
                g = offset + np.arange(m, dtype=np.int64)
                win = ((g * n_windows) // total).astype(np.int32)
            # Last tenant per (shard, window): duplicate fancy-index
            # assignment keeps the final occurrence — exactly "the tenant
            # of this shard's last request in this window so far".
            last_tenant[own, win] = tids
            cwin = win * n_tenants + tids
        else:
            sl = slice(offset, offset + m)
            p, w = pages[sl], is_write[sl]
            own, cwin = owner_all[sl], gwin_all[sl]
        cnt = np.bincount(own, minlength=n_shards)
        cap = primary if int(cnt.max()) <= primary else fallback
        sh_p, sh_w, cnt, _, sh_win = partition_streams(
            p, w, n_shards=n_shards, mapping=spec.mapping, n_pages=n_pages,
            cap=cap, n_windows=eng_windows, window_ids=cwin, owner=own)
        counts += cnt
        shard_writes += np.bincount(own[w], minlength=n_shards)
        tc1 = perf_counter()
        # Async pipeline: device_put + dispatch return before the chunk
        # finishes computing, so the next iteration's host work (generate,
        # bin, partition) overlaps device compute. donate=False is the
        # deliberately-synchronous naive baseline.
        dev = jax.device_put((sh_p, sh_w, sh_win))
        carry = eng(hyper, carry, *dev)
        tc2 = perf_counter()
        if not donate:
            jax.block_until_ready(carry)
        offset += m
        if prof is not None:
            prof["stream_chunk_host"] = (
                prof.get("stream_chunk_host", 0.0) + (tc1 - tc0))
            prof["stream_chunk_dispatch"] = (
                prof.get("stream_chunk_dispatch", 0.0) + (tc2 - tc1))
            prof["stream_chunk_wait"] = (
                prof.get("stream_chunk_wait", 0.0)
                + (perf_counter() - tc2))
            prof["stream_chunks"] = prof.get("stream_chunks", 0) + 1

    # Materialize the carry on the host once: the numpy copies survive the
    # next resume's donation, feed the counter assembly below, and make
    # the checkpoint picklable.
    tw0 = perf_counter()
    carry_host = jax.tree.map(np.asarray, carry)
    if prof is not None:
        prof["stream_chunk_wait"] = (
            prof.get("stream_chunk_wait", 0.0) + (perf_counter() - tw0))
    stats = stream_stats_from_carry(carry_host, counts)

    tenant_ctr = None
    if tenant:
        def collapse(a):
            a = np.asarray(a)
            return a.reshape(n_shards, n_windows, n_tenants,
                             *a.shape[2:]).sum(axis=2)

        # Windowed expert weights: the engine snapshot lives per composite
        # sub-window; the plain window's snapshot is the one at the shard's
        # last request in the window, i.e. the last-tenant sub-window.
        ww = np.asarray(stats.win_weights)
        wwr = ww.reshape(n_shards, n_windows, n_tenants, ww.shape[-1])
        sel = np.maximum(last_tenant, 0)[:, :, None, None]
        w_sel = np.take_along_axis(wwr, sel, axis=2)[:, :, 0, :]
        w_sel = np.where((last_tenant >= 0)[:, :, None], w_sel, 0.0)
        per_tw = np.asarray(stats.win_requests).reshape(
            n_shards, n_windows, n_tenants)
        tenant_ctr = TenantCounters(
            names=tuple(t.name for t in spec.traffic.tenants),
            win_requests=per_tw.sum(axis=0).T,
            win_hits=np.asarray(stats.win_hits).reshape(
                n_shards, n_windows, n_tenants).sum(axis=0).T,
            win_misses=np.asarray(stats.win_misses).reshape(
                n_shards, n_windows, n_tenants).sum(axis=0).T,
        )
        stats = stats._replace(
            win_requests=collapse(stats.win_requests),
            win_hits=collapse(stats.win_hits),
            win_misses=collapse(stats.win_misses),
            win_prefetch_hits=collapse(stats.win_prefetch_hits),
            win_tier2_reads=collapse(stats.win_tier2_reads),
            win_tier2_writes=collapse(stats.win_tier2_writes),
            win_evictions=collapse(stats.win_evictions),
            win_expert_use=collapse(stats.win_expert_use),
            win_weights=w_sel,
        )
    # Masked pads never touched the accumulators, so no padding correction
    # applies — _assemble_counters consumes the stats as-is.
    ctr = _assemble_counters(stats, counts, shard_writes)

    ck = StreamCheckpoint(
        signature=signature,
        offset=offset,
        total=total,
        counts=counts.copy(),
        shard_writes=shard_writes.copy(),
        carry=carry_host,
        n_pages=n_pages,
        n_windows=n_windows,
        n_tenants=n_tenants,
        tenant_state=gen.state() if tenant else None,
        last_tenant=last_tenant.copy() if tenant else None,
    )
    return ctr, tenant_ctr, ck


def _frontier_fluid_q0(spec: SimSpec, rep: SimReport) -> Optional[tuple]:
    """Pooled fluid backlog ``(q1, q2)`` at the consumed frontier of a
    partial replay: the fluid solve re-run over the non-empty prefix of
    the window grid (the report's own solve includes the trailing not-yet-
    streamed windows, which drain the backlog as if the stream had gone
    idle). Healthy service rates — a continuation solve under a fault
    schedule should re-solve from the counters instead."""
    if spec.transient_mode != "fluid" or rep.window_duration_s <= 0:
        return None
    pooled = np.asarray(rep.windows.requests).sum(axis=0)
    nz = np.nonzero(pooled)[0]
    if nz.size == 0:
        return None
    hi = int(nz[-1]) + 1
    rates = spec.rates.resolve()
    tr = rep.transient
    sol = transient_two_tier(
        np.asarray(tr.lam)[:hi], np.asarray(tr.p12)[:hi],
        rates.mu1, rates.mu2, k=spec.k_servers, flow=spec.flow,
        mode="fluid", dt=rep.window_duration_s, mu_load=rates.mu_load,
    )
    return (np.asarray(sol.q1_end), np.asarray(sol.q2_end))


def simulate_stream(
    spec: SimSpec,
    trace=None,
    *,
    chunk: int = DEFAULT_CHUNK,
    unroll: int = 1,
    checkpoint: Optional[StreamCheckpoint] = None,
    max_requests: Optional[int] = None,
    donate: bool = True,
    engine: str = "fused",
    profile: Optional[dict] = None,
):
    """Streaming counterpart of :func:`repro.sim.engine.simulate`.

    Replays the workload in bounded-memory chunks
    (:func:`stream_tier1_counters`) and solves the queuing network on the
    streamed counters. The resulting :class:`SimReport` is bit-identical
    to ``simulate(spec)``'s for every counter and windowed series, at a
    peak device footprint independent of trace length; ``tenant_mix``
    workloads additionally carry per-tenant
    :class:`~repro.sim.engine.TenantReport` attribution.

    With ``max_requests`` set the call returns ``(report, checkpoint)``:
    the report covers the consumed prefix (untouched windows are idle) and
    the checkpoint — including the pooled fluid backlog at the frontier —
    resumes the replay bit-exactly via ``checkpoint=``. Without it the
    call runs to the end of the stream and returns the report alone."""
    ctr, tenant_ctr, ck = stream_tier1_counters(
        spec, trace, chunk=chunk, unroll=unroll, checkpoint=checkpoint,
        max_requests=max_requests, donate=donate, engine=engine,
        profile=profile)
    rep = report_from_counters(spec, ctr, tenants=tenant_ctr)
    if max_requests is None:
        return rep
    ck.fluid_q0 = _frontier_fluid_q0(spec, rep)
    return rep, ck
