"""Sweep engine: evaluate a grid of scenarios with shared work batched.

``sweep(base, axes)`` expands a cartesian grid of dotted-path overrides
over a base :class:`SimSpec` (e.g. ``{"store.n_lines": [16, 64, 256],
"n_shards": [2, 4], "store.policy": ["ws", "lru"]}``) and returns one
:class:`SimReport` per point.

Four levels of work sharing make wide sweeps cheap:

1. **Cache-run dedup** — points that differ only in queuing-side knobs
   (λ, k, flow, rates, p12_override) share a
   :meth:`SimSpec.cache_signature`; the expensive tier-1 counter
   simulation runs once per signature.
2. **Megabatch vmap** — signatures whose *structural* engine is identical
   (same ``StoreConfig.static_config()``, shard count, mapping) stack into
   one ``[point, shard, len]`` batch processed by a single triply-batched
   ``run_stream`` call. The scalar learning knobs (``alpha``, ``beta``,
   ``threshold`` and the policy selector) ride along as **traced**
   :class:`~repro.storage.tiered_store.StoreHyper` operands on the point
   axis, so a whole hyperparameter/policy grid compiles the engine **once**
   instead of once per combination.
3. **Bucketed padding** — each point is padded to the next power-of-two
   length bucket of *its own* max shard load (floor :data:`MIN_BUCKET`)
   rather than the group-wide max, so short streams stop paying for the
   longest one; buckets dispatch as separate stacked calls.
4. **Device sharding + async dispatch** — the point axis of every stacked
   call is sharded across all local devices (``shard_map`` via the
   :mod:`repro.launch.compat` shims) and calls are dispatched
   asynchronously: host-side traffic generation, padding and queuing
   solves for later groups overlap device compute for earlier ones.

Windowed telemetry (``SimSpec.n_windows``) rides the same batch: window
ids are a data operand next to the stream (pads carry the dropped
out-of-range id), so the ``[point, shard, n_windows]`` counters add no
compiles beyond the structural split on ``n_windows`` itself. Wall-clock
windows (``SimSpec.window_dt``) ride the *same* operand: arrival times
are binned host-side in float64 (:func:`timestamp_window_ids`) and the
resulting int32 ids stack next to the stream, so timestamped grids share
one compiled engine with request-index grids of the same window count —
and long-horizon traces bin exactly (no f32 drift in the scan).

Compiles of the batched engine are observable via
:func:`engine_compile_count` (a trace-time counter used by
``benchmarks/bench_sweep.py`` to gate compile-cache behavior).

**Miss-rate-curve routing** (``mrc=`` keyword): ``store.n_lines`` is
*structural* — every cache size costs a fresh engine compile and a fresh
pass over the stream. When a grid axis varies only the cache size and the
spec sits inside the exact stack-distance domain (LRU, no prefetch — see
:func:`repro.sim.mrc.mrc_unsupported_reason`), the whole size axis is
served by :func:`repro.sim.mrc.mrc_tier1_counters` instead: one distance
pass, zero engine compiles, counters bit-identical to the scan engine.
``mrc="auto"`` (default) routes eligible multi-size groups and falls back
to the engine with a logged reason otherwise; ``"off"`` disables the
path; ``"require"`` raises ``ValueError`` if any group cannot be routed
(the compile-budget guard for capacity-planning sweeps).

**Streaming routing** (``stream=`` keyword): the megabatch stacks whole
traces on device, so a grid point with a multi-million-request stream
(or a ``tenant_mix`` workload, whose per-tenant attribution only the
streaming path produces) is better served by the chunked replay engine
(:mod:`repro.sim.stream`): bounded device memory, at most two compiles,
counters bit-identical to the scan. ``stream="auto"`` (default) routes
``tenant_mix`` signatures and streams longer than
:data:`STREAM_THRESHOLD` requests; ``"off"`` forces everything through
the megabatch.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import logging
import warnings
from time import perf_counter
from typing import Callable, Mapping, NamedTuple, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

from repro.core.queuing import fluid_compile_count, reset_fluid_compile_count
from repro.core.traffic import make_stream, make_timed_stream
from repro.launch.compat import device_mesh, shard_map
from repro.sim.engine import (
    SimReport,
    TenantCounters,
    Tier1Counters,
    batched_reports,
    counters_from_stats,
    fault_owner,
    report_from_counters,
    sim_n_pages,
    tier1_counters,
)
from repro.sim.mrc import mrc_tier1_counters, mrc_unsupported_reason
from repro.sim.stream import stream_tier1_counters
from repro.sim.spec import SimSpec
from repro.storage.tiered_store import (
    StoreConfig,
    StoreHyper,
    partition_streams,
    run_stream,
    timestamp_window_ids,
)

__all__ = [
    "expand_grid",
    "sweep",
    "SweepResult",
    "engine_compile_count",
    "reset_engine_compile_count",
    "fluid_compile_count",
    "reset_fluid_compile_count",
]

log = logging.getLogger(__name__)

# Smallest padded stream-length bucket; lengths round up to powers of two so
# ragged groups land in a handful of shapes instead of one shape per point.
MIN_BUCKET = 16
# Streams longer than this route through the chunked replay engine under
# stream="auto": stacking them whole on device stops paying off before the
# megabatch's compile sharing does.
STREAM_THRESHOLD = 1 << 20
# Default lax.scan unroll for the batched engine (semantics-preserving).
DEFAULT_UNROLL = 4

# The batched engine is cached per (static store, unroll, n_devices); the
# counter increments at trace time, i.e. exactly once per XLA compile.
_ENGINE_CACHE: dict[tuple, Callable] = {}
_ENGINE_COMPILES = [0]


def engine_compile_count() -> int:
    """Number of XLA compiles of the batched sweep engine so far."""
    return _ENGINE_COMPILES[0]


def reset_engine_compile_count() -> None:
    _ENGINE_COMPILES[0] = 0


def expand_grid(axes: Mapping[str, Sequence]) -> list[dict]:
    """Cartesian product of ``{dotted.path: values}`` into override dicts."""
    if not axes:
        return [{}]
    keys = list(axes)
    return [
        dict(zip(keys, combo))
        for combo in itertools.product(*(axes[k] for k in keys))
    ]


@dataclasses.dataclass(frozen=True)
class SweepResult:
    base: SimSpec
    axes: dict
    points: tuple          # override dict per point
    reports: tuple         # SimReport per point
    # sweep(profile=True): per-stage wall-clock seconds — stream_gen
    # (host-side traffic generation + partitioning for the megabatch),
    # engine_dispatch (device engine calls + gather, plus the routed
    # stream/MRC/unbatched paths), report_solve (queuing-network solves),
    # assembly (SimReport construction) and total.
    profile: Optional[dict] = None

    def rows(self) -> list[dict]:
        """One flat dict per point: the overrides + aggregate metrics."""
        out = []
        for pt, rep in zip(self.points, self.reports):
            d = rep.to_dict()
            d.pop("shards")
            d.pop("spec")
            out.append({**{str(k): v for k, v in pt.items()}, **d})
        return out

    def to_json(self, path: Optional[str] = None) -> str:
        payload = {
            "axes": {k: list(v) for k, v in self.axes.items()},
            "n_points": len(self.points),
            "points": [
                {**{str(k): v for k, v in pt.items()}, **rep.to_dict()}
                for pt, rep in zip(self.points, self.reports)
            ],
        }
        if self.profile is not None:
            payload["profile"] = dict(self.profile)
        text = json.dumps(payload, indent=2, default=_jsonify)
        if path is not None:
            with open(path, "w") as f:
                f.write(text)
        return text


def _jsonify(obj):
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, np.generic):  # any numpy scalar, incl. np.bool_
        return obj.item()
    raise TypeError(f"not JSON serializable: {type(obj)!r}")


def _batch_key(spec: SimSpec) -> tuple:
    """Signatures with equal batch keys share one compiled engine: only the
    *structural* store config splits groups — the scalar learning knobs
    (alpha/beta/threshold/policy) are traced operands and stack instead.
    The window count shapes the accumulator arrays, so it is structural
    too — but window ids are data (wall-clock specs bin their arrival
    times host-side into the same int32 operand), so one compile serves
    any window layout, timed or not."""
    n_windows, _ = spec.window_grid()
    return (spec.store.static_config(), spec.n_shards, spec.mapping,
            n_windows)


def _mrc_group_key(spec: SimSpec) -> tuple:
    """Signatures equal after erasing ``store.n_lines`` form one MRC group:
    they share the stream, partition, faults and window layout and differ
    only in cache size — exactly the axis one stack-distance pass covers."""
    return spec.replace(**{"store.n_lines": 1}).cache_signature()


def _route_mrc(
    unique: Mapping[tuple, SimSpec], mrc: str
) -> dict[tuple, Tier1Counters]:
    """Serve every eligible size-only signature group via the one-pass MRC
    engine. Returns ``{signature: counters}`` for the routed signatures
    (bit-identical to the scan engine); the caller runs the rest through
    the batched engine. ``mrc="require"`` raises if any group is
    ineligible; ``"auto"`` routes only groups with >= 2 sizes (a single
    size gains nothing over the engine)."""
    groups: dict[tuple, list[tuple]] = {}
    for sig, spec in unique.items():
        groups.setdefault(_mrc_group_key(spec), []).append(sig)

    counters: dict[tuple, Tier1Counters] = {}
    for sigs in groups.values():
        rep = unique[sigs[0]]
        reason = mrc_unsupported_reason(rep)
        if reason is not None:
            if mrc == "require":
                raise ValueError(
                    "mrc='require' but the MRC path cannot serve this "
                    f"grid: {reason}"
                )
            if len(sigs) >= 2:
                log.info(
                    "sweep: MRC fallback to scan engine for %d sizes (%s)",
                    len(sigs), reason,
                )
            continue
        if len(sigs) < 2 and mrc != "require":
            continue
        sizes = sorted(unique[s].store.n_lines for s in sigs)
        log.info(
            "sweep: MRC route — %d cache sizes from one distance pass "
            "(policy=lru, n_shards=%d)",
            len(sizes), rep.n_shards,
        )
        by_size = mrc_tier1_counters(rep, sizes)
        for s in sigs:
            counters[s] = by_size[int(unique[s].store.n_lines)]
    return counters


def _route_stream(
    unique: Mapping[tuple, SimSpec], stream: str, *,
    engine: str = "fused", profile: Optional[dict] = None,
) -> tuple[dict[tuple, Tier1Counters], dict[tuple, TenantCounters]]:
    """Serve ``tenant_mix`` and oversized-stream signatures via the chunked
    replay engine (:mod:`repro.sim.stream`): bounded device memory, at most
    two compiles, counters bit-identical to the scan engine. Returns
    ``({signature: counters}, {signature: tenant_counters})`` for the
    routed signatures; the caller runs the rest through the megabatch.
    ``profile`` threads per-chunk sub-timings through to
    :func:`repro.sim.stream.stream_tier1_counters`."""
    counters: dict[tuple, Tier1Counters] = {}
    tenants: dict[tuple, TenantCounters] = {}
    if stream == "off":
        return counters, tenants
    for sig, spec in unique.items():
        mix = spec.traffic.kind == "tenant_mix"
        if not (mix or spec.traffic.n_requests > STREAM_THRESHOLD):
            continue
        log.info(
            "sweep: stream route — %s, %d requests (chunked replay)",
            "tenant_mix" if mix else "oversized stream",
            spec.traffic.n_requests,
        )
        ctr, tc, _ = stream_tier1_counters(spec, engine=engine,
                                           profile=profile)
        counters[sig] = ctr
        if tc is not None:
            tenants[sig] = tc
    return counters, tenants


def _bucket_cap(n: int) -> int:
    """Next power-of-two length bucket (floor MIN_BUCKET) for a shard load."""
    cap = MIN_BUCKET
    while cap < n:
        cap <<= 1
    return cap


def _stack_hypers(stores: Sequence[StoreConfig]) -> StoreHyper:
    """Concrete [N]-leaf StoreHyper stack for a list of store configs."""
    hypers = [s.hyper() for s in stores]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *hypers)


def _batched_engine(
    store: StoreConfig, unroll: int, n_dev: int, n_windows: int,
    engine: str = "fused", donate: bool = True,
) -> Callable:
    """The one-compile megabatch engine for a structural store config:
    ``(hyper [N], pages [N, S, L], writes [N, S, L], win [N, S, L]) ->
    StreamStats [N, S]`` (windowed counters ``[N, S, n_windows]``), point
    axis sharded over all local devices. Wall-clock specs feed the same
    ``win`` operand (arrival times become int32 ids host-side), so timed
    and request-index grids share this one engine. Cached so repeated
    sweeps reuse both the wrapper and jit's compile cache.

    ``engine`` selects the request-loop implementation (see
    :func:`repro.storage.tiered_store.run_stream`); ``donate=True``
    donates the three stacked chunk buffers to the dispatch
    (``donate_argnums``) so XLA may recycle their allocations while the
    engine runs — ``donate=False`` keeps the undonated baseline
    available (buffers stay valid after the call)."""
    key = (store, unroll, n_dev, n_windows, engine, donate)
    fn = _ENGINE_CACHE.get(key)
    if fn is not None:
        return fn

    def body(hyper, sh_pages, sh_writes, sh_win):
        _ENGINE_COMPILES[0] += 1  # trace-time: once per XLA compile

        def point(h, p, w, wi):
            return jax.vmap(
                lambda pp, ww, wwi: run_stream(
                    store, pp, ww, hyper=h, unroll=unroll,
                    n_windows=n_windows, window_ids=wwi, engine=engine,
                )
            )(p, w, wi)

        return jax.vmap(point)(hyper, sh_pages, sh_writes, sh_win)
    n_in = 4

    if n_dev > 1:
        spec = PartitionSpec("points")
        jfn = jax.jit(shard_map(
            body,
            mesh=device_mesh("points"),
            in_specs=(spec,) * n_in,
            out_specs=spec,
            check_vma=True,
        ), donate_argnums=(1, 2, 3) if donate else ())
    else:
        jfn = jax.jit(body, donate_argnums=(1, 2, 3) if donate else ())

    if donate:
        # The stacked stream operands have no same-shape output to alias
        # (the StreamStats counters are tiny), so XLA can only free them
        # early, not reuse them — intended; silence just that warning.
        def fn(*args):
            with warnings.catch_warnings():
                warnings.filterwarnings(
                    "ignore",
                    message="Some donated buffers were not usable")
                return jfn(*args)
    else:
        fn = jfn
    _ENGINE_CACHE[key] = fn
    return fn


class _Member(NamedTuple):
    """One unique cache signature prepared for stacking."""

    bucket: int          # power-of-two padded length for this point
    sig: tuple           # cache signature
    spec: SimSpec
    sh_pages: np.ndarray  # [S, own_cap] partitioned stream
    sh_writes: np.ndarray
    sh_win: np.ndarray   # [S, own_cap] window ids (n_windows = pad/drop);
                         # timed specs pre-bin arrival times into these
    counts: np.ndarray   # per-shard real request counts
    shard_writes: np.ndarray  # per-shard write counts


@dataclasses.dataclass
class _PendingBucket:
    """One dispatched stacked engine call awaiting materialization."""

    sigs: list           # cache signature per real point
    counts: list         # per-point per-shard real request counts
    writes: list         # per-point per-shard write counts
    cap: int             # padded stream length (bucket)
    stats: object        # StreamStats of device arrays (async futures)

    def gather(self) -> dict:
        stacked = jax.tree.map(np.asarray, self.stats)  # blocks on device
        out = {}
        for i, sig in enumerate(self.sigs):
            stats_i = jax.tree.map(lambda a: a[i], stacked)
            out[sig] = counters_from_stats(
                stats_i, self.counts[i], self.writes[i], cap=self.cap
            )
        return out


def _dispatch_group(
    specs: list[SimSpec], sigs: list, *, unroll: int,
    engine: str = "fused", donate: bool = True,
    _prof: Optional[dict] = None,
) -> list[_PendingBucket]:
    """Partition, bucket, pad and asynchronously dispatch every unique cache
    signature of one batch-key group. Returns pending buckets; device compute
    proceeds while the caller prepares and dispatches later groups.
    ``_prof`` accumulates ``stream_gen`` / ``engine_dispatch`` seconds
    (submission side — see ``engine_dispatch_submit``)."""
    store_static = specs[0].store.static_config()
    n_shards = specs[0].n_shards
    n_windows, window_dt0 = specs[0].window_grid()
    timed = window_dt0 is not None
    n_dev = jax.local_device_count()

    t0 = perf_counter()
    members = []
    for spec, sig in zip(specs, sigs):
        n_windows_i, window_dt = spec.window_grid()
        assert n_windows_i == n_windows  # grouped by batch key
        if timed:
            pages, is_write, times = make_timed_stream(
                spec.traffic, default_rate=spec.agg_rate())
            n_pages_i = sim_n_pages(spec, pages)
            # Fault schedules ride the megabatch as *data*: the failover
            # remap happens host-side and only reshuffles the owner
            # operand, so a fault grid shares one compiled engine.
            own = fault_owner(spec, pages, times, n_pages_i)
            # Bin arrival times host-side (float64) into the same int32
            # window-id operand the index path uses — one engine, exact
            # long-horizon binning.
            gwin = timestamp_window_ids(times, n_windows, window_dt)
            sh_p, sh_w, counts, owner, sh_tw = partition_streams(
                pages, is_write, n_shards=n_shards, mapping=spec.mapping,
                n_pages=n_pages_i, n_windows=n_windows, window_ids=gwin,
                owner=own,
            )
        else:
            pages, is_write = make_stream(spec.traffic)
            sh_p, sh_w, counts, owner, sh_tw = partition_streams(
                pages, is_write, n_shards=n_shards, mapping=spec.mapping,
                n_pages=sim_n_pages(spec, pages), n_windows=n_windows,
            )
        members.append(_Member(
            bucket=_bucket_cap(sh_p.shape[1]),
            sig=sig,
            spec=spec,
            sh_pages=sh_p,
            sh_writes=sh_w,
            sh_win=sh_tw,
            counts=counts,
            shard_writes=np.bincount(owner[is_write], minlength=n_shards),
        ))

    t1 = perf_counter()
    if _prof is not None:
        _prof["stream_gen"] = _prof.get("stream_gen", 0.0) + (t1 - t0)

    buckets: dict[int, list[_Member]] = {}
    for m in members:
        buckets.setdefault(m.bucket, []).append(m)

    pending = []
    for cap, group in sorted(buckets.items()):
        n = len(group)
        n_pad = -(-n // n_dev) * n_dev  # point axis must split over devices
        sh_pages = np.zeros((n_pad, n_shards, cap), np.int32)
        sh_writes = np.zeros((n_pad, n_shards, cap), bool)
        # Bucket-extension positions are padding: window id n_windows
        # drops them from the windowed counters (so windowed telemetry is
        # bit-identical across bucket choices).
        sh_win = np.full((n_pad, n_shards, cap), n_windows, np.int32)
        for i, m in enumerate(group):
            w = m.sh_pages.shape[1]
            # Rows come pre-padded with their shard's last page; extending
            # that edge-repeat keeps the padding a pure-hit stream.
            sh_pages[i, :, :w] = m.sh_pages
            sh_pages[i, :, w:] = m.sh_pages[:, -1:]
            sh_writes[i, :, :w] = m.sh_writes
            sh_win[i, :, :w] = m.sh_win
        sh_pages[n:] = sh_pages[0]  # padded points: discarded after gather
        sh_writes[n:] = sh_writes[0]

        stores = [m.spec.store for m in group]
        stores += [stores[0]] * (n_pad - n)
        hyper = _stack_hypers(stores)

        eng = _batched_engine(store_static, unroll, n_dev, n_windows,
                              engine, donate)
        log.info(
            "sweep: dispatch %d points x %d shards @ len %d "
            "(n_lines=%d, windows=%d, timed=%s, devices=%d)",
            n, n_shards, cap, store_static.n_lines, n_windows, timed, n_dev,
        )
        stats = eng(hyper, jnp.asarray(sh_pages),
                    jnp.asarray(sh_writes), jnp.asarray(sh_win))
        pending.append(_PendingBucket(
            sigs=[m.sig for m in group],
            counts=[m.counts for m in group],
            writes=[m.shard_writes for m in group],
            cap=cap,
            stats=stats,
        ))
    if _prof is not None:
        # Submission side of the engine stage: tracing + host→device
        # transfer of the stacked operands (the calls are async — device
        # compute is still in flight when this returns). The wait side
        # (device compute + gather transfer) lands on
        # ``engine_dispatch_wait``; ``engine_dispatch`` stays their sum.
        dt = perf_counter() - t1
        _prof["engine_dispatch"] = _prof.get("engine_dispatch", 0.0) + dt
        _prof["engine_dispatch_submit"] = (
            _prof.get("engine_dispatch_submit", 0.0) + dt)
    return pending


def sweep(
    base: SimSpec,
    axes,
    *,
    batch: bool = True,
    unroll: int = DEFAULT_UNROLL,
    mrc: str = "auto",
    stream: str = "auto",
    report: str = "auto",
    engine: str = "fused",
    donate: bool = True,
    profile: bool = False,
    verbose: bool = False,
) -> SweepResult:
    """Evaluate ``base`` at every point of the ``axes`` grid.

    ``axes`` is either a ``{dotted.path: values}`` mapping (expanded to
    its cartesian grid) or an explicit sequence of override dicts — the
    capacity planner's path for sweeping a hand-picked candidate set in
    one batched call.

    ``batch=True`` runs the megabatched one-compile engine (see module
    docstring); ``batch=False`` simulates every signature independently
    (reference path, bit-identical counters). ``unroll`` chunks the
    per-request scan of the batched engine.

    ``mrc`` controls miss-rate-curve routing of cache-size axes (see
    module docstring): ``"auto"`` serves eligible size-only groups from
    one stack-distance pass, ``"off"`` always scans, ``"require"`` raises
    ``ValueError`` when the MRC path cannot serve the grid (incompatible
    with ``batch=False``, whose purpose is the reference scan).

    ``stream`` controls chunked-replay routing (see module docstring):
    ``"auto"`` serves ``tenant_mix`` signatures (adding per-tenant
    attribution to their reports) and streams past
    :data:`STREAM_THRESHOLD` requests via :mod:`repro.sim.stream`;
    ``"off"`` forces the megabatch.

    ``report`` picks the report-stage solver
    (:func:`repro.sim.engine.batched_reports`): ``"batched"`` stacks every
    fluid-mode point's windowed rates into one ``[point, shard, window]``
    jitted solve (one compile per structural config —
    :func:`fluid_compile_count`); ``"scalar"`` solves per point with the
    numpy reference loop — bit-identical ``SimReport`` JSON to the
    pre-batching per-point path; ``"auto"`` follows ``batch``. Batched and
    scalar reports agree to ~1e-13 (analytic k=1 path).

    ``engine`` selects the tier-1 request-loop implementation
    (:func:`repro.storage.tiered_store.run_stream`): ``"fused"`` (default)
    is the fused cache-scan engine, ``"scan"`` the original per-step
    reference it is bit-exact against. ``donate=True`` donates the stacked
    stream buffers to each megabatch dispatch (``donate_argnums``);
    ``donate=False`` keeps the undonated baseline.

    ``profile=True`` attaches a per-stage wall-clock breakdown (stream
    gen / engine dispatch / report solve / assembly, seconds) to
    :attr:`SweepResult.profile`, serialized by ``to_json``. The engine
    stage is split into ``engine_dispatch_submit`` (host-side tracing +
    transfer of async dispatches) and ``engine_dispatch_wait``
    (device compute + gather back to host); ``engine_dispatch`` is their
    sum, with the routed stream/MRC/unbatched paths' cost included
    (chunked streaming additionally reports per-chunk
    ``stream_chunk_host`` / ``stream_chunk_dispatch`` /
    ``stream_chunk_wait`` timings).
    """
    if mrc not in ("auto", "off", "require"):
        raise ValueError(
            f"mrc must be 'auto', 'off' or 'require', got {mrc!r}")
    if stream not in ("auto", "off"):
        raise ValueError(f"stream must be 'auto' or 'off', got {stream!r}")
    if report not in ("auto", "batched", "scalar"):
        raise ValueError(
            f"report must be 'auto', 'batched' or 'scalar', got {report!r}")
    if mrc == "require" and not batch:
        raise ValueError(
            "mrc='require' is incompatible with batch=False: the unbatched "
            "path exists as the scan-engine reference")
    if verbose:
        # Convenience for interactive use: make this module's INFO progress
        # lines visible regardless of how (or whether) the app configured
        # logging. verbose=False leaves logging config entirely to the app.
        log.setLevel(logging.INFO)
        if not (log.handlers or logging.getLogger().handlers):
            logging.basicConfig(level=logging.INFO)
    if isinstance(axes, Mapping):
        axes_dict = dict(axes)
        points = expand_grid(axes)
    else:
        axes_dict = {}
        points = [dict(pt) for pt in axes]
    specs = [base.replace(**pt) for pt in points]
    solver = ("batched" if batch else "scalar") if report == "auto" else report
    prof: Optional[dict] = (
        {"stream_gen": 0.0, "engine_dispatch": 0.0,
         "engine_dispatch_submit": 0.0, "engine_dispatch_wait": 0.0,
         "report_solve": 0.0, "assembly": 0.0}
        if profile else None
    )
    t_start = perf_counter()

    # One cache run per unique signature.
    sig_of = [spec.cache_signature() for spec in specs]
    unique: dict[tuple, SimSpec] = {}
    for spec, sig in zip(specs, sig_of):
        unique.setdefault(sig, spec)

    counters: dict[tuple, Tier1Counters] = {}
    tenant_ctrs: dict[tuple, TenantCounters] = {}
    t0 = perf_counter()
    if batch:
        counters, tenant_ctrs = _route_stream(unique, stream,
                                              engine=engine, profile=prof)
    if batch and mrc != "off":
        counters.update(_route_mrc(
            {s: sp for s, sp in unique.items() if s not in counters}, mrc))
    if prof is not None:
        # The routed paths generate their streams internally; their whole
        # cost lands on engine_dispatch.
        prof["engine_dispatch"] += perf_counter() - t0
    if batch:
        groups: dict[tuple, list[tuple]] = {}
        for sig, spec in unique.items():
            if sig in counters:  # already served by the MRC path
                continue
            groups.setdefault(_batch_key(spec), []).append(sig)
        # Dispatch everything first (async), then gather: traffic generation
        # and padding for group k+1 overlap device compute for group k, and
        # the queuing solves below overlap the tail of device compute.
        pending: list[_PendingBucket] = []
        for key, sigs in groups.items():
            log.info(
                "sweep: batch group n_shards=%d, %d signatures "
                "(n_lines=%d, mapping=%s)",
                key[1], len(sigs), key[0].n_lines, key[2],
            )
            pending.extend(
                _dispatch_group([unique[s] for s in sigs], sigs,
                                unroll=unroll, engine=engine,
                                donate=donate, _prof=prof)
            )
        t0 = perf_counter()
        for bucket in pending:
            counters.update(bucket.gather())
        if prof is not None:
            # Gather blocks on device compute: this is the wait side of
            # the engine stage (device compute + device→host transfer).
            dt = perf_counter() - t0
            prof["engine_dispatch"] += dt
            prof["engine_dispatch_wait"] += dt
    else:
        t0 = perf_counter()
        for sig, spec in unique.items():
            log.info("sweep: run %s", sig)
            counters[sig] = tier1_counters(spec, engine=engine)
        if prof is not None:
            prof["engine_dispatch"] += perf_counter() - t0

    reports = batched_reports(
        [(spec, counters[sig], tenant_ctrs.get(sig))
         for spec, sig in zip(specs, sig_of)],
        solver=solver, _prof=prof,
    )
    if prof is not None:
        prof["total"] = perf_counter() - t_start
        prof["n_points"] = len(points)
        prof["report_solver"] = solver
    return SweepResult(
        base=base,
        axes=axes_dict,
        points=tuple(points),
        reports=tuple(reports),
        profile=prof,
    )
