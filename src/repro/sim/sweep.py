"""Sweep engine: evaluate a grid of scenarios with shared work batched.

``sweep(base, axes)`` expands a cartesian grid of dotted-path overrides
over a base :class:`SimSpec` (e.g. ``{"store.n_lines": [16, 64, 256],
"n_shards": [2, 4], "store.policy": ["ws", "lru"]}``) and returns one
:class:`SimReport` per point.

Two levels of work sharing make wide sweeps cheap:

1. **Cache-run dedup** — points that differ only in queuing-side knobs
   (λ, k, flow, rates, p12_override) share a
   :meth:`SimSpec.cache_signature`; the expensive tier-1 counter
   simulation runs once per signature.
2. **vmap batching** — signatures whose jitted engine is identical (same
   ``StoreConfig``, shard count, mapping) differ only in stream *data*, so
   their padded per-shard streams stack into one ``[point, shard, len]``
   batch processed by a single doubly-vmapped ``run_stream`` call (one
   compile instead of one per point). Traffic generation (host-side numpy)
   and queuing solves run host-side per point.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
from typing import Mapping, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.traffic import make_stream
from repro.sim.engine import (
    SimReport,
    Tier1Counters,
    counters_from_stats,
    report_from_counters,
    sim_n_pages,
    tier1_counters,
)
from repro.sim.spec import SimSpec
from repro.storage.tiered_store import partition_streams, run_stream

__all__ = ["expand_grid", "sweep", "SweepResult"]


def expand_grid(axes: Mapping[str, Sequence]) -> list[dict]:
    """Cartesian product of ``{dotted.path: values}`` into override dicts."""
    if not axes:
        return [{}]
    keys = list(axes)
    return [
        dict(zip(keys, combo))
        for combo in itertools.product(*(axes[k] for k in keys))
    ]


@dataclasses.dataclass(frozen=True)
class SweepResult:
    base: SimSpec
    axes: dict
    points: tuple          # override dict per point
    reports: tuple         # SimReport per point

    def rows(self) -> list[dict]:
        """One flat dict per point: the overrides + aggregate metrics."""
        out = []
        for pt, rep in zip(self.points, self.reports):
            d = rep.to_dict()
            d.pop("shards")
            d.pop("spec")
            out.append({**{str(k): v for k, v in pt.items()}, **d})
        return out

    def to_json(self, path: Optional[str] = None) -> str:
        payload = {
            "axes": {k: list(v) for k, v in self.axes.items()},
            "n_points": len(self.points),
            "points": [
                {**{str(k): v for k, v in pt.items()}, **rep.to_dict()}
                for pt, rep in zip(self.points, self.reports)
            ],
        }
        text = json.dumps(payload, indent=2, default=_jsonify)
        if path is not None:
            with open(path, "w") as f:
                f.write(text)
        return text


def _jsonify(obj):
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    raise TypeError(f"not JSON serializable: {type(obj)!r}")


def _batch_key(spec: SimSpec) -> tuple:
    """Signatures with equal batch keys share one jitted engine."""
    return (spec.store, spec.n_shards, spec.mapping)


def _run_signature_group(specs: list[SimSpec]) -> list[Tier1Counters]:
    """Run every unique cache signature in ``specs`` (all sharing a batch
    key) as one stacked vmap over (point, shard)."""
    store, n_shards = specs[0].store, specs[0].n_shards
    partitioned = []
    for spec in specs:
        pages, is_write = make_stream(spec.traffic)
        sh_p, sh_w, counts, owner = partition_streams(
            pages, is_write, n_shards=n_shards, mapping=spec.mapping,
            n_pages=sim_n_pages(spec, pages),
        )
        partitioned.append((sh_p, sh_w, counts, owner, is_write))

    # Widen every point to the group's max shard load so the stack is
    # regular. Each row is already padded with its shard's last page, so
    # edge-repeating that column keeps the padding a pure-hit stream.
    cap = max(p[0].shape[1] for p in partitioned)
    sh_pages = np.zeros((len(specs), n_shards, cap), np.int32)
    sh_writes = np.zeros((len(specs), n_shards, cap), bool)
    for i, (sh_p, sh_w, _, _, _) in enumerate(partitioned):
        w = sh_p.shape[1]
        sh_pages[i, :, :w] = sh_p
        sh_pages[i, :, w:] = sh_p[:, -1:]
        sh_writes[i, :, :w] = sh_w

    run = jax.vmap(jax.vmap(lambda p, w: run_stream(store, p, w)))
    stacked = run(jnp.asarray(sh_pages), jnp.asarray(sh_writes))
    stacked = jax.tree.map(np.asarray, stacked)

    out = []
    for i, (_, _, counts, owner, is_write) in enumerate(partitioned):
        stats_i = jax.tree.map(lambda a: a[i], stacked)
        writes = np.bincount(owner[is_write], minlength=n_shards)
        out.append(counters_from_stats(stats_i, counts, writes, cap=cap))
    return out


def sweep(
    base: SimSpec,
    axes: Mapping[str, Sequence],
    *,
    batch: bool = True,
    verbose: bool = False,
) -> SweepResult:
    """Evaluate ``base`` at every point of the ``axes`` grid."""
    points = expand_grid(axes)
    specs = [base.replace(**pt) for pt in points]

    # One cache run per unique signature.
    sig_of = [spec.cache_signature() for spec in specs]
    unique: dict[tuple, SimSpec] = {}
    for spec, sig in zip(specs, sig_of):
        unique.setdefault(sig, spec)

    counters: dict[tuple, Tier1Counters] = {}
    if batch:
        groups: dict[tuple, list[tuple]] = {}
        for sig, spec in unique.items():
            groups.setdefault(_batch_key(spec), []).append(sig)
        for key, sigs in groups.items():
            if verbose:
                print(f"sweep: batch {key[1]}x{len(sigs)} signatures "
                      f"(policy={key[0].policy}, n_lines={key[0].n_lines})")
            group_specs = [unique[s] for s in sigs]
            for sig, ctr in zip(sigs, _run_signature_group(group_specs)):
                counters[sig] = ctr
    else:
        for sig, spec in unique.items():
            if verbose:
                print(f"sweep: run {sig}")
            counters[sig] = tier1_counters(spec)

    reports = [
        report_from_counters(spec, counters[sig])
        for spec, sig in zip(specs, sig_of)
    ]
    return SweepResult(
        base=base,
        axes=dict(axes),
        points=tuple(points),
        reports=tuple(reports),
    )
