"""Two-tier storage substrate: tier-1 cache engine, tier-2 simulator, and
the paged pools used by serving (KV) and training (data shards).
"""
from repro.storage import cache_state, tier2, tiered_store  # noqa: F401
