"""Tier-1 cache state (paper §III).

Each cache line carries ``index, tag, valid and dirty bits`` plus the
``frequency counter and timestamp fields`` used by the eviction experts.
The paper stores cache *states* in CPU memory and *data* on NVMe; here the
state is a pure pytree (all decisions are derivable from it, as required by
the low-overhead experts of §III-A) and data lives in a separate page pool
(see :mod:`repro.storage.kvpool` / :mod:`repro.storage.datacache`).

The cache is demand-driven, fully-associative, write-back, single-copy
(no replication => no coherency protocol), exactly as in the paper.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

__all__ = ["CacheState", "init_cache", "lookup"]


class CacheState(NamedTuple):
    """Fully-associative tier-1 cache metadata (one shard)."""

    tags: jnp.ndarray   # int32[N] page number per line; -1 = empty
    valid: jnp.ndarray  # bool[N]
    dirty: jnp.ndarray  # bool[N]
    freq: jnp.ndarray   # int32[N]  LFU frequency counter
    ts: jnp.ndarray     # int32[N]  LRU last-access timestamp

    @property
    def n_lines(self) -> int:
        return self.tags.shape[-1]


def init_cache(n_lines: int) -> CacheState:
    return CacheState(
        tags=jnp.full((n_lines,), -1, dtype=jnp.int32),
        valid=jnp.zeros((n_lines,), dtype=bool),
        dirty=jnp.zeros((n_lines,), dtype=bool),
        freq=jnp.zeros((n_lines,), dtype=jnp.int32),
        ts=jnp.zeros((n_lines,), dtype=jnp.int32),
    )


def lookup(cache: CacheState, page: jnp.ndarray):
    """Fully-associative lookup. Returns ``(hit, line_idx)``.

    ``line_idx`` is arbitrary when ``hit`` is False.
    """
    match = cache.valid & (cache.tags == page)
    hit = jnp.any(match)
    idx = jnp.argmax(match).astype(jnp.int32)
    return hit, idx
