"""The two-tier storage engine (paper §III), as a jitted ``lax.scan``.

Semantics per request (page, is_write), faithful to the paper:

1. **Lookup** in the fully-associative tier-1 cache. A hit updates the
   timestamp (LRU), frequency counter (LFU) and dirty bit.
2. A **miss** first probes the prefetch buffer; a buffered page is promoted
   to the cache without a tier-2 access. Otherwise the page is fetched from
   tier 2 (one tier-2 read).
3. Insertion uses a free line if one exists; otherwise **GetVictim**
   (Algorithm 1) selects the eviction expert by probability, every expert's
   proposal is recorded in its prediction vector, and the chosen victim is
   evicted (a dirty victim costs one tier-2 write-back).
4. The **stream identifier** observes the miss stream and issues prefetches
   into free buffer slots ("page misses are prioritized over prefetches").
5. Every ``epoch_width`` iterations, **WeightAdjust** (Algorithm 2) runs and
   prediction vectors are cleared.

The engine is branchless (computed-both-paths + select) so it vmaps across
distributed cache shards (paper's per-process caches). Tier-2 is counted
here (reads / write-backs); converting counts to time is the queuing and
device-model layer (:mod:`repro.core.queuing`, :mod:`repro.core.device_models`).

**Windowed telemetry.** The scan folds every per-request outcome into
``n_windows`` accumulator slots carried through the loop (scatter-add by the
request's time-window id) instead of materializing ``[T]`` per-request
outputs — memory is O(n_windows), not O(stream length), on the megabatch
sweep path. A request's window is either its **wall-clock time bin**
(``timestamps``/``window_dt`` operands: bin = ``t // window_dt``, clipped
into the last bin — per-window arrival rates are then *measured*, not flat
by construction) or, on the historic request-index path, its *global*
stream position ``g`` mapped to ``g * n_windows // T``. Padding positions
carry the out-of-range id ``n_windows`` (timestamp ``-1`` on the timed
path) and are dropped by the scatter, so windowed counters count real
requests only and are bit-identical across padding/bucketing choices.
Whole-stream counters are still accumulated separately (pads included,
corrected by :func:`correct_padded_stats` exactly as before), so windowed
totals reconcile exactly: ``win_*.sum(-1)`` equals every corrected counter.
The windowed accumulators also resolve the online learner over time:
``win_expert_use`` counts evictions per expert per window and
``win_weights`` snapshots the expert weights at each window's last real
request (zeros where a window saw none), so adaptation at phase boundaries
is observable.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import online_learning as ol
from repro.core import prefetch as pfm
from repro.core.mapping import page_to_shard
from repro.kernels.cache_scan import fused_cache_scan
from repro.storage.cache_state import CacheState, init_cache

__all__ = [
    "StoreConfig",
    "StoreHyper",
    "StoreState",
    "StreamStats",
    "run_stream",
    "run_stream_chunked",
    "run_distributed",
    "partition_streams",
    "partition_window_ids",
    "stream_window_ids",
    "timestamp_window_ids",
    "correct_padded_stats",
    "init_stream_carry",
    "stream_chunk_engine",
    "stream_stats_from_carry",
    "stream_compile_count",
    "reset_stream_compile_count",
]

# Traced policy selector convention: ws (online learning) = -1, experts by
# their index in ol.EXPERTS. Part of the public contract (sweep stacking).
WS_POLICY_IDX = -1
POLICY_TO_IDX = {"ws": WS_POLICY_IDX,
                 **{name: i for i, name in enumerate(ol.EXPERTS)}}


class StoreHyper(NamedTuple):
    """The scalar online-learning knobs of a :class:`StoreConfig`, as traced
    operands of the engine rather than compile-time constants.

    Points of a sweep that differ only in these fields share one compiled
    engine: the sweep stacks ``StoreHyper`` leaves on a vmap axis next to the
    stream data instead of splitting per-config jit caches. ``policy_idx``
    follows :data:`POLICY_TO_IDX` (``-1`` = weight-sharing online learning).
    """

    alpha: jnp.ndarray      # f32[] weight-share rate
    beta: jnp.ndarray       # f32[] multiplicative penalty base
    threshold: jnp.ndarray  # f32[] misprediction threshold fraction
    policy_idx: jnp.ndarray  # i32[] expert index, -1 = online learning


@dataclasses.dataclass(frozen=True)
class StoreConfig:
    n_lines: int = 64
    policy: str = "ws"  # ws | lru | lfu | random
    epoch_width: int = 4
    alpha: float = 0.5
    beta: float = 0.7
    threshold: float = 0.25
    pred_cap: int = 64
    prefetch: bool = False
    prefetch_width: int = 4
    prefetch_buf: int = 16

    def ol_config(self) -> ol.OLConfig:
        return ol.OLConfig(
            epoch_width=self.epoch_width,
            alpha=self.alpha,
            beta=self.beta,
            threshold=self.threshold,
            pred_cap=self.pred_cap,
        )

    def policy_idx(self) -> Optional[int]:
        if self.policy == "ws":
            return None
        return ol.EXPERTS.index(self.policy)

    def hyper(self) -> StoreHyper:
        """This config's scalar knobs as concrete :class:`StoreHyper` leaves."""
        try:
            idx = POLICY_TO_IDX[self.policy]
        except KeyError:
            raise ValueError(
                f"unknown policy {self.policy!r}; "
                f"options: {sorted(POLICY_TO_IDX)}"
            ) from None
        return StoreHyper(
            alpha=jnp.asarray(self.alpha, jnp.float32),
            beta=jnp.asarray(self.beta, jnp.float32),
            threshold=jnp.asarray(self.threshold, jnp.float32),
            policy_idx=jnp.asarray(idx, jnp.int32),
        )

    def static_config(self) -> "StoreConfig":
        """The structural residue of this config: every field that shapes the
        compiled engine (array sizes, scan structure), with the traced knobs
        (:class:`StoreHyper` fields) reset to class defaults. Two configs with
        equal ``static_config()`` share one compiled engine."""
        defaults = {
            f.name: f.default
            for f in dataclasses.fields(StoreConfig)
            if f.name in ("alpha", "beta", "threshold", "policy")
        }
        return dataclasses.replace(self, **defaults)


class StoreState(NamedTuple):
    cache: CacheState
    ols: ol.OLState
    pf: pfm.PrefetchState
    t: jnp.ndarray          # int32 iteration counter
    key: jax.Array          # PRNG for the Random expert


class StreamStats(NamedTuple):
    """Aggregated counters for a processed request stream.

    Scalar fields are whole-stream totals (padding included, exactly the
    historic semantics); ``win_*`` fields resolve the same counters over
    ``n_windows`` time windows of the stream (last axis; padding excluded
    by construction, see the module docstring).
    """

    requests: jnp.ndarray
    hits: jnp.ndarray
    misses: jnp.ndarray
    prefetch_hits: jnp.ndarray   # misses serviced from the prefetch buffer
    tier2_reads: jnp.ndarray     # demand fetches + prefetch fetches
    tier2_writes: jnp.ndarray    # dirty write-backs
    evictions: jnp.ndarray
    expert_use: jnp.ndarray      # int32[E] evictions issued per expert
    final_weights: jnp.ndarray   # f32[E]
    # Windowed telemetry: int32[..., n_windows], real (unpadded) requests.
    win_requests: jnp.ndarray
    win_hits: jnp.ndarray
    win_misses: jnp.ndarray
    win_prefetch_hits: jnp.ndarray
    win_tier2_reads: jnp.ndarray
    win_tier2_writes: jnp.ndarray
    win_evictions: jnp.ndarray
    # Windowed online-learning telemetry: per-window evictions per expert
    # (int32[..., n_windows, E]) and the expert weights at each window's
    # last real request (f32[..., n_windows, E]; zeros where the window saw
    # no real request).
    win_expert_use: jnp.ndarray
    win_weights: jnp.ndarray

    @property
    def miss_rate(self):
        return self.misses / jnp.maximum(self.requests, 1)

    @property
    def n_windows(self) -> int:
        return self.win_requests.shape[-1]


def init_store(cfg: StoreConfig, seed: int = 0) -> StoreState:
    return StoreState(
        cache=init_cache(cfg.n_lines),
        ols=ol.init_ol(cfg.ol_config()),
        pf=pfm.init_prefetch(cfg.prefetch_buf),
        t=jnp.zeros((), jnp.int32),
        key=jax.random.PRNGKey(seed),
    )


def _step(cfg: StoreConfig, hyper: StoreHyper, state: StoreState, req):
    # ``cfg`` carries only structural knobs here (shapes, scan layout,
    # prefetcher wiring); the scalar learning knobs come from ``hyper`` so
    # they may be traced (one compile serves a grid of settings).
    ol_cfg = ol.OLConfig(
        epoch_width=cfg.epoch_width,
        alpha=hyper.alpha,
        beta=hyper.beta,
        threshold=hyper.threshold,
        pred_cap=cfg.pred_cap,
    )
    page, is_write = req
    page = page.astype(jnp.int32)
    cache, ols, pf = state.cache, state.ols, state.pf
    t = state.t
    key, vkey = jax.random.split(state.key)

    # --- 1. lookup -------------------------------------------------------
    match = cache.valid & (cache.tags == page)
    hit = jnp.any(match)
    hit_idx = jnp.argmax(match).astype(jnp.int32)

    # Hit path metadata updates.
    ts_hit = cache.ts.at[hit_idx].set(t)
    freq_hit = cache.freq.at[hit_idx].add(1)
    dirty_hit = cache.dirty.at[hit_idx].set(cache.dirty[hit_idx] | is_write)

    # --- 2/3. miss path ---------------------------------------------------
    miss = ~hit
    ols = jax.tree.map(
        lambda new, old: jnp.where(miss, new, old), ol.note_miss(ols, page), ols
    )
    # Prefetch buffer probe (only meaningful on a miss).
    pf_probed, in_buf = pfm.probe_and_promote(pf, page)
    pf = jax.tree.map(lambda new, old: jnp.where(miss, new, old), pf_probed, pf)
    promoted = miss & in_buf

    free = ~cache.valid
    has_free = jnp.any(free)
    free_idx = jnp.argmax(free).astype(jnp.int32)

    # GetVictim: every expert proposes; chosen expert's proposal is used.
    proposals = ol.propose_victims(cache, vkey)          # int32[E] line idx
    victim_pages = cache.tags[proposals]                  # int32[E]
    chosen = ol.choose_expert(ols, hyper.policy_idx)
    victim_idx = proposals[chosen]

    evict = miss & ~has_free
    slot = jnp.where(has_free, free_idx, victim_idx)
    writeback = evict & cache.dirty[slot]

    # Record prediction vectors only when an eviction actually happens.
    ols_pred = ol.record_predictions(ols, ol_cfg, victim_pages)
    ols = jax.tree.map(lambda new, old: jnp.where(evict, new, old), ols_pred, ols)
    ols = ols._replace(chosen=jnp.where(evict, chosen, ols.chosen[0])[None])

    # Insert the missed page.
    tags_miss = cache.tags.at[slot].set(page)
    valid_miss = cache.valid.at[slot].set(True)
    dirty_miss = cache.dirty.at[slot].set(is_write)
    freq_miss = cache.freq.at[slot].set(1)
    ts_miss = cache.ts.at[slot].set(t)

    cache = CacheState(
        tags=jnp.where(miss, tags_miss, cache.tags),
        valid=jnp.where(miss, valid_miss, cache.valid),
        dirty=jnp.where(miss, dirty_miss, jnp.where(hit, dirty_hit, cache.dirty)),
        freq=jnp.where(miss, freq_miss, jnp.where(hit, freq_hit, cache.freq)),
        ts=jnp.where(miss, ts_miss, jnp.where(hit, ts_hit, cache.ts)),
    )

    # --- 4. stream identifier + prefetch issue ----------------------------
    if cfg.prefetch:
        pf_obs = pfm.observe_miss(pf, page)
        pf = jax.tree.map(lambda new, old: jnp.where(miss, new, old), pf_obs, pf)
        n_before = pf.issued
        pf_issued = pfm.issue_prefetches(
            pf, page, cache.tags, cache.valid, cfg.prefetch_width
        )
        pf = jax.tree.map(lambda new, old: jnp.where(miss, new, old), pf_issued, pf)
        prefetch_fetches = jnp.where(miss, pf.issued - n_before, 0)
    else:
        prefetch_fetches = jnp.zeros((), jnp.int32)

    # --- 5. epoch boundary -------------------------------------------------
    # WeightAdjust fires only for the weight-sharing policy (policy_idx < 0);
    # fixed-expert baselines keep their initial weights, exactly as when the
    # policy was a compile-time constant.
    epoch_end = (t + 1) % cfg.epoch_width == 0
    is_ws = hyper.policy_idx < 0
    ols_adj = ol.weight_adjust(ols, ol_cfg)
    ols = jax.tree.map(
        lambda new, old: jnp.where(epoch_end & is_ws, new, old), ols_adj, ols
    )

    out = dict(
        hit=hit,
        miss=miss,
        prefetch_hit=promoted,
        tier2_read=(miss & ~promoted).astype(jnp.int32) + prefetch_fetches,
        tier2_write=writeback.astype(jnp.int32),
        evict=evict,
        chosen=jnp.where(evict, chosen, -1),
    )
    return StoreState(cache=cache, ols=ols, pf=pf, t=t + 1, key=key), out


class _Accum(NamedTuple):
    """Scan-carried counter accumulators: scalar whole-stream totals plus
    ``n_windows`` windowed slots (pads scatter to the out-of-range id and
    are dropped)."""

    hits: jnp.ndarray
    misses: jnp.ndarray
    prefetch_hits: jnp.ndarray
    tier2_reads: jnp.ndarray
    tier2_writes: jnp.ndarray
    evictions: jnp.ndarray
    expert_use: jnp.ndarray      # int32[E]
    win_requests: jnp.ndarray    # int32[W]
    win_hits: jnp.ndarray
    win_misses: jnp.ndarray
    win_prefetch_hits: jnp.ndarray
    win_tier2_reads: jnp.ndarray
    win_tier2_writes: jnp.ndarray
    win_evictions: jnp.ndarray
    win_expert_use: jnp.ndarray  # int32[W, E]
    win_weights: jnp.ndarray     # f32[W, E]


def _init_accum(n_windows: int) -> _Accum:
    zero = jnp.zeros((), jnp.int32)
    zw = jnp.zeros((n_windows,), jnp.int32)
    return _Accum(
        hits=zero, misses=zero, prefetch_hits=zero, tier2_reads=zero,
        tier2_writes=zero, evictions=zero,
        expert_use=jnp.zeros((ol.N_EXPERTS,), jnp.int32),
        win_requests=zw, win_hits=zw, win_misses=zw, win_prefetch_hits=zw,
        win_tier2_reads=zw, win_tier2_writes=zw, win_evictions=zw,
        win_expert_use=jnp.zeros((n_windows, ol.N_EXPERTS), jnp.int32),
        win_weights=jnp.zeros((n_windows, ol.N_EXPERTS), jnp.float32),
    )


def _fold(acc: _Accum, out: dict, win: jnp.ndarray,
          weights: jnp.ndarray) -> _Accum:
    """Fold one request's outcome into the accumulators. ``win`` is the
    request's window id; ``win == n_windows`` (padding) drops out of the
    windowed scatter but still counts toward the scalar totals.
    ``weights`` is the post-step expert weight vector: overwriting the
    window's row every step leaves each row holding the weights at that
    window's *last* real request."""
    hit = out["hit"].astype(jnp.int32)
    miss = out["miss"].astype(jnp.int32)
    pfh = out["prefetch_hit"].astype(jnp.int32)
    t2r = out["tier2_read"].astype(jnp.int32)
    t2w = out["tier2_write"].astype(jnp.int32)
    ev = out["evict"].astype(jnp.int32)
    expert = jnp.where(out["evict"], out["chosen"], 0)
    return _Accum(
        hits=acc.hits + hit,
        misses=acc.misses + miss,
        prefetch_hits=acc.prefetch_hits + pfh,
        tier2_reads=acc.tier2_reads + t2r,
        tier2_writes=acc.tier2_writes + t2w,
        evictions=acc.evictions + ev,
        expert_use=acc.expert_use.at[expert].add(ev),
        win_requests=acc.win_requests.at[win].add(1, mode="drop"),
        win_hits=acc.win_hits.at[win].add(hit, mode="drop"),
        win_misses=acc.win_misses.at[win].add(miss, mode="drop"),
        win_prefetch_hits=acc.win_prefetch_hits.at[win].add(pfh, mode="drop"),
        win_tier2_reads=acc.win_tier2_reads.at[win].add(t2r, mode="drop"),
        win_tier2_writes=acc.win_tier2_writes.at[win].add(t2w, mode="drop"),
        win_evictions=acc.win_evictions.at[win].add(ev, mode="drop"),
        win_expert_use=acc.win_expert_use.at[win, expert].add(ev,
                                                              mode="drop"),
        win_weights=acc.win_weights.at[win].set(weights, mode="drop"),
    )


def stream_window_ids(n: int, n_windows: int) -> np.ndarray:
    """Window id per stream position: position ``g`` of an ``n``-long stream
    belongs to window ``g * n_windows // n`` (equal request-count slices of
    the global timeline)."""
    if n_windows < 1:
        raise ValueError("n_windows must be >= 1")
    if n == 0:
        return np.zeros(0, np.int32)
    return (np.arange(n, dtype=np.int64) * n_windows // n).astype(np.int32)


def timestamp_window_ids(times: np.ndarray, n_windows: int,
                         window_dt: float) -> np.ndarray:
    """Wall-clock window id per request: arrival time ``t`` belongs to bin
    ``t // window_dt``, clipped into the last bin (arrivals past the nominal
    horizon still count — windowed counters always reconcile exactly with
    the whole-stream totals). Negative times mark padding and map to the
    dropped id ``n_windows``.

    Binning happens host-side in float64: an f32 ratio loses whole-integer
    resolution past ~2^24, so multi-hour streamed traces (epoch-style or
    simply long horizons) would drift across bin edges. The int32 ids are
    what the engine consumes (``window_ids=`` operand), so the scan itself
    never touches arrival-time floats."""
    if n_windows < 1:
        raise ValueError("n_windows must be >= 1")
    if window_dt <= 0:
        raise ValueError("window_dt must be positive")
    t = np.asarray(times, np.float64)
    # Clip in float space *before* the integer cast: a ratio beyond int32
    # (epoch-style absolute times) must saturate into the last bin, not
    # wrap.
    ids = np.clip(t / np.float64(window_dt), 0,
                  np.float64(n_windows - 1)).astype(np.int32)
    return np.where(t >= 0, ids, n_windows).astype(np.int32)


def run_stream(
    cfg: StoreConfig,
    pages: jnp.ndarray,
    is_write: jnp.ndarray,
    *,
    seed: int = 0,
    hyper: Optional[StoreHyper] = None,
    unroll: int = 1,
    n_windows: int = 1,
    window_ids: Optional[jnp.ndarray] = None,
    timestamps: Optional[jnp.ndarray] = None,
    window_dt=None,
    engine: str = "fused",
) -> StreamStats:
    """Process a request stream through one tier-1 shard. Jitted scan.

    ``hyper`` overrides the scalar learning knobs of ``cfg`` with (possibly
    traced) :class:`StoreHyper` operands — the sweep engine's third vmap
    axis. When traced hypers are supplied, only ``cfg.static_config()``
    shapes the computation. ``unroll`` chunks the per-request scan body
    (semantics-preserving; larger values trade compile time for fewer loop
    iterations on wide batches).

    ``engine`` selects the request-loop implementation: ``"fused"`` (the
    default) routes through :func:`repro.kernels.cache_scan.fused_cache_scan`
    — one-hot elementwise state updates with hoisted Random-expert draws,
    VMEM-resident Pallas kernel on TPU backends — and ``"scan"`` keeps the
    original per-step gather/scatter ``lax.scan``, the golden reference the
    fused engine is bit-exact against.

    ``n_windows`` resolves the counters over time windows (carried
    accumulators — O(n_windows) memory, no per-request outputs). The window
    of a request is, in precedence order:

    - its wall-clock time bin ``t // window_dt`` when ``timestamps``
      (f32[T] arrival seconds, ``-1`` marking padding) and ``window_dt``
      are given — both are *data* operands (traced, so one compile serves
      any timestamp layout and window duration; only ``n_windows`` is
      structural), and arrivals past ``n_windows * window_dt`` clip into
      the last bin;
    - an explicit ``window_ids`` assignment (int32[T], values in
      [0, n_windows]; ``n_windows`` marks padding, dropped from the
      windowed counters);
    - by default, equal request-count slices of this stream's own length.
    """
    pages = jnp.asarray(pages, jnp.int32)
    is_write = jnp.asarray(is_write, bool)
    if hyper is None:
        hyper = cfg.hyper()
    if timestamps is not None:
        if window_dt is None:
            raise ValueError("timestamps need a window_dt (seconds per bin)")
        ts = jnp.asarray(timestamps, jnp.float32)
        wdt = jnp.asarray(window_dt, jnp.float32)
        # Float-space clip before the cast (see timestamp_window_ids).
        ids = jnp.clip(ts / wdt, 0.0, float(n_windows - 1)).astype(jnp.int32)
        window_ids = jnp.where(ts >= 0, ids, n_windows)
    elif window_ids is None:
        window_ids = stream_window_ids(pages.shape[0], n_windows)
    window_ids = jnp.asarray(window_ids, jnp.int32)
    if engine not in ("fused", "scan"):
        raise ValueError(f"unknown engine {engine!r}; options: fused, scan")

    carry0 = (init_store(cfg, seed), _init_accum(n_windows))
    if engine == "fused":
        final, acc = fused_cache_scan(
            cfg, hyper, carry0[0], carry0[1], pages, is_write, window_ids,
            n_windows=n_windows, unroll=unroll,
        )
    else:
        def scan_fn(carry, req):
            state, acc = carry
            page, write, win = req
            state, out = _step(cfg, hyper, state, (page, write))
            return (state, _fold(acc, out, win, state.ols.weights)), None

        (final, acc), _ = jax.lax.scan(
            scan_fn, carry0, (pages, is_write, window_ids), unroll=unroll
        )
    return StreamStats(
        requests=pages.shape[0] + jnp.zeros((), jnp.int32),
        hits=acc.hits,
        misses=acc.misses,
        prefetch_hits=acc.prefetch_hits,
        tier2_reads=acc.tier2_reads,
        tier2_writes=acc.tier2_writes,
        evictions=acc.evictions,
        expert_use=acc.expert_use,
        final_weights=final.ols.weights,
        win_requests=acc.win_requests,
        win_hits=acc.win_hits,
        win_misses=acc.win_misses,
        win_prefetch_hits=acc.win_prefetch_hits,
        win_tier2_reads=acc.win_tier2_reads,
        win_tier2_writes=acc.win_tier2_writes,
        win_evictions=acc.win_evictions,
        win_expert_use=acc.win_expert_use,
        win_weights=acc.win_weights,
    )


run_stream_jit = jax.jit(
    run_stream, static_argnums=0,
    static_argnames=("seed", "unroll", "n_windows", "engine"),
)


def partition_streams(
    pages: np.ndarray,
    is_write: np.ndarray,
    *,
    n_shards: int,
    mapping: str = "block",
    n_pages: Optional[int] = None,
    cap: Optional[int] = None,
    n_windows: Optional[int] = None,
    window_ids: Optional[np.ndarray] = None,
    times: Optional[np.ndarray] = None,
    owner: Optional[np.ndarray] = None,
):
    """Partition a request stream into per-shard substreams (§III mapping).

    Each shard's substream is padded to ``cap`` (default: the max shard load)
    with repeats of its own last page — pure hits, so every counter except
    ``requests``/``hits`` is unaffected and those two are correctable from
    the pad length. Returns ``(sh_pages [S, cap], sh_writes [S, cap],
    counts [S], owner [n])``; with ``n_windows`` set, additionally returns
    ``sh_win [S, cap]`` window ids (see :func:`partition_window_ids`),
    reusing this call's shard sort instead of re-sorting. ``window_ids``
    (int32[n], values in [0, n_windows]) overrides the default equal-count
    ids with precomputed *global* per-request window assignments — the
    wall-clock paths pass :func:`timestamp_window_ids` output here, so the
    float64 host binning is the only time→window mapping and the engine
    only ever sees int ids. With ``times`` set (wall-clock arrival seconds,
    float[n]), additionally returns ``sh_times [S, cap]`` float32 per-shard
    arrival timestamps (padding positions carry ``-1``, which the engine's
    in-graph time binning drops).

    ``owner`` overrides the §III mapping with a precomputed per-request
    owner array (int[n]) — the fault-injection path passes owners already
    rerouted around down shards (:func:`repro.core.mapping.apply_failover`).
    """
    pages = np.asarray(pages)
    is_write = np.asarray(is_write, bool)
    n_pages = int(n_pages if n_pages is not None else (pages.max() + 1))
    if owner is None:
        owner = np.asarray(
            page_to_shard(jnp.asarray(pages), n_shards, n_pages, mapping)
        )
    else:
        owner = np.asarray(owner)
        if owner.shape != pages.shape:
            raise ValueError("owner must align with the request stream")
    counts = np.bincount(owner, minlength=n_shards)
    cap = int(cap if cap is not None else max(int(counts.max()), 1))
    if cap < counts.max():
        raise ValueError(f"cap={cap} < max shard load {int(counts.max())}")
    # Argsort-based scatter (stable sort preserves per-shard request order):
    # request j lands at row owner[j], column = its rank within its shard.
    order, row, col = _shard_positions(owner, counts)
    sh_pages = np.zeros((n_shards, cap), np.int32)
    sh_writes = np.zeros((n_shards, cap), bool)
    sh_pages[row, col] = pages[order]
    sh_writes[row, col] = is_write[order]
    # Pad each shard with its own last page — pure hits (empty shards keep
    # page 0, whose first access is the phantom miss correct_padded_stats
    # zeroes out).
    last = sh_pages[np.arange(n_shards), np.maximum(counts - 1, 0)]
    pad = np.arange(cap)[None, :] >= counts[:, None]
    sh_pages = np.where(pad, last[:, None], sh_pages)
    out = [sh_pages, sh_writes, counts, owner]
    if window_ids is not None:
        if n_windows is None:
            raise ValueError("window_ids need n_windows (the dropped pad id)")
        window_ids = np.asarray(window_ids, np.int32)
        if window_ids.shape != owner.shape:
            raise ValueError("window_ids must align with the request stream")
        sh_win = np.full((n_shards, cap), n_windows, np.int32)
        sh_win[row, col] = window_ids[order]
        out.append(sh_win)
    elif n_windows is not None:
        out.append(_scatter_window_ids(owner, n_shards, n_windows, cap,
                                       order, row, col))
    if times is not None:
        times = np.asarray(times, np.float32)
        if times.shape != owner.shape:
            raise ValueError("times must align with the request stream")
        sh_times = np.full((n_shards, cap), -1.0, np.float32)
        sh_times[row, col] = times[order]
        out.append(sh_times)
    return tuple(out)


def _shard_positions(owner: np.ndarray, counts: np.ndarray):
    """(order, row, col) scatter coordinates: the stable shard-sort of the
    request indices (original order preserved within each shard), and for
    each sorted request its owning shard and rank within that shard."""
    order = np.argsort(owner, kind="stable")
    starts = np.concatenate(([0], np.cumsum(counts)[:-1]))
    row = owner[order]
    col = np.arange(owner.shape[0]) - starts[row]
    return order, row, col


def _scatter_window_ids(
    owner, n_shards: int, n_windows: int, cap: int, order, row, col
) -> np.ndarray:
    """Scatter global window ids to per-shard positions (pads keep the
    dropped id ``n_windows``) using precomputed shard-sort coordinates."""
    gwin = stream_window_ids(owner.shape[0], n_windows)
    sh_win = np.full((n_shards, cap), n_windows, np.int32)
    sh_win[row, col] = gwin[order]
    return sh_win


def partition_window_ids(
    owner: np.ndarray,
    counts: np.ndarray,
    cap: int,
    n_windows: int,
) -> np.ndarray:
    """Per-shard window-id arrays aligned with :func:`partition_streams`.

    Returns int32 ``[n_shards, cap]``: real positions carry their request's
    *global* time window (``g * n_windows // n`` for global position ``g``),
    padding positions carry the out-of-range id ``n_windows`` so the
    engine's windowed scatter drops them. Windowed counters are therefore
    independent of padding/bucketing choices. (The internal partitioning
    paths use ``partition_streams(..., n_windows=)`` instead, which reuses
    one shard sort for streams and window ids.)
    """
    owner = np.asarray(owner)
    counts = np.asarray(counts)
    order, row, col = _shard_positions(owner, counts)
    return _scatter_window_ids(owner, counts.shape[0], n_windows, cap,
                               order, row, col)


def correct_padded_stats(stats: StreamStats, counts, cap: int) -> StreamStats:
    """Undo padding artifacts in per-shard stats from padded substreams
    (see :func:`partition_streams`): padded requests are pure hits on each
    shard's last page (subtracted from ``hits``), and a shard with no real
    requests ran a pure-padding stream whose first access is a phantom
    miss (all its counters are zeroed).

    The windowed counters need no correction at all: real requests carry
    their own window ids, pads (including the whole stream of an empty
    shard, phantom miss included) scatter to the dropped out-of-range id,
    so per-window counters already count exactly the real requests."""
    pad = jnp.asarray(cap - np.asarray(counts), jnp.int32)
    nonempty = jnp.asarray(np.asarray(counts) > 0)
    zero = jnp.zeros((), jnp.int32)
    return stats._replace(
        requests=jnp.asarray(counts, jnp.int32),
        hits=jnp.maximum(stats.hits - pad, 0),
        misses=jnp.where(nonempty, stats.misses, zero),
        prefetch_hits=jnp.where(nonempty, stats.prefetch_hits, zero),
        tier2_reads=jnp.where(nonempty, stats.tier2_reads, zero),
        tier2_writes=jnp.where(nonempty, stats.tier2_writes, zero),
        evictions=jnp.where(nonempty, stats.evictions, zero),
    )


def run_distributed(
    cfg: StoreConfig,
    pages: np.ndarray,
    is_write: np.ndarray,
    *,
    n_shards: int,
    mapping: str = "block",
    n_pages: Optional[int] = None,
    seed: int = 0,
    n_windows: int = 1,
    timestamps: Optional[np.ndarray] = None,
    window_dt: Optional[float] = None,
    owner: Optional[np.ndarray] = None,
    engine: str = "fused",
):
    """Distributed tier-1 cache: requests partitioned to per-shard caches by
    the §III mapping policy, shards processed by ``vmap`` (the paper's
    per-process caches are independent — no replication, no migration).

    Returns ``(per_shard_stats, shard_request_counts)``; per-shard stats are
    padded streams, so counters are exact but ``requests`` reflects real
    (unpadded) request counts. ``n_windows`` resolves every counter over
    time windows of the *global* request stream (``win_*`` fields, shape
    ``[n_shards, n_windows]``): wall-clock bins of ``window_dt`` seconds
    when ``timestamps`` (arrival seconds, float[n]) are supplied, equal
    request-count slices otherwise. ``owner`` optionally overrides the
    mapping policy with precomputed (e.g. failover-remapped) owners.
    """
    if timestamps is not None:
        if window_dt is None:
            raise ValueError("timestamps need a window_dt (seconds per bin)")
        # Bin host-side in float64 (timestamp_window_ids) and hand the
        # engine int32 ids: f32 arrival times lose whole-second resolution
        # past ~2^24, so long-horizon traces would drift across bin edges.
        gwin = timestamp_window_ids(timestamps, n_windows, window_dt)
        sh_pages, sh_writes, counts, owner, sh_win = partition_streams(
            pages, is_write, n_shards=n_shards, mapping=mapping,
            n_pages=n_pages, n_windows=n_windows, window_ids=gwin,
            owner=owner,
        )
    else:
        sh_pages, sh_writes, counts, owner, sh_win = partition_streams(
            pages, is_write, n_shards=n_shards, mapping=mapping,
            n_pages=n_pages, n_windows=n_windows, owner=owner,
        )
    stats = jax.vmap(
        lambda p, w, wi: run_stream(
            cfg, p, w, seed=seed, n_windows=n_windows, window_ids=wi,
            engine=engine,
        )
    )(jnp.asarray(sh_pages), jnp.asarray(sh_writes), jnp.asarray(sh_win))
    return correct_padded_stats(stats, counts, sh_pages.shape[1]), counts


# ---------------------------------------------------------------------------
# Chunked streaming replay: resumable masked scan with donated chunk buffers.
#
# The one-shot paths above hold the whole trace in one [shard, len] device
# array. The streaming path instead carries the full engine state — the
# [S]-stacked (StoreState, _Accum) pytree — across fixed-size chunks, so a
# trace of any length replays in O(S * chunk) device memory. Bit-exactness
# with the one-shot scan comes from *masking*: a chunk row's padding
# positions (window id == the dropped ``n_windows``) leave the carried state
# completely untouched (``t`` not advanced, PRNG key not split) and
# contribute zero to every counter, so the state seen by real request ``j``
# of a shard is identical whatever the chunking. (The one-shot path instead
# lets trailing pads run as pure hits and corrects the totals afterwards —
# equivalent for trailing pads, wrong for mid-stream pads, which is exactly
# why the chunk engine masks.)
# ---------------------------------------------------------------------------

# Chunk engines are cached per (static store, unroll, n_windows, donate);
# the counter increments at trace time, i.e. once per XLA compile (jit's
# shape cache adds one compile per distinct (n_shards, cap) chunk shape).
_STREAM_CACHE: dict = {}
_STREAM_COMPILES = [0]


def stream_compile_count() -> int:
    """Number of XLA compiles of the chunked stream engine so far."""
    return _STREAM_COMPILES[0]


def reset_stream_compile_count() -> None:
    _STREAM_COMPILES[0] = 0


def init_stream_carry(cfg: StoreConfig, n_shards: int, *, seed: int = 0,
                      n_windows: int = 1):
    """Fresh [n_shards]-stacked ``(StoreState, _Accum)`` chunk-engine carry
    — every shard starts from the cold :func:`init_store` state (same seed,
    matching :func:`run_distributed`'s per-shard init) with zeroed
    accumulators."""
    one = (init_store(cfg, seed), _init_accum(n_windows))
    return jax.tree.map(
        lambda x: jnp.repeat(x[None], n_shards, axis=0), one)


def stream_chunk_engine(cfg: StoreConfig, *, unroll: int = 1,
                        n_windows: int = 1, donate: bool = True,
                        engine: str = "fused"):
    """The compiled chunk engine for a structural store config:
    ``(hyper, carry, pages [S, L], writes [S, L], win [S, L]) -> carry``.

    The carry and all three chunk buffers are donated
    (``jit(..., donate_argnums=(1, 2, 3, 4))``) so every chunk reuses the
    previous chunk's device allocations — peak device memory is O(S * L)
    regardless of how many chunks stream through. ``hyper`` is a traced
    operand (one compile serves a grid of learning knobs); padding rows
    carry window id ``n_windows`` and are masked no-ops (see the section
    comment). Callers must treat donated arguments as consumed: thread the
    returned carry, never reuse a chunk buffer after passing it in.
    ``donate=False`` exists for the naive per-chunk baseline benchmarks
    compare against. ``engine`` selects the fused one-hot request loop
    (default) or the original ``"scan"`` reference (see
    :func:`run_stream`); both are bit-exact, masked-pad semantics
    included."""
    if engine not in ("fused", "scan"):
        raise ValueError(f"unknown engine {engine!r}; options: fused, scan")
    static = cfg.static_config()
    key = (static, unroll, n_windows, donate, engine)
    fn = _STREAM_CACHE.get(key)
    if fn is not None:
        return fn

    def body(hyper, carry, pages, writes, win):
        _STREAM_COMPILES[0] += 1  # trace-time: once per XLA compile

        def shard(state, acc, p, w, wi):
            if engine == "fused":
                # Resumable masked mode: pads leave the carried state
                # (PRNG key included) untouched; the PRNG stays in-loop
                # because the carried key must advance per real request.
                return fused_cache_scan(
                    static, hyper, state, acc, p, w, wi,
                    n_windows=n_windows, unroll=unroll, masked=True)

            def scan_fn(c, req):
                state, acc = c
                page, write, win_i = req
                valid = win_i < n_windows
                new_state, out = _step(static, hyper, state,
                                       (page, write))
                # Masked step: padding leaves the state (including t and
                # the PRNG key) untouched and contributes nothing to the
                # scalar totals; the windowed scatters drop pad ids on
                # their own. ``chosen`` needs no mask — it only feeds
                # expert_use scaled by the (masked) evict flag.
                state = jax.tree.map(
                    lambda n, o: jnp.where(valid, n, o), new_state, state)
                out = dict(
                    hit=out["hit"] & valid,
                    miss=out["miss"] & valid,
                    prefetch_hit=out["prefetch_hit"] & valid,
                    tier2_read=jnp.where(valid, out["tier2_read"], 0),
                    tier2_write=jnp.where(valid, out["tier2_write"], 0),
                    evict=out["evict"] & valid,
                    chosen=out["chosen"],
                )
                return (state, _fold(acc, out, win_i,
                                     state.ols.weights)), None

            (state, acc), _ = jax.lax.scan(
                scan_fn, (state, acc), (p, w, wi), unroll=unroll)
            return state, acc

        state, acc = carry
        return tuple(jax.vmap(shard)(state, acc,
                                     pages.astype(jnp.int32),
                                     writes.astype(bool),
                                     win.astype(jnp.int32)))

    jfn = jax.jit(body, donate_argnums=(1, 2, 3, 4) if donate else ())

    if donate:
        # The chunk buffers (int32/bool operands) have no same-shape output
        # to alias, so XLA warns it can only *free* them early, not reuse
        # them. That is the intended behavior — silence just that warning
        # (the carry donation, the one that bounds peak memory, is silent).
        def fn(*args):
            with warnings.catch_warnings():
                warnings.filterwarnings(
                    "ignore",
                    message="Some donated buffers were not usable")
                return jfn(*args)
    else:
        fn = jfn
    _STREAM_CACHE[key] = fn
    return fn


def stream_stats_from_carry(carry, counts) -> StreamStats:
    """Materialize :class:`StreamStats` from a chunk-engine carry. ``counts``
    is the per-shard count of *real* requests streamed so far. No padding
    correction applies — masked pads never touched the accumulators — so
    the result is directly comparable to :func:`run_distributed`'s
    padding-corrected per-shard stats."""
    state, acc = carry
    return StreamStats(
        requests=jnp.asarray(counts, jnp.int32),
        hits=acc.hits,
        misses=acc.misses,
        prefetch_hits=acc.prefetch_hits,
        tier2_reads=acc.tier2_reads,
        tier2_writes=acc.tier2_writes,
        evictions=acc.evictions,
        expert_use=acc.expert_use,
        final_weights=state.ols.weights,
        win_requests=acc.win_requests,
        win_hits=acc.win_hits,
        win_misses=acc.win_misses,
        win_prefetch_hits=acc.win_prefetch_hits,
        win_tier2_reads=acc.win_tier2_reads,
        win_tier2_writes=acc.win_tier2_writes,
        win_evictions=acc.win_evictions,
        win_expert_use=acc.win_expert_use,
        win_weights=acc.win_weights,
    )


def run_stream_chunked(
    cfg: StoreConfig,
    pages: np.ndarray,
    is_write: np.ndarray,
    *,
    chunk: int,
    seed: int = 0,
    hyper: Optional[StoreHyper] = None,
    unroll: int = 1,
    n_windows: int = 1,
    window_ids: Optional[np.ndarray] = None,
    engine: str = "fused",
) -> StreamStats:
    """Single-shard chunked replay: :func:`run_stream` semantics, consumed
    ``chunk`` requests at a time through the resumable chunk engine.
    Bit-identical to ``run_stream(cfg, pages, is_write, ...)`` for every
    counter (``final_weights`` may differ only when that one-shot call was
    itself padded — pads there keep running epoch boundaries after the last
    real request; no counter reads the difference). The multi-shard,
    generator-fed production path is :func:`repro.sim.stream.simulate_stream`."""
    if chunk < 1:
        raise ValueError("chunk must be >= 1")
    pages = np.asarray(pages, np.int32)
    is_write = np.asarray(is_write, bool)
    n = pages.shape[0]
    if window_ids is None:
        window_ids = stream_window_ids(n, n_windows)
    window_ids = np.asarray(window_ids, np.int32)
    if hyper is None:
        hyper = cfg.hyper()
    eng = stream_chunk_engine(cfg, unroll=unroll, n_windows=n_windows,
                              engine=engine)
    carry = init_stream_carry(cfg, 1, seed=seed, n_windows=n_windows)
    for start in range(0, n, chunk):
        stop = min(start + chunk, n)
        p = np.zeros(chunk, np.int32)
        w = np.zeros(chunk, bool)
        wi = np.full(chunk, n_windows, np.int32)  # tail padding: masked
        p[: stop - start] = pages[start:stop]
        w[: stop - start] = is_write[start:stop]
        wi[: stop - start] = window_ids[start:stop]
        carry = eng(hyper, carry, p[None], w[None], wi[None])
    stats = stream_stats_from_carry(carry, np.array([n], np.int32))
    return jax.tree.map(lambda a: a[0], stats)
