"""Training-data shard cache: the paper's two-tier store feeding the input
pipeline.

Shards (fixed-size token files on disk = tier 2) are cached in host RAM
(tier 1) with the same policy machinery as the KV pools: the access stream
is fed through :mod:`repro.storage.tiered_store`'s OL weight-sharing
replacement (host-side mirror), and a stream-identifier prefetcher warms the
next shards while batches are served ("prefetch when IO threads idle").

This is a host-side component (numpy) — it produces device batches for the
jitted train step.
"""
from __future__ import annotations

import dataclasses
import os
from collections import OrderedDict
from typing import Optional

import numpy as np

from repro.core import online_learning as ol_mod

__all__ = ["DataCacheConfig", "ShardedTokenStore", "DataCache"]


@dataclasses.dataclass(frozen=True)
class DataCacheConfig:
    cache_shards: int = 8        # tier-1 capacity (shards in RAM)
    policy: str = "ws"           # ws | lru | lfu
    epoch_width: int = 4
    beta: float = 0.7
    alpha: float = 0.5
    threshold: float = 0.25
    prefetch_depth: int = 2


class ShardedTokenStore:
    """Tier 2: token shards on disk (synthetic corpus generator included)."""

    def __init__(self, root: str, n_shards: int, shard_tokens: int,
                 vocab: int, seed: int = 0):
        self.root = root
        self.n_shards = n_shards
        self.shard_tokens = shard_tokens
        self.vocab = vocab
        os.makedirs(root, exist_ok=True)
        rng = np.random.default_rng(seed)
        for s in range(n_shards):
            fn = self._path(s)
            if not os.path.exists(fn):
                toks = rng.integers(0, vocab, shard_tokens, dtype=np.int32)
                np.save(fn, toks)

    def _path(self, s: int) -> str:
        return os.path.join(self.root, f"shard_{s:05d}.npy")

    def read(self, s: int) -> np.ndarray:
        return np.load(self._path(s))


class _HostOL:
    """Host-side mirror of the OL weight-sharing policy (numpy, §III-A)."""

    def __init__(self, cfg: DataCacheConfig):
        self.cfg = cfg
        self.weights = np.ones(3) / 3
        self.pred: list[set] = [set(), set(), set()]
        self.mispred = np.zeros(3, int)
        self.epoch_misses = 0
        self.t = 0
        self.rng = np.random.default_rng(0)

    def choose(self) -> int:
        if self.cfg.policy != "ws":
            return {"lru": 0, "lfu": 1}.get(self.cfg.policy, 0)
        return int(np.argmax(self.weights))

    def note_miss(self, shard: int):
        self.epoch_misses += 1
        for i in range(3):
            if shard in self.pred[i]:
                self.mispred[i] += 1

    def record(self, proposals):
        for i, p in enumerate(proposals):
            self.pred[i].add(p)

    def tick(self):
        self.t += 1
        if self.t % self.cfg.epoch_width:
            return
        thr = self.cfg.threshold * self.epoch_misses
        losses = np.where(self.mispred >= thr, self.mispred, 0)
        prev = self.weights.copy()
        self.weights = self.weights * (self.cfg.beta ** losses)
        self.weights += self.cfg.alpha * np.mean(prev - self.weights)
        self.weights = np.maximum(self.weights, 1e-8)
        self.weights /= self.weights.sum()
        self.pred = [set(), set(), set()]
        self.mispred[:] = 0
        self.epoch_misses = 0


class DataCache:
    """Tier-1 shard cache with OL eviction + stride prefetch."""

    def __init__(self, store: ShardedTokenStore, cfg: DataCacheConfig):
        self.store = store
        self.cfg = cfg
        self.cache: "OrderedDict[int, np.ndarray]" = OrderedDict()
        self.freq: dict[int, int] = {}
        self.ts: dict[int, int] = {}
        self.ol = _HostOL(cfg)
        self.hits = 0
        self.misses = 0
        self.last_miss = -1
        self.stride = 0
        self.conf = 0

    def _proposals(self):
        if not self.cache:
            return (None, None, None)
        lru = min(self.cache, key=lambda s: self.ts[s])
        lfu = min(self.cache, key=lambda s: self.freq[s])
        rnd = self.ol.rng.choice(list(self.cache))
        return (lru, lfu, int(rnd))

    def _insert(self, s: int, data: np.ndarray):
        while len(self.cache) >= self.cfg.cache_shards:
            props = self._proposals()
            self.ol.record(props)
            victim = props[self.ol.choose()]
            self.cache.pop(victim, None)
        self.cache[s] = data
        self.freq[s] = self.freq.get(s, 0) + 1
        self.ts[s] = self.ol.t

    def get(self, s: int) -> np.ndarray:
        self.ol.tick()
        if s in self.cache:
            self.hits += 1
            self.freq[s] += 1
            self.ts[s] = self.ol.t
            return self.cache[s]
        self.misses += 1
        self.ol.note_miss(s)
        # Stream identifier on the miss stream.
        delta = s - self.last_miss
        if self.last_miss >= 0 and delta == self.stride and delta != 0:
            self.conf += 1
        elif delta != 0:
            self.stride, self.conf = delta, 1
        self.last_miss = s
        data = self.store.read(s)
        self._insert(s, data)
        # Prefetch (only into free slots, like the paper's prefetch buffer).
        if self.conf >= 2:
            for k in range(1, self.cfg.prefetch_depth + 1):
                nxt = (s + k * self.stride) % self.store.n_shards
                if nxt not in self.cache and \
                        len(self.cache) < self.cfg.cache_shards:
                    self._insert(nxt, self.store.read(nxt))
        return data

    def batch(self, step: int, batch: int, seq: int, *,
              shards_per_step: int = 1) -> dict:
        """Deterministic batch assembly: step -> shard ids -> sequences."""
        toks_needed = batch * (seq + 1)
        shard = (step * shards_per_step) % self.store.n_shards
        data = self.get(shard)
        reps = -(-toks_needed // len(data))
        flat = np.concatenate([data] * reps)[:toks_needed]
        arr = flat.reshape(batch, seq + 1)
        return {"tokens": arr[:, :-1].astype(np.int32),
                "labels": arr[:, 1:].astype(np.int32)}
