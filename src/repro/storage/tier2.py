"""Tier-2 backing store simulator (paper §V-B).

Converts tier-2 traffic counts (from :mod:`repro.storage.tiered_store`) into
service times / rates using the fitted HDD behavioral models, and provides
the μ2 values consumed by the queuing network. This is the piece that made
the paper's measured performance "include the cost of page misses" (§I).
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache

from repro.core.device_models import DeviceModel, fit_hdd_model, fit_nvme_model

__all__ = ["Tier2Sim", "default_tier2", "Tier1Sim", "default_tier1"]


@lru_cache(maxsize=None)
def _hdd(read: bool) -> DeviceModel:
    return fit_hdd_model(read=read)


@lru_cache(maxsize=None)
def _nvme(read: bool) -> DeviceModel:
    return fit_nvme_model(read=read)


@dataclasses.dataclass(frozen=True)
class Tier2Sim:
    """Shared HDD array behind the distributed cache.

    Layout parameters follow §V-B: stripe_count (X2), stripe_size (X4),
    file_size (X5), n_processes (X1). Stripes/disk (X3) is derived.
    """

    n_processes: int = 4
    stripe_count: int = 8
    stripe_size: int = 524288
    file_size: int = 400 << 30

    def _x3(self) -> float:
        return max(self.file_size / (self.stripe_size * self.stripe_count), 1.0)

    def full_file_time(self, *, read: bool) -> float:
        """Model prediction for one parallel pass over the whole file — the
        regime the §V-B campaigns were trained on."""
        m = _hdd(read)
        t = m.total_time(
            x1=float(self.n_processes),
            x2=float(self.stripe_count),
            x3=self._x3(),
            x4=float(self.stripe_size),
            x5=float(self.file_size),
        )
        floor = self.file_size / (1.5e8 * self.stripe_count)
        return max(t, floor)

    def total_time(self, n_stripes: float, *, read: bool) -> float:
        """Time to move ``n_stripes`` stripes at the model's mean per-stripe
        rate (§V-B: "compute the mean read/write time per stripe from total
        time" — avoids extrapolating the fit far below its training range).
        """
        per_stripe = self.full_file_time(read=read) / (
            self.file_size / self.stripe_size)
        return n_stripes * per_stripe

    def mu2(self, *, read: bool = True, n_stripes: float = 1024.0) -> float:
        """Mean miss service rate (stripes/sec) — μ2 for the queuing model."""
        return n_stripes / max(self.total_time(n_stripes, read=read), 1e-12)


@dataclasses.dataclass(frozen=True)
class Tier1Sim:
    """Per-process NVMe cache device (§V-A) — provides μ1 for queuing."""

    n_client_threads: int = 16
    request_size: int = 512
    address_range: int = 32 << 30

    def total_time(self, n_requests: float, *, read: bool) -> float:
        m = _nvme(read)
        t = m.total_time(
            x1=float(self.n_client_threads),
            x3=float(self.request_size),
            x4=float(n_requests),
            x5=float(self.address_range),
        )
        floor = n_requests * self.request_size / 3.5e9
        return max(t, floor)

    def mu1(self, *, read: bool = True, n_requests: float = 1e5) -> float:
        return n_requests / self.total_time(n_requests, read=read)


def default_tier2() -> Tier2Sim:
    return Tier2Sim()


def default_tier1() -> Tier1Sim:
    return Tier1Sim()
