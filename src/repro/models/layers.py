"""Shared model layers, written for manual-SPMD execution (see
``distributed/axes.py``): TP over the ``model`` axis, FSDP gathers over the
``data`` axis, explicit psums where partial sums cross shards.

Numerics: params/activations bf16, normalization + softmax + logsumexp in
f32, matmul accumulation in f32 via ``preferred_element_type``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.axes import Axes

__all__ = [
    "rms_norm",
    "layer_norm",
    "apply_rope",
    "sinusoidal_positions",
    "embed",
    "unembed_loss",
    "unembed_greedy",
    "mlp_swiglu",
    "mlp_gelu",
    "dense",
]

_F32 = jnp.float32


def dense(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x @ w with f32 accumulation, output cast back to x.dtype."""
    return jnp.einsum(
        "...d,df->...f", x, w, preferred_element_type=_F32
    ).astype(x.dtype)


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(_F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(_F32))).astype(x.dtype)


def rms_norm_tp(
    x: jnp.ndarray, scale: jnp.ndarray, eps: float, ax: Axes, full_width: int
) -> jnp.ndarray:
    """RMSNorm over a TP-sharded last dim: sum-of-squares psum'ed over the
    model axis so the normalizer matches the unsharded computation."""
    xf = x.astype(_F32)
    ss = jnp.sum(xf * xf, axis=-1, keepdims=True)
    if x.shape[-1] != full_width:  # sharded: reduce across model shards
        ss = ax.psum(ss, ax.model)
    var = ss / full_width
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(_F32))).astype(x.dtype)


def layer_norm(
    x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray, eps: float
) -> jnp.ndarray:
    xf = x.astype(_F32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(_F32) + bias.astype(_F32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Positions.
# ---------------------------------------------------------------------------


def apply_rope(
    x: jnp.ndarray, positions: jnp.ndarray, theta: float
) -> jnp.ndarray:
    """RoPE over the last dim. x: [..., S, H, hd]; positions: [..., S]."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=_F32) / half)
    ang = positions[..., :, None].astype(_F32) * freq  # [..., S, half]
    cos = jnp.cos(ang)[..., :, None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half].astype(_F32), x[..., half:].astype(_F32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(positions: jnp.ndarray, d: int) -> jnp.ndarray:
    """Whisper-style sinusoidal absolute position embeddings [..., S, d]."""
    half = d // 2
    freq = jnp.exp(-jnp.arange(half, dtype=_F32) * (jnp.log(10000.0) / (half - 1)))
    ang = positions[..., :, None].astype(_F32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Vocab-sharded embedding / unembedding. The embedding table is TP-sharded
# over the padded vocab (dim0, "model" axis) and FSDP-sharded over d (dim1).
# ---------------------------------------------------------------------------


def embed(tokens: jnp.ndarray, emb: jnp.ndarray, ax: Axes) -> jnp.ndarray:
    """tokens [B,S] int32, emb [V_local, d] (already FSDP-gathered)."""
    v_local = emb.shape[0]
    if ax.model is None:
        return jnp.take(emb, tokens, axis=0)
    start = ax.index(ax.model) * v_local
    local = tokens - start
    ok = (local >= 0) & (local < v_local)
    safe = jnp.clip(local, 0, v_local - 1)
    out = jnp.take(emb, safe, axis=0) * ok[..., None].astype(emb.dtype)
    return ax.psum(out, ax.model)


def unembed_loss(
    x: jnp.ndarray,
    emb: jnp.ndarray,
    labels: jnp.ndarray,
    ax: Axes,
    *,
    mask: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Fused unembed + cross-entropy over the TP-sharded vocab.

    Never materializes global logits: logsumexp uses a pmax/psum pair and
    the label logit a masked psum — the only cross-shard traffic is O(B*S).
    x: [B,S,d]; emb: [V_local, d]; labels: [B,S]. Returns mean NLL (f32).
    """
    v_local = emb.shape[0]
    logits = jnp.einsum(
        "bsd,vd->bsv", x, emb, preferred_element_type=_F32
    )  # [B,S,V_local] f32
    m_loc = jnp.max(logits, axis=-1)
    m = ax.pmax(jax.lax.stop_gradient(m_loc), ax.model)
    se = ax.psum_rep(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1), ax.model)
    start = ax.index(ax.model) * v_local if ax.model is not None else 0
    local = labels - start
    ok = (local >= 0) & (local < v_local)
    safe = jnp.clip(local, 0, v_local - 1)
    ll_loc = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    label_logit = ax.psum_rep(jnp.where(ok, ll_loc, 0.0), ax.model)
    nll = jnp.log(se) + m - label_logit  # [B,S]
    if mask is not None:
        nll = nll * mask
        denom = jnp.maximum(jnp.sum(mask), 1.0)
    else:
        denom = jnp.asarray(nll.size, _F32)
    return jnp.sum(nll) / denom


def unembed_greedy(
    x: jnp.ndarray, emb: jnp.ndarray, ax: Axes
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Greedy next token over the TP-sharded vocab without gathering logits.

    x: [B,d] -> (token [B] int32, logprob [B] f32).
    """
    v_local = emb.shape[0]
    logits = jnp.einsum("bd,vd->bv", x, emb, preferred_element_type=_F32)
    m_loc = jnp.max(logits, axis=-1)
    i_loc = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    m = ax.pmax(m_loc, ax.model)
    se = ax.psum(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1), ax.model)
    start = ax.index(ax.model) * v_local if ax.model is not None else 0
    is_max = m_loc >= m  # ties: every shard claiming max contributes; take min id
    big = jnp.iinfo(jnp.int32).max
    cand = jnp.where(is_max, i_loc + start, big)
    token = -ax.pmax(-cand, ax.model)  # global argmin over candidate ids
    logprob = m - jnp.log(se)
    return token.astype(jnp.int32), logprob


# ---------------------------------------------------------------------------
# MLPs (TP over d_ff; partial down-projection psum'ed over "model").
# ---------------------------------------------------------------------------


def mlp_swiglu(x, w_gate, w_up, w_down, ax: Axes,
               reduce_dtype=_F32) -> jnp.ndarray:
    g = dense(x, w_gate)
    u = dense(x, w_up)
    h = jax.nn.silu(g.astype(_F32)).astype(x.dtype) * u
    out = jnp.einsum("...f,fd->...d", h, w_down, preferred_element_type=_F32)
    # TP partial reduction; bf16 wire halves the dominant collective bytes.
    return ax.psum(out.astype(reduce_dtype), ax.model).astype(x.dtype)


def mlp_gelu(x, w1, b1, w2, b2, ax: Axes, reduce_dtype=_F32) -> jnp.ndarray:
    h = dense(x, w1) + b1.astype(x.dtype)
    h = jax.nn.gelu(h.astype(_F32)).astype(x.dtype)
    out = jnp.einsum("...f,fd->...d", h, w2, preferred_element_type=_F32)
    out = ax.psum(out.astype(reduce_dtype), ax.model)
    return (out.astype(_F32) + b2.astype(_F32)).astype(x.dtype)
