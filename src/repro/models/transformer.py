"""Unified model trunk for all 10 assigned architectures.

A model is a cycled ``block_pattern`` (attention / RG-LRU / SSD blocks)
scanned as stacked "superblocks" (one pattern repetition per scan step) +
an unstacked tail for non-divisible depths, plus vocab-sharded embeddings,
an optional whisper encoder (stub frame embeddings) and an optional VLM
patch-embedding prefix (prefix-LM masking).

Everything runs in manual SPMD (``Axes``): FSDP all-gathers per layer
(ZeRO-3 via AD transposition), TP over heads / d_ff / recurrence width,
psums only where partial sums cross the model axis.
"""
from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.distributed.axes import Axes, pvary_like
from repro.models import params as pm
from repro.models.attention import blockwise_attention
from repro.models.layers import (
    apply_rope,
    dense,
    embed,
    layer_norm,
    mlp_gelu,
    mlp_swiglu,
    rms_norm,
    sinusoidal_positions,
    unembed_loss,
)
from repro.models.moe import moe_swiglu
from repro.models.rglru import recurrent_block
from repro.models.ssd import ssd_block

__all__ = ["fwd_hidden", "fwd_train", "encode_frames", "Metrics"]

_F32 = jnp.float32


class Metrics(NamedTuple):
    loss: jnp.ndarray
    aux_loss: jnp.ndarray
    dropped: jnp.ndarray


# ---------------------------------------------------------------------------
# FSDP fetch.
# ---------------------------------------------------------------------------


def _fetch(ax: Axes, p: dict, fdims: dict) -> dict:
    return {
        k: (w if fdims[k] is None else ax.all_gather(w, ax.data, axis=fdims[k]))
        for k, w in p.items()
    }


# ---------------------------------------------------------------------------
# Blocks.
# ---------------------------------------------------------------------------


def _local_kv_slice(k, v, cfg: ModelConfig, ax: Axes):
    """Slice the (model-replicated) KV heads down to the groups needed by
    this shard's local q heads."""
    H, KV = cfg.n_heads, cfg.n_kv_heads
    tp_h = ax.tp_degree(H)
    if tp_h == 1:
        return k, v
    h_local = H // tp_h
    kv_count = max(1, (h_local * KV) // H)
    start = (ax.index(ax.model) * h_local * KV) // H
    k = jax.lax.dynamic_slice_in_dim(k, start, kv_count, axis=2)
    v = jax.lax.dynamic_slice_in_dim(v, start, kv_count, axis=2)
    return k, v


def _self_attention(
    x, p, cfg: ModelConfig, ax: Axes, positions, *, kind: str,
    prefix_len: int, capture: bool = False
):
    B, S, d = x.shape
    hd = cfg.head_dim
    tp_h = ax.tp_degree(cfg.n_heads)
    h_local = cfg.n_heads // tp_h
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    q = dense(h, p["wq"]).reshape(B, S, h_local, hd)
    k = dense(h, p["wk"]).reshape(B, S, cfg.n_kv_heads, hd)
    v = dense(h, p["wv"]).reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.family != "audio":  # whisper uses absolute positions
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    kv_full = (k, v) if capture else None  # all KV heads (paged-pool layout)
    k, v = _local_kv_slice(k, v, cfg, ax)
    window = cfg.window if kind in ("attn_swa", "attn_local") else None
    o = blockwise_attention(
        q, k, v, causal=True, window=window, prefix_len=prefix_len
    )
    out = jnp.einsum(
        "bshd,hdD->bsD",
        o.reshape(B, S, h_local, hd),
        p["wo"].reshape(h_local, hd, d),
        preferred_element_type=_F32,
    )
    if tp_h > 1:
        out = ax.psum(out.astype(jnp.dtype(cfg.tp_reduce_dtype)), ax.model)
    return out.astype(x.dtype), kv_full


def _cross_attention(x, enc_out, p, cfg: ModelConfig, ax: Axes):
    B, S, d = x.shape
    hd = cfg.head_dim
    tp_h = ax.tp_degree(cfg.n_heads)
    h_local = cfg.n_heads // tp_h
    h = rms_norm(x, p["xnorm"], cfg.norm_eps)
    q = dense(h, p["xwq"]).reshape(B, S, h_local, hd)
    k = dense(enc_out, p["xwk"]).reshape(B, enc_out.shape[1], cfg.n_kv_heads, hd)
    v = dense(enc_out, p["xwv"]).reshape(B, enc_out.shape[1], cfg.n_kv_heads, hd)
    k, v = _local_kv_slice(k, v, cfg, ax)
    o = blockwise_attention(q, k, v, causal=False)
    out = jnp.einsum(
        "bshd,hdD->bsD",
        o.reshape(B, S, h_local, hd),
        p["xwo"].reshape(h_local, hd, d),
        preferred_element_type=_F32,
    )
    if tp_h > 1:
        out = ax.psum(out.astype(jnp.dtype(cfg.tp_reduce_dtype)), ax.model)
    return out.astype(x.dtype)


def _ffn(x, p, cfg: ModelConfig, ax: Axes):
    """Dense / MoE / gelu FFN sub-block. Returns (delta, aux, dropped)."""
    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    if cfg.moe is not None:
        B, S, d = h.shape
        out = moe_swiglu(
            h.reshape(B * S, d),
            p["w_router"], p["w_gate"], p["w_up"], p["w_down"],
            cfg.moe, ax, reduce_dtype=jnp.dtype(cfg.tp_reduce_dtype),
        )
        return out.y.reshape(B, S, d), out.aux_loss, out.dropped
    rd = jnp.dtype(cfg.tp_reduce_dtype)
    if cfg.family == "audio":
        return (
            mlp_gelu(h, p["w1"], p["b1"], p["w2"], p["b2"], ax,
                     reduce_dtype=rd),
            jnp.zeros((), _F32),
            jnp.zeros((), _F32),
        )
    return (
        mlp_swiglu(h, p["w_gate"], p["w_up"], p["w_down"], ax,
                   reduce_dtype=rd),
        jnp.zeros((), _F32),
        jnp.zeros((), _F32),
    )


def apply_block(
    kind: str,
    x,
    p: dict,
    cfg: ModelConfig,
    ax: Axes,
    positions,
    *,
    prefix_len: int = 0,
    enc_out=None,
    capture: bool = False,
):
    """One block of the pattern. Returns (x, aux_loss, dropped, extras);
    ``extras`` (with capture) is the attention KV or recurrent state."""
    aux = jnp.zeros((), _F32)
    dropped = jnp.zeros((), _F32)
    extras = None
    if kind.startswith("attn"):
        delta, kv_full = _self_attention(
            x, p, cfg, ax, positions, kind=kind, prefix_len=prefix_len,
            capture=capture,
        )
        x = x + delta
        if enc_out is not None and "xwq" in p:
            x = x + _cross_attention(x, enc_out, p, cfg, ax)
        delta, aux, dropped = _ffn(x, p, cfg, ax)
        x = x + delta
        extras = kv_full
    elif kind == "rglru":
        h = rms_norm(x, p["norm"], cfg.norm_eps)
        delta, state = recurrent_block(
            h, p, ax, capture=capture,
            reduce_dtype=jnp.dtype(cfg.tp_reduce_dtype))
        x = x + delta
        delta, aux, dropped = _ffn(x, p, cfg, ax)
        x = x + delta
        extras = state
    elif kind == "ssd":
        h = rms_norm(x, p["norm"], cfg.norm_eps)
        delta, state = ssd_block(
            h, p, cfg.ssm or SSMConfig(), ax, capture=capture,
            reduce_dtype=jnp.dtype(cfg.tp_reduce_dtype))
        x = x + delta
        extras = state
    else:
        raise ValueError(kind)
    return x, aux, dropped, extras


# ---------------------------------------------------------------------------
# Trunk.
# ---------------------------------------------------------------------------


def encode_frames(frames, params, cfg: ModelConfig, ax: Axes, fdims) -> jnp.ndarray:
    """Whisper encoder over stub frame embeddings [B, T_enc, d]."""
    T = frames.shape[1]
    pos = jnp.arange(T)
    x = frames + sinusoidal_positions(pos, cfg.d_model)[None].astype(frames.dtype)

    def body(x, layer_p):
        pf = _fetch(ax, layer_p, fdims["enc_blocks"][0])
        h = rms_norm(x, pf["norm"], cfg.norm_eps)
        B, S, d = x.shape
        hd = cfg.head_dim
        tp_h = ax.tp_degree(cfg.n_heads)
        h_local = cfg.n_heads // tp_h
        q = dense(h, pf["wq"]).reshape(B, S, h_local, hd)
        k = dense(h, pf["wk"]).reshape(B, S, cfg.n_kv_heads, hd)
        v = dense(h, pf["wv"]).reshape(B, S, cfg.n_kv_heads, hd)
        k, v = _local_kv_slice(k, v, cfg, ax)
        o = blockwise_attention(q, k, v, causal=False)
        out = jnp.einsum(
            "bshd,hdD->bsD", o.reshape(B, S, h_local, hd),
            pf["wo"].reshape(h_local, hd, d), preferred_element_type=_F32,
        )
        if tp_h > 1:
            out = ax.psum(out, ax.model)
        x = x + out.astype(x.dtype)
        delta, _, _ = _ffn(x, pf, cfg, ax)
        return x + delta, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"][0])
    return rms_norm(x, params["enc_final_norm"], cfg.norm_eps)


def fwd_hidden(
    params: dict,
    tokens: jnp.ndarray,
    cfg: ModelConfig,
    ax: Axes,
    *,
    prefix_embeds: Optional[jnp.ndarray] = None,
    frames: Optional[jnp.ndarray] = None,
    fdims: Optional[dict] = None,
    ms: Optional[pm.MeshSizes] = None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Token ids -> final hidden states. Returns (x, aux_loss, dropped)."""
    ms = ms or pm.MeshSizes()
    fdims = fdims or pm.fsdp_dims(cfg, ms)
    emb = params["embed"]
    emb_g = emb if fdims["embed"] is None else ax.all_gather(emb, ax.data, axis=1)
    x = embed(tokens, emb_g, ax)

    prefix_len = 0
    if prefix_embeds is not None:
        prefix_len = prefix_embeds.shape[1]
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)

    S = x.shape[1]
    positions = jnp.arange(S)[None, :]
    if cfg.family == "audio":
        x = x + sinusoidal_positions(positions[0], cfg.d_model)[None].astype(x.dtype)

    enc_out = None
    if cfg.enc_dec:
        assert frames is not None, "whisper needs stub frame embeddings"
        enc_out = encode_frames(frames, params, cfg, ax, fdims)

    pattern = cfg.block_pattern
    aux_total = pvary_like(jnp.zeros((), _F32), x)
    drop_total = pvary_like(jnp.zeros((), _F32), x)

    def superblock(carry, layer_ps):
        x, aux, drop = carry
        for i, kind in enumerate(pattern):
            pf = _fetch(ax, layer_ps[i], fdims["blocks"][i])
            x, a, dr, _ = apply_block(
                kind, x, pf, cfg, ax, positions,
                prefix_len=prefix_len, enc_out=enc_out,
            )
            aux = aux + a
            drop = drop + dr
        return (x, aux, drop), None

    body = jax.checkpoint(superblock) if cfg.remat else superblock
    reps, tail = pm.model_layout(cfg)
    if reps:
        (x, aux_total, drop_total), _ = jax.lax.scan(
            body, (x, aux_total, drop_total), params["blocks"]
        )
    for i, kind in enumerate(tail):
        pf = _fetch(ax, params["tail"][i], fdims["tail"][i])
        x, a, dr, _ = apply_block(
            kind, x, pf, cfg, ax, positions,
            prefix_len=prefix_len, enc_out=enc_out,
        )
        aux_total = aux_total + a
        drop_total = drop_total + dr

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux_total, drop_total


def fwd_train(
    params: dict,
    batch: dict,
    cfg: ModelConfig,
    ax: Axes,
    *,
    ms: Optional[pm.MeshSizes] = None,
    aux_weight: float = 0.01,
) -> tuple[jnp.ndarray, Metrics]:
    """Next-token LM loss (globally batch-mean'ed across data/pod shards)."""
    ms = ms or pm.MeshSizes()
    fdims = pm.fsdp_dims(cfg, ms)
    x, aux, dropped = fwd_hidden(
        params,
        batch["tokens"],
        cfg,
        ax,
        prefix_embeds=batch.get("prefix_embeds"),
        frames=batch.get("frames"),
        fdims=fdims,
        ms=ms,
    )
    if cfg.vlm_prefix:
        x = x[:, batch["prefix_embeds"].shape[1]:]
    emb_key = "embed" if cfg.tie_embeddings or "unembed" not in params else "unembed"
    ue = params[emb_key]
    ue_g = ue if fdims[emb_key] is None else ax.all_gather(ue, ax.data, axis=1)
    loss = unembed_loss(x, ue_g, batch["labels"], ax)
    loss = loss + aux_weight * aux
    # Mean across the data axis in-graph (AD then inserts the correct FSDP/TP
    # grad reductions). The pod axis is reduced explicitly by the train step
    # so inter-pod gradient traffic can be compressed.
    loss = ax.pmean(loss, ax.data)
    return loss, Metrics(loss=loss, aux_loss=aux, dropped=dropped)
