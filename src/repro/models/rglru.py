"""RG-LRU recurrent block (recurrentgemma / Griffin).

The Griffin recurrent block: two branches from the residual stream —
GeLU(x·W1) gating a (x·W2 -> causal conv1d -> RG-LRU) branch — merged by an
output projection.

RG-LRU recurrence (per channel, gates diagonal — see DESIGN.md for the
block-diagonal simplification note):

    r_t = sigmoid(w_a * u_t + b_a)              (recurrence gate)
    i_t = sigmoid(w_x * u_t + b_x)              (input gate)
    log a_t = -c * softplus(Lambda) * r_t       (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

Training uses ``jax.lax.associative_scan`` (log-depth linear recurrence);
decode is the single-step update with carried state. The recurrence is
elementwise over the LRU width, so it is embarrassingly TP-sharded.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.axes import Axes
from repro.models.layers import dense

__all__ = ["rglru_scan", "rglru_step", "recurrent_block", "recurrent_block_step"]

_F32 = jnp.float32
_C = 8.0


def _gates(u, w_a, b_a, w_x, b_x, lam):
    uf = u.astype(_F32)
    r = jax.nn.sigmoid(uf * w_a + b_a)
    i = jax.nn.sigmoid(uf * w_x + b_x)
    log_a = -_C * jax.nn.softplus(lam) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * uf)
    return a, b


def rglru_scan(u: jnp.ndarray, w_a, b_a, w_x, b_x, lam) -> jnp.ndarray:
    """u: [B, S, W] -> h: [B, S, W] via associative scan over S."""
    a, b = _gates(u, w_a.astype(_F32), b_a.astype(_F32),
                  w_x.astype(_F32), b_x.astype(_F32), lam.astype(_F32))

    def op(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    _, h = jax.lax.associative_scan(op, (a, b), axis=1)
    return h.astype(u.dtype)


def rglru_step(u, h_prev, w_a, b_a, w_x, b_x, lam):
    """Single decode step. u: [B, W], h_prev: [B, W] (f32)."""
    a, b = _gates(u, w_a.astype(_F32), b_a.astype(_F32),
                  w_x.astype(_F32), b_x.astype(_F32), lam.astype(_F32))
    h = a * h_prev + b
    return h.astype(u.dtype), h


def _causal_conv1d(x: jnp.ndarray, kernel: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv. x: [B, S, W], kernel: [K, W]."""
    K = kernel.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=_F32)
    for k in range(K):
        out = out + xp[:, k : k + x.shape[1]].astype(_F32) * kernel[k].astype(_F32)
    return out.astype(x.dtype)


def recurrent_block(
    x: jnp.ndarray, p: dict, ax: Axes, *, capture: bool = False,
    reduce_dtype=_F32,
):
    """Full Griffin recurrent block, training form. x: [B, S, d].

    Params (w = lru width, TP-sharded on dim1 of the projections):
      w1 [d, w_l], w2 [d, w_l], w_out [w_l, d], conv [K, w_l],
      gate params w_a/b_a/w_x/b_x/lam [w_l].

    With ``capture``, also returns the decode-continuation state
    {"h": [B, w_l] f32, "conv": [B, K-1, w_l]} (prefill -> decode handoff).
    """
    y1 = jax.nn.gelu(dense(x, p["w1"]).astype(_F32)).astype(x.dtype)
    u_pre = dense(x, p["w2"])
    u = _causal_conv1d(u_pre, p["conv"])
    h = rglru_scan(u, p["w_a"], p["b_a"], p["w_x"], p["b_x"], p["lam"])
    merged = (y1.astype(_F32) * h.astype(_F32)).astype(x.dtype)
    out = jnp.einsum("bsw,wd->bsd", merged, p["w_out"], preferred_element_type=_F32)
    out = ax.psum(out.astype(reduce_dtype), ax.model).astype(x.dtype)
    if not capture:
        return out, None
    K = p["conv"].shape[0]
    state = {
        "h": h[:, -1].astype(_F32),
        "conv": u_pre[:, -(K - 1):],
    }
    return out, state


def recurrent_block_step(
    x: jnp.ndarray, state: dict, p: dict, ax: Axes
) -> tuple[jnp.ndarray, dict]:
    """Decode step. x: [B, d]. state: {"h": [B,w_l] f32, "conv": [B,K-1,w_l]}."""
    y1 = jax.nn.gelu(dense(x, p["w1"]).astype(_F32)).astype(x.dtype)
    u_in = dense(x, p["w2"])  # [B, w_l]
    K = p["conv"].shape[0]
    window = jnp.concatenate([state["conv"], u_in[:, None, :]], axis=1)  # [B,K,w]
    u = jnp.einsum("bkw,kw->bw", window.astype(_F32), p["conv"].astype(_F32))
    u = u.astype(x.dtype)
    h_out, h_new = rglru_step(
        u, state["h"], p["w_a"], p["b_a"], p["w_x"], p["b_x"], p["lam"]
    )
    merged = (y1.astype(_F32) * h_out.astype(_F32)).astype(x.dtype)
    out = jnp.einsum("bw,wd->bd", merged, p["w_out"], preferred_element_type=_F32)
    out = ax.psum(out, ax.model).astype(x.dtype)
    new_state = {"h": h_new, "conv": window[:, 1:]}
    return out, new_state
