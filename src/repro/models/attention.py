"""Blockwise (flash-style) attention in pure JAX.

This is the production XLA path (and the CPU dry-run path): O(block) memory
via online softmax, static skipping of out-of-range KV blocks for causal /
sliding-window patterns (so HLO FLOPs reflect the *real* cost — important
for the roofline). The Pallas TPU kernel (``kernels/flash_attention.py``)
implements the same tiling for the MXU; this module doubles as its
shape/semantics reference.

Mask patterns: full (bidirectional), causal, causal+window (SWA / local),
prefix-LM (bidirectional prefix + causal suffix). GQA via head groups.

``attention_partial`` / ``combine_partials`` expose the online-softmax
partial state so *distributed* decode can combine per-device partial
attention over policy-mapped KV pages (DESIGN.md §2) with a tiny psum/pmax.
"""
from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.distributed.axes import Axes, pvary_like

__all__ = [
    "blockwise_attention",
    "attention_partial",
    "combine_partials",
    "Partial",
]

_F32 = jnp.float32
_NEG = -1e30


def _block_scores(q, k, scale):
    # q: [B, bq, KV, G, hd]  k: [B, bk, KV, hd] -> [B, KV, G, bq, bk]
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k, preferred_element_type=_F32)
    return s * scale


def _mask(
    q_pos: jnp.ndarray,  # [bq]
    kv_pos: jnp.ndarray,  # [bk]
    *,
    causal: bool,
    window: Optional[int],
    prefix_len: int,
    kv_len: jnp.ndarray | int,
) -> jnp.ndarray:
    ok = kv_pos[None, :] < kv_len  # kv padding / valid length
    if causal:
        vis = q_pos[:, None] >= kv_pos[None, :]
        if window is not None:
            vis &= kv_pos[None, :] > (q_pos[:, None] - window)
        if prefix_len:
            vis |= kv_pos[None, :] < prefix_len
        ok &= vis
    return ok  # [bq, bk]


def blockwise_attention(
    q: jnp.ndarray,  # [B, Sq, H, hd]
    k: jnp.ndarray,  # [B, Skv, KV, hd]
    v: jnp.ndarray,  # [B, Skv, KV, hd]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    prefix_len: int = 0,
    q_offset: int = 0,
    kv_len: Optional[jnp.ndarray] = None,
    block_q: int = 512,
    block_kv: int = 512,
) -> jnp.ndarray:
    """Online-softmax attention with statically-skipped KV blocks.

    The Python loop over q blocks is static, so each q block slices only the
    KV range it can see (triangular for causal, banded for windows) — the
    lowered HLO does no masked-away work beyond block granularity.
    """
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    scale = 1.0 / math.sqrt(hd)

    bq = min(block_q, Sq)
    bk = min(block_kv, Skv)
    nq = -(-Sq // bq)
    pad_q = nq * bq - Sq
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    nkv_total = -(-Skv // bk)
    pad_k = nkv_total * bk - Skv
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    if kv_len is None:
        kv_len = Skv

    qg = q.reshape(B, nq, bq, KV, G, hd)
    outs = []
    for i in range(nq):
        # Static KV block range visible to q block i.
        q_lo = q_offset + i * bq
        q_hi = q_offset + (i + 1) * bq - 1
        if causal:
            hi = min(nkv_total, -(-(q_hi + 1) // bk))
            lo = 0
            if window is not None:
                lo = max(0, (q_lo - window + 1) // bk)
            if prefix_len:
                lo = 0
                hi = max(hi, -(-prefix_len // bk))
            hi = max(hi, lo + 1)
        else:
            lo, hi = 0, nkv_total
        n_blocks = hi - lo
        k_slice = jax.lax.slice_in_dim(k, lo * bk, hi * bk, axis=1)
        v_slice = jax.lax.slice_in_dim(v, lo * bk, hi * bk, axis=1)
        k_blocks = k_slice.reshape(B, n_blocks, bk, KV, hd)
        v_blocks = v_slice.reshape(B, n_blocks, bk, KV, hd)
        q_i = qg[:, i]  # [B, bq, KV, G, hd]
        q_pos = q_offset + i * bq + jnp.arange(bq)

        def kv_step(carry, inp, q_i=q_i, q_pos=q_pos, lo=lo):
            m, l, acc = carry
            j, k_b, v_b = inp
            s = _block_scores(q_i, k_b, scale)  # [B, KV, G, bq, bk]
            kv_pos = (lo + j) * bk + jnp.arange(bk)
            msk = _mask(
                q_pos, kv_pos, causal=causal, window=window,
                prefix_len=prefix_len, kv_len=kv_len,
            )
            s = jnp.where(msk[None, None, None], s, _NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum(
                "bkgqs,bskh->bkgqh", p, v_b.astype(_F32),
                preferred_element_type=_F32,
            )
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = pvary_like(jnp.full((B, KV, G, bq), _NEG, _F32), q)
        l0 = pvary_like(jnp.zeros((B, KV, G, bq), _F32), q)
        a0 = pvary_like(jnp.zeros((B, KV, G, bq, hd), _F32), q)
        (m, l, acc), _ = jax.lax.scan(
            kv_step,
            (m0, l0, a0),
            (jnp.arange(n_blocks), jnp.moveaxis(k_blocks, 1, 0),
             jnp.moveaxis(v_blocks, 1, 0)),
        )
        out_i = acc / jnp.maximum(l, 1e-30)[..., None]
        outs.append(out_i)  # [B, KV, G, bq, hd]

    out = jnp.stack(outs, axis=3)  # [B, KV, G, nq, bq, hd]
    out = out.reshape(B, KV, G, nq * bq, hd)
    out = jnp.moveaxis(out, 3, 1)  # [B, S, KV, G, hd]
    out = out.reshape(B, nq * bq, H, hd)
    if pad_q:
        out = out[:, :Sq]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Partial attention for distributed decode over policy-mapped pages.
# ---------------------------------------------------------------------------


class Partial(NamedTuple):
    acc: jnp.ndarray  # [..., hd] f32 — unnormalized weighted values
    m: jnp.ndarray    # [...]     f32 — running max
    l: jnp.ndarray    # [...]     f32 — running sum of exp


def attention_partial(
    q: jnp.ndarray,      # [B, H, hd] single-token query
    k: jnp.ndarray,      # [B, T, KV, hd] local KV slice (may be masked)
    v: jnp.ndarray,
    valid: jnp.ndarray,  # [B, T] bool — which local positions are live
) -> Partial:
    B, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgh,btkh->bkgt", qg, k, preferred_element_type=_F32) * scale
    s = jnp.where(valid[:, None, None, :], s, _NEG)
    m = jnp.max(s, axis=-1)  # [B, KV, G]
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum(
        "bkgt,btkh->bkgh", p, v.astype(_F32), preferred_element_type=_F32
    )
    return Partial(acc=acc, m=m, l=l)


def combine_partials(p: Partial, ax: Axes, names) -> jnp.ndarray:
    """Combine per-device partial attention (flash-decoding across shards).

    Collective traffic per combine: O(B*H*hd) — three small reductions,
    instead of moving any KV page across the fabric.
    """
    m_g = ax.pmax_many(p.m, names)
    corr = jnp.exp(p.m - m_g)
    l_g = ax.psum_many(p.l * corr, names)
    acc_g = ax.psum_many(p.acc * corr[..., None], names)
    out = acc_g / jnp.maximum(l_g, 1e-30)[..., None]
    B, KV, G, hd = out.shape
    return out.reshape(B, KV * G, hd)
