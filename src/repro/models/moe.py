"""Mixture-of-Experts layer (grok-1 / mixtral: 8 experts, top-2).

Dispatch is the paper's request-routing problem in miniature: tokens are IO
requests, experts are storage shards, and the dispatch buffer is a bounded
IO queue (capacity factor == queue depth). We use a *local* capacity-buffer
dispatch: position-in-expert via a cumsum over one-hot assignments, a
scatter into an [E, C, d] buffer, batched expert matmuls, and a gather back
— all local to the device (tokens stay on their data shard; expert weights
are TP-sharded over d_ff, FSDP-sharded over d_model). No GSPMD guessing:
the only collective is the down-projection psum over "model".

An EP (expert-parallel all_to_all) variant is a §Perf hillclimb option —
see EXPERIMENTS.md.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.distributed.axes import Axes
from repro.models.layers import dense

__all__ = ["MoEOut", "moe_swiglu", "capacity"]

_F32 = jnp.float32


class MoEOut(NamedTuple):
    y: jnp.ndarray         # [T, d]
    aux_loss: jnp.ndarray  # load-balance loss (switch-style)
    dropped: jnp.ndarray   # fraction of (token, k) slots dropped


def capacity(n_tokens: int, cfg: MoEConfig) -> int:
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def moe_swiglu(
    x: jnp.ndarray,          # [T, d] local tokens
    w_router: jnp.ndarray,   # [d, E] (replicated over model, FSDP dim0)
    w_gate: jnp.ndarray,     # [E, d, f_local]
    w_up: jnp.ndarray,       # [E, d, f_local]
    w_down: jnp.ndarray,     # [E, f_local, d]
    cfg: MoEConfig,
    ax: Axes,
    reduce_dtype=_F32,
) -> MoEOut:
    T, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = capacity(T, cfg)

    logits = jnp.einsum("td,de->te", x, w_router, preferred_element_type=_F32)
    probs_full = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs_full, K)          # [T, K]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)  # renormalize

    # Load-balance aux loss (fraction routed vs mean router prob).
    frac = jnp.mean(
        jax.nn.one_hot(top_e[:, 0], E, dtype=_F32), axis=0
    )  # top-1 routing fraction
    mean_p = jnp.mean(probs_full, axis=0)
    aux = E * jnp.sum(frac * mean_p)

    # Position of each (token, k) slot within its expert queue.
    e_flat = top_e.reshape(-1)                            # [T*K]
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)   # [T*K, E]
    pos = jnp.take_along_axis(
        jnp.cumsum(onehot, axis=0) - 1, e_flat[:, None], axis=1
    )[:, 0]                                               # [T*K]
    keep = pos < C
    dropped = 1.0 - jnp.mean(keep.astype(_F32))
    slot = jnp.where(keep, e_flat * C + pos, E * C)       # overflow -> scratch row

    # Dispatch: scatter tokens into the expert buffers (+1 scratch row).
    tok_idx = jnp.repeat(jnp.arange(T), K)
    xb = jnp.zeros((E * C + 1, d), x.dtype).at[slot].set(x[tok_idx])
    xb = xb[: E * C].reshape(E, C, d)

    # Expert computation (batched over E; f_local is the TP shard).
    g = jnp.einsum("ecd,edf->ecf", xb, w_gate, preferred_element_type=_F32)
    u = jnp.einsum("ecd,edf->ecf", xb, w_up, preferred_element_type=_F32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    out_e = jnp.einsum("ecf,efd->ecd", h, w_down, preferred_element_type=_F32)

    # Combine: gather each slot's output, weight by router prob, sum over K.
    flat = jnp.concatenate(
        [out_e.reshape(E * C, d), jnp.zeros((1, d), out_e.dtype)], axis=0
    )
    y_slots = flat[slot] * (top_p.reshape(-1)[:, None] * keep[:, None])
    y = jnp.sum(y_slots.reshape(T, K, d), axis=1)
    y = ax.psum(y.astype(reduce_dtype), ax.model)  # TP partial reduction
    return MoEOut(y=y.astype(x.dtype), aux_loss=aux, dropped=dropped)
