"""Declarative parameter definitions: shapes, sharding, init.

Every leaf is described by a :class:`ParamDef` with a global shape plus the
dims that are FSDP-sharded (over "data") and TP-sharded (over "model").
Stacked-layer leaves get a leading layer dim (never sharded). The same defs
produce: init pytrees (smoke tests), ShapeDtypeStruct pytrees + PartitionSpecs
(dry-run), and the per-leaf FSDP-gather dims used inside the forward scan.

TP rule (``Axes.tp_degree``): a dim is TP-sharded only when the mesh model
axis divides it; otherwise compute is replicated across the model axis
(e.g. whisper-tiny's 6 heads on a 16-wide model axis).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, SSMConfig

__all__ = [
    "ParamDef",
    "MeshSizes",
    "build_defs",
    "init_params",
    "param_structs",
    "param_pspecs",
    "fsdp_dims",
    "pad_vocab",
]


@dataclasses.dataclass(frozen=True)
class MeshSizes:
    data: int = 1
    model: int = 1

    def tp(self, n: int) -> int:
        return self.model if (self.model > 1 and n % self.model == 0) else 1


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]          # per-layer (unstacked) global shape
    fsdp_dim: Optional[int] = None  # dim sharded over "data"
    tp_dim: Optional[int] = None    # dim sharded over "model"
    init: str = "normal"            # normal | zeros | ones | lambda
    scale: float = 0.02
    # Gradient sync over the model axis (manual SPMD, check_rep=False):
    # True  => forward consumers are split over "model" (grads are partial,
    #          psum over "model" required);
    # False => leaf is TP-owned or its use is fully replicated (grads are
    #          already correct / identical across the model axis).
    model_grad: bool = False


def pad_vocab(v: int, multiple: int = 256) -> int:
    return -(-v // multiple) * multiple


# ---------------------------------------------------------------------------
# Per-block-kind parameter tables.
# ---------------------------------------------------------------------------


def _attn_defs(cfg: ModelConfig, ms: MeshSizes, cross: bool = False) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    tp_h = ms.tp(H)
    split = tp_h > 1
    pre = "x" if cross else ""
    defs = {
        f"{pre}wq": ParamDef((d, H * hd), 0, 1 if split else None,
                             scale=d ** -0.5),
        f"{pre}wk": ParamDef((d, KV * hd), 0, None, scale=d ** -0.5,
                             model_grad=split),
        f"{pre}wv": ParamDef((d, KV * hd), 0, None, scale=d ** -0.5,
                             model_grad=split),
        f"{pre}wo": ParamDef((H * hd, d), 1, 0 if split else None,
                             scale=(H * hd) ** -0.5),
        f"{pre}norm": ParamDef((d,), init="zeros", model_grad=split),
    }
    return defs


def _mlp_defs(cfg: ModelConfig, ms: MeshSizes) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    tp_f = ms.tp(f)
    tpd = 1 if tp_f > 1 else None
    split = tp_f > 1
    if cfg.family == "audio":  # gelu mlp with biases (whisper)
        return {
            "w1": ParamDef((d, f), 0, tpd, scale=d ** -0.5),
            "b1": ParamDef((f,), None, 0 if split else None, init="zeros"),
            "w2": ParamDef((f, d), 1, 0 if split else None, scale=f ** -0.5),
            "b2": ParamDef((d,), init="zeros"),
            "norm2": ParamDef((d,), init="zeros", model_grad=split),
        }
    if cfg.moe is not None:
        E = cfg.moe.n_experts
        return {
            "w_router": ParamDef((d, E), 0, None, scale=d ** -0.5,
                                 model_grad=split),
            "w_gate": ParamDef((E, d, f), 1, 2 if split else None,
                               scale=d ** -0.5),
            "w_up": ParamDef((E, d, f), 1, 2 if split else None,
                             scale=d ** -0.5),
            "w_down": ParamDef((E, f, d), 2, 1 if split else None,
                               scale=f ** -0.5),
            "norm2": ParamDef((d,), init="zeros", model_grad=split),
        }
    return {
        "w_gate": ParamDef((d, f), 0, tpd, scale=d ** -0.5),
        "w_up": ParamDef((d, f), 0, tpd, scale=d ** -0.5),
        "w_down": ParamDef((f, d), 1, 0 if split else None, scale=f ** -0.5),
        "norm2": ParamDef((d,), init="zeros", model_grad=split),
    }


def _rglru_defs(cfg: ModelConfig, ms: MeshSizes) -> dict:
    d = cfg.d_model
    w = d  # lru width = d_model
    tp_w = ms.tp(w)
    tpd = 1 if tp_w > 1 else None
    vec_tp = 0 if tp_w > 1 else None
    return {
        "w1": ParamDef((d, w), 0, tpd, scale=d ** -0.5),
        "w2": ParamDef((d, w), 0, tpd, scale=d ** -0.5),
        "w_out": ParamDef((w, d), 1, 0 if tp_w > 1 else None, scale=w ** -0.5),
        "conv": ParamDef((4, w), None, 1 if tp_w > 1 else None, scale=0.1),
        "w_a": ParamDef((w,), None, vec_tp, scale=0.5),
        "b_a": ParamDef((w,), None, vec_tp, init="zeros"),
        "w_x": ParamDef((w,), None, vec_tp, scale=0.5),
        "b_x": ParamDef((w,), None, vec_tp, init="zeros"),
        "lam": ParamDef((w,), None, vec_tp, init="lambda"),
        "norm": ParamDef((d,), init="zeros", model_grad=tp_w > 1),
    }


def _ssd_defs(cfg: ModelConfig, ms: MeshSizes) -> dict:
    d = cfg.d_model
    s = cfg.ssm or SSMConfig()
    di = s.expand * d
    H = di // s.head_dim
    N = s.state_dim
    tp_i = ms.tp(di) if ms.tp(di) == ms.tp(H) else 1  # heads & width together
    tpd = 1 if tp_i > 1 else None
    vec_tp = 0 if tp_i > 1 else None
    split = tp_i > 1
    return {
        "w_z": ParamDef((d, di), 0, tpd, scale=d ** -0.5),
        "w_x": ParamDef((d, di), 0, tpd, scale=d ** -0.5),
        "w_bc": ParamDef((d, 2 * N), 0, None, scale=d ** -0.5, model_grad=split),
        "w_dt": ParamDef((d, H), 0, tpd, scale=d ** -0.5),
        "conv_x": ParamDef((s.conv_width, di), None, 1 if split else None,
                           scale=0.1),
        "conv_b": ParamDef((s.conv_width, N), None, None, scale=0.1,
                           model_grad=split),
        "conv_c": ParamDef((s.conv_width, N), None, None, scale=0.1,
                           model_grad=split),
        "A_log": ParamDef((H,), None, vec_tp, init="ones"),
        "dt_bias": ParamDef((H,), None, vec_tp, init="zeros"),
        "D": ParamDef((H,), None, vec_tp, init="ones"),
        "norm_g": ParamDef((di,), None, vec_tp, init="zeros"),
        "w_out": ParamDef((di, d), 1, 0 if split else None, scale=di ** -0.5),
        "norm": ParamDef((d,), init="zeros", model_grad=split),
    }


def block_defs(kind: str, cfg: ModelConfig, ms: MeshSizes, *, decoder: bool = False) -> dict:
    """Parameter defs for one block of the given kind."""
    defs: dict[str, ParamDef] = {}
    if kind.startswith("attn"):
        defs.update(_attn_defs(cfg, ms))
        if decoder and cfg.enc_dec:
            defs.update(_attn_defs(cfg, ms, cross=True))
        defs.update(_mlp_defs(cfg, ms))
    elif kind == "rglru":
        defs.update(_rglru_defs(cfg, ms))
        defs.update(_mlp_defs(cfg, ms))
    elif kind == "ssd":
        defs.update(_ssd_defs(cfg, ms))
    else:
        raise ValueError(kind)
    return defs


# ---------------------------------------------------------------------------
# Whole-model defs: pattern superblocks (stacked) + tail + embeddings (+enc).
# ---------------------------------------------------------------------------


def model_layout(cfg: ModelConfig) -> tuple[int, tuple[str, ...]]:
    """(n_superblock_repeats, tail_kinds)."""
    p = len(cfg.block_pattern)
    reps = cfg.n_layers // p
    tail = cfg.layer_kinds()[reps * p:]
    return reps, tail


def build_defs(cfg: ModelConfig, ms: MeshSizes) -> dict:
    """Full nested ParamDef tree (mirrors the params pytree structure)."""
    return _apply_fsdp_toggle(_build_defs_inner(cfg, ms), cfg)


def _build_defs_inner(cfg: ModelConfig, ms: MeshSizes) -> dict:
    reps, tail = model_layout(cfg)
    vp = pad_vocab(cfg.vocab)
    split_v = ms.model > 1
    tree: dict = {
        "embed": ParamDef((vp, cfg.d_model), 1, 0, scale=0.02),
        "final_norm": ParamDef((cfg.d_model,), init="zeros",
                               model_grad=split_v),
        "blocks": [
            block_defs(k, cfg, ms, decoder=cfg.enc_dec)
            for k in cfg.block_pattern
        ],
        "tail": [
            block_defs(k, cfg, ms, decoder=cfg.enc_dec) for k in tail
        ],
    }
    if not cfg.tie_embeddings:
        tree["unembed"] = ParamDef((vp, cfg.d_model), 1, 0, scale=0.02)
    if cfg.enc_dec:
        tree["enc_blocks"] = [block_defs("attn_full", cfg, ms)]
        tree["enc_final_norm"] = ParamDef((cfg.d_model,), init="zeros")
    return tree


def _leaf_init(d: ParamDef, key, dtype) -> jnp.ndarray:
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    if d.init == "lambda":  # RG-LRU Lambda: a in [0.9, 0.999]
        u = jax.random.uniform(key, d.shape, jnp.float32, 0.9, 0.999)
        # softplus^{-1}(-log(a)/c) with c=8
        x = -jnp.log(u) / 8.0
        lam = jnp.log(jnp.expm1(jnp.maximum(x, 1e-8)))
        return lam.astype(dtype)
    return (jax.random.normal(key, d.shape, jnp.float32) * d.scale).astype(dtype)


def _apply_fsdp_toggle(defs, cfg):
    """Drop FSDP sharding when cfg.fsdp is False (params replicated over
    "data"; kills the per-layer weight all-gathers at the cost of per-device
    param/optimizer memory — a §Perf trade for mid-sized models)."""
    if cfg.fsdp:
        return defs

    def strip(d):
        if isinstance(d, ParamDef):
            return dataclasses.replace(d, fsdp_dim=None)
        if isinstance(d, dict):
            return {k: strip(v) for k, v in d.items()}
        if isinstance(d, list):
            return [strip(v) for v in d]
        return d

    return strip(defs)


def _map_tree(tree, fn, *, stack: dict[int, int]):
    """Apply fn(def, path, n_stack) over the def tree. 'blocks'/'enc_blocks'
    entries are stacked with their repeat counts from ``stack``."""
    out = {}
    for name, sub in tree.items():
        if name == "blocks":
            out[name] = [
                {k: fn(d, (name, i, k), stack["blocks"]) for k, d in blk.items()}
                for i, blk in enumerate(sub)
            ]
        elif name == "enc_blocks":
            out[name] = [
                {k: fn(d, (name, i, k), stack["enc_blocks"]) for k, d in blk.items()}
                for i, blk in enumerate(sub)
            ]
        elif name == "tail":
            out[name] = [
                {k: fn(d, (name, i, k), 0) for k, d in blk.items()}
                for i, blk in enumerate(sub)
            ]
        else:
            out[name] = fn(sub, (name,), 0)
    return out


def _stacks(cfg: ModelConfig) -> dict[int, int]:
    reps, _ = model_layout(cfg)
    return {"blocks": reps, "enc_blocks": cfg.n_enc_layers}


def init_params(cfg: ModelConfig, key, ms: MeshSizes = MeshSizes()) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    defs = build_defs(cfg, ms)
    keys = iter(jax.random.split(key, 4096))

    def fn(d: ParamDef, path, n_stack):
        if n_stack:
            sub = jax.random.split(next(keys), n_stack)
            return jnp.stack([_leaf_init(d, k, dtype) for k in sub])
        return _leaf_init(d, next(keys), dtype)

    return _map_tree(defs, fn, stack=_stacks(cfg))


def param_structs(cfg: ModelConfig, ms: MeshSizes = MeshSizes()) -> dict:
    """ShapeDtypeStruct pytree (dry-run: no allocation)."""
    dtype = jnp.dtype(cfg.param_dtype)
    defs = build_defs(cfg, ms)

    def fn(d: ParamDef, path, n_stack):
        shape = (n_stack,) + d.shape if n_stack else d.shape
        return jax.ShapeDtypeStruct(shape, dtype)

    return _map_tree(defs, fn, stack=_stacks(cfg))


def param_pspecs(
    cfg: ModelConfig,
    ms: MeshSizes = MeshSizes(),
    *,
    data_axis: Optional[str] = "data",
    model_axis: Optional[str] = "model",
) -> dict:
    """PartitionSpec pytree matching the params tree."""
    defs = build_defs(cfg, ms)

    def fn(d: ParamDef, path, n_stack):
        ndim = len(d.shape)
        axes: list = [None] * ndim
        if d.fsdp_dim is not None and data_axis and ms.data > 1:
            axes[d.fsdp_dim] = data_axis
        if d.tp_dim is not None and model_axis and ms.model > 1:
            axes[d.tp_dim] = model_axis
        if n_stack:
            axes = [None] + axes
        return P(*axes)

    return _map_tree(defs, fn, stack=_stacks(cfg))


def fsdp_dims(cfg: ModelConfig, ms: MeshSizes = MeshSizes()) -> dict:
    """Per-leaf FSDP dim (in the per-layer view) or None — used by the
    forward pass to all-gather each layer's weights (ZeRO-3)."""
    defs = build_defs(cfg, ms)

    def fn(d: ParamDef, path, n_stack):
        return d.fsdp_dim

    return _map_tree(defs, fn, stack=_stacks(cfg))


def grad_sync(cfg: ModelConfig, ms: MeshSizes = MeshSizes()) -> dict:
    """Per-leaf gradient sync spec: dict(data=bool, model=bool).

    data=True  => leaf is NOT FSDP-sharded, grads need psum over "data"
                  (FSDP leaves are reduced by the all-gather transpose).
    model=True => forward consumers split over "model": psum over "model".
    Grads always need psum over "pod" (pure DP) when a pod axis exists.
    """
    defs = build_defs(cfg, ms)

    def fn(d: ParamDef, path, n_stack):
        return {
            "data": d.fsdp_dim is None,      # data-replicated => psum("data")
            "model": d.model_grad,           # split consumers => psum("model")
            "model_rep": d.tp_dim is None,   # value replicated over "model"
        }

    return _map_tree(defs, fn, stack=_stacks(cfg))
