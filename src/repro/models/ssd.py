"""Mamba-2 SSD block (state-space duality, arXiv:2405.21060).

Training uses the chunked SSD algorithm: quadratic attention-like compute
inside chunks (MXU-friendly batched matmuls) + a linear recurrence over
chunk boundary states. Decode is the O(1) state update.

TP layout: heads (and the inner width d_i = expand*d_model) are sharded
over "model"; the shared B/C projections (ngroups = 1, state dim N) are
replicated across model shards (they are tiny: d x 2N).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.distributed.axes import Axes
from repro.models.layers import dense, rms_norm_tp

__all__ = ["ssd_block", "ssd_block_step", "ssd_chunked"]

_F32 = jnp.float32


def _causal_conv1d(x, kernel):
    K = kernel.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros(x.shape, _F32)
    for k in range(K):
        out = out + xp[:, k : k + x.shape[1]].astype(_F32) * kernel[k].astype(_F32)
    return out.astype(x.dtype)


def ssd_chunked(
    x: jnp.ndarray,    # [B, S, H, P]
    dt: jnp.ndarray,   # [B, S, H]  (already softplus'ed, > 0)
    A: jnp.ndarray,    # [H]        (negative)
    Bm: jnp.ndarray,   # [B, S, N]
    Cm: jnp.ndarray,   # [B, S, N]
    chunk: int,
    *,
    return_state: bool = False,
):
    """Chunked SSD scan: y[t] = C_t . h_t,  h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t."""
    Bsz, S0, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S0)
    pad = (-S0) % Q
    if pad:  # zero-pad the tail: dt=0 contributes nothing to states/outputs
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    S = S0 + pad
    nc = S // Q

    xf = x.astype(_F32).reshape(Bsz, nc, Q, H, P)
    dtf = dt.astype(_F32).reshape(Bsz, nc, Q, H)
    Bf = Bm.astype(_F32).reshape(Bsz, nc, Q, N)
    Cf = Cm.astype(_F32).reshape(Bsz, nc, Q, N)

    dA = dtf * A.astype(_F32)                       # [B,nc,Q,H] (negative)
    cum = jnp.cumsum(dA, axis=2)                    # within-chunk cumulative

    # Intra-chunk (diagonal) term.
    CB = jnp.einsum("bcqn,bckn->bcqk", Cf, Bf, preferred_element_type=_F32)
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])  # [B,nc,Q,Q,H]
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(causal[None, None, :, :, None], decay, 0.0)
    y_diag = jnp.einsum(
        "bcqk,bcqkh,bckh,bckhp->bcqhp", CB, decay, dtf, xf,
        preferred_element_type=_F32,
    )

    # Chunk boundary states.
    edge = jnp.exp(cum[:, :, -1:, :] - cum)         # exp(cum_end - cum_s)
    states = jnp.einsum(
        "bckh,bckn,bckhp->bchnp", edge * dtf, Bf, xf, preferred_element_type=_F32
    )                                               # [B,nc,H,N,P]

    # Inter-chunk linear recurrence over boundary states.
    chunk_decay = jnp.exp(cum[:, :, -1, :])         # [B,nc,H]

    def op(l, r):
        al, hl = l
        ar, hr = r
        return al * ar, hl * ar[..., None, None] + hr

    _, h_all = jax.lax.associative_scan(op, (chunk_decay, states), axis=1)
    h_prev = jnp.concatenate(
        [jnp.zeros_like(h_all[:, :1]), h_all[:, :-1]], axis=1
    )                                               # state entering each chunk

    y_off = jnp.einsum(
        "bcqn,bchnp,bcqh->bcqhp", Cf, h_prev, jnp.exp(cum),
        preferred_element_type=_F32,
    )
    y = (y_diag + y_off).reshape(Bsz, S, H, P)
    if pad:
        y = y[:, :S0]
    y = y.astype(x.dtype)
    if return_state:
        # Final state in decode layout [B, H, N, P].
        return y, h_all[:, -1]
    return y


def ssd_block(
    x: jnp.ndarray, p: dict, cfg: SSMConfig, ax: Axes, *,
    capture: bool = False, reduce_dtype=_F32,
):
    """Full Mamba-2 block, training form. x: [B, S, d].

    With ``capture``, also returns the decode-continuation state
    {"h": [B, H_l, N, P] f32, "conv": [B, K-1, di_l + 2N]}.
    """
    Bsz, S, d = x.shape
    z = dense(x, p["w_z"])                  # [B,S,di_l]
    xin_pre = dense(x, p["w_x"])            # [B,S,di_l]
    bc = dense(x, p["w_bc"])                # [B,S,2N] (replicated over model)
    dt_raw = dense(x, p["w_dt"])            # [B,S,H_l]

    xin = _causal_conv1d(xin_pre, p["conv_x"])
    N = cfg.state_dim
    Bm = _causal_conv1d(bc[..., :N], p["conv_b"])
    Cm = _causal_conv1d(bc[..., N:], p["conv_c"])

    H_l = p["A_log"].shape[0]
    P = cfg.head_dim
    xh = xin.reshape(Bsz, S, H_l, P)
    dt = jax.nn.softplus(dt_raw.astype(_F32) + p["dt_bias"].astype(_F32))
    A = -jnp.exp(p["A_log"].astype(_F32))

    if capture:
        y, h_last = ssd_chunked(xh, dt, A, Bm, Cm, cfg.chunk, return_state=True)
    else:
        y = ssd_chunked(xh, dt, A, Bm, Cm, cfg.chunk)
    y = y + p["D"].astype(_F32)[None, None, :, None] * xh.astype(_F32)
    y = y.reshape(Bsz, S, H_l * P)
    y = (y * jax.nn.silu(z.astype(_F32))).astype(x.dtype)
    y = rms_norm_tp(y, p["norm_g"], 1e-6, ax, cfg.expand * d)
    out = jnp.einsum("bsw,wd->bsd", y, p["w_out"], preferred_element_type=_F32)
    out = ax.psum(out.astype(reduce_dtype), ax.model).astype(x.dtype)
    if not capture:
        return out, None
    K = p["conv_x"].shape[0]
    feats = jnp.concatenate([xin_pre, bc], axis=-1)  # pre-conv features
    state = {
        "h": h_last,
        "conv": feats[:, -(K - 1):],
    }
    return out, state


def ssd_block_step(
    x: jnp.ndarray, state: dict, p: dict, cfg: SSMConfig, ax: Axes
) -> tuple[jnp.ndarray, dict]:
    """Decode step. x: [B, d]; state: {"h": [B,H_l,N,P] f32, "conv": [B,K-1,di_l+2N]}."""
    Bsz, d = x.shape
    z = dense(x, p["w_z"])
    xin = dense(x, p["w_x"])
    bc = dense(x, p["w_bc"])
    dt_raw = dense(x, p["w_dt"])

    K = p["conv_x"].shape[0]
    N = cfg.state_dim
    feats = jnp.concatenate([xin, bc], axis=-1)  # [B, di_l+2N]
    window = jnp.concatenate([state["conv"], feats[:, None, :]], axis=1)  # [B,K,*]
    kernel = jnp.concatenate([p["conv_x"], p["conv_b"], p["conv_c"]], axis=1)
    conv = jnp.einsum("bkf,kf->bf", window.astype(_F32), kernel.astype(_F32))
    di_l = xin.shape[-1]
    xin_c = conv[:, :di_l]
    Bm = conv[:, di_l : di_l + N]
    Cm = conv[:, di_l + N :]

    H_l = p["A_log"].shape[0]
    P = cfg.head_dim
    xh = xin_c.reshape(Bsz, H_l, P)
    dt = jax.nn.softplus(dt_raw.astype(_F32) + p["dt_bias"].astype(_F32))  # [B,H_l]
    A = -jnp.exp(p["A_log"].astype(_F32))
    decay = jnp.exp(dt * A)                                   # [B,H_l]

    dBx = jnp.einsum("bn,bhp->bhnp", Bm, dt[..., None] * xh)
    h = state["h"] * decay[..., None, None] + dBx
    y = jnp.einsum("bn,bhnp->bhp", Cm, h, preferred_element_type=_F32)
    y = y + p["D"].astype(_F32)[None, :, None] * xh
    y = y.reshape(Bsz, H_l * P)
    y = (y * jax.nn.silu(z.astype(_F32))).astype(x.dtype)
    y = rms_norm_tp(y, p["norm_g"], 1e-6, ax, cfg.expand * d)
    out = jnp.einsum("bw,wd->bd", y, p["w_out"], preferred_element_type=_F32)
    out = ax.psum(out, ax.model).astype(x.dtype)
    return out, {"h": h, "conv": window[:, 1:]}
