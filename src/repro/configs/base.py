"""Model / shape / run configuration system.

Every assigned architecture gets a ``ModelConfig`` (exact published sizes)
plus a ``reduced()`` variant for CPU smoke tests. Input shapes are the four
assigned cells (train_4k / prefill_32k / decode_32k / long_500k).
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional

__all__ = ["ModelConfig", "ShapeSpec", "SHAPES", "MoEConfig", "SSMConfig"]

BlockKind = Literal["attn_full", "attn_swa", "attn_local", "rglru", "ssd"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128      # N (ssm_state)
    head_dim: int = 64        # P (mamba2 head dim)
    expand: int = 2           # d_inner = expand * d_model
    chunk: int = 256          # SSD chunk length
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    # Block pattern, cycled over layers (hybrid archs mix kinds).
    block_pattern: tuple[BlockKind, ...] = ("attn_full",)
    window: int = 4096            # SWA / local attention window
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # Encoder-decoder (whisper): encoder layers + stub frame inputs.
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 1500           # stub frame embeddings length
    # VLM (paligemma): prefix patch-embedding stub.
    vlm_prefix: int = 0           # number of stub patch embeddings
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # Numerics / memory policy.
    fsdp: bool = True              # shard params over "data" (ZeRO-3 gathers)
    tp_reduce_dtype: str = "float32"  # dtype of TP partial-sum psums
    param_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"   # bf16 option for very large models
    remat: bool = True
    # Serving: paged KV cache page size (tokens per page, tier-1 line size).
    page_size: int = 128
    # Whether attention is sub-quadratic (window/recurrent) => long_500k ok.

    @property
    def sub_quadratic(self) -> bool:
        return all(k != "attn_full" for k in self.block_pattern)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def layer_kinds(self) -> tuple[BlockKind, ...]:
        p = self.block_pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    def active_params(self) -> int:
        """Parameter count, counting only top_k experts for MoE (for the
        MODEL_FLOPS = 6·N_active·D roofline convention)."""
        return self._param_count(active_only=True)

    def total_params(self) -> int:
        return self._param_count(active_only=False)

    def _param_count(self, active_only: bool) -> int:
        d, f, hd = self.d_model, self.d_ff, self.head_dim
        n = 0
        kinds = self.layer_kinds()
        for k in kinds:
            if k.startswith("attn"):
                n += d * self.n_heads * hd          # q
                n += 2 * d * self.n_kv_heads * hd   # k, v
                n += self.n_heads * hd * d          # o
            elif k == "rglru":
                w = d  # lru width == d_model
                n += 2 * d * w + 2 * w + w * d      # in/gate projs, gates, out
                n += 2 * d * w                      # conv-ish branch proj
            elif k == "ssd":
                s = self.ssm or SSMConfig()
                di = s.expand * d
                nh = di // s.head_dim
                n += d * (2 * di + 2 * nh * s.state_dim + nh)  # in_proj fused
                n += di * d                          # out proj
            if k.startswith("attn") or k == "rglru":
                if self.moe is not None:
                    e = self.moe.top_k if active_only else self.moe.n_experts
                    n += e * 3 * d * f + d * self.moe.n_experts  # experts + router
                elif f > 0:
                    n += 3 * d * f
            n += 2 * d  # norms
        n += self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.enc_dec:
            # encoder layers: self-attn + mlp; decoder adds cross-attn.
            enc = self.n_enc_layers * (
                d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                + self.n_heads * hd * d + 3 * d * f + 2 * d
            )
            xattn = self.n_layers * (
                d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                + self.n_heads * hd * d + d
            )
            n += enc + xattn
        return n

    def reduced(self) -> "ModelConfig":
        """Small same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=max(len(self.block_pattern), 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab=512,
            window=32,
            moe=None if self.moe is None else dataclasses.replace(
                self.moe, n_experts=4, top_k=2
            ),
            ssm=None if self.ssm is None else dataclasses.replace(
                self.ssm, state_dim=16, head_dim=8, chunk=16
            ),
            n_enc_layers=2 if self.enc_dec else 0,
            enc_seq=24 if self.enc_dec else self.enc_seq,
            vlm_prefix=8 if self.vlm_prefix else 0,
            page_size=16,
            remat=False,
        )


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}
