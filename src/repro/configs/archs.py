"""The 10 assigned architectures (exact published configurations).

Sources per the assignment block: recurrentgemma [arXiv:2402.19427],
mamba2 [arXiv:2405.21060], grok-1 [hf:xai-org/grok-1], mixtral
[arXiv:2401.04088], mistral-nemo [hf:mistralai/Mistral-Nemo-Base-2407],
stablelm [hf:stabilityai], minitron [arXiv:2407.14679], llama3
[arXiv:2407.21783], whisper [arXiv:2212.04356], paligemma [arXiv:2407.07726].
"""
from __future__ import annotations

from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

__all__ = ["ARCHS", "get_config"]


ARCHS: dict[str, ModelConfig] = {
    # hybrid: RG-LRU + local attention, pattern (R, R, local-attn)
    "recurrentgemma-9b": ModelConfig(
        name="recurrentgemma-9b", family="hybrid",
        n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
        d_ff=12288, vocab=256000,
        block_pattern=("rglru", "rglru", "attn_local"), window=2048,
    ),
    # attention-free SSM (Mamba-2 SSD)
    "mamba2-370m": ModelConfig(
        name="mamba2-370m", family="ssm",
        n_layers=48, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
        d_ff=0, vocab=50280,
        block_pattern=("ssd",),
        ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, chunk=256),
        tie_embeddings=True,
    ),
    # MoE 8e top-2
    "grok-1-314b": ModelConfig(
        name="grok-1-314b", family="moe",
        n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
        d_ff=32768, vocab=131072,
        block_pattern=("attn_full",),
        moe=MoEConfig(n_experts=8, top_k=2),
        opt_state_dtype="bfloat16",
    ),
    # MoE 8e top-2 with sliding-window attention
    "mixtral-8x22b": ModelConfig(
        name="mixtral-8x22b", family="moe",
        n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
        d_ff=16384, vocab=32768,
        block_pattern=("attn_swa",), window=4096,
        moe=MoEConfig(n_experts=8, top_k=2),
        opt_state_dtype="bfloat16",
    ),
    # dense GQA, 128k ctx
    "mistral-nemo-12b": ModelConfig(
        name="mistral-nemo-12b", family="dense",
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab=131072,
        block_pattern=("attn_full",), rope_theta=1e6,
    ),
    # dense MHA (kv == heads)
    "stablelm-3b": ModelConfig(
        name="stablelm-3b", family="dense",
        n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
        d_ff=6912, vocab=50304,
        block_pattern=("attn_full",),
    ),
    # pruned nemotron
    "minitron-8b": ModelConfig(
        name="minitron-8b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=16384, vocab=256000,
        block_pattern=("attn_full",),
    ),
    # frontier dense
    "llama3-405b": ModelConfig(
        name="llama3-405b", family="dense",
        n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8, head_dim=128,
        d_ff=53248, vocab=128256,
        block_pattern=("attn_full",), rope_theta=5e5,
        opt_state_dtype="bfloat16",
    ),
    # enc-dec audio backbone (conv frontend stubbed as frame embeddings)
    "whisper-tiny": ModelConfig(
        name="whisper-tiny", family="audio",
        n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, head_dim=64,
        d_ff=1536, vocab=51865,
        block_pattern=("attn_full",),
        enc_dec=True, n_enc_layers=4, enc_seq=1500,
        norm_eps=1e-5,
    ),
    # VLM backbone (SigLIP frontend stubbed as patch embeddings)
    "paligemma-3b": ModelConfig(
        name="paligemma-3b", family="vlm",
        n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
        d_ff=16384, vocab=257216,
        block_pattern=("attn_full",),
        vlm_prefix=256,
        tie_embeddings=True,
    ),
}


def get_config(arch: str) -> ModelConfig:
    try:
        return ARCHS[arch]
    except KeyError:
        raise ValueError(f"unknown arch {arch!r}; options: {sorted(ARCHS)}") from None
