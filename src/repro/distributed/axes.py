"""Mesh-axis context + degradable collective helpers.

The whole framework runs in **manual SPMD** (shard_map): every collective is
explicit, so the dry-run HLO contains exactly the collective schedule we
designed (this is what makes the §Roofline collective term trustworthy and
the §Perf iterations controllable).

``Axes`` names the mesh axes a computation runs under; any axis can be
``None`` (absent), in which case the helpers degrade to identities — the
same model code then runs un-sharded on one device (smoke tests) or under
any mesh slice.

Convention for parameter leaves (see ``models/params.py``):
  - stacked-layer leaves: dim0 = layer, dim1 = FSDP ("data"), last dim = TP
    ("model") where applicable;
  - FSDP gather (``fsdp_gather``) all-gathers dim0 of a per-layer slice;
    its AD transpose is automatically a reduce-scatter => ZeRO-3 for free;
  - the "pod" axis is pure data parallelism: params replicated over pods,
    gradients explicitly ``pmean``-ed across pods (optionally int8
    compressed, see ``training/compression.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["Axes", "SINGLE", "pvary_like", "vma_of", "HAS_VMA", "pvary_entry"]

# Whether this jax has the varying-manual-axes system (jax >= 0.6). Pre-vma
# jax transposes collectives differently inside shard_map: transpose(psum)
# is psum (double-counting a replicated cotangent) and there is no implicit
# replicated->varying promotion whose transpose sums partial gradients. The
# two custom_vjp wrappers below restore the vma AD semantics on old jax so
# sharded gradients match the single-device reference bit-for-bit-ish.
HAS_VMA = hasattr(lax, "pvary")

if not HAS_VMA:
    from functools import partial as _partial

    @_partial(jax.custom_vjp, nondiff_argnums=(1,))
    def _psum_rep(x, names: tuple):
        return lax.psum(x, names)

    def _psum_rep_fwd(x, names: tuple):
        return lax.psum(x, names), None

    def _psum_rep_bwd(names, _, ct):
        # vma semantics: psum output is replicated, so its (replicated)
        # cotangent flows through unchanged.
        return (ct,)

    _psum_rep.defvjp(_psum_rep_fwd, _psum_rep_bwd)

    @_partial(jax.custom_vjp, nondiff_argnums=(1,))
    def _pvary_compat(x, names: tuple):
        return x

    def _pvary_compat_fwd(x, names: tuple):
        return x, None

    def _pvary_compat_bwd(names, _, ct):
        # vma semantics: transpose of replicated->varying promotion sums the
        # per-shard partial gradients.
        return (lax.psum(ct, names),)

    _pvary_compat.defvjp(_pvary_compat_fwd, _pvary_compat_bwd)


def _psum_replicated_ct(x, names: tuple):
    """psum whose output stays replicated over ``names`` until the loss.

    On vma jax this is plain psum. On old jax the default transpose(psum) =
    psum would re-sum the already-replicated cotangent (an axis-size
    inflation), so the custom_vjp identity-transpose version is used.
    Reductions whose output is consumed by *varying* compute (TP partial
    sums) must NOT use this: for those the old default transpose is the
    correct cross-shard cotangent sum.
    """
    if HAS_VMA:
        return lax.psum(x, names)
    return _psum_rep(x, names)


def pvary_entry(x, names: Sequence[str]):
    """Mark a replicated value as consumed shard-locally, so its partial
    gradients are psum'ed over ``names``. Identity on vma jax (the implicit
    promotion already transposes to psum); custom_vjp shim on old jax."""
    names = tuple(n for n in names if n)
    if HAS_VMA or not names:
        return x
    return _pvary_compat(x, names)


def vma_of(x) -> frozenset:
    """The varying-manual-axes set of a traced value (empty outside shard_map)."""
    try:
        return frozenset(jax.core.get_aval(x).vma)
    except AttributeError:  # pragma: no cover - older jax
        return frozenset()


def pvary_like(x, ref):
    """Promote ``x``'s varying axes to (at least) those of ``ref``.

    Needed for scan carries initialized from constants under
    ``shard_map(check_vma=True)``: the zero init is replicated while the body
    output is device-varying; pvary is free (no communication).
    """
    want = vma_of(ref) - vma_of(x)
    if not want:
        return x
    return jax.lax.pvary(x, tuple(sorted(want)))


def pvary_tree(tree, names: Sequence[str]):
    """pvary every leaf of a pytree to the given axis names (free op).

    Used for device-local state (paged pools, OL learners) whose out_specs
    declare full device variance even when the initial values are constants.
    """
    names = tuple(n for n in names if n)
    if not HAS_VMA:  # pre-vma jax: nothing to promote
        return tree

    def one(x):
        want = tuple(sorted(set(names) - vma_of(x)))
        return jax.lax.pvary(x, want) if want else x

    return jax.tree.map(one, tree)


@dataclasses.dataclass(frozen=True)
class Axes:
    data: Optional[str] = None   # FSDP + batch axis
    model: Optional[str] = None  # TP axis
    pod: Optional[str] = None    # pure-DP (multi-pod) axis

    # -- sizes / indices -----------------------------------------------------
    def size(self, name: Optional[str]) -> int:
        if name is None:
            return 1
        if hasattr(lax, "axis_size"):
            return lax.axis_size(name)
        return lax.psum(1, name)  # constant-folded to int on pre-0.6 jax

    def index(self, name: Optional[str]) -> jnp.ndarray:
        if name is None:
            return jnp.zeros((), jnp.int32)
        return lax.axis_index(name)

    @property
    def model_size(self) -> int:
        return self.size(self.model)

    @property
    def data_size(self) -> int:
        return self.size(self.data)

    @property
    def pod_size(self) -> int:
        return self.size(self.pod)

    def batch_shards(self) -> int:
        """How many ways the global batch is split (pod x data)."""
        return self.pod_size * self.data_size

    # -- collectives (identity when the axis is absent) ----------------------
    def psum(self, x, name: Optional[str]):
        """Partial-sum reduction consumed by shard-varying compute (TP)."""
        return x if name is None else lax.psum(x, name)

    def psum_rep(self, x, name: Optional[str]):
        """Reduction whose output stays replicated into the loss (softmax
        statistics, global losses) — AD-safe on pre-vma jax."""
        return x if name is None else _psum_replicated_ct(x, (name,))

    def pmean(self, x, name: Optional[str]):
        """Mean whose output stays replicated into the loss (loss/metric
        averaging); see psum_rep for the pre-vma AD caveat."""
        if name is None:
            return x
        return _psum_replicated_ct(x, (name,)) / self.size(name)

    def pmax(self, x, name: Optional[str]):
        return x if name is None else lax.pmax(x, name)

    def psum_many(self, x, names: Sequence[Optional[str]]):
        real = tuple(n for n in names if n is not None)
        return lax.psum(x, real) if real else x

    def pmax_many(self, x, names: Sequence[Optional[str]]):
        real = tuple(n for n in names if n is not None)
        return lax.pmax(x, real) if real else x

    def all_gather(self, x, name: Optional[str], *, axis: int = 0, tiled: bool = True):
        if name is None:
            return x
        return lax.all_gather(x, name, axis=axis, tiled=tiled)

    def psum_scatter(self, x, name: Optional[str], *, axis: int = 0):
        if name is None:
            return x
        return lax.psum_scatter(x, name, scatter_dimension=axis, tiled=True)

    def all_to_all(self, x, name: Optional[str], split_axis: int, concat_axis: int):
        if name is None:
            return x
        return lax.all_to_all(x, name, split_axis, concat_axis, tiled=True)

    def ppermute(self, x, name: Optional[str], perm):
        if name is None:
            return x
        return lax.ppermute(x, name, perm)

    # -- framework conventions ------------------------------------------------
    def fsdp_gather(self, w: jnp.ndarray) -> jnp.ndarray:
        """Gather a parameter's FSDP-sharded dim0 (ZeRO-3 unshard)."""
        return self.all_gather(w, self.data, axis=0, tiled=True)

    def dp_mean_grads(self, grads):
        """Pure-DP gradient mean across pods (the inter-pod all-reduce)."""
        if self.pod is None:
            return grads
        return jax.tree.map(lambda g: lax.pmean(g, self.pod), grads)

    def tp_degree(self, n: int) -> int:
        """TP degree used for an n-way-splittable dimension: the model axis
        when it divides n, else 1 (compute replicated across the axis)."""
        m = self.model_size
        return m if n % m == 0 else 1


SINGLE = Axes()  # un-sharded execution (smoke tests, reference paths)
