"""Pure-jnp oracles for every Pallas kernel (shape-for-shape references).

These are deliberately naive (full softmax, materialized scores, sequential
scans) — correctness baselines for the interpret-mode kernel tests, NOT the
production XLA path (that is ``models/attention.py`` etc.).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "attention_ref",
    "paged_attention_ref",
    "page_copy_ref",
    "reuse_distance_ref",
    "rglru_ref",
    "ssd_ref",
]

# Reuse distance of a first-ever access (compulsory miss): larger than any
# possible cache size, so `d < C` is False for every C. Shared sentinel with
# kernels/reuse_distance.py.
DIST_INF = 2**31 - 1


def attention_ref(
    q: jnp.ndarray,  # [B, H, Sq, hd]
    k: jnp.ndarray,  # [B, KV, Skv, hd]
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
) -> jnp.ndarray:
    B, H, Sq, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    qf = q.astype(jnp.float32).reshape(B, KV, G, Sq, hd)
    s = jnp.einsum("bkgqh,bksh->bkgqs", qf, k.astype(jnp.float32))
    s = s / math.sqrt(hd)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(k.shape[2])[None, :]
    if causal:
        m = qpos >= kpos
        if window is not None:
            m &= kpos > qpos - window
        s = jnp.where(m[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksh->bkgqh", p, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, hd).astype(q.dtype)


def paged_attention_ref(
    q: jnp.ndarray,        # [B, H, hd] single-token queries
    pool: jnp.ndarray,     # [slots, page, 2, KV, hd]
    page_slot: jnp.ndarray,  # [B, n_pages] int32 slot ids (-1 invalid)
    lengths: jnp.ndarray,  # [B]
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Partial attention over resident pages. Returns (acc, m, l) so results
    can be combined across shards exactly like the kernel."""
    B, H, hd = q.shape
    n_pages = page_slot.shape[1]
    page = pool.shape[1]
    KV = pool.shape[3]
    G = H // KV
    slot = jnp.clip(page_slot, 0)
    data = pool[slot]                          # [B, n_pages, page, 2, KV, hd]
    k = data[..., 0, :, :].reshape(B, n_pages * page, KV, hd)
    v = data[..., 1, :, :].reshape(B, n_pages * page, KV, hd)
    tok = (jnp.arange(n_pages)[:, None] * page
           + jnp.arange(page)[None, :]).reshape(-1)
    valid = (page_slot >= 0)[:, :, None].repeat(page, 2).reshape(B, -1)
    valid &= tok[None, :] < lengths[:, None]
    qf = q.astype(jnp.float32).reshape(B, KV, G, hd)
    s = jnp.einsum("bkgh,btkh->bkgt", qf, k.astype(jnp.float32))
    s = s / math.sqrt(hd)
    s = jnp.where(valid[:, None, None], s, -1e30)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(valid[:, None, None], p, 0.0)  # all-masked rows -> l = 0
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bkgt,btkh->bkgh", p, v.astype(jnp.float32))
    return acc, m, l


def page_copy_ref(
    dst: jnp.ndarray,       # [Sd, ...page payload...]
    src: jnp.ndarray,       # [Ss, ...]
    dst_idx: jnp.ndarray,   # [N] int32 (-1 = skip)
    src_idx: jnp.ndarray,   # [N] int32
) -> jnp.ndarray:
    """Tier movement: dst[dst_idx[i]] = src[src_idx[i]] for each live pair."""
    def body(i, d):
        ok = (dst_idx[i] >= 0) & (src_idx[i] >= 0)
        row = src[jnp.clip(src_idx[i], 0)]
        di = jnp.clip(dst_idx[i], 0)
        return jnp.where(ok, d.at[di].set(row), d)

    return jax.lax.fori_loop(0, dst_idx.shape[0], body, dst)


def reuse_distance_ref(
    prev: jnp.ndarray,   # int32[S, L] previous-occurrence index (-1 = first)
    valid: jnp.ndarray,  # bool[S, L]  real positions (False = padding)
    *,
    block: int = 128,
) -> jnp.ndarray:
    """LRU stack (Mattson reuse) distance per request, pure jnp.

    For request ``j`` of shard row ``s`` with previous same-page occurrence
    ``i = prev[s, j]``, the reuse distance is the number of *distinct* pages
    touched strictly between the two accesses — counted as the positions
    ``k`` in ``(i, j)`` whose own previous occurrence lies at or before
    ``i`` (``prev[s, k] <= i``), i.e. the first in-gap occurrence of each
    distinct page. First-ever accesses return :data:`DIST_INF` (compulsory
    miss at every cache size); padding returns ``-1``. Distances never
    cross shard rows.

    This is both the oracle for the Pallas kernel golden tests and the
    production CPU fallback: the O(L^2) dominance count is blocked over
    ``block`` queries at a time (O(block*L) memory, vectorized compares),
    not materialized as a full [L, L] matrix.
    """
    prev = jnp.asarray(prev, jnp.int32)
    valid = jnp.asarray(valid, bool)
    S, L = prev.shape
    pad = (-L) % block
    P = jnp.pad(prev, ((0, 0), (0, pad)), constant_values=-1)
    V = jnp.pad(valid, ((0, 0), (0, pad)), constant_values=False)
    Lp = L + pad
    kidx = jnp.arange(Lp, dtype=jnp.int32)

    def per_shard(Ps, Vs):
        def jblock(jb):
            j0 = jb * block
            pj = jax.lax.dynamic_slice(Ps, (j0,), (block,))
            vj = jax.lax.dynamic_slice(Vs, (j0,), (block,))
            jidx = j0 + jnp.arange(block, dtype=jnp.int32)
            m = (
                (kidx[None, :] > pj[:, None])
                & (kidx[None, :] < jidx[:, None])
                & (Ps[None, :] <= pj[:, None])
                & Vs[None, :]
            )
            d = jnp.sum(m, axis=1, dtype=jnp.int32)
            d = jnp.where(pj >= 0, d, DIST_INF)
            return jnp.where(vj, d, -1)

        return jax.lax.map(jblock, jnp.arange(Lp // block)).reshape(Lp)

    return jax.vmap(per_shard)(P, V)[:, :L]


def rglru_ref(u, w_a, b_a, w_x, b_x, lam):
    """Sequential RG-LRU recurrence. u: [B, S, W] -> h [B, S, W] (f32)."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf * w_a + b_a)
    i = jax.nn.sigmoid(uf * w_x + b_x)
    log_a = -8.0 * jax.nn.softplus(lam) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * uf)

    def step(h, ab):
        a_t, b_t = ab
        h = a_t * h + b_t
        return h, h

    _, hs = jax.lax.scan(step, jnp.zeros_like(uf[:, 0]),
                         (jnp.moveaxis(a, 1, 0), jnp.moveaxis(b, 1, 0)))
    return jnp.moveaxis(hs, 0, 1)


def ssd_ref(x, dt, A, Bm, Cm):
    """Sequential SSD scan. x: [B,S,H,P]; returns y [B,S,H,P] f32."""
    Bsz, S, H, P = x.shape

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        decay = jnp.exp(dt_t * A)                        # [B,H]
        h = h * decay[..., None, None] + jnp.einsum(
            "bn,bhp->bhnp", b_t, dt_t[..., None] * x_t)
        y = jnp.einsum("bn,bhnp->bhp", c_t, h)
        return h, y

    h0 = jnp.zeros((Bsz, H, Bm.shape[-1], P), jnp.float32)
    _, ys = jax.lax.scan(
        step, h0,
        (jnp.moveaxis(x.astype(jnp.float32), 1, 0),
         jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
         jnp.moveaxis(Bm.astype(jnp.float32), 1, 0),
         jnp.moveaxis(Cm.astype(jnp.float32), 1, 0)),
    )
    return jnp.moveaxis(ys, 0, 1)
