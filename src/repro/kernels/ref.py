"""Pure-jnp oracles for every Pallas kernel (shape-for-shape references).

These are deliberately naive (full softmax, materialized scores, sequential
scans) — correctness baselines for the interpret-mode kernel tests, NOT the
production XLA path (that is ``models/attention.py`` etc.).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import online_learning as _ol

__all__ = [
    "attention_ref",
    "paged_attention_ref",
    "page_copy_ref",
    "reuse_distance_ref",
    "cache_scan_noise",
    "cache_scan_ref",
    "fused_cache_step",
    "fused_fold",
    "rglru_ref",
    "ssd_ref",
]

# Reuse distance of a first-ever access (compulsory miss): larger than any
# possible cache size, so `d < C` is False for every C. Shared sentinel with
# kernels/reuse_distance.py.
DIST_INF = 2**31 - 1


def attention_ref(
    q: jnp.ndarray,  # [B, H, Sq, hd]
    k: jnp.ndarray,  # [B, KV, Skv, hd]
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
) -> jnp.ndarray:
    B, H, Sq, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    qf = q.astype(jnp.float32).reshape(B, KV, G, Sq, hd)
    s = jnp.einsum("bkgqh,bksh->bkgqs", qf, k.astype(jnp.float32))
    s = s / math.sqrt(hd)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(k.shape[2])[None, :]
    if causal:
        m = qpos >= kpos
        if window is not None:
            m &= kpos > qpos - window
        s = jnp.where(m[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksh->bkgqh", p, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, hd).astype(q.dtype)


def paged_attention_ref(
    q: jnp.ndarray,        # [B, H, hd] single-token queries
    pool: jnp.ndarray,     # [slots, page, 2, KV, hd]
    page_slot: jnp.ndarray,  # [B, n_pages] int32 slot ids (-1 invalid)
    lengths: jnp.ndarray,  # [B]
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Partial attention over resident pages. Returns (acc, m, l) so results
    can be combined across shards exactly like the kernel."""
    B, H, hd = q.shape
    n_pages = page_slot.shape[1]
    page = pool.shape[1]
    KV = pool.shape[3]
    G = H // KV
    slot = jnp.clip(page_slot, 0)
    data = pool[slot]                          # [B, n_pages, page, 2, KV, hd]
    k = data[..., 0, :, :].reshape(B, n_pages * page, KV, hd)
    v = data[..., 1, :, :].reshape(B, n_pages * page, KV, hd)
    tok = (jnp.arange(n_pages)[:, None] * page
           + jnp.arange(page)[None, :]).reshape(-1)
    valid = (page_slot >= 0)[:, :, None].repeat(page, 2).reshape(B, -1)
    valid &= tok[None, :] < lengths[:, None]
    qf = q.astype(jnp.float32).reshape(B, KV, G, hd)
    s = jnp.einsum("bkgh,btkh->bkgt", qf, k.astype(jnp.float32))
    s = s / math.sqrt(hd)
    s = jnp.where(valid[:, None, None], s, -1e30)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(valid[:, None, None], p, 0.0)  # all-masked rows -> l = 0
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bkgt,btkh->bkgh", p, v.astype(jnp.float32))
    return acc, m, l


def page_copy_ref(
    dst: jnp.ndarray,       # [Sd, ...page payload...]
    src: jnp.ndarray,       # [Ss, ...]
    dst_idx: jnp.ndarray,   # [N] int32 (-1 = skip)
    src_idx: jnp.ndarray,   # [N] int32
) -> jnp.ndarray:
    """Tier movement: dst[dst_idx[i]] = src[src_idx[i]] for each live pair."""
    def body(i, d):
        ok = (dst_idx[i] >= 0) & (src_idx[i] >= 0)
        row = src[jnp.clip(src_idx[i], 0)]
        di = jnp.clip(dst_idx[i], 0)
        return jnp.where(ok, d.at[di].set(row), d)

    return jax.lax.fori_loop(0, dst_idx.shape[0], body, dst)


def reuse_distance_ref(
    prev: jnp.ndarray,   # int32[S, L] previous-occurrence index (-1 = first)
    valid: jnp.ndarray,  # bool[S, L]  real positions (False = padding)
    *,
    block: int = 128,
) -> jnp.ndarray:
    """LRU stack (Mattson reuse) distance per request, pure jnp.

    For request ``j`` of shard row ``s`` with previous same-page occurrence
    ``i = prev[s, j]``, the reuse distance is the number of *distinct* pages
    touched strictly between the two accesses — counted as the positions
    ``k`` in ``(i, j)`` whose own previous occurrence lies at or before
    ``i`` (``prev[s, k] <= i``), i.e. the first in-gap occurrence of each
    distinct page. First-ever accesses return :data:`DIST_INF` (compulsory
    miss at every cache size); padding returns ``-1``. Distances never
    cross shard rows.

    This is both the oracle for the Pallas kernel golden tests and the
    production CPU fallback: the O(L^2) dominance count is blocked over
    ``block`` queries at a time (O(block*L) memory, vectorized compares),
    not materialized as a full [L, L] matrix.
    """
    prev = jnp.asarray(prev, jnp.int32)
    valid = jnp.asarray(valid, bool)
    S, L = prev.shape
    pad = (-L) % block
    P = jnp.pad(prev, ((0, 0), (0, pad)), constant_values=-1)
    V = jnp.pad(valid, ((0, 0), (0, pad)), constant_values=False)
    Lp = L + pad
    kidx = jnp.arange(Lp, dtype=jnp.int32)

    def per_shard(Ps, Vs):
        def jblock(jb):
            j0 = jb * block
            pj = jax.lax.dynamic_slice(Ps, (j0,), (block,))
            vj = jax.lax.dynamic_slice(Vs, (j0,), (block,))
            jidx = j0 + jnp.arange(block, dtype=jnp.int32)
            m = (
                (kidx[None, :] > pj[:, None])
                & (kidx[None, :] < jidx[:, None])
                & (Ps[None, :] <= pj[:, None])
                & Vs[None, :]
            )
            d = jnp.sum(m, axis=1, dtype=jnp.int32)
            d = jnp.where(pj >= 0, d, DIST_INF)
            return jnp.where(vj, d, -1)

        return jax.lax.map(jblock, jnp.arange(Lp // block)).reshape(Lp)

    return jax.vmap(per_shard)(P, V)[:, :L]


# ---------------------------------------------------------------------------
# Fused tier-1 cache scan (oracle + production CPU fallback).
#
# One request step of the storage engine with every scatter/gather replaced
# by one-hot iota-compare updates — elementwise selects and adds on [N]
# arrays, the exact op mix the Pallas kernel runs on its VMEM-resident
# state. Bit-identical to repro.storage.tiered_store._step: integer/bool
# updates are exact by construction (a one-hot where() IS a single-index
# scatter), and the float expert-weight arithmetic calls the same
# online_learning routines (same op order, same f32 rounding).
# ---------------------------------------------------------------------------


def cache_scan_noise(key: jax.Array, length: int, n_lines: int) -> jnp.ndarray:
    """Random-expert noise table: row ``t`` holds the uniforms the in-loop
    PRNG would draw at step ``t`` of a stream starting from ``key``.

    The reference scan splits per step (``key, vkey = split(key)``) and
    draws ``uniform(vkey, [n_lines])`` inside the sequential loop; each
    draw is a pure function of its ``vkey``, so precomputing the split
    chain (a cheap scan over single keys) and batching the draws
    (``vmap``'d threefry, fully parallel over ``length``) yields
    bit-identical values while removing the PRNG from the request loop.
    Under vmap over sweep points/shards the table is a *constant* (the
    seed is static), so one table serves the whole megabatch."""

    def split_step(k, _):
        k2, vk = jax.random.split(k)
        return k2, vk

    _, vkeys = jax.lax.scan(split_step, key, None, length=length)
    return jax.vmap(lambda vk: jax.random.uniform(vk, (n_lines,)))(vkeys)


class _ScanCache(NamedTuple):
    """Slim cache carry for :func:`cache_scan_ref` — the ``CacheState``
    fields the scan actually needs, with the ``valid`` array replaced by a
    scalar fill count. Lines fill strictly in order (inserts always take
    the lowest free index, nothing ever invalidates), so ``valid`` is
    exactly ``tags >= 0`` (init ``-1``; pages are non-negative) and the
    next free index is the fill count itself — dropping one ``[n_lines]``
    array from the sequential carry and three mask ops from the victim
    argreductions (see the step body)."""

    tags: jnp.ndarray    # int32[n_lines]
    dirty: jnp.ndarray   # bool[n_lines]
    freq: jnp.ndarray    # int32[n_lines]
    ts: jnp.ndarray      # int32[n_lines]
    n_valid: jnp.ndarray  # int32 scalar fill count


def fused_cache_step(state, page, is_write, noise, hyper, *,
                     epoch_width: int, pred_cap: int, prefetch: bool,
                     prefetch_width: int):
    """One fused request step on duck-typed store state (any pytree with
    the ``StoreState``/``OLState``/``PrefetchState`` fields, ``cache``
    being a :class:`_ScanCache`).

    ``noise`` is this step's Random-expert draw (f32[n_lines]) — a row of
    :func:`cache_scan_noise` or an in-loop ``uniform(vkey, ...)``; the PRNG
    key itself is managed by the caller (left untouched here). Returns
    ``(state, out)`` with ``out`` matching the reference step's dict."""
    cache, ols, pf = state.cache, state.ols, state.pf
    t = state.t
    page = page.astype(jnp.int32)
    n_lines = cache.tags.shape[-1]
    line = jnp.arange(n_lines, dtype=jnp.int32)
    E = _ol.N_EXPERTS

    # --- 1. lookup -------------------------------------------------------
    # A page occupies at most one line and free lines hold ``-1`` (never a
    # page id), so ``match`` is already the hit one-hot — no validity mask
    # or argmax needed, and the hit-path updates merge with the miss-path
    # insert below through a single ``touch`` mask.
    match = cache.tags == page
    hit = jnp.any(match)

    # --- 2/3. miss path ---------------------------------------------------
    miss = ~hit
    hit_pred = jnp.any(ols.pred == page, axis=1)  # bool[E]
    ols = ols._replace(
        mispred=ols.mispred + jnp.where(miss, hit_pred.astype(jnp.int32), 0),
        epoch_misses=ols.epoch_misses + jnp.where(miss, 1, 0),
    )
    # Prefetch buffer probe. With prefetch off the buffer is never
    # populated, so the probe is a state-invariant no-op — skipping it
    # entirely (promoted = False) is exact, and the [B]-wide compares drop
    # out of the hot loop.
    if prefetch:
        pmatch = pf.pvalid & (pf.ptags == page)
        in_buf = jnp.any(pmatch)
        pf = pf._replace(
            pvalid=jnp.where(miss & pmatch, False, pf.pvalid),
            useful=pf.useful + jnp.where(miss, in_buf.astype(jnp.int32), 0),
        )
        promoted = miss & in_buf
    else:
        promoted = jnp.zeros((), bool)

    # Sequential fill: the free lines are exactly the suffix [n_valid, N),
    # so the free-slot search is a scalar compare, not an argreduction.
    has_free = cache.n_valid < n_lines
    free_idx = cache.n_valid

    # GetVictim (ol.propose_victims with the provided noise): compares and
    # first-index argreductions only — exact. The reference masks invalid
    # lines out of each argreduction, but the victims are only *observable*
    # on an eviction (slot, pred ring, writeback — all gated by ``evict``,
    # which implies a full cache where the masks are identity), so the
    # unmasked reductions are bit-exact where it matters.
    lru = jnp.argmin(cache.ts).astype(jnp.int32)
    lfu = jnp.argmin(cache.freq).astype(jnp.int32)
    rnd = jnp.argmax(noise).astype(jnp.int32)
    proposals = jnp.stack([lru, lfu, rnd])
    victim_pages = cache.tags[proposals]                  # int32[E] gather
    chosen = _ol.choose_expert(ols, hyper.policy_idx)
    victim_idx = jnp.sum(
        jnp.where(jnp.arange(E, dtype=jnp.int32) == chosen, proposals, 0)
    ).astype(jnp.int32)

    evict = miss & ~has_free
    slot = jnp.where(has_free, free_idx, victim_idx)
    slot_oh = line == slot
    writeback = evict & cache.dirty[slot]

    # Prediction rings (one-hot column write), gated by evict. The ring
    # width is whatever the carried state holds — cache_scan_ref may have
    # truncated it to min(pred_cap, epoch_width) (see there); the modulo
    # follows the actual width so the truncated ring wraps consistently.
    ring = ols.pred.shape[-1]
    col_oh = (jnp.arange(ring, dtype=jnp.int32)[None, :]
              == (ols.pred_n % ring)[:, None])            # bool[E, C]
    pred_new = jnp.where(col_oh, victim_pages[:, None], ols.pred)
    ols = ols._replace(
        pred=jnp.where(evict, pred_new, ols.pred),
        pred_n=jnp.where(evict, ols.pred_n + 1, ols.pred_n),
        chosen=jnp.where(evict, chosen, ols.chosen[0])[None],
    )

    # Touched line: the hit line on a hit, the insert slot on a miss. On a
    # hit ``tags[match] == page`` already, so the unified writes below are
    # no-ops there — one select per array instead of the nested
    # hit/miss/unchanged merge (bit-identical: same values land).
    touch = jnp.where(miss, slot_oh, match)
    cache = cache._replace(
        tags=jnp.where(touch, page, cache.tags),
        dirty=jnp.where(touch, (cache.dirty & hit) | is_write, cache.dirty),
        freq=jnp.where(touch, jnp.where(miss, 0, cache.freq) + 1, cache.freq),
        ts=jnp.where(touch, t, cache.ts),
        n_valid=cache.n_valid + (miss & has_free).astype(jnp.int32),
    )

    # --- 4. stream identifier + prefetch issue ----------------------------
    if prefetch:
        delta = page - pf.last_miss
        same = (delta == pf.stride) & (pf.last_miss >= 0) & (delta != 0)
        conf_o = jnp.where(same, pf.conf + 1,
                           jnp.where(delta != 0, 1, pf.conf))
        stride_o = jnp.where(same, pf.stride,
                             jnp.where(delta != 0, delta, pf.stride))
        pf = pf._replace(
            last_miss=jnp.where(miss, page, pf.last_miss),
            stride=jnp.where(miss, stride_o, pf.stride),
            conf=jnp.where(miss, conf_o, pf.conf),
        )
        n_before = pf.issued
        active = pf.conf >= 2
        buf = jnp.arange(pf.ptags.shape[-1], dtype=jnp.int32)

        def body(k, pf_):
            cand = page + (k + 1) * pf_.stride
            # Free lines hold -1; a negative ``cand`` is discarded by the
            # ``cand >= 0`` gate below, so the tags compare alone is exact.
            in_cache = jnp.any(cache.tags == cand)
            in_buf2 = jnp.any(pf_.pvalid & (pf_.ptags == cand))
            bfree = ~pf_.pvalid
            do = (active & jnp.any(bfree) & ~in_cache & ~in_buf2
                  & (cand >= 0))
            boh = (buf == jnp.argmax(bfree).astype(jnp.int32)) & do
            return pf_._replace(
                ptags=jnp.where(boh, cand, pf_.ptags),
                pvalid=pf_.pvalid | boh,
                issued=pf_.issued + do.astype(jnp.int32),
            )

        pf_issued = jax.lax.fori_loop(0, prefetch_width, body, pf)
        pf = jax.tree.map(lambda n, o: jnp.where(miss, n, o), pf_issued, pf)
        prefetch_fetches = jnp.where(miss, pf.issued - n_before, 0)
    else:
        prefetch_fetches = jnp.zeros((), jnp.int32)

    # --- 5. epoch boundary -------------------------------------------------
    epoch_end = (t + 1) % epoch_width == 0
    is_ws = hyper.policy_idx < 0
    ol_cfg = _ol.OLConfig(epoch_width=epoch_width, alpha=hyper.alpha,
                          beta=hyper.beta, threshold=hyper.threshold,
                          pred_cap=pred_cap)
    ols = jax.tree.map(
        lambda new, old: jnp.where(epoch_end & is_ws, new, old),
        _ol.weight_adjust(ols, ol_cfg), ols,
    )

    out = dict(
        hit=hit,
        miss=miss,
        prefetch_hit=promoted,
        tier2_read=(miss & ~promoted).astype(jnp.int32) + prefetch_fetches,
        tier2_write=writeback.astype(jnp.int32),
        evict=evict,
        chosen=jnp.where(evict, chosen, -1),
    )
    return state._replace(cache=cache, ols=ols, pf=pf, t=t + 1), out


def fused_fold(acc, outs, win, weights, n_windows: int):
    """Dense post-pass counterpart of the reference per-step ``_fold``:
    consumes the *stacked* ``[L]`` per-request outcomes of a whole scan
    and reduces them into the accumulators in one shot — the windowed
    scatter-adds become one-hot mask reductions over the request axis
    (commutative integer adds: exact), hoisted out of the sequential loop
    entirely so the scan carries only the engine state.

    ``win == n_windows`` (padding) matches no window slot and drops,
    exactly the ``mode="drop"`` semantics; the scalar totals sum over all
    positions (pads included — historic semantics). ``weights`` is the
    ``[L, E]`` stack of post-step expert weights: each window row takes
    the weights at its *last* matching request (identical to the
    reference's overwrite-every-step fold), keeping ``acc``'s existing
    row where the window saw no request."""
    i32 = jnp.int32
    hit = outs["hit"].astype(i32)
    miss = outs["miss"].astype(i32)
    pfh = outs["prefetch_hit"].astype(i32)
    t2r = outs["tier2_read"].astype(i32)
    t2w = outs["tier2_write"].astype(i32)
    ev = outs["evict"].astype(i32)
    expert = jnp.where(outs["evict"], outs["chosen"], 0)
    length = hit.shape[0]
    woh = win[:, None] == jnp.arange(n_windows, dtype=i32)[None, :]  # [L, W]
    wohi = woh.astype(i32)
    eoh = (expert[:, None] == jnp.arange(_ol.N_EXPERTS, dtype=i32)[None, :]
           ).astype(i32) * ev[:, None]                               # [L, E]
    # [L, 7] stacked counters -> [W, 7] via one integer contraction.
    vals = jnp.stack([jnp.ones_like(hit), hit, miss, pfh, t2r, t2w, ev],
                     axis=1)
    winc = wohi.T @ vals                                             # [W, 7]
    # Last matching request per window (-1 = window untouched this scan).
    pos = jnp.max(jnp.where(woh, jnp.arange(length, dtype=i32)[:, None], -1),
                  axis=0)
    wsel = jnp.take(weights, jnp.maximum(pos, 0), axis=0)            # [W, E]
    return acc._replace(
        hits=acc.hits + jnp.sum(hit),
        misses=acc.misses + jnp.sum(miss),
        prefetch_hits=acc.prefetch_hits + jnp.sum(pfh),
        tier2_reads=acc.tier2_reads + jnp.sum(t2r),
        tier2_writes=acc.tier2_writes + jnp.sum(t2w),
        evictions=acc.evictions + jnp.sum(ev),
        expert_use=acc.expert_use + jnp.sum(eoh, axis=0),
        win_requests=acc.win_requests + winc[:, 0],
        win_hits=acc.win_hits + winc[:, 1],
        win_misses=acc.win_misses + winc[:, 2],
        win_prefetch_hits=acc.win_prefetch_hits + winc[:, 3],
        win_tier2_reads=acc.win_tier2_reads + winc[:, 4],
        win_tier2_writes=acc.win_tier2_writes + winc[:, 5],
        win_evictions=acc.win_evictions + winc[:, 6],
        win_expert_use=acc.win_expert_use + wohi.T @ eoh,
        win_weights=jnp.where((pos >= 0)[:, None], wsel, acc.win_weights),
    )


def cache_scan_ref(state0, acc0, pages, writes, win, hyper, noise, *,
                   epoch_width: int, pred_cap: int, prefetch: bool,
                   prefetch_width: int, n_windows: int, unroll: int = 1,
                   masked: bool = False):
    """One stream row of the fused cache engine, pure jnp — the oracle for
    the Pallas ``cache_scan`` kernel's golden tests AND the production CPU
    fallback (same pattern as :func:`reuse_distance_ref`; the sequential
    dependence means the scan stays a scan — ``unroll`` is the blocking
    knob here, chunking the loop body like the reference engine's).

    ``noise`` is the precomputed ``[len, n_lines]`` Random-expert table
    (:func:`cache_scan_noise` — the one-shot megabatch mode; ``state0.key``
    is carried through untouched) or ``None`` for in-loop PRNG splits (the
    resumable chunk-engine mode, where the carried key must advance exactly
    as the reference engine's). ``masked=True`` reproduces the chunk
    engine's pad semantics: positions with ``win >= n_windows`` leave the
    state (including ``t`` and the key) untouched and contribute zero to
    every counter. Returns ``(final_state, acc)``.

    The sequential scan carries *only* the engine state and emits the tiny
    per-request outcome scalars; the counter fold over those outcomes is
    commutative, so it runs as one dense post-pass (:func:`fused_fold`)
    instead of riding the loop carry.

    The prediction ring is carried truncated to ``min(pred_cap,
    epoch_width)`` columns: under online learning (ws) the ring is cleared
    every epoch boundary and sees at most one eviction per step, so slots
    ``>= epoch_width`` are never written between resets — they stay at
    their incoming value (``-1``), and truncating them is bit-exact. Under
    a fixed-expert policy the full ring *would* wrap through all
    ``pred_cap`` slots, but then ``weight_adjust`` never fires, so neither
    the ring nor ``mispred`` is observable in any output. The untouched
    tail columns are spliced back onto the final state unchanged."""

    c_eff = min(pred_cap, epoch_width)
    ols0 = state0.ols
    cache0 = state0.cache
    state0 = state0._replace(
        ols=ols0._replace(pred=ols0.pred[:, :c_eff]),
        # Slim cache carry: ``valid`` becomes a scalar fill count (lines
        # fill strictly in order — see _ScanCache), reconstructed exactly
        # as ``tags >= 0`` on exit.
        cache=_ScanCache(
            tags=cache0.tags, dirty=cache0.dirty, freq=cache0.freq,
            ts=cache0.ts,
            n_valid=jnp.sum(cache0.valid).astype(jnp.int32)),
    )

    def scan_fn(state, xs):
        if noise is None:
            page, write, win_i = xs
            key, vkey = jax.random.split(state.key)
            nrow = jax.random.uniform(vkey, state.cache.tags.shape)
            st_in = state._replace(key=key)
        else:
            page, write, win_i, nrow = xs
            st_in = state
        new_state, out = fused_cache_step(
            st_in, page, write.astype(bool), nrow, hyper,
            epoch_width=epoch_width, pred_cap=pred_cap, prefetch=prefetch,
            prefetch_width=prefetch_width,
        )
        if masked:
            valid = win_i < n_windows
            new_state = jax.tree.map(
                lambda n, o: jnp.where(valid, n, o), new_state, state)
            out = dict(
                hit=out["hit"] & valid,
                miss=out["miss"] & valid,
                prefetch_hit=out["prefetch_hit"] & valid,
                tier2_read=jnp.where(valid, out["tier2_read"], 0),
                tier2_write=jnp.where(valid, out["tier2_write"], 0),
                evict=out["evict"] & valid,
                chosen=out["chosen"],
            )
        return new_state, (out, new_state.ols.weights)

    xs = (pages, writes, win) if noise is None else (pages, writes, win, noise)
    final, (outs, wts) = jax.lax.scan(scan_fn, state0, xs, unroll=unroll)
    fc = final.cache
    final = final._replace(
        ols=final.ols._replace(pred=jnp.concatenate(
            [final.ols.pred, ols0.pred[:, c_eff:]], axis=1)),
        # Rebuild the full CacheState (duck-typed via the caller's class):
        # a line is valid iff it ever took an insert, i.e. tags >= 0.
        cache=type(cache0)(tags=fc.tags, valid=fc.tags >= 0, dirty=fc.dirty,
                           freq=fc.freq, ts=fc.ts),
    )
    return final, fused_fold(acc0, outs, win, wts, n_windows)


def rglru_ref(u, w_a, b_a, w_x, b_x, lam):
    """Sequential RG-LRU recurrence. u: [B, S, W] -> h [B, S, W] (f32)."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf * w_a + b_a)
    i = jax.nn.sigmoid(uf * w_x + b_x)
    log_a = -8.0 * jax.nn.softplus(lam) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * uf)

    def step(h, ab):
        a_t, b_t = ab
        h = a_t * h + b_t
        return h, h

    _, hs = jax.lax.scan(step, jnp.zeros_like(uf[:, 0]),
                         (jnp.moveaxis(a, 1, 0), jnp.moveaxis(b, 1, 0)))
    return jnp.moveaxis(hs, 0, 1)


def ssd_ref(x, dt, A, Bm, Cm):
    """Sequential SSD scan. x: [B,S,H,P]; returns y [B,S,H,P] f32."""
    Bsz, S, H, P = x.shape

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        decay = jnp.exp(dt_t * A)                        # [B,H]
        h = h * decay[..., None, None] + jnp.einsum(
            "bn,bhp->bhnp", b_t, dt_t[..., None] * x_t)
        y = jnp.einsum("bn,bhnp->bhp", c_t, h)
        return h, y

    h0 = jnp.zeros((Bsz, H, Bm.shape[-1], P), jnp.float32)
    _, ys = jax.lax.scan(
        step, h0,
        (jnp.moveaxis(x.astype(jnp.float32), 1, 0),
         jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
         jnp.moveaxis(Bm.astype(jnp.float32), 1, 0),
         jnp.moveaxis(Cm.astype(jnp.float32), 1, 0)),
    )
    return jnp.moveaxis(ys, 0, 1)
