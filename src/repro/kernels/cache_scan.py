"""Fused tier-1 cache-scan engine: VMEM-resident state for the request loop.

The reference engine (``repro.storage.tiered_store``) carries the full
``StoreState`` pytree through a ``lax.scan``, so every request round-trips
cache tags, recency metadata, prediction rings and expert weights through
HBM — the queue-starved access pattern that leaves the sweep's
``engine_dispatch`` stage dominant (~65% of wall time on the gated
288-point × 32-window grid, see ``BENCH_report.json``). This module fuses
the whole request loop — lookup → policy decision → eviction → windowed
scatter-add — per ``(shard, point)``:

- **Pallas kernel** (:func:`cache_scan_kernel`): one grid cell per stream
  row keeps the cache tag/metadata arrays, LRU/LFU recency state,
  prediction rings and online-learning expert weights in VMEM scratch
  (SMEM for the scalar learner/prefetcher state) and loops over the
  requests with elementwise one-hot updates — no per-step HBM round trip,
  no scatter/gather.
- **Pure-jax fallback** (:func:`repro.kernels.ref.cache_scan_ref`): the
  same one-hot step as a ``lax.scan`` — the CPU production path and the
  golden oracle, bit-identical to the kernel in interpret mode and to the
  reference engine everywhere (integer one-hot updates are exact; the
  float weight arithmetic calls the same ``online_learning`` routines).
- **Hoisted PRNG** (:func:`repro.kernels.ref.cache_scan_noise`): the
  Random expert's per-step uniforms become a precomputed ``[len,
  n_lines]`` table — bit-identical draws (same threefry chain), computed
  once per compile and *shared* across every megabatch row (the table is
  a vmap constant), instead of a sequential split+draw per request.

Dispatch follows the ``REPRO_KERNELS`` convention of
:mod:`repro.kernels.reuse_distance`: pure-jax fallback on this CPU
container, compiled Pallas on a TPU backend, interpret-mode Pallas
testable everywhere. :func:`cache_scan_compile_count` counts traces of
the production engine (once per XLA compile under jit) exactly like
``engine_compile_count`` / ``stream_compile_count``.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.online_learning import N_EXPERTS
from repro.kernels.ref import cache_scan_noise, cache_scan_ref

__all__ = [
    "cache_scan_kernel",
    "fused_cache_scan",
    "cache_scan_compile_count",
    "reset_cache_scan_compile_count",
]

# Mirrors kernels/ops.py: interpret-mode (pure-jax fallback) unless the
# container bakes a real TPU toolchain.
INTERPRET = os.environ.get("REPRO_KERNELS", "interpret") != "tpu"

# Noise-table budget, elements. One-shot streams whose [len, n_lines]
# Random-expert table would exceed this (f32 >16 MB) fall back to in-loop
# PRNG splits — correctness is unaffected (same draws), only the hoisting
# optimization is skipped. The Pallas kernel additionally requires the
# table to fit its VMEM block (NOISE_VMEM_MAX elements).
NOISE_TABLE_MAX = 1 << 22
NOISE_VMEM_MAX = 1 << 20

# Trace-time compile counter for the fused engine (both the Pallas wrapper
# and the ref fallback): increments once per trace, i.e. once per XLA
# compile when called under jit — benchmarks/bench_engine.py gates on it.
_CACHE_SCAN_COMPILES = [0]

# SMEM scalar slots of the kernel (learner + stream-identifier state).
_SM_EPOCH_MISSES, _SM_CHOSEN, _SM_LAST_MISS, _SM_STRIDE = 0, 1, 2, 3
_SM_CONF, _SM_ISSUED, _SM_USEFUL = 4, 5, 6
_N_SM = 8

_BIG = jnp.iinfo(jnp.int32).max


def cache_scan_compile_count() -> int:
    """Number of traces (== XLA compiles under jit) of the fused engine."""
    return _CACHE_SCAN_COMPILES[0]


def reset_cache_scan_compile_count() -> None:
    _CACHE_SCAN_COMPILES[0] = 0


def fused_cache_scan(cfg, hyper, state0, acc0, pages, writes, win, *,
                     n_windows: int, unroll: int = 1, masked: bool = False,
                     interpret=None):
    """Production fused engine for one stream row: ``(state0, acc0, pages
    [L], writes [L], win [L]) -> (final_state, acc)``.

    Plain traceable function (inlines into the caller's jit; the compile
    counter increments once per outer XLA compile). ``cfg`` supplies the
    structural knobs (``epoch_width``, ``pred_cap``, ``prefetch``,
    ``prefetch_width``), ``hyper`` the traced scalar knobs. ``masked=True``
    is the resumable chunk-engine mode: pads (``win >= n_windows``) leave
    the carried state untouched, and the PRNG stays in-loop (the carried
    key must advance per real request; a per-shard noise table would also
    defeat the chunk path's bounded-memory contract). The one-shot mode
    hoists the Random expert's draws into a shared noise table instead
    (see :func:`repro.kernels.ref.cache_scan_noise`).

    On a TPU backend (``REPRO_KERNELS=tpu``) the one-shot mode routes to
    :func:`cache_scan_kernel` (a fresh cold-start row, exactly what the
    one-shot callers construct); everything else runs the pure-jax
    fallback — bit-identical either way.
    """
    _CACHE_SCAN_COMPILES[0] += 1  # trace-time: once per XLA compile
    if interpret is None:
        interpret = INTERPRET
    n_lines = state0.cache.tags.shape[-1]
    length = pages.shape[0]
    use_table = (not masked) and length * n_lines <= NOISE_TABLE_MAX
    noise = cache_scan_noise(state0.key, length, n_lines) if use_table \
        else None
    if interpret or not use_table \
            or length * n_lines > NOISE_VMEM_MAX:
        return cache_scan_ref(
            state0, acc0, pages, writes, win, hyper, noise,
            epoch_width=cfg.epoch_width, pred_cap=cfg.pred_cap,
            prefetch=cfg.prefetch, prefetch_width=cfg.prefetch_width,
            n_windows=n_windows, unroll=unroll, masked=masked,
        )
    out = cache_scan_kernel(
        pages[None], writes[None], win[None], noise,
        hyper.alpha, hyper.beta, hyper.threshold, hyper.policy_idx,
        n_lines=n_lines, epoch_width=cfg.epoch_width,
        pred_cap=cfg.pred_cap, prefetch=cfg.prefetch,
        prefetch_width=cfg.prefetch_width,
        prefetch_buf=state0.pf.ptags.shape[-1], n_windows=n_windows,
        interpret=False,
    )
    # The kernel runs the row from the cold init state (what every one-shot
    # caller passes) and returns the accumulators directly; only the final
    # expert weights of the state are observable downstream.
    acc = jax.tree.map(
        lambda a0, a: a[0].reshape(jnp.shape(a0)).astype(a0.dtype),
        acc0, type(acc0)(**{f: out[f] for f in acc0._fields}))
    state = state0._replace(
        ols=state0.ols._replace(weights=out["final_weights"][0]),
        t=state0.t + length)
    return state, acc


def _cache_scan_body(pages_ref, writes_ref, win_ref, noise_ref,
                     alpha_ref, beta_ref, thr_ref, pol_ref,
                     scal_ref, eu_ref, winc_ref, weu_ref, ww_ref, fw_ref,
                     tags_s, valid_s, dirty_s, freq_s, ts_s,
                     pred_s, wts_s, predn_s, mispred_s, ptags_s, pvalid_s,
                     sm, *, length, n_lines, epoch_width, pred_cap,
                     prefetch, prefetch_width, prefetch_buf, n_windows):
    """One grid cell = one stream row, state resident in VMEM/SMEM scratch.

    Mirrors :func:`repro.kernels.ref.fused_cache_step` op for op (interpret
    mode is bit-identical by construction); arg-reductions are spelled as
    first-index min-selects (``min(where(mask, iota, BIG))``), which equal
    ``argmin``/``argmax`` first-match semantics exactly. The prediction
    rings are stored transposed (``[pred_cap, E]``) so the ring-cursor
    write is a row-iota compare against the ``[1, E]`` cursor — lane
    layouts only, no in-kernel transposes.
    """
    i32, f32 = jnp.int32, jnp.float32
    E = N_EXPERTS
    line = jax.lax.broadcasted_iota(i32, (1, n_lines), 1)
    eline = jax.lax.broadcasted_iota(i32, (1, E), 1)

    # Cold start: init_store() state, zeroed accumulators.
    tags_s[...] = jnp.full((1, n_lines), -1, i32)
    valid_s[...] = jnp.zeros((1, n_lines), i32)
    dirty_s[...] = jnp.zeros((1, n_lines), i32)
    freq_s[...] = jnp.zeros((1, n_lines), i32)
    ts_s[...] = jnp.zeros((1, n_lines), i32)
    pred_s[...] = jnp.full((pred_cap, E), -1, i32)
    wts_s[...] = jnp.full((1, E), 1.0 / E, f32)
    predn_s[...] = jnp.zeros((1, E), i32)
    mispred_s[...] = jnp.zeros((1, E), i32)
    ptags_s[...] = jnp.full((1, prefetch_buf), -1, i32)
    pvalid_s[...] = jnp.zeros((1, prefetch_buf), i32)
    for j in range(_N_SM):
        sm[j] = jnp.asarray(-1 if j == _SM_LAST_MISS else 0, i32)
    scal_ref[...] = jnp.zeros_like(scal_ref)
    eu_ref[...] = jnp.zeros_like(eu_ref)
    winc_ref[...] = jnp.zeros_like(winc_ref)
    weu_ref[...] = jnp.zeros_like(weu_ref)
    ww_ref[...] = jnp.zeros_like(ww_ref)

    alpha = alpha_ref[0, 0]
    beta = beta_ref[0, 0]
    thr = thr_ref[0, 0]
    pol = pol_ref[0, 0]

    def first_idx(mask, iota):
        return jnp.min(jnp.where(mask, iota, _BIG))

    def step(t, carry):
        page = pages_ref[0, t]
        is_w = writes_ref[0, t] != 0
        win_i = win_ref[0, t]
        nrow = noise_ref[pl.ds(t, 1), :]                  # (1, n_lines)
        tags, freq, ts = tags_s[...], freq_s[...], ts_s[...]
        valid, dirty = valid_s[...] != 0, dirty_s[...] != 0

        # --- lookup ---
        match = valid & (tags == page)
        hit = jnp.any(match)
        hit_oh = line == first_idx(match, line)
        ts_hit = jnp.where(hit_oh, t, ts)
        freq_hit = freq + hit_oh.astype(i32)
        dirty_hit = dirty | (hit_oh & is_w)

        # --- miss bookkeeping ---
        miss = ~hit
        hit_pred = jnp.max((pred_s[...] == page).astype(i32), axis=0,
                           keepdims=True)                 # (1, E)
        mispred_s[...] += jnp.where(miss, hit_pred, 0)
        sm[_SM_EPOCH_MISSES] = (sm[_SM_EPOCH_MISSES]
                                + jnp.where(miss, 1, 0).astype(i32))
        if prefetch:
            ptags, pvalid = ptags_s[...], pvalid_s[...] != 0
            pmatch = pvalid & (ptags == page)
            in_buf = jnp.any(pmatch)
            pvalid = jnp.where(miss & pmatch, False, pvalid)
            pvalid_s[...] = pvalid.astype(i32)
            sm[_SM_USEFUL] = (sm[_SM_USEFUL]
                              + jnp.where(miss & in_buf, 1, 0).astype(i32))
            promoted = miss & in_buf
        else:
            promoted = jnp.zeros((), bool)

        free = ~valid
        has_free = jnp.any(free)
        free_idx = first_idx(free, line)

        # --- GetVictim ---
        ts_m = jnp.where(valid, ts, _BIG)
        fq_m = jnp.where(valid, freq, _BIG)
        lru = first_idx(ts_m == jnp.min(ts_m), line)
        lfu = first_idx(fq_m == jnp.min(fq_m), line)
        nz = jnp.where(valid, nrow, -1.0)
        rnd = first_idx(nz == jnp.max(nz), line)
        w = wts_s[...]
        s = jnp.sum(w)
        probs = jnp.where(s > 0, w / s, 1.0 / E)
        learned = first_idx(probs == jnp.max(probs), eline)
        chosen = jnp.where(pol >= 0, jnp.clip(pol, 0, E - 1), learned)
        # E == 3 select chains (the expert contract of online_learning).
        victim_idx = jnp.where(chosen == 0, lru,
                               jnp.where(chosen == 1, lfu, rnd))
        vp_lru = jnp.sum(jnp.where(line == lru, tags, 0))
        vp_lfu = jnp.sum(jnp.where(line == lfu, tags, 0))
        vp_rnd = jnp.sum(jnp.where(line == rnd, tags, 0))
        victim_pages = jnp.where(eline == 0, vp_lru,
                                 jnp.where(eline == 1, vp_lfu, vp_rnd))

        evict = miss & ~has_free
        slot = jnp.where(has_free, free_idx, victim_idx)
        slot_oh = line == slot
        writeback = evict & jnp.any(slot_oh & dirty)

        # --- prediction rings (transposed [C, E] layout) ---
        ring = predn_s[...] % pred_cap                    # (1, E)
        riota = jax.lax.broadcasted_iota(i32, (pred_cap, E), 0)
        pred_new = jnp.where(riota == ring, victim_pages, pred_s[...])
        pred_s[...] = jnp.where(evict, pred_new, pred_s[...])
        predn_s[...] = jnp.where(evict, predn_s[...] + 1, predn_s[...])
        sm[_SM_CHOSEN] = jnp.where(evict, chosen, sm[_SM_CHOSEN])

        # --- insert + merge ---
        tags_n = jnp.where(miss, jnp.where(slot_oh, page, tags), tags)
        valid_n = jnp.where(miss, valid | slot_oh, valid)
        tags_s[...] = tags_n
        valid_s[...] = valid_n.astype(i32)
        dirty_s[...] = jnp.where(
            miss, jnp.where(slot_oh, is_w, dirty),
            jnp.where(hit, dirty_hit, dirty)).astype(i32)
        freq_s[...] = jnp.where(miss, jnp.where(slot_oh, 1, freq),
                                jnp.where(hit, freq_hit, freq))
        ts_s[...] = jnp.where(miss, jnp.where(slot_oh, t, ts),
                              jnp.where(hit, ts_hit, ts))

        # --- stream identifier + prefetch issue ---
        if prefetch:
            last_miss, stride = sm[_SM_LAST_MISS], sm[_SM_STRIDE]
            conf = sm[_SM_CONF]
            delta = page - last_miss
            same = (delta == stride) & (last_miss >= 0) & (delta != 0)
            conf_o = jnp.where(same, conf + 1,
                               jnp.where(delta != 0, 1, conf))
            stride_o = jnp.where(same, stride,
                                 jnp.where(delta != 0, delta, stride))
            stride_n = jnp.where(miss, stride_o, stride)
            conf_n = jnp.where(miss, conf_o, conf)
            sm[_SM_LAST_MISS] = jnp.where(miss, page, last_miss)
            sm[_SM_STRIDE] = stride_n
            sm[_SM_CONF] = conf_n
            n_before = sm[_SM_ISSUED]
            active = conf_n >= 2
            bline = jax.lax.broadcasted_iota(i32, (1, prefetch_buf), 1)

            def pbody(k, c):
                ptg, pvl, issued = c
                cand = page + (k + 1) * stride_n
                in_cache = jnp.any(valid_n & (tags_n == cand))
                in_buf2 = jnp.any(pvl & (ptg == cand))
                bfree = ~pvl
                do = (active & jnp.any(bfree) & ~in_cache & ~in_buf2
                      & (cand >= 0))
                boh = (bline == first_idx(bfree, bline)) & do
                return (jnp.where(boh, cand, ptg), pvl | boh,
                        issued + jnp.where(do, 1, 0).astype(i32))

            pt0, pv0 = ptags_s[...], pvalid_s[...] != 0
            pt1, pv1, iss1 = jax.lax.fori_loop(
                0, prefetch_width, pbody, (pt0, pv0, n_before))
            ptags_s[...] = jnp.where(miss, pt1, pt0)
            pvalid_s[...] = jnp.where(miss, pv1, pv0).astype(i32)
            issued_n = jnp.where(miss, iss1, n_before)
            sm[_SM_ISSUED] = issued_n
            prefetch_fetches = jnp.where(miss, issued_n - n_before, 0)
        else:
            prefetch_fetches = jnp.zeros((), i32)

        # --- epoch boundary (WeightAdjust, ws policy only) ---
        do_adj = ((t + 1) % epoch_width == 0) & (pol < 0)
        em = sm[_SM_EPOCH_MISSES]
        mis = mispred_s[...]
        losses = jnp.where(mis.astype(f32) >= thr * em.astype(f32),
                           mis, 0).astype(f32)
        prev = wts_s[...]
        wadj = prev * jnp.power(beta, losses)
        wadj = wadj + alpha * jnp.mean(prev - wadj)
        wadj = jnp.maximum(wadj, 1e-8)
        wadj = wadj / jnp.sum(wadj)
        wts_s[...] = jnp.where(do_adj, wadj, prev)
        pred_s[...] = jnp.where(do_adj, -1, pred_s[...])
        predn_s[...] = jnp.where(do_adj, 0, predn_s[...])
        mispred_s[...] = jnp.where(do_adj, 0, mis)
        sm[_SM_EPOCH_MISSES] = jnp.where(do_adj, 0, em)

        # --- fold (one-hot accumulators; pad win_i matches no slot) ---
        hit_c = hit.astype(i32)
        miss_c = miss.astype(i32)
        pfh_c = promoted.astype(i32)
        t2r_c = (miss & ~promoted).astype(i32) + prefetch_fetches
        t2w_c = writeback.astype(i32)
        ev_c = evict.astype(i32)
        expert = jnp.where(evict, chosen, 0)
        sc = jax.lax.broadcasted_iota(i32, (1, 8), 1)
        scal_ref[...] += jnp.where(
            sc == 0, hit_c, jnp.where(
                sc == 1, miss_c, jnp.where(
                    sc == 2, pfh_c, jnp.where(
                        sc == 3, t2r_c, jnp.where(
                            sc == 4, t2w_c, jnp.where(
                                sc == 5, ev_c, 0))))))
        eu_ref[...] += jnp.where(eline == expert, ev_c, 0)
        r7 = jax.lax.broadcasted_iota(i32, (1, 7, n_windows), 1)
        w7 = jax.lax.broadcasted_iota(i32, (1, 7, n_windows), 2)
        vals = jnp.where(
            r7 == 0, 1, jnp.where(
                r7 == 1, hit_c, jnp.where(
                    r7 == 2, miss_c, jnp.where(
                        r7 == 3, pfh_c, jnp.where(
                            r7 == 4, t2r_c, jnp.where(
                                r7 == 5, t2w_c, ev_c))))))
        winc_ref[...] += jnp.where(w7 == win_i, vals, 0)
        wW = jax.lax.broadcasted_iota(i32, (1, n_windows, E), 1)
        eE = jax.lax.broadcasted_iota(i32, (1, n_windows, E), 2)
        weu_ref[...] += jnp.where((wW == win_i) & (eE == expert), ev_c, 0)
        ww_ref[...] = jnp.where(wW == win_i, wts_s[...][:, None, :],
                                ww_ref[...])
        return carry

    jax.lax.fori_loop(0, length, step, jnp.zeros((), i32))
    fw_ref[...] = wts_s[...]


@functools.partial(jax.jit, static_argnames=(
    "n_lines", "epoch_width", "pred_cap", "prefetch", "prefetch_width",
    "prefetch_buf", "n_windows", "interpret"))
def cache_scan_kernel(
    pages: jnp.ndarray,   # int32[B, L] per-row request streams
    writes: jnp.ndarray,  # bool/int32[B, L]
    win: jnp.ndarray,     # int32[B, L] window ids (n_windows = pad/drop)
    noise: jnp.ndarray,   # f32[L, n_lines] shared Random-expert table
    alpha, beta, threshold, policy_idx,  # scalar or [B] hyper knobs
    *,
    n_lines: int,
    epoch_width: int = 4,
    pred_cap: int = 64,
    prefetch: bool = False,
    prefetch_width: int = 4,
    prefetch_buf: int = 16,
    n_windows: int = 1,
    interpret: bool = False,
) -> dict:
    """Batched Pallas cache scan: each of the ``B`` rows runs the whole
    request loop from the cold :func:`~repro.storage.tiered_store.init_store`
    state inside one grid cell, tier-1 state resident in VMEM scratch.

    Returns the accumulator dict (keys = the reference ``_Accum`` fields
    plus ``final_weights``): scalar counters ``[B]``, windowed counters
    ``[B, n_windows]``, ``win_expert_use``/``win_weights``
    ``[B, n_windows, E]``. Bit-identical to
    :func:`repro.kernels.ref.cache_scan_ref` over each row with the same
    ``noise`` table (golden-tested in interpret mode)."""
    B, L = pages.shape
    E = N_EXPERTS
    W = n_windows
    i32, f32 = jnp.int32, jnp.float32
    pages = jnp.asarray(pages, i32)
    writes = jnp.asarray(writes).astype(i32)
    win = jnp.asarray(win, i32)
    noise = jnp.asarray(noise, f32)
    # The ring only ever holds min(pred_cap, epoch_width) live entries:
    # under ws it is cleared every epoch (<= epoch_width evictions between
    # resets), and under fixed policies it is unobservable (weights never
    # adjust) — same truncation as cache_scan_ref, bit-exact.
    pred_cap = min(pred_cap, epoch_width)
    _CACHE_SCAN_COMPILES[0] += 1  # trace-time: once per XLA compile

    def knob(x, dtype):
        x = jnp.asarray(x, dtype)
        return jnp.broadcast_to(x.reshape(-1, 1), (B, 1))

    row = pl.BlockSpec((1, L), lambda b: (b, 0))
    smem1 = pl.BlockSpec((1, 1), lambda b: (b, 0),
                         memory_space=pltpu.SMEM)
    out = pl.pallas_call(
        functools.partial(
            _cache_scan_body, length=L, n_lines=n_lines,
            epoch_width=epoch_width, pred_cap=pred_cap, prefetch=prefetch,
            prefetch_width=prefetch_width, prefetch_buf=prefetch_buf,
            n_windows=W),
        grid=(B,),
        in_specs=[
            row, row, row,
            pl.BlockSpec((L, n_lines), lambda b: (0, 0)),  # shared noise
            smem1, smem1, smem1, smem1,
        ],
        out_specs=[
            pl.BlockSpec((1, 8), lambda b: (b, 0)),
            pl.BlockSpec((1, E), lambda b: (b, 0)),
            pl.BlockSpec((1, 7, W), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, W, E), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, W, E), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, E), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, 8), i32),       # packed scalar totals
            jax.ShapeDtypeStruct((B, E), i32),       # expert_use
            jax.ShapeDtypeStruct((B, 7, W), i32),    # packed win counters
            jax.ShapeDtypeStruct((B, W, E), i32),    # win_expert_use
            jax.ShapeDtypeStruct((B, W, E), f32),    # win_weights
            jax.ShapeDtypeStruct((B, E), f32),       # final_weights
        ],
        scratch_shapes=[
            pltpu.VMEM((1, n_lines), i32),   # tags
            pltpu.VMEM((1, n_lines), i32),   # valid
            pltpu.VMEM((1, n_lines), i32),   # dirty
            pltpu.VMEM((1, n_lines), i32),   # freq
            pltpu.VMEM((1, n_lines), i32),   # ts
            pltpu.VMEM((pred_cap, E), i32),  # prediction rings (transposed)
            pltpu.VMEM((1, E), f32),         # expert weights
            pltpu.VMEM((1, E), i32),         # pred_n
            pltpu.VMEM((1, E), i32),         # mispred
            pltpu.VMEM((1, prefetch_buf), i32),  # prefetch tags
            pltpu.VMEM((1, prefetch_buf), i32),  # prefetch valid
            pltpu.SMEM((_N_SM,), i32),       # scalar learner/prefetch state
        ],
        interpret=interpret,
    )(pages, writes, win, noise,
      knob(alpha, f32), knob(beta, f32), knob(threshold, f32),
      knob(policy_idx, i32))
    scal, eu, winc, weu, ww, fw = out
    return dict(
        hits=scal[:, 0], misses=scal[:, 1], prefetch_hits=scal[:, 2],
        tier2_reads=scal[:, 3], tier2_writes=scal[:, 4],
        evictions=scal[:, 5], expert_use=eu,
        win_requests=winc[:, 0], win_hits=winc[:, 1],
        win_misses=winc[:, 2], win_prefetch_hits=winc[:, 3],
        win_tier2_reads=winc[:, 4], win_tier2_writes=winc[:, 5],
        win_evictions=winc[:, 6], win_expert_use=weu, win_weights=ww,
        final_weights=fw,
    )
