"""Reuse-distance (Mattson LRU stack distance) extraction kernel.

Per request, the number of *distinct* keys touched since that key's last
access — the quantity the classic stack-distance / miss-ratio-curve
formulation is built on: under fully-associative LRU of capacity ``C`` a
request hits iff its reuse distance ``d < C``, so one pass over the stream
yields exact hit/miss counters for *every* cache size at once
(:mod:`repro.sim.mrc` builds the counters; this module computes ``d``).

The distance is reduced to a 2-D dominance count over the host-computed
previous-occurrence index ``P`` (``P[j]`` = index of the previous access of
``pages[j]`` within its shard row, ``-1`` for a first access):

    d_j = #{ k : P[j] < k < j  and  P[k] <= P[j]  and  valid[k] }

(the in-gap positions that are the *first* in-gap occurrence of their
page). The Pallas kernel tiles this count as a ``[block, block]``
broadcast-compare per ``(shard, query-block)`` grid cell, looping over
key blocks up to the query block — O(L^2/2) compares, VPU-friendly, no
inter-step dependence (contrast the sequential per-request ``lax.scan`` of
the cache engine). Distances never leak across shard rows (each grid cell
reads only its own row) or into pad slots (pads output ``-1`` and are
excluded from every count).

On this CPU container the production entry point :func:`reuse_distances`
dispatches to the pure-jax fallback (:func:`repro.kernels.ref.
reuse_distance_ref`, same math, same int32 results — bit-identical); on a
TPU backend (``REPRO_KERNELS=tpu``) it compiles the Pallas kernel. The
interpret-mode Pallas path stays testable everywhere
(``reuse_distance_kernel(..., interpret=True)``).
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import DIST_INF, reuse_distance_ref

__all__ = [
    "DIST_INF",
    "prev_occurrence",
    "reuse_distance_kernel",
    "reuse_distances",
    "reuse_compile_count",
    "reset_reuse_compile_count",
]

# Mirrors kernels/ops.py: interpret-mode (pure-jax fallback) unless the
# container bakes a real TPU toolchain.
INTERPRET = os.environ.get("REPRO_KERNELS", "interpret") != "tpu"

# Trace-time compile counter for the jitted distance engines (both the
# Pallas wrapper and the ref fallback) — the MRC bench gates on it exactly
# like benchmarks/bench_sweep.py gates on engine_compile_count().
_REUSE_COMPILES = [0]


def reuse_compile_count() -> int:
    """Number of XLA compiles of the distance engine so far."""
    return _REUSE_COMPILES[0]


def reset_reuse_compile_count() -> None:
    _REUSE_COMPILES[0] = 0


def prev_occurrence(sh_pages: np.ndarray, counts: np.ndarray):
    """Previous-occurrence index per request, host-side.

    ``sh_pages`` is the ``[S, L]`` partitioned key stream (per-shard
    substreams, padded at the row tails — :func:`repro.storage.
    tiered_store.partition_streams` layout); ``counts[s]`` is the number of
    real requests in row ``s``. Returns ``(prev, valid)``: int32 ``[S, L]``
    with ``prev[s, j]`` = column of the previous access of ``sh_pages[s,
    j]`` within row ``s`` (``-1`` if first access), and the bool ``[S, L]``
    real-position mask. Pads carry ``prev = -1`` and ``valid = False`` and
    never link to (or from) real positions; rows are fully independent.

    One vectorized lexsort over ``(shard, page, position)`` — O(T log T).
    """
    sh_pages = np.asarray(sh_pages)
    counts = np.asarray(counts)
    S, L = sh_pages.shape
    valid = np.arange(L)[None, :] < counts[:, None]
    shard = np.repeat(np.arange(S, dtype=np.int64), L)
    page = sh_pages.reshape(-1).astype(np.int64)
    pos = np.tile(np.arange(L, dtype=np.int64), S)
    idx = np.flatnonzero(valid.reshape(-1))
    order = idx[np.lexsort((pos[idx], page[idx], shard[idx]))]
    prev = np.full(S * L, -1, np.int64)
    if order.size > 1:
        same = (shard[order[1:]] == shard[order[:-1]]) & (
            page[order[1:]] == page[order[:-1]]
        )
        prev[order[1:][same]] = pos[order[:-1][same]]
    return prev.reshape(S, L).astype(np.int32), valid


def _dominance_kernel(p_ref, v_ref, pt_ref, vt_ref, o_ref, *, block: int):
    """One ``(shard, query-block)`` grid cell of the dominance count.

    ``p_ref``/``v_ref`` hold the full shard row (keys); ``pt_ref``/
    ``vt_ref`` hold this cell's query block as a ``[block, 1]`` column (a
    host-side transpose, so the kernel needs no in-register transposes).
    """
    jb = pl.program_id(1)
    j0 = jb * block
    pj = pt_ref[...]                                     # [block, 1] int32
    vj = vt_ref[...]                                     # [block, 1] int32
    jidx = j0 + jax.lax.broadcasted_iota(jnp.int32, (block, block), 0)

    def body(kb, acc):
        k0 = kb * block
        pk = p_ref[0:1, pl.ds(k0, block)]                # [1, block]
        vk = v_ref[0:1, pl.ds(k0, block)]                # [1, block]
        kidx = k0 + jax.lax.broadcasted_iota(jnp.int32, (block, block), 1)
        m = (
            (kidx > pj)
            & (kidx < jidx)
            & (pk <= pj)
            & (vk > 0)
        )
        return acc + jnp.sum(m.astype(jnp.int32), axis=1, keepdims=True)

    # Keys at or beyond the query block's end never satisfy k < j: loop
    # only over the jb+1 key blocks at or before the queries.
    acc = jax.lax.fori_loop(
        0, jb + 1, body, jnp.zeros((block, 1), jnp.int32)
    )
    out = jnp.where(pj >= 0, acc, DIST_INF)              # first access
    o_ref[...] = jnp.where(vj > 0, out, -1)              # padding


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def reuse_distance_kernel(
    prev: jnp.ndarray,   # int32[S, L] previous-occurrence index (-1 = first)
    valid: jnp.ndarray,  # bool[S, L]  real positions (False = padding)
    *,
    block: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Pallas dominance-count kernel: int32 ``[S, L]`` reuse distances
    (:data:`DIST_INF` for first accesses, ``-1`` at pad slots). Exact
    integer arithmetic — bit-identical to :func:`repro.kernels.ref.
    reuse_distance_ref` in both interpret and compiled modes."""
    prev = jnp.asarray(prev, jnp.int32)
    valid_i = jnp.asarray(valid, jnp.int32)
    S, L = prev.shape
    pad = (-L) % block
    P = jnp.pad(prev, ((0, 0), (0, pad)), constant_values=-1)
    V = jnp.pad(valid_i, ((0, 0), (0, pad)), constant_values=0)
    Lp = L + pad
    _REUSE_COMPILES[0] += 1  # trace-time: once per XLA compile

    out_t = pl.pallas_call(
        functools.partial(_dominance_kernel, block=block),
        grid=(S, Lp // block),
        in_specs=[
            pl.BlockSpec((1, Lp), lambda s, jb: (s, 0)),      # keys P
            pl.BlockSpec((1, Lp), lambda s, jb: (s, 0)),      # keys valid
            pl.BlockSpec((block, 1), lambda s, jb: (jb, s)),  # queries P^T
            pl.BlockSpec((block, 1), lambda s, jb: (jb, s)),  # queries V^T
        ],
        out_specs=pl.BlockSpec((block, 1), lambda s, jb: (jb, s)),
        out_shape=jax.ShapeDtypeStruct((Lp, S), jnp.int32),
        interpret=interpret,
    )(P, V, P.T, V.T)
    return out_t.T[:, :L]


@functools.partial(jax.jit, static_argnames=("block",))
def _ref_engine(prev, valid, *, block: int = 128):
    _REUSE_COMPILES[0] += 1  # trace-time: once per XLA compile
    return reuse_distance_ref(prev, valid, block=block)


def reuse_distances(
    prev: np.ndarray,
    valid: np.ndarray,
    *,
    block: int = 128,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Production entry point: Pallas kernel on a TPU backend, pure-jax
    :func:`~repro.kernels.ref.reuse_distance_ref` fallback on CPU (same
    int32 results, bit-identical). ``interpret=None`` follows the
    ``REPRO_KERNELS`` convention of :mod:`repro.kernels.ops`."""
    if interpret is None:
        interpret = INTERPRET
    if interpret:
        return _ref_engine(jnp.asarray(prev, jnp.int32),
                           jnp.asarray(valid, bool), block=block)
    return reuse_distance_kernel(jnp.asarray(prev, jnp.int32),
                                 jnp.asarray(valid, bool),
                                 block=block, interpret=False)
