"""Tier data movement kernel: batched page copies between pools.

The tier-1 <-> tier-2 engine hot path (evict write-backs, promotions,
prefill population): copy N whole pages between pools given index vectors.
Index vectors ride in scalar-prefetch SMEM so each grid step's BlockSpecs
address the right source/destination page; a -1 pair routes to the
destination scratch row (pools allocate one, see kvpool). The destination
is aliased in/out so untouched rows are preserved.

Pages are viewed as [rows, lane]-shaped payloads (lane = 128-aligned last
dim for the VPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(dst_idx_ref, src_idx_ref, dst_in_ref, src_ref, dst_out_ref):
    i = pl.program_id(0)
    ok = (dst_idx_ref[i] >= 0) & (src_idx_ref[i] >= 0)

    @pl.when(ok)
    def _copy():
        dst_out_ref[0] = src_ref[0]

    @pl.when(~ok)
    def _keep():
        dst_out_ref[0] = dst_in_ref[0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def page_copy(
    dst: jnp.ndarray,      # [Sd, R, C] destination pool (page payload [R, C])
    src: jnp.ndarray,      # [Ss, R, C]
    dst_idx: jnp.ndarray,  # [N] int32 (-1 = skip)
    src_idx: jnp.ndarray,  # [N] int32 (-1 = skip)
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    Sd, R, C = dst.shape
    N = dst_idx.shape[0]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(N,),
        in_specs=[
            pl.BlockSpec((1, R, C),
                         lambda i, di, si: (jnp.maximum(di[i], 0), 0, 0)),
            pl.BlockSpec((1, R, C),
                         lambda i, di, si: (jnp.maximum(si[i], 0), 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, R, C), lambda i, di, si: (jnp.maximum(di[i], 0), 0, 0)
        ),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(dst.shape, dst.dtype),
        input_output_aliases={2: 0},  # dst aliased in/out
        interpret=interpret,
    )(dst_idx, src_idx, dst, src)
