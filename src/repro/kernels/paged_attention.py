"""Paged decode attention, Pallas TPU kernel.

The device-local piece of the distributed two-tier decode: single-token
queries attend over this shard's *resident tier-1 pages*, gathered directly
from the page pool via the page table — the table rides in scalar-prefetch
SMEM so each grid step's BlockSpec index map picks the right pool slot (no
materialized gather in HBM). Pages are visited sequentially per sequence
with online-softmax state in VMEM scratch; the kernel returns the partial
(acc, m, l) so shards combine with the tiny psum/pmax of
``models.attention.combine_partials`` (paper: remote hits never move pages).

Layouts: q [B, H, hd]; pool [slots, page, 2, KV, hd];
page_slot [B, n_pages] (int32, -1 = non-resident); lengths [B].
Output: acc [B, H, hd] f32, m [B, H] f32, l [B, H] f32.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _kernel(page_slot_ref, lengths_ref, q_ref, pool_ref,
            acc_ref, m_ref, l_ref, m_scr, l_scr, acc_scr, *,
            page: int, n_kv: int, scale: float):
    b = pl.program_id(0)
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                     # [H, hd]
    blk = pool_ref[0].astype(jnp.float32)                # [page, 2, KV, hd]
    k = blk[:, 0]                                        # [page, KV, hd]
    v = blk[:, 1]
    H, hd = q.shape
    G = H // n_kv
    qg = q.reshape(n_kv, G, hd)
    s = jax.lax.dot_general(
        qg.reshape(n_kv * G, hd), k.reshape(page * n_kv, hd),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32,
    ).reshape(n_kv, G, page, n_kv)
    # keep only matching kv head: s[kv, g, t, kv]
    eye = jax.lax.broadcasted_iota(jnp.int32, (n_kv, n_kv), 0) == \
        jax.lax.broadcasted_iota(jnp.int32, (n_kv, n_kv), 1)
    s = jnp.sum(jnp.where(eye[:, None, None, :], s, 0.0), axis=3) * scale
    # [KV, G, page]

    resident = page_slot_ref[b, p] >= 0
    tok = p * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)[0]
    live = tok < lengths_ref[b]
    ok = live & resident
    s = jnp.where(ok[None, None, :], s, _NEG)

    m_prev = m_scr[...]                                  # [KV, G]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    pexp = jnp.exp(s - m_new[..., None])
    pexp = jnp.where(ok[None, None, :], pexp, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(pexp, axis=-1)
    pv = jax.lax.dot_general(
        pexp.reshape(n_kv * G, page) *
        jnp.ones((1,), jnp.float32),                     # [KV*G, page]
        v.reshape(page, n_kv * hd),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    ).reshape(n_kv, G, n_kv, hd)
    pv = jnp.sum(jnp.where(eye[:, None, :, None], pv, 0.0), axis=2)
    acc_scr[...] = acc_scr[...] * corr[..., None] + pv
    m_scr[...] = m_new

    @pl.when(p == pl.num_programs(1) - 1)
    def _done():
        H_, hd_ = acc_ref.shape[1], acc_ref.shape[2]
        acc_ref[0] = acc_scr[...].reshape(H_, hd_)
        m_ref[0] = m_scr[...].reshape(H_)
        l_ref[0] = l_scr[...].reshape(H_)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(
    q: jnp.ndarray,          # [B, H, hd]
    pool: jnp.ndarray,       # [slots, page, 2, KV, hd]
    page_slot: jnp.ndarray,  # [B, n_pages] int32 (-1 = non-resident)
    lengths: jnp.ndarray,    # [B] int32
    *,
    interpret: bool = False,
):
    B, H, hd = q.shape
    slots, page, _, KV, _ = pool.shape
    n_pages = page_slot.shape[1]
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(_kernel, page=page, n_kv=KV, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, n_pages),
        in_specs=[
            pl.BlockSpec((1, H, hd), lambda b, p, tbl, ln: (b, 0, 0)),
            pl.BlockSpec(
                (1, page, 2, KV, hd),
                # The page table IS the index map: resident slot or scratch 0.
                lambda b, p, tbl, ln: (jnp.maximum(tbl[b, p], 0), 0, 0, 0, 0),
            ),
        ],
        out_specs=[
            pl.BlockSpec((1, H, hd), lambda b, p, tbl, ln: (b, 0, 0)),
            pl.BlockSpec((1, H), lambda b, p, tbl, ln: (b, 0)),
            pl.BlockSpec((1, H), lambda b, p, tbl, ln: (b, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((KV, H // KV), jnp.float32),
            pltpu.VMEM((KV, H // KV), jnp.float32),
            pltpu.VMEM((KV, H // KV, hd), jnp.float32),
        ],
    )
    acc, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, H, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
        ],
        interpret=interpret,
    )(page_slot, lengths, q, pool)
    return acc, m, l
