"""Mamba-2 SSD chunked-scan kernel (state-space duality).

TPU adaptation of the SSD algorithm: grid (batch, head, chunk) with the
chunk dimension innermost/sequential; the inter-chunk state h [N, P] lives
in VMEM scratch, and each grid step runs the three MXU matmuls of the
intra-chunk form:

    CB    = C · Bᵀ                       [Q, Q]
    y     = (CB ⊙ causal-decay) · (dt·x) [Q, P]   (+ C·h_prev·exp(cum))
    h'    = decay_end·h_prev + (exp(cum_end - cum)·dt·B)ᵀ · x   [N, P]

Q = chunk length (128-aligned), N = state dim, P = head dim.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_scr, *, q: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, 0].astype(jnp.float32)     # [Q, P]
    dt = dt_ref[0, 0].astype(jnp.float32)   # [Q]
    A = a_ref[0].astype(jnp.float32)        # scalar (per head)
    Bm = b_ref[0].astype(jnp.float32)       # [Q, N]
    Cm = c_ref[0].astype(jnp.float32)       # [Q, N]

    dA = dt * A                              # [Q] (negative)
    cum = jnp.cumsum(dA)                     # [Q]

    CB = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                        # [Q, Q]
    t_i = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    s_i = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    decay = jnp.exp(cum[:, None] - cum[None, :])
    decay = jnp.where(t_i >= s_i, decay, 0.0)
    y_diag = jax.lax.dot_general(
        CB * decay, dt[:, None] * x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                        # [Q, P]

    h_prev = h_scr[...]                      # [N, P]
    y_off = jax.lax.dot_general(
        Cm * jnp.exp(cum)[:, None], h_prev, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    y_ref[0, 0] = (y_diag + y_off).astype(y_ref.dtype)

    edge = jnp.exp(cum[-1] - cum) * dt       # [Q]
    state_inc = jax.lax.dot_general(
        Bm * edge[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                        # [N, P]
    h_scr[...] = h_prev * jnp.exp(cum[-1]) + state_inc


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_kernel(
    x: jnp.ndarray,    # [B, S, H, P]
    dt: jnp.ndarray,   # [B, S, H]   (softplus'ed)
    A: jnp.ndarray,    # [H]         (negative)
    Bm: jnp.ndarray,   # [B, S, N]
    Cm: jnp.ndarray,   # [B, S, N]
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    grid = (B, H, S // Q)

    # Kernel-friendly layouts.
    xk = jnp.moveaxis(x, 2, 1)               # [B, H, S, P]
    dtk = jnp.moveaxis(dt, 2, 1)             # [B, H, S]

    kernel = functools.partial(_kernel, q=Q)
    yk = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Q), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(xk, dtk, A, Bm, Cm)
    return jnp.moveaxis(yk, 1, 2)            # [B, S, H, P]
