"""Flash attention forward, Pallas TPU kernel.

TPU-native tiling (not a CUDA port): the grid is (batch, q-head, q-block,
kv-block) with the kv-block dimension innermost and *sequential*, so the
online-softmax state (m, l, acc) lives in VMEM scratch across kv-block
iterations and the MXU sees [block_q, head_dim] x [head_dim, block_kv]
matmuls with 128-aligned tiles. GQA is handled in the BlockSpec index map
(kv head = q head // group); causal/sliding-window masking is applied
in-kernel with iota tiles.

Layouts: q [B, H, Sq, hd], k/v [B, KV, Skv, hd]. Output [B, H, Sq, hd].
Target: TPU v5e (validated on CPU via interpret=True against
``kernels/ref.py``).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: Optional[int],
            block_q: int, block_kv: int, n_kv: int):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)            # [bq, hd]
    k = k_ref[0, 0].astype(jnp.float32)            # [bkv, hd]
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                      # [bq, bkv]

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = kj * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
        if window is not None:
            mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, _NEG)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )
    m_scr[...] = m_new

    @pl.when(kj == pl.num_programs(3) - 1)
    def _done():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_kv", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,  # [B, H, Sq, hd]
    k: jnp.ndarray,  # [B, KV, Skv, hd]
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    B, H, Sq, hd = q.shape
    _, KV, Skv, _ = k.shape
    G = H // KV
    bq = min(block_q, Sq)
    bkv = min(block_kv, Skv)
    assert Sq % bq == 0 and Skv % bkv == 0, (Sq, bq, Skv, bkv)
    grid = (B, H, Sq // bq, Skv // bkv)
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        block_q=bq, block_kv=bkv, n_kv=KV,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bkv, hd), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bkv, hd), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=_scratch(bq, hd),
        interpret=interpret,
    )(q, k, v)


def _scratch(bq: int, hd: int):
    from jax.experimental.pallas import tpu as pltpu

    return [
        pltpu.VMEM((bq,), jnp.float32),      # m (running max)
        pltpu.VMEM((bq,), jnp.float32),      # l (running sum)
        pltpu.VMEM((bq, hd), jnp.float32),   # acc (weighted values)
    ]
