"""RG-LRU linear-recurrence kernel (recurrentgemma's temporal mixer).

TPU adaptation: the recurrence h_t = a_t h_{t-1} + b_t is elementwise over
the LRU width (VPU lanes) and sequential over time. The grid is
(batch, width-block, time-chunk) with the time dimension innermost and
sequential; the carry h lives in VMEM scratch; inside a chunk the recurrence
steps with a fori_loop over rows of the [chunk, width-block] tile — lanes
full, sublanes rolled. Gate math (sigmoid/exp/sqrt) is fused into the same
tile visit, so HBM traffic is one read of u and one write of h.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(u_ref, wa_ref, ba_ref, wx_ref, bx_ref, lam_ref, h_ref, carry, *,
            chunk: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        carry[...] = jnp.zeros_like(carry)

    u = u_ref[0].astype(jnp.float32)        # [chunk, wb]
    wa = wa_ref[...].astype(jnp.float32)    # [wb]
    ba = ba_ref[...].astype(jnp.float32)
    wx = wx_ref[...].astype(jnp.float32)
    bx = bx_ref[...].astype(jnp.float32)
    lam = lam_ref[...].astype(jnp.float32)

    r = jax.nn.sigmoid(u * wa + ba)
    i = jax.nn.sigmoid(u * wx + bx)
    log_a = -8.0 * jax.nn.softplus(lam) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * u)

    def step(s, h):
        h = a[s] * h + b[s]
        h_ref[0, s] = h.astype(h_ref.dtype)
        return h

    h = carry[...]
    h = jax.lax.fori_loop(0, chunk, lambda s, hh: step(s, hh), h)
    carry[...] = h


@functools.partial(
    jax.jit, static_argnames=("block_w", "chunk", "interpret")
)
def rglru_scan_kernel(
    u: jnp.ndarray,   # [B, S, W]
    w_a: jnp.ndarray, b_a: jnp.ndarray,
    w_x: jnp.ndarray, b_x: jnp.ndarray,
    lam: jnp.ndarray,  # all [W]
    *,
    block_w: int = 128,
    chunk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    B, S, W = u.shape
    bw = min(block_w, W)
    ch = min(chunk, S)
    assert W % bw == 0 and S % ch == 0
    grid = (B, W // bw, S // ch)

    kernel = functools.partial(_kernel, chunk=ch)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, ch, bw), lambda b, w, t: (b, t, w)),
            pl.BlockSpec((bw,), lambda b, w, t: (w,)),
            pl.BlockSpec((bw,), lambda b, w, t: (w,)),
            pl.BlockSpec((bw,), lambda b, w, t: (w,)),
            pl.BlockSpec((bw,), lambda b, w, t: (w,)),
            pl.BlockSpec((bw,), lambda b, w, t: (w,)),
        ],
        out_specs=pl.BlockSpec((1, ch, bw), lambda b, w, t: (b, t, w)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), u.dtype),
        scratch_shapes=[pltpu.VMEM((bw,), jnp.float32)],
        interpret=interpret,
    )(u, w_a, b_a, w_x, b_x, lam)
