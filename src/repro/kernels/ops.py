"""Jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels run in interpret mode (``interpret=True``
default via :data:`INTERPRET`); on real TPUs set ``REPRO_KERNELS=tpu`` (or
pass ``interpret=False``) to compile them for the MXU. The pure-jnp oracles
live in :mod:`repro.kernels.ref`.
"""
from __future__ import annotations

import os
from typing import Optional

import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.page_gather import page_copy as _page_copy
from repro.kernels.paged_attention import paged_attention as _paged
from repro.kernels.reuse_distance import reuse_distances as _reuse
from repro.kernels.rglru_scan import rglru_scan_kernel as _rglru
from repro.kernels.ssd_scan import ssd_scan_kernel as _ssd

__all__ = ["INTERPRET", "flash_attention", "paged_attention", "page_copy",
           "reuse_distances", "rglru_scan", "ssd_scan"]

INTERPRET = os.environ.get("REPRO_KERNELS", "interpret") != "tpu"


def flash_attention(q, k, v, *, causal=True, window: Optional[int] = None,
                    block_q=128, block_kv=128, interpret: Optional[bool] = None):
    return _flash(q, k, v, causal=causal, window=window, block_q=block_q,
                  block_kv=block_kv,
                  interpret=INTERPRET if interpret is None else interpret)


def paged_attention(q, pool, page_slot, lengths, *,
                    interpret: Optional[bool] = None):
    return _paged(q, pool, page_slot, lengths,
                  interpret=INTERPRET if interpret is None else interpret)


def page_copy(dst, src, dst_idx, src_idx, *, interpret: Optional[bool] = None):
    return _page_copy(dst, src, dst_idx, src_idx,
                      interpret=INTERPRET if interpret is None else interpret)


def reuse_distances(prev, valid, *, block=128,
                    interpret: Optional[bool] = None):
    """Reuse (LRU stack) distances per request — Pallas dominance-count
    kernel on TPU, bit-identical pure-jax fallback in interpret mode (the
    fallback is :func:`repro.kernels.ref.reuse_distance_ref`, not the
    interpreted kernel: same integer math, much faster on CPU)."""
    return _reuse(prev, valid, block=block,
                  interpret=INTERPRET if interpret is None else interpret)


def rglru_scan(u, w_a, b_a, w_x, b_x, lam, *, block_w=128, chunk=128,
               interpret: Optional[bool] = None):
    return _rglru(u, w_a, b_a, w_x, b_x, lam, block_w=block_w, chunk=chunk,
                  interpret=INTERPRET if interpret is None else interpret)


def ssd_scan(x, dt, A, Bm, Cm, *, chunk=128, interpret: Optional[bool] = None):
    return _ssd(x, dt, A, Bm, Cm, chunk=chunk,
                interpret=INTERPRET if interpret is None else interpret)
