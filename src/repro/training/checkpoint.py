"""Two-tier checkpointing + restart (fault tolerance).

The paper's tiering applied to training state: **tier 1** = frequent, fast
local snapshots (kept in a small ring, like NVMe burst buffers — restart
after a worker failure costs seconds), **tier 2** = infrequent durable
writes (parallel-FS class). Restore picks the newest *valid* checkpoint
across both tiers (manifest + per-leaf checksums catch torn writes).

Elastic restores: leaves are saved in the *global* view (host-gathered), so
a checkpoint taken on one mesh restores onto any other mesh — the loader
re-shards with the target mesh's PartitionSpecs (ZeRO-3 state included:
AdamW moments are elementwise, so resharding is sound).
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import time
import zlib
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["CheckpointConfig", "save_checkpoint", "restore_checkpoint",
           "latest_step"]


@dataclasses.dataclass(frozen=True)
class CheckpointConfig:
    dir_tier1: str = "ckpt/fast"    # frequent ring (fast restart)
    dir_tier2: str = "ckpt/durable"  # infrequent durable
    tier1_every: int = 20
    tier2_every: int = 100
    tier1_keep: int = 2


def _flatten(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _save_tree(tree: Any, path: str, step: int) -> None:
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    leaves, _ = _flatten(tree)
    manifest = {"step": step, "n_leaves": len(leaves), "time": time.time(),
                "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        fn = os.path.join(tmp, f"leaf_{i:05d}.npy")
        np.save(fn, arr)
        manifest["leaves"].append({
            "i": i, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "crc": zlib.crc32(arr.tobytes()) & 0xFFFFFFFF,
        })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)  # atomic publish


def _load_tree(like: Any, path: str) -> Any:
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = _flatten(like)
    assert len(leaves) == manifest["n_leaves"], "checkpoint/model mismatch"
    out = []
    for i, spec in enumerate(manifest["leaves"]):
        arr = np.load(os.path.join(path, f"leaf_{i:05d}.npy"))
        crc = zlib.crc32(arr.tobytes()) & 0xFFFFFFFF
        if crc != spec["crc"]:
            raise IOError(f"checksum mismatch in {path} leaf {i}")
        if arr.dtype.kind == "V":  # ml_dtypes (bf16/fp8) round-trip via .npy
            import ml_dtypes

            arr = arr.view(np.dtype(getattr(ml_dtypes, spec["dtype"])))
        out.append(jax.numpy.asarray(arr))
    return treedef.unflatten(out)


def _valid_ckpts(d: str) -> list[tuple[int, str]]:
    if not os.path.isdir(d):
        return []
    out = []
    for name in os.listdir(d):
        p = os.path.join(d, name)
        if name.startswith("step_") and os.path.exists(
                os.path.join(p, "manifest.json")):
            try:
                out.append((int(name.split("_")[1]), p))
            except ValueError:
                continue
    return sorted(out)


def save_checkpoint(state: Any, step: int, cfg: CheckpointConfig) -> list[str]:
    """Save per tier cadence; returns the paths written."""
    written = []
    if step % cfg.tier1_every == 0:
        p = os.path.join(cfg.dir_tier1, f"step_{step:08d}")
        _save_tree(state, p, step)
        written.append(p)
        # Ring eviction: keep the newest tier1_keep snapshots.
        for s, old in _valid_ckpts(cfg.dir_tier1)[:-cfg.tier1_keep]:
            shutil.rmtree(old, ignore_errors=True)
    if step % cfg.tier2_every == 0:
        p = os.path.join(cfg.dir_tier2, f"step_{step:08d}")
        _save_tree(state, p, step)
        written.append(p)
    return written


def latest_step(cfg: CheckpointConfig) -> Optional[int]:
    c = _valid_ckpts(cfg.dir_tier1) + _valid_ckpts(cfg.dir_tier2)
    return max(s for s, _ in c) if c else None


def restore_checkpoint(like: Any, cfg: CheckpointConfig) -> tuple[Any, int]:
    """Newest valid checkpoint across both tiers (tier-1 preferred on tie).
    Falls back to older snapshots if a newer one is corrupt."""
    cands = sorted(
        _valid_ckpts(cfg.dir_tier1) + _valid_ckpts(cfg.dir_tier2),
        key=lambda t: (t[0], "fast" in t[1]),
    )
    for step, path in reversed(cands):
        try:
            return _load_tree(like, path), step
        except Exception:
            continue
    raise FileNotFoundError("no valid checkpoint found")
