"""AdamW, implemented from scratch for sharded pytrees.

Optimizer state lives in the same sharding as the parameters (fully sharded
=> ZeRO-style: with FSDP+TP 2D sharding, per-device optimizer memory is
|params| * bytes / (data*model)). State dtype is configurable per model
(``opt_state_dtype`` — bf16 halves optimizer HBM for the 314B/405B configs).

Global-norm clipping under manual SPMD: each leaf's local squared norm is
weighted by 1/replication-factor before the cross-device psum so replicated
leaves are not double-counted (see ``training/train_step.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "AdamWState", "adamw_init", "adamw_update"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_frac: float = 0.1


class AdamWState(NamedTuple):
    step: jnp.ndarray  # int32 scalar
    mu: Any            # pytree like params
    nu: Any


def adamw_init(params: Any, dtype: str = "float32") -> AdamWState:
    dt = jnp.dtype(dtype)
    z = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(z, params),
        nu=jax.tree.map(z, params),
    )


def lr_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay to min_lr_frac."""
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (s - cfg.warmup_steps) / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def adamw_update(
    grads: Any,
    state: AdamWState,
    params: Any,
    cfg: AdamWConfig,
    *,
    grad_scale: jnp.ndarray | float = 1.0,
) -> tuple[Any, AdamWState]:
    """One AdamW step (elementwise — sharding-preserving).

    ``grad_scale`` multiplies gradients first (used for global-norm clip).
    """
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * grad_scale
        m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        v_new = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * gf * gf
        m_hat = m_new / bc1
        v_hat = v_new / bc2
        delta = m_hat / (jnp.sqrt(v_hat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v)
