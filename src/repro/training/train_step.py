"""The training step: loss -> synced grads -> clipped AdamW update.

Runs under manual SPMD (``shard_map(check_vma=True)``): JAX's varying-
manual-axes tracking makes the AD transposes insert exactly the right
gradient reductions over "data" (FSDP reduce-scatter) and "model" (TP
partials) — validated numerically against single-device AD in
``tests/test_spmd_equivalence.py``.

The "pod" axis (pure DP) is reduced *explicitly*: the loss is only
data-mean'ed in-graph, so pod-local gradients survive to this layer, where
they are either pmean'ed or int8-compressed with error feedback
(``training/compression.py``) — the hook for inter-pod gradient traffic.

Also provides microbatch gradient accumulation (scan) and a replication-
weighted global-norm clip that is exact under 2D sharding.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.axes import Axes
from repro.models import params as pm
from repro.models.transformer import fwd_train
from repro.training import compression
from repro.training.optimizer import AdamWConfig, AdamWState, adamw_update

__all__ = ["TrainHyper", "TrainState", "make_loss_and_grads", "make_train_step"]


@dataclasses.dataclass(frozen=True)
class TrainHyper:
    adamw: AdamWConfig = AdamWConfig()
    accum_steps: int = 1
    compress_pod_grads: bool = False
    aux_weight: float = 0.01


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    err_fb: Any  # error-feedback pytree (zeros when compression is off)


def global_grad_norm(grads: Any, gs_tree: Any, ax: Axes) -> jnp.ndarray:
    """Replication-weighted global L2 norm (exact under 2D sharding)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_s = treedef.flatten_up_to(gs_tree)
    total = jnp.zeros((), jnp.float32)
    for g, s in zip(flat_g, flat_s):
        rep = 1.0
        if s["data"] and ax.data is not None:
            rep *= ax.data_size
        if s["model_rep"] and ax.model is not None:
            rep *= ax.model_size
        total = total + jnp.sum(g.astype(jnp.float32) ** 2) / rep
    # pvary first: replicated contributions were pre-divided by their
    # replication factor, so psum over all axes is exact either way.
    from repro.distributed.axes import pvary_tree

    total = pvary_tree(total, tuple(n for n in (ax.data, ax.model) if n))
    total = ax.psum_many(total, (ax.data, ax.model))
    return jnp.sqrt(total)


def make_loss_and_grads(cfg: ModelConfig, ax: Axes, ms: pm.MeshSizes, hyper: TrainHyper):
    """(params, batch) -> (loss, metrics, synced grads). Handles microbatch
    accumulation when hyper.accum_steps > 1."""
    gs_tree = pm.grad_sync(cfg, ms)

    def promote(params):
        """Replicated leaves consumed shard-locally need their partial grads
        psum'ed (implicit on vma jax, pvary_entry shim on old jax)."""
        from repro.distributed.axes import pvary_entry

        flat_p, treedef = jax.tree.flatten(params)
        flat_s = treedef.flatten_up_to(gs_tree)
        out = []
        for p, s in zip(flat_p, flat_s):
            names = []
            if s["data"] and ax.data is not None:
                names.append(ax.data)
            if s["model"] and ax.model is not None:
                names.append(ax.model)
            out.append(pvary_entry(p, names))
        return jax.tree.unflatten(treedef, out)

    def loss_fn(params, batch):
        loss, metrics = fwd_train(
            promote(params), batch, cfg, ax, ms=ms, aux_weight=hyper.aux_weight
        )
        return loss, metrics

    vg = jax.value_and_grad(loss_fn, has_aux=True)

    def run(params, batch):
        a = hyper.accum_steps
        if a <= 1:
            (loss, metrics), grads = vg(params, batch)
        else:
            def micro(b):
                return jax.tree.map(
                    lambda x: x.reshape((a, x.shape[0] // a) + x.shape[1:]), b
                )

            mb = micro(batch)

            def body(acc, b):
                (loss, metrics), grads = vg(params, b)
                acc_g, acc_l = acc
                acc_g = jax.tree.map(
                    lambda t, g: t + g.astype(jnp.float32) / a, acc_g, grads
                )
                return (acc_g, acc_l + loss / a), metrics

            # Zero-init accumulators with the same varying-manual-axes as the
            # real gradients (check_vma-correct: derived via abstract eval).
            mb0 = jax.tree.map(lambda x: x[0], mb)
            g_shapes = jax.eval_shape(lambda p, b: vg(p, b)[1], params, mb0)
            from repro.distributed.axes import vma_of  # local import, no cycle

            def zero_like_vma(sds):
                z = jnp.zeros(sds.shape, jnp.float32)
                v = tuple(sorted(getattr(sds, "vma", ()) or ()))
                return jax.lax.pvary(z, v) if v else z

            zero_g = jax.tree.map(zero_like_vma, g_shapes)
            loss0 = zero_like_vma(
                jax.eval_shape(lambda p, b: vg(p, b)[0][0], params, mb0)
            )
            (grads, loss), metrics_all = jax.lax.scan(body, (zero_g, loss0), mb)
            metrics = jax.tree.map(lambda m: m[-1], metrics_all)
        return loss, metrics, grads

    return run, gs_tree


def make_train_step(
    cfg: ModelConfig,
    ax: Axes,
    ms: pm.MeshSizes,
    hyper: TrainHyper = TrainHyper(),
):
    """Build the SPMD train step body (to be wrapped in shard_map by the
    launcher, or called directly on one device)."""
    run, gs_tree = make_loss_and_grads(cfg, ax, ms, hyper)

    def step(state: TrainState, batch: dict):
        loss, metrics, grads = run(state.params, batch)
        err_fb = state.err_fb
        if ax.pod is not None:
            if hyper.compress_pod_grads:
                grads, err_fb = compression.compressed_psum(grads, err_fb, ax.pod)
            else:
                grads = jax.tree.map(lambda g: jax.lax.pmean(g, ax.pod), grads)
            loss = jax.lax.pmean(loss, ax.pod)
        gnorm = global_grad_norm(grads, gs_tree, ax)
        clip = hyper.adamw.clip_norm
        scale = (
            jnp.minimum(1.0, clip / jnp.maximum(gnorm, 1e-12))
            if clip is not None
            else jnp.asarray(1.0, jnp.float32)
        )
        # Fault tolerance: skip the update on non-finite gradients (bad data
        # shard / numeric overflow) instead of poisoning the params.
        scale = jnp.where(jnp.isfinite(gnorm), scale, 0.0)
        new_params, new_opt = adamw_update(
            grads, state.opt, state.params, hyper.adamw, grad_scale=scale
        )
        def rep(v):  # replicate across batch shards for P() out_specs
            return ax.pmean(ax.pmean(v, ax.data), ax.pod)

        out_metrics = {
            "loss": loss,
            "grad_norm": gnorm,
            "aux_loss": rep(metrics.aux_loss),
            "dropped": rep(metrics.dropped),
        }
        return TrainState(new_params, new_opt, err_fb), out_metrics

    return step
