"""Gradient compression for the inter-pod all-reduce (beyond-paper §Perf).

The pod axis is pure data parallelism: gradients are identical in shape and
must be psum'ed across pods over the (slow, inter-pod) links. We compress
that all-reduce with int8 block quantization + error feedback:

  scale = pmax(absmax(g_block)) / 127         (shared scale across pods)
  q     = round((g - err) / scale)  in int8   (err = residual from last step)
  g_sum = psum(q) * scale                     (int32 on the wire semantics)
  err  += dequant(q) - (g - err)

Wire bytes drop 4x vs f32 (2x vs bf16); error feedback keeps SGD unbiased
in the long run (Karimireddy et al., 2019). The tier analogy holds: this is
the paper's "increase the unit of data transfer / reduce arrival rate"
lever (§VI-B) applied to the gradient traffic between pods.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

__all__ = ["compressed_psum", "init_error_feedback"]


def init_error_feedback(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quantize_psum(g: jnp.ndarray, err: jnp.ndarray, axis_name: str):
    gf = g.astype(jnp.float32) + err
    amax = jnp.max(jnp.abs(gf))
    # Shared scale across the pod axis so the integer psum is exact.
    amax = jax.lax.pmax(amax, axis_name)
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    new_err = gf - deq
    summed = jax.lax.psum(q.astype(jnp.int32), axis_name).astype(jnp.float32)
    n = jax.lax.axis_size(axis_name)
    return (summed * scale / n).astype(g.dtype), new_err


def compressed_psum(
    grads: Any, err: Any, axis_name: Optional[str]
) -> tuple[Any, Any]:
    """pmean of ``grads`` over ``axis_name`` with int8 + error feedback.

    Returns (averaged grads, new error-feedback state). Identity when the
    axis is absent.
    """
    if axis_name is None:
        return grads, err
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err)
    out = [_quantize_psum(g, e, axis_name) for g, e in zip(flat_g, flat_e)]
    return (
        treedef.unflatten([o[0] for o in out]),
        treedef.unflatten([o[1] for o in out]),
    )
