"""Serving engine: chunk-free prefill + paged two-tier decode.

``decode_step`` is the paper's fig. 2 "client thread": it services the
current batch of requests against the distributed tier-1 cache (partial
flash-decode over policy-mapped pages + psum combine), forwarding page
misses to tier 2 in-line. ``promote_pages`` (kvpool) is the "IO thread",
run by the engine between steps. The OL learner adjusts eviction weights
every epoch exactly as in §III-A.

Page-shard geometry: pages are distributed over ``page_axes`` (a subset of
mesh axes, e.g. ("model",) for decode_32k, up to ("pod","data","model") for
long-context batch-1 decode); the batch is sharded over the remaining axes.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.core import online_learning as ol
from repro.distributed.axes import Axes, pvary_like, pvary_tree
from repro.models import params as pm
from repro.models.attention import (
    Partial,
    attention_partial,
    blockwise_attention,
    combine_partials,
)
from repro.models.layers import (
    apply_rope,
    dense,
    embed,
    mlp_gelu,
    mlp_swiglu,
    rms_norm,
    sinusoidal_positions,
    unembed_greedy,
)
from repro.models.moe import moe_swiglu
from repro.models.rglru import recurrent_block_step
from repro.models.ssd import ssd_block_step
from repro.serving import kvpool as kvp
from repro.serving.kvpool import KVSpec, PagedKV

__all__ = ["ServeConfig", "DecodeState", "make_decode_step", "init_decode_state",
           "decode_state_structs", "page_shard_index", "make_kv_spec"]

_F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_seq: int
    batch_local: int
    page_axes: tuple[str, ...] = ("model",)
    mapping: str = "block_cyclic"
    hbm_fraction: float = 0.5   # tier-1 capacity as fraction of owned pages
    n_promote: int = 2
    kv_dtype: str = "auto"      # "auto" (= param dtype) | "int8" (quantized)


def page_shard_index(ax: Axes, page_axes: tuple[str, ...]) -> jnp.ndarray:
    """Flat index of this device within the page-shard group. ``page_axes``
    holds semantic names ("pod"/"data"/"model") resolved via the Axes ctx."""
    me = jnp.zeros((), jnp.int32)
    for name in page_axes:
        actual = getattr(ax, name)
        me = me * ax.size(actual) + ax.index(actual)
    return me


def _page_shards(ax: Axes, page_axes: tuple[str, ...]) -> int:
    n = 1
    for name in page_axes:
        n *= ax.size(getattr(ax, name))
    return n


def make_kv_spec(cfg: ModelConfig, sc: ServeConfig, n_shards: int) -> KVSpec:
    """Static pool geometry for an (arch, serve shape) cell."""
    attn_pp = kvp.n_attn_layers(cfg)
    reps, tail = pm.model_layout(cfg)
    n_attn_layers = reps * len(attn_pp) + sum(
        1 for k in tail if k.startswith("attn")
    )
    n_pages = -(-sc.max_seq // cfg.page_size)
    total = sc.batch_local * n_pages
    owned = -(-total // n_shards) + 1
    window_pages = 0
    window = 0
    if all(k in ("attn_swa", "attn_local", "rglru", "ssd")
           for k in cfg.block_pattern) and any(
        k.startswith("attn") for k in cfg.block_pattern
    ):
        window_pages = -(-cfg.window // cfg.page_size) + 1
        window = cfg.window
    hbm = max(2, int(owned * sc.hbm_fraction))
    return KVSpec(
        b_local=sc.batch_local,
        n_pages=n_pages,
        page_size=cfg.page_size,
        n_kv=cfg.n_kv_heads,
        head_dim=cfg.head_dim,
        layers_per_slot=max(n_attn_layers, 1),
        hbm_slots=hbm,
        t2_slots=owned + 1,
        n_shards=n_shards,
        mapping=sc.mapping,
        read_pages=window_pages,
        window=window,
        dtype=cfg.param_dtype if sc.kv_dtype == "auto" else sc.kv_dtype,
    )


# ---------------------------------------------------------------------------
# Decode state.
# ---------------------------------------------------------------------------


class DecodeState(NamedTuple):
    kv: Optional[PagedKV]     # None for attention-free archs
    rec: Any                  # recurrent / cross-KV states per pattern position
    rec_tail: Any             # unstacked tail states


def _rec_state_one(kind: str, cfg: ModelConfig, ms: pm.MeshSizes, B: int,
                   struct: bool):
    mk = (lambda s, d: jax.ShapeDtypeStruct(s, d)) if struct else (
        lambda s, d: jnp.zeros(s, d))
    if kind == "rglru":
        w_l = cfg.d_model // ms.tp(cfg.d_model)
        return {"h": mk((B, w_l), _F32), "conv": mk((B, 3, w_l), cfg.param_dtype)}
    if kind == "ssd":
        s = cfg.ssm or SSMConfig()
        di = s.expand * cfg.d_model
        tp = ms.tp(di) if ms.tp(di) == ms.tp(di // s.head_dim) else 1
        di_l = di // tp
        H_l = di_l // s.head_dim
        return {
            "h": mk((B, H_l, s.state_dim, s.head_dim), _F32),
            "conv": mk((B, s.conv_width - 1, di_l + 2 * s.state_dim), cfg.param_dtype),
        }
    if kind.startswith("attn") and cfg.enc_dec:
        # Per-layer cross-attention KV (computed once from the encoder).
        sh = (B, cfg.enc_seq, cfg.n_kv_heads, cfg.head_dim)
        return {"ck": mk(sh, cfg.param_dtype), "cv": mk(sh, cfg.param_dtype)}
    return {}


def _rec_states(cfg: ModelConfig, ms: pm.MeshSizes, B: int, struct: bool):
    reps, tail = pm.model_layout(cfg)

    def stack(tree, n):
        return jax.tree.map(
            lambda x: (
                jax.ShapeDtypeStruct((n,) + x.shape, x.dtype)
                if struct else jnp.broadcast_to(x, (n,) + x.shape)
            ),
            tree,
        )

    rec = [
        stack(_rec_state_one(k, cfg, ms, B, struct), reps)
        for k in cfg.block_pattern
    ]
    rec_tail = [_rec_state_one(k, cfg, ms, B, struct) for k in tail]
    return rec, rec_tail


def _needs_kv(cfg: ModelConfig) -> bool:
    return any(k.startswith("attn") for k in cfg.layer_kinds())


def init_decode_state(
    cfg: ModelConfig, sc: ServeConfig, ax: Axes, ms: pm.MeshSizes, seed: int = 0
) -> DecodeState:
    spec = make_kv_spec(cfg, sc, _page_shards(ax, sc.page_axes))
    kv = None
    if _needs_kv(cfg):
        me = page_shard_index(ax, sc.page_axes)
        kv = kvp.init_paged_kv(spec, me, seed)
    rec, rec_tail = _rec_states(cfg, ms, sc.batch_local, struct=False)
    return DecodeState(kv=kv, rec=rec, rec_tail=rec_tail)


def decode_state_structs(
    cfg: ModelConfig, sc: ServeConfig, n_page_shards: int, ms: pm.MeshSizes
) -> DecodeState:
    spec = make_kv_spec(cfg, sc, n_page_shards)
    kv = kvp.paged_kv_structs(spec) if _needs_kv(cfg) else None
    rec, rec_tail = _rec_states(cfg, ms, sc.batch_local, struct=True)
    return DecodeState(kv=kv, rec=rec, rec_tail=rec_tail)


# ---------------------------------------------------------------------------
# Decode step.
# ---------------------------------------------------------------------------


def _decode_attention(
    x, p, cfg: ModelConfig, ax: Axes, sc: ServeConfig, spec: KVSpec,
    kv: PagedKV, plan, pools, li, positions,
):
    """One attention block at decode time over the distributed paged cache."""
    B, d = x.shape
    hd = cfg.head_dim
    tp_h = ax.tp_degree(cfg.n_heads)
    h_local = cfg.n_heads // tp_h
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    q = dense(h, p["wq"]).reshape(B, h_local, hd)
    k_new = dense(h, p["wk"]).reshape(B, cfg.n_kv_heads, hd)
    v_new = dense(h, p["wv"]).reshape(B, cfg.n_kv_heads, hd)
    if cfg.family != "audio":
        q = apply_rope(q[:, None], positions[:, None], cfg.rope_theta)[:, 0]
        k_new = apply_rope(k_new[:, None], positions[:, None],
                           cfg.rope_theta)[:, 0]
    # Full query on every page shard (tiny gather), partial attention locally.
    if tp_h > 1:
        q_full = ax.all_gather(q.reshape(B, h_local * hd), ax.model, axis=1)
        q_full = q_full.reshape(B, cfg.n_heads, hd)
    else:
        q_full = q.reshape(B, cfg.n_heads, hd)

    pools = kvp.write_token_kv(
        pools, plan, (k_new, v_new), kv.lengths, spec, li
    )
    k, v, valid = kvp.read_pages(pools, kv, spec, li)
    part = attention_partial(q_full, k, v, valid)
    names = tuple(
        getattr(ax, n) for n in ("pod", "data", "model") if n in sc.page_axes
    )
    o_full = combine_partials(part, ax, names)  # [B, H, hd] f32
    if tp_h > 1:
        start = ax.index(ax.model) * h_local
        o_loc = jax.lax.dynamic_slice_in_dim(o_full, start, h_local, axis=1)
    else:
        o_loc = o_full
    out = jnp.einsum(
        "bhd,hdD->bD", o_loc.astype(x.dtype), p["wo"].reshape(h_local, hd, d),
        preferred_element_type=_F32,
    )
    if tp_h > 1:
        out = ax.psum(out, ax.model)
    return out.astype(x.dtype), pools


def _decode_cross_attention(x, p, cfg, ax, cross_kv):
    B, d = x.shape
    hd = cfg.head_dim
    tp_h = ax.tp_degree(cfg.n_heads)
    h_local = cfg.n_heads // tp_h
    h = rms_norm(x, p["xnorm"], cfg.norm_eps)
    q = dense(h, p["xwq"]).reshape(B, h_local, hd)
    ck, cv = cross_kv
    from repro.models.transformer import _local_kv_slice  # reuse slicing rule

    ck4, cv4 = _local_kv_slice(ck, cv, cfg, ax)
    valid = jnp.ones(ck4.shape[:2], bool)
    # local q heads with local kv groups: G = h_local / kv_count
    part = attention_partial(q, ck4, cv4, valid)
    o = (part.acc / jnp.maximum(part.l, 1e-30)[..., None]).reshape(
        B, h_local, hd
    )
    out = jnp.einsum(
        "bhd,hdD->bD", o.astype(x.dtype), p["xwo"].reshape(h_local, hd, d),
        preferred_element_type=_F32,
    )
    if tp_h > 1:
        out = ax.psum(out, ax.model)
    return out.astype(x.dtype)


def _decode_ffn(x, p, cfg, ax):
    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    if cfg.moe is not None:
        out = moe_swiglu(h, p["w_router"], p["w_gate"], p["w_up"],
                         p["w_down"], cfg.moe, ax)
        return out.y
    if cfg.family == "audio":
        return mlp_gelu(h, p["w1"], p["b1"], p["w2"], p["b2"], ax)
    return mlp_swiglu(h, p["w_gate"], p["w_up"], p["w_down"], ax)


def make_decode_step(cfg: ModelConfig, sc: ServeConfig, ax: Axes,
                     ms: pm.MeshSizes):
    """Build the SPMD decode step body:
    (params, DecodeState, tokens[B_local]) -> (DecodeState, next_tokens)."""
    fdims = pm.fsdp_dims(cfg, ms)
    attn_pp = kvp.n_attn_layers(cfg)
    pattern = cfg.block_pattern
    reps, tail = pm.model_layout(cfg)
    n_attn_pp = len(attn_pp)

    def step(params, state: DecodeState, tokens):
        # Geometry (incl. hbm/t2 slot counts) depends on the page-shard count,
        # which is known only in mapped context.
        n_shards = _page_shards(ax, sc.page_axes)
        sp = make_kv_spec(cfg, sc, n_shards)
        kv = state.kv
        positions = kv.lengths if kv is not None else state_positions(state)
        emb = params["embed"]
        emb_g = emb if fdims["embed"] is None else ax.all_gather(
            emb, ax.data, axis=1)
        x = embed(tokens[:, None], emb_g, ax)[:, 0]  # [B, d]
        if cfg.family == "audio":
            x = x + sinusoidal_positions(positions[:, None], cfg.d_model)[
                :, 0].astype(x.dtype)

        if kv is not None:
            me = page_shard_index(ax, sc.page_axes)
            kv, plan = kvp.alloc_step(kv, sp, me, ol.OLConfig())
            if sp.quantized:
                pools = (kv.pool1, kv.pool2, kv.scale1, kv.scale2)
            else:
                pools = (kv.pool1, kv.pool2)
        else:
            plan, pools = None, None

        # The residual stream may pick up variance over any axis (paged reads,
        # recurrent states); fix the scan-carry type up front (free op).
        x = pvary_tree(x, tuple(n for n in (ax.pod, ax.data, ax.model) if n))

        def fetch(p, fd):
            return {
                k: (w if fd[k] is None else ax.all_gather(w, ax.data,
                                                          axis=fd[k]))
                for k, w in p.items()
            }

        def superblock(carry, xs):
            x, pools, r = carry
            layer_ps, recs = xs
            new_recs = []
            for i, kind in enumerate(pattern):
                pf = fetch(layer_ps[i], fdims["blocks"][i])
                if kind.startswith("attn"):
                    li = r * n_attn_pp + attn_pp.index(i)
                    delta, pools = _decode_attention(
                        x, pf, cfg, ax, sc, sp, kv, plan, pools, li, positions
                    )
                    x = x + delta
                    if cfg.enc_dec and "xwq" in pf:
                        x = x + _decode_cross_attention(
                            x, pf, cfg, ax, (recs[i]["ck"], recs[i]["cv"]))
                    x = x + _decode_ffn(x, pf, cfg, ax)
                    new_recs.append(recs[i])
                elif kind == "rglru":
                    h = rms_norm(x, pf["norm"], cfg.norm_eps)
                    out, ns = recurrent_block_step(h, recs[i], pf, ax)
                    x = x + out
                    x = x + _decode_ffn(x, pf, cfg, ax)
                    new_recs.append(ns)
                else:  # ssd
                    h = rms_norm(x, pf["norm"], cfg.norm_eps)
                    out, ns = ssd_block_step(
                        h, recs[i], pf, cfg.ssm or SSMConfig(), ax)
                    x = x + out
                    new_recs.append(ns)
            return (x, pools, r + 1), new_recs

        carry = (x, pools, jnp.zeros((), jnp.int32))
        if reps:
            carry, new_rec = jax.lax.scan(
                superblock, carry, (params["blocks"], state.rec)
            )
        else:
            new_rec = state.rec
        x, pools, r = carry
        new_tail = []
        for i, kind in enumerate(tail):
            pf = fetch(params["tail"][i], fdims["tail"][i])
            if kind.startswith("attn"):
                li = reps * n_attn_pp + sum(
                    1 for k in tail[:i] if k.startswith("attn"))
                delta, pools = _decode_attention(
                    x, pf, cfg, ax, sc, sp, kv, plan, pools,
                    jnp.asarray(li, jnp.int32), positions,
                )
                x = x + delta
                if cfg.enc_dec and "xwq" in pf:
                    x = x + _decode_cross_attention(
                        x, pf, cfg, ax,
                        (state.rec_tail[i]["ck"], state.rec_tail[i]["cv"]))
                x = x + _decode_ffn(x, pf, cfg, ax)
                new_tail.append(state.rec_tail[i])
            elif kind == "rglru":
                h = rms_norm(x, pf["norm"], cfg.norm_eps)
                out, ns = recurrent_block_step(h, state.rec_tail[i], pf, ax)
                x = x + out
                x = x + _decode_ffn(x, pf, cfg, ax)
                new_tail.append(ns)
            else:
                h = rms_norm(x, pf["norm"], cfg.norm_eps)
                out, ns = ssd_block_step(
                    h, state.rec_tail[i], pf, cfg.ssm or SSMConfig(), ax)
                x = x + out
                new_tail.append(ns)

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        emb_key = ("embed" if cfg.tie_embeddings or "unembed" not in params
                   else "unembed")
        ue = params[emb_key]
        ue_g = ue if fdims[emb_key] is None else ax.all_gather(
            ue, ax.data, axis=1)
        next_tok, logprob = unembed_greedy(x, ue_g, ax)

        if kv is not None:
            kv = kv._replace(
                pool1=pools[0], pool2=pools[1],
                lengths=kv.lengths + 1, t=kv.t + 1,
                **({"scale1": pools[2], "scale2": pools[3]}
                   if sp.quantized else {}),
            )
        new_state = DecodeState(kv=kv, rec=new_rec, rec_tail=new_tail)
        return new_state, (next_tok, logprob)

    return step


def state_positions(state: DecodeState) -> jnp.ndarray:
    """Positions for attention-free archs (track via a counter in rec[0])."""
    # Attention-free models (mamba2) do not carry lengths; decode positions
    # are irrelevant to the recurrence, so zeros suffice.
    leaf = jax.tree.leaves(state.rec)[0]
    B = leaf.shape[1] if leaf.ndim > 1 else 1
    return jnp.zeros((B,), jnp.int32)


# ---------------------------------------------------------------------------
# Prefill: full forward over the prompt, populating the two-tier pools and
# the recurrent states, returning a DecodeState ready for decode.
# ---------------------------------------------------------------------------


def make_prefill_step(cfg: ModelConfig, sc: ServeConfig, ax: Axes,
                      ms: pm.MeshSizes):
    """Build the SPMD prefill body:
    (params, tokens[B_local, S_prompt], extras) -> (DecodeState, first_token).

    ``extras``: {"frames": ...} for whisper (stub frame embeddings),
    {"prefix_embeds": ...} for the VLM prefix. S_prompt must be a multiple
    of the page size (pad prompts host-side).
    """
    from repro.models.transformer import (
        _cross_attention, _fetch, apply_block, encode_frames,
    )
    from repro.models.layers import embed as embed_fn

    fdims = pm.fsdp_dims(cfg, ms)
    pattern = cfg.block_pattern
    reps, tail = pm.model_layout(cfg)
    attn_pp = kvp.n_attn_layers(cfg)
    n_attn_pp = len(attn_pp)

    def step(params, tokens, extras=None):
        extras = extras or {}
        n_shards = _page_shards(ax, sc.page_axes)
        spec = make_kv_spec(cfg, sc, n_shards)
        B = tokens.shape[0]
        emb = params["embed"]
        emb_g = emb if fdims["embed"] is None else ax.all_gather(
            emb, ax.data, axis=1)
        x = embed_fn(tokens, emb_g, ax)
        prefix_len = 0
        if cfg.vlm_prefix and "prefix_embeds" in extras:
            prefix_len = extras["prefix_embeds"].shape[1]
            x = jnp.concatenate(
                [extras["prefix_embeds"].astype(x.dtype), x], axis=1)
        S = x.shape[1]
        positions = jnp.arange(S)[None, :]
        if cfg.family == "audio":
            x = x + sinusoidal_positions(
                positions[0], cfg.d_model)[None].astype(x.dtype)
        enc_out = None
        if cfg.enc_dec:
            enc_out = encode_frames(extras["frames"], params, cfg, ax, fdims)

        # Tier residency plan (meta only; the scan fills the pools).
        kv = None
        axis_names = tuple(n for n in (ax.pod, ax.data, ax.model) if n)
        if _needs_kv(cfg):
            me = page_shard_index(ax, sc.page_axes)
            kv = kvp.init_paged_kv(spec, me)
            kv = kvp.prefill_residency(
                kv, spec, jnp.full((spec.b_local,), S, jnp.int32))
            # Freshly-built state is constant-valued but device-local: mark
            # it varying over every mesh axis (free) for check_vma.
            kv = pvary_tree(kv, axis_names)
            if spec.quantized:
                pools = (kv.pool1, kv.pool2, kv.scale1, kv.scale2)
            else:
                pools = (kv.pool1, kv.pool2)
        else:
            pools = (jnp.zeros((), jnp.int32),) * 2  # dummy carry

        x = pvary_tree(x, axis_names) if axis_names else x
        pad_s = (-S) % spec.page_size if kv is not None else 0

        def one_block(i, kind, x, pools, layer_p, r):
            pf = _fetch(ax, layer_p, fdims["blocks"][i]
                        if isinstance(r, jnp.ndarray) else fdims["tail"][i])
            x, _, _, ex = apply_block(
                kind, x, pf, cfg, ax, positions,
                prefix_len=prefix_len, enc_out=enc_out, capture=True,
            )
            state = {}
            if kind.startswith("attn"):
                if kv is not None:
                    li = (r * n_attn_pp + attn_pp.index(i)
                          if isinstance(r, jnp.ndarray)
                          else jnp.asarray(r, jnp.int32))
                    k_full, v_full = ex
                    if pad_s:
                        k_full = jnp.pad(
                            k_full, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
                        v_full = jnp.pad(
                            v_full, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
                    pools = kvp.prefill_write(
                        pools, kv, spec, li, k_full, v_full)
                if cfg.enc_dec:
                    ck = dense(enc_out, pf["xwk"]).reshape(
                        B, cfg.enc_seq, cfg.n_kv_heads, cfg.head_dim)
                    cv = dense(enc_out, pf["xwv"]).reshape(
                        B, cfg.enc_seq, cfg.n_kv_heads, cfg.head_dim)
                    state = {"ck": ck, "cv": cv}
            else:
                state = ex
            return x, pools, state

        def superblock(carry, layer_ps):
            x, pools, r = carry
            states = []
            for i, kind in enumerate(pattern):
                x, pools, st = one_block(i, kind, x, pools, layer_ps[i], r)
                states.append(st)
            return (x, pools, r + 1), states

        carry = (x, pools, jnp.zeros((), jnp.int32))
        if reps:
            carry, rec = jax.lax.scan(superblock, carry, params["blocks"])
        else:
            rec, _ = _rec_states(cfg, ms, B, struct=False)
        x, pools, _ = carry
        rec_tail = []
        for i, kind in enumerate(tail):
            li_base = reps * n_attn_pp + sum(
                1 for k in tail[:i] if k.startswith("attn"))
            x, pools, st = one_block(i, kind, x, pools, params["tail"][i],
                                     li_base)
            rec_tail.append(st)

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        emb_key = ("embed" if cfg.tie_embeddings or "unembed" not in params
                   else "unembed")
        ue = params[emb_key]
        ue_g = ue if fdims[emb_key] is None else ax.all_gather(
            ue, ax.data, axis=1)
        next_tok, logprob = unembed_greedy(x[:, -1], ue_g, ax)

        if kv is not None:
            kv = kv._replace(
                pool1=pools[0], pool2=pools[1],
                **({"scale1": pools[2], "scale2": pools[3]}
                   if spec.quantized else {}),
            )
        state = DecodeState(kv=kv, rec=rec, rec_tail=rec_tail)
        return state, (next_tok, logprob)

    return step
