"""Paged two-tier KV cache — the paper's tier-1/tier-2 store on a TPU mesh.

Mapping of paper concepts (DESIGN.md §2):

- **cache line / page**: ``page_size`` consecutive tokens of one sequence's
  KV, across *all* attention layers (a page is the unit of residency and of
  tier movement, like the paper's posix-file cache lines).
- **tier 1**: a fixed pool of page slots in device HBM (``pool1``); states
  (tags/valid/dirty/freq/ts) mirror §III exactly and are stored separately
  from data, like the paper keeps states in CPU RAM and data on NVMe.
- **tier 2**: the full backing pool (``pool2``). On real TPUs this is
  pinned host memory (``memory_kind='pinned_host'``); here it is a second
  device array (CPU backend has one memory space — noted in DESIGN.md).
  The cache is *inclusive* and *write-back*: dirty tier-1 pages are copied
  down on eviction.
- **mapping policy**: pages are distributed over the page-shard axes of the
  mesh by ``core.mapping.page_to_shard`` (block / cyclic / random /
  round-robin). Decode attention is computed *in place* per shard
  (flash-decoding partials + a tiny combine psum), so a remote "hit" costs
  O(B·H·hd) collective bytes instead of moving the page — the TPU-native
  replacement for the paper's RPC'd remote hits.
- **OL eviction**: `core.online_learning` runs verbatim over the page
  metadata: every eviction records all experts' proposals; a tier-2 read of
  a recently evicted page is a misprediction; weights adjust per epoch.

All state is a pytree (``PagedKV``) carried through jitted steps.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import online_learning as ol
from repro.core.mapping import page_to_shard
from repro.distributed.axes import Axes
from repro.storage.cache_state import CacheState, init_cache

__all__ = ["KVSpec", "PagedKV", "init_paged_kv", "paged_kv_structs",
           "alloc_step", "write_token_kv", "read_pages", "promote_pages",
           "n_attn_layers"]

_F32 = jnp.float32


def n_attn_layers(cfg: ModelConfig) -> tuple[int, ...]:
    """Indices of attention positions within the block pattern."""
    return tuple(
        i for i, k in enumerate(cfg.block_pattern) if k.startswith("attn")
    )


@dataclasses.dataclass(frozen=True)
class KVSpec:
    """Static geometry of the paged pool (per device)."""

    b_local: int           # sequences on this device's batch shard
    n_pages: int           # pages per sequence (max_seq / page_size)
    page_size: int
    n_kv: int
    head_dim: int
    layers_per_slot: int   # attention layers stored per page (stacked dim)
    hbm_slots: int         # tier-1 capacity (pages)
    t2_slots: int          # tier-2 capacity (>= owned pages)
    n_shards: int          # page-shard group size (product of page axes)
    mapping: str = "block_cyclic"
    read_pages: int = 0    # pages visible to decode attention (0 = all)
    window: int = 0        # sliding-window size in tokens (0 = full)
    dtype: str = "bfloat16"  # "int8" => per-(token,k/v) scaled quantization

    @property
    def quantized(self) -> bool:
        return self.dtype == "int8"

    @property
    def total_pages(self) -> int:
        return self.b_local * self.n_pages

    def flat_id(self, b, p):
        return b * self.n_pages + p

    def owner(self, flat_id):
        return page_to_shard(
            flat_id, self.n_shards, self.total_pages, self.mapping
        )


class PagedKV(NamedTuple):
    """Device-local paged KV state (one pattern position's attention)."""

    pool1: jnp.ndarray      # [hbm_slots, Lp, page, 2, KV, hd]
    pool2: jnp.ndarray      # [t2_slots,  Lp, page, 2, KV, hd]
    scale1: jnp.ndarray     # [hbm_slots, Lp, page, 2] f32 (int8 mode; else [1])
    scale2: jnp.ndarray     # [t2_slots,  Lp, page, 2] f32
    meta: CacheState        # over hbm_slots; tags = flat page id
    page_slot: jnp.ndarray  # [B, n_pages] tier-1 slot or -1
    t2_slot: jnp.ndarray    # [B, n_pages] tier-2 slot (-1 if not owned)
    ols: ol.OLState
    lengths: jnp.ndarray    # [B] int32 tokens present
    t: jnp.ndarray          # int32[1] step counter
    key: jax.Array          # PRNG for the Random expert
    t2_reads: jnp.ndarray   # int32[1] stats: pages read from tier 2
    t1_reads: jnp.ndarray   # int32[1] stats: pages read from tier 1


def _t2_slot_table(spec: KVSpec, me: jnp.ndarray) -> jnp.ndarray:
    """[B, n_pages] tier-2 slot for owned pages, -1 otherwise. Computed
    in-graph (owner depends on the device's page-shard index)."""
    flat = jnp.arange(spec.total_pages, dtype=jnp.int32)
    mine = spec.owner(flat) == me
    rank = jnp.cumsum(mine.astype(jnp.int32)) - 1
    tbl = jnp.where(mine, rank, -1)
    return tbl.reshape(spec.b_local, spec.n_pages)


def init_paged_kv(spec: KVSpec, me: jnp.ndarray, seed: int = 0) -> PagedKV:
    dt = jnp.dtype(spec.dtype)
    # +1 scratch row on each pool (masked scatter target for prefill writes).
    shape1 = (spec.hbm_slots + 1, spec.layers_per_slot, spec.page_size, 2,
              spec.n_kv, spec.head_dim)
    shape2 = (spec.t2_slots,) + shape1[1:]
    if spec.quantized:
        sc1 = jnp.ones(shape1[:4], jnp.float32)
        sc2 = jnp.ones(shape2[:4], jnp.float32)
    else:
        sc1 = jnp.ones((1,), jnp.float32)
        sc2 = jnp.ones((1,), jnp.float32)
    return PagedKV(
        pool1=jnp.zeros(shape1, dt),
        pool2=jnp.zeros(shape2, dt),
        scale1=sc1,
        scale2=sc2,
        meta=init_cache(spec.hbm_slots),
        page_slot=jnp.full((spec.b_local, spec.n_pages), -1, jnp.int32),
        t2_slot=_t2_slot_table(spec, me),
        ols=ol.init_ol(ol.OLConfig()),
        lengths=jnp.zeros((spec.b_local,), jnp.int32),
        t=jnp.zeros((1,), jnp.int32),
        key=jax.random.PRNGKey(seed),
        t2_reads=jnp.zeros((1,), jnp.int32),
        t1_reads=jnp.zeros((1,), jnp.int32),
    )


def paged_kv_structs(spec: KVSpec) -> PagedKV:
    """ShapeDtypeStruct skeleton (dry-run, no allocation)."""
    dt = jnp.dtype(spec.dtype)
    shape1 = (spec.hbm_slots + 1, spec.layers_per_slot, spec.page_size, 2,
              spec.n_kv, spec.head_dim)
    shape2 = (spec.t2_slots,) + shape1[1:]
    S = jax.ShapeDtypeStruct
    i32 = jnp.int32
    sc1_shape = shape1[:4] if spec.quantized else (1,)
    sc2_shape = shape2[:4] if spec.quantized else (1,)
    return PagedKV(
        pool1=S(shape1, dt),
        pool2=S(shape2, dt),
        scale1=S(sc1_shape, jnp.float32),
        scale2=S(sc2_shape, jnp.float32),
        meta=CacheState(
            tags=S((spec.hbm_slots,), i32), valid=S((spec.hbm_slots,), bool),
            dirty=S((spec.hbm_slots,), bool), freq=S((spec.hbm_slots,), i32),
            ts=S((spec.hbm_slots,), i32),
        ),
        page_slot=S((spec.b_local, spec.n_pages), i32),
        t2_slot=S((spec.b_local, spec.n_pages), i32),
        ols=ol.OLState(
            weights=S((ol.N_EXPERTS,), jnp.float32),
            pred=S((ol.N_EXPERTS, ol.OLConfig().pred_cap), i32),
            pred_n=S((ol.N_EXPERTS,), i32),
            mispred=S((ol.N_EXPERTS,), i32),
            epoch_misses=S((1,), i32),
            chosen=S((1,), i32),
        ),
        lengths=S((spec.b_local,), i32),
        t=S((1,), i32),
        key=S((2,), jnp.uint32),
        t2_reads=S((1,), i32),
        t1_reads=S((1,), i32),
    )


# ---------------------------------------------------------------------------
# Metadata phase: allocation + OL eviction decisions (once per decode step,
# shared by every attention layer). Returns the plan the layer scan executes.
# ---------------------------------------------------------------------------


class AllocPlan(NamedTuple):
    cur_slot: jnp.ndarray   # [B] tier-1 slot of each sequence's current page
    evict_slot: jnp.ndarray  # [B] slot evicted to make room (-1 = none)
    evict_t2: jnp.ndarray    # [B] tier-2 slot of the evicted page (-1 = none)
    writeback: jnp.ndarray   # [B] bool — evicted page dirty?
    write_here: jnp.ndarray  # [B] bool — this shard owns the current page


def alloc_step(kv: PagedKV, spec: KVSpec, me: jnp.ndarray, cfg_ol: ol.OLConfig
               ) -> tuple[PagedKV, AllocPlan]:
    """Allocate tier-1 slots for each sequence's current page; evict via the
    OL policy when full; update LRU/LFU metadata and the OL learner."""
    B = spec.b_local
    page_idx = kv.lengths // spec.page_size           # [B]
    flat = kv.lengths // spec.page_size + jnp.arange(B) * spec.n_pages
    boundary = (kv.lengths % spec.page_size) == 0
    mine = spec.owner(flat) == me

    meta, ols, key = kv.meta, kv.ols, kv.key
    page_slot = kv.page_slot

    cur_slot = jnp.zeros((B,), jnp.int32)
    evict_slot = jnp.full((B,), -1, jnp.int32)
    evict_t2 = jnp.full((B,), -1, jnp.int32)
    writeback = jnp.zeros((B,), bool)

    # Pin every sequence's current page (single-writer: in-flight lines are
    # not eviction candidates).
    cur_flat = jnp.arange(B) * spec.n_pages + page_idx
    pinned = jnp.isin(meta.tags, cur_flat) & meta.valid

    for b in range(B):  # B is small; bounded per-sequence allocation
        need = boundary[b] & mine[b]
        have = page_slot[b, page_idx[b]] >= 0
        do_alloc = need & ~have
        key, vkey = jax.random.split(key)

        free = ~meta.valid
        has_free = jnp.any(free)
        free_idx = jnp.argmax(free).astype(jnp.int32)
        proposals = ol.propose_victims(meta, vkey, pinned)
        victim_pages = meta.tags[proposals]
        chosen = ol.choose_expert(ols)
        victim = proposals[chosen]
        slot = jnp.where(has_free, free_idx, victim)
        evicting = do_alloc & ~has_free

        # Record predictions + chosen expert on a real eviction.
        ols_pred = ol.record_predictions(ols, cfg_ol, victim_pages)
        ols = jax.tree.map(
            lambda new, old: jnp.where(evicting, new, old), ols_pred, ols
        )
        # Evicted page bookkeeping.
        v_flat = meta.tags[slot]
        v_b = v_flat // spec.n_pages
        v_p = v_flat % spec.n_pages
        page_slot = jnp.where(
            evicting,
            page_slot.at[v_b, v_p].set(-1),
            page_slot,
        )
        evict_slot = evict_slot.at[b].set(jnp.where(evicting, slot, -1))
        evict_t2 = evict_t2.at[b].set(
            jnp.where(evicting, kv.t2_slot[v_b, v_p], -1)
        )
        writeback = writeback.at[b].set(evicting & meta.dirty[slot])

        # Insert the new page.
        meta = CacheState(
            tags=jnp.where(do_alloc, meta.tags.at[slot].set(flat[b]), meta.tags),
            valid=jnp.where(do_alloc, meta.valid.at[slot].set(True), meta.valid),
            dirty=jnp.where(do_alloc, meta.dirty.at[slot].set(True), meta.dirty),
            freq=jnp.where(do_alloc, meta.freq.at[slot].set(1), meta.freq),
            ts=jnp.where(do_alloc, meta.ts.at[slot].set(kv.t[0]), meta.ts),
        )
        page_slot = jnp.where(
            do_alloc, page_slot.at[b, page_idx[b]].set(slot), page_slot
        )
        cur_slot = cur_slot.at[b].set(page_slot[b, page_idx[b]])
        # Newly allocated current pages are pinned for the rest of the step.
        pinned = pinned.at[slot].set(pinned[slot] | do_alloc)

    # The current page receives this step's token KV (write-back cache: mark
    # dirty so eviction copies it down to tier 2).
    wrote = (cur_slot >= 0) & mine                      # [B]
    meta = meta._replace(
        dirty=meta.dirty.at[jnp.clip(cur_slot, 0)].max(wrote)
    )

    # Touch resident pages read this step (LRU ts / LFU freq) + count tier-2
    # reads as misses for the OL learner.
    read_lo = _read_window_start(kv.lengths, spec)
    p_range = jnp.arange(spec.n_pages)[None, :]
    readable = (p_range * spec.page_size < kv.lengths[:, None]) & (
        p_range >= read_lo[:, None]
    )
    owned = kv.t2_slot >= 0
    resident = page_slot >= 0
    read_res = readable & resident & owned
    read_miss = readable & ~resident & owned
    slot_hit = jnp.zeros((spec.hbm_slots,), bool).at[
        jnp.clip(page_slot, 0, spec.hbm_slots - 1)
    ].max(read_res)
    meta = meta._replace(
        freq=meta.freq + slot_hit.astype(jnp.int32),
        ts=jnp.where(slot_hit, kv.t[0], meta.ts),
    )
    n_miss = jnp.sum(read_miss).astype(jnp.int32)
    # OL miss accounting: count once per missed page (prediction check).
    miss_flat = jnp.where(
        read_miss, p_range + jnp.arange(B)[:, None] * spec.n_pages, -1
    ).reshape(-1)
    hit_pred = jax.vmap(
        lambda page: jnp.any(ols.pred == page, axis=1) & (page >= 0)
    )(miss_flat).sum(axis=0)
    ols = ols._replace(
        mispred=ols.mispred + hit_pred.astype(jnp.int32),
        epoch_misses=ols.epoch_misses + n_miss,
    )
    # Epoch boundary weight adjust.
    epoch_end = (kv.t[0] + 1) % cfg_ol.epoch_width == 0
    ols_adj = ol.weight_adjust(ols, cfg_ol)
    ols = jax.tree.map(lambda new, old: jnp.where(epoch_end, new, old), ols_adj, ols)

    kv = kv._replace(
        meta=meta, ols=ols, key=key, page_slot=page_slot,
        t2_reads=kv.t2_reads + n_miss,
        t1_reads=kv.t1_reads + jnp.sum(read_res).astype(jnp.int32),
    )
    plan = AllocPlan(
        cur_slot=cur_slot, evict_slot=evict_slot, evict_t2=evict_t2,
        writeback=writeback, write_here=mine,
    )
    return kv, plan


def _read_window_start(lengths: jnp.ndarray, spec: KVSpec) -> jnp.ndarray:
    if spec.read_pages <= 0:
        return jnp.zeros_like(lengths)
    first = lengths // spec.page_size - (spec.read_pages - 1)
    return jnp.maximum(first, 0)


# ---------------------------------------------------------------------------
# Per-layer data phase, executed inside the layer scan.
# ---------------------------------------------------------------------------


def write_token_kv(
    pools,  # (pool1, pool2) or (pool1, pool2, scale1, scale2) in int8 mode
    plan: AllocPlan,
    kv_slot_data,  # k_new, v_new: [B, KV, hd]
    lengths: jnp.ndarray,
    spec: KVSpec,
    li: jnp.ndarray,  # layer index within the slot stack
):
    """Execute the alloc plan for one attention layer: write-back the evicted
    page slice, then write the new token's K/V into the current page.

    int8 mode quantizes the token's K and V with per-(token, k/v) scales."""
    quant = spec.quantized
    if quant:
        pool1, pool2, scale1, scale2 = pools
    else:
        pool1, pool2 = pools
        scale1 = scale2 = None
    k_new, v_new = kv_slot_data
    B = spec.b_local
    offset = lengths % spec.page_size
    new = jnp.stack([k_new, v_new], axis=1)  # [B, 2, KV, hd]
    if quant:
        amax = jnp.max(jnp.abs(new.astype(jnp.float32)), axis=(2, 3))  # [B,2]
        sc = jnp.maximum(amax, 1e-30) / 127.0
        new_q = jnp.clip(jnp.round(new.astype(jnp.float32) / sc[..., None, None]),
                         -127, 127).astype(jnp.int8)
    else:
        new_q = new.astype(pool1.dtype)
    for b in range(B):
        # Write-back of the evicted page's slice for this layer.
        src = pool1[jnp.clip(plan.evict_slot[b], 0), li]
        do_wb = plan.writeback[b] & (plan.evict_slot[b] >= 0)
        t2 = jnp.clip(plan.evict_t2[b], 0)
        pool2 = jnp.where(do_wb, pool2.at[t2, li].set(src), pool2)
        if quant:
            src_sc = scale1[jnp.clip(plan.evict_slot[b], 0), li]
            scale2 = jnp.where(do_wb, scale2.at[t2, li].set(src_sc), scale2)
        # Append the token KV.
        do_w = plan.write_here[b]
        s = jnp.clip(plan.cur_slot[b], 0)
        pool1 = jnp.where(
            do_w, pool1.at[s, li, offset[b]].set(new_q[b]), pool1
        )
        if quant:
            scale1 = jnp.where(
                do_w, scale1.at[s, li, offset[b]].set(sc[b]), scale1
            )
    if quant:
        return pool1, pool2, scale1, scale2
    return pool1, pool2


def read_pages(
    pools,
    kv: PagedKV,
    spec: KVSpec,
    li: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Gather this device's readable KV for one layer.

    Returns (k, v, valid): [B, R*page, KV, hd] with a validity mask marking
    live tokens of owned pages (resident pages come from tier-1 slots,
    non-resident from their tier-2 home — the "page miss serviced by tier 2"
    path whose bytes the roofline charges to the host link).
    """
    quant = spec.quantized
    if quant:
        pool1, pool2, scale1, scale2 = pools
    else:
        pool1, pool2 = pools
    B = spec.b_local
    R = spec.read_pages if spec.read_pages > 0 else spec.n_pages
    lo = _read_window_start(kv.lengths, spec)                     # [B]
    p_idx = lo[:, None] + jnp.arange(R)[None, :]                  # [B, R]
    p_idx = jnp.clip(p_idx, 0, spec.n_pages - 1)
    slot = jnp.take_along_axis(kv.page_slot, p_idx, axis=1)       # [B, R]
    t2 = jnp.take_along_axis(kv.t2_slot, p_idx, axis=1)
    owned = t2 >= 0
    resident = slot >= 0

    from1 = pool1[jnp.clip(slot, 0), li]   # [B, R, page, 2, KV, hd]
    from2 = pool2[jnp.clip(t2, 0), li]
    sel = resident[..., None, None, None, None]
    data = jnp.where(sel, from1, from2)
    if quant:
        sc1 = scale1[jnp.clip(slot, 0), li]    # [B, R, page, 2]
        sc2 = scale2[jnp.clip(t2, 0), li]
        sc = jnp.where(resident[..., None, None], sc1, sc2)
        data = data.astype(jnp.float32) * sc[..., None, None]
        data = data.astype(jnp.bfloat16)
    k = data[..., 0, :, :].reshape(B, R * spec.page_size, spec.n_kv,
                                   spec.head_dim)
    v = data[..., 1, :, :].reshape(B, R * spec.page_size, spec.n_kv,
                                   spec.head_dim)
    tok_pos = (p_idx[:, :, None] * spec.page_size
               + jnp.arange(spec.page_size)[None, None, :])       # [B,R,page]
    live = tok_pos <= kv.lengths[:, None, None]  # include the just-written token
    if spec.window > 0:  # sliding-window mask (q position == lengths)
        live &= tok_pos > (kv.lengths[:, None, None] - spec.window)
    valid = (owned[:, :, None] & live).reshape(B, R * spec.page_size)
    return k, v, valid


# ---------------------------------------------------------------------------
# Prefill: residency init + bulk page writes.
# ---------------------------------------------------------------------------


def prefill_residency(
    kv: PagedKV, spec: KVSpec, prompt_len: jnp.ndarray
) -> PagedKV:
    """Initialize tier-1 residency after a prefill of ``prompt_len`` tokens:
    the most recent owned pages become resident (LRU-friendly warm start),
    older pages live only in tier 2. Returns kv with meta/page_slot/lengths
    set (pool writes happen per layer via :func:`prefill_write`)."""
    B, NP = spec.b_local, spec.n_pages
    p_range = jnp.arange(NP)[None, :]
    in_prompt = p_range * spec.page_size < prompt_len[:, None]
    owned = kv.t2_slot >= 0
    cand = (in_prompt & owned).reshape(-1)
    # Recency key: later pages first (ties broken by batch index).
    key = (p_range * B + jnp.arange(B)[:, None]).reshape(-1)
    sort_key = jnp.where(cand, -key, jnp.iinfo(jnp.int32).max)
    order = jnp.argsort(sort_key)  # resident candidates first, newest first
    n_res = min(spec.hbm_slots, B * NP)
    chosen = order[:n_res]
    is_cand = cand[chosen]
    slots = jnp.arange(n_res, dtype=jnp.int32)
    page_slot = jnp.full((B * NP,), -1, jnp.int32).at[chosen].set(
        jnp.where(is_cand, slots, -1)
    ).reshape(B, NP)
    flat_ids = chosen.astype(jnp.int32)
    p_of = flat_ids % NP
    meta = CacheState(
        tags=jnp.full((spec.hbm_slots,), -1, jnp.int32).at[slots].set(
            jnp.where(is_cand, flat_ids, -1)
        ),
        valid=jnp.zeros((spec.hbm_slots,), bool).at[slots].set(is_cand),
        dirty=jnp.zeros((spec.hbm_slots,), bool),  # write-through at prefill
        freq=jnp.zeros((spec.hbm_slots,), jnp.int32).at[slots].set(
            is_cand.astype(jnp.int32)
        ),
        ts=jnp.zeros((spec.hbm_slots,), jnp.int32).at[slots].set(
            jnp.where(is_cand, p_of, 0)
        ),
    )
    return kv._replace(meta=meta, page_slot=page_slot, lengths=prompt_len,
                       t=jnp.zeros((1,), jnp.int32))


def prefill_write(
    pools,
    kv: PagedKV,
    spec: KVSpec,
    li: jnp.ndarray,
    k: jnp.ndarray,  # [B, S, KV, hd] (S padded to a page multiple)
    v: jnp.ndarray,
):
    """Write one layer's prefill KV into both pools (owned pages only;
    resident pages also land in tier 1). Scratch rows absorb masked writes."""
    quant = spec.quantized
    if quant:
        pool1, pool2, scale1, scale2 = pools
    else:
        pool1, pool2 = pools
    B = spec.b_local
    S = k.shape[1]
    npg = S // spec.page_size
    data = jnp.stack([k, v], axis=2)  # [B, S, 2, KV, hd]
    data = data.reshape(B * npg, spec.page_size, 2, spec.n_kv, spec.head_dim)
    if quant:
        amax = jnp.max(jnp.abs(data.astype(jnp.float32)), axis=(3, 4))
        sc = jnp.maximum(amax, 1e-30) / 127.0     # [B*npg, page, 2]
        data = jnp.clip(jnp.round(data.astype(jnp.float32) / sc[..., None, None]),
                        -127, 127).astype(jnp.int8)
    else:
        data = data.astype(pool1.dtype)
    t2 = kv.t2_slot[:, :npg].reshape(-1)
    slot1 = kv.page_slot[:, :npg].reshape(-1)
    idx2 = jnp.where(t2 >= 0, t2, spec.t2_slots - 1)          # scratch last row
    idx1 = jnp.where(slot1 >= 0, slot1, spec.hbm_slots)       # scratch row
    pool2 = pool2.at[idx2, li].set(data)
    pool1 = pool1.at[idx1, li].set(data)
    if quant:
        scale2 = scale2.at[idx2, li].set(sc)
        scale1 = scale1.at[idx1, li].set(sc)
        return pool1, pool2, scale1, scale2
    return pool1, pool2


# ---------------------------------------------------------------------------
# IO-thread analog: promotion of hot tier-2 pages between decode steps.
# ---------------------------------------------------------------------------


def promote_pages(kv: PagedKV, spec: KVSpec, n_promote: int = 2) -> PagedKV:
    """Promote up to ``n_promote`` readable-but-nonresident owned pages into
    free tier-1 slots (the paper's prefetch-on-idle IO thread; "prefetching
    is performed only if there are empty slots")."""
    p_range = jnp.arange(spec.n_pages)[None, :]
    lo = _read_window_start(kv.lengths, spec)
    readable = (p_range * spec.page_size < kv.lengths[:, None]) & (
        p_range >= lo[:, None]
    )
    cand = readable & (kv.page_slot < 0) & (kv.t2_slot >= 0)
    flat_cand = cand.reshape(-1)
    meta, page_slot, pool1, scale1 = kv.meta, kv.page_slot, kv.pool1, kv.scale1

    def body(i, carry):
        meta, page_slot, pool1, scale1 = carry
        free = ~meta.valid
        has_free = jnp.any(free)
        slot = jnp.argmax(free).astype(jnp.int32)
        nxt = jnp.argmax(flat_cand & (page_slot.reshape(-1) < 0))
        do = has_free & flat_cand[nxt] & (page_slot.reshape(-1)[nxt] < 0)
        b, p = nxt // spec.n_pages, nxt % spec.n_pages
        t2 = jnp.clip(kv.t2_slot[b, p], 0)
        pool1 = jnp.where(do, pool1.at[slot].set(kv.pool2[t2]), pool1)
        if spec.quantized:
            scale1 = jnp.where(do, scale1.at[slot].set(kv.scale2[t2]), scale1)
        meta = CacheState(
            tags=jnp.where(do, meta.tags.at[slot].set(nxt), meta.tags),
            valid=jnp.where(do, meta.valid.at[slot].set(True), meta.valid),
            dirty=jnp.where(do, meta.dirty.at[slot].set(False), meta.dirty),
            freq=jnp.where(do, meta.freq.at[slot].set(1), meta.freq),
            ts=jnp.where(do, meta.ts.at[slot].set(kv.t[0]), meta.ts),
        )
        page_slot = jnp.where(
            do, page_slot.at[b, p].set(slot), page_slot
        )
        return meta, page_slot, pool1, scale1

    meta, page_slot, pool1, scale1 = jax.lax.fori_loop(
        0, n_promote, body, (meta, page_slot, pool1, scale1)
    )
    return kv._replace(meta=meta, page_slot=page_slot, pool1=pool1,
                       scale1=scale1)
