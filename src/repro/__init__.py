"""repro: two-tiered storage for JAX/TPU training & serving.

Reproduction + TPU-native extension of "Performance Models for a Two-tiered
Storage System" (Sasidharan et al., CS.DC 2025).
"""
__version__ = "0.1.0"
