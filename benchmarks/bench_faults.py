"""Fault-injection benchmark: degraded-mode accuracy, retry-storm
dynamics, fault-grid compile behavior and report determinism.

  PYTHONPATH=src python benchmarks/bench_faults.py [--smoke]

Measures the ISSUE-6 fault-injection subsystem (``FaultSpec`` wall-clock
schedules, retry feedback in ``repro.core.queuing.fluid_two_tier``,
failover remap + cold refill in ``repro.sim``) and writes a
``BENCH_faults.json`` artifact at the repo root.

Gates:

- **degraded accuracy** — a constant degraded interval converges to the
  closed-form stationary solve at the degraded μ, and every healthy window
  before the fault is *bit-exact* against the pre-fault fluid path (the
  no-fault solver branch is kept verbatim; faults only pay for what they
  touch).
- **retry storm** — after a burst, an aggressive retry policy (hot
  timeouts, no backoff) is flagged metastable by
  :meth:`FluidReport.metastable_onset` while the same budget with capped
  exponential backoff drains; backlog curves order aggressive >= gentle
  >= no-retries window by window.
- **compile gate** — a fault grid (outage start times x retry policies,
  the no-fault point included) rides the megabatch as data and compiles
  the engine at most :data:`COMPILE_LIMIT` times.
- **determinism** — same seed + same fault schedule => byte-identical
  ``SimReport.to_dict()`` JSON across runs.

``--smoke`` shrinks the engine-heavy stages for CI; every gate still runs.
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core.queuing import (  # noqa: E402
    RetryPolicy,
    fluid_two_tier,
    transient_two_tier,
)
from repro.core.traffic import TrafficSpec  # noqa: E402
from repro.sim import (  # noqa: E402
    FaultSpec,
    RateSpec,
    SimSpec,
    device_degrade,
    shard_down,
    simulate,
    sweep,
)
from repro.sim.sweep import (  # noqa: E402
    engine_compile_count,
    reset_engine_compile_count,
)
from repro.storage.tiered_store import StoreConfig  # noqa: E402

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ARTIFACT = os.path.join(ROOT, "BENCH_faults.json")
COMPILE_LIMIT = 2
MU1, MU2 = 100.0, 33.0

# Timed §V-flavored base scenario (wall-clock arrivals, fluid transient).
BASE = SimSpec(
    traffic=TrafficSpec(kind="irm", n_requests=1500, n_pages=256,
                        zipf_s=0.8, write_fraction=0.2, seed=7, rate=100.0),
    store=StoreConfig(n_lines=64, policy="ws"),
    n_shards=4,
    lam=25.0,
    k_servers=1,
    rates=RateSpec(mu1=MU1, mu2=MU2),
    p12_override=0.2,
    window_dt=1.0,
    transient_mode="fluid",
)

# Retry-storm scenario (locked by tests/test_faults.py as well): a 2-window
# burst deposits backlog; external load then sits well under capacity.
STORM_LAM = np.array([30.0] * 4 + [130.0] * 2 + [30.0] * 18)
STORM_P12 = 0.1
AGGRESSIVE = RetryPolicy(timeout=0.2, max_retries=4,
                         backoff_base=1.0, backoff_init=0.2)
GENTLE = RetryPolicy(timeout=0.2, max_retries=4,
                     backoff_base=4.0, backoff_init=0.5, backoff_cap=8.0)


def bench_degraded_accuracy() -> dict:
    """Constant degraded interval: stationary accuracy + healthy-window
    bit-exactness vs the unfaulted fluid path."""
    n, w0 = 40, 10
    lam = np.full(n, 30.0)
    p12 = np.full(n, 0.1)
    mu1 = np.full(n, MU1)
    mu1_deg = mu1.copy()
    mu1_deg[w0:] = 0.5 * MU1
    base = fluid_two_tier(lam, p12, mu1, MU2, dt=1.0)
    deg = fluid_two_tier(lam, p12, mu1_deg, MU2, dt=1.0)
    # Closed-form stationary network at the degraded rate (the piecewise
    # mode *is* the per-window closed-form solve).
    ref = transient_two_tier(lam[-1:], np.array([STORM_P12]), 0.5 * MU1,
                             MU2, mode="piecewise")
    w1_err = abs(float(deg.w1[-1]) - float(np.asarray(ref.w1)[-1]))
    rel_err = w1_err / float(np.asarray(ref.w1)[-1])
    healthy_exact = all(
        np.array_equal(np.asarray(getattr(base, f))[:w0],
                       np.asarray(getattr(deg, f))[:w0])
        for f in ("q1", "q2", "w1", "w2", "rho1", "rho2", "response",
                  "stable")
    )
    # Engine-level: a factor=1.0 degrade walks the entire fault path (mu
    # multipliers, spill branch, remap plumbing) and must not move a bit
    # of the transient solution.
    rep_plain = simulate(BASE)
    rep_noop = simulate(BASE.replace(faults=FaultSpec(
        events=(device_degrade(1, 1.0, 2.0, 5.0),))))
    engine_exact = all(
        np.array_equal(np.asarray(getattr(rep_plain.transient, f)),
                       np.asarray(getattr(rep_noop.transient, f)))
        for f in ("q1", "q2", "w1", "w2", "rho1", "response", "stable")
    )
    return {
        "n_windows": n,
        "degrade_window": w0,
        "stationary_w1_s": round(float(np.asarray(ref.w1)[-1]), 6),
        "fluid_tail_w1_s": round(float(deg.w1[-1]), 6),
        "stationary_rel_err": float(rel_err),
        "healthy_windows_bit_exact": bool(healthy_exact),
        "engine_noop_degrade_bit_exact": bool(engine_exact),
        "ok": bool(rel_err < 1e-6 and healthy_exact and engine_exact),
    }


def bench_retry_storm() -> dict:
    """Aggressive retries pin the queue above capacity (metastable); the
    same retry budget with capped backoff drains."""
    p12 = np.full_like(STORM_LAM, STORM_P12)

    def solve(retry):
        return fluid_two_tier(STORM_LAM, p12, MU1, MU2, dt=1.0,
                              retry=retry)

    agg = solve(AGGRESSIVE)
    gen = solve(GENTLE)
    none = solve(None)
    agg_onset = int(agg.metastable_onset())
    gen_onset = int(gen.metastable_onset())
    tol = 1e-9
    ordered = bool(np.all(agg.q1 >= gen.q1 - tol)
                   and np.all(gen.q1 >= none.q1 - tol))
    ok = (agg_onset >= 0 and gen_onset == -1
          and float(gen.q1[-1]) < 1.0 and ordered)
    return {
        "burst_lam": float(STORM_LAM.max()),
        "post_burst_lam": float(STORM_LAM[-1]),
        "mu1": MU1,
        "aggressive_metastable_onset": agg_onset,
        "gentle_metastable_onset": gen_onset,
        "final_backlog": {
            "aggressive": round(float(agg.q1[-1]), 3),
            "gentle": round(float(gen.q1[-1]), 3),
            "no_retries": round(float(none.q1[-1]), 3),
        },
        "final_retry_rate_aggressive": round(float(agg.retry_rate[-1]), 3),
        "backlog_curves_ordered": ordered,
        "ok": bool(ok),
    }


def bench_compile_gate(smoke: bool) -> dict:
    """Fault grids are data operands: outage start times x retry policies
    share one compiled megabatch engine."""
    base = (BASE.replace(traffic=dataclasses.replace(
        BASE.traffic, n_requests=600)) if smoke else BASE)
    faults_axis = [None]
    starts = (2.0, 4.0) if smoke else (1.0, 2.0, 3.0, 4.0)
    for t0 in starts:
        faults_axis.append(FaultSpec(events=(shard_down(1, t0, t0 + 2.0),)))
    for to in (0.1, 0.2):
        faults_axis.append(FaultSpec(
            events=(device_degrade(1, 0.5, 1.0, 3.0),),
            retry=RetryPolicy(timeout=to, max_retries=3)))
    reset_engine_compile_count()
    t0s = time.perf_counter()
    res = sweep(base, {"faults": faults_axis})
    wall = time.perf_counter() - t0s
    compiles = engine_compile_count()
    # Retry sweeps ride a single cached counter run (schedule-free points
    # share one cache signature); shard_down points re-run the remap only.
    sigs = {s.cache_signature() for s in
            (base.replace(faults=f) for f in faults_axis)}
    return {
        "n_points": len(res.points),
        "n_unique_cache_signatures": len(sigs),
        "wall_s": round(wall, 3),
        "compiles": compiles,
        "compile_limit": COMPILE_LIMIT,
        "ok": bool(compiles <= COMPILE_LIMIT),
    }


def bench_determinism() -> dict:
    """Same seed + same fault schedule => byte-identical report JSON."""
    fs = FaultSpec(events=(shard_down(1, 2.0, 5.0),),
                   retry=RetryPolicy(timeout=0.2, max_retries=2))
    spec = BASE.replace(faults=fs)
    a = json.dumps(simulate(spec).to_dict(), sort_keys=True)
    b = json.dumps(simulate(spec).to_dict(), sort_keys=True)
    return {
        "json_bytes": len(a),
        "byte_identical": bool(a == b),
        "ok": bool(a == b),
    }


def main() -> None:
    smoke = "--smoke" in sys.argv
    artifact = {
        "mode": "smoke" if smoke else "full",
        "degraded_accuracy": bench_degraded_accuracy(),
        "retry_storm": bench_retry_storm(),
        "compile_gate": bench_compile_gate(smoke),
        "determinism": bench_determinism(),
    }
    with open(ARTIFACT, "w") as f:
        json.dump(artifact, f, indent=1)
        f.write("\n")

    da, rs, cg, dt = (artifact["degraded_accuracy"], artifact["retry_storm"],
                      artifact["compile_gate"], artifact["determinism"])
    print(f"degraded accuracy: fluid tail w1={da['fluid_tail_w1_s']:.6f}s "
          f"vs stationary {da['stationary_w1_s']:.6f}s "
          f"(rel err {da['stationary_rel_err']:.2e}), healthy windows "
          f"bit-exact={da['healthy_windows_bit_exact']} ok={da['ok']}")
    print(f"retry storm: aggressive metastable from window "
          f"{rs['aggressive_metastable_onset']}, gentle drains to "
          f"q1={rs['final_backlog']['gentle']} "
          f"(ordered={rs['backlog_curves_ordered']}) ok={rs['ok']}")
    print(f"compile gate: {cg['n_points']} fault points "
          f"({cg['n_unique_cache_signatures']} cache signatures) -> "
          f"{cg['compiles']} compiles (limit {COMPILE_LIMIT}) ok={cg['ok']}")
    print(f"determinism: {dt['json_bytes']} JSON bytes, "
          f"byte_identical={dt['byte_identical']} ok={dt['ok']}")
    print(f"artifact: {ARTIFACT}")
    failures = [k for k in ("degraded_accuracy", "retry_storm",
                            "compile_gate", "determinism")
                if not artifact[k]["ok"]]
    if failures:
        raise SystemExit(f"bench_faults gates failed: {failures}")


if __name__ == "__main__":
    main()
