"""Miss-rate-curve engine benchmark: exactness, compile count, speedup.

  PYTHONPATH=src python benchmarks/bench_mrc.py [--smoke]

Gates the one-pass MRC path (``repro.sim.mrc`` + ``sweep(mrc="auto")``)
and writes a ``BENCH_mrc.json`` artifact at the repo root:

- **exactness gate** — every :class:`~repro.sim.engine.Tier1Counters`
  field from :func:`~repro.sim.mrc.mrc_tier1_counters` is bit-identical
  to the sequential scan engine for LRU at **all** cache sizes of the
  curve grid (per-size verdicts land in the artifact).
- **compile gate** — the 64-size sweep routes through MRC with **zero**
  engine compiles and at most :data:`REUSE_COMPILE_LIMIT` distance-engine
  compiles.
- **speedup gate** — ≥ :data:`MIN_SPEEDUP`x points/sec on the 64-size
  grid versus the scan engine (timed on a stratified subset of sizes and
  scaled — the engine pays a fresh structural compile per size, which is
  exactly what the MRC path removes).

``--smoke`` shrinks the stream (gates unchanged) for CI.
"""
from __future__ import annotations

import json
import os
import sys
import time

# Match the sweep benches: shard sweep points across forced host devices
# (must precede jax import).
_FLAG = "--xla_force_host_platform_device_count"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    _n_dev = max(1, min(os.cpu_count() or 1, 8))
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + f" {_FLAG}={_n_dev}"
    ).strip()

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

import jax  # noqa: E402

from repro.kernels.reuse_distance import (  # noqa: E402
    reset_reuse_compile_count,
    reuse_compile_count,
)
from repro.sim import (  # noqa: E402
    RateSpec,
    SimSpec,
    mrc_tier1_counters,
    sweep,
)
from repro.sim.engine import tier1_counters  # noqa: E402
from repro.sim.spec import StoreConfig, TrafficSpec  # noqa: E402
from repro.sim.sweep import (  # noqa: E402
    engine_compile_count,
    reset_engine_compile_count,
)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ARTIFACT = os.path.join(ROOT, "BENCH_mrc.json")
N_SIZES = 64                  # the capacity-planning curve grid
REUSE_COMPILE_LIMIT = 2       # distance-engine compiles for the whole curve
MIN_SPEEDUP = 10.0            # points/sec vs the per-size scan engine
ENGINE_SUBSET = 4             # sizes the scan engine is actually timed on


def _base(smoke: bool) -> SimSpec:
    return SimSpec(
        traffic=TrafficSpec(kind="irm",
                            n_requests=600 if smoke else 4000,
                            n_pages=128 if smoke else 512,
                            write_fraction=0.25, seed=17),
        store=StoreConfig(n_lines=8, policy="lru"),
        n_shards=4,
        lam=100.0,
        rates=RateSpec(source="paper"),
    )


def _size_grid(n_pages: int) -> list[int]:
    """Exactly N_SIZES distinct cache sizes from 1 to 2x the page count
    (log-spaced head + linear tail fill)."""
    hi = 2 * n_pages
    sizes = np.unique(np.round(np.geomspace(1, hi, N_SIZES)).astype(int))
    extra = np.setdiff1d(np.arange(1, hi + 1), sizes)
    sizes = np.concatenate([sizes, extra[: N_SIZES - sizes.size]])
    return sorted(int(s) for s in sizes)


def bench_exactness(spec: SimSpec, sizes: list[int]) -> dict:
    """All Tier1Counters fields bit-equal to the scan engine at every
    size of the curve grid."""
    t0 = time.perf_counter()
    mrc = mrc_tier1_counters(spec, sizes)
    mrc_wall = time.perf_counter() - t0

    per_size = {}
    t0 = time.perf_counter()
    for C in sizes:
        ref = tier1_counters(spec.replace(**{"store.n_lines": C}))
        got = mrc[C]
        bad = [f for f in ref._fields
               if not np.array_equal(np.asarray(getattr(got, f)),
                                     np.asarray(getattr(ref, f)))]
        per_size[str(C)] = {"exact": not bad,
                            **({"mismatched_fields": bad} if bad else {})}
    engine_wall = time.perf_counter() - t0
    n_exact = sum(v["exact"] for v in per_size.values())
    return {
        "n_sizes": len(sizes),
        "n_exact": n_exact,
        "mrc_wall_s": round(mrc_wall, 3),
        "engine_wall_s": round(engine_wall, 3),
        "per_size": per_size,
        "ok": n_exact == len(sizes),
    }


def bench_curve_sweep(spec: SimSpec, sizes: list[int]) -> dict:
    """The 64-size capacity-planning sweep: zero engine compiles, bounded
    distance-engine compiles, and >= MIN_SPEEDUP x points/sec over the
    scan engine (timed on a stratified size subset and scaled)."""
    axes = {"store.n_lines": sizes}
    reset_engine_compile_count()
    reset_reuse_compile_count()
    t0 = time.perf_counter()
    res = sweep(spec, axes)                       # mrc="auto"
    wall_mrc = time.perf_counter() - t0
    engine_compiles = engine_compile_count()
    reuse_compiles = reuse_compile_count()
    pps_mrc = len(res.points) / wall_mrc

    subset = sizes[:: max(1, len(sizes) // ENGINE_SUBSET)][:ENGINE_SUBSET]
    t0 = time.perf_counter()
    ref = sweep(spec, {"store.n_lines": subset}, mrc="off")
    wall_eng = time.perf_counter() - t0
    pps_eng = len(ref.points) / wall_eng

    # Cross-check the subset's reports against the MRC-served curve.
    by_size = {pt["store.n_lines"]: rep
               for pt, rep in zip(res.points, res.reports)}
    mismatches = sum(
        1 for pt, rrep in zip(ref.points, ref.reports)
        if (by_size[pt["store.n_lines"]].misses != rrep.misses
            or by_size[pt["store.n_lines"]].tier2_writes != rrep.tier2_writes)
    )
    speedup = pps_mrc / pps_eng
    return {
        "n_points": len(res.points),
        "wall_s": round(wall_mrc, 3),
        "points_per_sec": round(pps_mrc, 3),
        "engine_compiles": engine_compiles,
        "reuse_compiles": reuse_compiles,
        "reuse_compile_limit": REUSE_COMPILE_LIMIT,
        "engine_subset_sizes": subset,
        "engine_wall_s": round(wall_eng, 3),
        "engine_points_per_sec": round(pps_eng, 3),
        "subset_report_mismatches": mismatches,
        "speedup": round(speedup, 2),
        "min_speedup": MIN_SPEEDUP,
        "ok": (engine_compiles == 0
               and reuse_compiles <= REUSE_COMPILE_LIMIT
               and mismatches == 0
               and speedup >= MIN_SPEEDUP),
    }


def main() -> None:
    smoke = "--smoke" in sys.argv
    spec = _base(smoke)
    sizes = _size_grid(spec.traffic.n_pages)
    assert len(sizes) == N_SIZES
    artifact = {
        "mode": "smoke" if smoke else "full",
        "devices": jax.local_device_count(),
        "n_requests": spec.traffic.n_requests,
        "exactness": bench_exactness(spec, sizes),
        "curve_sweep": bench_curve_sweep(spec, sizes),
    }
    with open(ARTIFACT, "w") as f:
        json.dump(artifact, f, indent=1)
        f.write("\n")

    ex, cs = artifact["exactness"], artifact["curve_sweep"]
    print(f"devices: {artifact['devices']}")
    print(f"exactness: {ex['n_exact']}/{ex['n_sizes']} sizes bit-exact "
          f"(mrc {ex['mrc_wall_s']}s vs engine {ex['engine_wall_s']}s) "
          f"ok={ex['ok']}")
    print(f"curve sweep: {cs['n_points']} sizes in {cs['wall_s']}s "
          f"({cs['points_per_sec']} pts/s, {cs['engine_compiles']} engine / "
          f"{cs['reuse_compiles']} distance compiles) vs engine "
          f"{cs['engine_points_per_sec']} pts/s -> speedup {cs['speedup']}x "
          f"ok={cs['ok']}")
    print(f"artifact: {ARTIFACT}")
    failures = [k for k in ("exactness", "curve_sweep")
                if not artifact[k]["ok"]]
    if failures:
        raise SystemExit(f"bench_mrc gates failed: {failures}")


if __name__ == "__main__":
    main()
