"""Time-resolved pipeline benchmark: vectorized queuing speedup, windowed
bit-exactness, compile behavior and transient warm-up convergence.

  PYTHONPATH=src python benchmarks/bench_telemetry.py [--smoke]

Measures the ISSUE-4 refactor (windowed engine telemetry + numpy-vectorized
queuing + piecewise-stationary transient solves, see ``repro.sim`` and
``repro.core.queuing``) and writes a ``BENCH_telemetry.json`` artifact at
the repo root.

Gates:

- **queuing speedup** — the vectorized queuing layer solves a
  288-point x 8-shard grid ≥ :data:`MIN_SPEEDUP`x faster than a faithful
  reimplementation of the pre-refactor scalar-float + per-shard Python
  loop (both paths also cross-checked numerically).
- **windowed bit-exactness** — windowed counters sum exactly to the
  whole-stream counters and the §V worked example still yields
  λ_eff = 86.6 *exactly* through the ``n_windows`` path.
- **compile gate** — a traced-knob grid at ``n_windows`` > 1 compiles the
  megabatch engine at most :data:`COMPILE_LIMIT` times (the window axis
  rides the existing batch; window ids are data, not structure).
- **warm-up convergence** — a cold-cache transient's tail window converges
  to the steady-state report (relative gap < :data:`TAIL_TOL`).

``--smoke`` shrinks the engine-heavy stages for CI; every gate still runs.
"""
from __future__ import annotations

import json
import math
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core.queuing import (  # noqa: E402
    TwoTierModel,
    expected_response,
    residence_times,
)
from repro.core.traffic import TrafficSpec  # noqa: E402
from repro.sim import (  # noqa: E402
    RateSpec,
    SimSpec,
    report_from_counters,
    simulate,
    sweep,
    tier1_counters,
)
from repro.sim.sweep import (  # noqa: E402
    engine_compile_count,
    reset_engine_compile_count,
)
from repro.storage.tiered_store import StoreConfig  # noqa: E402

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ARTIFACT = os.path.join(ROOT, "BENCH_telemetry.json")
PUBLISHED_LAM_EFF = 86.6  # §V worked example
N_POINTS = 288            # queuing grid: points axis
N_SHARDS = 8              # queuing grid: shard axis
MIN_SPEEDUP = 5.0         # vectorized vs scalar-loop queuing layer
COMPILE_LIMIT = 2         # traced-knob grid at n_windows > 1
TAIL_TOL = 0.25           # tail-window vs steady-state relative gap

WORKED = SimSpec(
    traffic=TrafficSpec(kind="irm", n_requests=1500, n_pages=512,
                        write_fraction=0.3, seed=7),
    store=StoreConfig(n_lines=64, policy="ws"),
    n_shards=4,
    lam=100.0,
    k_servers=1,
    rates=RateSpec(source="paper"),
    p12_override=0.2,
)


# ---------------------------------------------------------------------------
# Pre-refactor reference: scalar float math + per-shard Python loops
# (faithful reimplementation of the old core.queuing / engine loop).
# ---------------------------------------------------------------------------


def _legacy_mm1(lam, mu):
    if lam <= 0.0:
        return 0.0, 0.0, True
    rho = lam / mu
    if rho >= 1.0:
        return rho, math.inf, False
    lq = rho * rho / (1.0 - rho)
    return rho, lq / lam, True


def _legacy_mmk(lam, mu, k):
    if lam <= 0.0:
        return 0.0, 0.0, True
    a = lam / mu
    rho = a / k
    if rho >= 1.0:
        return rho, math.inf, False
    s = sum(a**i / math.factorial(i) for i in range(k))
    s += a**k / (math.factorial(k) * (1.0 - a / k))
    p0 = 1.0 / s
    lq = p0 * a ** (k + 1) / (math.factorial(k - 1) * (k - a) ** 2)
    return rho, lq / lam, True


def _legacy_solve(lam, mu1, mu2, p12, k):
    """One scalar two-tier solve (paper flow), the old per-shard body:
    returns (rho1, rho2, w1, w2, response, equilibrium)."""
    lam_eff = (1.0 - p12) * lam + p12 * mu2
    rho1, wq1, s1 = _legacy_mmk(lam_eff, mu1, k)
    rho2, wq2, s2 = _legacy_mm1(p12 * lam, mu2)
    eq = s1 and s2
    w1 = wq1 + 1.0 / mu1 if eq else math.inf
    w2 = wq2 + 1.0 / mu2 if eq else math.inf
    resp = w1 + (p12 * w2 if p12 > 0.0 else 0.0)
    return rho1 * k, rho2, w1, w2, resp, eq


def _queuing_grid(rng):
    """A [points, shards] operating grid spanning stable and saturated
    regimes with per-shard device heterogeneity."""
    lam = rng.uniform(5.0, 250.0, size=(N_POINTS, 1))
    lam = np.broadcast_to(lam, (N_POINTS, N_SHARDS)).copy()
    mu1 = rng.uniform(400.0, 4000.0, size=(1, N_SHARDS))
    mu1 = np.broadcast_to(mu1, (N_POINTS, N_SHARDS)).copy()
    mu2 = rng.uniform(20.0, 60.0, size=(1, N_SHARDS))
    mu2 = np.broadcast_to(mu2, (N_POINTS, N_SHARDS)).copy()
    p12 = rng.uniform(0.0, 0.6, size=(N_POINTS, N_SHARDS))
    p12[rng.random((N_POINTS, N_SHARDS)) < 0.05] = 0.0
    return lam, mu1, mu2, p12


def bench_queuing_speedup() -> dict:
    """Vectorized queuing layer vs the scalar per-shard loop on a
    288-point x 8-shard sweep's worth of queue solves."""
    rng = np.random.default_rng(0)
    lam, mu1, mu2, p12 = _queuing_grid(rng)
    k = 1

    def vectorized():
        rep = TwoTierModel(lam=lam, mu1=mu1, mu2=mu2, p12=p12, k=k,
                           flow="paper").analyze()
        eq = np.asarray(rep.equilibrium, bool)
        w1, w2 = residence_times(rep.q1.wq, rep.q2.wq, mu1, mu2, eq)
        resp = expected_response(w1, w2, p12)
        return (np.asarray(rep.q1.rho) * k, np.asarray(rep.q2.rho),
                w1, w2, resp, eq)

    def scalar_loop():
        out = np.empty((N_POINTS, N_SHARDS, 6))
        for i in range(N_POINTS):
            for s in range(N_SHARDS):
                out[i, s] = _legacy_solve(
                    lam[i, s], mu1[i, s], mu2[i, s], p12[i, s], k)
        return out

    # Cross-check before timing: both paths agree everywhere.
    vec = vectorized()
    ref = scalar_loop()
    mismatches = 0
    for j, field in enumerate(("rho1", "rho2", "w1", "w2", "resp", "eq")):
        if not np.allclose(np.asarray(vec[j], float), ref[..., j],
                           rtol=1e-10, atol=0.0, equal_nan=True):
            mismatches += 1

    def best_of(fn, n=5):
        times = []
        for _ in range(n):
            t0 = time.perf_counter()
            fn()
            times.append(time.perf_counter() - t0)
        return min(times)

    t_vec = best_of(vectorized)
    t_ref = best_of(scalar_loop)
    speedup = t_ref / t_vec
    return {
        "n_points": N_POINTS,
        "n_shards": N_SHARDS,
        "scalar_loop_s": round(t_ref, 6),
        "vectorized_s": round(t_vec, 6),
        "speedup": round(speedup, 2),
        "min_speedup": MIN_SPEEDUP,
        "mismatched_fields": mismatches,
        "ok": mismatches == 0 and speedup >= MIN_SPEEDUP,
    }


def bench_windowed_exactness(smoke: bool) -> dict:
    """Windowed counters reconcile exactly and the §V worked example is
    unchanged (λ_eff = 86.6 exactly) through the n_windows path."""
    spec = WORKED if not smoke else WORKED.replace(
        **{"traffic.n_requests": 600})
    base = simulate(spec)
    windowed = simulate(spec.replace(n_windows=8))
    win = windowed.windows
    sums_exact = all(
        int(np.asarray(getattr(win, name)).sum()) == getattr(windowed, name)
        for name in ("requests", "hits", "misses", "prefetch_hits",
                     "tier2_reads", "tier2_writes", "evictions")
    )
    totals_exact = (
        base.hits == windowed.hits
        and base.misses == windowed.misses
        and base.tier2_reads == windowed.tier2_reads
        and base.tier2_writes == windowed.tier2_writes
    )
    lam_eff_exact = (windowed.lam_eff == base.lam_eff
                     and abs(windowed.lam_eff - PUBLISHED_LAM_EFF) < 1e-9)
    return {
        "n_windows": 8,
        "lam_eff": windowed.lam_eff,
        "lam_eff_published": PUBLISHED_LAM_EFF,
        "window_sums_exact": sums_exact,
        "totals_bit_exact_vs_unwindowed": totals_exact,
        "lam_eff_exact": lam_eff_exact,
        "ok": sums_exact and totals_exact and lam_eff_exact,
    }


def bench_compile_gate(smoke: bool) -> dict:
    """Traced-knob grid at n_windows=8: the window axis must not add
    engine compiles (gate ≤ COMPILE_LIMIT)."""
    base = SimSpec(
        traffic=TrafficSpec(kind="irm", n_requests=400 if smoke else 1200,
                            n_pages=256, write_fraction=0.2, seed=3),
        store=StoreConfig(n_lines=64, policy="ws"),
        n_shards=4,
        lam=50.0,
        rates=RateSpec(source="paper"),
        n_windows=8,
    )
    axes = {
        "store.policy": ["lru", "ws"] if smoke else ["lru", "lfu", "ws",
                                                     "random"],
        "store.alpha": [0.3, 0.7],
        "store.beta": [0.5, 0.9],
    }
    reset_engine_compile_count()
    t0 = time.perf_counter()
    res = sweep(base, axes)
    wall = time.perf_counter() - t0
    compiles = engine_compile_count()
    return {
        "n_points": len(res.points),
        "n_windows": 8,
        "wall_s": round(wall, 3),
        "compiles": compiles,
        "compile_limit": COMPILE_LIMIT,
        "ok": compiles <= COMPILE_LIMIT,
    }


def bench_warmup_curve(smoke: bool) -> dict:
    """Cold-cache warm-up: the transient tail window converges to the
    steady-state report of the *settled* regime (the equilibrium solve at
    the tail-half mean miss fraction — the §V analysis is the t→∞ limit of
    the windowed solve; the whole-stream report stays contaminated by the
    warm-up windows it averages over, reported here for contrast)."""
    spec = SimSpec(
        traffic=TrafficSpec(kind="markov", n_requests=1500 if smoke else 4000,
                            n_pages=256, n_hot_states=24, seed=5),
        store=StoreConfig(n_lines=64, policy="lru"),
        n_shards=2,
        lam=40.0,
        rates=RateSpec(source="paper"),
        mapping="block_cyclic",
        n_windows=8,
    )
    ctr = tier1_counters(spec)
    rep = report_from_counters(spec, ctr)
    p12_w = np.asarray(rep.transient.p12)
    resp_w = np.asarray(rep.transient.response)
    half = rep.n_windows // 2
    tail_p12 = float(p12_w[half:].mean())
    steady_tail = report_from_counters(
        spec.replace(p12_override=tail_p12), ctr)
    tail_gap = (abs(resp_w[-1] - steady_tail.response_s)
                / steady_tail.response_s)
    whole_gap = abs(resp_w[-1] - rep.response_s) / rep.response_s
    return {
        "n_windows": rep.n_windows,
        "p12_per_window": [round(float(v), 4) for v in p12_w],
        "response_ms_per_window": [round(float(v) * 1e3, 4)
                                   for v in resp_w],
        "steady_state_response_ms": round(rep.response_s * 1e3, 4),
        "steady_tail_response_ms": round(steady_tail.response_s * 1e3, 4),
        "cold_start_visible": bool(p12_w[0] > p12_w[-1]),
        "tail_rel_gap": round(float(tail_gap), 4),
        "whole_stream_rel_gap": round(float(whole_gap), 4),
        "tail_tol": TAIL_TOL,
        "saturation_onset": rep.saturation_onset,
        "ok": bool(p12_w[0] > p12_w[-1] and tail_gap < TAIL_TOL
                   and rep.saturation_onset is None),
    }


def main() -> None:
    smoke = "--smoke" in sys.argv
    artifact = {
        "mode": "smoke" if smoke else "full",
        "queuing_speedup": bench_queuing_speedup(),
        "windowed_exactness": bench_windowed_exactness(smoke),
        "compile_gate": bench_compile_gate(smoke),
        "warmup_curve": bench_warmup_curve(smoke),
    }
    with open(ARTIFACT, "w") as f:
        json.dump(artifact, f, indent=1)
        f.write("\n")

    qs, we, cg, wc = (artifact["queuing_speedup"],
                      artifact["windowed_exactness"],
                      artifact["compile_gate"], artifact["warmup_curve"])
    print(f"queuing speedup: {qs['n_points']}x{qs['n_shards']} grid, "
          f"vectorized {qs['vectorized_s']*1e3:.2f}ms vs scalar loop "
          f"{qs['scalar_loop_s']*1e3:.2f}ms -> {qs['speedup']}x "
          f"(min {MIN_SPEEDUP}x) ok={qs['ok']}")
    print(f"windowed exactness: lam_eff={we['lam_eff']:.1f} "
          f"sums_exact={we['window_sums_exact']} "
          f"bit_exact={we['totals_bit_exact_vs_unwindowed']} ok={we['ok']}")
    print(f"compile gate: {cg['n_points']} windowed traced-knob points -> "
          f"{cg['compiles']} compiles (limit {COMPILE_LIMIT}) ok={cg['ok']}")
    print(f"warm-up curve: p12 {wc['p12_per_window'][0]:.3f} -> "
          f"{wc['p12_per_window'][-1]:.3f}, tail gap "
          f"{wc['tail_rel_gap']:.3f} (tol {TAIL_TOL}) ok={wc['ok']}")
    print(f"artifact: {ARTIFACT}")
    failures = [k for k in ("queuing_speedup", "windowed_exactness",
                            "compile_gate", "warmup_curve")
                if not artifact[k]["ok"]]
    if failures:
        raise SystemExit(f"bench_telemetry gates failed: {failures}")


if __name__ == "__main__":
    main()
