"""Chunked streaming replay benchmark: the four PR gates, in one artifact.

  PYTHONPATH=src python benchmarks/bench_stream.py [--smoke]

Produces repo-root ``BENCH_stream.json`` with:

- ``bit_exact``: streamed counters and full ``SimReport`` vs the one-shot
  engine — request-index windows, wall-clock windows with a fault
  schedule straddling chunk boundaries, and a ``tenant_mix`` workload
  whose per-tenant series must reconcile with the pooled windows.
- ``compile_count``: a fresh >= 32-chunk replay of a >= 1M-request stream
  (smoke: scaled down) must compile the chunk engine at most twice (the
  primary and fallback length buckets).
- ``memory``: peak live device bytes sampled across replays of two
  streams 8x apart in length must stay flat (the whole point of
  streaming: footprint is O(chunk), not O(trace)).
- ``throughput``: the optimized replay (balanced-load bucket sizing +
  donated buffers + async dispatch) vs a naive chunked baseline that
  pads every shard to the worst case (the whole chunk on one shard), runs
  without donation and synchronizes + round-trips the carry to host after
  every chunk. Gate: >= 2x requests/second.

``--smoke`` shrinks every stream so the whole file runs in CI seconds;
gates keep their structure (the compile-count and flatness assertions are
scale-free).
"""
from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

import jax  # noqa: E402

from repro.core.traffic import (  # noqa: E402
    TenantSpec,
    TrafficSpec,
    tenant_mix,
)
from repro.sim import SimSpec, simulate_stream, stream_tier1_counters  # noqa: E402
from repro.sim.engine import report_from_counters, tier1_counters  # noqa: E402
from repro.sim.spec import FaultSpec, StoreConfig, shard_down  # noqa: E402
from repro.sim.stream import _chunk_caps  # noqa: E402
from repro.storage.tiered_store import (  # noqa: E402
    init_stream_carry,
    partition_streams,
    reset_stream_compile_count,
    stream_chunk_engine,
    stream_compile_count,
    stream_window_ids,
)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ARTIFACT = os.path.join(ROOT, "BENCH_stream.json")

COMPILE_LIMIT = 2       # chunk-engine compiles per replay (two buckets)
MEM_FLAT_RATIO = 1.25   # peak(8x stream) / peak(1x stream) must stay under
MIN_SPEEDUP = 2.0       # optimized vs naive chunked replay
LEN_RATIO = 8           # memory gate: long stream / short stream


def _irm(n_requests: int, *, n_pages: int = 4096, seed: int = 7,
         rate: float = 0.0) -> TrafficSpec:
    return TrafficSpec(kind="irm", n_requests=n_requests, n_pages=n_pages,
                       zipf_s=1.1, write_fraction=0.3, seed=seed, rate=rate)


def _live_device_bytes() -> int:
    return int(sum(a.nbytes for a in jax.live_arrays()))


def _ctr_equal(a, b) -> list:
    """Field names on which two Tier1Counters disagree."""
    bad = []
    for f in a._fields:
        if not np.array_equal(np.asarray(getattr(a, f)),
                              np.asarray(getattr(b, f))):
            bad.append(f)
    return bad


def bench_bit_exact(smoke: bool) -> dict:
    n = 20_000 if smoke else 200_000
    chunk = 1_999 if smoke else 19_993  # prime: boundaries straddle windows
    cases = {}

    # Request-index windows.
    spec = SimSpec(traffic=_irm(n), store=StoreConfig(n_lines=256,
                                                      policy="ws"),
                   n_shards=4, n_windows=12)
    ref = tier1_counters(spec)
    ctr, _, ck = stream_tier1_counters(spec, chunk=chunk)
    rep_eq = (report_from_counters(spec, ref).to_dict()
              == simulate_stream(spec, chunk=chunk).to_dict())
    cases["indexed"] = {"counter_mismatches": _ctr_equal(ref, ctr),
                       "report_equal": rep_eq, "chunks": -(-n // chunk)}

    # Wall-clock windows + a fault schedule: failover reroutes and the
    # cold-refill correction must survive chunk boundaries.
    spec_f = SimSpec(
        traffic=_irm(n // 4, rate=float(n // 4) / 60.0, seed=9),
        store=StoreConfig(n_lines=128), n_shards=4, window_dt=2.0,
        faults=FaultSpec(events=(shard_down(1, 10.0, 25.0),)),
    )
    ref_f = tier1_counters(spec_f)
    ctr_f, _, _ = stream_tier1_counters(spec_f, chunk=chunk // 4 + 1)
    rep_f_eq = (report_from_counters(spec_f, ref_f).to_dict()
                == simulate_stream(spec_f, chunk=chunk // 4 + 1).to_dict())
    cases["faulted"] = {"counter_mismatches": _ctr_equal(ref_f, ctr_f),
                        "report_equal": rep_f_eq}

    # Tenant mix: streamed counters equal the one-shot merge, per-tenant
    # series reconcile with the pooled windows.
    mix = tenant_mix(
        TenantSpec(name="oltp", rate=600.0, n_pages=1024, zipf_s=1.3,
                   write_fraction=0.4),
        TenantSpec(name="analytics", rate=200.0, n_pages=4096, zipf_s=0.9),
        n_requests=n // 4, seed=3)
    spec_t = SimSpec(traffic=mix, store=StoreConfig(n_lines=256,
                                                    policy="ws"),
                     n_shards=4, window_dt=1.0)
    ref_t = tier1_counters(spec_t)
    ctr_t, tc, _ = stream_tier1_counters(spec_t, chunk=chunk // 3 + 1)
    recon = bool(
        np.array_equal(tc.win_requests.sum(axis=0),
                       np.asarray(ctr_t.win_requests).sum(axis=0))
        and np.array_equal(tc.win_misses.sum(axis=0),
                           np.asarray(ctr_t.win_misses).sum(axis=0))
        and int(tc.win_requests.sum()) == mix.n_requests)
    cases["tenant_mix"] = {"counter_mismatches": _ctr_equal(ref_t, ctr_t),
                           "attribution_reconciles": recon,
                           "tenants": list(tc.names)}

    ok = all(
        not c["counter_mismatches"] and c.get("report_equal", True)
        and c.get("attribution_reconciles", True)
        for c in cases.values())
    return {**cases, "ok": bool(ok)}


def bench_compile_count(smoke: bool) -> dict:
    n = 65_536 if smoke else 1_048_576
    chunk = 2_048 if smoke else 32_768          # 32 chunks either way
    # A store shape no other section uses, so the jit cache starts cold
    # and the counter measures this replay's compiles alone.
    spec = SimSpec(traffic=_irm(n, n_pages=8192, seed=13),
                   store=StoreConfig(n_lines=192), n_shards=4, n_windows=8)
    reset_stream_compile_count()
    t0 = time.perf_counter()
    ctr, _, ck = stream_tier1_counters(spec, chunk=chunk)
    wall = time.perf_counter() - t0
    compiles = stream_compile_count()
    return {
        "n_requests": n,
        "chunks": n // chunk,
        "compiles": compiles,
        "wall_s": round(wall, 3),
        "requests_per_sec": round(n / wall),
        "ok": bool(compiles <= COMPILE_LIMIT and ck.done
                   and int(np.asarray(ctr.requests).sum()) == n),
    }


def _replay_peak_bytes(cfg: StoreConfig, pages, writes, *, chunk: int,
                       n_shards: int) -> int:
    """Drive the chunk engine directly, sampling live device bytes after
    every (synchronized) chunk — the measured peak of a replay."""
    primary, fallback = _chunk_caps(chunk, n_shards)
    eng = stream_chunk_engine(cfg, n_windows=1)
    hyper = cfg.hyper()
    carry = init_stream_carry(cfg, n_shards, n_windows=1)
    n = pages.shape[0]
    zeros = np.zeros(chunk, np.int32)
    peak = 0
    for start in range(0, n, chunk):
        sl = slice(start, min(start + chunk, n))
        m = sl.stop - sl.start
        own = (pages[sl] % n_shards).astype(np.int32)  # round-robin owners
        cnt = np.bincount(own, minlength=n_shards)
        sh_p, sh_w, _, _, sh_win = partition_streams(
            pages[sl], writes[sl], n_shards=n_shards,
            n_pages=int(pages.max()) + 1,
            cap=primary if int(cnt.max()) <= primary else fallback,
            n_windows=1, window_ids=zeros[:m], owner=own)
        carry = eng(hyper, carry, *jax.device_put((sh_p, sh_w, sh_win)))
        jax.block_until_ready(carry)
        peak = max(peak, _live_device_bytes())
    return peak


def bench_memory(smoke: bool) -> dict:
    n_short = 16_384 if smoke else 131_072
    n_long = n_short * LEN_RATIO
    chunk = 2_048 if smoke else 16_384
    cfg = StoreConfig(n_lines=256)
    rng = np.random.default_rng(5)
    pages = rng.integers(0, 4096, size=n_long).astype(np.int32)
    writes = rng.random(n_long) < 0.3
    peak_short = _replay_peak_bytes(cfg, pages[:n_short], writes[:n_short],
                                    chunk=chunk, n_shards=4)
    peak_long = _replay_peak_bytes(cfg, pages, writes,
                                   chunk=chunk, n_shards=4)
    ratio = peak_long / max(peak_short, 1)
    return {
        "n_short": n_short,
        "n_long": n_long,
        "peak_bytes_short": peak_short,
        "peak_bytes_long": peak_long,
        "ratio": round(ratio, 4),
        "ok": bool(ratio <= MEM_FLAT_RATIO),
    }


def bench_throughput(smoke: bool) -> dict:
    n = 65_536 if smoke else 524_288
    chunk = 4_096 if smoke else 16_384
    # round_robin spreads the zipf head across shards, so chunks land in
    # the primary (balanced-load) bucket — chunk/4 per shard at S=8 —
    # while the naive baseline scans the full worst-case chunk per shard.
    spec = SimSpec(traffic=_irm(n, n_pages=8192, seed=21),
                   store=StoreConfig(n_lines=256), n_shards=8,
                   mapping="round_robin", n_windows=4)

    # Optimized streamed replay (warm the engine once, then time).
    stream_tier1_counters(spec, chunk=chunk, max_requests=chunk)
    t0 = time.perf_counter()
    ctr, _, _ = stream_tier1_counters(spec, chunk=chunk)
    t_stream = time.perf_counter() - t0

    # Naive chunked baseline: worst-case padding (every shard sized to the
    # whole chunk), no donation, a hard sync + carry round-trip per chunk.
    from repro.sim.engine import fault_owner, stream_for_spec
    pages, is_write, times, n_pages, n_windows, _ = stream_for_spec(spec)
    gwin = stream_window_ids(n, n_windows)
    owner = fault_owner(spec, pages, times, n_pages)
    cap = 1
    while cap < chunk:
        cap <<= 1
    eng = stream_chunk_engine(spec.store, n_windows=n_windows, donate=False)
    hyper = spec.store.hyper()
    # Warm the naive shape too: the gate measures steady-state throughput.
    carry = init_stream_carry(spec.store, spec.n_shards, n_windows=n_windows)
    sh = partition_streams(pages[:chunk], is_write[:chunk],
                           n_shards=spec.n_shards, n_pages=n_pages, cap=cap,
                           n_windows=n_windows, window_ids=gwin[:chunk],
                           owner=owner[:chunk])
    jax.block_until_ready(eng(hyper, carry, sh[0], sh[1], sh[4]))
    t0 = time.perf_counter()
    carry = init_stream_carry(spec.store, spec.n_shards, n_windows=n_windows)
    for start in range(0, n, chunk):
        sl = slice(start, min(start + chunk, n))
        sh_p, sh_w, _, _, sh_win = partition_streams(
            pages[sl], is_write[sl], n_shards=spec.n_shards,
            n_pages=n_pages, cap=cap, n_windows=n_windows,
            window_ids=gwin[sl], owner=owner[sl])
        carry = eng(hyper, carry, sh_p, sh_w, sh_win)
        jax.tree.map(np.asarray, carry)  # sync + host round-trip
    t_naive = time.perf_counter() - t0

    speedup = t_naive / t_stream
    return {
        "n_requests": n,
        "chunk": chunk,
        "stream_wall_s": round(t_stream, 3),
        "stream_requests_per_sec": round(n / t_stream),
        "naive_wall_s": round(t_naive, 3),
        "naive_requests_per_sec": round(n / t_naive),
        "speedup": round(speedup, 2),
        "ok": bool(speedup >= MIN_SPEEDUP
                   and int(np.asarray(ctr.requests).sum()) == n),
    }


def main() -> None:
    smoke = "--smoke" in sys.argv
    artifact = {
        "mode": "smoke" if smoke else "full",
        "devices": jax.local_device_count(),
        "bit_exact": bench_bit_exact(smoke),
        "compile_count": bench_compile_count(smoke),
        "memory": bench_memory(smoke),
        "throughput": bench_throughput(smoke),
    }
    with open(ARTIFACT, "w") as f:
        json.dump(artifact, f, indent=1)
        f.write("\n")

    be, cc = artifact["bit_exact"], artifact["compile_count"]
    mem, tp = artifact["memory"], artifact["throughput"]
    print(f"devices: {artifact['devices']}")
    print(f"bit-exact: indexed/faulted/tenant ok={be['ok']}")
    print(f"compile count: {cc['compiles']} compiles over {cc['chunks']} "
          f"chunks of {cc['n_requests']} requests "
          f"({cc['requests_per_sec']} req/s) ok={cc['ok']}")
    print(f"memory: peak {mem['peak_bytes_short']}B @ {mem['n_short']} vs "
          f"{mem['peak_bytes_long']}B @ {mem['n_long']} "
          f"(ratio {mem['ratio']}) ok={mem['ok']}")
    print(f"throughput: {tp['stream_requests_per_sec']} req/s streamed vs "
          f"{tp['naive_requests_per_sec']} req/s naive -> "
          f"{tp['speedup']}x ok={tp['ok']}")
    print(f"artifact: {ARTIFACT}")
    failures = [k for k in ("bit_exact", "compile_count", "memory",
                            "throughput") if not artifact[k]["ok"]]
    if failures:
        raise SystemExit(f"bench_stream gates failed: {failures}")


if __name__ == "__main__":
    main()
