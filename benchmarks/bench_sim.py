"""End-to-end simulator benchmark: §V worked example + a 3-axis sweep.

  PYTHONPATH=src python benchmarks/bench_sim.py

Produces repo-root ``BENCH_sim.json`` with:

- ``worked_example``: the full simulate() pipeline (traffic -> distributed
  tier 1 -> queuing) run with the §V constants and p12 = 0.2, for both flow
  conventions. Accuracy gate: λ_eff within 1% of the published 86.6.
- ``sweep``: a cache-size x shard-count x policy x traffic grid (the
  ROADMAP capacity-planning scenario), with per-point wall time for the
  batched vs. unbatched engine.
"""
from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.traffic import TrafficSpec  # noqa: E402
from repro.sim import RateSpec, SimSpec, simulate, sweep  # noqa: E402
from repro.storage.tiered_store import StoreConfig  # noqa: E402

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ARTIFACT = os.path.join(ROOT, "BENCH_sim.json")
PUBLISHED_LAM_EFF = 86.6  # §V worked example


def bench_worked_example() -> dict:
    spec = SimSpec(
        traffic=TrafficSpec(
            kind="irm", n_requests=4000, n_pages=1024,
            write_fraction=0.3, seed=7,
        ),
        store=StoreConfig(n_lines=128, policy="ws"),
        n_shards=4,
        lam=100.0,
        k_servers=1,
        rates=RateSpec(source="paper"),
        p12_override=0.2,
    )
    out = {}
    for flow in ("paper", "conserving"):
        t0 = time.perf_counter()
        rep = simulate(spec.replace(flow=flow))
        dt = time.perf_counter() - t0
        out[flow] = {
            "wall_s": round(dt, 3),
            "lam_eff": rep.lam_eff,
            "rho1": rep.rho1,
            "rho2": rep.rho2,
            "w1_s": rep.w1,
            "w2_s": rep.w2,
            "response_s": rep.response_s,
            "mu_system": rep.mu_system,
            "measured_miss_rate": rep.miss_rate,
            "t_total_s": rep.t_total_s,
        }
    err = abs(out["paper"]["lam_eff"] - PUBLISHED_LAM_EFF) / PUBLISHED_LAM_EFF
    out["lam_eff_published"] = PUBLISHED_LAM_EFF
    out["lam_eff_rel_err"] = err
    out["ok"] = err < 0.01
    return out


def bench_sweep() -> dict:
    base = SimSpec(
        traffic=TrafficSpec(
            kind="irm", n_requests=3000, n_pages=1024,
            write_fraction=0.2, seed=3,
        ),
        store=StoreConfig(n_lines=64, policy="ws"),
        n_shards=4,
        lam=50.0,
        rates=RateSpec(source="paper"),
    )
    axes = {
        "store.n_lines": [16, 64, 256],
        "n_shards": [2, 4],
        "store.policy": ["lru", "lfu", "ws"],
        "traffic.kind": ["irm", "poisson"],
    }
    t0 = time.perf_counter()
    res = sweep(base, axes, batch=True)
    t_batched = time.perf_counter() - t0
    t0 = time.perf_counter()
    sweep(base, axes, batch=False)
    t_unbatched = time.perf_counter() - t0

    best = min(res.rows(), key=lambda r: r["miss_rate"])
    return {
        "axes": {k: list(v) for k, v in axes.items()},
        "n_points": len(res.points),
        "wall_s_batched": round(t_batched, 3),
        "wall_s_unbatched": round(t_unbatched, 3),
        "best_point": {
            k: best[k]
            for k in (*map(str, axes), "miss_rate", "response_s", "lam_eff")
        },
        "points": res.rows(),
    }


def main() -> None:
    artifact = {
        "worked_example": bench_worked_example(),
        "sweep": bench_sweep(),
    }
    with open(ARTIFACT, "w") as f:
        json.dump(artifact, f, indent=1)
    we = artifact["worked_example"]
    sw = artifact["sweep"]
    print(f"worked_example: lam_eff={we['paper']['lam_eff']:.1f} "
          f"(published {PUBLISHED_LAM_EFF}, rel_err={we['lam_eff_rel_err']:.2e}) "
          f"ok={we['ok']}")
    print(f"sweep: {sw['n_points']} points over {len(sw['axes'])} axes, "
          f"batched={sw['wall_s_batched']}s unbatched={sw['wall_s_unbatched']}s")
    print(f"best point: {sw['best_point']}")
    print(f"artifact: {ARTIFACT}")
    if not we["ok"]:
        raise SystemExit("worked example outside 1% of published lam_eff")


if __name__ == "__main__":
    main()
