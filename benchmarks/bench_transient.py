"""Wall-clock transient benchmark: fluid-vs-piecewise burst accuracy,
timestamped-sweep compile behavior and vectorized fluid-solve throughput.

  PYTHONPATH=src python benchmarks/bench_transient.py [--smoke]

Measures the ISSUE-5 refactor (timestamped arrivals + fluid transient
queues, see ``repro.core.traffic``, ``repro.core.queuing`` and
``repro.sim``) and writes a ``BENCH_transient.json`` artifact at the repo
root.

Gates:

- **burst accuracy** — on a step-burst scenario the coarse-window *fluid*
  solve tracks a fine-grained oracle (the same fluid ODE at 16x time
  resolution, averaged back onto the coarse grid) at least
  :data:`ACCURACY_MARGIN` x closer than the window-independent piecewise
  solve, whose drain windows snap back instantly.
- **compile gate** — a traced-knob sweep grid with timestamps threaded
  through the megabatch engine compiles at most :data:`COMPILE_LIMIT`
  times (timestamps and window durations are data, not structure).
- **worked example** — the §V worked example still yields λ_eff = 86.6
  *exactly* through the timestamped wall-clock path.
- **fluid throughput** — the vectorized fluid solver beats a faithful
  per-series Python-loop reference by ≥ :data:`MIN_SPEEDUP` x on a
  [points x shards x windows] grid (cross-checked numerically first).

``--smoke`` shrinks the engine-heavy stages for CI; every gate still runs.
"""
from __future__ import annotations

import json
import math
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core.queuing import (  # noqa: E402
    fluid_two_tier,
    transient_two_tier,
)
from repro.core.traffic import TrafficSpec  # noqa: E402
from repro.sim import RateSpec, SimSpec, simulate, sweep  # noqa: E402
from repro.sim.sweep import (  # noqa: E402
    engine_compile_count,
    reset_engine_compile_count,
)
from repro.storage.tiered_store import StoreConfig  # noqa: E402

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ARTIFACT = os.path.join(ROOT, "BENCH_transient.json")
PUBLISHED_LAM_EFF = 86.6   # §V worked example
ACCURACY_MARGIN = 2.0      # fluid must be >= 2x closer to the oracle
COMPILE_LIMIT = 2          # timestamped traced-knob grid
MIN_SPEEDUP = 3.0          # vectorized vs per-series Python loop
ORACLE_REFINE = 16         # fine-grid refinement factor for the oracle
N_POINTS = 64              # throughput grid: points axis
N_SHARDS = 8               # throughput grid: shard axis
N_WINDOWS = 16             # throughput grid: window axis

WORKED = SimSpec(
    traffic=TrafficSpec(kind="irm", n_requests=1500, n_pages=512,
                        write_fraction=0.3, seed=7),
    store=StoreConfig(n_lines=64, policy="ws"),
    n_shards=4,
    lam=100.0,
    k_servers=1,
    rates=RateSpec(source="paper"),
    p12_override=0.2,
)


def _step_burst(n_windows: int = 18):
    """A step-burst λ(t): calm, 4 windows of tier-2 overload, calm again."""
    lam = np.full(n_windows, 20.0)
    lam[5:9] = 200.0          # lam2 = p12 * lam = 40 > mu2 = 33
    p12 = np.full(n_windows, 0.2)
    return lam, p12


def bench_burst_accuracy() -> dict:
    """Coarse fluid vs coarse piecewise, judged against a fine-grid fluid
    oracle averaged back onto the coarse windows. The piecewise solve
    cannot represent the post-burst drain (its saturated windows are inf
    and its recovery windows snap to baseline), so we compare on the
    finite-response windows only — which is exactly where the drain tail
    lives."""
    dt = 1.0
    lam, p12 = _step_burst()
    n = lam.shape[0]
    fine = ORACLE_REFINE
    lam_f = np.repeat(lam, fine)
    p12_f = np.repeat(p12, fine)
    oracle = fluid_two_tier(lam_f, p12_f, 1000.0, 33.0, dt=dt / fine, k=1,
                            n_substeps=8)
    # Time-average the oracle's response back onto the coarse grid.
    resp_oracle = np.asarray(oracle.response).reshape(n, fine).mean(axis=1)
    fl = fluid_two_tier(lam, p12, 1000.0, 33.0, dt=dt, k=1)
    pw = transient_two_tier(lam, p12, 1000.0, 33.0, k=1, mode="piecewise")
    resp_fl = np.asarray(fl.response)
    resp_pw = np.asarray(pw.response)
    finite = np.isfinite(resp_pw)  # piecewise inf windows excluded
    err_fl = float(np.abs(resp_fl - resp_oracle)[finite].max())
    err_pw = float(np.abs(resp_pw - resp_oracle)[finite].max())
    # The drain window right after the burst is where the models diverge.
    drain = 9
    return {
        "n_windows": n,
        "oracle_refine": fine,
        "max_err_fluid_ms": round(err_fl * 1e3, 4),
        "max_err_piecewise_ms": round(err_pw * 1e3, 4),
        "drain_window_response_ms": {
            "oracle": round(float(resp_oracle[drain]) * 1e3, 3),
            "fluid": round(float(resp_fl[drain]) * 1e3, 3),
            "piecewise": round(float(resp_pw[drain]) * 1e3, 3),
        },
        "accuracy_margin": ACCURACY_MARGIN,
        "ok": bool(err_pw >= ACCURACY_MARGIN * err_fl),
    }


def bench_compile_gate(smoke: bool) -> dict:
    """Traced-knob grid with timestamps threaded through the sweep: the
    wall-clock axis must not add engine compiles (gate ≤ COMPILE_LIMIT)."""
    base = SimSpec(
        traffic=TrafficSpec(kind="irm", n_requests=400 if smoke else 1200,
                            n_pages=256, write_fraction=0.2, seed=3),
        store=StoreConfig(n_lines=64, policy="ws"),
        n_shards=4,
        lam=50.0,
        rates=RateSpec(source="paper"),
        window_dt=0.25,
        n_windows=8,  # pinned so every point shares one structural grid
    )
    axes = {
        "store.policy": ["lru", "ws"] if smoke else ["lru", "lfu", "ws",
                                                     "random"],
        "store.alpha": [0.3, 0.7],
        "store.beta": [0.5, 0.9],
    }
    reset_engine_compile_count()
    t0 = time.perf_counter()
    res = sweep(base, axes)
    wall = time.perf_counter() - t0
    compiles = engine_compile_count()
    lam_measured = [
        float(np.asarray(rep.windows.lam).sum(axis=0).mean())
        / base.n_shards
        for rep in res.reports[:1]
    ]
    return {
        "n_points": len(res.points),
        "window_dt": base.window_dt,
        "wall_s": round(wall, 3),
        "compiles": compiles,
        "compile_limit": COMPILE_LIMIT,
        "mean_measured_lam": round(lam_measured[0], 2),
        "ok": compiles <= COMPILE_LIMIT,
    }


def bench_worked_example() -> dict:
    """§V worked example through the timestamped wall-clock path: the
    whole-stream counters (and hence λ_eff = 86.6) must be bit-exact vs
    the request-index path."""
    base = simulate(WORKED)
    timed = simulate(WORKED.replace(window_dt=0.5))
    ok = (
        timed.lam_eff == base.lam_eff
        and abs(timed.lam_eff - PUBLISHED_LAM_EFF) < 1e-9
        and timed.hits == base.hits
        and timed.misses == base.misses
        and timed.tier2_writes == base.tier2_writes
    )
    return {
        "lam_eff": timed.lam_eff,
        "lam_eff_published": PUBLISHED_LAM_EFF,
        "n_windows_timed": timed.n_windows,
        "totals_bit_exact": bool(
            timed.hits == base.hits and timed.misses == base.misses),
        "ok": bool(ok),
    }


def _scalar_fluid_reference(lam, p12, mu1, mu2, dt, n_substeps=8):
    """Faithful per-series Python-loop reimplementation of the fluid
    solver's M/M/1-PSFFA path (plain floats, window loop per grid
    element)."""
    out = np.empty(lam.shape)
    h = dt / n_substeps

    def implicit(l, a, mu):
        r = l + h * a
        b = 1.0 + h * mu + r
        disc = max(b * b - 4.0 * h * r * mu, 0.0)
        x = (b - math.sqrt(disc)) / (2.0 * h)
        return l + h * (a - x), x

    for i in range(lam.shape[0]):
        for s in range(lam.shape[1]):
            # warm start at the first window's stationary solution
            a1 = (1.0 - p12[i, s, 0]) * lam[i, s, 0] + p12[i, s, 0] * mu2
            a2 = p12[i, s, 0] * lam[i, s, 0]
            l1 = a1 / (mu1 - a1) if a1 < mu1 else 0.0
            l2 = a2 / (mu2 - a2) if a2 < mu2 else 0.0
            for w in range(lam.shape[2]):
                a1 = ((1.0 - p12[i, s, w]) * lam[i, s, w]
                      + p12[i, s, w] * mu2)
                a2 = p12[i, s, w] * lam[i, s, w]
                l1_sum, l2_sum = 0.5 * l1, 0.5 * l2
                x1_sum = x2_sum = 0.0
                for t in range(n_substeps):
                    l1, x1 = implicit(l1, a1, mu1)
                    l2, x2 = implicit(l2, a2, mu2)
                    wgt = 0.5 if t == n_substeps - 1 else 1.0
                    l1_sum += wgt * l1
                    l2_sum += wgt * l2
                    x1_sum += x1
                    x2_sum += x2
                q1m, g1 = l1_sum / n_substeps, x1_sum / n_substeps
                q2m, g2 = l2_sum / n_substeps, x2_sum / n_substeps
                w1 = q1m / g1 if g1 > 1e-12 else 1.0 / mu1
                w2 = q2m / g2 if g2 > 1e-12 else 1.0 / mu2
                out[i, s, w] = w1 + (p12[i, s, w] * w2
                                     if p12[i, s, w] > 0 else 0.0)
    return out


def bench_fluid_throughput(smoke: bool) -> dict:
    """Vectorized fluid solve vs the per-series Python loop on a
    [points x shards x windows] grid (both paths cross-checked first)."""
    n_pts = 16 if smoke else N_POINTS
    rng = np.random.default_rng(0)
    lam = rng.uniform(5.0, 150.0, size=(n_pts, N_SHARDS, N_WINDOWS))
    p12 = rng.uniform(0.0, 0.4, size=(n_pts, N_SHARDS, N_WINDOWS))
    mu1, mu2 = 1000.0, 33.0
    dt = 0.5

    def vectorized():
        return np.asarray(
            fluid_two_tier(lam, p12, mu1, mu2, dt=dt, k=1).response)

    vec = vectorized()
    ref = _scalar_fluid_reference(lam, p12, mu1, mu2, dt)
    max_dev = float(np.abs(vec - ref).max())

    def best_of(fn, n=3):
        times = []
        for _ in range(n):
            t0 = time.perf_counter()
            fn()
            times.append(time.perf_counter() - t0)
        return min(times)

    t_vec = best_of(vectorized)
    t_ref = best_of(
        lambda: _scalar_fluid_reference(lam, p12, mu1, mu2, dt))
    speedup = t_ref / t_vec
    return {
        "grid": [n_pts, N_SHARDS, N_WINDOWS],
        "python_loop_s": round(t_ref, 4),
        "vectorized_s": round(t_vec, 4),
        "speedup": round(speedup, 2),
        "min_speedup": MIN_SPEEDUP,
        "max_abs_dev": max_dev,
        "ok": bool(max_dev < 1e-9 and speedup >= MIN_SPEEDUP),
    }


def main() -> None:
    smoke = "--smoke" in sys.argv
    artifact = {
        "mode": "smoke" if smoke else "full",
        "burst_accuracy": bench_burst_accuracy(),
        "compile_gate": bench_compile_gate(smoke),
        "worked_example": bench_worked_example(),
        "fluid_throughput": bench_fluid_throughput(smoke),
    }
    with open(ARTIFACT, "w") as f:
        json.dump(artifact, f, indent=1)
        f.write("\n")

    ba, cg, we, ft = (artifact["burst_accuracy"], artifact["compile_gate"],
                      artifact["worked_example"],
                      artifact["fluid_throughput"])
    print(f"burst accuracy: fluid {ba['max_err_fluid_ms']:.3f}ms vs "
          f"piecewise {ba['max_err_piecewise_ms']:.3f}ms max error "
          f"(margin {ACCURACY_MARGIN}x) ok={ba['ok']}")
    print(f"compile gate: {cg['n_points']} timestamped traced-knob points "
          f"-> {cg['compiles']} compiles (limit {COMPILE_LIMIT}) "
          f"ok={cg['ok']}")
    print(f"worked example: lam_eff={we['lam_eff']:.1f} through "
          f"{we['n_windows_timed']} wall-clock windows ok={we['ok']}")
    print(f"fluid throughput: {ft['grid']} grid, vectorized "
          f"{ft['vectorized_s']*1e3:.1f}ms vs loop "
          f"{ft['python_loop_s']*1e3:.1f}ms -> {ft['speedup']}x "
          f"(min {MIN_SPEEDUP}x) ok={ft['ok']}")
    print(f"artifact: {ARTIFACT}")
    failures = [k for k in ("burst_accuracy", "compile_gate",
                            "worked_example", "fluid_throughput")
                if not artifact[k]["ok"]]
    if failures:
        raise SystemExit(f"bench_transient gates failed: {failures}")


if __name__ == "__main__":
    main()
