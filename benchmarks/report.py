"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from dryrun.json."""
from __future__ import annotations

import json
import os
import sys


def main(path: str = "benchmarks/results/dryrun.json") -> None:
    d = json.load(open(path))
    cells = sorted({k.rsplit("|", 1)[0] for k in d})

    print("### Dry-run matrix (lower+compile status, 16x16 and 2x16x16)\n")
    print("| arch | shape | pod1 | pod2 | peak GB/dev (pod1) | compile s |")
    print("|---|---|---|---|---|---|")
    for c in cells:
        arch, shape = c.split("|")
        r1 = d.get(c + "|pod1", {})
        r2 = d.get(c + "|pod2", {})
        mem = r1.get("memory", {})
        peak = (mem.get("argument_size_in_bytes", 0)
                + mem.get("temp_size_in_bytes", 0)) / 1e9
        print(f"| {arch} | {shape} | {r1.get('status','-')} "
              f"| {r2.get('status','-')} | {peak:.1f} "
              f"| {r1.get('compile_s','-')} |")

    print("\n### Roofline (single-pod 16x16, per device per step)\n")
    print("| arch | shape | t_compute | t_memory | t_coll | dominant "
          "| MODEL/HLO flops | roofline frac |")
    print("|---|---|---|---|---|---|---|---|")
    for c in cells:
        r = d.get(c + "|pod1", {})
        if r.get("status") != "ok":
            continue
        arch, shape = c.split("|")
        print(f"| {arch} | {shape} | {r['t_compute_s']:.3g} "
              f"| {r['t_memory_s']:.3g} | {r['t_collective_s']:.3g} "
              f"| **{r['dominant']}** | {r['useful_flops_frac']:.2f} "
              f"| {r['roofline_frac']:.3f} |")

    skips = [(k, v) for k, v in sorted(d.items())
             if v.get("status") == "skipped" and k.endswith("pod1")]
    if skips:
        print("\nSkipped cells (documented):")
        for k, v in skips:
            print(f"- `{k[:-5]}`: {v['reason']}")


if __name__ == "__main__":
    main(*sys.argv[1:])
