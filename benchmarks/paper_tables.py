"""One benchmark per paper table/figure (reproduction evidence).

Each function returns a list of CSV rows ``(name, us_per_call, derived)``;
``derived`` carries the table's headline quantity. Full outputs are also
dumped to benchmarks/results/*.json for EXPERIMENTS.md.
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.core.device_models import (
    PAPER_HDD_READ, PAPER_HDD_WRITE, PAPER_NVME_READ, PAPER_NVME_WRITE,
    fit_hdd_model, fit_nvme_model,
)
from repro.core.queuing import TwoTierModel, service_time_model
from repro.core.traffic import irm_stream, poisson_stream
from repro.storage.tier2 import Tier1Sim, Tier2Sim
from repro.storage.tiered_store import StoreConfig, run_stream

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def _dump(name: str, obj) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(obj, f, indent=1)


def _time_stream(cfg: StoreConfig, pages, writes) -> tuple[dict, float]:
    fn = jax.jit(lambda p, w: run_stream(cfg, p, w))
    st = fn(pages, writes)
    jax.block_until_ready(st.misses)  # compile
    t0 = time.perf_counter()
    st = fn(pages, writes)
    jax.block_until_ready(st.misses)
    dt = time.perf_counter() - t0
    return st, dt / len(pages) * 1e6  # us per request


def tables_v_vi_online_learning() -> list[tuple]:
    """Tables V & VI: cache misses for LRU / LFU / WS on Poisson and IRM
    traffic (1 process, 64 lines), plus WS decision time."""
    rows = []
    out = {}
    for kind, gen in (("poisson", poisson_stream), ("irm", irm_stream)):
        table = []
        for n in (500, 1000, 2500, 5000, 10000):
            pages, writes = gen(n, 256, seed=1)
            rec = {"reqs": n}
            for pol in ("lru", "lfu", "ws"):
                st, us = _time_stream(
                    StoreConfig(n_lines=64, policy=pol), pages, writes)
                rec[pol] = int(st.misses)
                if pol == "ws":
                    rec["ws_us_per_req"] = round(us, 3)
                    rows.append((f"table{'V' if kind=='poisson' else 'VI'}"
                                 f"_{kind}_n{n}", round(us, 3),
                                 f"lru={rec['lru']};lfu={rec['lfu']};"
                                 f"ws={rec['ws']}"))
            table.append(rec)
        out[kind] = table
    _dump("tables_v_vi", out)
    return rows


def fig3_miss_rate_vs_cache_size() -> list[tuple]:
    """Fig. 3: capacity-miss rate vs cache size, 1 process, random reads."""
    pages, writes = poisson_stream(4000, 512, seed=0, decay_tau=1e9)
    rows = []
    curve = []
    for n_lines in (16, 32, 64, 128, 256, 512):
        st, us = _time_stream(StoreConfig(n_lines=n_lines, policy="lru"),
                              pages, writes)
        mr = float(st.miss_rate)
        curve.append({"cache_lines": n_lines, "miss_rate": mr})
        rows.append((f"fig3_lines{n_lines}", round(us, 3),
                     f"miss_rate={mr:.4f}"))
    # monotone non-increasing check (capacity misses)
    mrs = [c["miss_rate"] for c in curve]
    assert all(a >= b - 1e-9 for a, b in zip(mrs, mrs[1:])), mrs
    _dump("fig3", curve)
    return rows


def tables_i_ii_nvme_models() -> list[tuple]:
    """Tables I & II: NVMe write/read regression recovery."""
    rows = []
    out = {}
    for read, paper in ((False, PAPER_NVME_WRITE), (True, PAPER_NVME_READ)):
        t0 = time.perf_counter()
        m = fit_nvme_model(read=read)
        us = (time.perf_counter() - t0) * 1e6
        rec = dict(zip(m.fit.term_names(), m.fit.coef))
        errs = {k: abs(rec[k] - v) / abs(v)
                for k, v in paper.items() if k != "(Intercept)"
                and k in rec and abs(v) > 0}
        key = "nvme_read" if read else "nvme_write"
        out[key] = {
            "r2": m.fit.r2, "aic": m.fit.aic, "cv_rmse": m.cv_rmse,
            "dominant_term_rel_err": {
                k: errs[k] for k in ("x1:x3:x4", "x3:x4:x5")},
            "coef": {k: float(v) for k, v in rec.items()},
        }
        rows.append((f"table{'II' if read else 'I'}_{key}", round(us, 1),
                     f"r2={m.fit.r2:.4f};x1x3x4_err="
                     f"{errs['x1:x3:x4']:.3f};x3x4x5_err={errs['x3:x4:x5']:.3f}"))
    _dump("tables_i_ii", out)
    return rows


def tables_iii_iv_hdd_models() -> list[tuple]:
    """Tables III & IV: HDD write/read regression recovery."""
    rows = []
    out = {}
    for read, paper in ((False, PAPER_HDD_WRITE), (True, PAPER_HDD_READ)):
        t0 = time.perf_counter()
        m = fit_hdd_model(read=read)
        us = (time.perf_counter() - t0) * 1e6
        rec = dict(zip(m.fit.term_names(), m.fit.coef))
        keys = ("x3", "x3:x4", "x1:x5") if read else ("x5", "x1:x5", "x2:x5")
        errs = {k: abs(rec[k] - paper[k]) / abs(paper[k]) for k in keys}
        key = "hdd_read" if read else "hdd_write"
        out[key] = {"r2": m.fit.r2, "aic": m.fit.aic, "cv_rmse": m.cv_rmse,
                    "sig_term_rel_err": errs,
                    "coef": {k: float(v) for k, v in rec.items()}}
        rows.append((f"table{'IV' if read else 'III'}_{key}", round(us, 1),
                     f"r2={m.fit.r2:.4f};" + ";".join(
                         f"{k}_err={v:.3f}" for k, v in errs.items())))
    _dump("tables_iii_iv", out)
    return rows


def section_v_worked_example() -> list[tuple]:
    """§V worked example: the queuing model's published numbers."""
    t0 = time.perf_counter()
    m = TwoTierModel(lam=100, mu1=1000, mu2=33, p12=0.2, k=1)
    s = m.analyze().summary()
    t = m.time_for(2500)
    us = (time.perf_counter() - t0) * 1e6
    _dump("worked_example", {**s, **t})
    return [("secV_worked_example", round(us, 1),
             f"lam_eff={s['lam_eff']:.1f};rho1={s['rho1']:.4f};"
             f"rho2={s['rho2']:.3f};T={t['arrival_window_s']:.1f}s")]


def tables_vii_ix_strong_scaling() -> list[tuple]:
    """Tables VII-IX: strong-scaling predictions from eqs. 1-4 + device
    models (workload1 = low reuse/miss-bound; workload2 = high reuse)."""
    rows = []
    out = {}
    t1 = Tier1Sim(n_client_threads=16, request_size=512)
    # Misses are page-grain tier-2 fetches: ~every distinct page is fetched
    # once (cold) + an eviction factor when the working set stresses the
    # cache. workload1 touches 229376 pages (~112 GB), workload2 32768.
    for wl, (n_req, n_pages, evict_factor) in {
        "workload1": (5_000_000, 229_376, 2.0),  # low reuse, cache-stressed
        "workload2": (8_000_000, 32_768, 1.0),   # high reuse, fits tier 1
    }.items():
        tab = []
        for procs in (16, 32, 64, 128, 200):
            t2 = Tier2Sim(n_processes=procs, stripe_count=8,
                          stripe_size=524288, file_size=400 << 30)
            per_proc = n_req / procs
            n_miss = n_pages * evict_factor / procs  # stripes per process
            mu1 = t1.mu1(read=True, n_requests=per_proc)
            mu2 = t2.mu2(read=True, n_stripes=max(n_miss, 1.0))
            st = service_time_model(
                n_read=[per_proc], n_write=[0], n_miss=[n_miss],
                mu1_read=mu1, mu1_write=mu1, mu2=mu2,
            )
            tab.append({"procs": procs, "t_hit_s": float(st.t_hit[0]),
                        "t_miss_s": float(st.t_miss[0]),
                        "response_s": float(st.t_total),
                        "bound": "miss" if st.t_miss[0] > st.t_hit[0]
                        else "hit"})
        out[wl] = tab
        # headline: does the model reproduce the paper's regimes?
        # workload1: miss(HDD)-bound at scale; workload2: strong-scales.
        first, last = tab[0]["response_s"], tab[-1]["response_s"]
        rows.append((f"tableVII_IX_{wl}", 0.0,
                     f"resp16={first:.1f}s;resp200={last:.1f}s;"
                     f"bound={tab[-1]['bound']}"))
    _dump("tables_vii_ix", out)
    return rows


def fig10_read_throughput() -> list[tuple]:
    """Fig. 10: read throughput vs process count (tiered, model-driven)."""
    t1 = Tier1Sim(n_client_threads=16, request_size=128)
    rows = []
    curve = []
    n_pages = (20 << 30) // 524288  # 2M 128-byte reads over 20 GB of pages
    for procs in (4, 8, 16, 32, 64, 128):
        n_req = 2_000_000 / procs
        mu1 = t1.mu1(read=True, n_requests=n_req)
        t2 = Tier2Sim(n_processes=procs)
        n_miss = n_pages / procs  # cold page fetches, split across caches
        mu2 = t2.mu2(read=True, n_stripes=max(n_miss, 1))
        t_total = max(n_req / mu1, n_miss / mu2)
        thr = 2_000_000 / t_total / 1e6  # Mreq/s aggregate
        curve.append({"procs": procs, "throughput_mreq_s": thr})
        rows.append((f"fig10_procs{procs}", 0.0, f"thr={thr:.3f}Mreq/s"))
    _dump("fig10", curve)
    return rows
