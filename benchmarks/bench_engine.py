"""Fused cache-scan engine benchmark: exactness, compile count, speedup.

  PYTHONPATH=src python benchmarks/bench_engine.py [--smoke]

Measures the fused tier-1 request-loop engine (``engine="fused"`` —
``repro.kernels.cache_scan.fused_cache_scan``: the whole request loop with
cache state, recency metadata and online-learning expert weights carried
through one fused scan, windowed counters folded in a dense post-pass)
against the original per-step ``lax.scan`` engine it replaces, and writes a
``BENCH_engine.json`` artifact at the repo root.

Gates:

- **equivalence** — the fused engine is *bit-exact* against the scan engine
  on every counter: one-shot streams over policy × prefetch, sharded
  scenarios over every mapping policy, a faulted wall-clock-binned timeline
  (failover remap + retry storm + degraded tier-2), and a chunk-streamed
  multi-tenant replay including per-tenant attribution. Zero tolerance —
  any differing field fails the gate.
- **interpret parity** — the Pallas ``cache_scan_kernel`` in interpret mode
  reproduces the pure-jax oracle ``cache_scan_ref`` bit for bit over a
  policy × prefetch sample (the compiled TPU path shares the same body).
- **compile gate** — a 288-point traced-knob sweep (alpha × beta ×
  threshold × policy) × 32 windows over the faulted workload traces the
  fused engine at most :data:`COMPILE_LIMIT` times
  (``cache_scan_compile_count()``): the megabatch dispatch traces once per
  structural shape, and traced hyperparameters ride as operands.
- **speedup** (full mode only) — ≥ :data:`MIN_SPEEDUP`x engine-stage
  points/sec over the scan engine on the same 288-point × 32-window grid
  (``sweep(profile=True)``'s ``engine_dispatch`` stage, warm jit caches;
  each engine runs at its best unroll).

``--smoke`` runs reduced grids for CI (equivalence + interpret parity +
compile gates only).
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core.traffic import TenantSpec, TrafficSpec  # noqa: E402
from repro.kernels.cache_scan import (  # noqa: E402
    cache_scan_compile_count,
    cache_scan_kernel,
    cache_scan_noise,
    reset_cache_scan_compile_count,
)
from repro.kernels.ref import cache_scan_ref  # noqa: E402
from repro.sim import (  # noqa: E402
    FaultSpec,
    RetryPolicy,
    SimSpec,
    device_degrade,
    shard_down,
    sweep,
    tier1_counters,
)
from repro.sim.spec import StoreConfig  # noqa: E402
from repro.sim.stream import stream_tier1_counters  # noqa: E402
from repro.storage.tiered_store import (  # noqa: E402
    _init_accum,
    init_store,
    run_stream,
)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ARTIFACT = os.path.join(ROOT, "BENCH_engine.json")
COMPILE_LIMIT = 2   # megabatch dispatch trace + at most one length bucket
MIN_SPEEDUP = 3.0   # engine-stage points/sec, fused vs scan

N_WINDOWS = 32
WINDOW_DT = 0.3
# Engine-side knobs only (all traced operands): 4 x 4 x 6 x 3 = 288 points.
FULL_AXES = {
    "store.alpha": tuple(np.linspace(0.2, 0.8, 4)),
    "store.beta": tuple(np.linspace(0.4, 0.9, 4)),
    "store.threshold": tuple(np.linspace(0.05, 0.45, 6)),
    "store.policy": ("ws", "lru", "lfu"),
}
SMOKE_AXES = {
    "store.alpha": (0.3, 0.6),
    "store.beta": (0.5, 0.8),
    "store.policy": ("ws", "lru"),
}

FAULTS = FaultSpec(
    events=(shard_down(1, 0.8, 2.4),
            device_degrade(2, 0.4, 1.5, 4.0)),
    retry=RetryPolicy(timeout=0.05, max_retries=2, backoff_init=0.4),
)


def base_spec(n_windows: int, faults) -> SimSpec:
    return SimSpec(
        traffic=TrafficSpec(kind="poisson", n_requests=2000, n_pages=512,
                            rate=240.0, seed=11),
        store=StoreConfig(n_lines=64),
        n_shards=4,
        n_windows=n_windows,
        window_dt=WINDOW_DT,
        faults=faults,
    )


def _diff_fields(a, b, skip=()) -> list[str]:
    """Names of fields on which two counter trees disagree (bit-exact)."""
    bad = []
    for f in a._fields:
        if f in skip:
            continue
        if not np.array_equal(np.asarray(getattr(a, f)),
                              np.asarray(getattr(b, f))):
            bad.append(f)
    return bad


def bench_equivalence(smoke: bool) -> dict:
    n = 800 if smoke else 2000
    mismatches: list[str] = []
    cases = 0

    # One-shot streams: policy x prefetch.
    rng = np.random.default_rng(0)
    pages = jnp.asarray(rng.integers(0, 400, n), jnp.int32)
    writes = jnp.asarray(rng.random(n) < 0.3)
    win = jnp.asarray(np.minimum(np.arange(n) // (n // 8), 7), jnp.int32)
    policies = ("ws", "lru") if smoke else ("ws", "lru", "lfu", "random")
    for policy in policies:
        for prefetch in (False, True):
            cfg = StoreConfig(n_lines=48, policy=policy, prefetch=prefetch)
            fused = run_stream(cfg, pages, writes, window_ids=win,
                               n_windows=8, seed=5, engine="fused")
            scan = run_stream(cfg, pages, writes, window_ids=win,
                              n_windows=8, seed=5, engine="scan")
            cases += 1
            mismatches += [f"stream/{policy}/pf={prefetch}:{f}"
                           for f in _diff_fields(fused, scan)]

    # Sharded scenarios: every mapping policy.
    mappings = ("block",) if smoke else ("block", "round_robin", "random",
                                         "block_cyclic")
    for mapping in mappings:
        spec = SimSpec(
            traffic=TrafficSpec(kind="irm", n_requests=n, n_pages=400,
                                rate=200.0, seed=3),
            store=StoreConfig(n_lines=32, policy="ws"),
            n_shards=3, n_windows=6, mapping=mapping,
        )
        cases += 1
        mismatches += [f"mapping/{mapping}:{f}"
                       for f in _diff_fields(
                           tier1_counters(spec, engine="fused"),
                           tier1_counters(spec, engine="scan"),
                           skip=("tenants",))]

    # Faulted wall-clock timeline.
    spec = base_spec(8 if smoke else 16, FAULTS).replace(
        **{"traffic.n_requests": n})
    cases += 1
    mismatches += [f"faulted:{f}"
                   for f in _diff_fields(
                       tier1_counters(spec, engine="fused"),
                       tier1_counters(spec, engine="scan"),
                       skip=("tenants",))]

    # Chunk-streamed multi-tenant replay, incl. per-tenant attribution.
    spec = SimSpec(
        traffic=TrafficSpec(
            kind="tenant_mix", n_requests=n, n_pages=600, rate=300.0, seed=5,
            tenants=(TenantSpec("a", 180.0, 400, write_fraction=0.2),
                     TenantSpec("b", 120.0, 200, zipf_s=1.3, seed=9)),
        ),
        n_shards=2, n_windows=8,
    )
    ca, ta, _ = stream_tier1_counters(spec, chunk=256, engine="fused")
    cb, tb, _ = stream_tier1_counters(spec, chunk=256, engine="scan")
    cases += 1
    mismatches += [f"tenant:{f}"
                   for f in _diff_fields(ca, cb, skip=("tenants",))]
    mismatches += [f"tenant-attr:{f}" for f in _diff_fields(ta, tb)]

    return {
        "cases": cases,
        "mismatched_fields": mismatches,
        "ok": not mismatches,
    }


def bench_interpret_parity(smoke: bool) -> dict:
    L, N, W = (256, 32, 8) if smoke else (512, 32, 8)
    combos = [("ws", False), ("ws", True)] if smoke else [
        ("ws", False), ("lru", False), ("lfu", True), ("random", True)]
    rng = np.random.default_rng(1)
    pages = jnp.asarray(rng.integers(0, 200, L), jnp.int32)
    writes = jnp.asarray((rng.random(L) < 0.3).astype(np.int32))
    win = jnp.asarray(np.minimum(np.arange(L) // (L // W), W - 1), jnp.int32)
    mismatches = []
    for policy, prefetch in combos:
        cfg = StoreConfig(n_lines=N, policy=policy, prefetch=prefetch)
        hyper = cfg.hyper()
        st0 = init_store(cfg, 9)
        noise = cache_scan_noise(st0.key, L, N)
        final, acc = cache_scan_ref(
            st0, _init_accum(W), pages, writes, win, hyper, noise,
            epoch_width=cfg.epoch_width, pred_cap=cfg.pred_cap,
            prefetch=cfg.prefetch, prefetch_width=cfg.prefetch_width,
            n_windows=W)
        out = cache_scan_kernel(
            pages[None], writes[None], win[None], noise,
            hyper.alpha, hyper.beta, hyper.threshold, hyper.policy_idx,
            n_lines=cfg.n_lines, epoch_width=cfg.epoch_width,
            pred_cap=cfg.pred_cap, prefetch=cfg.prefetch,
            prefetch_width=cfg.prefetch_width,
            prefetch_buf=st0.pf.ptags.shape[-1], n_windows=W,
            interpret=True)
        for f in acc._fields:
            x = np.asarray(getattr(acc, f))
            if not np.array_equal(np.asarray(out[f][0]).reshape(x.shape), x):
                mismatches.append(f"{policy}/pf={prefetch}:{f}")
        if not np.array_equal(np.asarray(out["final_weights"][0]),
                              np.asarray(final.ols.weights)):
            mismatches.append(f"{policy}/pf={prefetch}:final_weights")
    return {
        "combos": len(combos),
        "mismatched_fields": mismatches,
        "ok": not mismatches,
    }


def bench_compile_gate(smoke: bool) -> dict:
    axes = SMOKE_AXES if smoke else FULL_AXES
    n_windows = 6 if smoke else N_WINDOWS
    # n_lines distinct from the equivalence workloads so this sweep counts
    # its own traces rather than inheriting a warm engine cache.
    base = base_spec(n_windows, FAULTS).replace(**{"store.n_lines": 80})
    n_points = int(np.prod([len(v) for v in axes.values()]))
    reset_cache_scan_compile_count()
    res = sweep(base, axes, engine="fused", unroll=1, profile=True)
    compiles = cache_scan_compile_count()
    assert len(res.reports) == n_points
    return {
        "n_points": n_points,
        "n_windows": n_windows,
        "compiles": compiles,
        "limit": COMPILE_LIMIT,
        "profile": {k: round(v, 4) if isinstance(v, float) else v
                    for k, v in res.profile.items()},
        "ok": compiles <= COMPILE_LIMIT,
    }


def bench_speedup(smoke: bool) -> dict:
    if smoke:
        return {"skipped": True, "ok": True}
    base = base_spec(N_WINDOWS, FAULTS).replace(**{"store.n_lines": 80})
    n_points = int(np.prod([len(v) for v in FULL_AXES.values()]))

    def engine_time(engine: str, unroll: int) -> float:
        sweep(base, FULL_AXES, engine=engine, unroll=unroll)  # warm
        res = sweep(base, FULL_AXES, engine=engine, unroll=unroll,
                    profile=True)
        return res.profile["engine_dispatch"]

    # Each engine at its best unroll on this grid: the per-step scan
    # amortises loop overhead with unroll=4; the fused engine's single
    # pass gains nothing from unrolling.
    t_scan = engine_time("scan", unroll=4)
    t_fused = engine_time("fused", unroll=1)
    speedup = t_scan / t_fused if t_fused > 0 else float("inf")
    return {
        "n_points": n_points,
        "n_windows": N_WINDOWS,
        "fused_s": round(t_fused, 4),
        "scan_s": round(t_scan, 4),
        "fused_points_per_sec": round(n_points / t_fused, 1),
        "scan_points_per_sec": round(n_points / t_scan, 1),
        "speedup": round(speedup, 2),
        "min_speedup": MIN_SPEEDUP,
        "ok": speedup >= MIN_SPEEDUP,
    }


def main() -> None:
    smoke = "--smoke" in sys.argv
    artifact = {
        "mode": "smoke" if smoke else "full",
        "devices": jax.local_device_count(),
        "equivalence": bench_equivalence(smoke),
        "interpret_parity": bench_interpret_parity(smoke),
        "compile_gate": bench_compile_gate(smoke),
        "speedup": bench_speedup(smoke),
    }
    with open(ARTIFACT, "w") as f:
        json.dump(artifact, f, indent=1)
        f.write("\n")

    eq, ip, cg, sp = (artifact["equivalence"], artifact["interpret_parity"],
                      artifact["compile_gate"], artifact["speedup"])
    print(f"equivalence: {eq['cases']} cases, "
          f"{len(eq['mismatched_fields'])} mismatched fields ok={eq['ok']}")
    print(f"interpret parity: {ip['combos']} combos, "
          f"{len(ip['mismatched_fields'])} mismatched fields ok={ip['ok']}")
    print(f"compile gate: {cg['n_points']} points x {cg['n_windows']} "
          f"windows -> {cg['compiles']} engine traces "
          f"(limit {COMPILE_LIMIT}) ok={cg['ok']}")
    if sp.get("skipped"):
        print("speedup: skipped (--smoke)")
    else:
        print(f"speedup: fused {sp['fused_points_per_sec']} pts/s vs "
              f"scan {sp['scan_points_per_sec']} pts/s -> "
              f"{sp['speedup']}x (min {MIN_SPEEDUP}) ok={sp['ok']}")
    print(f"artifact: {ARTIFACT}")
    failures = [k for k in ("equivalence", "interpret_parity",
                            "compile_gate", "speedup")
                if not artifact[k]["ok"]]
    if failures:
        raise SystemExit(f"bench_engine gates failed: {failures}")


if __name__ == "__main__":
    main()
