"""Megabatch sweep benchmark: compile count, points/sec, §V gate.

  PYTHONPATH=src python benchmarks/bench_sweep.py [--smoke]

Measures the one-compile sweep engine (traced hyperparameters + bucketed
padding + device sharding, see ``repro.sim.sweep``) against a faithful
reimplementation of the pre-PR batching strategy, and writes a
``BENCH_sweep.json`` artifact at the repo root so later PRs have a perf
trajectory.

Gates:

- **compile gate** — a sweep whose axes cover only traced knobs
  (``store.alpha``/``beta``/``threshold``/``policy``) compiles the engine at
  most :data:`COMPILE_LIMIT` times (the pre-PR engine compiled once per
  policy x hyperparameter combination).
- **§V worked example** — λ_eff through the new batching path matches
  ``simulate()`` bit-exactly and stays within 1% of the published 86.6.
- **speedup** (full mode only) — ≥ :data:`MIN_SPEEDUP`x points/sec over the
  pre-PR reference on a ≥200-point grid spanning policy, hyperparameter,
  cache-size and traffic axes.

``--smoke`` runs reduced grids for CI (compile + bit-exactness gates only).
"""
from __future__ import annotations

import json
import os
import sys
import time

# Shard sweep points across forced host devices (must precede jax import).
_FLAG = "--xla_force_host_platform_device_count"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    _n_dev = max(1, min(os.cpu_count() or 1, 8))
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + f" {_FLAG}={_n_dev}"
    ).strip()

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core.traffic import TrafficSpec, make_stream  # noqa: E402
from repro.sim import (  # noqa: E402
    RateSpec,
    SimSpec,
    report_from_counters,
    simulate,
    sweep,
)
from repro.sim.engine import counters_from_stats, sim_n_pages  # noqa: E402
from repro.sim.sweep import (  # noqa: E402
    engine_compile_count,
    reset_engine_compile_count,
)
from repro.storage.tiered_store import (  # noqa: E402
    StoreConfig,
    partition_streams,
    run_stream,
)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ARTIFACT = os.path.join(ROOT, "BENCH_sweep.json")
PUBLISHED_LAM_EFF = 86.6  # §V worked example
COMPILE_LIMIT = 2         # traced-only grid must stay within this
MIN_SPEEDUP = 3.0         # full-mode points/sec gate vs the pre-PR path

BASE = SimSpec(
    traffic=TrafficSpec(kind="irm", n_requests=1200, n_pages=256,
                        write_fraction=0.2, seed=3),
    store=StoreConfig(n_lines=64, policy="ws"),
    n_shards=4,
    lam=50.0,
    rates=RateSpec(source="paper"),
)


# ---------------------------------------------------------------------------
# Pre-PR reference path. Faithful reimplementation of the old sweep batching:
# one group per *full* (StoreConfig, n_shards, mapping) — so every policy or
# hyperparameter value splits the jit cache — padded to the group-wide max
# shard load, run on a single device by an unjitted doubly-vmapped engine.
# ---------------------------------------------------------------------------


def _legacy_run_group(specs):
    store, n_shards = specs[0].store, specs[0].n_shards
    partitioned = []
    for spec in specs:
        pages, is_write = make_stream(spec.traffic)
        sh_p, sh_w, counts, owner = partition_streams(
            pages, is_write, n_shards=n_shards, mapping=spec.mapping,
            n_pages=sim_n_pages(spec, pages),
        )
        partitioned.append((sh_p, sh_w, counts, owner, is_write))

    cap = max(p[0].shape[1] for p in partitioned)
    sh_pages = np.zeros((len(specs), n_shards, cap), np.int32)
    sh_writes = np.zeros((len(specs), n_shards, cap), bool)
    for i, (sh_p, sh_w, _, _, _) in enumerate(partitioned):
        w = sh_p.shape[1]
        sh_pages[i, :, :w] = sh_p
        sh_pages[i, :, w:] = sh_p[:, -1:]
        sh_writes[i, :, :w] = sh_w

    run = jax.vmap(jax.vmap(lambda p, w: run_stream(store, p, w)))
    stacked = run(jnp.asarray(sh_pages), jnp.asarray(sh_writes))
    stacked = jax.tree.map(np.asarray, stacked)

    out = []
    for i, (_, _, counts, owner, is_write) in enumerate(partitioned):
        stats_i = jax.tree.map(lambda a: a[i], stacked)
        writes = np.bincount(owner[is_write], minlength=n_shards)
        out.append(counters_from_stats(stats_i, counts, writes, cap=cap))
    return out


def legacy_sweep(base: SimSpec, points: list[dict]) -> list:
    """The pre-PR sweep loop: dedup by cache signature, then one engine
    (re)build per full-config group, groups sequential."""
    specs = [base.replace(**pt) for pt in points]
    sig_of = [spec.cache_signature() for spec in specs]
    unique = {}
    for spec, sig in zip(specs, sig_of):
        unique.setdefault(sig, spec)
    groups = {}
    for sig, spec in unique.items():
        groups.setdefault((spec.store, spec.n_shards, spec.mapping), []).append(sig)
    counters = {}
    for _, sigs in groups.items():
        for sig, ctr in zip(sigs, _legacy_run_group([unique[s] for s in sigs])):
            counters[sig] = ctr
    return [report_from_counters(spec, counters[sig])
            for spec, sig in zip(specs, sig_of)]


# ---------------------------------------------------------------------------
# Benchmark stages.
# ---------------------------------------------------------------------------


def bench_compile_gate(smoke: bool) -> dict:
    """Traced-knob-only grid must compile the engine at most COMPILE_LIMIT
    times (one compile serves every policy x hyperparameter combination)."""
    axes = {
        "store.policy": ["lru", "lfu", "ws", "random"],
        "store.alpha": [0.3, 0.5, 0.7],
        "store.beta": [0.5, 0.7, 0.9],
        "store.threshold": [0.1, 0.25],
    }
    if smoke:
        axes = {
            "store.policy": ["lru", "ws"],
            "store.alpha": [0.3, 0.7],
            "store.beta": [0.5, 0.9],
        }
    reset_engine_compile_count()
    t0 = time.perf_counter()
    res = sweep(BASE, axes)
    wall = time.perf_counter() - t0
    compiles = engine_compile_count()
    return {
        "axes": {k: list(v) for k, v in axes.items()},
        "n_points": len(res.points),
        "wall_s": round(wall, 3),
        "points_per_sec": round(len(res.points) / wall, 3),
        "compiles": compiles,
        "compile_limit": COMPILE_LIMIT,
        "ok": compiles <= COMPILE_LIMIT,
    }


def bench_reference_grid(smoke: bool) -> dict:
    """The ≥200-point grid spanning policy, hyperparameter, cache-size and
    traffic axes; new engine on the full grid, pre-PR reference timed on a
    subset (it pays a compile per config, so full-grid legacy runs are
    prohibitively slow — exactly the point) and scaled to points/sec."""
    axes = {
        "store.policy": ["lru", "lfu", "ws", "random"],
        "store.alpha": [0.3, 0.5, 0.7],
        "store.beta": [0.5, 0.7, 0.9],
        "store.threshold": [0.1, 0.25],
        "store.n_lines": [32, 64],
        "traffic.kind": ["irm", "markov"],
    }
    if smoke:
        axes = {
            "store.policy": ["lru", "ws"],
            "store.alpha": [0.3, 0.7],
            "store.n_lines": [32, 64],
            "traffic.kind": ["irm", "markov"],
        }

    reset_engine_compile_count()
    t0 = time.perf_counter()
    res = sweep(BASE, axes)
    wall_new = time.perf_counter() - t0
    pps_new = len(res.points) / wall_new

    # Legacy reference on a stratified subset: one point per
    # policy x cache-size x traffic combination (hyperparameter values
    # subsampled), so every structurally distinct engine is represented.
    strata = ("store.policy", "store.n_lines", "traffic.kind")
    subset_by_combo = {}
    for pt in res.points:
        subset_by_combo.setdefault(tuple(pt[k] for k in strata), pt)
    subset = list(subset_by_combo.values())
    t0 = time.perf_counter()
    legacy_reports = legacy_sweep(BASE, subset)
    wall_legacy = time.perf_counter() - t0
    pps_legacy = len(subset) / wall_legacy

    # Cross-check: legacy and megabatch paths agree on the subset's counters.
    by_point = {tuple(sorted(pt.items())): rep
                for pt, rep in zip(res.points, res.reports)}
    mismatches = sum(
        1
        for pt, lrep in zip(subset, legacy_reports)
        if (by_point[tuple(sorted(pt.items()))].misses != lrep.misses
            or by_point[tuple(sorted(pt.items()))].hits != lrep.hits)
    )

    speedup = pps_new / pps_legacy
    return {
        "axes": {k: list(v) for k, v in axes.items()},
        "n_points": len(res.points),
        "compiles": engine_compile_count(),
        "wall_s": round(wall_new, 3),
        "points_per_sec": round(pps_new, 3),
        "legacy_n_points": len(subset),
        "legacy_wall_s": round(wall_legacy, 3),
        "legacy_points_per_sec": round(pps_legacy, 3),
        "legacy_counter_mismatches": mismatches,
        "speedup": round(speedup, 2),
        "min_speedup": MIN_SPEEDUP,
        "ok": mismatches == 0 and (smoke or speedup >= MIN_SPEEDUP),
    }


def bench_worked_example() -> dict:
    """§V worked example (λ_eff ≈ 86.6) through the megabatch path,
    bit-exact against the unbatched simulate()."""
    spec = SimSpec(
        traffic=TrafficSpec(kind="irm", n_requests=1500, n_pages=512,
                            write_fraction=0.3, seed=7),
        store=StoreConfig(n_lines=64, policy="ws"),
        n_shards=4,
        lam=100.0,
        k_servers=1,
        rates=RateSpec(source="paper"),
        p12_override=0.2,
    )
    res = sweep(spec, {"store.alpha": [0.3, 0.5], "store.policy": ["ws", "lru"]})
    batched = next(
        rep for pt, rep in zip(res.points, res.reports)
        if pt == {"store.alpha": 0.5, "store.policy": "ws"}
    )
    direct = simulate(spec)
    rel_err = abs(batched.lam_eff - PUBLISHED_LAM_EFF) / PUBLISHED_LAM_EFF
    bit_exact = (
        batched.lam_eff == direct.lam_eff
        and batched.misses == direct.misses
        and batched.hits == direct.hits
        and batched.tier2_reads == direct.tier2_reads
        and batched.tier2_writes == direct.tier2_writes
    )
    return {
        "lam_eff": batched.lam_eff,
        "lam_eff_published": PUBLISHED_LAM_EFF,
        "lam_eff_rel_err": rel_err,
        "bit_exact_vs_simulate": bit_exact,
        "ok": bit_exact and rel_err < 0.01,
    }


def main() -> None:
    smoke = "--smoke" in sys.argv
    artifact = {
        "mode": "smoke" if smoke else "full",
        "devices": jax.local_device_count(),
        "compile_gate": bench_compile_gate(smoke),
        "reference_grid": bench_reference_grid(smoke),
        "worked_example": bench_worked_example(),
    }
    with open(ARTIFACT, "w") as f:
        json.dump(artifact, f, indent=1)
        f.write("\n")

    cg, rg, we = (artifact["compile_gate"], artifact["reference_grid"],
                  artifact["worked_example"])
    print(f"devices: {artifact['devices']}")
    print(f"compile gate: {cg['n_points']} traced-only points -> "
          f"{cg['compiles']} compiles (limit {COMPILE_LIMIT}) ok={cg['ok']}")
    print(f"reference grid: {rg['n_points']} points in {rg['wall_s']}s "
          f"({rg['points_per_sec']} pts/s, {rg['compiles']} compiles) vs "
          f"legacy {rg['legacy_points_per_sec']} pts/s -> "
          f"speedup {rg['speedup']}x ok={rg['ok']}")
    print(f"worked example: lam_eff={we['lam_eff']:.1f} "
          f"(rel_err={we['lam_eff_rel_err']:.2e}) "
          f"bit_exact={we['bit_exact_vs_simulate']} ok={we['ok']}")
    print(f"artifact: {ARTIFACT}")
    failures = [k for k in ("compile_gate", "reference_grid", "worked_example")
                if not artifact[k]["ok"]]
    if failures:
        raise SystemExit(f"bench_sweep gates failed: {failures}")


if __name__ == "__main__":
    main()
