"""SLO-constrained capacity planner on the batched report pipeline.

  PYTHONPATH=src python benchmarks/hillclimb.py [--smoke] [--lam L] [--slo S]

Closes the loop from simulator to decision (the ROADMAP's capacity-planner
open item): given a traffic spec, an offered rate λ and a latency SLO,
search (cache size, shard count, replacement policy) for the *cheapest*
configuration whose worst-window response stays under the SLO — by
successive halving, where every rung is **one** ``sweep()`` call over the
surviving candidate set (explicit override dicts, megabatched counters,
``report="batched"`` so the whole rung's queuing networks solve as one
stacked ``[point, shard, window]`` fluid call).

Rungs double the stream-length fidelity: all candidates run at a short
stream first, the cheapest feasible half survives to the next rung, and
the final rung's cheapest feasible candidate is the answer ("to serve
λ=X at worst-window response < Y s you need Z shards / N lines"). When no
candidate is feasible at a rung, the half with the lowest worst-window
response survives (the planner then reports infeasibility at the top
fidelity instead of guessing).

Cost model: ``n_shards * (1 + COST_PER_LINE * n_lines)`` — an illustrative
device-cost proxy (tier-1 capacity dominates spend; shards multiply it).

Writes ``BENCH_hillclimb.json`` at the repo root. ``--smoke`` runs a
reduced candidate set and two rungs for CI; its gate is structural (a
winner or explicit infeasibility at full fidelity, one batched report
group per rung) rather than perf.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core.traffic import TrafficSpec  # noqa: E402
from repro.sim import (  # noqa: E402
    RateSpec,
    SimSpec,
    fluid_compile_count,
    reset_fluid_compile_count,
    sweep,
)
from repro.storage.tiered_store import StoreConfig  # noqa: E402

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ARTIFACT = os.path.join(ROOT, "BENCH_hillclimb.json")

COST_PER_LINE = 0.004   # tier-1 cost per cache line, in base-shard units
N_WINDOWS = 16

DEFAULT_LAM = 60.0
DEFAULT_SLO = 0.05      # worst-window response budget (seconds)


def candidate_grid(smoke: bool) -> list[dict]:
    """Explicit override dicts — the planner's search space. Cache size and
    shard count are structural engine knobs; the policy rides as a traced
    hyper, so each (n_lines, n_shards) pair still compiles once."""
    if smoke:
        sizes, shards, policies = [32, 64], [2, 4], ["lru"]
    else:
        sizes, shards, policies = [32, 64, 128, 256], [2, 4, 8], ["lru", "ws"]
    return [
        {"store.n_lines": nl, "n_shards": ns, "store.policy": p}
        for nl in sizes for ns in shards for p in policies
    ]


def config_cost(pt: dict) -> float:
    return pt["n_shards"] * (1.0 + COST_PER_LINE * pt["store.n_lines"])


def base_spec(lam: float, n_requests: int) -> SimSpec:
    rate = 240.0
    return SimSpec(
        traffic=TrafficSpec(kind="irm", n_requests=n_requests, n_pages=512,
                            zipf_s=1.1, write_fraction=0.1, rate=rate,
                            seed=17),
        store=StoreConfig(n_lines=64, policy="lru"),
        n_shards=4,
        lam=lam,
        rates=RateSpec(mu1=400.0, mu2=60.0),
        n_windows=N_WINDOWS,
        window_dt=n_requests / rate / N_WINDOWS,
    )


def worst_window_response(rep) -> float:
    resp = np.asarray(rep.transient.response, float)
    finite = resp[np.isfinite(resp)]
    if finite.size < resp.size:
        return float("inf")  # a saturated window blows the SLO by itself
    return float(finite.max()) if finite.size else float("inf")


def feasible(rep, slo: float) -> bool:
    return (bool(rep.equilibrium)
            and rep.saturation_onset is None
            and worst_window_response(rep) <= slo)


def run(smoke: bool = False, lam: float = DEFAULT_LAM,
        slo: float = DEFAULT_SLO, artifact: str = ARTIFACT) -> dict:
    fidelities = [600, 1200] if smoke else [1000, 2000, 4000]
    survivors = candidate_grid(smoke)

    rungs = []
    final: list[tuple[dict, dict]] = []
    reset_fluid_compile_count()
    for rung, n_requests in enumerate(fidelities):
        base = base_spec(lam, n_requests)
        res = sweep(base, survivors, report="batched", profile=True)
        scored = []
        for pt, rep in zip(res.points, res.reports):
            scored.append((pt, {
                "cost": config_cost(pt),
                "feasible": feasible(rep, slo),
                "worst_window_response_s": worst_window_response(rep),
                "mean_response_s": float(rep.response_s),
                "miss_rate": float(rep.miss_rate),
            }))
        feas = [s for s in scored if s[1]["feasible"]]
        n_keep = max(1, len(survivors) // 2)
        if feas:
            # Cheapest feasible half survives; ties break on response.
            feas.sort(key=lambda s: (s[1]["cost"],
                                     s[1]["worst_window_response_s"]))
            kept = feas[:n_keep]
        else:
            scored.sort(key=lambda s: s[1]["worst_window_response_s"])
            kept = scored[:n_keep]
        rungs.append({
            "fidelity_requests": n_requests,
            "n_candidates": len(survivors),
            "n_feasible": len(feas),
            "kept": [s[0] for s in kept],
            "profile": {k: round(v, 4) if isinstance(v, float) else v
                        for k, v in res.profile.items()},
        })
        survivors = [s[0] for s in kept]
        final = kept

    winners = [s for s in final if s[1]["feasible"]]
    winner = None
    if winners:
        pt, m = min(winners, key=lambda s: (s[1]["cost"],
                                            s[1]["worst_window_response_s"]))
        winner = {**{str(k): v for k, v in pt.items()}, **m}
    out = {
        "mode": "smoke" if smoke else "full",
        "lam": lam,
        "slo_s": slo,
        "fluid_compiles": fluid_compile_count(),
        "rungs": rungs,
        "winner": winner,
        # A planner run is structurally ok when it terminates with either a
        # winner or an explicit top-fidelity infeasibility verdict, and the
        # batched report path served every rung (compile budget: at most
        # one [P,S,W] + one [P,W] trace per distinct (shape, rung) config).
        "ok": bool(winner is not None or final),
    }
    with open(artifact, "w") as f:
        json.dump(out, f, indent=1)
        f.write("\n")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--lam", type=float, default=DEFAULT_LAM)
    ap.add_argument("--slo", type=float, default=DEFAULT_SLO)
    args = ap.parse_args()
    out = run(smoke=args.smoke, lam=args.lam, slo=args.slo)

    for r in out["rungs"]:
        print(f"rung @{r['fidelity_requests']} req: "
              f"{r['n_candidates']} candidates, {r['n_feasible']} feasible, "
              f"report_solve {r['profile']['report_solve']}s")
    w = out["winner"]
    if w is None:
        print(f"no configuration meets SLO {out['slo_s']}s at "
              f"lam={out['lam']} (top fidelity) — raise capacity or SLO")
    else:
        print(f"to serve lam={out['lam']} at worst-window response "
              f"< {out['slo_s']}s: n_shards={w['n_shards']}, "
              f"n_lines={w['store.n_lines']}, policy={w['store.policy']} "
              f"(cost {w['cost']:.2f}, worst window "
              f"{w['worst_window_response_s']:.4f}s)")
    print(f"fluid compiles across rungs: {out['fluid_compiles']}")
    print(f"artifact: {ARTIFACT}")
    if not out["ok"]:
        raise SystemExit("hillclimb planner failed to terminate cleanly")


if __name__ == "__main__":
    main()
