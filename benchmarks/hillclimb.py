import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimbing driver: re-lower chosen cells with candidate changes
and record hypothesis -> change -> before/after roofline terms.

  PYTHONPATH=src python -m benchmarks.hillclimb [--cell N]

Appends iterations to benchmarks/results/perf_iterations.json.
"""
import argparse
import json
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import dryrun  # noqa: E402
from repro.configs.base import MoEConfig  # noqa: E402

OUT = os.path.join(os.path.dirname(__file__), "results",
                   "perf_iterations.json")

# (cell, variant-name, hypothesis, cfg_patch, sc_patch)
EXPERIMENTS = [
    # --- Cell A: mixtral decode_32k — the paper-representative two-tier
    #     paged-KV cell; memory-bound on KV page reads.
    ("mixtral-8x22b|decode_32k", "baseline",
     "paper-faithful bf16 two-pool paged KV", None, None),
    ("mixtral-8x22b|decode_32k", "int8_kv",
     "int8-quantized KV pools halve page-read bytes => memory term ~-45%",
     None, {"kv_dtype": "int8"}),
    ("mixtral-8x22b|decode_32k", "int8_kv+hbm75",
     "larger tier-1 (hbm_fraction .75) shifts reads from tier-2: same HLO "
     "bytes on CPU sim but fewer tier-2 (host-link) reads at runtime; "
     "measure structural delta", None,
     {"kv_dtype": "int8", "hbm_fraction": 0.75}),
    # --- Extension: worst decode cells (MHA KV / flagship).
    ("stablelm-3b|decode_32k", "baseline",
     "MHA (kv=32) KV pools dominate decode bytes", None, None),
    ("stablelm-3b|decode_32k", "int8_kv",
     "int8 KV halves the MHA page reads", None, {"kv_dtype": "int8"}),
    ("stablelm-3b|decode_32k", "int8_kv+no_fsdp",
     "5.6 GB of params fit without FSDP: kills the per-token weight "
     "all-gathers on top of int8 KV",
     {"fsdp": False}, {"kv_dtype": "int8"}),
    ("llama3-405b|decode_32k", "baseline",
     "flagship decode: KV reads + per-token FSDP gathers", None, None),
    ("llama3-405b|decode_32k", "int8_kv",
     "int8 KV halves 2.2 TB of global KV reads", None, {"kv_dtype": "int8"}),
    # --- Cell B: mistral-nemo train_4k — most collective-bound cell.
    ("mistral-nemo-12b|train_4k", "baseline",
     "FSDP over data: per-layer weight all-gathers dominate collectives",
     None, None),
    ("mistral-nemo-12b|train_4k", "no_fsdp",
     "12B fits without data-sharding (TP-sharded params ~9 GB/chip incl. "
     "f32 adam): dropping FSDP kills fwd+bwd weight gathers => collective "
     "term ~-60%", {"fsdp": False}, None),
    ("mistral-nemo-12b|train_4k", "no_fsdp+bf16opt",
     "bf16 adam moments halve optimizer HBM so no_fsdp also fits "
     "comfortably; no effect on roofline terms (control)",
     {"fsdp": False, "opt_state_dtype": "bfloat16"}, None),
    ("mistral-nemo-12b|train_4k", "bf16_tp_psum",
     "collectives are TP activation psums in f32 (refuted-FSDP finding): "
     "bf16 wire on attention/MLP partial reductions => collective ~-50%",
     {"tp_reduce_dtype": "bfloat16"}, None),
    ("mistral-nemo-12b|train_4k", "bf16_tp_psum+no_fsdp",
     "compose both: bf16 psums + no FSDP gathers",
     {"tp_reduce_dtype": "bfloat16", "fsdp": False}, None),
    ("grok-1-314b|train_4k", "cf1.0+bf16psum",
     "compose: cf1.0 + bf16 TP psums (MoE combine psum is f32 and large)",
     {"moe": MoEConfig(n_experts=8, top_k=2, capacity_factor=1.0),
      "tp_reduce_dtype": "bfloat16"}, None),
    # --- Cell C: grok-1 train_4k — worst useful-FLOPs MoE cell.
    ("grok-1-314b|train_4k", "baseline",
     "MoE capacity factor 1.25 pads expert matmuls by 25%", None, None),
    ("grok-1-314b|train_4k", "cf1.0",
     "capacity_factor 1.0 cuts expert GEMM flops+bytes ~20% (more drops, "
     "acceptable with aux loss)",
     {"moe": MoEConfig(n_experts=8, top_k=2, capacity_factor=1.0)}, None),
    ("grok-1-314b|train_4k", "cf1.0+accum2",
     "2 microbatches: halves activation peak; gathers x2 => collective "
     "term up — quantify the memory/collective trade", 
     {"moe": MoEConfig(n_experts=8, top_k=2, capacity_factor=1.0)}, None),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="substring filter")
    args = ap.parse_args()

    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    results = []
    if os.path.exists(OUT):
        results = json.load(open(OUT))
    done = {(r["cell"], r["variant"]) for r in results}

    for cell, variant, hypothesis, cfg_patch, sc_patch in EXPERIMENTS:
        if args.only and args.only not in f"{cell}:{variant}":
            continue
        if (cell, variant) in done:
            print(f"[cached] {cell} {variant}")
            continue
        arch, shape = cell.split("|")
        print(f"[run] {cell} :: {variant}", flush=True)
        kw = {}
        if variant.endswith("accum2"):
            # accum handled through TrainHyper — patch dryrun's default
            from repro.training import train_step as ts_mod
            import repro.launch.spmd as spmd_mod
            from repro.training.train_step import TrainHyper
            orig = spmd_mod.build_train_step
            def patched(cfg, mesh, hyper=TrainHyper()):
                import dataclasses as dc
                return orig(cfg, mesh, dc.replace(hyper, accum_steps=2))
            spmd_mod.build_train_step = patched
            dryrun.spmd.build_train_step = patched
        try:
            rec = dryrun.run_cell(arch, shape, False,
                                  cfg_patch=cfg_patch, sc_patch=sc_patch)
        finally:
            if variant.endswith("accum2"):
                spmd_mod.build_train_step = orig
                dryrun.spmd.build_train_step = orig
        row = {"cell": cell, "variant": variant, "hypothesis": hypothesis,
               **{k: rec.get(k) for k in (
                   "status", "dominant", "roofline_frac", "t_compute_s",
                   "t_memory_s", "t_collective_s", "useful_flops_frac",
                   "hlo_flops", "hlo_bytes_accessed",
                   "collective_wire_bytes_total", "compile_s")}}
        if rec.get("status") == "error":
            row["error"] = rec.get("error")
        results.append(row)
        json.dump(results, open(OUT, "w"), indent=1)
        print(f"[done] {variant}: dom={row.get('dominant')} "
              f"tc={row.get('t_compute_s')} tm={row.get('t_memory_s')} "
              f"tcoll={row.get('t_collective_s')}", flush=True)


if __name__ == "__main__":
    main()
