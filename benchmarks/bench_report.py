"""Batched report-pipeline benchmark: equivalence, compile count, speedup.

  PYTHONPATH=src python benchmarks/bench_report.py [--smoke]

Measures the batched fluid report path (``repro.sim.engine.batched_reports``
— every point's windowed rates stacked into one ``[point, shard, window]``
jitted ``lax.scan`` solve, see ``repro.core.queuing.fluid_two_tier_batched``)
against the per-point numpy host loop it replaces, and writes a
``BENCH_report.json`` artifact at the repo root.

Gates:

- **equivalence** — batched fluid outputs match the scalar numpy solver to
  ≤ :data:`EQUIV_TOL` on a healthy (fault-free, k=1 analytic) grid, and the
  full ``SimReport.to_dict`` JSON is *bit-exact* with ``mu_load`` off where
  bitwise equality is the contract: the scalar report path
  (``batched_reports(solver="scalar")``) reproduces the pre-batching
  ``report_from_counters`` byte for byte, and a repeated batched run of the
  same grid reproduces itself byte for byte. (The jax and numpy solvers
  differ at the ~1e-14 FMA level, and XLA re-fuses the kernel per batch
  shape, so cross-solver or cross-grouping bitwise equality is not a
  meaningful target.) The faulted grid (retry storm + shard-down +
  degraded tier-2) additionally checks agreement on the finite entries
  with identical non-finite masks.
- **compile gate** — a 288-point × 32-window faulted sweep through
  ``sweep(report="batched")`` traces the batched fluid kernel at most
  :data:`COMPILE_LIMIT` times (``fluid_compile_count()``: one compile for
  the ``[P, S, W]`` per-shard stack + one for the ``[P, W]`` pooled stack).
- **speedup** (full mode only) — ≥ :data:`MIN_SPEEDUP`x report-stage
  points/sec over the per-point host loop on the same 288-point grid.

``--smoke`` runs a reduced grid for CI (equivalence + compile gates only).
"""
from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

import jax  # noqa: E402

from repro.core.traffic import TrafficSpec  # noqa: E402
from repro.sim import (  # noqa: E402
    FaultSpec,
    RateSpec,
    RetryPolicy,
    SimSpec,
    batched_reports,
    device_degrade,
    fluid_compile_count,
    report_from_counters,
    reset_fluid_compile_count,
    shard_down,
    sweep,
    tier1_counters,
)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ARTIFACT = os.path.join(ROOT, "BENCH_report.json")
EQUIV_TOL = 1e-10   # healthy-grid batched vs scalar numpy
COMPILE_LIMIT = 2   # [P,S,W] shard stack + [P,W] pooled stack
MIN_SPEEDUP = 10.0  # report-stage points/sec vs the per-point host loop

# 24 x 12 = 288 queuing-side points over one shared counter run (the
# traffic spec pins its own wall-clock rate, so lam only affects the
# queuing network, not the cache signature).
N_LAM, N_MU2 = 24, 12
N_WINDOWS = 32

FAULTS = FaultSpec(
    events=(shard_down(1, 0.8, 2.4),
            device_degrade(2, 0.4, 1.5, 4.0)),
    retry=RetryPolicy(timeout=0.05, max_retries=2, backoff_init=0.4),
)


def base_spec(n_windows: int, faults) -> SimSpec:
    return SimSpec(
        traffic=TrafficSpec(kind="poisson", n_requests=2000, n_pages=512,
                            rate=240.0, seed=11),
        n_shards=4,
        lam=60.0,
        rates=RateSpec(mu1=400.0, mu2=40.0),
        n_windows=n_windows,
        window_dt=2000 / 240.0 / n_windows,
        faults=faults,
    )


def grid_points(n_lam: int, n_mu2: int) -> list[dict]:
    return [
        {"lam": float(l), "rates.mu2": float(m)}
        for l in np.linspace(30.0, 95.0, n_lam)
        for m in np.linspace(25.0, 80.0, n_mu2)
    ]


def _jsonify(obj):
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, np.generic):
        return obj.item()
    raise TypeError(f"not JSON serializable: {type(obj)!r}")


def report_json(rep) -> str:
    return json.dumps(rep.to_dict(), sort_keys=True, default=_jsonify)


def max_finite_diff(a, b) -> float:
    """Max |a-b| over finite entries; inf if the non-finite masks differ."""
    worst = 0.0
    for name in ("q1", "q2", "w1", "w2", "response", "rho1", "rho2",
                 "lam_eff"):
        xa = np.asarray(getattr(a.transient, name), float)
        xb = np.asarray(getattr(b.transient, name), float)
        fa, fb = np.isfinite(xa), np.isfinite(xb)
        if not (fa == fb).all():
            return float("inf")
        if fa.any():
            worst = max(worst, float(np.abs(xa[fa] - xb[fb]).max()))
    for name in ("w1", "w2", "response_s", "rho1", "rho2", "lam_eff"):
        va, vb = float(getattr(a, name)), float(getattr(b, name))
        if np.isfinite(va) != np.isfinite(vb):
            return float("inf")
        if np.isfinite(va):
            worst = max(worst, abs(va - vb))
    if a.saturation_onset != b.saturation_onset:
        return float("inf")
    if a.metastable_onset != b.metastable_onset:
        return float("inf")
    return worst


def bench_equivalence(smoke: bool) -> dict:
    n_lam, n_mu2 = (4, 3) if smoke else (8, 6)
    n_windows = 8 if smoke else 16
    points = grid_points(n_lam, n_mu2)

    out = {}
    for label, faults in (("healthy", None), ("faulted", FAULTS)):
        spec0 = base_spec(n_windows, faults)
        ctr = tier1_counters(spec0)
        specs = [spec0.replace(**pt) for pt in points]
        items = [(s, ctr, None) for s in specs]
        scalar = batched_reports(items, solver="scalar")
        batched = batched_reports(items, solver="batched")
        worst = max(max_finite_diff(a, b) for a, b in zip(scalar, batched))
        out[f"{label}_max_diff"] = worst
    out["n_points"] = len(points)

    # Bit-exact JSON with mu_load off: the scalar report path reproduces
    # the pre-batching per-point reference byte for byte, and the batched
    # path is deterministic (same grid twice -> same bytes).
    spec0 = base_spec(n_windows, FAULTS)
    ctr = tier1_counters(spec0)
    specs = [spec0.replace(**pt) for pt in points[:: max(1, len(points) // 6)]]
    items = [(s, ctr, None) for s in specs]
    scalar_ref = [report_from_counters(s, c, t) for s, c, t in items]
    scalar_now = batched_reports(items, solver="scalar")
    bit_exact = all(
        report_json(a) == report_json(b)
        for a, b in zip(scalar_ref, scalar_now)
    )
    deterministic = all(
        report_json(a) == report_json(b)
        for a, b in zip(batched_reports(items), batched_reports(items))
    )
    out["bit_exact_json"] = bit_exact
    out["batched_deterministic"] = deterministic
    bit_exact = bit_exact and deterministic
    out["ok"] = bool(out["healthy_max_diff"] <= EQUIV_TOL and bit_exact)
    return out


def bench_compile_gate(smoke: bool) -> dict:
    # Shapes distinct from the equivalence grids, so the gate counts this
    # sweep's own traces rather than inheriting a warm jit cache.
    n_lam, n_mu2 = (3, 2) if smoke else (N_LAM, N_MU2)
    n_windows = 6 if smoke else N_WINDOWS
    base = base_spec(n_windows, FAULTS)
    points = grid_points(n_lam, n_mu2)
    reset_fluid_compile_count()
    res = sweep(base, points, report="batched", profile=True)
    compiles = fluid_compile_count()
    return {
        "n_points": len(points),
        "n_windows": n_windows,
        "compiles": compiles,
        "limit": COMPILE_LIMIT,
        "profile": {k: round(v, 4) if isinstance(v, float) else v
                    for k, v in res.profile.items()},
        "ok": compiles <= COMPILE_LIMIT,
    }


def bench_speedup(smoke: bool) -> dict:
    if smoke:
        return {"skipped": True, "ok": True}
    n_windows = N_WINDOWS
    spec0 = base_spec(n_windows, FAULTS)
    ctr = tier1_counters(spec0)
    points = grid_points(N_LAM, N_MU2)
    specs = [spec0.replace(**pt) for pt in points]
    items = [(s, ctr, None) for s in specs]

    batched_reports(items)  # warm the jit cache (compile cost excluded)
    t0 = time.perf_counter()
    batched_reports(items)
    t_batched = time.perf_counter() - t0
    t0 = time.perf_counter()
    batched_reports(items, solver="scalar")
    t_scalar = time.perf_counter() - t0

    speedup = t_scalar / t_batched if t_batched > 0 else float("inf")
    return {
        "n_points": len(points),
        "n_windows": n_windows,
        "batched_s": round(t_batched, 4),
        "scalar_s": round(t_scalar, 4),
        "batched_points_per_sec": round(len(points) / t_batched, 1),
        "scalar_points_per_sec": round(len(points) / t_scalar, 1),
        "speedup": round(speedup, 2),
        "min_speedup": MIN_SPEEDUP,
        "ok": speedup >= MIN_SPEEDUP,
    }


def main() -> None:
    smoke = "--smoke" in sys.argv
    artifact = {
        "mode": "smoke" if smoke else "full",
        "devices": jax.local_device_count(),
        "equivalence": bench_equivalence(smoke),
        "compile_gate": bench_compile_gate(smoke),
        "speedup": bench_speedup(smoke),
    }
    with open(ARTIFACT, "w") as f:
        json.dump(artifact, f, indent=1)
        f.write("\n")

    eq, cg, sp = (artifact["equivalence"], artifact["compile_gate"],
                  artifact["speedup"])
    print(f"equivalence: healthy max diff {eq['healthy_max_diff']:.2e} "
          f"(tol {EQUIV_TOL}), faulted {eq['faulted_max_diff']:.2e}, "
          f"bit_exact_json={eq['bit_exact_json']} ok={eq['ok']}")
    print(f"compile gate: {cg['n_points']} points x {cg['n_windows']} "
          f"windows -> {cg['compiles']} fluid compiles "
          f"(limit {COMPILE_LIMIT}) ok={cg['ok']}")
    if sp.get("skipped"):
        print("speedup: skipped (--smoke)")
    else:
        print(f"speedup: batched {sp['batched_points_per_sec']} pts/s vs "
              f"scalar {sp['scalar_points_per_sec']} pts/s -> "
              f"{sp['speedup']}x (min {MIN_SPEEDUP}) ok={sp['ok']}")
    print(f"artifact: {ARTIFACT}")
    failures = [k for k in ("equivalence", "compile_gate", "speedup")
                if not artifact[k]["ok"]]
    if failures:
        raise SystemExit(f"bench_report gates failed: {failures}")


if __name__ == "__main__":
    main()
