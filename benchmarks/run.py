"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and writes them to repo-root
``BENCH_run.json`` (every benchmark artifact lands at the repo root as
``BENCH_<name>.json``). The dry-run / roofline cells (deliverables e+g) are
produced by ``python -m repro.launch.dryrun`` (long-running, writes
benchmarks/results/dryrun.json) and summarized here if that file exists.
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks import paper_tables as pt  # noqa: E402

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ARTIFACT = os.path.join(ROOT, "BENCH_run.json")


def _dryrun_summary() -> list[tuple]:
    path = os.path.join(os.path.dirname(__file__), "results", "dryrun.json")
    if not os.path.exists(path):
        return [("dryrun", 0.0, "not-run (python -m repro.launch.dryrun)")]
    with open(path) as f:
        d = json.load(f)
    ok = sum(1 for r in d.values() if r.get("status") == "ok")
    sk = sum(1 for r in d.values() if r.get("status") == "skipped")
    er = sum(1 for r in d.values() if r.get("status") == "error")
    rows = [("dryrun_cells", 0.0, f"ok={ok};skipped={sk};error={er}")]
    for k in sorted(d):
        r = d[k]
        if r.get("status") == "ok" and k.endswith("pod1"):
            rows.append((
                f"roofline_{k[:-5]}", 0.0,
                f"dom={r['dominant']};frac={r['roofline_frac']:.3f};"
                f"tc={r['t_compute_s']:.3g};tm={r['t_memory_s']:.3g};"
                f"tcoll={r['t_collective_s']:.3g}"))
    return rows


def _report_summary() -> list[tuple]:
    """Batched report pipeline gates (benchmarks/bench_report.py)."""
    path = os.path.join(ROOT, "BENCH_report.json")
    if not os.path.exists(path):
        return [("bench_report", 0.0,
                 "not-run (python benchmarks/bench_report.py)")]
    with open(path) as f:
        d = json.load(f)
    eq, cg, sp = d["equivalence"], d["compile_gate"], d["speedup"]
    rows = [(
        "report_equivalence", 0.0,
        f"healthy_max_diff={eq['healthy_max_diff']:.2e};"
        f"bit_exact={eq['bit_exact_json']};ok={eq['ok']}"),
        ("report_compile_gate", 0.0,
         f"points={cg['n_points']};compiles={cg['compiles']};"
         f"limit={cg['limit']};ok={cg['ok']}")]
    if sp.get("skipped"):
        rows.append(("report_speedup", 0.0, "skipped (smoke)"))
    else:
        rows.append((
            "report_speedup", 0.0,
            f"batched={sp['batched_points_per_sec']}pts/s;"
            f"scalar={sp['scalar_points_per_sec']}pts/s;"
            f"speedup={sp['speedup']}x;ok={sp['ok']}"))
    return rows


def _engine_summary() -> list[tuple]:
    """Fused cache-scan engine gates (benchmarks/bench_engine.py)."""
    path = os.path.join(ROOT, "BENCH_engine.json")
    if not os.path.exists(path):
        return [("bench_engine", 0.0,
                 "not-run (python benchmarks/bench_engine.py)")]
    with open(path) as f:
        d = json.load(f)
    eq, ip, cg, sp = (d["equivalence"], d["interpret_parity"],
                      d["compile_gate"], d["speedup"])
    rows = [(
        "engine_equivalence", 0.0,
        f"cases={eq['cases']};"
        f"mismatches={len(eq['mismatched_fields'])};ok={eq['ok']}"),
        ("engine_interpret_parity", 0.0,
         f"combos={ip['combos']};"
         f"mismatches={len(ip['mismatched_fields'])};ok={ip['ok']}"),
        ("engine_compile_gate", 0.0,
         f"points={cg['n_points']};compiles={cg['compiles']};"
         f"limit={cg['limit']};ok={cg['ok']}")]
    if sp.get("skipped"):
        rows.append(("engine_speedup", 0.0, "skipped (smoke)"))
    else:
        rows.append((
            "engine_speedup", 0.0,
            f"fused={sp['fused_points_per_sec']}pts/s;"
            f"scan={sp['scan_points_per_sec']}pts/s;"
            f"speedup={sp['speedup']}x;ok={sp['ok']}"))
    return rows


def main() -> None:
    rows: list[tuple] = []
    rows += pt.section_v_worked_example()
    rows += pt.tables_i_ii_nvme_models()
    rows += pt.tables_iii_iv_hdd_models()
    rows += pt.fig3_miss_rate_vs_cache_size()
    rows += pt.tables_v_vi_online_learning()
    rows += pt.tables_vii_ix_strong_scaling()
    rows += pt.fig10_read_throughput()
    rows += _report_summary()
    rows += _engine_summary()
    rows += _dryrun_summary()
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us},{derived}")
    artifact = [
        {"name": name, "us_per_call": us, "derived": derived}
        for name, us, derived in rows
    ]
    with open(ARTIFACT, "w") as f:
        json.dump(artifact, f, indent=1)
    print(f"artifact: {ARTIFACT}")


if __name__ == "__main__":
    main()
