"""Serve a small model with batched requests through the paged two-tier KV
cache: prefill -> decode, with the OL eviction learner + IO-thread-style
page promotion running between steps (paper fig. 2).

  PYTHONPATH=src python examples/serve_paged.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.archs import ARCHS
from repro.distributed.axes import SINGLE
from repro.models import params as pm
from repro.serving import kvpool as kvp
from repro.serving.engine import (
    ServeConfig, make_decode_step, make_kv_spec, make_prefill_step,
)

cfg = ARCHS["mixtral-8x22b"].reduced()  # SWA + MoE: windowed paged reads
ms = pm.MeshSizes()
params = pm.init_params(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(0)

B, S0, n_new = 4, 32, 32
sc = ServeConfig(max_seq=128, batch_local=B, page_axes=(),
                 hbm_fraction=0.4, n_promote=2)
spec = make_kv_spec(cfg, sc, 1)

prompts = rng.integers(0, cfg.vocab, (B, S0)).astype(np.int32)
prefill = jax.jit(make_prefill_step(cfg, sc, SINGLE, ms))
decode = jax.jit(make_decode_step(cfg, sc, SINGLE, ms))
promote = jax.jit(lambda kv: kvp.promote_pages(kv, spec, sc.n_promote))

print(f"prefill {B} requests x {S0} tokens ...")
state, (tok, lp) = prefill(params, jnp.asarray(prompts), {})
outs = [np.asarray(tok)]
for t in range(n_new - 1):
    # client-thread step (decode against the distributed tier-1 cache)
    state, (tok, lp) = decode(params, state, tok)
    outs.append(np.asarray(tok))
    # IO-thread step (promote hot tier-2 pages into free tier-1 slots)
    if t % 4 == 3:
        state = state._replace(kv=promote(state.kv))

kv = state.kv
total = int(kv.t1_reads[0]) + int(kv.t2_reads[0])
print(f"generated {n_new} tokens/request")
print(f"tier-1 hit rate: {int(kv.t1_reads[0])}/{total} = "
      f"{100*int(kv.t1_reads[0])/max(total,1):.1f}%")
print(f"OL weights (lru/lfu/random): {np.round(np.asarray(kv.ols.weights),3)}")
print(f"sequences now at length {np.asarray(kv.lengths)}")
print("serve_paged OK")
