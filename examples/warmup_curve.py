"""Time-resolved telemetry: warm-up curves and saturation onset.

  PYTHONPATH=src python examples/warmup_curve.py
  # or: python -m examples.warmup_curve

The paper analyzes the two-tier store at equilibrium (§V); this example
shows what that summary hides. ``SimSpec.n_windows`` resolves every engine
counter over time windows of the request stream and re-solves the queuing
network per window (piecewise-stationary transient analysis):

1. a cold cache warming up — early windows miss hard, the tail converges
   to the steady-state report;
2. a phased workload drifting into overload — the report pinpoints the
   saturation-onset window (first window with utilization >= 1).
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.traffic import TrafficSpec, phase_schedule
from repro.sim import RateSpec, SimSpec, simulate
from repro.storage.tiered_store import StoreConfig

print("=== 1. Cold-cache warm-up curve (markov traffic, LRU) ===")
spec = SimSpec(
    traffic=TrafficSpec(kind="markov", n_requests=4000, n_pages=256,
                        n_hot_states=24, seed=5),
    store=StoreConfig(n_lines=64, policy="lru"),
    n_shards=2,
    mapping="block_cyclic",
    lam=40.0,
    rates=RateSpec(source="paper"),
    n_windows=10,
)
rep = simulate(spec)
print(f"  {rep.requests} requests in {rep.n_windows} windows of "
      f"{rep.window_duration_s:.2f}s")
print(f"  {'window':>7} {'p12':>7} {'rho2':>7} {'response_ms':>12}")
for w in range(rep.n_windows):
    print(f"  {w:>7} {rep.transient.p12[w]:>7.3f} "
          f"{rep.transient.rho2[w]:>7.3f} "
          f"{rep.transient.response[w]*1e3:>12.3f}")
print(f"  steady-state report (whole stream): p12={rep.p12:.3f} "
      f"response={rep.response_s*1e3:.3f} ms")
print(f"  -> cold start misses {rep.transient.p12[0]/rep.transient.p12[-1]:.1f}x "
      f"harder than the warmed-up tail")

print("\n=== 2. Saturation onset: a warm phase, then a flood ===")
warm = TrafficSpec(kind="strided", n_requests=800, n_pages=64, stride=1,
                   seed=1)
flood = TrafficSpec(kind="irm", n_requests=800, n_pages=4096, zipf_s=0.8,
                    seed=2)
drift = simulate(SimSpec(
    traffic=phase_schedule(warm, flood),
    store=StoreConfig(n_lines=64, policy="lru"),
    n_shards=2,
    mapping="block_cyclic",
    lam=50.0,
    rates=RateSpec(source="paper"),
    n_windows=8,
))
print(f"  phase boundary at window {drift.n_windows // 2}; "
      f"measured rho2 per window:")
print("  " + "  ".join(f"{v:.2f}" for v in np.asarray(drift.transient.rho2)))
print(f"  equilibrium (whole-stream view): {drift.equilibrium}")
print(f"  saturation onset: window {drift.saturation_onset} "
      f"(first window with rho >= 1)")
onsets = [s.saturation_onset for s in drift.shards]
print(f"  per-shard onsets (mapping skew included): {onsets}")

print("\n=== 3. Checkpoint bursts (on/off modulation) ===")
bursty = simulate(SimSpec(
    traffic=TrafficSpec(kind="onoff", n_requests=1600, n_pages=512,
                        on_len=100, off_len=300, burst_pages=16, seed=3),
    store=StoreConfig(n_lines=16, policy="lru"),
    n_shards=2,
    mapping="block_cyclic",
    lam=30.0,
    rates=RateSpec(source="paper"),
    n_windows=8,
))
t2w = np.asarray(bursty.windows.tier2_writes).sum(axis=0)
print(f"  tier-2 write-backs per window: {t2w.tolist()} "
      f"(dirty checkpoint pages flushed after each burst)")
print(f"  p12 per window: "
      + " ".join(f"{v:.2f}" for v in np.asarray(bursty.transient.p12)))
