"""Quickstart: the paper's pieces in 60 seconds on CPU.

  PYTHONPATH=src python examples/quickstart.py

1. Runs the two-tier store on Poisson + IRM traffic and shows the OL
   weight-sharing policy tracking the best expert (Tables V/VI).
2. Analyzes a two-tier configuration with the queuing network (§V).
3. Takes one training step of a reduced LM through the SPMD train step.
4. Decodes a few tokens through the paged two-tier KV cache.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.archs import ARCHS
from repro.core.queuing import TwoTierModel
from repro.core.traffic import irm_stream, poisson_stream
from repro.distributed.axes import SINGLE
from repro.models import params as pm
from repro.serving.engine import ServeConfig, init_decode_state, make_decode_step
from repro.storage.tiered_store import StoreConfig, run_stream
from repro.training.compression import init_error_feedback
from repro.training.optimizer import adamw_init
from repro.training.train_step import TrainHyper, TrainState, make_train_step

print("=== 1. OL cache replacement (paper Tables V/VI) ===")
for kind, gen in (("poisson", poisson_stream), ("irm", irm_stream)):
    pages, writes = gen(2000, 256, seed=1)
    row = {}
    for pol in ("lru", "lfu", "ws"):
        st = run_stream(StoreConfig(n_lines=64, policy=pol), pages, writes)
        row[pol] = int(st.misses)
    print(f"  {kind:8s} misses: lru={row['lru']} lfu={row['lfu']} "
          f"ws={row['ws']}  (WS tracks the best expert)")

print("\n=== 2. Queuing network (§V worked example) ===")
m = TwoTierModel(lam=100, mu1=1000, mu2=33, p12=0.2, k=1)
s = m.analyze().summary()
print(f"  lam_eff={s['lam_eff']:.1f} rho1={s['rho1']:.4f} "
      f"rho2={s['rho2']:.3f} equilibrium={bool(s['equilibrium'])}")

print("\n=== 3. One SPMD train step (reduced stablelm-3b) ===")
cfg = ARCHS["stablelm-3b"].reduced()
params = pm.init_params(cfg, jax.random.PRNGKey(0))
state = TrainState(params, adamw_init(params, cfg.opt_state_dtype),
                   init_error_feedback(params))
step = jax.jit(make_train_step(cfg, SINGLE, pm.MeshSizes(), TrainHyper()))
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 64)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab, (2, 64)), jnp.int32)}
state, metrics = step(state, batch)
print(f"  loss={float(metrics['loss']):.4f} "
      f"grad_norm={float(metrics['grad_norm']):.3f}")

print("\n=== 4. Paged two-tier decode (tier-1 evictions live) ===")
sc = ServeConfig(max_seq=64, batch_local=2, page_axes=(), hbm_fraction=0.5)
dstate = init_decode_state(cfg, sc, SINGLE, pm.MeshSizes())
dstep = jax.jit(make_decode_step(cfg, sc, SINGLE, pm.MeshSizes()))
tok = jnp.asarray(rng.integers(0, cfg.vocab, (2,)), jnp.int32)
for t in range(24):
    dstate, (tok, lp) = dstep(state.params, dstate, tok)
kv = dstate.kv
print(f"  decoded 24 tokens; tier-1 page reads={int(kv.t1_reads[0])} "
      f"tier-2 (miss) reads={int(kv.t2_reads[0])}")
print(f"  OL expert weights (lru/lfu/random): "
      f"{np.round(np.asarray(kv.ols.weights), 3)}")
print("\nquickstart OK")
