"""Chunked streaming replay: multi-tenant workload, bounded device memory,
checkpoint/resume.

  PYTHONPATH=src python examples/stream_replay.py
  # or: python -m examples.stream_replay

Two tenants (an OLTP service and an analytics scanner) share one tiered
store. The trace is never materialized on device: ``simulate_stream``
generates it chunk-by-chunk on the host, feeds each chunk through the
resumable chunk engine (donated buffers, one compiled engine for every
chunk), and carries the cache state, windowed counters and fluid queue
backlog across chunk boundaries. The report is bit-identical to a one-shot
replay of the same merged stream — plus per-tenant attribution.

The second half pauses the replay mid-stream (``max_requests``), inspects
the partial report, and resumes from the checkpoint with a *different*
chunk size; the final report is identical to the uninterrupted run.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.traffic import TenantSpec, tenant_mix
from repro.sim import SimSpec, simulate_stream
from repro.storage.tiered_store import StoreConfig

mix = tenant_mix(
    TenantSpec(name="oltp", rate=600.0, n_pages=1024, zipf_s=1.3,
               write_fraction=0.4),
    TenantSpec(name="analytics", rate=200.0, n_pages=4096, zipf_s=0.9,
               seed=1),
    n_requests=60_000, seed=7,
)
spec = SimSpec(
    traffic=mix,
    store=StoreConfig(n_lines=256, policy="ws"),
    n_shards=4,
    window_dt=2.0,
)

rep = simulate_stream(spec, chunk=8192)
print(f"streamed {rep.requests} requests in chunks of 8192 "
      f"({rep.n_windows} wall-clock windows)")
print(f"pooled miss rate {rep.miss_rate:.3f}, "
      f"expected response {rep.response_s * 1e3:.2f} ms")
for t in rep.tenants:
    print(f"  tenant {t.name:>9}: {t.requests:6d} req, "
          f"miss rate {t.miss_rate:.3f}, "
          f"mean response {t.mean_response_s * 1e3:.2f} ms")

# -- pause mid-stream, then resume with a different chunk size ------------
partial, ck = simulate_stream(spec, chunk=8192, max_requests=25_000)
print(f"\npaused at {ck.offset}/{ck.total} requests "
      f"(partial miss rate {partial.miss_rate:.3f}); resuming...")
resumed = simulate_stream(spec, chunk=4096, checkpoint=ck)
same = resumed.to_dict() == rep.to_dict()
print(f"resumed report bit-identical to uninterrupted run: {same}")
assert same
