"""Use the performance models to *configure* a two-tier system (§VII):
given a workload and a target arrival rate, sweep (cache size x IO threads)
through the miss-rate curve + queuing network, and print the equilibrium
frontier.

  PYTHONPATH=src python examples/configure_from_model.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.configurator import configure, miss_rate_curve
from repro.core.traffic import TrafficSpec

spec = TrafficSpec(kind="irm", n_requests=2000, n_pages=512, seed=0)

print("miss-rate curve (Fig. 3 machinery):")
for n, mr in miss_rate_curve(spec, (32, 64, 128, 256)):
    print(f"  cache={n:4d} lines  miss_rate={mr:.3f}")

print("\nconfiguration sweep @ arrival 200 req/s (queuing + device models):")
cands = configure(spec, arrival_rate=200.0,
                  cache_sizes=(32, 64, 128, 256), k_threads=(1, 4, 16))
print(f"  {'lines':>6} {'k':>3} {'miss':>6} {'rho1':>6} {'rho2':>6} "
      f"{'eq':>3} {'T_pred(s)':>10}")
for c in cands[:8]:
    print(f"  {c.n_lines:6d} {c.k_threads:3d} {c.miss_rate:6.3f} "
          f"{c.rho1:6.3f} {c.rho2:6.3f} {str(c.equilibrium)[:1]:>3} "
          f"{c.predicted_time_s:10.2f}")
best = cands[0]
print(f"\nchosen: {best.n_lines} lines x {best.k_threads} threads "
      f"(miss {best.miss_rate:.3f}, predicted {best.predicted_time_s:.2f}s)")
print("configure_from_model OK")
