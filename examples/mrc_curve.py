"""The whole miss-rate-vs-cache-size curve from one pass (paper §V's
capacity-planning question, answered without a per-size sweep).

  PYTHONPATH=src python examples/mrc_curve.py
  # or: python -m examples.mrc_curve

``store.n_lines`` is *structural* to the scan engine — every cache size
costs a fresh compile and a fresh pass over the stream. For LRU the
Mattson stack-distance result collapses that loop: one reuse-distance
pass (``repro.kernels.reuse_distance``) yields exact hit/miss/write-back
counters for **every** size at once (``repro.sim.mrc``), and
``sweep(mrc="auto")`` routes size-only axes through it automatically.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core.traffic import TrafficSpec  # noqa: E402
from repro.sim import (  # noqa: E402
    RateSpec,
    SimSpec,
    mrc_curve,
    simulate,
    sweep,
)
from repro.sim.engine import tier1_counters  # noqa: E402
from repro.sim.sweep import (  # noqa: E402
    engine_compile_count,
    reset_engine_compile_count,
)
from repro.storage.tiered_store import StoreConfig  # noqa: E402

# The §V workload, under the LRU expert (the stack-distance domain).
spec = SimSpec(
    traffic=TrafficSpec(kind="irm", n_requests=4000, n_pages=1024,
                        write_fraction=0.3, seed=7),
    store=StoreConfig(n_lines=128, policy="lru"),
    n_shards=4,
    mapping="block",
    lam=200.0,
)

print("=== 1. The full miss-rate curve from one distance pass ===")
sizes = sorted(int(s) for s in
               np.unique(np.round(np.geomspace(1, 2048, 40)).astype(int)))
sz, mr = mrc_curve(spec, sizes)
print(f"  {len(sz)} cache sizes, one pass, no cache simulation:")
step = max(1, len(sz) // 10)
for c, r in list(zip(sz, mr))[::step]:
    bar = "#" * int(r * 40)
    print(f"  n_lines={c:>5}  miss_rate={r:.3f}  {bar}")

print("\n=== 2. Exactness: the paper's cache size, engine vs MRC ===")
from repro.sim import mrc_tier1_counters  # noqa: E402
C = spec.store.n_lines
eng = tier1_counters(spec)
one = mrc_tier1_counters(spec, [C])[C]
same = all(
    np.array_equal(np.asarray(getattr(one, f)), np.asarray(getattr(eng, f)))
    for f in eng._fields)
print(f"  n_lines={C}: all Tier1Counters fields bit-identical "
      f"to the scan engine: {same}")
print(f"  hits={int(one.hits.sum())} misses={int(one.misses.sum())} "
      f"tier2_writes={int(one.tier2_writes.sum())} "
      f"evictions={int(one.evictions.sum())}")

print("\n=== 3. §V worked example at its cache size, via the MRC path ===")
worked_spec = spec.replace(
    lam=100.0, rates=RateSpec(source="paper"), p12_override=0.2)
reset_engine_compile_count()
res = sweep(worked_spec,
            {"store.n_lines": [32, 64, 128, 256, 512, 1024]})
print(f"  6-size capacity sweep: {engine_compile_count()} engine compiles "
      f"(the curve rode the distance pass)")
print(f"  {'n_lines':>8} {'miss_rate':>10} {'lam_eff':>8} {'response_ms':>12}")
for row in res.rows():
    print(f"  {row['store.n_lines']:>8} {row['miss_rate']:>10.3f} "
          f"{row['lam_eff']:>8.1f} {row['response_s']*1e3:>12.3f}")
worked = simulate(worked_spec)
at_128 = next(r for r in res.rows() if r["store.n_lines"] == 128)
print(f"  at the paper's n_lines=128: lam_eff={at_128['lam_eff']:.1f} "
      f"(direct simulate(): {worked.lam_eff:.1f}, published: 86.6)")
