"""End-to-end tiered-storage simulation in one call (paper §V, composed).

  PYTHONPATH=src python examples/end_to_end.py
  # or: python -m examples.end_to_end

Walks the full pipeline the paper assembles by hand: a declarative
workload flows through the distributed tier-1 cache shards, the measured
miss/write-back counters become queuing-network inputs, and device
behavioral models supply the service rates. Then sweeps cache size to
show the capacity-planning use case.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.traffic import TrafficSpec
from repro.sim import RateSpec, SimSpec, simulate, sweep
from repro.storage.tiered_store import StoreConfig

print("=== 1. One scenario end to end (fitted device rates) ===")
spec = SimSpec(
    traffic=TrafficSpec(kind="irm", n_requests=4000, n_pages=1024,
                        write_fraction=0.3, seed=7),
    store=StoreConfig(n_lines=128, policy="ws"),
    n_shards=4,
    mapping="block",
    lam=200.0,
)
rep = simulate(spec)
print(f"  {rep.requests} requests over {spec.n_shards} shards "
      f"({spec.mapping} mapping, {spec.store.policy} policy)")
print(f"  miss_rate={rep.miss_rate:.3f}  tier2: {rep.tier2_reads} reads, "
      f"{rep.tier2_writes} write-backs, {rep.evictions} evictions")
print(f"  mu1={rep.rates.mu1:.0f}/s mu2={rep.rates.mu2:.1f}/s "
      f"(fitted NVMe/HDD behavioral models)")
print(f"  queuing: lam_eff={rep.lam_eff:.1f} rho1={rep.rho1:.4f} "
      f"rho2={rep.rho2:.3f} response={rep.response_s*1e3:.3f} ms "
      f"equilibrium={rep.equilibrium}")
print(f"  min-time model (eqs 1-4): T={rep.t_total_s:.4f}s -> "
      f"{rep.min_time_throughput_rps:.0f} req/s")
for s in rep.shards:
    print(f"    shard {s.shard}: {s.requests:5d} reqs p12={s.p12:.3f} "
          f"w1={s.w1*1e3:.3f}ms w2={s.w2*1e3:.2f}ms")

print("\n=== 2. The §V worked example through the same pipeline ===")
worked = simulate(spec.replace(
    lam=100.0, rates=RateSpec(source="paper"), p12_override=0.2))
print(f"  paper constants mu1=1000 mu2=33, p12 pinned to 0.2:")
print(f"  lam_eff={worked.lam_eff:.1f} (published: 86.6) "
      f"rho1={worked.rho1:.4f} rho2={worked.rho2:.3f}")

print("\n=== 3. Capacity planning: sweep cache size x policy ===")
res = sweep(spec.replace(lam=100.0),
            {"store.n_lines": [32, 128, 512],
             "store.policy": ["lru", "ws"]})
print(f"  {'n_lines':>8} {'policy':>7} {'miss_rate':>10} {'response_ms':>12}")
for row in res.rows():
    print(f"  {row['store.n_lines']:>8} {row['store.policy']:>7} "
          f"{row['miss_rate']:>10.3f} {row['response_s']*1e3:>12.3f}")
best = min(res.rows(), key=lambda r: r["response_s"])
print(f"  -> best response: n_lines={best['store.n_lines']} "
      f"policy={best['store.policy']}")
