"""End-to-end training driver (deliverable b): a ~100M-parameter LM trained
through the tiered data pipeline with two-tier checkpointing and a restart
drill.

Default is a fast CI-sized run; for the full ~100M / few-hundred-step run:

  PYTHONPATH=src python examples/train_tiered.py --full

(on this 1-core CPU container the full run takes hours — the same driver
scales to the production mesh via launch/spmd.build_train_step.)
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import run_training
from repro.training.checkpoint import CheckpointConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="~100M params, 200 steps (hours on CPU)")
    ap.add_argument("--steps", type=int, default=0)
    args = ap.parse_args()

    if args.full:
        d_model, steps, batch, seq = 640, args.steps or 200, 8, 256
    else:
        d_model, steps, batch, seq = 128, args.steps or 30, 4, 64

    ck = CheckpointConfig(dir_tier1="ckpt/fast", dir_tier2="ckpt/durable",
                          tier1_every=10, tier2_every=50)
    out = run_training(
        arch="stablelm-3b", reduced=True, steps=steps, batch=batch, seq=seq,
        d_model_override=d_model, ckpt=ck, resume=True, lr=1e-3,
    )
    print(f"\nparams={out['n_params']/1e6:.1f}M "
          f"final_loss={out['final_loss']:.4f} "
          f"steps/s={out['steps_per_s']:.2f} "
          f"data-cache hits={out['cache_hits']} misses={out['cache_misses']}")


if __name__ == "__main__":
    main()
