"""Burst response: MMPP checkpoint bursts through the fluid transient path.

  PYTHONPATH=src python examples/burst_response.py
  # or: python -m examples.burst_response

An on/off (MMPP-style) workload alternates Zipf-read background traffic
with checkpoint write bursts arriving 10x faster. With wall-clock windows
(``SimSpec.window_dt``) the per-window arrival rate is *measured* from the
arrival timestamps, and the default fluid transient solver carries queue
backlog across windows — so the report shows what the burst actually does
to latency: a peak during the burst and a multi-window drain after it,
where the window-independent piecewise solve snaps back instantly.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.traffic import TrafficSpec
from repro.sim import RateSpec, SimSpec, simulate
from repro.storage.tiered_store import StoreConfig

spec = SimSpec(
    traffic=TrafficSpec(
        kind="onoff", n_requests=6000, n_pages=512,
        rate=120.0,          # background arrival rate (aggregate req/s)
        burst_rate=1200.0,   # checkpoint stripes stream 10x faster
        on_len=300, off_len=1700,
        burst_pages=256,     # working set >> cache: bursts miss hard
        seed=4,
    ),
    store=StoreConfig(n_lines=64, policy="lru"),
    n_shards=2,
    mapping="block_cyclic",
    lam=60.0,
    rates=RateSpec(source="paper"),
    window_dt=2.0,           # wall-clock bins; window count derived
)

fluid = simulate(spec)
piecewise = simulate(spec.replace(transient_mode="piecewise"))

lam_w = np.asarray(fluid.windows.lam).sum(axis=0) / spec.n_shards
resp_fl = np.asarray(fluid.transient.response) * 1e3
resp_pw = np.asarray(piecewise.transient.response) * 1e3
q2 = np.asarray(fluid.transient.q2)

print(f"=== MMPP checkpoint bursts, {fluid.n_windows} windows of "
      f"{fluid.window_duration_s:.1f}s ===")
print(f"  {'win':>4} {'lam_meas':>9} {'p12':>6} {'q2':>7} "
      f"{'fluid_ms':>9} {'piecewise_ms':>13}")
for w in range(fluid.n_windows):
    pw_ms = f"{resp_pw[w]:13.3f}" if np.isfinite(resp_pw[w]) else (
        " " * 9 + "inf ")
    print(f"  {w:>4} {lam_w[w]:>9.1f} {fluid.transient.p12[w]:>6.3f} "
          f"{q2[w]:>7.2f} {resp_fl[w]:>9.3f} {pw_ms}")

# Burst windows: measured rate well above background.
background = np.median(lam_w)
burst_wins = np.flatnonzero(lam_w > 1.5 * background)
peak = int(np.argmax(resp_fl))
print(f"\n  background rate ~{background:.0f} req/s; burst windows "
      f"{burst_wins.tolist()} (measured from timestamps, not assumed)")
print(f"  peak latency: fluid {resp_fl[peak]:.2f} ms at window {peak} "
      f"(piecewise: {'inf' if not np.isfinite(resp_pw[peak]) else f'{resp_pw[peak]:.2f} ms'})")

# Time-to-drain: windows after the first burst until the fluid response is
# back within 25% of the calm baseline. The piecewise model by construction
# drains in 0 windows — queue state does not carry over.
calm = np.median(resp_fl[np.isfinite(resp_pw)])
first_burst = int(burst_wins.min()) if burst_wins.size else 0
drain = 0
for w in range(first_burst + 1, fluid.n_windows):
    if resp_fl[w] <= 1.25 * calm:
        break
    drain += 1
print(f"  time-to-drain after the first burst: fluid {drain} windows "
      f"({drain * fluid.window_duration_s:.0f}s of elevated latency, "
      f"backlog draining at tier-2 capacity); piecewise 0 windows "
      f"(snaps back by construction)")
print(f"  saturation onset (offered rate >= capacity): "
      f"window {fluid.saturation_onset}")
