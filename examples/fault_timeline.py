"""Fault timeline: a shard dies, traffic fails over, the cache re-warms —
and the retry policy decides whether the cluster recovers at all.

  PYTHONPATH=src python examples/fault_timeline.py
  # or: python -m examples.fault_timeline

Act 1 walks one outage through the engine: shard 1 goes down for three
seconds, its key range fails over to survivors (deterministic cyclic
remap, so the same keys land on the same survivor), and on recovery the
shard re-warms from a cold cache — post-recovery windows show the miss
spike that tier 2 has to absorb.

Act 2 replays the same degraded interval under two client retry policies
with the *same* retry budget. Hot timeouts with no backoff re-offer
timed-out work immediately: the queue never drains and the solve flags a
trailing metastable run (a retry storm — the system would be stable
without the feedback). Capped exponential backoff spreads the re-offers
and the backlog drains within a few windows of recovery.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.traffic import TrafficSpec
from repro.sim import (
    FaultSpec,
    RateSpec,
    RetryPolicy,
    SimSpec,
    shard_down,
    simulate,
)
from repro.storage.tiered_store import StoreConfig

OUTAGE = (3.0, 6.0)  # shard 1 down over [3s, 6s)

base = SimSpec(
    traffic=TrafficSpec(kind="irm", n_requests=2400, n_pages=256,
                        zipf_s=0.9, seed=11, rate=160.0),
    store=StoreConfig(n_lines=64, policy="lru"),
    n_shards=4,
    lam=40.0,
    rates=RateSpec(mu1=100.0, mu2=33.0),
    p12_override=0.15,
    window_dt=1.0,
)

# --- Act 1: outage, failover, cold-cache recovery -------------------------
healthy = simulate(base)
faulted = simulate(base.replace(
    faults=FaultSpec(events=(shard_down(1, *OUTAGE),))))

req_h = np.asarray(healthy.windows.requests)
req_f = np.asarray(faulted.windows.requests)
mis_h = np.asarray(healthy.windows.misses)
mis_f = np.asarray(faulted.windows.misses)

print(f"=== shard 1 down over [{OUTAGE[0]:.0f}s, {OUTAGE[1]:.0f}s), "
      f"{faulted.n_windows} windows of {faulted.window_duration_s:.0f}s ===")
print(f"  {'win':>4} {'shard1_req':>11} {'survivors_req':>14} "
      f"{'shard1_miss':>12} {'note'}")
for w in range(faulted.n_windows):
    t0, t1 = w * 1.0, (w + 1) * 1.0
    note = ""
    if t0 >= OUTAGE[0] and t1 <= OUTAGE[1]:
        note = "down -> failover"
    elif t0 >= OUTAGE[1] and mis_f[1, w] > mis_h[1, w]:
        note = "cold-cache refill"
    surv = int(req_f[0, w] + req_f[2, w] + req_f[3, w])
    print(f"  {w:>4} {int(req_f[1, w]):>11} {surv:>14} "
          f"{int(mis_f[1, w]):>12} {note}")

down_w = slice(int(OUTAGE[0]), int(OUTAGE[1]))
moved = int(req_h[1, down_w].sum())
extra_miss = int(faulted.misses - healthy.misses)
print(f"\nfailover moved {moved} requests off shard 1 "
      f"(per-window totals conserved: "
      f"{bool((req_f.sum(0) == req_h.sum(0)).all())}); "
      f"re-warming after recovery cost {extra_miss} extra misses "
      f"served from tier 2.")

# --- Act 2: same outage, two retry policies -------------------------------
# Degrade all tier-1 devices harder + a burst of external load so the
# outage leaves real backlog, then compare retry policies on the drain.
aggressive = RetryPolicy(timeout=0.2, max_retries=4,
                         backoff_base=1.0, backoff_init=0.2)
capped = RetryPolicy(timeout=0.2, max_retries=4,
                     backoff_base=4.0, backoff_init=0.5, backoff_cap=8.0)

from repro.core.queuing import fluid_two_tier  # noqa: E402

lam_t = np.array([30.0] * 4 + [130.0] * 2 + [30.0] * 18)
p12_t = np.full_like(lam_t, 0.1)
print("\n=== same burst, two retry policies (mu1=100/s, k=1) ===")
print(f"  {'win':>4} {'lam_ext':>8} {'q1_aggressive':>14} "
      f"{'q1_capped':>10} {'q1_no_retry':>12}")
agg = fluid_two_tier(lam_t, p12_t, 100.0, 33.0, dt=1.0, retry=aggressive)
cap = fluid_two_tier(lam_t, p12_t, 100.0, 33.0, dt=1.0, retry=capped)
non = fluid_two_tier(lam_t, p12_t, 100.0, 33.0, dt=1.0)
for w in range(0, len(lam_t), 2):
    print(f"  {w:>4} {lam_t[w]:>8.0f} {agg.q1[w]:>14.2f} "
          f"{cap.q1[w]:>10.2f} {non.q1[w]:>12.2f}")

agg_on = int(agg.metastable_onset())
cap_on = int(cap.metastable_onset())
print(f"\naggressive policy: metastable from window {agg_on} — external "
      f"load is back to {lam_t[-1]:.0f}/s (< capacity 100/s) but retries "
      f"re-offer {float(agg.retry_rate[-1]):.0f}/s on top, so the queue "
      f"never drains (a retry storm).")
print(f"capped backoff: metastable onset {cap_on} (never) — backlog "
      f"drains to q1={float(cap.q1[-1]):.2f} within a few windows; "
      f"time-to-recovery is set by the drain rate, not the retry rate.")
assert agg_on >= 0 and cap_on == -1
