"""Subprocess body for the multi-device sweep equivalence test: with the
point axis sharded over >1 (forced host) devices, the megabatched sweep must
match the unbatched reference exactly. Run with XLA_FLAGS containing
--xla_force_host_platform_device_count=2 (set by the pytest wrapper)."""
import jax

from repro.core.traffic import TrafficSpec
from repro.sim import RateSpec, SimSpec, sweep
from repro.storage.tiered_store import StoreConfig

assert jax.local_device_count() > 1, "host device forcing did not take"

base = SimSpec(
    traffic=TrafficSpec(kind="irm", n_requests=400, n_pages=128,
                        write_fraction=0.2, seed=9),
    store=StoreConfig(n_lines=16, policy="ws"),
    n_shards=2,
    lam=20.0,
    rates=RateSpec(source="paper"),
)
# 3 points: an odd count forces point-axis padding up to the device multiple.
axes = {"store.policy": ["ws", "lru", "lfu"]}
a = sweep(base, axes, batch=True)
b = sweep(base, axes, batch=False)
for pt, ra, rb in zip(a.points, a.reports, b.reports):
    for name in ("requests", "hits", "misses", "tier2_reads",
                 "tier2_writes", "evictions"):
        av, bv = getattr(ra, name), getattr(rb, name)
        assert av == bv, (pt, name, av, bv)

print("MULTIDEVICE SWEEP OK")
