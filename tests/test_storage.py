"""Tier-1/tier-2 store engine: invariants + paper Tables V/VI behavior."""
import jax
import numpy as np
import pytest

from repro.core.traffic import irm_stream, poisson_stream, strided_stream
from repro.storage.tiered_store import StoreConfig, run_stream


def _run(pages, writes, **kw):
    return run_stream(StoreConfig(**kw), pages, writes)


def test_counters_consistent():
    pages, writes = poisson_stream(800, 128, seed=3, write_fraction=0.3)
    st = _run(pages, writes, n_lines=32, policy="ws")
    assert int(st.hits) + int(st.misses) == 800
    # misses = evictions + initial fills (inclusive cache, fixed capacity)
    assert int(st.evictions) == int(st.misses) - 32
    # write-backs cannot exceed evictions
    assert int(st.tier2_writes) <= int(st.evictions)
    # every non-promoted miss is a tier-2 read
    assert int(st.tier2_reads) >= int(st.misses) - int(st.prefetch_hits)


def test_cache_hit_when_capacity_sufficient():
    # Working set smaller than the cache => only cold misses.
    pages = np.tile(np.arange(16, dtype=np.int32), 50)
    writes = np.zeros_like(pages, dtype=bool)
    st = _run(pages, writes, n_lines=32, policy="lru")
    assert int(st.misses) == 16


def test_poisson_ws_tracks_lru():
    """Table V: on slow-evolving Poisson traffic WS ~ LRU << LFU."""
    pages, writes = poisson_stream(2500, 256, seed=1)
    res = {
        p: int(_run(pages, writes, n_lines=64, policy=p).misses)
        for p in ("lru", "lfu", "ws")
    }
    assert res["lru"] < res["lfu"]
    assert res["ws"] <= 1.3 * res["lru"]


def test_irm_ws_tracks_lfu():
    """Table VI: on IRM traffic WS ~ LFU (within a small factor)."""
    pages, writes = irm_stream(2500, 256, seed=1)
    res = {
        p: int(_run(pages, writes, n_lines=64, policy=p).misses)
        for p in ("lru", "lfu", "ws")
    }
    assert res["ws"] <= 1.15 * min(res["lru"], res["lfu"])


def test_prefetcher_cuts_tier2_reads_on_strided():
    pages, writes = strided_stream(600, 4096, stride=1, seed=0)
    base = _run(pages, writes, n_lines=32, policy="lru", prefetch=False)
    pf = _run(pages, writes, n_lines=32, policy="lru", prefetch=True,
              prefetch_width=4, prefetch_buf=16)
    assert int(pf.prefetch_hits) > 0
    assert int(pf.misses) <= int(base.misses)


def test_distributed_partitions_all_requests():
    from repro.storage.tiered_store import run_distributed

    pages, writes = poisson_stream(1000, 256, seed=2)
    stats, counts = run_distributed(
        StoreConfig(n_lines=32), np.asarray(pages), np.asarray(writes),
        n_shards=4, mapping="block_cyclic", n_pages=256,
    )
    assert counts.sum() == 1000
    assert np.asarray(stats.misses).shape == (4,)
