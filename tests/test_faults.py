"""Fault-injection timeline: degraded-mode solves, retry storms, failover
remap, cold-cache refill and fault-grid sweeps (one compile)."""
import json
import warnings

import numpy as np
import pytest

from repro.core.queuing import FluidReport, RetryPolicy, fluid_two_tier
from repro.core.mapping import apply_failover
from repro.core.traffic import TrafficSpec
from repro.sim import (
    FaultSpec,
    RateSpec,
    SimSpec,
    device_degrade,
    engine_compile_count,
    reset_engine_compile_count,
    shard_down,
    simulate,
    sweep,
    tier2_outage,
)

MU1, MU2 = 100.0, 33.0


def _timed_spec(**kw):
    base = dict(
        traffic=TrafficSpec(kind="irm", n_requests=1500, n_pages=256,
                            zipf_s=0.8, seed=7, rate=100.0),
        n_shards=4,
        lam=25.0,
        rates=RateSpec(mu1=MU1, mu2=MU2),
        p12_override=0.2,
        window_dt=1.0,
        transient_mode="fluid",
    )
    base.update(kw)
    return SimSpec(**base)


# --- degraded-mode fluid solves (queuing level) ---------------------------

def test_degraded_interval_matches_stationary():
    """A long constant-degraded interval converges to the stationary solve
    at the degraded rate; pre-fault windows are bit-exact vs the no-fault
    fluid path (carryover only flows forward)."""
    n = 40
    lam = np.full(n, 30.0)
    p12 = np.full(n, 0.1)
    mu1 = np.full(n, MU1)
    mu1_deg = mu1.copy()
    mu1_deg[10:] = 0.5 * MU1  # degraded from t=10 onwards
    base = fluid_two_tier(lam, p12, mu1, MU2, dt=1.0)
    deg = fluid_two_tier(lam, p12, mu1_deg, MU2, dt=1.0)
    # Healthy prefix: byte-identical to the no-fault solve.
    for name in ("q1", "q2", "w1", "w2", "rho1", "rho2", "response"):
        a, b = getattr(base, name), getattr(deg, name)
        assert np.array_equal(a[:10], b[:10]), name
    # Degraded tail: converged to the stationary network at mu1/2 — which
    # is exactly what a fluid solve running at the degraded rate from the
    # start settles into.
    ref = fluid_two_tier(lam, p12, np.full(n, 0.5 * MU1), MU2, dt=1.0)
    np.testing.assert_allclose(deg.w1[-1], ref.w1[-1], rtol=1e-9)
    np.testing.assert_allclose(deg.q1[-1], ref.q1[-1], rtol=1e-9)
    assert deg.w1[-1] > base.w1[-1]  # degraded device is slower


def test_dead_device_backlog_grows_cleanly():
    """mu -> 0 with offered load: backlog grows linearly, w1 = inf only in
    the stationary sense — and no runtime warnings leak out."""
    n = 6
    lam = np.full(n, 20.0)
    p12 = np.zeros(n)
    mu1 = np.zeros(n)  # dead the whole time
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        rep = fluid_two_tier(lam, p12, mu1, MU2, dt=1.0)
    assert not rep.stable.any()
    assert np.all(np.diff(rep.q1) > 0)  # strictly growing backlog
    # No service at all: window-mean backlog ~ lam * t (midpoint rule).
    np.testing.assert_allclose(rep.q1[-1], 20.0 * (n - 0.5), rtol=1e-6)
    assert np.all(np.isinf(rep.w1))


def test_recovery_from_saturation_drains():
    """Outage ends -> the accumulated backlog drains at capacity and the
    final windows return to the healthy stationary point."""
    n = 30
    lam = np.full(n, 30.0)
    p12 = np.full(n, 0.1)
    mu1 = np.full(n, MU1)
    mu1[2:6] = 0.0  # dead for 4 windows
    base = fluid_two_tier(lam, p12, np.full(n, MU1), MU2, dt=1.0)
    rep = fluid_two_tier(lam, p12, mu1, MU2, dt=1.0)
    peak = rep.q1[2:6].max()
    assert peak > 50.0  # outage piled up real backlog
    assert rep.q1[-1] < 1.0  # ... which fully drained
    np.testing.assert_allclose(rep.w1[-1], base.w1[-1], rtol=1e-6)
    assert rep.stable[-1]


def test_zero_traffic_adjacent_to_overload():
    """Idle windows bracketing a hard overload: no NaNs, correct stability
    flags, and the backlog drains during the idle tail."""
    lam = np.array([0.0, 0.0, 200.0, 200.0, 0.0, 0.0, 0.0, 0.0])
    p12 = np.zeros_like(lam)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        rep = fluid_two_tier(lam, p12, MU1, MU2, dt=1.0)
    assert list(rep.stable) == [True, True, False, False,
                                True, True, True, True]
    assert np.isfinite(rep.q1).all() and np.isfinite(rep.response).all()
    assert rep.q1[2:4].max() > 50.0
    assert rep.q1[-1] < 1.0


# --- retry storms (queuing level) ----------------------------------------

STORM_LAM = np.array([30.0] * 4 + [130.0] * 2 + [30.0] * 18)
AGGRESSIVE = RetryPolicy(timeout=0.2, max_retries=4,
                         backoff_base=1.0, backoff_init=0.2)
GENTLE = RetryPolicy(timeout=0.2, max_retries=4,
                     backoff_base=4.0, backoff_init=0.5, backoff_cap=8.0)


def _storm(retry):
    p12 = np.full_like(STORM_LAM, 0.1)
    return fluid_two_tier(STORM_LAM, p12, MU1, MU2, dt=1.0, retry=retry)


def test_retry_storm_is_metastable():
    """Aggressive timeouts: the burst's timeouts re-offer enough load that
    the system stays pinned above capacity after the burst passes."""
    rep = _storm(AGGRESSIVE)
    onset = int(rep.metastable_onset())
    assert onset == 6  # right after the 2-window burst
    assert rep.metastable[-1]  # never recovers
    assert rep.retry_rate[-1] > 0.0
    # External load alone is well under capacity — this is pure feedback.
    assert STORM_LAM[-1] < MU1


def test_capped_backoff_drains():
    """Same burst, same retry budget — but exponential backoff with a cap
    spreads the re-offers and the queue drains."""
    rep = _storm(GENTLE)
    assert int(rep.metastable_onset()) == -1
    assert rep.q1[-1] < 1.0
    assert not rep.metastable[-1]


def test_retry_backlog_ordering():
    """Backlog curves order by retry pressure: aggressive >= gentle >= no
    retries, window by window."""
    none = _storm(None)
    gen = _storm(GENTLE)
    agg = _storm(AGGRESSIVE)
    tol = 1e-9
    assert np.all(agg.q1 >= gen.q1 - tol)
    assert np.all(gen.q1 >= none.q1 - tol)
    assert agg.q1[-1] > 10.0 * max(gen.q1[-1], 1e-3)


def test_metastable_onset_trailing_run_semantics():
    def _rep(meta):
        z = np.zeros(len(meta))
        return FluidReport(lam=z, p12=z, lam_eff=z, rho1=z, rho2=z, w1=z,
                           w2=z, response=z, stable=z.astype(bool), q1=z,
                           q2=z, metastable=np.asarray(meta, bool))
    # Mid-run metastable episode that recovers -> healthy ending, -1.
    assert int(_rep([0, 1, 1, 0, 0]).metastable_onset()) == -1
    # Trailing run -> its first window, ignoring earlier recovered runs.
    assert int(_rep([0, 1, 0, 1, 1]).metastable_onset()) == 3
    assert int(_rep([1, 1, 1, 1, 1]).metastable_onset()) == 0
    # No retry diagnostics at all -> -1.
    z = np.zeros(3)
    rep = FluidReport(lam=z, p12=z, lam_eff=z, rho1=z, rho2=z, w1=z, w2=z,
                      response=z, stable=z.astype(bool), q1=z, q2=z)
    assert int(rep.metastable_onset()) == -1


# --- failover remap (mapping + engine level) ------------------------------

def test_apply_failover_reroutes_to_survivors():
    owner = np.array([0, 1, 2, 3, 1, 1], dtype=np.int32)
    times = np.array([0.5, 0.5, 0.5, 0.5, 2.5, 9.0])
    new, remapped = apply_failover(owner, times, [(1, 2.0, 5.0)], 4)
    # Only the request hitting shard 1 during [2, 5) moves — to shard 2.
    np.testing.assert_array_equal(new, [0, 1, 2, 3, 2, 1])
    np.testing.assert_array_equal(remapped,
                                  [False, False, False, False, True, False])
    # All shards down at that instant: requests keep their home shard.
    all_down = [(s, 0.0, 1.0) for s in range(4)]
    new2, remapped2 = apply_failover(owner[:4], times[:4], all_down, 4)
    np.testing.assert_array_equal(new2, owner[:4])
    assert not remapped2.any()


def test_shard_down_failover_in_engine():
    base = simulate(_timed_spec())
    fs = FaultSpec(events=(shard_down(1, 2.0, 5.0),), refill_cold=False)
    rep = simulate(_timed_spec(faults=fs))
    req_b = np.asarray(base.windows.requests)
    req_f = np.asarray(rep.windows.requests)
    # The down shard serves nothing during the outage windows ...
    assert req_f[1, 2:5].sum() == 0
    assert req_b[1, 2:5].sum() > 0
    # ... its traffic lands on survivors: per-window totals are conserved.
    np.testing.assert_array_equal(req_f.sum(axis=0), req_b.sum(axis=0))
    assert rep.requests == base.requests
    # Windowed counters reconcile bit-exactly with shard totals.
    for name in ("requests", "hits", "misses", "tier2_reads"):
        win = np.asarray(getattr(rep.windows, name)).sum(axis=1)
        tot = np.array([getattr(s, name) for s in rep.shards])
        np.testing.assert_array_equal(win, tot)


def test_cold_refill_after_recovery():
    fs_cold = FaultSpec(events=(shard_down(1, 2.0, 5.0),), refill_cold=True)
    fs_warm = FaultSpec(events=(shard_down(1, 2.0, 5.0),), refill_cold=False)
    cold = simulate(_timed_spec(faults=fs_cold))
    warm = simulate(_timed_spec(faults=fs_warm))
    # Same stream, same remap — only the post-recovery hit accounting moves.
    assert cold.requests == warm.requests
    extra_miss = cold.misses - warm.misses
    assert extra_miss > 0  # recovery re-warms from an empty cache
    assert warm.hits - cold.hits == extra_miss
    assert cold.tier2_reads - warm.tier2_reads == extra_miss
    # The correction lands on the recovered shard, after recovery.
    m_cold = np.asarray(cold.windows.misses)
    m_warm = np.asarray(warm.windows.misses)
    delta = m_cold - m_warm
    assert delta[1, 5:].sum() == extra_miss
    assert np.all(delta[0] == 0) and np.all(delta[2:] == 0)
    # Reconciliation stays exact after the refill correction.
    for name in ("requests", "hits", "misses", "tier2_reads"):
        win = np.asarray(getattr(cold.windows, name)).sum(axis=1)
        tot = np.array([getattr(s, name) for s in cold.shards])
        np.testing.assert_array_equal(win, tot)


# --- degraded solves (engine level) ---------------------------------------

def test_factor_one_degrade_is_bit_exact():
    """factor=1.0 exercises the whole fault path (spill branch, mu
    multipliers, remap plumbing) but must not change a single bit of the
    transient solution."""
    base = simulate(_timed_spec())
    rep = simulate(_timed_spec(faults=FaultSpec(
        events=(device_degrade(1, 1.0, 2.0, 5.0),))))
    for name in ("q1", "q2", "w1", "w2", "rho1", "rho2", "response",
                 "stable", "lam_eff"):
        a = np.asarray(getattr(base.transient, name))
        b = np.asarray(getattr(rep.transient, name))
        assert np.array_equal(a, b), name
    assert rep.requests == base.requests
    assert rep.misses == base.misses


def test_tier2_outage_backs_up_tier2():
    base = simulate(_timed_spec())
    rep = simulate(_timed_spec(faults=FaultSpec(
        events=(tier2_outage(2.0, 6.0),))))
    q2_b = np.asarray(base.transient.q2)
    q2_f = np.asarray(rep.transient.q2)
    # Misses have nowhere to go while tier 2 is out: backlog builds ...
    assert q2_f[2:6].max() > 10.0 * max(q2_b.max(), 1e-6)
    # ... and drains after the outage.
    assert q2_f[-1] < 1.0


def test_shard_down_metastable_with_aggressive_retries():
    """A long outage plus hot retries drives the pooled solve metastable;
    capped backoff over the same outage recovers."""
    fs_hot = FaultSpec(events=(shard_down(1, 2.0, 5.0),),
                       retry=RetryPolicy(timeout=0.05, max_retries=6,
                                         backoff_base=1.0))
    rep = simulate(_timed_spec(faults=fs_hot))
    # Per-shard view: the dead shard's survivors carry inflated load; the
    # retry diagnostics are attached to the fluid report either way.
    assert rep.transient.retry_rate is not None
    assert rep.transient.metastable is not None


# --- sweeps, caching, determinism ----------------------------------------

def test_fault_grid_sweep_compiles_once():
    faults_axis = [None]
    for t0 in (1.0, 2.0, 3.0):
        faults_axis.append(
            FaultSpec(events=(shard_down(1, t0, t0 + 2.0),)))
    for to in (0.1, 0.2):
        faults_axis.append(FaultSpec(
            events=(device_degrade(1, 0.5, 1.0, 3.0),),
            retry=RetryPolicy(timeout=to, max_retries=3)))
    reset_engine_compile_count()
    res = sweep(_timed_spec(), {"faults": faults_axis})
    assert len(res.reports) == len(faults_axis)
    assert engine_compile_count() <= 2
    # Fault schedules are data: the remap changed per-shard loads without
    # recompiling, and the no-fault point matches a plain simulate().
    solo = simulate(_timed_spec())
    assert res.reports[0].requests == solo.requests
    assert [s.requests for s in res.reports[1].shards] != \
        [s.requests for s in res.reports[0].shards]


def test_retry_axis_shares_cache_signature():
    """Retry/degrade sweeps act on the queuing side only — they dedupe to
    one cached tier-1 counter run. shard_down changes the remap, so it
    must not share."""
    s_none = _timed_spec()
    s_r1 = _timed_spec(faults=FaultSpec(retry=RetryPolicy(timeout=0.1)))
    s_r2 = _timed_spec(faults=FaultSpec(retry=RetryPolicy(timeout=0.9)))
    s_deg = _timed_spec(faults=FaultSpec(
        events=(device_degrade(1, 0.5, 1.0, 2.0),)))
    s_down = _timed_spec(faults=FaultSpec(
        events=(shard_down(1, 1.0, 2.0),)))
    assert s_r1.cache_signature() == s_r2.cache_signature()
    assert s_r1.cache_signature() == s_none.cache_signature()
    assert s_deg.cache_signature() == s_none.cache_signature()
    assert s_down.cache_signature() != s_none.cache_signature()


def test_fault_report_deterministic():
    fs = FaultSpec(events=(shard_down(1, 2.0, 5.0),),
                   retry=RetryPolicy(timeout=0.2, max_retries=2))
    a = json.dumps(simulate(_timed_spec(faults=fs)).to_dict(),
                   sort_keys=True)
    b = json.dumps(simulate(_timed_spec(faults=fs)).to_dict(),
                   sort_keys=True)
    assert a == b
