"""``sweep(mrc=...)`` routing: size-only axes served by the one-pass MRC
engine must be report-identical to the scan paths, ineligible grids must
fall back (logged) or raise (``mrc='require'``), and the MRC path must
add zero engine compiles."""
import logging

import numpy as np
import pytest

from repro.sim import SimSpec, sweep
from repro.sim.spec import RateSpec, StoreConfig, TrafficSpec
from repro.sim.sweep import (
    engine_compile_count,
    reset_engine_compile_count,
)

BASE = SimSpec(
    traffic=TrafficSpec(kind="irm", n_requests=260, n_pages=64,
                        write_fraction=0.2, seed=21),
    store=StoreConfig(n_lines=8, policy="lru"),
    n_shards=2,
    lam=60.0,
    rates=RateSpec(source="paper"),
)

SIZE_AXES = {"store.n_lines": [4, 8, 16, 32], "lam": [40.0, 60.0]}


def _assert_reports_equal(a, b, ctx):
    for name in ("requests", "hits", "misses", "prefetch_hits",
                 "tier2_reads", "tier2_writes", "evictions"):
        av, bv = getattr(a, name), getattr(b, name)
        assert av == bv, f"{ctx}: {name} mrc={av} reference={bv}"
    for sa, sb in zip(a.shards, b.shards):
        for name in ("requests", "hits", "misses", "tier2_reads",
                     "tier2_writes", "evictions"):
            av, bv = getattr(sa, name), getattr(sb, name)
            assert av == bv, f"{ctx} shard {sa.shard}: {name} {av} != {bv}"
    for name in a.windows._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a.windows, name)),
            np.asarray(getattr(b.windows, name)),
            err_msg=f"{ctx}: windows.{name}")


def test_size_axis_routes_through_mrc_without_engine_compiles():
    """A pure cache-size grid (with queuing-side riders) is served
    entirely by the stack-distance pass: zero engine compiles, reports
    identical to the unbatched scan reference."""
    reset_engine_compile_count()
    a = sweep(BASE, SIZE_AXES)                     # mrc="auto"
    assert engine_compile_count() == 0
    b = sweep(BASE, SIZE_AXES, batch=False)
    for pt, ra, rb in zip(a.points, a.reports, b.reports):
        _assert_reports_equal(ra, rb, str(pt))


def test_mrc_off_uses_engine():
    reset_engine_compile_count()
    a = sweep(BASE, {"store.n_lines": [4, 8]}, mrc="off")
    assert engine_compile_count() > 0
    b = sweep(BASE, {"store.n_lines": [4, 8]}, batch=False)
    for pt, ra, rb in zip(a.points, a.reports, b.reports):
        _assert_reports_equal(ra, rb, str(pt))


def test_mixed_policy_axis_splits_between_paths():
    """policy in {lru, ws} x sizes: the lru half rides MRC, the ws half
    the batched engine — both bit-equal to the reference."""
    axes = {"store.n_lines": [8, 16], "store.policy": ["lru", "ws"]}
    a = sweep(BASE, axes)
    b = sweep(BASE, axes, batch=False)
    for pt, ra, rb in zip(a.points, a.reports, b.reports):
        _assert_reports_equal(ra, rb, str(pt))


def test_ineligible_grid_falls_back_with_logged_reason(caplog):
    """A multi-size non-LRU grid cannot ride MRC: auto mode falls back to
    the engine and says why."""
    axes = {"store.n_lines": [8, 16]}
    base_ws = BASE.replace(**{"store.policy": "ws"})
    with caplog.at_level(logging.INFO, logger="repro.sim.sweep"):
        a = sweep(base_ws, axes)
    assert any("MRC fallback" in r.message and "policy" in r.message
               for r in caplog.records)
    b = sweep(base_ws, axes, batch=False)
    for pt, ra, rb in zip(a.points, a.reports, b.reports):
        _assert_reports_equal(ra, rb, str(pt))


def test_require_raises_on_unsupported_policy():
    axes = {"store.n_lines": [8, 16], "store.policy": ["lru", "ws"]}
    with pytest.raises(ValueError,
                       match="mrc='require' but the MRC path cannot"):
        sweep(BASE, axes, mrc="require")


def test_require_raises_on_windowed_writes():
    with pytest.raises(ValueError, match="window"):
        sweep(BASE.replace(n_windows=4), {"store.n_lines": [8, 16]},
              mrc="require")


def test_require_incompatible_with_unbatched():
    with pytest.raises(ValueError, match="batch=False"):
        sweep(BASE, SIZE_AXES, mrc="require", batch=False)


def test_invalid_mrc_value():
    with pytest.raises(ValueError, match="mrc must be"):
        sweep(BASE, SIZE_AXES, mrc="always")


def test_timed_grid_routes_and_matches():
    """Wall-clock windows (write-free) ride MRC too."""
    base = BASE.replace(window_dt=0.4,
                        **{"traffic.write_fraction": 0.0})
    axes = {"store.n_lines": [4, 16]}
    reset_engine_compile_count()
    a = sweep(base, axes)
    assert engine_compile_count() == 0
    b = sweep(base, axes, batch=False)
    for pt, ra, rb in zip(a.points, a.reports, b.reports):
        _assert_reports_equal(ra, rb, str(pt))


def test_fault_grid_routes_and_matches():
    """shard_down failover remaps the stream host-side, so fault
    schedules stay inside the MRC exactness domain."""
    from repro.sim import FaultSpec, shard_down
    base = BASE.replace(
        window_dt=0.4,
        faults=FaultSpec(events=(shard_down(1, 0.2, 0.8),)),
        **{"traffic.write_fraction": 0.0})
    axes = {"store.n_lines": [4, 16]}
    a = sweep(base, axes)
    b = sweep(base, axes, batch=False)
    for pt, ra, rb in zip(a.points, a.reports, b.reports):
        _assert_reports_equal(ra, rb, str(pt))
