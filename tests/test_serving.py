"""Serving engine: paged two-tier decode == full forward; prefill handoff;
OL eviction stats accumulate."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.archs import ARCHS
from repro.distributed.axes import SINGLE
from repro.models import params as pm
from repro.models.layers import unembed_greedy
from repro.models.transformer import fwd_hidden
from repro.serving.engine import (
    ServeConfig, init_decode_state, make_decode_step, make_prefill_step,
)


def _cfg(name):
    c = ARCHS[name].reduced()
    moe = None if c.moe is None else dataclasses.replace(
        c.moe, capacity_factor=c.moe.n_experts / c.moe.top_k)
    return dataclasses.replace(c, param_dtype="float32", moe=moe)


def _extras(cfg, rng, B):
    e = {}
    if cfg.enc_dec:
        e["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.enc_seq, cfg.d_model)) * 0.02, jnp.float32)
    if cfg.vlm_prefix:
        e["prefix_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.vlm_prefix, cfg.d_model)) * 0.02,
            jnp.float32)
    return e


@pytest.mark.parametrize("name", [
    "stablelm-3b", "recurrentgemma-9b", "mamba2-370m", "mixtral-8x22b",
    "whisper-tiny", "paligemma-3b",
])
def test_prefill_then_decode_matches_forward(name, rng):
    cfg = _cfg(name)
    ms = pm.MeshSizes()
    params = pm.init_params(cfg, jax.random.PRNGKey(1))
    B, S0, n_dec = 2, 16, 12
    toks = rng.integers(0, cfg.vocab, (B, S0 + n_dec)).astype(np.int32)
    # hbm_fraction < 1 forces evictions + tier-2 reads mid-decode.
    sc = ServeConfig(max_seq=64, batch_local=B, page_axes=(),
                     hbm_fraction=0.6)
    extras = _extras(cfg, rng, B)
    pre = jax.jit(make_prefill_step(cfg, sc, SINGLE, ms))
    state, (nt, lp) = pre(params, jnp.asarray(toks[:, :S0]), extras)
    step = jax.jit(make_decode_step(cfg, sc, SINGLE, ms))
    lps = [np.asarray(lp)]
    for t in range(S0, S0 + n_dec):
        state, (nt, lp) = step(params, state, jnp.asarray(toks[:, t]))
        lps.append(np.asarray(lp))

    kw = {}
    if cfg.enc_dec:
        kw["frames"] = extras["frames"]
    if cfg.vlm_prefix:
        kw["prefix_embeds"] = extras["prefix_embeds"]
    x, _, _ = fwd_hidden(params, jnp.asarray(toks), cfg, SINGLE, **kw)
    if cfg.vlm_prefix:
        x = x[:, cfg.vlm_prefix:]
    emb_key = ("embed" if cfg.tie_embeddings or "unembed" not in params
               else "unembed")
    maxd = 0.0
    for j, t in enumerate(range(S0 - 1, S0 + n_dec)):
        _, rlp = unembed_greedy(x[:, t], params[emb_key], SINGLE)
        maxd = max(maxd, float(np.abs(lps[j] - np.asarray(rlp)).max()))
    assert maxd < 2e-4, (name, maxd)


def test_ol_eviction_stats_accumulate(rng):
    cfg = _cfg("stablelm-3b")
    ms = pm.MeshSizes()
    params = pm.init_params(cfg, jax.random.PRNGKey(1))
    B = 2
    sc = ServeConfig(max_seq=64, batch_local=B, page_axes=(),
                     hbm_fraction=0.4)
    state = init_decode_state(cfg, sc, SINGLE, ms)
    step = jax.jit(make_decode_step(cfg, sc, SINGLE, ms))
    toks = rng.integers(0, cfg.vocab, (B, 48)).astype(np.int32)
    for t in range(48):
        state, _ = step(params, state, jnp.asarray(toks[:, t]))
    kv = state.kv
    assert int(kv.t[0]) == 48
    assert int(kv.t2_reads[0]) > 0   # misses happened (tier-2 serviced)
    assert int(kv.t1_reads[0]) > 0
    w = np.asarray(kv.ols.weights)
    assert abs(w.sum() - 1) < 1e-5 and (w > 0).all()
    assert (np.asarray(kv.lengths) == 48).all()


def test_promote_pages_moves_hot_pages(rng):
    from repro.serving import kvpool as kvp
    from repro.serving.engine import make_kv_spec

    cfg = _cfg("stablelm-3b")
    sc = ServeConfig(max_seq=64, batch_local=2, page_axes=(),
                     hbm_fraction=0.4)
    spec = make_kv_spec(cfg, sc, 1)
    kv = kvp.init_paged_kv(spec, jnp.zeros((), jnp.int32))
    kv = kvp.prefill_residency(kv, spec, jnp.full((2,), 64, jnp.int32))
    before = int((np.asarray(kv.page_slot) >= 0).sum())
    # evict one page artificially, then promote
    kv = kv._replace(
        meta=kv.meta._replace(valid=kv.meta.valid.at[0].set(False)),
        page_slot=kv.page_slot.at[0, 3].set(-1),
    )
    kv2 = kvp.promote_pages(kv, spec, n_promote=2)
    after = int((np.asarray(kv2.page_slot) >= 0).sum())
    assert after >= int((np.asarray(kv.page_slot) >= 0).sum())


@pytest.mark.parametrize("mapping", ["block_cyclic", "random"])
def test_paged_kv_inclusion_invariant(mapping, rng):
    """Paper §III: the cache is *inclusive* and write-back — after evicting
    every resident page, tier 2 must hold exactly the data that was written
    to tier 1 (no token lost across evictions)."""
    import jax.numpy as jnp

    from repro.serving import kvpool as kvp
    from repro.serving.engine import make_kv_spec

    cfg = _cfg("stablelm-3b")
    sc = ServeConfig(max_seq=64, batch_local=2, page_axes=(),
                     hbm_fraction=0.4, mapping=mapping)
    spec = make_kv_spec(cfg, sc, 1)
    kv = kvp.init_paged_kv(spec, jnp.zeros((), jnp.int32))

    # Simulate the decode write path for enough steps to force evictions.
    import jax

    from repro.core import online_learning as ol

    L = spec.layers_per_slot
    written = {}
    for t in range(48):
        kv, plan = kvp.alloc_step(kv, spec, jnp.zeros((), jnp.int32),
                                  ol.OLConfig())
        pools = (kv.pool1, kv.pool2)
        for li in range(L):
            k_new = jnp.full((2, spec.n_kv, spec.head_dim), float(t + li),
                             jnp.float32)
            v_new = -k_new
            pools = kvp.write_token_kv(pools, plan, (k_new, v_new),
                                       kv.lengths, spec, jnp.asarray(li))
        kv = kv._replace(pool1=pools[0], pool2=pools[1],
                         lengths=kv.lengths + 1, t=kv.t + 1)
        for b in range(2):
            written[(b, t)] = float(t)  # layer-0 k value at position t

    # Read everything back through the two-tier read path: every written
    # token must be recoverable (from tier 1 if resident, tier 2 otherwise).
    k, v, valid = kvp.read_pages((kv.pool1, kv.pool2), kv, spec,
                                 jnp.asarray(0))
    k = np.asarray(k, np.float32)
    valid = np.asarray(valid)
    for b in range(2):
        for t in range(48):
            assert valid[b, t], (b, t)
            assert k[b, t, 0, 0] == written[(b, t)], (b, t, k[b, t, 0, 0])
