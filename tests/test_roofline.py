"""HLO cost walker + collective parser against known computations."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.roofline import (
    collective_bytes, hlo_cost, module_collective_bytes, roofline_report,
    CollectiveStats,
)


def test_matmul_flops_exact():
    f = lambda a, b: a @ b
    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((128, 256), jnp.float32),
        jax.ShapeDtypeStruct((256, 64), jnp.float32),
    ).compile()
    got = hlo_cost(c.as_text())["flops"]
    assert got == 2 * 128 * 256 * 64


def test_scan_trip_count_multiplied():
    def g(x, ws):
        def body(x, w):
            return x @ w, None
        x, _ = jax.lax.scan(body, x, ws)
        return x

    c = jax.jit(g).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32),
        jax.ShapeDtypeStruct((10, 64, 64), jnp.float32),
    ).compile()
    got = hlo_cost(c.as_text())["flops"]
    assert got == 10 * 2 * 64 * 64 * 64


def test_collective_parser_on_synthetic_hlo():
    hlo = """
HloModule test

ENTRY %main (p: f32[8,128]) -> f32[8,128] {
  %p = f32[8,128]{1,0} parameter(0)
  %ag = f32[32,128]{1,0} all-gather(%p), replica_groups={{0,1,2,3}}, dimensions={0}
  %ar = f32[8,128]{1,0} all-reduce(%p), replica_groups={{0,1,2,3}}, to_apply=%add
  ROOT %out = f32[8,128]{1,0} copy(%ar)
}
"""
    st = collective_bytes(hlo)
    assert st.count == 2
    ag = 32 * 128 * 4 * 3 / 4        # out * (n-1)/n
    ar = 2 * 8 * 128 * 4 * 3 / 4     # 2 * out * (n-1)/n
    assert abs(st.by_kind["all-gather"] - ag) < 1e-6
    assert abs(st.by_kind["all-reduce"] - ar) < 1e-6


def test_roofline_report_dominant_term():
    rep = roofline_report(
        hlo_flops=197e12, hlo_bytes=819e9 * 2, coll=CollectiveStats(),
        chips=1, model_flops=100e12,
    )
    assert rep["dominant"] == "memory"
    assert abs(rep["t_compute_s"] - 1.0) < 1e-9
    assert abs(rep["t_memory_s"] - 2.0) < 1e-9
    assert 0 < rep["roofline_frac"] < 1
