"""Batched fluid solver (ISSUE 9): golden equivalence against the scalar
numpy PSFFA loop, compile accounting, and the μ(Q) load-dependent hook.

``fluid_two_tier_batched`` is a drop-in counterpart of ``fluid_two_tier``
whose window loop runs as one jitted ``lax.scan`` over all leading axes.
The contract tested here:

- batched == scalar to ~1e-12 on the analytic k=1 path — across fault-like
  μ(t) schedules, retry storms, tier-1 spill, idle windows and dead-μ
  windows (identical non-finite masks, finite entries agree);
- k>1 / M/G/k grids agree to the bisection tolerance (~1e-6);
- one jit trace per structural config (``fluid_compile_count``);
- ``mu_load=((0,0),(0,0))`` is bitwise identical to ``mu_load=None``
  through the batched kernel (the off-by-default guarantee), and positive
  coefficients actually bend the service rate;
- the onset/metastable scans vectorize over arbitrary leading point axes.
"""
import numpy as np
import pytest

from repro.core.device_models import fit_mu_load, mu_load_from_devices
from repro.core.queuing import (
    RetryPolicy,
    fluid_compile_count,
    fluid_two_tier,
    fluid_two_tier_batched,
    reset_fluid_compile_count,
)

DT = 0.1


def grids(n_points=6, n_shards=3, n_windows=12, seed=0):
    """A [P, S, W] stack of diverse healthy rate grids."""
    rng = np.random.default_rng(seed)
    lam = rng.uniform(0.0, 140.0, (n_points, n_shards, n_windows))
    p12 = rng.uniform(0.0, 0.6, (n_points, n_shards, n_windows))
    mu1 = rng.uniform(150.0, 450.0, (n_points, n_shards, n_windows))
    mu2 = rng.uniform(30.0, 90.0, (n_points, n_shards, n_windows))
    return lam, p12, mu1, mu2


def assert_reports_match(batched, scalar, tol, what=""):
    """Field-by-field: identical None-ness and non-finite masks, finite
    entries within tol."""
    for name, vb, vs in zip(batched._fields, batched, scalar):
        if vs is None or vb is None:
            assert vs is None and vb is None, f"{what}{name} None mismatch"
            continue
        xb, xs = np.asarray(vb), np.asarray(vs)
        assert xb.shape == xs.shape, f"{what}{name} shape"
        if xb.dtype == bool:
            np.testing.assert_array_equal(xb, xs, err_msg=f"{what}{name}")
            continue
        xb, xs = xb.astype(float), xs.astype(float)
        fb, fs = np.isfinite(xb), np.isfinite(xs)
        np.testing.assert_array_equal(fb, fs,
                                      err_msg=f"{what}{name} finite mask")
        if fb.any():
            np.testing.assert_allclose(xb[fb], xs[fs], rtol=0, atol=tol,
                                       err_msg=f"{what}{name}")


def scalar_stack(lam, p12, mu1, mu2, **kw):
    """Per-point scalar solves restacked to the batched layout."""
    reps = [fluid_two_tier(lam[i], p12[i], mu1[i], mu2[i], **kw)
            for i in range(lam.shape[0])]
    fields = []
    for j in range(len(reps[0])):
        if reps[0][j] is None:
            fields.append(None)
        else:
            fields.append(np.stack([np.asarray(r[j]) for r in reps]))
    return type(reps[0])(*fields)


def test_batched_matches_scalar_healthy():
    lam, p12, mu1, mu2 = grids()
    b = fluid_two_tier_batched(lam, p12, mu1, mu2, dt=DT)
    s = scalar_stack(lam, p12, mu1, mu2, dt=DT)
    assert_reports_match(b, s, 1e-12)


def test_batched_matches_scalar_faulted():
    """Retry storm + tier-1 spill + a dead-μ outage window + idle windows:
    the full degraded-mode feature set through one batched solve."""
    lam, p12, mu1, mu2 = grids(seed=1)
    lam[:, :, 3] = 0.0            # idle windows (solver guards, p12 zeroed)
    mu1[:, 1, 5:7] = 0.0          # shard-down: dead tier-1 device
    mu2[:, :, 6] = 0.0            # tier-2 outage window
    lam[:, :, 8] = 400.0          # overload burst to light up the orbit
    retry = RetryPolicy(timeout=0.04, max_retries=3, backoff_init=0.2)
    kw = dict(dt=DT, retry=retry, tier1_spill=True)
    b = fluid_two_tier_batched(lam, p12, mu1, mu2, **kw)
    s = scalar_stack(lam, p12, mu1, mu2, **kw)
    assert b.retry_rate is not None and b.metastable is not None
    assert_reports_match(b, s, 1e-10)


def test_batched_matches_scalar_multiserver_mgk():
    """k>1 bisection (plus service-time variance): the jax solve runs the
    fixed 60-iteration bisection vs numpy's early-break, so agreement is
    bounded by the bisection tolerance, not machine epsilon."""
    lam, p12, mu1, mu2 = grids(n_points=4, seed=2)
    for kw in (dict(k=3), dict(k=2, var_s1=2e-5)):
        b = fluid_two_tier_batched(lam, p12, mu1, mu2, dt=DT, **kw)
        s = scalar_stack(lam, p12, mu1, mu2, dt=DT, **kw)
        assert_reports_match(b, s, 1e-6, what=f"{kw}: ")


def test_batched_matches_scalar_kscale_q0_conserving():
    lam, p12, mu1, mu2 = grids(n_points=3, seed=3)
    k_scale = np.ones_like(lam)
    k_scale[:, :, 4:6] = 0.5      # half the service threads mid-horizon
    q0 = (np.full(lam.shape[:-1], 3.0), np.full(lam.shape[:-1], 1.5))
    b = fluid_two_tier_batched(lam, p12, mu1, mu2, dt=DT, flow="conserving",
                               k_scale=k_scale, q0=q0)
    reps = [fluid_two_tier(lam[i], p12[i], mu1[i], mu2[i], dt=DT,
                           flow="conserving", k_scale=k_scale[i],
                           q0=(q0[0][i], q0[1][i]))
            for i in range(lam.shape[0])]
    s = type(reps[0])(*(
        None if reps[0][j] is None
        else np.stack([np.asarray(r[j]) for r in reps])
        for j in range(len(reps[0]))))
    assert_reports_match(b, s, 1e-12)


def test_compile_count_one_trace_per_config():
    lam, p12, mu1, mu2 = grids(n_points=2, n_shards=2, n_windows=7, seed=4)
    reset_fluid_compile_count()
    fluid_two_tier_batched(lam, p12, mu1, mu2, dt=DT)
    first = fluid_compile_count()
    assert first <= 1
    # Same config and shapes again: served from the jit cache, no retrace.
    fluid_two_tier_batched(lam, p12, mu1, mu2, dt=DT)
    assert fluid_compile_count() == first
    # New *shape*, same structural config: one more trace at most.
    fluid_two_tier_batched(lam[:, 0], p12[:, 0], mu1[:, 0], mu2[:, 0],
                           dt=DT)
    second = fluid_compile_count()
    assert second <= first + 1
    # New structural config (retry feedback): separate kernel.
    retry = RetryPolicy(timeout=0.04, max_retries=2, backoff_init=0.2)
    fluid_two_tier_batched(lam, p12, mu1, mu2, dt=DT, retry=retry)
    assert fluid_compile_count() <= second + 1


def test_mu_load_zero_coefficients_bitwise_off():
    """mu_load=((0,0),(0,0)) must be *bitwise* identical to mu_load=None —
    the off-by-default guarantee that shipping the hook changes nothing."""
    lam, p12, mu1, mu2 = grids(n_points=3, seed=5)
    off = fluid_two_tier_batched(lam, p12, mu1, mu2, dt=DT)
    zero = fluid_two_tier_batched(lam, p12, mu1, mu2, dt=DT,
                                  mu_load=((0.0, 0.0), (0.0, 0.0)))
    for name, vo, vz in zip(off._fields, off, zero):
        if vo is None:
            assert vz is None
            continue
        np.testing.assert_array_equal(np.asarray(vo), np.asarray(vz),
                                      err_msg=name)


def test_mu_load_bends_service_rate_and_matches_scalar():
    """Positive denominator coefficients (service slows with queue depth)
    must raise the backlog vs the fixed-rate solve, agree between scalar
    and batched paths, and speed-up coefficients must do the opposite."""
    lam = np.full((2, 1, 10), 90.0)
    p12 = np.full_like(lam, 0.3)
    mu1 = np.full_like(lam, 120.0)
    mu2 = np.full_like(lam, 45.0)
    slow = ((0.0, 0.8), (0.0, 0.8))
    fast = ((0.5, 0.0), (0.5, 0.0))
    base = fluid_two_tier_batched(lam, p12, mu1, mu2, dt=DT)
    b_slow = fluid_two_tier_batched(lam, p12, mu1, mu2, dt=DT, mu_load=slow)
    b_fast = fluid_two_tier_batched(lam, p12, mu1, mu2, dt=DT, mu_load=fast)
    s_slow = scalar_stack(lam, p12, mu1, mu2, dt=DT, mu_load=slow)
    assert_reports_match(b_slow, s_slow, 1e-10)
    assert np.all(np.asarray(b_slow.q1)[..., -1]
                  > np.asarray(base.q1)[..., -1])
    assert np.all(np.asarray(b_fast.q1)[..., -1]
                  < np.asarray(base.q1)[..., -1])


def test_mu_load_validation():
    lam, p12, mu1, mu2 = grids(n_points=1, seed=6)
    for bad in (((-1.0, 0.0), (0.0, 0.0)), ((np.nan, 0.0), (0.0, 0.0)),
                (1.0, 2.0), ((1.0,), (0.0, 0.0))):
        with pytest.raises(ValueError):
            fluid_two_tier_batched(lam, p12, mu1, mu2, dt=DT, mu_load=bad)


def test_fit_mu_load_recovers_coefficients():
    q = np.linspace(0.0, 40.0, 60)
    a, b = 0.02, 0.11
    ratio = (1.0 + a * q) / (1.0 + b * q)
    fa, fb = fit_mu_load(q, ratio)
    assert fa == pytest.approx(a, rel=1e-6)
    assert fb == pytest.approx(b, rel=1e-6)
    (t1, t2) = mu_load_from_devices(q, ratio, q, np.ones_like(q))
    assert t1 == (pytest.approx(a, rel=1e-6), pytest.approx(b, rel=1e-6))
    assert t2[0] == pytest.approx(0.0, abs=1e-9)
    with pytest.raises(ValueError):
        fit_mu_load(q[:1], ratio[:1])
    with pytest.raises(ValueError):
        fit_mu_load(q, -ratio)


def test_onset_scans_vectorize_over_point_axis():
    """onset()/metastable_onset() on a stacked report must equal the
    per-point scalar calls — the satellite fix for the per-report re-runs."""
    lam, p12, mu1, mu2 = grids(n_points=5, seed=7)
    lam[1, :, 6:] = 500.0   # saturate one point late in the horizon
    lam[3, :, 0:] = 500.0   # and one from the start
    retry = RetryPolicy(timeout=0.04, max_retries=2, backoff_init=0.2)
    b = fluid_two_tier_batched(lam, p12, mu1, mu2, dt=DT, retry=retry)
    onset = np.asarray(b.onset())
    meta = np.asarray(b.metastable_onset())
    assert onset.shape == lam.shape[:2] and meta.shape == lam.shape[:2]
    for i in range(lam.shape[0]):
        s = fluid_two_tier(lam[i], p12[i], mu1[i], mu2[i], dt=DT,
                           retry=retry)
        np.testing.assert_array_equal(onset[i], np.asarray(s.onset()))
        np.testing.assert_array_equal(meta[i],
                                      np.asarray(s.metastable_onset()))


def test_hypothesis_fuzz_batched_equivalence():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        n_windows=st.integers(2, 24),
        n_points=st.integers(1, 5),
        retry_on=st.booleans(),
        spill=st.booleans(),
    )
    def fuzz(seed, n_windows, n_points, retry_on, spill):
        rng = np.random.default_rng(seed)
        shape = (n_points, 2, n_windows)
        lam = rng.uniform(0.0, 300.0, shape)
        p12 = rng.uniform(0.0, 1.0, shape)
        mu1 = rng.uniform(0.0, 500.0, shape)   # includes dead-μ draws
        mu2 = rng.uniform(0.0, 120.0, shape)
        retry = (RetryPolicy(timeout=0.05, max_retries=2, backoff_init=0.3)
                 if retry_on else None)
        kw = dict(dt=DT, retry=retry, tier1_spill=spill)
        b = fluid_two_tier_batched(lam, p12, mu1, mu2, **kw)
        s = scalar_stack(lam, p12, mu1, mu2, **kw)
        assert_reports_match(b, s, 1e-9)

    fuzz()
