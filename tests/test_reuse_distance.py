"""Reuse-distance kernel + MRC exactness harness.

Three rings of defense around the one-pass miss-rate-curve engine:

1. **Kernel goldens** — the Pallas dominance-count kernel (interpret and,
   where a compiled backend exists, compiled mode) against the pure-jax
   oracle, and both against a brute-force python stack simulation;
   segmentation tests prove distances never leak across shard rows or
   into padding.
2. **Counter exactness** — :func:`repro.sim.mrc.mrc_tier1_counters` is
   bit-identical to the sequential scan engine for LRU at *every* cache
   size, whole-stream and per-window, on adversarial access patterns
   (all-unique, single hot key, cycles straddling the capacity) and on
   random traffic with writes (the write-back episode intervals).
3. **Domain fences** — sizes/policy/prefetch/windowed-write requests
   outside the exactness domain raise ``ValueError``.

Property-based fuzzing (hypothesis) deepens ring 2 when the library is
installed; the fixed-seed tests above always run.
"""
import numpy as np
import pytest

import jax

from repro.kernels.ref import DIST_INF, reuse_distance_ref
from repro.kernels.reuse_distance import (
    prev_occurrence,
    reuse_distance_kernel,
    reuse_distances,
)
from repro.sim import SimSpec, mrc_tier1_counters, mrc_unsupported_reason
from repro.sim.engine import tier1_counters
from repro.sim.spec import StoreConfig, TrafficSpec

# ---------------------------------------------------------------------------
# brute-force oracles


def _brute_distances(pages):
    """Set-based Mattson stack distances for one flat stream."""
    last = {}
    out = np.empty(len(pages), np.int64)
    for j, p in enumerate(pages):
        if p in last:
            out[j] = len({pages[k] for k in range(last[p] + 1, j)})
        else:
            out[j] = DIST_INF
        last[p] = j
    return out


def _ragged_prev(rng, S, L, n_pages):
    """Random ragged shard rows (pads = repeats of the last page, like
    partition_streams) plus their prev/valid arrays."""
    counts = rng.integers(0, L + 1, S)
    counts[rng.integers(0, S)] = L          # at least one full row
    sh_pages = rng.integers(0, n_pages, (S, L)).astype(np.int32)
    for s in range(S):
        if counts[s] < L:
            fill = sh_pages[s, counts[s] - 1] if counts[s] else 0
            sh_pages[s, counts[s]:] = fill
    return sh_pages, counts


def test_prev_occurrence_bruteforce():
    rng = np.random.default_rng(0)
    for _ in range(5):
        sh_pages, counts = _ragged_prev(rng, S=3, L=41, n_pages=7)
        prev, valid = prev_occurrence(sh_pages, counts)
        for s in range(3):
            last = {}
            for j in range(41):
                if j >= counts[s]:
                    assert not valid[s, j] and prev[s, j] == -1
                    continue
                assert valid[s, j]
                assert prev[s, j] == last.get(sh_pages[s, j], -1)
                last[sh_pages[s, j]] = j


def test_ref_matches_bruteforce():
    rng = np.random.default_rng(1)
    for _ in range(5):
        sh_pages, counts = _ragged_prev(rng, S=3, L=57, n_pages=9)
        prev, valid = prev_occurrence(sh_pages, counts)
        d = np.asarray(reuse_distance_ref(prev, valid, block=16))
        for s in range(3):
            want = _brute_distances(sh_pages[s, : counts[s]].tolist())
            np.testing.assert_array_equal(d[s, : counts[s]], want)
            np.testing.assert_array_equal(d[s, counts[s]:], -1)


@pytest.mark.parametrize("seed,S,L,block", [(2, 1, 16, 8), (3, 4, 100, 16),
                                            (4, 2, 128, 128), (5, 3, 37, 32)])
def test_pallas_interpret_matches_ref(seed, S, L, block):
    """Golden: interpret-mode Pallas kernel == pure-jax oracle, bit for
    bit, across shapes that exercise padding and multi-block loops."""
    rng = np.random.default_rng(seed)
    sh_pages, counts = _ragged_prev(rng, S=S, L=L, n_pages=11)
    prev, valid = prev_occurrence(sh_pages, counts)
    ref = np.asarray(reuse_distance_ref(prev, valid, block=block))
    ker = np.asarray(
        reuse_distance_kernel(prev, valid, block=block, interpret=True))
    np.testing.assert_array_equal(ker, ref)


@pytest.mark.kernels
def test_pallas_compiled_matches_ref():
    """Compiled-mode golden — only meaningful on an accelerator backend
    (deselect with ``-m 'not kernels'``; auto-skips on CPU, where
    non-interpret Pallas does not lower)."""
    if jax.default_backend() == "cpu":
        pytest.skip("no accelerator backend: compiled Pallas needs TPU/GPU")
    rng = np.random.default_rng(6)
    sh_pages, counts = _ragged_prev(rng, S=2, L=100, n_pages=13)
    prev, valid = prev_occurrence(sh_pages, counts)
    ref = np.asarray(reuse_distance_ref(prev, valid))
    ker = np.asarray(
        reuse_distance_kernel(prev, valid, interpret=False))
    np.testing.assert_array_equal(ker, ref)


def test_shard_segmentation_no_leaks():
    """A page ending one shard row and opening the next must be a
    compulsory miss in the second row, and pads (edge-repeats) must
    neither count toward gaps nor receive distances."""
    sh_pages = np.array([
        [5, 1, 2, 5, 5, 5],     # row 0: last real = page 5, then pads
        [5, 3, 5, 3, 3, 3],     # row 1 opens with page 5: must be INF
    ], np.int32)
    counts = np.array([4, 4])
    prev, valid = prev_occurrence(sh_pages, counts)
    d = np.asarray(reuse_distances(prev, valid, block=4))
    # Row 0: 5 reused at j=3 with gap {1, 2}.
    np.testing.assert_array_equal(d[0, :4], [DIST_INF, DIST_INF, DIST_INF, 2])
    # Row 1: page 5 did NOT carry over from row 0; the pad repeats of
    # page 3 (row 0's pads repeat page 5) contribute to nothing.
    np.testing.assert_array_equal(d[1, :4], [DIST_INF, DIST_INF, 1, 1])
    np.testing.assert_array_equal(d[:, 4:], -1)
    # Interpret-mode kernel agrees on the same segmentation case.
    ker = np.asarray(
        reuse_distance_kernel(prev, valid, block=4, interpret=True))
    np.testing.assert_array_equal(ker, d)


# ---------------------------------------------------------------------------
# MRC counter exactness vs the scan engine

_BASE = SimSpec(
    traffic=TrafficSpec(kind="irm", n_requests=240, n_pages=48,
                        write_fraction=0.0, seed=9),
    store=StoreConfig(n_lines=8, policy="lru"),
    n_shards=3,
    lam=120.0,
)


def _assert_counters_equal(spec, sizes, trace=None, ctx=""):
    got = mrc_tier1_counters(spec, sizes, trace=trace)
    for C in sizes:
        ref = tier1_counters(spec.replace(**{"store.n_lines": int(C)}),
                             trace=trace)
        g = got[int(C)]
        for f in ref._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(g, f)), np.asarray(getattr(ref, f)),
                err_msg=f"{ctx} C={C} field={f}")


def _adversarial_traces(n_lines, n):
    """Access patterns that sit exactly on the hit/miss boundary."""
    rng = np.random.default_rng(13)
    cyc = lambda period: np.arange(n) % period
    hot = rng.integers(0, 12, n)
    hot[rng.random(n) < 0.5] = 0                       # single hot key
    return {
        "all-unique": np.arange(n),
        "single-hot-key": hot,
        f"cycle-{n_lines - 1}": cyc(n_lines - 1),
        f"cycle-{n_lines}": cyc(n_lines),
        f"cycle-{n_lines + 1}": cyc(n_lines + 1),      # classic LRU thrash
    }


@pytest.mark.parametrize("pattern", ["all-unique", "single-hot-key",
                                     "cycle-7", "cycle-8", "cycle-9"])
def test_mrc_adversarial_patterns_whole_stream(pattern):
    n_lines = 8
    trace_pages = _adversarial_traces(n_lines, 160)[pattern]
    sizes = [1, n_lines - 1, n_lines, n_lines + 1, 64]
    trace = (trace_pages, np.zeros(len(trace_pages), bool))
    _assert_counters_equal(_BASE, sizes, trace=trace, ctx=pattern)


@pytest.mark.parametrize("pattern", ["all-unique", "cycle-8", "cycle-9"])
def test_mrc_adversarial_patterns_windowed(pattern):
    trace_pages = _adversarial_traces(8, 160)[pattern]
    spec = _BASE.replace(n_windows=5)
    trace = (trace_pages, np.zeros(len(trace_pages), bool))
    _assert_counters_equal(spec, [7, 8, 9], trace=trace, ctx=pattern)


def test_mrc_writes_whole_stream():
    """Random write traffic: the episode-interval write-back counts must
    equal the engine's dirty-eviction write-backs at every size —
    including sizes beyond the working set (no evictions at all)."""
    spec = _BASE.replace(**{"traffic.write_fraction": 0.35})
    _assert_counters_equal(spec, [1, 2, 5, 8, 11, 48, 200], ctx="writes")


def test_mrc_windowed_write_free_traffic():
    spec = _BASE.replace(n_windows=4, **{"traffic.kind": "markov"})
    _assert_counters_equal(spec, [1, 8, 16, 64], ctx="windowed")


def test_mrc_timed_windows():
    spec = _BASE.replace(window_dt=0.4)
    _assert_counters_equal(spec, [4, 8, 32], ctx="timed")


def test_mrc_trace_with_timestamps():
    rng = np.random.default_rng(3)
    pages = rng.integers(0, 30, 300)
    times = np.sort(rng.uniform(0.0, 2.0, 300))
    spec = _BASE.replace(window_dt=0.5)
    trace = (pages, np.zeros(300, bool), times)
    _assert_counters_equal(spec, [2, 8, 30], trace=trace, ctx="trace-timed")


# ---------------------------------------------------------------------------
# domain fences


def test_mrc_rejects_non_lru_policies():
    for policy in ("lfu", "ws", "random"):
        spec = _BASE.replace(**{"store.policy": policy})
        assert mrc_unsupported_reason(spec) is not None
        with pytest.raises(ValueError,
                           match="only for policy='lru'"):
            mrc_tier1_counters(spec, [8])


def test_mrc_rejects_prefetch():
    spec = _BASE.replace(**{"store.prefetch": True})
    with pytest.raises(ValueError, match="prefetch"):
        mrc_tier1_counters(spec, [8])


def test_mrc_rejects_windowed_writes():
    spec = _BASE.replace(n_windows=4,
                         **{"traffic.write_fraction": 0.3})
    assert "window" in mrc_unsupported_reason(spec)
    with pytest.raises(ValueError, match="write-free"):
        mrc_tier1_counters(spec, [8])


def test_mrc_rejects_bad_sizes():
    with pytest.raises(ValueError, match="non-empty"):
        mrc_tier1_counters(_BASE, [])
    with pytest.raises(ValueError, match=">= 1"):
        mrc_tier1_counters(_BASE, [0, 4])


# ---------------------------------------------------------------------------
# property-based fuzz (optional dependency)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:            # pragma: no cover - optional dependency
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(
        pages=st.lists(st.integers(0, 12), min_size=1, max_size=120),
        writes=st.lists(st.booleans(), min_size=120, max_size=120),
        n_lines=st.integers(1, 14),
    )
    def test_fuzz_mrc_matches_engine_whole_stream(pages, writes, n_lines):
        trace = (np.asarray(pages),
                 np.asarray(writes[: len(pages)], bool))
        sizes = [max(1, n_lines - 1), n_lines, n_lines + 1]
        _assert_counters_equal(_BASE, sizes, trace=trace, ctx="fuzz")

    @settings(max_examples=15, deadline=None)
    @given(pages=st.lists(st.integers(0, 9), min_size=4, max_size=80))
    def test_fuzz_distances_match_bruteforce(pages):
        arr = np.asarray(pages, np.int32)[None, :]
        counts = np.array([len(pages)])
        prev, valid = prev_occurrence(arr, counts)
        d = np.asarray(reuse_distances(prev, valid, block=16))
        np.testing.assert_array_equal(d[0], _brute_distances(pages))
