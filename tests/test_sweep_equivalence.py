"""Megabatch sweep equivalence: the one-compile engine (traced hypers,
bucketed padding, device sharding) must reproduce the unbatched reference
path counter-for-counter. Guards the bucketed-padding rewrite."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.mapping import MAPPING_POLICIES
from repro.core.traffic import TrafficSpec
from repro.sim import RateSpec, SimSpec, sweep
from repro.sim.sweep import (
    _bucket_cap,
    engine_compile_count,
    reset_engine_compile_count,
)
from repro.storage.tiered_store import POLICY_TO_IDX, StoreConfig

BASE = SimSpec(
    traffic=TrafficSpec(kind="poisson", n_requests=300, n_pages=96,
                        write_fraction=0.25, seed=5),
    store=StoreConfig(n_lines=16, policy="ws"),
    n_shards=3,
    lam=20.0,
    rates=RateSpec(source="paper"),
)

ALL_POLICIES = sorted(POLICY_TO_IDX)          # lfu, lru, random, ws
ALL_MAPPINGS = sorted(MAPPING_POLICIES)       # block, block_cyclic, ...


def _assert_reports_equal(a, b, ctx):
    for name in ("requests", "hits", "misses", "prefetch_hits",
                 "tier2_reads", "tier2_writes", "evictions"):
        av, bv = getattr(a, name), getattr(b, name)
        assert av == bv, f"{ctx}: {name} batched={av} unbatched={bv}"
    for sa, sb in zip(a.shards, b.shards):
        for name in ("requests", "hits", "misses", "tier2_reads",
                     "tier2_writes", "evictions"):
            av, bv = getattr(sa, name), getattr(sb, name)
            assert av == bv, f"{ctx} shard {sa.shard}: {name} {av} != {bv}"
    # Windowed telemetry is bit-exact across paths too (window ids ride the
    # global stream position, independent of padding buckets).
    for name in a.windows._fields:
        av = np.asarray(getattr(a.windows, name))
        bv = np.asarray(getattr(b.windows, name))
        np.testing.assert_array_equal(av, bv,
                                      err_msg=f"{ctx}: windows.{name}")


def test_all_policies_and_mappings_match_unbatched():
    """Every policy x mapping combination: identical counters through the
    megabatched and reference paths. Poisson traffic under block mapping is
    deliberately ragged (most requests land on shard 0)."""
    axes = {"store.policy": ALL_POLICIES, "mapping": ALL_MAPPINGS}
    a = sweep(BASE, axes, batch=True)
    b = sweep(BASE, axes, batch=False)
    for pt, ra, rb in zip(a.points, a.reports, b.reports):
        _assert_reports_equal(ra, rb, str(pt))


def test_ragged_stream_lengths_match_unbatched():
    """Points with very different stream lengths land in different padding
    buckets; counters must still match the per-point reference exactly."""
    axes = {
        "traffic.n_requests": [60, 300, 700],
        "store.policy": ["ws", "lru"],
        "store.alpha": [0.3, 0.7],
    }
    a = sweep(BASE, axes, batch=True)
    b = sweep(BASE, axes, batch=False)
    for pt, ra, rb in zip(a.points, a.reports, b.reports):
        _assert_reports_equal(ra, rb, str(pt))
    # The lengths really span more than one bucket.
    caps = {_bucket_cap(n) for n in (60, 300, 700)}
    assert len(caps) > 1


def test_traced_knob_axes_share_one_compile():
    """Axes covering only traced knobs (alpha/beta/threshold/policy) stack
    into the hyper vmap axis: at most one fresh engine compile."""
    spec = BASE.replace(**{"traffic.seed": 11})
    axes = {
        "store.policy": ALL_POLICIES,
        "store.alpha": [0.25, 0.5, 0.75],
        "store.beta": [0.6, 0.9],
        "store.threshold": [0.1, 0.25],
    }
    sweep(spec, axes)  # warm the jit cache for this shape
    reset_engine_compile_count()
    res = sweep(spec, axes)
    assert engine_compile_count() == 0  # fully served from the compile cache
    assert len(res.points) == len(ALL_POLICIES) * 3 * 2 * 2
    # The hyper axis is live: policies disagree on eviction behavior.
    miss_by_policy = {}
    for pt, rep in zip(res.points, res.reports):
        miss_by_policy.setdefault(pt["store.policy"], set()).add(rep.misses)
    assert len({frozenset(v) for v in miss_by_policy.values()}) > 1


def test_windowed_sweep_matches_unbatched():
    """n_windows > 1 through the megabatch path: every windowed counter
    equals the per-point reference bit for bit, across ragged buckets."""
    base = BASE.replace(n_windows=6)
    axes = {
        "traffic.n_requests": [60, 300, 700],
        "store.policy": ["ws", "lru"],
    }
    a = sweep(base, axes, batch=True)
    b = sweep(base, axes, batch=False)
    for pt, ra, rb in zip(a.points, a.reports, b.reports):
        _assert_reports_equal(ra, rb, str(pt))
        assert ra.n_windows == 6


def test_n_windows_axis_adds_no_compiles():
    """A traced-knob grid at fixed n_windows compiles once; repeating the
    sweep serves everything from the compile cache (the window-id operand
    is data, not structure)."""
    spec = BASE.replace(**{"traffic.seed": 13, "n_windows": 4})
    axes = {
        "store.policy": ALL_POLICIES,
        "store.alpha": [0.25, 0.75],
        "store.beta": [0.6, 0.9],
    }
    reset_engine_compile_count()
    sweep(spec, axes)
    first = engine_compile_count()
    assert first <= 2  # the bench_sweep compile gate, windowed
    reset_engine_compile_count()
    res = sweep(spec, axes)
    assert engine_compile_count() == 0
    assert all(rep.n_windows == 4 for rep in res.reports)


def test_bucket_cap_powers_of_two():
    assert _bucket_cap(1) == 16
    assert _bucket_cap(16) == 16
    assert _bucket_cap(17) == 32
    assert _bucket_cap(700) == 1024


@pytest.mark.slow
def test_multidevice_sweep_matches_single_device():
    """Device-sharded point axis (forced host devices) must not change any
    counter; runs in a subprocess so XLA_FLAGS precedes jax import."""
    script = os.path.join(os.path.dirname(__file__),
                          "sweep_multidevice_script.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=2").strip()
    out = subprocess.run(
        [sys.executable, script], env=env, capture_output=True, text=True,
        timeout=1800,
    )
    assert out.returncode == 0, out.stdout[-3000:] + out.stderr[-3000:]
    assert "MULTIDEVICE SWEEP OK" in out.stdout
