"""Smoke test for the SLO capacity planner (``benchmarks/hillclimb.py``):
the successive-halving loop terminates on the batched report path, halves
its candidate set per rung, and emits a structurally complete artifact."""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import hillclimb  # noqa: E402


def test_hillclimb_smoke(tmp_path):
    artifact = str(tmp_path / "BENCH_hillclimb.json")
    out = hillclimb.run(smoke=True, artifact=artifact)
    assert out["ok"]
    assert out["mode"] == "smoke"
    assert len(out["rungs"]) == 2
    first, second = out["rungs"]
    assert first["n_candidates"] == len(hillclimb.candidate_grid(True))
    assert second["n_candidates"] == max(1, first["n_candidates"] // 2)
    for rung in out["rungs"]:
        assert rung["profile"]["report_solver"] == "batched"
        assert rung["profile"]["report_solve"] >= 0
    if out["winner"] is not None:
        w = out["winner"]
        assert w["feasible"]
        assert w["worst_window_response_s"] <= out["slo_s"]
        assert w["cost"] == hillclimb.config_cost(
            {"n_shards": w["n_shards"],
             "store.n_lines": w["store.n_lines"]})
    on_disk = json.load(open(artifact))
    assert on_disk["slo_s"] == out["slo_s"]
    assert on_disk["ok"]
