"""Page->shard mapping policies (§III): hypothesis property tests."""
import jax.numpy as jnp
import numpy as np
import pytest

try:  # hypothesis fuzz tests are optional (requirements-dev.txt)
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.mapping import MAPPING_POLICIES, page_to_shard, shard_load


@pytest.mark.parametrize("policy", sorted(MAPPING_POLICIES))
def test_owner_in_range_and_deterministic(policy):
    rng = np.random.default_rng(7)
    pages = jnp.asarray(rng.integers(0, 256, 64), jnp.int32)
    o1 = np.asarray(page_to_shard(pages, 8, 256, policy))
    o2 = np.asarray(page_to_shard(pages, 8, 256, policy))
    assert (o1 >= 0).all() and (o1 < 8).all()
    np.testing.assert_array_equal(o1, o2)


if HAVE_HYPOTHESIS:

    @given(
        policy=st.sampled_from(sorted(MAPPING_POLICIES)),
        n_shards=st.integers(1, 16),
        n_pages=st.integers(1, 512),
        seed=st.integers(0, 1000),
    )
    @settings(max_examples=60, deadline=None)
    def test_owner_in_range_and_deterministic_fuzz(
        policy, n_shards, n_pages, seed
    ):
        rng = np.random.default_rng(seed)
        pages = jnp.asarray(rng.integers(0, n_pages, 64), jnp.int32)
        o1 = np.asarray(page_to_shard(pages, n_shards, n_pages, policy))
        o2 = np.asarray(page_to_shard(pages, n_shards, n_pages, policy))
        assert (o1 >= 0).all() and (o1 < n_shards).all()
        np.testing.assert_array_equal(o1, o2)


def test_round_robin_perfectly_balanced():
    pages = jnp.arange(1024, dtype=jnp.int32)
    load = np.asarray(shard_load(pages, 8, 1024, "round_robin"))
    assert load.min() == load.max() == 128


def test_block_is_contiguous():
    pages = jnp.arange(100, dtype=jnp.int32)
    owner = np.asarray(page_to_shard(pages, 4, 100, "block"))
    assert (np.diff(owner) >= 0).all()  # monotone => contiguous ranges


def test_block_cyclic_blocks():
    pages = jnp.arange(64, dtype=jnp.int32)
    owner = np.asarray(page_to_shard(pages, 4, 64, "block_cyclic", block=8))
    for b in range(8):
        blk = owner[b * 8:(b + 1) * 8]
        assert (blk == blk[0]).all()


def test_random_balances_hot_set():
    """Paper §III: random mapping load-balances shared page sets."""
    pages = jnp.asarray(np.random.default_rng(0).integers(0, 4096, 8192),
                        jnp.int32)
    load = np.asarray(shard_load(pages, 8, 4096, "random"))
    assert load.max() < 2.0 * load.mean()
