"""Blockwise XLA attention (production path) vs naive reference, and the
distributed-partial combine identity."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ref import attention_ref
from repro.models.attention import (
    attention_partial, blockwise_attention,
)


@pytest.mark.parametrize("S,H,KV,hd,causal,window,prefix", [
    (64, 4, 2, 16, True, None, 0),
    (100, 4, 1, 8, True, 16, 0),
    (64, 8, 8, 16, False, None, 0),
    (96, 4, 2, 16, True, None, 24),
])
def test_blockwise_matches_naive(S, H, KV, hd, causal, window, prefix, rng):
    B = 2
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    out = blockwise_attention(q, k, v, causal=causal, window=window,
                              prefix_len=prefix, block_q=32, block_kv=16)
    # naive ref in [B,H,S,hd] layout
    ref = attention_ref(
        jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1), jnp.moveaxis(v, 2, 1),
        causal=causal, window=window,
    )
    if prefix:  # prefix-LM not in kernel ref; recompute with mask manually
        import math
        KVh = KV
        G = H // KVh
        qf = np.asarray(q, np.float64).reshape(B, S, KVh, G, hd)
        s = np.einsum("bqkgh,bskh->bkgqs", qf, np.asarray(k, np.float64))
        s /= math.sqrt(hd)
        qpos = np.arange(S)[:, None]
        kpos = np.arange(S)[None, :]
        m = (qpos >= kpos) | (kpos < prefix)
        s = np.where(m[None, None, None], s, -1e30)
        p = np.exp(s - s.max(-1, keepdims=True))
        p /= p.sum(-1, keepdims=True)
        o = np.einsum("bkgqs,bskh->bqkgh", p, np.asarray(v, np.float64))
        expected = o.reshape(B, S, H, hd)
    else:
        expected = np.moveaxis(np.asarray(ref), 1, 2)
    np.testing.assert_allclose(np.asarray(out), expected, atol=3e-5)


def test_two_partials_combine_to_full(rng):
    B, H, KV, hd, T = 3, 8, 4, 16, 40
    q = jnp.asarray(rng.normal(size=(B, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, KV, hd)), jnp.float32)
    valid = jnp.ones((B, T), bool)
    p1 = attention_partial(q, k[:, :25], v[:, :25], valid[:, :25])
    p2 = attention_partial(q, k[:, 25:], v[:, 25:], valid[:, 25:])
    m = jnp.maximum(p1.m, p2.m)
    l = p1.l * jnp.exp(p1.m - m) + p2.l * jnp.exp(p2.m - m)
    acc = (p1.acc * jnp.exp(p1.m - m)[..., None]
           + p2.acc * jnp.exp(p2.m - m)[..., None])
    out = (acc / l[..., None]).reshape(B, H, hd)
    ref = attention_ref(
        q[:, :, None], jnp.moveaxis(k, 2, 1), jnp.moveaxis(v, 2, 1),
        causal=False,
    )[:, :, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
