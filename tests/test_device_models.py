"""Interaction-term regressions (eqs. 8-9): formula expansion + recovery of
the paper's published coefficients (Tables I-IV) from simulated campaigns."""
import numpy as np
import pytest

from repro.core.device_models import (
    PAPER_HDD_READ, PAPER_HDD_WRITE, PAPER_NVME_READ, PAPER_NVME_WRITE,
    expand_formula, fit_hdd_model, fit_nvme_model, fit_ols, kfold_cv,
)


def test_formula_expansion_matches_table_rows():
    terms = expand_formula("x1*x3*x4 + x5*x4*x3")
    names = {":".join(t) for t in terms}
    # exactly the 11 rows of Table I/II (sans intercept)
    assert names == {
        "x1", "x3", "x4", "x5", "x1:x3", "x1:x4", "x3:x4", "x3:x5",
        "x4:x5", "x1:x3:x4", "x3:x4:x5",
    }
    terms = expand_formula("x3*x4 + x5*x1*x2")
    assert len(terms) == 10  # Table III/IV rows


def test_ols_matches_closed_form():
    rng = np.random.default_rng(0)
    x = rng.normal(size=200)
    y = 3.0 * x + 1.0 + rng.normal(size=200) * 0.01
    fit = fit_ols({"x1": x}, y, "x1")
    assert abs(fit.coef[0] - 1.0) < 0.01
    assert abs(fit.coef[1] - 3.0) < 0.01
    assert fit.r2 > 0.99
    assert fit.pvalues[1] < 1e-10


@pytest.mark.parametrize("read", [False, True])
def test_nvme_coefficient_recovery(read):
    m = fit_nvme_model(read=read)
    paper = PAPER_NVME_READ if read else PAPER_NVME_WRITE
    rec = dict(zip(m.fit.term_names(), m.fit.coef))
    # the paper's dominant interaction terms recover within 10%
    for key in ("x1:x3:x4", "x3:x4:x5"):
        assert abs(rec[key] - paper[key]) <= 0.1 * abs(paper[key]), key
    assert m.fit.r2 > 0.98


@pytest.mark.parametrize("read", [False, True])
def test_hdd_coefficient_recovery(read):
    """Check recovery of the terms the paper's own fit marks significant
    (Table III: x5, x5:x1, x5:x2, x5:x1:x2; Table IV: x3, x3:x4, x1:x5 —
    x5 alone is insignificant in the read model, Pr=0.77)."""
    m = fit_hdd_model(read=read)
    paper = PAPER_HDD_READ if read else PAPER_HDD_WRITE
    rec = dict(zip(m.fit.term_names(), m.fit.coef))
    keys = (("x3", "x3:x4", "x1:x5", "x1:x2:x5") if read
            else ("x5", "x1:x5", "x2:x5", "x1:x2:x5"))
    for key in keys:
        assert abs(rec[key] - paper[key]) <= 0.15 * abs(paper[key]), key
    assert m.fit.r2 > 0.97


def test_cv_rmse_finite():
    m = fit_nvme_model(read=False, n_exp=200)
    assert np.isfinite(m.cv_rmse)


def test_service_rate_positive():
    m = fit_nvme_model(read=True)
    mu = m.service_rate(1e5, x1=16, x3=512, x5=32 << 30)
    assert mu > 0
    h = fit_hdd_model(read=True)
    t = h.total_time(x1=16, x2=8, x3=125, x4=524288, x5=5e8)
    assert np.isfinite(t)
