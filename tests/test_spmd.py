"""Multi-device SPMD equivalence, run in a subprocess (needs the
xla_force_host_platform_device_count flag before jax import)."""
import os
import subprocess
import sys

import pytest

SCRIPT = os.path.join(os.path.dirname(__file__), "spmd_eq_script.py")


@pytest.mark.slow
def test_sharded_train_matches_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    out = subprocess.run(
        [sys.executable, SCRIPT], env=env, capture_output=True, text=True,
        timeout=1800,
    )
    assert out.returncode == 0, out.stdout[-3000:] + out.stderr[-3000:]
    assert "SPMD EQUIVALENCE OK" in out.stdout
