"""End-to-end behaviour: train a tiny model for real steps (loss falls),
fault-injection restart drill, checkpoint round-trip, configurator."""
import os

import jax
import numpy as np
import pytest

from repro.launch.train import run_training
from repro.training.checkpoint import CheckpointConfig


def test_train_loss_decreases(tmp_path):
    ck = CheckpointConfig(
        dir_tier1=str(tmp_path / "fast"), dir_tier2=str(tmp_path / "durable"),
        tier1_every=1000, tier2_every=1000,
    )
    out = run_training(
        arch="stablelm-3b", steps=40, batch=4, seq=64,
        data_dir=str(tmp_path / "data"), ckpt=ck, resume=False, log_every=100,
        lr=1e-3,
    )
    losses = out["losses"]
    assert np.mean(losses[-5:]) < np.mean(losses[:5])
    assert out["cache_hits"] + out["cache_misses"] > 0


def test_fault_injection_and_restart(tmp_path):
    ck = CheckpointConfig(
        dir_tier1=str(tmp_path / "fast"), dir_tier2=str(tmp_path / "durable"),
        tier1_every=5, tier2_every=100,
    )
    out1 = run_training(arch="stablelm-3b", steps=20, batch=2, seq=32,
                        data_dir=str(tmp_path / "data"), ckpt=ck, kill_at=12,
                        log_every=100)
    assert out1["killed_at"] == 12
    out2 = run_training(arch="stablelm-3b", steps=20, batch=2, seq=32,
                        data_dir=str(tmp_path / "data"), ckpt=ck,
                        log_every=100)
    # resumed: fewer than 20 fresh steps were run
    assert len(out2["losses"]) <= 12


def test_checkpoint_roundtrip_and_corruption(tmp_path):
    import jax.numpy as jnp

    from repro.training.checkpoint import (
        restore_checkpoint, save_checkpoint,
    )

    ck = CheckpointConfig(dir_tier1=str(tmp_path / "f"),
                          dir_tier2=str(tmp_path / "d"),
                          tier1_every=1, tier2_every=2)
    state = {"a": jnp.arange(8, dtype=jnp.float32),
             "b": {"c": jnp.ones((3, 3), jnp.bfloat16)}}
    save_checkpoint(state, 2, ck)
    got, step = restore_checkpoint(state, ck)
    assert step == 2
    np.testing.assert_array_equal(np.asarray(got["a"]), np.arange(8))
    # corrupt the newest tier-1 copy: restore falls back to tier-2
    import glob
    leaf = sorted(glob.glob(str(tmp_path / "f" / "step_*" / "leaf_*.npy")))[0]
    with open(leaf, "r+b") as f:
        f.seek(200)
        f.write(b"\x00" * 8)
    got2, step2 = restore_checkpoint(state, ck)
    assert step2 == 2  # durable copy still valid


def test_configurator_prefers_equilibrium():
    from repro.core.configurator import configure
    from repro.core.traffic import TrafficSpec

    spec = TrafficSpec(kind="poisson", n_requests=600, n_pages=128)
    cands = configure(spec, arrival_rate=100.0, cache_sizes=(16, 64),
                      k_threads=(1, 16))
    assert cands, "no candidates"
    best = cands[0]
    assert best.equilibrium
    # bigger cache => lower (or equal) miss rate among candidates
    by_size = {}
    for c in cands:
        by_size.setdefault(c.n_lines, c.miss_rate)
    assert by_size[64] <= by_size[16] + 1e-9
