"""Chunked streaming replay: bit-exactness across adversarial chunkings,
checkpoint/resume, tenant-mix attribution, compile-count bounds."""
import numpy as np
import pytest

try:  # hypothesis fuzz tests are optional (requirements-dev.txt)
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

import jax

from repro.core.traffic import (
    TenantSpec,
    TenantStream,
    TrafficSpec,
    tenant_mix,
    tenant_mix_stream,
)
from repro.sim import (
    FaultSpec,
    SimSpec,
    mrc_unsupported_reason,
    shard_down,
    simulate,
    simulate_stream,
    stream_tier1_counters,
    sweep,
    tier1_counters,
)
from repro.sim.engine import report_from_counters
from repro.storage.tiered_store import (
    StoreConfig,
    reset_stream_compile_count,
    run_stream,
    run_stream_chunked,
    stream_compile_count,
    timestamp_window_ids,
)


def assert_counters_equal(a, b):
    for f in a._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
            err_msg=f"Tier1Counters.{f} differs")


@pytest.fixture(scope="module")
def indexed_spec():
    return SimSpec(
        traffic=TrafficSpec(kind="irm", n_requests=1200, n_pages=512,
                            zipf_s=1.1, write_fraction=0.3, seed=3),
        store=StoreConfig(n_lines=64, policy="ws"),
        n_shards=4, n_windows=7,
    )


@pytest.fixture(scope="module")
def indexed_ref(indexed_spec):
    return tier1_counters(indexed_spec)


class TestRunStreamChunked:
    @pytest.mark.parametrize("policy", ["lru", "ws"])
    def test_bit_exact_vs_one_shot(self, policy):
        cfg = StoreConfig(n_lines=32, policy=policy, prefetch=True)
        rng = np.random.default_rng(11)
        pages = rng.integers(0, 200, size=600).astype(np.int32)
        writes = rng.random(600) < 0.25
        ref = run_stream(cfg, pages, writes, n_windows=5)
        for chunk in (7, 64, 600, 1024):
            got = run_stream_chunked(cfg, pages, writes, chunk=chunk,
                                     n_windows=5)
            for f in ref._fields:
                if f == "final_weights":
                    continue  # one-shot pads keep running epoch boundaries
                np.testing.assert_array_equal(
                    np.asarray(getattr(ref, f)), np.asarray(getattr(got, f)),
                    err_msg=f"{policy} chunk={chunk}: {f}")

    def test_chunk_must_be_positive(self):
        cfg = StoreConfig(n_lines=8)
        with pytest.raises(ValueError, match="chunk"):
            run_stream_chunked(cfg, np.zeros(4, np.int32),
                               np.zeros(4, bool), chunk=0)


class TestStreamCountersBitExact:
    @pytest.mark.parametrize("chunk", [11, 173, 600, 1200, 2048])
    def test_window_edge_chunkings(self, indexed_spec, indexed_ref, chunk):
        # 1200 requests over 7 windows: these chunk sizes straddle window
        # edges, split windows across many chunks, and exceed the stream.
        ctr, tenant_ctr, ck = stream_tier1_counters(indexed_spec,
                                                    chunk=chunk)
        assert tenant_ctr is None and ck.done
        assert_counters_equal(indexed_ref, ctr)

    def test_chunk_of_one(self, indexed_spec):
        # Degenerate chunking on a short prefix of the same workload.
        spec = indexed_spec.replace(**{"traffic.n_requests": 40})
        assert_counters_equal(tier1_counters(spec),
                              stream_tier1_counters(spec, chunk=1)[0])

    def test_report_bit_exact(self, indexed_spec, indexed_ref):
        one = report_from_counters(indexed_spec, indexed_ref)
        assert simulate_stream(indexed_spec, chunk=173).to_dict() \
            == one.to_dict()

    def test_trace_override(self, indexed_spec):
        rng = np.random.default_rng(5)
        trace = (rng.integers(0, 300, size=500), rng.random(500) < 0.4)
        assert_counters_equal(
            tier1_counters(indexed_spec, trace),
            stream_tier1_counters(indexed_spec, trace, chunk=99)[0])


class TestWallClockAndFaults:
    @pytest.fixture(scope="class")
    def fault_spec(self):
        return SimSpec(
            traffic=TrafficSpec(kind="irm", n_requests=1500, n_pages=256,
                                zipf_s=1.2, rate=500.0, seed=5),
            store=StoreConfig(n_lines=32),
            n_shards=4, window_dt=0.25,
            faults=FaultSpec(events=(shard_down(1, 0.9, 1.7),)),
        )

    def test_fault_event_straddles_chunks(self, fault_spec):
        # chunk=250 at 500 req/s ~ 0.5 s of arrivals per chunk: the outage
        # window [0.9, 1.7) opens and closes mid-chunk, and wall-clock
        # window edges (0.25 s) never align with chunk edges.
        ref = report_from_counters(fault_spec, tier1_counters(fault_spec))
        for chunk in (250, 499):
            assert simulate_stream(fault_spec, chunk=chunk).to_dict() \
                == ref.to_dict()

    def test_no_donation_path_matches(self, fault_spec):
        ref = tier1_counters(fault_spec)
        assert_counters_equal(
            ref, stream_tier1_counters(fault_spec, chunk=300,
                                       donate=False)[0])


class TestCheckpointResume:
    def test_resume_bit_exact(self, indexed_spec, indexed_ref):
        ctr_p, _, ck = stream_tier1_counters(indexed_spec, chunk=150,
                                             max_requests=487)
        assert not ck.done and ck.offset == 487
        # Partial counters are exact for the consumed prefix.
        assert int(np.asarray(ctr_p.requests).sum()) == 487
        ctr, _, ck2 = stream_tier1_counters(indexed_spec, chunk=321,
                                            checkpoint=ck)
        assert ck2.done
        assert_counters_equal(indexed_ref, ctr)

    def test_partial_report_and_fluid_q0(self):
        spec = SimSpec(
            traffic=TrafficSpec(kind="irm", n_requests=1000, n_pages=256,
                                rate=400.0, seed=2),
            store=StoreConfig(n_lines=32), n_shards=2, window_dt=0.5,
        )
        rep, ck = simulate_stream(spec, chunk=256, max_requests=600)
        assert rep.requests == 600 and not ck.done
        assert ck.fluid_q0 is not None and len(ck.fluid_q0) == 2
        rep_full = simulate_stream(spec, chunk=200, checkpoint=ck)
        assert rep_full.to_dict() == simulate_stream(spec).to_dict()

    def test_resume_rejects_other_spec(self, indexed_spec):
        _, _, ck = stream_tier1_counters(indexed_spec, chunk=200,
                                         max_requests=200)
        other = indexed_spec.replace(**{"store.n_lines": 16})
        with pytest.raises(ValueError, match="cache_signature"):
            stream_tier1_counters(other, checkpoint=ck)


class TestTenantMix:
    @pytest.fixture(scope="class")
    def mix(self):
        return tenant_mix(
            TenantSpec(name="oltp", rate=300.0, n_pages=128, zipf_s=1.3,
                       write_fraction=0.4),
            TenantSpec(name="scan", rate=100.0, n_pages=384, zipf_s=0.9,
                       seed=1),
            n_requests=1600, seed=7)

    def test_generator_chunk_invariant(self, mix):
        full = tenant_mix_stream(mix)
        for chunks in ((1600,), (1, 1599), (7, 700, 893), (512,) * 4):
            gen = TenantStream(mix)
            parts = [gen.take(c) for c in chunks]
            for i in range(4):
                np.testing.assert_array_equal(
                    np.concatenate([p[i] for p in parts]), full[i])

    def test_generator_state_restore(self, mix):
        gen = TenantStream(mix)
        gen.take(700)
        snap = gen.state()
        tail = gen.take(900)
        gen2 = TenantStream(mix)
        gen2.restore(snap)
        for a, b in zip(tail, gen2.take(900)):
            np.testing.assert_array_equal(a, b)

    def test_attribution_reconciles(self, mix):
        spec = SimSpec(traffic=mix, store=StoreConfig(n_lines=64,
                                                      policy="ws"),
                       n_shards=4, window_dt=0.5)
        ref = tier1_counters(spec)  # one-shot drain of the same merge
        ctr, tc, _ = stream_tier1_counters(spec, chunk=300)
        assert_counters_equal(ref, ctr)
        assert tc.names == ("oltp", "scan")
        np.testing.assert_array_equal(
            tc.win_requests.sum(axis=0),
            np.asarray(ctr.win_requests).sum(axis=0))
        np.testing.assert_array_equal(
            tc.win_misses.sum(axis=0),
            np.asarray(ctr.win_misses).sum(axis=0))
        assert int(tc.win_requests.sum()) == mix.n_requests

    def test_simulate_delegates_with_tenant_reports(self, mix):
        spec = SimSpec(traffic=mix, store=StoreConfig(n_lines=64),
                       n_shards=2, window_dt=0.5)
        rep = simulate(spec)
        assert [t.name for t in rep.tenants] == ["oltp", "scan"]
        assert sum(t.requests for t in rep.tenants) == rep.requests
        assert sum(t.misses for t in rep.tenants) == rep.misses
        for t in rep.tenants:
            assert t.response_s.shape == (rep.n_windows,)
            assert t.mean_response_s >= 0.0
        d = rep.to_dict()
        assert len(d["tenants"]) == 2
        assert d["tenants"][0]["name"] == "oltp"

    def test_sweep_routes_tenant_mix(self, mix):
        spec = SimSpec(traffic=mix, store=StoreConfig(n_lines=32),
                       n_shards=2, window_dt=0.5)
        res = sweep(spec, {"lam": [50.0, 100.0]})
        assert all(len(r.tenants) == 2 for r in res.reports)
        off = sweep(spec, {"lam": [50.0, 100.0]}, stream="off")
        assert all(r.tenants == () for r in off.reports)
        for a, b in zip(res.reports, off.reports):
            assert (a.requests, a.misses) == (b.requests, b.misses)

    def test_mrc_fence(self, mix):
        # policy="lru" so the MRC pass is otherwise eligible: the reason
        # reported must be the tenant_mix streaming fence itself.
        spec = SimSpec(traffic=mix,
                       store=StoreConfig(n_lines=32, policy="lru"),
                       n_shards=2, window_dt=0.5)
        assert "tenant_mix" in mrc_unsupported_reason(spec)

    def test_spec_validation(self):
        with pytest.raises(ValueError, match="unique"):
            tenant_mix(TenantSpec(name="a", rate=1.0, n_pages=4),
                       TenantSpec(name="a", rate=1.0, n_pages=4),
                       n_requests=10)
        with pytest.raises(ValueError, match="rate"):
            TenantSpec(name="a", rate=0.0, n_pages=4)
        with pytest.raises(ValueError, match="tenant_mix"):
            TrafficSpec(kind="irm", n_requests=10, n_pages=8,
                        tenants=(TenantSpec(name="a", rate=1.0,
                                            n_pages=8),))


class TestCompileCount:
    def test_at_most_two_buckets(self):
        # Fresh structural config -> cold jit cache for this engine.
        spec = SimSpec(
            traffic=TrafficSpec(kind="irm", n_requests=4000, n_pages=512,
                                zipf_s=1.1, seed=17),
            store=StoreConfig(n_lines=48), n_shards=4, n_windows=3,
        )
        reset_stream_compile_count()
        stream_tier1_counters(spec, chunk=250)  # 16 chunks
        assert stream_compile_count() <= 2
        # More chunkings with the same chunk size: no further compiles.
        stream_tier1_counters(spec, chunk=250, max_requests=999)
        assert stream_compile_count() <= 2


def test_timestamp_binning_is_float64():
    # Long-horizon arrivals: f32 cannot represent 2^24 + 0.5-spaced times,
    # so f32 binning would collapse neighbouring bins. The host-side f64
    # path must keep them distinct.
    t0 = float(2 ** 24)
    times = t0 + 0.5 * np.arange(8)
    n_windows = 2 ** 26
    ids = timestamp_window_ids(times, n_windows, 0.5)
    np.testing.assert_array_equal(
        ids.astype(np.int64), (times / 0.5).astype(np.int64))
    assert len(set(ids.tolist())) == 8  # f32 would merge pairs


if HAVE_HYPOTHESIS:

    _PROP_SPEC = SimSpec(
        traffic=TrafficSpec(kind="irm", n_requests=150, n_pages=64,
                            zipf_s=1.1, write_fraction=0.3, seed=23),
        store=StoreConfig(n_lines=16, policy="ws"),
        n_shards=2, n_windows=4,
    )
    _PROP_REF = None

    @given(chunk=st.integers(1, 160))
    @settings(max_examples=20, deadline=None)
    def test_streamed_equals_one_shot_fuzz(chunk):
        global _PROP_REF
        if _PROP_REF is None:
            _PROP_REF = tier1_counters(_PROP_SPEC)
        ctr, _, _ = stream_tier1_counters(_PROP_SPEC, chunk=chunk)
        assert_counters_equal(_PROP_REF, ctr)

    @given(split=st.integers(1, 149), chunk=st.integers(1, 80))
    @settings(max_examples=15, deadline=None)
    def test_resume_equals_one_shot_fuzz(split, chunk):
        global _PROP_REF
        if _PROP_REF is None:
            _PROP_REF = tier1_counters(_PROP_SPEC)
        _, _, ck = stream_tier1_counters(_PROP_SPEC, chunk=chunk,
                                         max_requests=split)
        ctr, _, _ = stream_tier1_counters(_PROP_SPEC, chunk=chunk,
                                          checkpoint=ck)
        assert_counters_equal(_PROP_REF, ctr)
