"""Property tests (hypothesis) for the OL weight-sharing algorithm."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # hypothesis fuzz tests are optional (requirements-dev.txt)
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import online_learning as ol
from repro.storage.cache_state import init_cache


def _check_weight_adjust(mispred, misses):
    cfg = ol.OLConfig()
    s = ol.init_ol(cfg)
    s = s._replace(
        mispred=jnp.asarray(mispred, jnp.int32),
        epoch_misses=jnp.asarray([misses], jnp.int32),
    )
    out = ol.weight_adjust(s, cfg)
    w = np.asarray(out.weights)
    # normalized simplex
    assert abs(w.sum() - 1.0) < 1e-5
    assert (w > 0).all()
    # epoch state cleared
    assert int(out.epoch_misses[0]) == 0
    assert (np.asarray(out.pred) == -1).all()


@pytest.mark.parametrize("mispred", [[0, 0, 0], [5, 1, 0], [20, 20, 20]])
def test_weight_adjust_properties(mispred):
    _check_weight_adjust(mispred, misses=16)


if HAVE_HYPOTHESIS:

    @given(
        mispred=st.lists(st.integers(0, 20), min_size=3, max_size=3),
        misses=st.integers(1, 64),
    )
    @settings(max_examples=50, deadline=None)
    def test_weight_adjust_properties_fuzz(mispred, misses):
        _check_weight_adjust(mispred, misses)


def test_penalized_expert_loses_weight():
    cfg = ol.OLConfig()
    s = ol.init_ol(cfg)
    s = s._replace(
        mispred=jnp.asarray([10, 0, 0], jnp.int32),
        epoch_misses=jnp.asarray([10], jnp.int32),
    )
    out = ol.weight_adjust(s, cfg)
    w = np.asarray(out.weights)
    assert w[0] < w[1] and w[0] < w[2]


def test_below_threshold_ignored():
    """Paper: mispredictions < THRESHOLD*miss_count are ignored."""
    cfg = ol.OLConfig(threshold=0.25)
    s = ol.init_ol(cfg)
    s = s._replace(
        mispred=jnp.asarray([1, 0, 0], jnp.int32),  # 1 < 0.25*100
        epoch_misses=jnp.asarray([100], jnp.int32),
    )
    out = ol.weight_adjust(s, cfg)
    w = np.asarray(out.weights)
    np.testing.assert_allclose(w, np.ones(3) / 3, atol=1e-6)


def _check_victim_proposals(seed, n):
    cache = init_cache(n)
    # fill half the lines
    k = max(1, n // 2)
    cache = cache._replace(
        valid=cache.valid.at[:k].set(True),
        tags=cache.tags.at[:k].set(jnp.arange(k)),
        ts=cache.ts.at[:k].set(jnp.arange(k)),
        freq=cache.freq.at[:k].set(jnp.arange(k) + 1),
    )
    props = ol.propose_victims(cache, jax.random.PRNGKey(seed))
    p = np.asarray(props)
    assert (p >= 0).all() and (p < n).all()
    assert (p < k).all()  # only valid lines
    assert p[0] == 0      # LRU = oldest ts
    assert p[1] == 0      # LFU = lowest freq


@pytest.mark.parametrize("seed,n", [(0, 2), (3, 8), (11, 32)])
def test_victim_proposals_valid(seed, n):
    _check_victim_proposals(seed, n)


if HAVE_HYPOTHESIS:

    @given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 32))
    @settings(max_examples=30, deadline=None)
    def test_victim_proposals_valid_fuzz(seed, n):
        _check_victim_proposals(seed, n)


def test_pinned_lines_never_proposed():
    cache = init_cache(8)
    cache = cache._replace(
        valid=cache.valid.at[:].set(True),
        ts=cache.ts.at[:].set(jnp.arange(8)),
        freq=cache.freq.at[:].set(jnp.arange(8) + 1),
    )
    pinned = jnp.zeros(8, bool).at[0].set(True).at[1].set(True)
    for seed in range(5):
        p = np.asarray(ol.propose_victims(cache, jax.random.PRNGKey(seed), pinned))
        assert (p >= 2).all()
