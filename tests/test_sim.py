"""End-to-end simulator (repro.sim): §V golden numbers + sweep behavior."""
import json
import math

import numpy as np
import pytest

from repro.core.traffic import TrafficSpec
from repro.sim import (
    PAPER_MU1,
    PAPER_MU2,
    RateSpec,
    SimSpec,
    expand_grid,
    simulate,
    sweep,
    tier1_counters,
    report_from_counters,
)
from repro.storage.tiered_store import StoreConfig

WORKED = SimSpec(
    traffic=TrafficSpec(kind="irm", n_requests=1500, n_pages=512,
                        write_fraction=0.3, seed=7),
    store=StoreConfig(n_lines=64, policy="ws"),
    n_shards=4,
    lam=100.0,
    k_servers=1,
    rates=RateSpec(source="paper"),
    p12_override=0.2,  # the §V worked example fixes the miss fraction
)


def test_worked_example_paper_flow():
    """§V: lam=100, mu1=1000, mu2=33, p12=0.2 through the full pipeline."""
    rep = simulate(WORKED.replace(flow="paper"))
    assert abs(rep.lam_eff - 86.6) < 1e-9
    assert abs(rep.rho1 - 0.0866) < 1e-4
    assert abs(rep.rho2 - 20 / 33) < 1e-9
    # Eq. 5 composed service rate: 1 / (0.8/1000 + 0.2/33).
    assert abs(rep.mu_system - 1.0 / (0.8 / PAPER_MU1 + 0.2 / PAPER_MU2)) < 1e-9
    # Residence times: W = Wq + 1/mu for each queue.
    lam_miss = 0.2 * 100.0
    rho2 = lam_miss / PAPER_MU2
    wq2 = (rho2 * rho2 / (1 - rho2)) / lam_miss
    assert abs(rep.w2 - (wq2 + 1 / PAPER_MU2)) < 1e-12
    rho1 = 86.6 / PAPER_MU1
    wq1 = (rho1 * rho1 / (1 - rho1)) / 86.6
    assert abs(rep.w1 - (wq1 + 1 / PAPER_MU1)) < 1e-12
    assert rep.equilibrium
    # Every shard uses the pinned p12 => identical queue solutions.
    assert all(abs(s.lam_eff - 86.6) < 1e-9 for s in rep.shards)
    # "The expected length of the tier 1 queue is almost 0."
    assert rep.response_s < 0.1


def test_worked_example_conserving_flow():
    rep = simulate(WORKED.replace(flow="conserving"))
    assert abs(rep.lam_eff - 100.0) < 1e-9
    assert abs(rep.rho1 - 0.1) < 1e-9
    assert abs(rep.rho2 - 20 / 33) < 1e-9  # miss queue identical
    assert rep.equilibrium


def test_measured_p12_and_counter_mapping():
    """Without the override, p12 is the measured miss rate and the counter
    -> queuing mapping is exact."""
    spec = WORKED.replace(p12_override=None)
    ctr = tier1_counters(spec)
    rep = report_from_counters(spec, ctr)
    assert rep.requests == spec.traffic.n_requests
    assert rep.hits + rep.misses == rep.requests
    assert abs(rep.p12 - rep.misses / rep.requests) < 1e-12
    # Per-shard read/write split feeds eq. 1.
    reads = sum(s.reads for s in rep.shards)
    writes = sum(s.writes for s in rep.shards)
    assert reads + writes == rep.requests
    assert writes > 0  # write_fraction=0.3
    # Eqs. 1-4: T is the max over per-shard service times.
    t_proc = np.asarray(rep.min_time.t_proc)
    assert rep.t_total_s == pytest.approx(float(t_proc.max()))
    assert rep.min_time_throughput_rps == pytest.approx(
        rep.requests / rep.t_total_s)


def test_device_model_rates():
    """source="devices" wires the fitted NVMe/HDD behavioral models in."""
    rep = simulate(WORKED.replace(
        p12_override=None, rates=RateSpec(source="devices")))
    assert rep.rates.mu1 > 0 and rep.rates.mu2 > 0
    assert rep.rates.mu1 > rep.rates.mu2  # NVMe tier is faster than HDD tier
    assert math.isfinite(rep.response_s)


def test_report_json_round_trip():
    rep = simulate(WORKED)
    d = rep.to_dict()
    text = json.dumps(d)
    back = json.loads(text)
    assert back["lam_eff"] == pytest.approx(86.6)
    assert len(back["shards"]) == 4
    assert back["spec"]["flow"] == "paper"
    assert back["min_time"]["t_total"] == pytest.approx(rep.t_total_s)


def test_report_to_dict_is_plain_python():
    """Regression (ISSUE 4 satellite): SimReport.to_dict must emit plain
    Python values — np.int64/np.float64/np.bool_ used to leak through, so
    json.dumps without a default= hook is the gate."""
    spec = WORKED.replace(
        p12_override=None, n_windows=3,
        rates=RateSpec(source="paper",
                       mu1_shards=(4000.0, 2000.0, 1000.0, 500.0)),
    )
    d = simulate(spec).to_dict()
    json.dumps(d)  # raises TypeError on any leaked numpy scalar/array

    def walk(x, path="root"):
        if isinstance(x, dict):
            for k, v in x.items():
                assert type(k) is str, f"non-str key at {path}: {type(k)}"
                walk(v, f"{path}.{k}")
        elif isinstance(x, list):
            for i, v in enumerate(x):
                walk(v, f"{path}[{i}]")
        else:
            assert x is None or type(x) in (bool, int, float, str), (
                f"non-plain value at {path}: {type(x)}")

    walk(d)
    # Windowed / transient sections are present and list-typed.
    assert len(d["transient"]["rho2"]) == 3
    assert len(d["windows"]["requests"]) == spec.n_shards
    assert all(s["saturation_onset"] is None or
               isinstance(s["saturation_onset"], int) for s in d["shards"])


def test_expand_grid():
    pts = expand_grid({"a": [1, 2], "b": ["x", "y", "z"]})
    assert len(pts) == 6
    assert {"a": 1, "b": "z"} in pts
    assert expand_grid({}) == [{}]


def test_sweep_miss_rate_monotonic_in_cache_size():
    """Smoke test: on an IRM stream, a bigger tier-1 cache never misses
    more (single shard, LRU to keep replacement deterministic)."""
    base = SimSpec(
        traffic=TrafficSpec(kind="irm", n_requests=1200, n_pages=256, seed=3),
        store=StoreConfig(n_lines=8, policy="lru"),
        n_shards=1,
        lam=10.0,
        rates=RateSpec(source="paper"),
    )
    sizes = [8, 32, 128, 256]
    res = sweep(base, {"store.n_lines": sizes})
    rates = [rep.miss_rate for rep in res.reports]
    assert all(a >= b - 1e-12 for a, b in zip(rates, rates[1:])), rates
    assert rates[0] > rates[-1]  # the sweep axis actually matters


def test_sweep_batched_matches_unbatched():
    base = SimSpec(
        traffic=TrafficSpec(kind="irm", n_requests=600, n_pages=128, seed=1),
        store=StoreConfig(n_lines=16, policy="ws"),
        n_shards=2,
        rates=RateSpec(source="paper"),
    )
    axes = {"store.policy": ["lru", "ws"], "traffic.kind": ["irm", "markov"]}
    a = sweep(base, axes, batch=True)
    b = sweep(base, axes, batch=False)
    for ra, rb in zip(a.reports, b.reports):
        assert ra.misses == rb.misses
        assert ra.hits == rb.hits
        assert ra.p12 == pytest.approx(rb.p12)


def test_sweep_dedupes_cache_runs():
    """Queuing-only axes (lam, flow) must reuse one tier-1 run."""
    base = SimSpec(
        traffic=TrafficSpec(kind="irm", n_requests=400, n_pages=64, seed=1),
        store=StoreConfig(n_lines=16, policy="lru"),
        n_shards=2,
        rates=RateSpec(source="paper"),
    )
    res = sweep(base, {"lam": [10.0, 50.0], "flow": ["paper", "conserving"]})
    assert len(res.reports) == 4
    sigs = {base.replace(**pt).cache_signature() for pt in res.points}
    assert len(sigs) == 1  # one cache simulation for all four points
    assert len({rep.misses for rep in res.reports}) == 1
    assert len({rep.lam_eff for rep in res.reports}) == 4


def test_sweep_to_json_handles_numpy_bools():
    """Regression: np.bool_ is neither np.integer nor np.floating, so a
    boolean metric used to raise TypeError in SweepResult.to_json()."""
    from repro.sim.sweep import _jsonify

    assert _jsonify(np.bool_(True)) is True
    assert _jsonify(np.bool_(False)) is False
    assert _jsonify(np.int32(3)) == 3
    assert _jsonify(np.float32(1.5)) == 1.5
    payload = {"equilibrium": np.bool_(True), "misses": np.int64(7)}
    assert json.loads(json.dumps(payload, default=_jsonify)) == {
        "equilibrium": True, "misses": 7}
    with pytest.raises(TypeError):
        _jsonify(object())
    # End to end: a sweep artifact with injected numpy bools serializes.
    res = sweep(WORKED.replace(**{"traffic.n_requests": 200}),
                {"lam": [10.0, 50.0]})
    text = res.to_json()
    assert json.loads(text)["n_points"] == 2


def test_per_shard_rate_heterogeneity():
    """Tables VII-IX strong scaling: per-shard mu1/mu2 vectors give each
    shard its own queue solution and feed eqs. 1-4 as rate vectors."""
    spec = WORKED.replace(
        p12_override=None,
        rates=RateSpec(source="paper",
                       mu1_shards=(4000.0, 2000.0, 1000.0, 500.0),
                       mu2_shards=(66.0, 33.0, 33.0, 33.0)),
        lam=20.0,
    )
    ctr = tier1_counters(spec)
    rep = report_from_counters(spec, ctr)
    # Scalar rates default to across-shard means.
    assert rep.rates.mu1 == pytest.approx(np.mean([4000, 2000, 1000, 500]))
    assert rep.rates.mu2 == pytest.approx(np.mean([66, 33, 33, 33]))
    # Shards with slower devices wait longer (equal p12 would be needed for
    # strict monotonicity, so compare the two extreme shards' service part).
    per_shard_mu1 = [4000.0, 2000.0, 1000.0, 500.0]
    for s, mu1 in zip(rep.shards, per_shard_mu1):
        assert s.w1 >= 1.0 / mu1  # residence >= pure service at shard's rate
    # eqs. 1-4 use per-shard rates: recompute t_hit for shard 0 by hand.
    t_hit0 = (rep.shards[0].reads / 4000.0) + (rep.shards[0].writes / 4000.0)
    assert np.asarray(rep.min_time.t_hit)[0] == pytest.approx(t_hit0)
    # Homogeneous spec reproduces the scalar-rate behavior bit for bit.
    hom_vec = report_from_counters(
        WORKED.replace(p12_override=None,
                       rates=RateSpec(source="paper",
                                      mu1_shards=(PAPER_MU1,) * 4)), ctr)
    hom = report_from_counters(
        WORKED.replace(p12_override=None, rates=RateSpec(source="paper")),
        ctr)
    assert hom_vec.t_total_s == hom.t_total_s
    assert hom_vec.response_s == hom.response_s


def test_per_shard_rate_validation():
    with pytest.raises(ValueError):
        WORKED.replace(rates=RateSpec(source="paper", mu1_shards=(1.0, 2.0)))
    with pytest.raises(ValueError):
        RateSpec(source="paper", mu2_shards=(33.0, -1.0, 33.0, 33.0)).resolve()
    with pytest.raises(ValueError):
        RateSpec(source="paper", mu1_shards=()).resolve()


def test_spec_validation():
    with pytest.raises(ValueError):
        SimSpec(traffic=WORKED.traffic, flow="bogus")
    with pytest.raises(ValueError):
        SimSpec(traffic=WORKED.traffic, p12_override=1.5)
    with pytest.raises(ValueError):
        RateSpec(source="nope").resolve()
    with pytest.raises(ValueError):
        RateSpec(source="paper", mu2=-1.0).resolve()


def test_block_mapping_uses_declared_page_space():
    """The §III block mapping must partition the *declared* traffic page
    space, not the data-inferred max page id (regression)."""
    spec = SimSpec(
        traffic=TrafficSpec(kind="poisson", n_requests=400, n_pages=1024,
                            seed=0),
        store=StoreConfig(n_lines=16, policy="lru"),
        n_shards=4,
        mapping="block",
        rates=RateSpec(source="paper"),
    )
    ctr = tier1_counters(spec)
    # Poisson traffic touches only low page ids; over the declared
    # 1024-page space, blocks of 256 put every request on shard 0.
    assert ctr.requests[0] == 400
    assert ctr.requests[1:].sum() == 0


def test_zero_miss_shard_does_not_crash():
    """p12 = 0 (no misses) must give an empty, stable miss queue, not a
    division by zero (regression: mm1_queue(lam=0))."""
    rep = simulate(WORKED.replace(p12_override=0.0))
    assert rep.equilibrium
    assert rep.w2 == pytest.approx(1 / PAPER_MU2)  # pure service time
    # A 1-request workload leaves most shards empty (p12 = 0, stable and
    # finite); the one loaded shard has p12 = 1, which at lam=100 > mu2=33
    # correctly reports a saturated (non-equilibrium) miss queue.
    tiny = simulate(WORKED.replace(
        p12_override=None, **{"traffic.n_requests": 1}))
    assert tiny.requests == 1
    for s in tiny.shards:
        if s.requests == 0:
            assert s.equilibrium and math.isfinite(s.response_s)
        else:
            assert s.p12 == 1.0 and not s.equilibrium
    assert not tiny.equilibrium


def test_saturated_tier1_with_zero_p12_is_inf_not_nan():
    """inf + 0*inf must not poison response_s (regression)."""
    rep = simulate(WORKED.replace(lam=2000.0, p12_override=0.0))
    assert not rep.equilibrium
    assert math.isinf(rep.response_s)
    assert all(math.isinf(s.response_s) for s in rep.shards)


def test_zero_offered_rate_is_idle_not_crash():
    """Regression: lam=0 (idle system) must produce a finite idle report,
    not a ZeroDivisionError in the window-duration computation."""
    rep = simulate(WORKED.replace(lam=0.0, n_windows=4))
    assert rep.equilibrium
    assert rep.window_duration_s == 0.0
    assert np.asarray(rep.windows.lam).max() == 0.0
    assert rep.saturation_onset is None
    assert math.isfinite(rep.response_s)


def test_user_trace_input():
    """simulate() accepts a user-provided trace instead of TrafficSpec."""
    pages = np.tile(np.arange(8, dtype=np.int32), 50)
    writes = np.zeros_like(pages, dtype=bool)
    rep = simulate(WORKED.replace(p12_override=None, n_shards=2),
                   trace=(pages, writes))
    assert rep.requests == 400
    assert rep.misses == 8  # cold misses only: working set fits
