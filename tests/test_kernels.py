"""Pallas kernels vs pure-jnp oracles (interpret mode, shape/dtype sweeps)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("B,H,KV,S,hd,causal,window", [
    (2, 4, 2, 128, 32, True, None),
    (1, 4, 1, 256, 16, True, 64),
    (2, 2, 2, 128, 32, False, None),
    (1, 8, 8, 128, 64, True, None),
])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_flash_attention(B, H, KV, S, hd, causal, window, dtype, rng):
    dt = jnp.dtype(dtype)
    q = jnp.asarray(rng.normal(size=(B, H, S, hd)), dt)
    k = jnp.asarray(rng.normal(size=(B, KV, S, hd)), dt)
    v = jnp.asarray(rng.normal(size=(B, KV, S, hd)), dt)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=64, block_kv=64, interpret=True)
    expected = ref.attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == "float32" else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expected, np.float32),
        atol=tol, rtol=tol)


@pytest.mark.parametrize("B,H,KV,hd,page,n_pages,slots", [
    (2, 4, 2, 16, 8, 6, 8),
    (1, 8, 8, 32, 16, 4, 4),
    (3, 4, 1, 16, 8, 5, 16),
])
def test_paged_attention(B, H, KV, hd, page, n_pages, slots, rng):
    q = jnp.asarray(rng.normal(size=(B, H, hd)), jnp.float32)
    pool = jnp.asarray(rng.normal(size=(slots, page, 2, KV, hd)), jnp.float32)
    ps = jnp.asarray(rng.integers(-1, slots, size=(B, n_pages)), jnp.int32)
    lengths = jnp.asarray(rng.integers(1, page * n_pages, size=(B,)), jnp.int32)
    acc, m, l = ops.paged_attention(q, pool, ps, lengths, interpret=True)
    racc, rm, rl = ref.paged_attention_ref(q, pool, ps, lengths)
    np.testing.assert_allclose(np.asarray(acc), np.asarray(racc).reshape(B, H, hd),
                               atol=3e-5, rtol=3e-5)
    np.testing.assert_allclose(np.asarray(l), np.asarray(rl).reshape(B, H),
                               atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("Sd,Ss,R,C,N", [(6, 9, 4, 32, 5), (3, 3, 8, 16, 2)])
def test_page_copy(Sd, Ss, R, C, N, rng):
    dst = jnp.asarray(rng.normal(size=(Sd, R, C)), jnp.float32)
    src = jnp.asarray(rng.normal(size=(Ss, R, C)), jnp.float32)
    di = rng.integers(-1, Sd, size=(N,)).astype(np.int32)
    si = rng.integers(-1, Ss, size=(N,)).astype(np.int32)
    seen = set()
    for i in range(N):  # unique dst rows (copy order is unspecified)
        if di[i] in seen:
            di[i] = -1
        seen.add(di[i])
    out = ops.page_copy(dst, src, jnp.asarray(di), jnp.asarray(si),
                        interpret=True)
    expected = ref.page_copy_ref(dst, src, jnp.asarray(di), jnp.asarray(si))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expected))


@pytest.mark.parametrize("B,S,W,bw,ch", [(2, 64, 32, 16, 16),
                                         (1, 128, 64, 64, 32)])
def test_rglru_scan(B, S, W, bw, ch, rng):
    u = jnp.asarray(rng.normal(size=(B, S, W)), jnp.float32)
    ps = [jnp.asarray(rng.normal(size=(W,)) * 0.5, jnp.float32)
          for _ in range(5)]
    out = ops.rglru_scan(u, *ps, block_w=bw, chunk=ch, interpret=True)
    expected = ref.rglru_ref(u, *ps)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected), atol=1e-5)


@pytest.mark.parametrize("B,S,H,P,N,Q", [(2, 64, 3, 8, 16, 16),
                                         (1, 128, 2, 16, 8, 32)])
def test_ssd_scan(B, S, H, P, N, Q, rng):
    x = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=(B, S, H))) * 0.5 + 0.01,
                     jnp.float32)
    A = jnp.asarray(-np.abs(rng.normal(size=(H,))), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    out = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=Q, interpret=True)
    expected = ref.ssd_ref(x, dt, A, Bm, Cm)
    rel = float(jnp.abs(out - expected).max() / (jnp.abs(expected).max()))
    assert rel < 1e-5
