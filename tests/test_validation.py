"""Input-validation hardening: nonsensical specs raise clear ValueErrors at
construction time instead of surfacing as NaN reports downstream."""
import numpy as np
import pytest

from repro.core.queuing import RetryPolicy, transient_two_tier
from repro.core.traffic import TrafficSpec
from repro.sim import (
    FaultEvent,
    FaultSpec,
    RateSpec,
    SimSpec,
    device_degrade,
    shard_down,
    tier2_outage,
)


def _traffic(**kw):
    base = dict(kind="irm", n_requests=100, n_pages=64)
    base.update(kw)
    return TrafficSpec(**base)


def _spec(**kw):
    base = dict(traffic=_traffic(), n_shards=2, lam=10.0,
                rates=RateSpec(mu1=100.0, mu2=33.0))
    base.update(kw)
    return SimSpec(**base)


# --- TrafficSpec ----------------------------------------------------------

def test_traffic_n_requests_must_be_positive():
    with pytest.raises(ValueError, match="n_requests must be positive"):
        _traffic(n_requests=0)


def test_traffic_n_pages_must_be_positive():
    with pytest.raises(ValueError, match="n_pages must be positive"):
        _traffic(n_pages=-1)


def test_traffic_write_fraction_range():
    with pytest.raises(ValueError, match=r"write_fraction must be in \[0, 1\]"):
        _traffic(write_fraction=1.5)
    # Boundaries are legal (pure-read / pure-write workloads).
    _traffic(write_fraction=0.0)
    _traffic(write_fraction=1.0)


def test_traffic_rate_non_negative():
    with pytest.raises(ValueError, match="rate must be non-negative"):
        _traffic(rate=-1.0)
    _traffic(rate=0.0)  # 0 = unset, the caller supplies a default


def test_traffic_burst_rate_non_negative():
    with pytest.raises(ValueError, match="burst_rate must be non-negative"):
        _traffic(burst_rate=-5.0)


# --- RateSpec -------------------------------------------------------------

@pytest.mark.parametrize("field", ["mu1", "mu2", "mu1_read", "mu1_write"])
def test_rates_mu_must_be_positive(field):
    with pytest.raises(ValueError, match=f"{field} must be a positive rate"):
        RateSpec(**{field: 0.0})


def test_rates_zero_mu_points_at_faults():
    """The error explains that failed devices are modeled with faults."""
    with pytest.raises(ValueError, match="SimSpec.faults"):
        RateSpec(mu1=0.0)


@pytest.mark.parametrize("field", ["mu1_shards", "mu2_shards"])
def test_rates_shard_vectors_positive_nonempty(field):
    with pytest.raises(ValueError, match=f"{field} must be a non-empty"):
        RateSpec(**{field: ()})
    with pytest.raises(ValueError, match=f"{field} must be a non-empty"):
        RateSpec(**{field: (100.0, 0.0)})


def test_rates_operating_points_positive():
    with pytest.raises(ValueError, match="n_requests_op must be positive"):
        RateSpec(n_requests_op=0)
    with pytest.raises(ValueError, match="n_stripes_op must be positive"):
        RateSpec(n_stripes_op=-1)


# --- SimSpec --------------------------------------------------------------

def test_sim_lam_non_negative():
    with pytest.raises(ValueError, match="lam .* must be non-negative"):
        _spec(lam=-1.0)
    _spec(lam=0.0)  # idle system is a legal regime


def test_sim_k_servers_at_least_one():
    with pytest.raises(ValueError, match="k_servers must be >= 1"):
        _spec(k_servers=0)


def test_sim_faults_require_wall_clock_windows():
    with pytest.raises(ValueError, match="set window_dt"):
        _spec(faults=FaultSpec(events=(shard_down(0, 1.0, 2.0),)))


def test_sim_faults_require_fluid_mode():
    with pytest.raises(ValueError, match="transient_mode='fluid'"):
        _spec(window_dt=1.0, transient_mode="piecewise",
              faults=FaultSpec(retry=RetryPolicy(timeout=0.1)))


def test_sim_faults_shard_index_in_range():
    with pytest.raises(ValueError, match="names shard 5"):
        _spec(window_dt=1.0,
              faults=FaultSpec(events=(shard_down(5, 1.0, 2.0),)))


# --- FaultEvent / FaultSpec ----------------------------------------------

def test_fault_event_unknown_kind():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent(kind="meteor_strike", t0=0.0, t1=1.0)


def test_fault_event_interval_ordering():
    with pytest.raises(ValueError, match="0 <= t0 < t1"):
        shard_down(0, 2.0, 1.0)
    with pytest.raises(ValueError, match="0 <= t0 < t1"):
        tier2_outage(-1.0, 1.0)


def test_fault_event_degrade_factor_range():
    with pytest.raises(ValueError, match=r"factor .* in \[0, 1\]"):
        device_degrade(1, 1.5, 0.0, 1.0)


def test_fault_event_degrade_tier():
    with pytest.raises(ValueError, match="tier must be 1 or 2"):
        device_degrade(3, 0.5, 0.0, 1.0)


def test_fault_event_shard_down_needs_shard():
    with pytest.raises(ValueError, match="concrete shard index"):
        FaultEvent(kind="shard_down", t0=0.0, t1=1.0)


def test_fault_spec_rejects_non_events():
    with pytest.raises(ValueError, match="FaultEvent instances"):
        FaultSpec(events=("shard_down",))


# --- RetryPolicy ----------------------------------------------------------

def test_retry_policy_validation():
    with pytest.raises(ValueError, match="timeout must be > 0"):
        RetryPolicy(timeout=0.0)
    with pytest.raises(ValueError, match="max_retries must be >= 0"):
        RetryPolicy(timeout=0.1, max_retries=-1)
    with pytest.raises(ValueError, match="backoff_base must be >= 1"):
        RetryPolicy(timeout=0.1, backoff_base=0.5)
    with pytest.raises(ValueError, match="backoff_init must be >= 0"):
        RetryPolicy(timeout=0.1, backoff_init=-1.0)
    with pytest.raises(ValueError, match="backoff_cap must be >= 0"):
        RetryPolicy(timeout=0.1, backoff_cap=-1.0)
    with pytest.raises(ValueError, match=r"jitter must be in \[0, 1\)"):
        RetryPolicy(timeout=0.1, jitter=1.0)


def test_retry_policy_delays():
    p = RetryPolicy(timeout=0.1, max_retries=3, backoff_base=2.0,
                    backoff_init=0.5, backoff_cap=1.5)
    np.testing.assert_allclose(p.delays(), [0.5, 1.0, 1.5])
    # backoff_init defaults to the timeout itself.
    np.testing.assert_allclose(
        RetryPolicy(timeout=0.2, max_retries=2).delays(), [0.2, 0.4])


# --- solver-level guards --------------------------------------------------

def test_piecewise_mode_rejects_fault_dynamics():
    lam = np.full(4, 10.0)
    p12 = np.full(4, 0.2)
    with pytest.raises(ValueError, match="fluid-only"):
        transient_two_tier(lam, p12, 100.0, 33.0, mode="piecewise",
                           retry=RetryPolicy(timeout=0.1))
    with pytest.raises(ValueError, match="fluid-only"):
        transient_two_tier(lam, p12, 100.0, 33.0, mode="piecewise",
                           tier1_spill=True)
