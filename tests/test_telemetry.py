"""Time-resolved pipeline (ISSUE 4): windowed engine telemetry, transient
queuing solves and non-stationary traffic.

The reconciliation tests are the load-bearing ones: windowed counters must
sum *exactly* (bit-exact integer arithmetic) to the whole-stream counters
for every policy x mapping x prefetch combination, on both the direct and
the distributed/padded paths.
"""
import json

import numpy as np
import pytest

from repro.core.mapping import MAPPING_POLICIES
from repro.core.queuing import transient_two_tier
from repro.core.traffic import (
    TrafficSpec,
    make_stream,
    onoff_stream,
    phase_schedule,
    phased_stream,
)
from repro.sim import RateSpec, SimSpec, simulate
from repro.storage.tiered_store import (
    POLICY_TO_IDX,
    StoreConfig,
    partition_streams,
    partition_window_ids,
    run_distributed,
    run_stream,
    stream_window_ids,
)

ALL_POLICIES = sorted(POLICY_TO_IDX)
ALL_MAPPINGS = sorted(MAPPING_POLICIES)
WINDOWED = [
    ("requests", "win_requests"),
    ("hits", "win_hits"),
    ("misses", "win_misses"),
    ("prefetch_hits", "win_prefetch_hits"),
    ("tier2_reads", "win_tier2_reads"),
    ("tier2_writes", "win_tier2_writes"),
    ("evictions", "win_evictions"),
]


def _assert_windows_reconcile(stats, *, requests=None):
    """Every windowed counter sums (over the window axis) to its
    whole-stream counterpart, exactly."""
    for total_name, win_name in WINDOWED:
        total = np.asarray(getattr(stats, total_name), np.int64)
        win = np.asarray(getattr(stats, win_name), np.int64)
        np.testing.assert_array_equal(
            win.sum(axis=-1), total,
            err_msg=f"{win_name} does not sum to {total_name}",
        )
    if requests is not None:
        assert int(np.asarray(stats.requests).sum()) == requests


@pytest.mark.parametrize("policy", ALL_POLICIES)
@pytest.mark.parametrize("prefetch", [False, True])
def test_single_shard_windows_reconcile(policy, prefetch):
    spec = TrafficSpec(kind="mixed", n_requests=600, n_pages=128,
                       write_fraction=0.3, seed=11)
    pages, writes = make_stream(spec)
    cfg = StoreConfig(n_lines=32, policy=policy, prefetch=prefetch)
    st = run_stream(cfg, pages, writes, n_windows=7)
    _assert_windows_reconcile(st, requests=600)
    # Window ids partition the stream into near-equal slices.
    np.testing.assert_array_equal(
        np.asarray(st.win_requests),
        np.bincount(stream_window_ids(600, 7), minlength=7),
    )


@pytest.mark.parametrize("policy", ALL_POLICIES)
@pytest.mark.parametrize("mapping", ALL_MAPPINGS)
@pytest.mark.parametrize("prefetch", [False, True])
def test_distributed_windows_reconcile(policy, mapping, prefetch):
    """Windowed totals are bit-exact with the (padding-corrected)
    whole-stream counters for every policy x mapping x prefetch combo."""
    pages, writes = make_stream(TrafficSpec(
        kind="irm", n_requests=500, n_pages=96, write_fraction=0.25, seed=3))
    stats, counts = run_distributed(
        StoreConfig(n_lines=16, policy=policy, prefetch=prefetch),
        pages, writes, n_shards=4, mapping=mapping, n_pages=96, n_windows=5,
    )
    _assert_windows_reconcile(stats, requests=500)
    # Each global window holds an equal slice of the stream (summed over
    # shards), regardless of how the mapping skews per-shard load.
    np.testing.assert_array_equal(
        np.asarray(stats.win_requests).sum(axis=0),
        np.bincount(stream_window_ids(500, 5), minlength=5),
    )


def test_windows_independent_of_padding_cap():
    """Window ids ride the global stream position, so windowed counters are
    bit-identical whatever padded cap the engine ran at."""
    pages, writes = make_stream(TrafficSpec(
        kind="poisson", n_requests=300, n_pages=64, write_fraction=0.2,
        seed=9))
    sh_p, sh_w, counts, owner = partition_streams(
        pages, writes, n_shards=3, mapping="random", n_pages=64)
    base_cap = sh_p.shape[1]
    results = []
    for cap in (base_cap, 2 * base_cap):
        sh_p2, sh_w2, c2, o2 = partition_streams(
            pages, writes, n_shards=3, mapping="random", n_pages=64, cap=cap)
        wi = partition_window_ids(o2, c2, cap, 4)
        import jax
        import jax.numpy as jnp
        stats = jax.vmap(
            lambda p, w, i: run_stream(
                StoreConfig(n_lines=16, policy="lru"), p, w,
                n_windows=4, window_ids=i)
        )(jnp.asarray(sh_p2), jnp.asarray(sh_w2), jnp.asarray(wi))
        results.append(stats)
    for _, win_name in WINDOWED:
        np.testing.assert_array_equal(
            np.asarray(getattr(results[0], win_name)),
            np.asarray(getattr(results[1], win_name)),
            err_msg=f"{win_name} depends on the padding cap",
        )


def test_simulate_windowed_report():
    spec = SimSpec(
        traffic=TrafficSpec(kind="irm", n_requests=1200, n_pages=256,
                            write_fraction=0.3, seed=7),
        store=StoreConfig(n_lines=64, policy="ws"),
        n_shards=4, lam=100.0, rates=RateSpec(source="paper"), n_windows=6,
    )
    rep = simulate(spec)
    assert rep.n_windows == 6
    assert rep.windows.requests.shape == (4, 6)
    # The report's window series reconciles with the per-shard totals.
    for total_name, _ in WINDOWED:
        totals = np.array([getattr(s, total_name) for s in rep.shards])
        win = np.asarray(getattr(rep.windows, total_name))
        np.testing.assert_array_equal(win.sum(axis=-1), totals,
                                      err_msg=total_name)
    # Window durations tile the stream's arrival span.
    assert rep.window_duration_s * 6 == pytest.approx(
        rep.requests / (spec.lam * spec.n_shards))
    # The pooled per-process arrival rate is ~lam in every window (equal
    # request-count windows by construction).
    np.testing.assert_allclose(
        rep.windows.lam.sum(axis=0) / spec.n_shards,
        np.full(6, spec.lam), rtol=0.05)
    # n_windows=1 degenerates to the historic steady-state-only report.
    rep1 = simulate(spec.replace(n_windows=1))
    assert rep1.transient.response.shape == (1,)
    assert rep1.misses == rep.misses


def test_warmup_curve_converges_to_steady_state():
    """Cold-cache warm-up: early windows miss more than late ones, and the
    tail-window transient response matches a steady-state solve at the
    tail-window miss fraction."""
    spec = SimSpec(
        traffic=TrafficSpec(kind="markov", n_requests=4000, n_pages=256,
                            n_hot_states=24, seed=5),
        store=StoreConfig(n_lines=64, policy="lru"),
        n_shards=2, lam=40.0, rates=RateSpec(source="paper"), n_windows=8,
        mapping="block_cyclic",
    )
    rep = simulate(spec)
    p12_w = np.asarray(rep.transient.p12)
    assert p12_w[0] > p12_w[-1]  # cold start misses more
    # Tail windows have settled: late-window p12 is near the tail mean.
    tail = p12_w[4:]
    assert abs(p12_w[-1] - tail.mean()) < 0.05
    # The default transient is the fluid solve: its settled tail agrees
    # with an independent stationary solve at the tail window's measured
    # inputs to within the carryover residue (~1%).
    tr = transient_two_tier(
        np.asarray(rep.transient.lam)[-1:], p12_w[-1:],
        rep.rates.mu1, rep.rates.mu2, k=spec.k_servers, flow=spec.flow)
    assert float(tr.response[0]) == pytest.approx(
        float(np.asarray(rep.transient.response)[-1]), rel=0.02)
    # Piecewise-stationarity (mode="piecewise"): re-solving the network at
    # the tail window's measured inputs reproduces the tail entry exactly.
    pw = simulate(spec.replace(transient_mode="piecewise"))
    assert float(tr.response[0]) == pytest.approx(
        float(np.asarray(pw.transient.response)[-1]))


def test_saturation_onset_detection():
    """A phase schedule whose second phase drives the miss queue past
    rho2 = 1 reports the onset at the phase boundary window."""
    warm = TrafficSpec(kind="strided", n_requests=800, n_pages=64, stride=1,
                       seed=1)
    cold = TrafficSpec(kind="irm", n_requests=800, n_pages=4096, zipf_s=0.8,
                       seed=2)
    spec = SimSpec(
        traffic=phase_schedule(warm, cold),
        store=StoreConfig(n_lines=64, policy="lru"),
        n_shards=2, lam=50.0, rates=RateSpec(source="paper"),
        mapping="block_cyclic", n_windows=8,
    )
    rep = simulate(spec)
    assert rep.saturation_onset == 4  # windows 0-3 = warm phase, 4+ = cold
    stable = np.asarray(rep.transient.stable)
    assert stable[:4].all() and not stable[4:].all()
    # The fluid default keeps latency finite through overload (the backlog
    # is finite at any finite time) and shows it *growing* while the
    # overload persists — carryover, not per-window resets.
    resp = np.asarray(rep.transient.response)
    assert np.isfinite(resp).all()
    assert resp[5] > resp[4] and resp[6] > resp[5]
    # The piecewise oracle reports the same onset with its historic inf
    # convention for saturated windows.
    pw = simulate(spec.replace(transient_mode="piecewise"))
    assert pw.saturation_onset == 4
    assert np.isinf(np.asarray(pw.transient.response)[4])
    # A uniformly stable scenario reports no onset.
    calm = simulate(SimSpec(
        traffic=warm, store=StoreConfig(n_lines=64, policy="lru"),
        n_shards=2, lam=50.0, rates=RateSpec(source="paper"),
        mapping="block_cyclic", n_windows=4))
    assert calm.saturation_onset is None


def test_windowed_report_json_serializable():
    rep = simulate(SimSpec(
        traffic=TrafficSpec(kind="onoff", n_requests=400, n_pages=128,
                            seed=1, on_len=32, off_len=96),
        store=StoreConfig(n_lines=16, policy="ws"),
        n_shards=2, lam=30.0, rates=RateSpec(source="paper"), n_windows=4))
    d = rep.to_dict()
    back = json.loads(json.dumps(d))  # no default= hook: plain Python only
    assert back["n_windows"] == 4
    assert len(back["transient"]["response"]) == 4
    assert len(back["windows"]["misses"]) == 2
    assert back["spec"]["n_windows"] == 4


# --- non-stationary traffic ------------------------------------------------


def test_phase_schedule_composition():
    a = TrafficSpec(kind="irm", n_requests=300, n_pages=64, seed=1)
    b = TrafficSpec(kind="markov", n_requests=200, n_pages=256,
                    write_fraction=1.0, seed=2)
    sched = phase_schedule(a, b)
    assert sched.kind == "phased"
    assert sched.n_requests == 500 and sched.n_pages == 256
    hash(sched)  # specs stay hashable (sweep dedup requires it)
    pages, writes = make_stream(sched)
    assert pages.shape == (500,) and pages.dtype == np.int32
    ref_a, _ = make_stream(a)
    np.testing.assert_array_equal(pages[:300], ref_a)
    assert not writes[:300].any() and writes[300:].all()


def test_phase_schedule_validation():
    with pytest.raises(ValueError):
        phase_schedule()
    with pytest.raises(ValueError):
        phased_stream([])
    bad = TrafficSpec(kind="phased", n_requests=999, n_pages=64,
                      phases=(TrafficSpec(kind="irm", n_requests=10,
                                          n_pages=64),))
    with pytest.raises(ValueError):
        make_stream(bad)
    with pytest.raises(ValueError):
        make_stream(TrafficSpec(kind="phased", n_requests=10, n_pages=64))


def test_onoff_burst_modulation():
    pages, writes = onoff_stream(1000, 512, on_len=50, off_len=150,
                                 burst_pages=16, write_fraction=0.1, seed=0)
    assert pages.shape == (1000,)
    # Burst stretches are sequential writes over the hot checkpoint range.
    for start in (150, 350, 550, 750):
        assert writes[start:start + 50].all()
        assert pages[start:start + 50].max() < 16
    # Background stretches span the whole page space with few writes.
    bg = writes[:150]
    assert bg.mean() < 0.5
    assert pages[:150].max() >= 16
    with pytest.raises(ValueError):
        onoff_stream(100, 64, on_len=0, off_len=0)


def test_onoff_windows_shift_write_mix():
    """Windows aligned with bursts see a different write mix — the signal
    the windowed report exists to resolve."""
    spec = SimSpec(
        traffic=TrafficSpec(kind="onoff", n_requests=800, n_pages=256,
                            seed=3, on_len=100, off_len=100, burst_pages=8),
        store=StoreConfig(n_lines=32, policy="lru"),
        n_shards=1, lam=20.0, rates=RateSpec(source="paper"), n_windows=8,
    )
    rep = simulate(spec)
    t2w = np.asarray(rep.windows.tier2_writes).sum(axis=0)
    assert t2w.sum() == rep.tier2_writes
    p12_w = np.asarray(rep.transient.p12)
    assert p12_w.std() > 0.05  # bursts visibly modulate the miss fraction
