"""Wall-clock time core (ISSUE 5): timestamped arrivals, time-binned
windows and the fluid transient solver with queue-length carryover.

The load-bearing checks:

- fluid == piecewise in the stationary limit (constant rates, fine
  windows) — the piecewise mode is the fluid solver's oracle;
- carryover: after a step burst the backlog drains *monotonically* over
  several windows instead of snapping back;
- timestamp-binned counters reconcile exactly with whole-stream counters
  across padding caps and length buckets;
- MMPP arrival processes hit their nominal rates empirically;
- per-window expert telemetry reconciles and exposes the learner.
"""
import numpy as np
import pytest

from repro.core.queuing import FluidReport, fluid_two_tier, transient_two_tier
from repro.core.traffic import (
    TrafficSpec,
    arrival_times,
    make_stream,
    make_timed_stream,
    nominal_duration,
    onoff_arrival_times,
    phase_schedule,
)
from repro.sim import RateSpec, SimSpec, simulate, sweep
from repro.storage.tiered_store import (
    StoreConfig,
    partition_streams,
    run_distributed,
    run_stream,
    timestamp_window_ids,
)


# --- fluid solver vs the piecewise-stationary oracle ------------------------


def test_fluid_matches_piecewise_in_stationary_limit():
    """Constant arrival rate, fine windows: the fluid fixed point is the
    stationary solution, so every reported metric matches the piecewise
    oracle within 1% (the ISSUE acceptance bound; warm start makes it
    machine-precision)."""
    lam = np.full(32, 80.0)
    p12 = np.full(32, 0.2)
    pw = transient_two_tier(lam, p12, 1000.0, 33.0, k=1, mode="piecewise")
    fl = transient_two_tier(lam, p12, 1000.0, 33.0, k=1, mode="fluid",
                            dt=0.25)
    assert isinstance(fl, FluidReport)
    for name in ("rho1", "rho2", "w1", "w2", "response"):
        np.testing.assert_allclose(
            np.asarray(getattr(fl, name)), np.asarray(getattr(pw, name)),
            rtol=0.01, err_msg=name)
    np.testing.assert_array_equal(fl.stable, pw.stable)
    assert int(fl.onset()) == int(pw.onset()) == -1


def test_fluid_matches_piecewise_mgk_and_multiserver():
    """The bisection path (k > 1, M/G/k service variance, per-shard mu
    arrays) also lands on its stationary oracle."""
    lam = np.full((3, 12), 60.0)
    p12 = np.full((3, 12), 0.15)
    mu1 = np.array([[400.0], [500.0], [600.0]])
    pw = transient_two_tier(lam, p12, mu1, 33.0, k=2, var_s1=1e-5,
                            mode="piecewise")
    fl = transient_two_tier(lam, p12, mu1, 33.0, k=2, var_s1=1e-5,
                            mode="fluid", dt=0.5)
    np.testing.assert_allclose(fl.response, np.asarray(pw.response),
                               rtol=0.01)
    np.testing.assert_allclose(fl.w1, np.asarray(pw.w1), rtol=0.01)


def test_fluid_carryover_monotone_drain_after_step_burst():
    """A step burst overloads tier 2, then the offered rate drops back:
    the backlog must drain monotonically over the post-burst windows (not
    instantly, not oscillating), from a peak above the stationary baseline
    back down to it."""
    lam = np.array([20.0] * 4 + [200.0] * 4 + [20.0] * 8)
    p12 = np.full(16, 0.2)  # burst lam2 = 40 > mu2 = 33: overload
    fl = fluid_two_tier(lam, p12, 1000.0, 33.0, dt=1.0, k=1)
    q2 = np.asarray(fl.q2)
    w2 = np.asarray(fl.w2)
    # Backlog builds monotonically through the burst...
    assert all(q2[w + 1] > q2[w] for w in range(4, 7))
    # ...and drains monotonically after it, over more than one window.
    assert all(q2[w + 1] < q2[w] for w in range(8, 12))
    baseline = w2[2]
    assert w2[8] > 2.0 * baseline          # non-instant drain
    assert w2[9] > baseline * 1.05         # still elevated one window later
    assert w2[-1] == pytest.approx(baseline, rel=0.01)  # fully drained
    # The piecewise oracle snaps back instantly — the contrast the fluid
    # model exists to fix.
    pw = transient_two_tier(lam, p12, 1000.0, 33.0, k=1, mode="piecewise")
    assert np.asarray(pw.w2)[8] == pytest.approx(baseline, rel=0.01)
    # Same onset semantics: the burst windows flag as unstable.
    assert int(fl.onset()) == int(pw.onset()) == 4


def test_fluid_response_shows_drain_through_idle_gap():
    """A burst followed by a true lam=0 gap: the response series must show
    the residual tier-2 backlog draining through the idle windows (p12
    carried forward), not snap to bare service time while q2/w2 still
    report the drain."""
    lam = np.array([200.0] * 4 + [0.0] * 6)
    p12 = np.full(10, 0.2)
    fl = fluid_two_tier(lam, p12, 1000.0, 33.0, dt=1.0, k=1)
    resp = np.asarray(fl.response)
    q2 = np.asarray(fl.q2)
    assert q2[4] > 1.0                     # backlog survives into the gap
    assert resp[4] > 10.0 / 1000.0         # drain visible in the response
    assert all(resp[w + 1] < resp[w] for w in range(4, 8))
    assert resp[-1] == pytest.approx(1.0 / 1000.0 + 0.2 / 33.0, rel=0.05)


def test_fluid_cold_start_relaxes_to_equilibrium():
    """q0=0 (empty system) relaxes monotonically up to the stationary
    queue length under a constant load."""
    lam = np.full(20, 90.0)
    p12 = np.full(20, 0.2)
    fl = fluid_two_tier(lam, p12, 1000.0, 33.0, dt=0.5, k=1, q0=0.0)
    pw = transient_two_tier(lam, p12, 1000.0, 33.0, k=1, mode="piecewise")
    q2 = np.asarray(fl.q2)
    assert q2[0] < q2[5] <= q2[-1] * 1.001
    assert np.asarray(fl.response)[-1] == pytest.approx(
        float(np.asarray(pw.response)[-1]), rel=0.01)


def test_transient_mode_validation():
    with pytest.raises(ValueError):
        transient_two_tier([1.0], [0.1], 10.0, 5.0, mode="fluid")  # no dt
    with pytest.raises(ValueError):
        transient_two_tier([1.0], [0.1], 10.0, 5.0, mode="nope")
    with pytest.raises(ValueError):
        fluid_two_tier([1.0], [0.1], 10.0, 5.0, dt=0.0)


def test_onset_guarded_against_nan_and_idle_windows():
    """λ=0 gaps (and NaN rate estimates from empty windows) must read as
    idle/stable instead of poisoning the saturation-onset index."""
    lam = np.array([50.0, 0.0, np.nan, 50.0, 0.0])
    p12 = np.array([0.2, np.nan, np.nan, 0.2, 0.0])
    for rep in (
        transient_two_tier(lam, p12, 1000.0, 33.0, mode="piecewise"),
        transient_two_tier(lam, p12, 1000.0, 33.0, mode="fluid", dt=1.0),
    ):
        assert int(rep.onset()) == -1
        assert np.asarray(rep.stable).all()
        assert np.isfinite(np.asarray(rep.rho1)).all()
        assert np.isfinite(np.asarray(rep.rho2)).all()
        assert np.isfinite(np.asarray(rep.response)).all()


# --- arrival-time processes -------------------------------------------------


def test_arrival_times_empirical_vs_nominal_rate():
    """Homogeneous Poisson arrivals hit the nominal rate within sampling
    tolerance, and timestamps never perturb the page sequence."""
    spec = TrafficSpec(kind="irm", n_requests=20000, n_pages=512,
                       rate=250.0, seed=11)
    pages, writes, times = make_timed_stream(spec)
    ref_pages, ref_writes = make_stream(spec)
    np.testing.assert_array_equal(pages, ref_pages)
    np.testing.assert_array_equal(writes, ref_writes)
    assert (np.diff(times) > 0).all()
    assert 20000 / times[-1] == pytest.approx(250.0, rel=0.05)


def test_mmpp_onoff_rates_empirical_vs_nominal():
    """MMPP modulation: OFF stretches arrive at the base rate (Poisson),
    ON bursts exactly at burst_rate (deterministic checkpoint stripes)."""
    n, base, burst = 20000, 50.0, 400.0
    on_len, off_len = 64, 192
    times = onoff_arrival_times(n, base, on_len=on_len, off_len=off_len,
                                burst_rate=burst, seed=3)
    gaps = np.diff(np.concatenate([[0.0], times]))
    on = (np.arange(n) % (on_len + off_len)) >= off_len
    assert 1.0 / gaps[~on].mean() == pytest.approx(base, rel=0.05)
    np.testing.assert_allclose(gaps[on], 1.0 / burst, rtol=1e-9)
    # Unset burst_rate defaults to a multiple of the base rate.
    t2 = onoff_arrival_times(2000, base, on_len=on_len, off_len=off_len,
                             seed=3)
    g2 = np.diff(np.concatenate([[0.0], t2]))[
        (np.arange(2000) % (on_len + off_len)) >= off_len]
    np.testing.assert_allclose(g2, g2[0])
    assert 1.0 / g2[0] > base


def test_phase_schedule_composes_in_seconds():
    """Phases occupy wall-clock spans proportional to n/rate — a fast
    phase is a short, dense stretch of the timeline."""
    fast = TrafficSpec(kind="strided", n_requests=1000, n_pages=64,
                       rate=500.0, seed=1)
    slow = TrafficSpec(kind="markov", n_requests=1000, n_pages=64,
                       rate=50.0, seed=2)
    sched = phase_schedule(fast, slow)
    assert sched.rate == pytest.approx(2000.0 / 22.0)  # 2000 req / 22 s
    assert nominal_duration(sched) == pytest.approx(22.0)
    pages, writes, times = make_timed_stream(sched)
    ref_pages, ref_writes = make_stream(sched)
    np.testing.assert_array_equal(pages, ref_pages)
    span_fast = times[999]
    span_slow = times[-1] - times[999]
    assert span_fast == pytest.approx(2.0, rel=0.15)
    assert span_slow == pytest.approx(20.0, rel=0.15)
    assert (np.diff(times) > 0).all()


def test_arrival_times_validation():
    with pytest.raises(ValueError):
        arrival_times(10, 0.0)
    with pytest.raises(ValueError):
        arrival_times(10, 1.0, gap_rates=np.zeros(10))
    with pytest.raises(ValueError):
        nominal_duration(TrafficSpec(kind="irm", n_requests=10, n_pages=4))


# --- time-binned windowed counters ------------------------------------------

WINDOWED = [
    ("requests", "win_requests"),
    ("hits", "win_hits"),
    ("misses", "win_misses"),
    ("prefetch_hits", "win_prefetch_hits"),
    ("tier2_reads", "win_tier2_reads"),
    ("tier2_writes", "win_tier2_writes"),
    ("evictions", "win_evictions"),
]


def test_timestamp_binned_counters_reconcile_exactly():
    """Time-binned windowed counters sum bit-exactly to the (padding-
    corrected) whole-stream counters, overflow arrivals included."""
    spec = TrafficSpec(kind="onoff", n_requests=1500, n_pages=256,
                       rate=60.0, write_fraction=0.2, seed=5)
    pages, writes, times = make_timed_stream(spec)
    stats, counts = run_distributed(
        StoreConfig(n_lines=16, policy="ws"), pages, writes,
        n_shards=4, mapping="block_cyclic", n_pages=256,
        n_windows=10, timestamps=times, window_dt=2.0,
    )
    for total_name, win_name in WINDOWED:
        np.testing.assert_array_equal(
            np.asarray(getattr(stats, win_name), np.int64).sum(axis=-1),
            np.asarray(getattr(stats, total_name), np.int64),
            err_msg=win_name)
    # The binning matches the host-side reference ids exactly.
    ids = timestamp_window_ids(times, 10, 2.0)
    np.testing.assert_array_equal(
        np.asarray(stats.win_requests).sum(axis=0),
        np.bincount(ids, minlength=10))


def test_timestamp_windows_independent_of_padding_cap():
    """Padding carries timestamp -1 (dropped), so time-binned counters are
    bit-identical whatever padded cap / length bucket the engine ran at."""
    import jax
    import jax.numpy as jnp

    spec = TrafficSpec(kind="poisson", n_requests=400, n_pages=64,
                       rate=80.0, write_fraction=0.2, seed=9)
    pages, writes, times = make_timed_stream(spec)
    base = partition_streams(pages, writes, n_shards=3, mapping="random",
                             n_pages=64, times=times)
    base_cap = base[0].shape[1]
    results = []
    for cap in (base_cap, 2 * base_cap):
        sh_p, sh_w, counts, owner, sh_t = partition_streams(
            pages, writes, n_shards=3, mapping="random", n_pages=64,
            cap=cap, times=times)
        stats = jax.vmap(
            lambda p, w, t: run_stream(
                StoreConfig(n_lines=16, policy="lru"), p, w,
                n_windows=5, timestamps=t, window_dt=1.0)
        )(jnp.asarray(sh_p), jnp.asarray(sh_w), jnp.asarray(sh_t))
        results.append(stats)
    for _, win_name in WINDOWED:
        np.testing.assert_array_equal(
            np.asarray(getattr(results[0], win_name)),
            np.asarray(getattr(results[1], win_name)),
            err_msg=f"{win_name} depends on the padding cap")
    np.testing.assert_array_equal(
        np.asarray(results[0].win_expert_use),
        np.asarray(results[1].win_expert_use))


def test_simulate_measures_bursty_pooled_rate():
    """The point of the refactor: with wall-clock windows the *pooled*
    per-window arrival rate tracks the MMPP modulation (request-index
    windows made it flat by construction)."""
    spec = SimSpec(
        traffic=TrafficSpec(kind="onoff", n_requests=3000, n_pages=256,
                            rate=120.0, burst_rate=1200.0, on_len=100,
                            off_len=200, seed=2),
        store=StoreConfig(n_lines=32, policy="lru"),
        n_shards=2, lam=60.0, rates=RateSpec(source="paper"),
        window_dt=1.0,
    )
    rep = simulate(spec)
    pooled = np.asarray(rep.windows.lam).sum(axis=0) / spec.n_shards
    assert pooled.max() > 1.5 * max(pooled.min(), 1.0)
    assert rep.window_duration_s == 1.0
    # Same stream through request-index windows: pooled rate ~flat.
    flat = simulate(spec.replace(window_dt=None,
                                 n_windows=rep.n_windows))
    pooled_flat = np.asarray(flat.windows.lam).sum(axis=0) / spec.n_shards
    assert pooled_flat.std() / pooled_flat.mean() < 0.01
    assert pooled.std() / pooled.mean() > 0.2
    # Totals are independent of the window axis.
    assert rep.misses == flat.misses and rep.hits == flat.hits


def test_window_grid_derivation_and_signature():
    base = SimSpec(
        traffic=TrafficSpec(kind="irm", n_requests=1000, n_pages=128,
                            seed=1),
        n_shards=4, lam=50.0, window_dt=0.5,
    )
    # horizon = 1000 / (50*4) = 5 s, padded by 4 std of the realized span
    # (4 * sqrt(1000)/200 ~ 0.63 s) -> 12 windows of 0.5 s.
    assert base.window_grid() == (12, 0.5)
    assert base.replace(n_windows=6).window_grid() == (6, 0.5)
    assert base.replace(window_dt=None).window_grid() == (1, None)
    # lam enters the cache signature only on the wall-clock path.
    assert (base.cache_signature()
            != base.replace(lam=80.0).cache_signature())
    untimed = base.replace(window_dt=None)
    assert (untimed.cache_signature()
            == untimed.replace(lam=80.0).cache_signature())
    with pytest.raises(ValueError):
        SimSpec(traffic=base.traffic, window_dt=-1.0)
    with pytest.raises(ValueError):
        SimSpec(traffic=base.traffic, transient_mode="nope")


def test_derived_grid_absorbs_realized_horizon_fluctuation():
    """The sampled Poisson span fluctuates around the nominal horizon; the
    derived grid's 4-sigma slack must keep overflow arrivals from piling
    into the clipped last bin as a phantom rate spike / saturation onset."""
    base = SimSpec(
        traffic=TrafficSpec(kind="irm", n_requests=4000, n_pages=256,
                            rate=200.0, seed=4),
        store=StoreConfig(n_lines=64, policy="lru"),
        n_shards=2, lam=100.0, rates=RateSpec(source="paper"),
        window_dt=0.25,
    )
    for seed in (0, 2, 4):
        rep = simulate(base.replace(**{"traffic.seed": seed}))
        pooled = np.asarray(rep.windows.lam).sum(axis=0) / base.n_shards
        # No clipping pile-up: every window's measured rate stays within
        # sampling noise of the offered per-process rate (100 req/s) —
        # before the slack, unlucky seeds piled the overflow into the
        # last bin as a multi-x spike.
        assert pooled.max() < 2.0 * 100.0
        # Early-finishing seeds leave trailing slack windows idle; they
        # solve as empty queues, never NaN.
        assert np.isfinite(np.asarray(rep.transient.response)).all()
        assert np.asarray(rep.transient.stable)[-1]


def test_trace_with_window_dt_covers_trace_horizon():
    """A trace longer than the spec's nominal traffic must get a window
    grid sized to the *trace*, not the spec — no tail pile-up in the last
    bin (the grid-vs-trace mismatch regression)."""
    spec = SimSpec(
        traffic=TrafficSpec(kind="irm", n_requests=500, n_pages=128,
                            seed=1),
        store=StoreConfig(n_lines=32, policy="lru"),
        n_shards=2, lam=15.0, rates=RateSpec(source="paper"),
        window_dt=1.0,
    )
    rng = np.random.default_rng(0)
    n = 2000
    trace = (rng.integers(0, 128, size=n), np.zeros(n, bool))
    rep = simulate(spec, trace=trace)
    # Synthesized deterministic arrivals at agg rate 30/s -> ~67 s horizon
    # (before the fix the grid stopped at the spec's nominal 500-request
    # horizon and piled the 1500-request tail into the last bin).
    assert rep.n_windows == 67
    pooled = np.asarray(rep.windows.lam).sum(axis=0) / spec.n_shards
    np.testing.assert_allclose(pooled[:-1], 15.0, rtol=0.05)
    assert rep.saturation_onset is None
    # An explicit timed trace is honored too.
    times = (1.0 + np.arange(n)) / 400.0   # 5 s horizon at 400 req/s
    rep_t = simulate(spec, trace=trace + (times,))
    assert rep_t.n_windows == 5
    assert rep_t.requests == n
    # Absolute (epoch-style) trace timestamps are normalized to the trace
    # start: same grid, same binning, no epoch-sized window counts or
    # int32 bin overflow.
    rep_e = simulate(spec, trace=trace + (times + 1.75e9,))
    assert rep_e.n_windows == 5
    np.testing.assert_array_equal(np.asarray(rep_e.windows.requests),
                                  np.asarray(rep_t.windows.requests))


def test_timestamp_window_ids_saturate_not_wrap():
    """Bin ratios beyond int32 saturate into the last bin (identically to
    the engine's in-graph cast), never wrap negative into bin 0."""
    from repro.storage.tiered_store import timestamp_window_ids

    ids = timestamp_window_ids(np.array([1e9, 0.1, -1.0]), 50, 0.3)
    np.testing.assert_array_equal(ids, [49, 0, 50])


# --- windowed expert telemetry ----------------------------------------------


def test_windowed_expert_telemetry_reconciles():
    """Per-window expert_use sums to the whole-stream expert_use and to
    the eviction counters; the last window's weights equal the final
    weights."""
    spec = TrafficSpec(kind="mixed", n_requests=1200, n_pages=256,
                       seed=4)
    pages, writes = make_stream(spec)
    cfg = StoreConfig(n_lines=32, policy="ws")
    st = run_stream(cfg, pages, writes, n_windows=6)
    use = np.asarray(st.win_expert_use, np.int64)
    assert use.shape == (6, 3)
    np.testing.assert_array_equal(use.sum(axis=0),
                                  np.asarray(st.expert_use, np.int64))
    np.testing.assert_array_equal(use.sum(axis=1),
                                  np.asarray(st.win_evictions, np.int64))
    np.testing.assert_allclose(np.asarray(st.win_weights)[-1],
                               np.asarray(st.final_weights), rtol=1e-6)


def test_report_carries_expert_windows_and_ffills_weights():
    spec = SimSpec(
        traffic=phase_schedule(
            TrafficSpec(kind="strided", n_requests=600, n_pages=64,
                        stride=1, seed=1),
            TrafficSpec(kind="irm", n_requests=600, n_pages=512,
                        zipf_s=0.9, seed=2),
        ),
        store=StoreConfig(n_lines=32, policy="ws"),
        n_shards=2, lam=40.0, rates=RateSpec(source="paper"), n_windows=8,
    )
    rep = simulate(spec)
    use = np.asarray(rep.windows.expert_use)
    weights = np.asarray(rep.windows.weights)
    assert use.shape == (2, 8, 3) and weights.shape == (2, 8, 3)
    assert use.sum() == rep.evictions
    # Weights rows are carried forward over empty windows: every row is a
    # probability-ish vector (positive sum), never the engine's zero
    # sentinel.
    assert (weights.sum(axis=-1) > 0).all()
    # JSON round-trips with the new fields.
    import json
    d = json.loads(json.dumps(rep.to_dict()))
    assert len(d["windows"]["expert_use"][0]) == 8


# --- sweep integration -------------------------------------------------------


@pytest.mark.parametrize("policy", ["lru", "ws"])
def test_sweep_timed_batched_matches_unbatched(policy):
    base = SimSpec(
        traffic=TrafficSpec(kind="irm", n_requests=300, n_pages=128,
                            write_fraction=0.2, seed=3),
        store=StoreConfig(n_lines=32, policy=policy),
        n_shards=2, lam=50.0, rates=RateSpec(source="paper"),
        window_dt=0.4,
    )
    axes = {"store.alpha": [0.3, 0.7], "lam": [50.0, 75.0]}
    res = sweep(base, axes)
    ref = sweep(base, axes, batch=False)
    for r1, r2 in zip(res.reports, ref.reports):
        assert r1.misses == r2.misses and r1.hits == r2.hits
        np.testing.assert_array_equal(np.asarray(r1.windows.requests),
                                      np.asarray(r2.windows.requests))
        np.testing.assert_array_equal(np.asarray(r1.windows.expert_use),
                                      np.asarray(r2.windows.expert_use))
        np.testing.assert_allclose(np.asarray(r1.transient.response),
                                   np.asarray(r2.transient.response),
                                   rtol=1e-10)
