"""Fused cache-scan engine exactness harness.

Three rings of defense around the fused tier-1 request loop
(`repro.kernels.cache_scan.fused_cache_scan` and its megabatch/chunked
wiring):

1. **Engine parity** — the fused engine is bit-identical to the original
   per-step ``lax.scan`` reference (``engine="scan"``) over policy ×
   mapping × prefetch grids, on windowed, wall-clock-binned, faulted and
   chunk-streamed workloads, including per-tenant attribution — every
   counter, not a statistical comparison.
2. **Kernel goldens** — the Pallas ``cache_scan_kernel`` (interpret mode
   everywhere; compiled mode under the ``kernels`` marker where a real
   accelerator backend exists) against the pure-jax oracle
   ``cache_scan_ref`` it falls back to in production on CPU.
3. **Invariance fences** — padding/bucketing choices change no windowed
   counter (pads scatter to the dropped id), sweep results are identical
   with buffer donation on and off (the undonated path must stay
   available), and unknown engine names fail fast.

Property-based fuzzing (hypothesis) deepens ring 1 when the library is
installed; the fixed-seed tests always run.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.cache_scan import (
    cache_scan_compile_count,
    cache_scan_kernel,
    cache_scan_noise,
    reset_cache_scan_compile_count,
)
from repro.kernels.ref import cache_scan_ref
from repro.sim.engine import tier1_counters
from repro.sim.spec import (
    FaultSpec,
    RetryPolicy,
    SimSpec,
    StoreConfig,
    TrafficSpec,
    device_degrade,
    shard_down,
)
from repro.sim.stream import stream_tier1_counters
from repro.sim.sweep import sweep
from repro.core.traffic import TenantSpec
from repro.storage.tiered_store import init_store, run_stream, _init_accum

# ---------------------------------------------------------------------------
# helpers


def _assert_trees_equal(a, b, ctx="", skip=()):
    for f in a._fields:
        if f in skip:
            continue
        x, y = np.asarray(getattr(a, f)), np.asarray(getattr(b, f))
        np.testing.assert_array_equal(x, y, err_msg=f"{ctx} field={f}")


_REPORT_COUNTERS = ("requests", "hits", "misses", "prefetch_hits",
                    "tier2_reads", "tier2_writes", "evictions")


def _assert_reports_equal(a, b, ctx=""):
    """Integer counters + windowed telemetry of two SimReports, bit-exact."""
    for f in _REPORT_COUNTERS:
        assert getattr(a, f) == getattr(b, f), f"{ctx} field={f}"
    for f in a.windows._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a.windows, f)),
            np.asarray(getattr(b.windows, f)),
            err_msg=f"{ctx} windows.{f}")


def _stream(seed, n=1200, n_pages=300, wf=0.3):
    rng = np.random.default_rng(seed)
    pages = jnp.asarray(rng.integers(0, n_pages, n), jnp.int32)
    writes = jnp.asarray(rng.random(n) < wf)
    return pages, writes


# ---------------------------------------------------------------------------
# ring 1: fused engine vs the per-step scan reference


@pytest.mark.parametrize("policy", ["ws", "lru", "lfu", "random"])
@pytest.mark.parametrize("prefetch", [False, True])
def test_run_stream_fused_matches_scan(policy, prefetch):
    pages, writes = _stream(0)
    win = jnp.asarray(np.minimum(np.arange(1200) // 150, 7), jnp.int32)
    cfg = StoreConfig(n_lines=48, policy=policy, prefetch=prefetch)
    fused = run_stream(cfg, pages, writes, window_ids=win, n_windows=8,
                       seed=5, engine="fused")
    scan = run_stream(cfg, pages, writes, window_ids=win, n_windows=8,
                      seed=5, engine="scan")
    _assert_trees_equal(fused, scan, ctx=f"{policy}/pf={prefetch}")


@pytest.mark.parametrize("mapping", ["block", "round_robin", "random",
                                     "block_cyclic"])
def test_engine_fused_matches_scan_across_mappings(mapping):
    spec = SimSpec(
        traffic=TrafficSpec(kind="irm", n_requests=1500, n_pages=400,
                            rate=200.0, seed=3),
        store=StoreConfig(n_lines=32, policy="ws"),
        n_shards=3, n_windows=6, mapping=mapping,
    )
    _assert_trees_equal(tier1_counters(spec, engine="fused"),
                        tier1_counters(spec, engine="scan"), ctx=mapping)


def test_engine_fused_matches_scan_faulted_timed():
    """Wall-clock windows + failover remap + retry storm: the fault
    schedule rides the engine as data, so the fused path must reproduce
    the scan bit for bit on the degraded timeline too."""
    spec = SimSpec(
        traffic=TrafficSpec(kind="poisson", n_requests=1500, n_pages=400,
                            rate=200.0, seed=7),
        store=StoreConfig(n_lines=32, policy="ws"),
        n_shards=4, n_windows=16, window_dt=0.5,
        faults=FaultSpec(
            events=(shard_down(1, 0.8, 2.4),
                    device_degrade(2, 0.4, 1.5, 4.0)),
            retry=RetryPolicy(timeout=0.05, max_retries=2, backoff_init=0.4),
        ),
    )
    _assert_trees_equal(tier1_counters(spec, engine="fused"),
                        tier1_counters(spec, engine="scan"), ctx="faulted")


def test_chunked_fused_matches_one_shot_scan():
    spec = SimSpec(
        traffic=TrafficSpec(kind="irm", n_requests=3000, n_pages=400,
                            rate=200.0, seed=3),
        n_shards=4, n_windows=8,
    )
    chunked, _, _ = stream_tier1_counters(spec, chunk=128, engine="fused")
    one_shot = tier1_counters(spec, engine="scan")
    _assert_trees_equal(chunked, one_shot, ctx="chunked",
                        skip=("final_weights", "tenants"))


def test_chunk_size_invariance_fused():
    spec = SimSpec(
        traffic=TrafficSpec(kind="markov", n_requests=2000, n_pages=300,
                            rate=150.0, seed=9),
        n_shards=2, n_windows=5,
    )
    a, _, _ = stream_tier1_counters(spec, chunk=100, engine="fused")
    b, _, _ = stream_tier1_counters(spec, chunk=512, engine="fused")
    _assert_trees_equal(a, b, ctx="chunk-size", skip=("tenants",))


def test_tenant_mix_chunked_fused_matches_scan():
    spec = SimSpec(
        traffic=TrafficSpec(
            kind="tenant_mix", n_requests=2000, n_pages=600, rate=300.0,
            seed=5,
            tenants=(TenantSpec("a", 180.0, 400, write_fraction=0.2),
                     TenantSpec("b", 120.0, 200, zipf_s=1.3, seed=9)),
        ),
        n_shards=2, n_windows=8,
    )
    ca, ta, _ = stream_tier1_counters(spec, chunk=256, engine="fused")
    cb, tb, _ = stream_tier1_counters(spec, chunk=256, engine="scan")
    _assert_trees_equal(ca, cb, ctx="tenant", skip=("tenants",))
    _assert_trees_equal(ta, tb, ctx="tenant-attribution")


def test_sweep_fused_matches_scan():
    base = SimSpec(
        traffic=TrafficSpec(kind="irm", n_requests=800, n_pages=256,
                            rate=150.0, seed=2),
        store=StoreConfig(n_lines=24),
        n_shards=2, n_windows=4,
    )
    axes = {"store.alpha": (0.3, 0.7), "store.policy": ("ws", "lfu")}
    fused = sweep(base, axes, engine="fused")
    scan = sweep(base, axes, engine="scan")
    assert len(fused.reports) == len(scan.reports) == 4
    for a, b in zip(fused.reports, scan.reports):
        _assert_reports_equal(a, b, ctx="sweep")


# ---------------------------------------------------------------------------
# ring 2: Pallas kernel goldens


def _kernel_case(policy, prefetch, seed=1, L=512, N=32, W=8):
    rng = np.random.default_rng(seed)
    pages = jnp.asarray(rng.integers(0, 200, L), jnp.int32)
    writes = jnp.asarray((rng.random(L) < 0.3).astype(np.int32))
    win = jnp.asarray(np.minimum(np.arange(L) // (L // W), W - 1), jnp.int32)
    cfg = StoreConfig(n_lines=N, policy=policy, prefetch=prefetch)
    hyper = cfg.hyper()
    st0 = init_store(cfg, 9)
    noise = cache_scan_noise(st0.key, L, N)
    return cfg, hyper, st0, noise, pages, writes, win, W


def _kernel_vs_ref(policy, prefetch, interpret):
    cfg, hyper, st0, noise, pages, writes, win, W = _kernel_case(
        policy, prefetch)
    final, acc = cache_scan_ref(
        st0, _init_accum(W), pages, writes, win, hyper, noise,
        epoch_width=cfg.epoch_width, pred_cap=cfg.pred_cap,
        prefetch=cfg.prefetch, prefetch_width=cfg.prefetch_width,
        n_windows=W)
    out = cache_scan_kernel(
        pages[None], writes[None], win[None], noise,
        hyper.alpha, hyper.beta, hyper.threshold, hyper.policy_idx,
        n_lines=cfg.n_lines, epoch_width=cfg.epoch_width,
        pred_cap=cfg.pred_cap, prefetch=cfg.prefetch,
        prefetch_width=cfg.prefetch_width,
        prefetch_buf=st0.pf.ptags.shape[-1], n_windows=W,
        interpret=interpret)
    for f in acc._fields:
        x = np.asarray(getattr(acc, f))
        y = np.asarray(out[f][0]).reshape(x.shape)
        np.testing.assert_array_equal(
            y, x, err_msg=f"{policy}/pf={prefetch} field={f}")
    np.testing.assert_array_equal(np.asarray(out["final_weights"][0]),
                                  np.asarray(final.ols.weights))


@pytest.mark.parametrize("policy,prefetch",
                         [("ws", False), ("lru", False), ("lfu", False),
                          ("random", False), ("ws", True), ("random", True)])
def test_pallas_interpret_matches_ref(policy, prefetch):
    """Golden: interpret-mode Pallas kernel == pure-jax oracle, bit for
    bit — counters, windowed telemetry and final expert weights."""
    _kernel_vs_ref(policy, prefetch, interpret=True)


def test_pallas_interpret_batched_rows_independent():
    """Rows of one grid launch must not bleed VMEM scratch state into each
    other: a [2, L] batch equals two independent single-row launches."""
    cfg, hyper, st0, noise, pages, writes, win, W = _kernel_case("ws", False)
    pages2 = jnp.stack([pages, pages[::-1]])
    writes2 = jnp.stack([writes, writes[::-1]])
    win2 = jnp.stack([win, win])
    both = cache_scan_kernel(
        pages2, writes2, win2, noise,
        hyper.alpha, hyper.beta, hyper.threshold, hyper.policy_idx,
        n_lines=cfg.n_lines, epoch_width=cfg.epoch_width,
        pred_cap=cfg.pred_cap, prefetch=False,
        prefetch_width=cfg.prefetch_width,
        prefetch_buf=st0.pf.ptags.shape[-1], n_windows=W, interpret=True)
    for r in range(2):
        solo = cache_scan_kernel(
            pages2[r:r + 1], writes2[r:r + 1], win2[r:r + 1], noise,
            hyper.alpha, hyper.beta, hyper.threshold, hyper.policy_idx,
            n_lines=cfg.n_lines, epoch_width=cfg.epoch_width,
            pred_cap=cfg.pred_cap, prefetch=False,
            prefetch_width=cfg.prefetch_width,
            prefetch_buf=st0.pf.ptags.shape[-1], n_windows=W,
            interpret=True)
        for f in both:
            np.testing.assert_array_equal(
                np.asarray(both[f][r]), np.asarray(solo[f][0]),
                err_msg=f"row={r} field={f}")


@pytest.mark.kernels
def test_pallas_compiled_matches_ref():
    """Compiled-mode golden — only meaningful on an accelerator backend
    (deselect with ``-m 'not kernels'``; auto-skips on CPU, where
    non-interpret Pallas does not lower)."""
    if jax.default_backend() == "cpu":
        pytest.skip("no accelerator backend: compiled Pallas needs TPU/GPU")
    _kernel_vs_ref("ws", False, interpret=False)
    _kernel_vs_ref("lru", True, interpret=False)


# ---------------------------------------------------------------------------
# ring 3: invariance fences


def test_padding_does_not_leak_into_windows():
    """Bucket-style padding (edge-repeat pages, window id == n_windows)
    must leave every windowed counter untouched and add only pure hits to
    the whole-stream totals — the invariant the megabatch buckets and the
    chunk engine's masked tail both rely on."""
    pages, writes = _stream(4, n=600)
    win = jnp.asarray(np.minimum(np.arange(600) // 100, 5), jnp.int32)
    cfg = StoreConfig(n_lines=32, policy="ws")
    base = run_stream(cfg, pages, writes, window_ids=win, n_windows=6,
                      seed=3, engine="fused")
    for n_pad in (1, 37, 256):
        pad_pages = jnp.concatenate(
            [pages, jnp.full((n_pad,), pages[-1], jnp.int32)])
        pad_writes = jnp.concatenate(
            [writes, jnp.zeros((n_pad,), writes.dtype)])
        pad_win = jnp.concatenate(
            [win, jnp.full((n_pad,), 6, jnp.int32)])
        padded = run_stream(cfg, pad_pages, pad_writes, window_ids=pad_win,
                            n_windows=6, seed=3, engine="fused")
        for f in base._fields:
            x, y = np.asarray(getattr(base, f)), np.asarray(getattr(padded, f))
            if f == "requests":
                assert y - x == n_pad
            elif f == "hits":
                assert y - x == n_pad, "pads must be pure hits"
            else:
                np.testing.assert_array_equal(
                    y, x, err_msg=f"n_pad={n_pad} field={f}")


def test_sweep_donation_off_matches_on():
    """The undonated dispatch path must stay available and bit-identical
    — the donation is a pure buffer-lifetime optimization."""
    base = SimSpec(
        traffic=TrafficSpec(kind="irm", n_requests=600, n_pages=200,
                            rate=120.0, seed=8),
        store=StoreConfig(n_lines=16),
        n_shards=2, n_windows=4,
    )
    axes = {"store.policy": ("ws", "lru"), "store.beta": (0.5, 0.8)}
    donated = sweep(base, axes, donate=True)
    plain = sweep(base, axes, donate=False)
    assert len(donated.reports) == len(plain.reports) == 4
    for a, b in zip(donated.reports, plain.reports):
        _assert_reports_equal(a, b, ctx="donate")


def test_stream_donation_off_matches_on():
    spec = SimSpec(
        traffic=TrafficSpec(kind="irm", n_requests=1000, n_pages=200,
                            rate=150.0, seed=6),
        n_shards=2, n_windows=4,
    )
    a, _, _ = stream_tier1_counters(spec, chunk=256, donate=True)
    b, _, _ = stream_tier1_counters(spec, chunk=256, donate=False)
    _assert_trees_equal(a, b, ctx="stream-donate", skip=("tenants",))


def test_unknown_engine_rejected():
    pages, writes = _stream(1, n=64)
    with pytest.raises(ValueError, match="unknown engine"):
        run_stream(StoreConfig(n_lines=8), pages, writes, engine="bogus")
    spec = SimSpec(traffic=TrafficSpec(kind="irm", n_requests=64,
                                       n_pages=32, rate=50.0, seed=1),
                   n_shards=1)
    with pytest.raises(ValueError, match="unknown engine"):
        stream_tier1_counters(spec, engine="bogus")


def test_sweep_profile_splits_engine_stage():
    """Satellite: the engine stage reports submit/wait sub-timings that
    sum to the total, and the chunked path reports per-chunk phases."""
    base = SimSpec(
        traffic=TrafficSpec(kind="irm", n_requests=400, n_pages=128,
                            rate=100.0, seed=5),
        store=StoreConfig(n_lines=16),
        n_shards=2, n_windows=4,
    )
    res = sweep(base, {"store.alpha": (0.4, 0.6)}, profile=True)
    prof = res.profile
    assert {"engine_dispatch", "engine_dispatch_submit",
            "engine_dispatch_wait"} <= set(prof)
    # Sub-timings bracket narrower regions than the stage total, so they
    # sum to slightly less; allow a small absolute slack for timer overhead.
    parts = (prof["engine_dispatch_submit"] + prof["engine_dispatch_wait"])
    assert parts > 0
    assert abs(prof["engine_dispatch"] - parts) < 0.05

    spec = SimSpec(traffic=TrafficSpec(kind="irm", n_requests=600,
                                       n_pages=128, rate=100.0, seed=5),
                   n_shards=2, n_windows=4)
    chunk_prof = {}
    stream_tier1_counters(spec, chunk=128, profile=chunk_prof)
    assert {"stream_chunk_host", "stream_chunk_dispatch",
            "stream_chunk_wait", "stream_chunks"} <= set(chunk_prof)
    assert chunk_prof["stream_chunks"] >= 4


def test_compile_count_small_traced_grid():
    """A traced-knob grid (alpha x policy) over one structural config must
    trace the fused engine at most twice (one-shot megabatch + at most one
    extra length bucket)."""
    base = SimSpec(
        traffic=TrafficSpec(kind="irm", n_requests=500, n_pages=160,
                            rate=120.0, seed=12),
        store=StoreConfig(n_lines=20),  # distinct shape => own compile
        n_shards=2, n_windows=4,
    )
    axes = {"store.alpha": (0.3, 0.5, 0.7),
            "store.policy": ("ws", "lru", "lfu")}
    sweep(base, axes)  # warm the jit/engine caches
    reset_cache_scan_compile_count()
    sweep(base, axes)
    assert cache_scan_compile_count() <= 2


# ---------------------------------------------------------------------------
# property-based fuzz (optional dependency)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:            # pragma: no cover - optional dependency
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(
        seed=st.integers(0, 2**16),
        n=st.integers(1, 200),
        n_pages=st.integers(1, 64),
        n_lines=st.integers(1, 24),
        policy=st.sampled_from(["ws", "lru", "lfu", "random"]),
        prefetch=st.booleans(),
    )
    def test_fuzz_fused_matches_scan(seed, n, n_pages, n_lines, policy,
                                     prefetch):
        rng = np.random.default_rng(seed)
        pages = jnp.asarray(rng.integers(0, n_pages, n), jnp.int32)
        writes = jnp.asarray(rng.random(n) < 0.4)
        win = jnp.asarray(rng.integers(0, 4, n), jnp.int32)
        cfg = StoreConfig(n_lines=n_lines, policy=policy, prefetch=prefetch)
        fused = run_stream(cfg, pages, writes, window_ids=win, n_windows=4,
                           seed=seed % 7, engine="fused")
        scan = run_stream(cfg, pages, writes, window_ids=win, n_windows=4,
                          seed=seed % 7, engine="scan")
        _assert_trees_equal(fused, scan, ctx=f"fuzz-{seed}")
